//! Serve-sweep report rows and the JSON / Markdown emitters
//! (`torrent serve-sim --out PREFIX` writes both).
//!
//! Lives in `serve` (not `analysis`) so `analysis::experiments` can
//! import the row type without a module cycle. The JSON schema is
//! `torrent-serve-sweep-v1`: flat rows, snake_case keys, one object per
//! (fabric × scheduler × threads × rate) load point — the same
//! hand-rolled no-serde convention as the bench baselines. The
//! resilience sweep (`torrent resilience-sweep`, ISSUE 9) emits its own
//! `torrent-resilience-sweep-v1` rows, one per (fabric × fault-policy ×
//! seed) cell.

/// One swept load point. Latencies in cycles; `util` is fabric
/// utilization in `[0, 1]` — router activity normalized by the
/// topology's aggregate port capacity
/// ([`crate::serve::stats::utilization`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSweepRow {
    pub fabric: &'static str,
    pub sched: &'static str,
    pub threads: usize,
    /// Offered arrival rate (tasks per kilocycle, the x-axis).
    pub rate_per_kcycle: u64,
    pub offered: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub p50: u64,
    pub p99: u64,
    pub p999: u64,
    pub util: f64,
    /// Peak admission-queue depth over the run (the measured-pending
    /// column in EXPERIMENTS.md).
    pub pending_peak: usize,
}

/// Render sweep rows as `torrent-serve-sweep-v1` JSON.
pub fn sweep_json(rows: &[ServeSweepRow]) -> String {
    let mut out = String::from("{\n  \"schema\": \"torrent-serve-sweep-v1\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"fabric\": \"{}\", \"sched\": \"{}\", \"threads\": {}, \
             \"rate_per_kcycle\": {}, \"offered\": {}, \"admitted\": {}, \
             \"rejected\": {}, \"completed\": {}, \"p50\": {}, \"p99\": {}, \
             \"p999\": {}, \"util\": {:.6}, \"pending_peak\": {}}}{}\n",
            r.fabric,
            r.sched,
            r.threads,
            r.rate_per_kcycle,
            r.offered,
            r.admitted,
            r.rejected,
            r.completed,
            r.p50,
            r.p99,
            r.p999,
            r.util,
            r.pending_peak,
            if i + 1 == rows.len() { "" } else { "," },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Render sweep rows as a Markdown latency/utilization curve, one table
/// per (fabric × scheduler × threads) leg in input order.
pub fn sweep_markdown(rows: &[ServeSweepRow]) -> String {
    let mut out = String::from("# Serve sweep — tail latency vs offered load\n");
    let mut cur: Option<(&str, &str, usize)> = None;
    for r in rows {
        let leg = (r.fabric, r.sched, r.threads);
        if cur != Some(leg) {
            cur = Some(leg);
            out.push_str(&format!(
                "\n## {} · {} · t={}\n\n\
                 | rate/kcycle | offered | admitted | rejected | completed | p50 | p99 | p999 | util | pending peak |\n\
                 |---|---|---|---|---|---|---|---|---|---|\n",
                r.fabric, r.sched, r.threads
            ));
        }
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {:.3} | {} |\n",
            r.rate_per_kcycle,
            r.offered,
            r.admitted,
            r.rejected,
            r.completed,
            r.p50,
            r.p99,
            r.p999,
            r.util,
            r.pending_peak,
        ));
    }
    out
}

/// One resilience-sweep cell: a (fabric × fault-policy × seed) serving
/// run under an armed fault schedule. `policy` is the repair posture
/// (`fail-stop`, `restream`, `resume`, `resume+reroute`), not the
/// admission policy.
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceRow {
    pub fabric: &'static str,
    pub policy: &'static str,
    pub seed: u64,
    pub offered: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    /// completed / offered — the availability axis.
    pub availability: f64,
    /// Destination-bytes delivered (served fraction for repaired tasks).
    pub goodput_bytes: u64,
    /// Bytes repair chains re-streamed (the resume savings axis).
    pub restreamed_bytes: u64,
    pub repaired_tasks: u64,
    /// Distinct requests that took the client retry path.
    pub retried: u64,
    pub p99: u64,
}

/// Render resilience rows as `torrent-resilience-sweep-v1` JSON.
pub fn resilience_json(rows: &[ResilienceRow]) -> String {
    let mut out =
        String::from("{\n  \"schema\": \"torrent-resilience-sweep-v1\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"fabric\": \"{}\", \"policy\": \"{}\", \"seed\": {}, \
             \"offered\": {}, \"completed\": {}, \"failed\": {}, \"rejected\": {}, \
             \"availability\": {:.6}, \"goodput_bytes\": {}, \"restreamed_bytes\": {}, \
             \"repaired_tasks\": {}, \"retried\": {}, \"p99\": {}}}{}\n",
            r.fabric,
            r.policy,
            r.seed,
            r.offered,
            r.completed,
            r.failed,
            r.rejected,
            r.availability,
            r.goodput_bytes,
            r.restreamed_bytes,
            r.repaired_tasks,
            r.retried,
            r.p99,
            if i + 1 == rows.len() { "" } else { "," },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Render resilience rows as Markdown, one table per fabric in input
/// order, policies as rows.
pub fn resilience_markdown(rows: &[ResilienceRow]) -> String {
    let mut out = String::from("# Resilience sweep — serving under injected faults\n");
    let mut cur: Option<&str> = None;
    for r in rows {
        if cur != Some(r.fabric) {
            cur = Some(r.fabric);
            out.push_str(&format!(
                "\n## {}\n\n\
                 | policy | seed | offered | completed | failed | rejected | availability | goodput B | restreamed B | repaired | retried | p99 |\n\
                 |---|---|---|---|---|---|---|---|---|---|---|---|\n",
                r.fabric
            ));
        }
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {:.4} | {} | {} | {} | {} | {} |\n",
            r.policy,
            r.seed,
            r.offered,
            r.completed,
            r.failed,
            r.rejected,
            r.availability,
            r.goodput_bytes,
            r.restreamed_bytes,
            r.repaired_tasks,
            r.retried,
            r.p99,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(rate: u64, threads: usize) -> ServeSweepRow {
        ServeSweepRow {
            fabric: "mesh",
            sched: "greedy",
            threads,
            rate_per_kcycle: rate,
            offered: 40,
            admitted: 38,
            rejected: 2,
            completed: 38,
            p50: 900,
            p99: 2100,
            p999: 2500,
            util: 0.125,
            pending_peak: 5,
        }
    }

    #[test]
    fn json_has_schema_and_balanced_braces() {
        let s = sweep_json(&[row(1, 1), row(4, 1)]);
        assert!(s.contains("\"schema\": \"torrent-serve-sweep-v1\""));
        assert!(s.contains("\"rate_per_kcycle\": 4"));
        assert_eq!(
            s.matches('{').count(),
            s.matches('}').count(),
            "unbalanced JSON braces:\n{s}"
        );
        // Exactly one separating comma between the two row objects.
        assert_eq!(s.matches("},\n").count(), 1);
    }

    #[test]
    fn markdown_groups_rows_by_leg() {
        let md = sweep_markdown(&[row(1, 1), row(4, 1), row(1, 2)]);
        assert_eq!(md.matches("## mesh · greedy · t=1").count(), 1);
        assert_eq!(md.matches("## mesh · greedy · t=2").count(), 1);
        assert_eq!(md.matches("| 1 | 40 |").count(), 2);
        assert!(md.contains("pending peak"));
    }

    fn res_row(fabric: &'static str, policy: &'static str) -> ResilienceRow {
        ResilienceRow {
            fabric,
            policy,
            seed: 7,
            offered: 50,
            completed: 46,
            failed: 2,
            rejected: 2,
            availability: 0.92,
            goodput_bytes: 188_416,
            restreamed_bytes: 8_192,
            repaired_tasks: 3,
            retried: 4,
            p99: 5_100,
        }
    }

    #[test]
    fn resilience_json_has_schema_and_balanced_braces() {
        let s = resilience_json(&[res_row("mesh", "resume"), res_row("mesh", "restream")]);
        assert!(s.contains("\"schema\": \"torrent-resilience-sweep-v1\""));
        assert!(s.contains("\"policy\": \"resume\""));
        assert!(s.contains("\"restreamed_bytes\": 8192"));
        assert_eq!(
            s.matches('{').count(),
            s.matches('}').count(),
            "unbalanced JSON braces:\n{s}"
        );
        assert_eq!(s.matches("},\n").count(), 1);
    }

    #[test]
    fn resilience_markdown_groups_by_fabric() {
        let md = resilience_markdown(&[
            res_row("mesh", "fail-stop"),
            res_row("mesh", "resume+reroute"),
            res_row("torus", "resume"),
        ]);
        assert_eq!(md.matches("## mesh").count(), 1);
        assert_eq!(md.matches("## torus").count(), 1);
        assert!(md.contains("| resume+reroute | 7 |"));
        assert!(md.contains("restreamed B"));
    }
}

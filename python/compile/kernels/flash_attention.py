"""L1 Pallas blocked attention kernel (online softmax / FlashAttention
style) — the TPU-idiomatic extension of the paper's attention workload.

The naive path (`model.attention_prefill`) materializes the full T×T
score matrix; at DeepSeek-V3 prefill lengths that matrix dominates VMEM.
This kernel never materializes it: the grid walks (query block × key
block) with the key dimension innermost, carrying running max `m`,
normalizer `l` and the unnormalized accumulator in the output block —
the standard online-softmax recurrence, expressed with the same
BlockSpec machinery the GeMM kernels use (DESIGN.md
§Hardware-Adaptation: KV blocks stream HBM→VMEM per grid step while the
q block stays resident).
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, nk, scale):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]  # (bq, d)
    k = k_ref[...]  # (bk, d)
    v = v_ref[...]  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    m_prev = m_ref[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # Rescale previous state to the new max.
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kb == nk - 1)
    def _finalize():
        o_ref[...] = o_ref[...] / l_ref[...]


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def flash_attention(q, k, v, bq=64, bk=64):
    """Single-head attention with online softmax: (T, d) x 3 -> (T, d).

    Never materializes the T x T score matrix; VMEM per grid step is
    O(bq*d + bk*d + bq*bk).
    """
    t, d = q.shape
    tk, dk = k.shape
    assert v.shape == (tk, dk) and d == dk
    while t % bq:
        bq -= 1
    while tk % bk:
        bk -= 1
    scale = 1.0 / math.sqrt(d)  # python float: baked into the kernel
    grid = (t // bq, tk // bk)  # kv block innermost: sequential accumulate
    out, _, _ = pl.pallas_call(
        functools.partial(_flash_kernel, nk=grid[1], scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),  # running max
            jax.ShapeDtypeStruct((t, 1), jnp.float32),  # normalizer
        ],
        interpret=True,
    )(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    return out

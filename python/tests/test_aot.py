"""AOT path tests: every entry point lowers to parseable HLO text whose
entry computation has the manifest's parameter count, and the lowered
module still computes the right numbers when re-executed through
xla_client (the same engine the Rust PJRT runtime embeds)."""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import ref


@pytest.mark.parametrize("name", list(aot.ENTRY_POINTS))
def test_entry_point_lowers_to_hlo_text(name):
    fn, specs = aot.ENTRY_POINTS[name]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "ROOT" in text
    # entry computation takes exactly len(specs) parameters
    entry = text[text.index("ENTRY"):]
    first_line = entry.splitlines()[0]
    n_params = len(re.findall(r"parameter\(", text))
    assert n_params >= len(specs), (name, first_line)


def test_hlo_text_has_no_64bit_ids():
    """Guard against the xla_extension 0.5.1 proto-id pitfall: text must be
    plain HLO the 0.5.x parser accepts (no serialized-proto artifacts)."""
    fn, specs = aot.ENTRY_POINTS["gemm_prefill"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.lstrip().startswith("HloModule")


def test_lowered_gemm_recomputes_correctly():
    fn, specs = aot.ENTRY_POINTS["gemm_prefill"]
    m, k = specs[0].shape
    _, n = specs[1].shape
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    (got,) = jax.jit(fn)(a, b)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-6)


def test_manifest_shape_strings():
    assert aot._shape_str(jax.ShapeDtypeStruct((2, 3), jnp.float32)) == "f32[2,3]"

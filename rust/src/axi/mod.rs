//! AXI4 transport layer: burst rules, ID management, and the memory-side
//! slave that services requests arriving over the NoC.
//!
//! FlooNoC-style mapping (paper §IV-A): one AXI write burst travels as a
//! single NoC packet — head flit = AW channel beat, body flits = W beats
//! (64 B data width), and the B response returns as a one-flit packet.
//! Reads are a one-flit AR request and a multi-flit R response. Torrent's
//! Backend builds exactly these packets, which is why Chainwrite needs no
//! protocol changes.

pub mod id_pool;
pub mod slave;
pub mod split;

pub use id_pool::IdPool;
pub use slave::AxiSlave;
pub use split::{split_bursts, Burst, AXI_4K, MAX_BURST_BYTES};

//! Data Streaming Engine: ND-affine address generation.
//!
//! The Torrent frontend reuses the XDMA/DataMaestro DSE (paper Fig 3): an
//! n-deep affine loop nest `base + Σ i_k · stride_k` that both gathers a
//! source stream and scatters an incoming stream, enabling on-the-fly
//! layout transforms (Table II's MNMxNy re-tilings) without staging
//! buffers.
//!
//! Timing: the DSE emits one *run* (maximal contiguous byte span) per
//! iteration of the inner non-contiguous loop. Runs ≥ 64 B stream at the
//! full 64 B/cycle port rate; shorter runs waste port slots, so the
//! effective rate is `min(run_bytes, 64)` per cycle — the fraction
//! [`AffinePattern::rate_per_cycle`] feeds the engines' injection gates.

use crate::mem::Scratchpad;

/// An n-D affine access pattern. `dims` are (count, stride_bytes) pairs,
/// innermost first. A contiguous transfer of `len` bytes is
/// `AffinePattern::contiguous(base, len)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AffinePattern {
    pub base: u64,
    /// Contiguous bytes moved per innermost iteration.
    pub elem_bytes: usize,
    /// (count, stride) per dimension, innermost first. Empty = one element.
    pub dims: Vec<(usize, i64)>,
}

impl AffinePattern {
    /// 1-D contiguous pattern.
    pub fn contiguous(base: u64, len: usize) -> Self {
        AffinePattern { base, elem_bytes: len, dims: vec![] }
    }

    /// 2-D strided pattern: `rows` runs of `run_bytes` every `pitch` bytes.
    pub fn strided(base: u64, rows: usize, run_bytes: usize, pitch: i64) -> Self {
        AffinePattern { base, elem_bytes: run_bytes, dims: vec![(rows, pitch)] }
    }

    /// Total payload bytes.
    pub fn total_bytes(&self) -> usize {
        self.elem_bytes * self.dims.iter().map(|(c, _)| *c).product::<usize>().max(1)
    }

    /// Iterate `(addr, len)` runs in stream order, merging adjacent
    /// contiguous runs (the DSE's run coalescer).
    pub fn runs(&self) -> Vec<(u64, usize)> {
        let mut out: Vec<(u64, usize)> = Vec::new();
        let counts: Vec<usize> = self.dims.iter().map(|(c, _)| *c).collect();
        let total: usize = counts.iter().product::<usize>().max(1);
        let mut idx = vec![0usize; self.dims.len()];
        for _ in 0..total {
            let off: i64 = idx
                .iter()
                .zip(&self.dims)
                .map(|(&i, &(_, s))| i as i64 * s)
                .sum();
            let addr = (self.base as i64 + off) as u64;
            match out.last_mut() {
                Some((a, l)) if *a + *l as u64 == addr => *l += self.elem_bytes,
                _ => out.push((addr, self.elem_bytes)),
            }
            // Odometer increment, innermost first.
            for k in 0..idx.len() {
                idx[k] += 1;
                if idx[k] < counts[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        if self.dims.is_empty() {
            // single contiguous element
            return vec![(self.base, self.elem_bytes)];
        }
        out
    }

    /// Effective port utilisation in bytes/cycle (≤ 64): short runs waste
    /// slots on the 64 B port.
    pub fn rate_per_cycle(&self) -> f64 {
        let runs = self.runs();
        if runs.is_empty() {
            return 64.0;
        }
        let total: usize = runs.iter().map(|(_, l)| l).sum();
        let cycles: u64 = runs
            .iter()
            .map(|(_, l)| (*l as u64).div_ceil(crate::noc::FLIT_BYTES as u64))
            .sum();
        (total as f64 / cycles as f64).min(64.0)
    }

    /// Cycles for the DSE to stream this pattern through its port.
    pub fn stream_cycles(&self) -> u64 {
        self.runs()
            .iter()
            .map(|(_, l)| (*l as u64).div_ceil(crate::noc::FLIT_BYTES as u64))
            .sum()
    }

    /// Stream bytes per iteration of the *outermost* dimension — the
    /// granularity at which a prefix of the stream can be cut off and
    /// the remainder still expressed as one affine pattern (drop
    /// completed outer iterations, shift the base). Contiguous patterns
    /// split anywhere.
    fn outer_block_bytes(&self) -> usize {
        self.elem_bytes
            * self.dims[..self.dims.len() - 1].iter().map(|(c, _)| *c).product::<usize>().max(1)
    }

    /// Largest resumable split point ≤ `bytes`: the longest stream
    /// prefix not exceeding `bytes` whose *tail* is itself an affine
    /// pattern ([`AffinePattern::tail_at`]). Contiguous patterns resume
    /// at any byte; ND patterns floor to the outermost-iteration
    /// boundary (partial outer rows are re-streamed — re-writing
    /// already-delivered bytes is idempotent, losing delivered bytes is
    /// not).
    pub fn split_floor(&self, bytes: usize) -> usize {
        let b = bytes.min(self.total_bytes());
        if self.dims.is_empty() {
            return b;
        }
        let block = self.outer_block_bytes();
        (b / block) * block
    }

    /// The pattern covering stream bytes `k..total`, for `k` a valid
    /// split point strictly inside the stream (`k == split_floor(k)`,
    /// `k < total_bytes`).
    pub fn tail_at(&self, k: usize) -> AffinePattern {
        assert_eq!(k, self.split_floor(k), "tail_at off a resumable boundary");
        assert!(k < self.total_bytes(), "tail_at past the stream");
        if self.dims.is_empty() {
            return AffinePattern::contiguous(self.base + k as u64, self.elem_bytes - k);
        }
        let done = k / self.outer_block_bytes();
        let mut tail = self.clone();
        let (count, stride) = *tail.dims.last().unwrap();
        tail.dims.last_mut().unwrap().0 = count - done;
        tail.base = (tail.base as i64 + done as i64 * stride) as u64;
        tail
    }

    /// Gather the pattern's bytes from `mem` into a stream buffer.
    pub fn gather(&self, mem: &mut Scratchpad) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.total_bytes());
        for (addr, len) in self.runs() {
            out.extend_from_slice(&mem.read(addr, len));
        }
        out
    }

    /// Scatter `stream` into `mem` following the pattern. Returns bytes
    /// consumed (= total_bytes; panics if the stream is short).
    pub fn scatter(&self, stream: &[u8], mem: &mut Scratchpad) -> usize {
        let mut off = 0;
        for (addr, len) in self.runs() {
            mem.write(addr, &stream[off..off + len]);
            off += len;
        }
        off
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spm() -> Scratchpad {
        let mut s = Scratchpad::new(0, 1 << 16);
        s.fill_pattern(0x5A);
        s
    }

    #[test]
    fn contiguous_is_one_run() {
        let p = AffinePattern::contiguous(0x100, 4096);
        assert_eq!(p.runs(), vec![(0x100, 4096)]);
        assert_eq!(p.total_bytes(), 4096);
        assert!((p.rate_per_cycle() - 64.0).abs() < 1e-9);
    }

    #[test]
    fn strided_rows() {
        let p = AffinePattern::strided(0, 4, 8, 128);
        assert_eq!(p.runs(), vec![(0, 8), (128, 8), (256, 8), (384, 8)]);
        assert_eq!(p.total_bytes(), 32);
        assert!((p.rate_per_cycle() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn adjacent_runs_coalesce() {
        // stride == elem_bytes -> fully contiguous despite 2 dims
        let p = AffinePattern { base: 0, elem_bytes: 8, dims: vec![(16, 8)] };
        assert_eq!(p.runs(), vec![(0, 128)]);
        assert!((p.rate_per_cycle() - 64.0).abs() < 1e-9);
    }

    #[test]
    fn three_level_nest() {
        // 2 tiles of 2 rows of 4 bytes; row pitch 16, tile pitch 64.
        let p = AffinePattern { base: 0, elem_bytes: 4, dims: vec![(2, 16), (2, 64)] };
        assert_eq!(p.runs(), vec![(0, 4), (16, 4), (64, 4), (80, 4)]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut src = spm();
        let mut dst = Scratchpad::new(0, 1 << 16);
        let read = AffinePattern::strided(0x40, 8, 16, 256);
        let stream = read.gather(&mut src);
        assert_eq!(stream.len(), 128);
        // Write it compacted at 0x1000 (a layout transform!).
        let write = AffinePattern::contiguous(0x1000, 128);
        assert_eq!(write.scatter(&stream, &mut dst), 128);
        // Verify element by element.
        for row in 0..8 {
            let want = src.peek(0x40 + row * 256, 16);
            let got = dst.peek(0x1000 + row * 16, 16);
            assert_eq!(want, got, "row {row}");
        }
    }

    #[test]
    fn stream_cycles_counts_port_slots() {
        assert_eq!(AffinePattern::contiguous(0, 128).stream_cycles(), 2);
        // 4 runs of 8B: one port slot each.
        assert_eq!(AffinePattern::strided(0, 4, 8, 128).stream_cycles(), 4);
        // run of 100 B: 2 slots.
        assert_eq!(AffinePattern::strided(0, 2, 100, 512).stream_cycles(), 4);
    }

    #[test]
    fn negative_stride_walks_backward() {
        let p = AffinePattern { base: 1024, elem_bytes: 8, dims: vec![(3, -64)] };
        assert_eq!(p.runs(), vec![(1024, 8), (960, 8), (896, 8)]);
    }

    #[test]
    fn split_floor_is_any_byte_for_contiguous_and_outer_rows_otherwise() {
        let c = AffinePattern::contiguous(0x100, 4096);
        assert_eq!(c.split_floor(1000), 1000);
        assert_eq!(c.split_floor(9999), 4096, "clamped to the stream");
        // 4 rows x 8 B: resumable only at whole rows.
        let s = AffinePattern::strided(0, 4, 8, 128);
        assert_eq!(s.split_floor(0), 0);
        assert_eq!(s.split_floor(7), 0);
        assert_eq!(s.split_floor(8), 8);
        assert_eq!(s.split_floor(23), 16);
        assert_eq!(s.split_floor(64), 32);
        // 3-level nest [(2,16),(2,64)] — outer block = 2 inner elems.
        let n = AffinePattern { base: 0, elem_bytes: 4, dims: vec![(2, 16), (2, 64)] };
        assert_eq!(n.split_floor(7), 0);
        assert_eq!(n.split_floor(11), 8);
    }

    #[test]
    fn tail_at_resumes_exactly_the_undelivered_suffix() {
        let mut mem = spm();
        for (pat, k) in [
            (AffinePattern::contiguous(0x40, 1024), 600),
            (AffinePattern::strided(0x40, 8, 16, 256), 48),
            (AffinePattern { base: 0x80, elem_bytes: 4, dims: vec![(2, 16), (4, 64)] }, 16),
        ] {
            assert_eq!(pat.split_floor(k), k, "chosen k must be a boundary");
            let tail = pat.tail_at(k);
            assert_eq!(tail.total_bytes(), pat.total_bytes() - k);
            let full = pat.gather(&mut mem);
            assert_eq!(tail.gather(&mut mem), full[k..], "tail mismatches suffix");
        }
        // k = 0 is the whole pattern again.
        let p = AffinePattern::strided(0, 4, 8, 128);
        assert_eq!(p.tail_at(0), p);
    }

    #[test]
    #[should_panic(expected = "resumable boundary")]
    fn tail_at_rejects_mid_row_splits() {
        AffinePattern::strided(0, 4, 8, 128).tail_at(5);
    }

    #[test]
    fn mnm16n8_relayout_pattern() {
        // Read a 32x16 int8 matrix stored MNM16N8 (tiles 16x8, 128 B each,
        // tile-row-major) as logical rows: per logical row, 2 runs of 8 B
        // at tile-local offsets.
        // Tile (ti, tj) base = (ti * 2 + tj) * 128; row r within tile at +r*8.
        // Logical row 17 = tile row 1, local row 1: runs at 256+8, 384+8.
        let row17 = AffinePattern { base: (2 * 128) + 8, elem_bytes: 8, dims: vec![(2, 128)] };
        assert_eq!(row17.runs(), vec![(264, 8), (392, 8)]);
    }
}

//! Activity-based power model (paper §IV-F, Fig 11(d–f)).
//!
//! Anchored on the published numbers: 175.7 mW initiator cluster at
//! 600 MHz / 0.8 V, 4.68 pJ/B/hop end-to-end energy efficiency, and the
//! observation that mid-chain followers consume more than the tail
//! because they also *forward* the stream. The model splits cluster
//! power into a static + clock baseline and per-byte dynamic energies
//! for the read, write and forward datapaths, calibrated so the
//! initiator lands at the published figure for the 64 KB 3-destination
//! post-synthesis workload.

/// Published end-to-end transport energy.
pub const PJ_PER_BYTE_HOP: f64 = 4.68;
/// Clock frequency of the synthesis SoC.
pub const FREQ_HZ: f64 = 600e6;

/// Baseline (static + clock tree + idle SRAM) cluster power, mW.
pub const CLUSTER_BASELINE_MW: f64 = 96.0;
/// Dynamic energy per byte streamed out of the source DSE (SRAM read +
/// switch + backend), pJ/B. Calibrated so the 64 KB / 3-dest workload
/// puts the initiator cluster at the published 175.7 mW.
pub const PJ_PER_BYTE_READ: f64 = 1.84;
/// Dynamic energy per byte scattered into local memory, pJ/B.
pub const PJ_PER_BYTE_WRITE: f64 = 2.3;
/// Dynamic energy per byte duplicated + forwarded by the data switch.
pub const PJ_PER_BYTE_FWD: f64 = 1.9;

/// Which chain position a cluster played (Fig 11(d–f)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PowerRole {
    Initiator,
    MiddleFollower,
    TailFollower,
}

/// Transport energy of a task: bytes moved × hops traversed.
pub fn chain_energy_pj(bytes: usize, total_hops: usize) -> f64 {
    bytes as f64 * total_hops as f64 * PJ_PER_BYTE_HOP
}

/// Average cluster power (mW) over a window of `cycles`, given byte-level
/// activity counters from the simulation.
pub fn cluster_power_mw(
    role: PowerRole,
    bytes_read: u64,
    bytes_written: u64,
    bytes_forwarded: u64,
    cycles: u64,
) -> f64 {
    assert!(cycles > 0);
    let dyn_pj = bytes_read as f64 * PJ_PER_BYTE_READ
        + bytes_written as f64 * PJ_PER_BYTE_WRITE
        + bytes_forwarded as f64 * PJ_PER_BYTE_FWD;
    let seconds = cycles as f64 / FREQ_HZ;
    let dynamic_mw = dyn_pj * 1e-12 / seconds * 1e3;
    // Initiators also burn GeMM/control activity the followers do not.
    let baseline = match role {
        PowerRole::Initiator => CLUSTER_BASELINE_MW + 24.0,
        PowerRole::MiddleFollower | PowerRole::TailFollower => CLUSTER_BASELINE_MW,
    };
    baseline + dynamic_mw
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's post-synthesis workload: 64 KB, 3-destination
    /// Chainwrite from cluster 0.
    fn workload() -> (u64, u64) {
        let bytes = 64 * 1024u64;
        // Streaming 64 KB at ~64 B/CC plus protocol overhead ≈ 1300 CC.
        (bytes, 1300)
    }

    #[test]
    fn initiator_power_near_published() {
        let (bytes, cycles) = workload();
        let p = cluster_power_mw(PowerRole::Initiator, bytes, 0, 0, cycles);
        assert!((p - 175.7).abs() < 10.0, "initiator {p} mW vs 175.7 published");
    }

    #[test]
    fn middle_follower_above_tail() {
        let (bytes, cycles) = workload();
        let mid =
            cluster_power_mw(PowerRole::MiddleFollower, 0, bytes, bytes, cycles);
        let tail = cluster_power_mw(PowerRole::TailFollower, 0, bytes, 0, cycles);
        assert!(mid > tail, "mid {mid} <= tail {tail}");
    }

    #[test]
    fn chain_energy_matches_published_coefficient() {
        assert!((chain_energy_pj(1, 1) - 4.68).abs() < 1e-12);
        let e = chain_energy_pj(64 * 1024, 6);
        assert!((e - 64.0 * 1024.0 * 6.0 * 4.68).abs() < 1e-6);
    }

    #[test]
    fn power_scales_with_activity() {
        let lo = cluster_power_mw(PowerRole::TailFollower, 0, 1024, 0, 1000);
        let hi = cluster_power_mw(PowerRole::TailFollower, 0, 64 * 1024, 0, 1000);
        assert!(hi > lo);
    }
}

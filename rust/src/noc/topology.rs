//! NoC topologies and minimal routing.
//!
//! The paper's evaluation SoCs are FlooNoC 2D meshes: 4×5 (20 clusters,
//! §IV-A), 8×8 (Fig 6 hop study) and 3×3 (FPGA, §IV-E), all XY-routed.
//! `NodeId`s are row-major: node = y * cols + x, so cluster C0 is the
//! origin corner — matching the paper's "start from dest closest to C0".
//!
//! Chainwrite's central claim is that the chain *order* must be derived
//! from the fabric (§III-D, §IV-C), so the fabric itself is abstracted
//! behind the [`Topology`] trait: [`Mesh`] (XY dimension-ordered),
//! [`Torus`] (wraparound XY, shortest-direction per dimension) and
//! [`Ring`] (bidirectional, shortest arc). The routers, the multicast
//! fork, and every `sched` strategy consume the trait — none of them
//! hard-code mesh geometry. [`Topo`] is the `Copy` dispatch enum the
//! simulator stores (no boxing on the per-flit hot path).
//!
//! Routing contract (shared by all three, property-tested in
//! `rust/tests/topologies.rs`): `next_hop` strictly decreases
//! `distance` to the destination, `path` has `distance + 1` nodes, and
//! `links` are exactly the consecutive pairs of `path`. Tie-breaks are
//! deterministic — equal-length arcs resolve East (X) / North (Y) — so
//! every schedule and cycle count is run-to-run reproducible.

/// Node index in row-major order over the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// (x, y) layout coordinate; x is the column, y the row. A [`Ring`]
/// reports y = 0 for every node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Coord {
    pub x: usize,
    pub y: usize,
}

/// Router port direction. `Local` is the endpoint (NI) port.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    Local,
    North,
    East,
    South,
    West,
}

impl Dir {
    pub const ALL: [Dir; 5] = [Dir::Local, Dir::North, Dir::East, Dir::South, Dir::West];

    pub fn index(self) -> usize {
        match self {
            Dir::Local => 0,
            Dir::North => 1,
            Dir::East => 2,
            Dir::South => 3,
            Dir::West => 4,
        }
    }

    /// The port on the neighbouring router that faces back at us.
    pub fn opposite(self) -> Dir {
        match self {
            Dir::Local => Dir::Local,
            Dir::North => Dir::South,
            Dir::East => Dir::West,
            Dir::South => Dir::North,
            Dir::West => Dir::East,
        }
    }
}

/// A routed point-to-point fabric.
///
/// Object-safe so the router pipeline, the multicast fork and the chain
/// schedulers take `&dyn Topology`; concrete fabrics (`&Mesh`, `&Torus`,
/// `&Ring`, `&Topo`) coerce at the call site. Implementations must keep
/// `next_hop` monotone (each hop strictly decreases `distance`) — the
/// default `path`/`links` bodies, the wormhole routers and the greedy
/// scheduler's in-place path walk all rely on it terminating.
pub trait Topology {
    /// Short fabric label for reports ("mesh", "torus", "ring").
    fn name(&self) -> &'static str;

    fn n_nodes(&self) -> usize;

    /// Layout position of `n` (plots, visualizers).
    fn coord(&self, n: NodeId) -> Coord;

    /// Inverse of [`Topology::coord`].
    fn node(&self, c: Coord) -> NodeId;

    /// Routing distance in hops (the Fig-6 metric's unit).
    fn distance(&self, a: NodeId, b: NodeId) -> usize;

    /// Output port taken at `cur` toward `dst`; `Local` iff `cur == dst`.
    fn next_hop(&self, cur: NodeId, dst: NodeId) -> Dir;

    /// Neighbour of `n` through port `d`, if that link exists.
    fn neighbour(&self, n: NodeId, d: Dir) -> Option<NodeId>;

    /// Longest shortest-path in the fabric. Upper bound for Alg. 1's
    /// hop-count init (`sched::greedy_order`).
    fn diameter(&self) -> usize;

    /// Full routed path from `from` to `to`, inclusive of both endpoints.
    fn path(&self, from: NodeId, to: NodeId) -> Vec<NodeId> {
        let mut path = vec![from];
        let mut cur = from;
        while cur != to {
            let d = self.next_hop(cur, to);
            cur = self.neighbour(cur, d).expect("routing left the fabric");
            path.push(cur);
        }
        path
    }

    /// The directed links (node pairs) of the routed path — the "edges"
    /// used by Alg. 1's overlap test.
    fn links(&self, from: NodeId, to: NodeId) -> Vec<(NodeId, NodeId)> {
        let p = self.path(from, to);
        p.windows(2).map(|w| (w[0], w[1])).collect()
    }
}

/// A `cols` × `rows` 2D mesh, XY (dimension-ordered) routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh {
    pub cols: usize,
    pub rows: usize,
}

impl Mesh {
    pub fn new(cols: usize, rows: usize) -> Self {
        assert!(cols >= 1 && rows >= 1);
        Mesh { cols, rows }
    }

    pub fn n_nodes(&self) -> usize {
        self.cols * self.rows
    }

    pub fn coord(&self, n: NodeId) -> Coord {
        assert!(n.0 < self.n_nodes(), "node {n:?} out of mesh {self:?}");
        Coord { x: n.0 % self.cols, y: n.0 / self.cols }
    }

    pub fn node(&self, c: Coord) -> NodeId {
        assert!(c.x < self.cols && c.y < self.rows, "{c:?} out of mesh {self:?}");
        NodeId(c.y * self.cols + c.x)
    }

    /// Manhattan distance in hops.
    pub fn manhattan(&self, a: NodeId, b: NodeId) -> usize {
        let (ca, cb) = (self.coord(a), self.coord(b));
        ca.x.abs_diff(cb.x) + ca.y.abs_diff(cb.y)
    }

    /// Neighbour in direction `d`, if inside the mesh.
    pub fn neighbour(&self, n: NodeId, d: Dir) -> Option<NodeId> {
        let c = self.coord(n);
        let nc = match d {
            Dir::Local => return Some(n),
            Dir::North => {
                if c.y + 1 >= self.rows {
                    return None;
                }
                Coord { x: c.x, y: c.y + 1 }
            }
            Dir::South => {
                if c.y == 0 {
                    return None;
                }
                Coord { x: c.x, y: c.y - 1 }
            }
            Dir::East => {
                if c.x + 1 >= self.cols {
                    return None;
                }
                Coord { x: c.x + 1, y: c.y }
            }
            Dir::West => {
                if c.x == 0 {
                    return None;
                }
                Coord { x: c.x - 1, y: c.y }
            }
        };
        Some(self.node(nc))
    }

    /// Next output port under XY routing (X fully first, then Y).
    pub fn xy_next_hop(&self, cur: NodeId, dst: NodeId) -> Dir {
        let (c, d) = (self.coord(cur), self.coord(dst));
        if c.x < d.x {
            Dir::East
        } else if c.x > d.x {
            Dir::West
        } else if c.y < d.y {
            Dir::North
        } else if c.y > d.y {
            Dir::South
        } else {
            Dir::Local
        }
    }

    /// Full XY path from `from` to `to`, inclusive of both endpoints.
    pub fn xy_path(&self, from: NodeId, to: NodeId) -> Vec<NodeId> {
        Topology::path(self, from, to)
    }

    /// The directed links (node pairs) of the XY path — the "edges" used
    /// by Alg. 1's overlap test.
    pub fn xy_links(&self, from: NodeId, to: NodeId) -> Vec<(NodeId, NodeId)> {
        Topology::links(self, from, to)
    }

    pub fn nodes(&self) -> impl Iterator<Item = NodeId> {
        (0..self.n_nodes()).map(NodeId)
    }
}

impl Topology for Mesh {
    fn name(&self) -> &'static str {
        "mesh"
    }

    fn n_nodes(&self) -> usize {
        Mesh::n_nodes(self)
    }

    fn coord(&self, n: NodeId) -> Coord {
        Mesh::coord(self, n)
    }

    fn node(&self, c: Coord) -> NodeId {
        Mesh::node(self, c)
    }

    fn distance(&self, a: NodeId, b: NodeId) -> usize {
        self.manhattan(a, b)
    }

    fn next_hop(&self, cur: NodeId, dst: NodeId) -> Dir {
        self.xy_next_hop(cur, dst)
    }

    fn neighbour(&self, n: NodeId, d: Dir) -> Option<NodeId> {
        Mesh::neighbour(self, n, d)
    }

    fn diameter(&self) -> usize {
        (self.cols - 1) + (self.rows - 1)
    }
}

/// A `cols` × `rows` 2D torus: the mesh plus wraparound links in both
/// dimensions. Routing is dimension-ordered (X fully first, then Y) and
/// takes the shorter wrap direction per dimension; equal arcs break
/// East / North. A dimension of size 1 has no wrap link (it would be a
/// self-loop) and size 2 keeps both directed ports (two parallel links
/// between the pair, as in a physical 2-ary torus).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Torus {
    pub cols: usize,
    pub rows: usize,
}

impl Torus {
    pub fn new(cols: usize, rows: usize) -> Self {
        assert!(cols >= 1 && rows >= 1);
        Torus { cols, rows }
    }

    /// Shortest wrap distance between offsets `a` and `b` modulo `len`.
    fn arc(len: usize, a: usize, b: usize) -> usize {
        let fwd = (b + len - a) % len;
        fwd.min(len - fwd)
    }

    /// True when moving in the increasing direction is the shorter (or
    /// tied) arc from `a` to `b` modulo `len`.
    fn forward_is_short(len: usize, a: usize, b: usize) -> bool {
        let fwd = (b + len - a) % len;
        fwd <= len - fwd
    }
}

impl Topology for Torus {
    fn name(&self) -> &'static str {
        "torus"
    }

    fn n_nodes(&self) -> usize {
        self.cols * self.rows
    }

    fn coord(&self, n: NodeId) -> Coord {
        assert!(n.0 < self.n_nodes(), "node {n:?} out of torus {self:?}");
        Coord { x: n.0 % self.cols, y: n.0 / self.cols }
    }

    fn node(&self, c: Coord) -> NodeId {
        assert!(c.x < self.cols && c.y < self.rows, "{c:?} out of torus {self:?}");
        NodeId(c.y * self.cols + c.x)
    }

    fn distance(&self, a: NodeId, b: NodeId) -> usize {
        let (ca, cb) = (self.coord(a), self.coord(b));
        Self::arc(self.cols, ca.x, cb.x) + Self::arc(self.rows, ca.y, cb.y)
    }

    fn next_hop(&self, cur: NodeId, dst: NodeId) -> Dir {
        let (c, d) = (self.coord(cur), self.coord(dst));
        if c.x != d.x {
            if Self::forward_is_short(self.cols, c.x, d.x) {
                Dir::East
            } else {
                Dir::West
            }
        } else if c.y != d.y {
            if Self::forward_is_short(self.rows, c.y, d.y) {
                Dir::North
            } else {
                Dir::South
            }
        } else {
            Dir::Local
        }
    }

    fn neighbour(&self, n: NodeId, d: Dir) -> Option<NodeId> {
        let c = self.coord(n);
        let nc = match d {
            Dir::Local => return Some(n),
            Dir::North if self.rows > 1 => Coord { x: c.x, y: (c.y + 1) % self.rows },
            Dir::South if self.rows > 1 => Coord { x: c.x, y: (c.y + self.rows - 1) % self.rows },
            Dir::East if self.cols > 1 => Coord { x: (c.x + 1) % self.cols, y: c.y },
            Dir::West if self.cols > 1 => Coord { x: (c.x + self.cols - 1) % self.cols, y: c.y },
            _ => return None,
        };
        Some(self.node(nc))
    }

    fn diameter(&self) -> usize {
        self.cols / 2 + self.rows / 2
    }
}

/// An `n`-node bidirectional ring: East is node `i + 1 (mod n)`, West is
/// `i - 1 (mod n)`. Routing follows the shorter arc; equal arcs break
/// East. Layout coordinates are `(i, 0)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ring {
    pub n: usize,
}

impl Ring {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        Ring { n }
    }
}

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn n_nodes(&self) -> usize {
        self.n
    }

    fn coord(&self, n: NodeId) -> Coord {
        assert!(n.0 < self.n, "node {n:?} out of ring {self:?}");
        Coord { x: n.0, y: 0 }
    }

    fn node(&self, c: Coord) -> NodeId {
        assert!(c.x < self.n && c.y == 0, "{c:?} out of ring {self:?}");
        NodeId(c.x)
    }

    fn distance(&self, a: NodeId, b: NodeId) -> usize {
        Torus::arc(self.n, self.coord(a).x, self.coord(b).x)
    }

    fn next_hop(&self, cur: NodeId, dst: NodeId) -> Dir {
        if cur == dst {
            Dir::Local
        } else if Torus::forward_is_short(self.n, self.coord(cur).x, self.coord(dst).x) {
            Dir::East
        } else {
            Dir::West
        }
    }

    fn neighbour(&self, n: NodeId, d: Dir) -> Option<NodeId> {
        let i = self.coord(n).x;
        match d {
            Dir::Local => Some(n),
            Dir::East if self.n > 1 => Some(NodeId((i + 1) % self.n)),
            Dir::West if self.n > 1 => Some(NodeId((i + self.n - 1) % self.n)),
            _ => None,
        }
    }

    fn diameter(&self) -> usize {
        self.n / 2
    }
}

/// A fault-degraded view of a fabric: the base [`Topo`] minus killed
/// routers and severed directed links.
///
/// The physical routers keep routing with the *base* topology — a mesh
/// router has no reroute tables — so this view deliberately does **not**
/// change `next_hop`/`path`. What it changes is `distance`: a pair whose
/// routed path crosses dead hardware is pushed beyond every clean
/// distance by a fixed penalty, so the chain schedulers
/// (`sched::schedule_pairs`) order clean legs first. The repair planner
/// then truncates chains at the first dirty leg via
/// [`Degraded::path_is_clean`] — the authoritative reachability test.
#[derive(Debug, Clone)]
pub struct Degraded {
    topo: Topo,
    dead: Vec<bool>,
    /// `link_dead[node][dir.index()]`: the channel leaving `node`
    /// toward `dir` is severed.
    link_dead: Vec<[bool; 5]>,
}

impl Degraded {
    pub fn new(topo: Topo, dead: Vec<bool>, link_dead: Vec<[bool; 5]>) -> Self {
        assert_eq!(dead.len(), topo.n_nodes());
        assert_eq!(link_dead.len(), topo.n_nodes());
        Degraded { topo, dead, link_dead }
    }

    /// An undamaged view (every node alive, every link whole).
    pub fn healthy(topo: Topo) -> Self {
        let n = topo.n_nodes();
        Degraded::new(topo, vec![false; n], vec![[false; 5]; n])
    }

    pub fn base(&self) -> Topo {
        self.topo
    }

    pub fn node_alive(&self, n: NodeId) -> bool {
        !self.dead[n.0]
    }

    /// Direction of the physical channel `from -> to` (adjacent nodes).
    fn dir_between(&self, from: NodeId, to: NodeId) -> Dir {
        [Dir::North, Dir::East, Dir::South, Dir::West]
            .into_iter()
            .find(|&d| self.topo.neighbour(from, d) == Some(to))
            .expect("dir_between on non-adjacent nodes")
    }

    /// True when the fabric's routed path `from -> to` touches only
    /// living routers and whole links (endpoints included). This is the
    /// test that decides whether a chain leg survives.
    pub fn path_is_clean(&self, from: NodeId, to: NodeId) -> bool {
        if self.dead[from.0] || self.dead[to.0] {
            return false;
        }
        if from == to {
            return true;
        }
        let p = self.topo.path(from, to);
        p.windows(2).all(|w| {
            let d = self.dir_between(w[0], w[1]);
            !self.dead[w[1].0] && !self.link_dead[w[0].0][d.index()]
        })
    }

    /// Distance penalty for dirty pairs: strictly larger than any clean
    /// routed distance, so schedulers always prefer clean legs.
    fn penalty(&self) -> usize {
        self.topo.n_nodes() * (self.topo.diameter() + 1)
    }

    /// True when routing `from -> to` through waypoint `via` (`None` =
    /// the fabric's default route) crosses only living routers and whole
    /// links. A `Some(v)` waypoint route is the concatenation
    /// `path(from, v) + path(v, to)`; it must additionally be *simple* —
    /// the two segments share no node besides `v` — because the routers
    /// steer toward `v` whenever the current node lies on
    /// `path(from, v)` before `v` (see `noc::router`), so any other
    /// shared node would loop the packet forever.
    pub fn route_is_clean(&self, from: NodeId, via: Option<NodeId>, to: NodeId) -> bool {
        match via {
            None => self.path_is_clean(from, to),
            Some(v) => {
                if v == from || v == to || self.dead[v.0] {
                    return false;
                }
                if !self.path_is_clean(from, v) || !self.path_is_clean(v, to) {
                    return false;
                }
                let head = self.topo.path(from, v);
                let tail = self.topo.path(v, to);
                head.iter().all(|n| *n == v || !tail.contains(n))
            }
        }
    }

    /// Deterministic candidate waypoints for `from -> to`, most direct
    /// first: the default route, then the YX corner (mesh/torus — the
    /// dimension-swapped L), then complementary-arc midpoints per wrap
    /// dimension (torus/ring), then — on the wrapped fabrics only, where
    /// path diversity is the whole point — every alive intermediate in
    /// ascending id order. Candidates are *geometric* proposals;
    /// [`Degraded::route_is_clean`] decides which survive the damage.
    pub fn route_candidates(&self, from: NodeId, to: NodeId) -> Vec<Option<NodeId>> {
        let mut cands: Vec<Option<NodeId>> = vec![None];
        if from == to {
            return cands;
        }
        let (cf, ct) = (self.topo.coord(from), self.topo.coord(to));
        let yx_corner = |cands: &mut Vec<Option<NodeId>>| {
            if cf.x != ct.x && cf.y != ct.y {
                cands.push(Some(self.topo.node(Coord { x: cf.x, y: ct.y })));
            }
        };
        match self.topo {
            Topo::Mesh(_) => yx_corner(&mut cands),
            Topo::Torus(t) => {
                yx_corner(&mut cands);
                if let Some(x) = wrap_mid(t.cols, cf.x, ct.x) {
                    cands.push(Some(self.topo.node(Coord { x, y: cf.y })));
                }
                if let Some(y) = wrap_mid(t.rows, cf.y, ct.y) {
                    cands.push(Some(self.topo.node(Coord { x: ct.x, y })));
                }
                self.push_alive_intermediates(from, to, &mut cands);
            }
            Topo::Ring(r) => {
                if let Some(x) = wrap_mid(r.n, cf.x, ct.x) {
                    cands.push(Some(NodeId(x)));
                }
                self.push_alive_intermediates(from, to, &mut cands);
            }
        }
        cands
    }

    fn push_alive_intermediates(&self, from: NodeId, to: NodeId, cands: &mut Vec<Option<NodeId>>) {
        for v in 0..self.topo.n_nodes() {
            let v = NodeId(v);
            if v != from && v != to && !self.dead[v.0] {
                cands.push(Some(v));
            }
        }
    }

    /// The first clean candidate route for `from -> to`:
    /// `Some(None)` = the default route is clean, `Some(Some(v))` = the
    /// default is dirty but the waypoint route via `v` is clean, `None`
    /// = no candidate survives (the hop is genuinely unreachable).
    pub fn clean_route(&self, from: NodeId, to: NodeId) -> Option<Option<NodeId>> {
        self.route_candidates(from, to)
            .into_iter()
            .find(|&via| self.route_is_clean(from, via, to))
    }
}

/// Midpoint of the complementary (long-way-around) arc from offset `a`
/// to `b` on a wrap dimension of size `len`, or `None` when the
/// dimension has no meaningful alternate arc (`a == b`, or fewer than 4
/// positions — with 2 or 3 there is no intermediate strictly inside the
/// long arc). The midpoint is the single waypoint that forces routing
/// the "wrong" way around the wrap: both halves of the detour are
/// shorter going that direction than coming back.
fn wrap_mid(len: usize, a: usize, b: usize) -> Option<usize> {
    if a == b || len < 4 {
        return None;
    }
    let fwd = (b + len - a) % len;
    let long = fwd.max(len - fwd);
    // Step half the long arc away from `a`, against the default
    // direction (default ties East/forward, so the long arc is backward
    // when fwd <= len - fwd).
    let d1 = long / 2;
    if d1 == 0 || d1 >= long {
        return None;
    }
    let mid = if fwd <= len - fwd {
        (a + len - d1) % len // default forward; detour backward
    } else {
        (a + d1) % len // default backward; detour forward
    };
    if mid == a || mid == b {
        None
    } else {
        Some(mid)
    }
}

impl Topology for Degraded {
    fn name(&self) -> &'static str {
        self.topo.name()
    }

    fn n_nodes(&self) -> usize {
        self.topo.n_nodes()
    }

    fn coord(&self, n: NodeId) -> Coord {
        self.topo.coord(n)
    }

    fn node(&self, c: Coord) -> NodeId {
        self.topo.node(c)
    }

    fn distance(&self, a: NodeId, b: NodeId) -> usize {
        let base = self.topo.distance(a, b);
        if self.path_is_clean(a, b) {
            base
        } else {
            base + self.penalty()
        }
    }

    fn next_hop(&self, cur: NodeId, dst: NodeId) -> Dir {
        self.topo.next_hop(cur, dst)
    }

    fn neighbour(&self, n: NodeId, d: Dir) -> Option<NodeId> {
        self.topo.neighbour(n, d)
    }

    fn diameter(&self) -> usize {
        self.topo.diameter()
    }
}

/// Fabric selector for configs and the CLI (`--topology mesh|torus|ring`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TopologyKind {
    #[default]
    Mesh,
    Torus,
    Ring,
}

impl TopologyKind {
    pub const ALL: [TopologyKind; 3] =
        [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::Ring];

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mesh" => Some(TopologyKind::Mesh),
            "torus" => Some(TopologyKind::Torus),
            "ring" => Some(TopologyKind::Ring),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            TopologyKind::Mesh => "mesh",
            TopologyKind::Torus => "torus",
            TopologyKind::Ring => "ring",
        }
    }
}

/// The concrete fabric a [`Network`](crate::noc::Network) runs on.
/// `Copy` enum dispatch — no boxing or vtable on the per-flit hot path,
/// and it coerces to `&dyn Topology` wherever the trait is consumed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topo {
    Mesh(Mesh),
    Torus(Torus),
    Ring(Ring),
}

impl Topo {
    /// Build the fabric `kind` over a `cols` × `rows` node grid. A ring
    /// threads all `cols * rows` nodes (same node count and address map
    /// as the grid fabrics, so configs swap topology without resizing).
    pub fn build(kind: TopologyKind, cols: usize, rows: usize) -> Topo {
        match kind {
            TopologyKind::Mesh => Topo::Mesh(Mesh::new(cols, rows)),
            TopologyKind::Torus => Topo::Torus(Torus::new(cols, rows)),
            TopologyKind::Ring => Topo::Ring(Ring::new(cols * rows)),
        }
    }

    pub fn kind(&self) -> TopologyKind {
        match self {
            Topo::Mesh(_) => TopologyKind::Mesh,
            Topo::Torus(_) => TopologyKind::Torus,
            Topo::Ring(_) => TopologyKind::Ring,
        }
    }

    fn inner(&self) -> &dyn Topology {
        match self {
            Topo::Mesh(m) => m,
            Topo::Torus(t) => t,
            Topo::Ring(r) => r,
        }
    }
}

impl From<Mesh> for Topo {
    fn from(m: Mesh) -> Topo {
        Topo::Mesh(m)
    }
}

impl From<Torus> for Topo {
    fn from(t: Torus) -> Topo {
        Topo::Torus(t)
    }
}

impl From<Ring> for Topo {
    fn from(r: Ring) -> Topo {
        Topo::Ring(r)
    }
}

impl Topology for Topo {
    fn name(&self) -> &'static str {
        self.inner().name()
    }

    fn n_nodes(&self) -> usize {
        self.inner().n_nodes()
    }

    fn coord(&self, n: NodeId) -> Coord {
        self.inner().coord(n)
    }

    fn node(&self, c: Coord) -> NodeId {
        self.inner().node(c)
    }

    fn distance(&self, a: NodeId, b: NodeId) -> usize {
        self.inner().distance(a, b)
    }

    fn next_hop(&self, cur: NodeId, dst: NodeId) -> Dir {
        self.inner().next_hop(cur, dst)
    }

    fn neighbour(&self, n: NodeId, d: Dir) -> Option<NodeId> {
        self.inner().neighbour(n, d)
    }

    fn diameter(&self) -> usize {
        self.inner().diameter()
    }

    fn path(&self, from: NodeId, to: NodeId) -> Vec<NodeId> {
        self.inner().path(from, to)
    }

    fn links(&self, from: NodeId, to: NodeId) -> Vec<(NodeId, NodeId)> {
        self.inner().links(from, to)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_node_ids() {
        let m = Mesh::new(4, 5);
        assert_eq!(m.n_nodes(), 20);
        assert_eq!(m.coord(NodeId(0)), Coord { x: 0, y: 0 });
        assert_eq!(m.coord(NodeId(5)), Coord { x: 1, y: 1 });
        assert_eq!(m.node(Coord { x: 3, y: 4 }), NodeId(19));
    }

    #[test]
    fn manhattan_distance() {
        let m = Mesh::new(8, 8);
        assert_eq!(m.manhattan(NodeId(0), NodeId(63)), 14);
        assert_eq!(m.manhattan(NodeId(9), NodeId(9)), 0);
    }

    #[test]
    fn neighbours_at_edges() {
        let m = Mesh::new(3, 3);
        assert_eq!(m.neighbour(NodeId(0), Dir::West), None);
        assert_eq!(m.neighbour(NodeId(0), Dir::South), None);
        assert_eq!(m.neighbour(NodeId(0), Dir::East), Some(NodeId(1)));
        assert_eq!(m.neighbour(NodeId(0), Dir::North), Some(NodeId(3)));
        assert_eq!(m.neighbour(NodeId(8), Dir::East), None);
    }

    #[test]
    fn xy_routes_x_first() {
        let m = Mesh::new(4, 4);
        // 0=(0,0) -> 15=(3,3): east 3 times then north 3 times
        let p = m.xy_path(NodeId(0), NodeId(15));
        assert_eq!(
            p,
            vec![0, 1, 2, 3, 7, 11, 15].into_iter().map(NodeId).collect::<Vec<_>>()
        );
    }

    #[test]
    fn xy_path_length_is_manhattan() {
        let m = Mesh::new(5, 7);
        for a in m.nodes() {
            for b in m.nodes() {
                assert_eq!(m.xy_path(a, b).len(), m.manhattan(a, b) + 1);
            }
        }
    }

    #[test]
    fn xy_path_to_self() {
        let m = Mesh::new(2, 2);
        assert_eq!(m.xy_path(NodeId(3), NodeId(3)), vec![NodeId(3)]);
    }

    #[test]
    fn opposite_ports() {
        for d in Dir::ALL {
            assert_eq!(d.opposite().opposite(), d);
        }
    }

    #[test]
    fn next_hop_local_at_destination() {
        let m = Mesh::new(3, 3);
        assert_eq!(m.xy_next_hop(NodeId(4), NodeId(4)), Dir::Local);
    }

    #[test]
    fn torus_wraps_both_dimensions() {
        let t = Torus::new(4, 4);
        // Corner (3,3) wraps East to (0,3) and North to (3,0).
        assert_eq!(t.neighbour(NodeId(15), Dir::East), Some(NodeId(12)));
        assert_eq!(t.neighbour(NodeId(15), Dir::North), Some(NodeId(3)));
        assert_eq!(t.neighbour(NodeId(0), Dir::West), Some(NodeId(3)));
        assert_eq!(t.neighbour(NodeId(0), Dir::South), Some(NodeId(12)));
    }

    #[test]
    fn torus_distance_uses_shortest_arc() {
        let t = Torus::new(4, 4);
        // (0,0) -> (3,3): 1 hop West + 1 hop South via the wrap links.
        assert_eq!(t.distance(NodeId(0), NodeId(15)), 2);
        let mesh = Mesh::new(4, 4);
        assert!(t.distance(NodeId(0), NodeId(15)) <= mesh.manhattan(NodeId(0), NodeId(15)));
        assert_eq!(t.diameter(), 4);
    }

    #[test]
    fn torus_next_hop_breaks_ties_east_and_north() {
        // 4 columns, dx = 2 both ways: deterministic East. Same for Y.
        let t = Torus::new(4, 4);
        assert_eq!(t.next_hop(NodeId(0), NodeId(2)), Dir::East);
        assert_eq!(t.next_hop(NodeId(0), NodeId(8)), Dir::North);
    }

    #[test]
    fn torus_routes_x_first_via_wrap() {
        let t = Torus::new(4, 4);
        // (0,0) -> (3,1): West wrap then North.
        assert_eq!(
            t.path(NodeId(0), NodeId(7)),
            vec![NodeId(0), NodeId(3), NodeId(7)]
        );
    }

    #[test]
    fn torus_degenerate_dimensions_have_no_self_links() {
        let t = Torus::new(1, 4);
        assert_eq!(t.neighbour(NodeId(0), Dir::East), None);
        assert_eq!(t.neighbour(NodeId(0), Dir::West), None);
        assert_eq!(t.neighbour(NodeId(0), Dir::North), Some(NodeId(1)));
    }

    #[test]
    fn ring_shortest_arc_and_tie_break() {
        let r = Ring::new(8);
        assert_eq!(r.distance(NodeId(1), NodeId(7)), 2); // wrap: 1 -> 0 -> 7
        assert_eq!(r.next_hop(NodeId(1), NodeId(7)), Dir::West);
        assert_eq!(r.next_hop(NodeId(0), NodeId(4)), Dir::East); // tie -> East
        assert_eq!(r.distance(NodeId(0), NodeId(4)), 4);
        assert_eq!(r.diameter(), 4);
        assert_eq!(r.neighbour(NodeId(0), Dir::North), None);
        assert_eq!(r.neighbour(NodeId(7), Dir::East), Some(NodeId(0)));
    }

    #[test]
    fn ring_path_follows_one_arc() {
        let r = Ring::new(6);
        assert_eq!(
            r.path(NodeId(5), NodeId(1)),
            vec![NodeId(5), NodeId(0), NodeId(1)]
        );
        assert_eq!(
            r.links(NodeId(5), NodeId(1)),
            vec![(NodeId(5), NodeId(0)), (NodeId(0), NodeId(1))]
        );
    }

    #[test]
    fn topo_builds_and_dispatches_every_kind() {
        for kind in TopologyKind::ALL {
            let topo = Topo::build(kind, 3, 4);
            assert_eq!(topo.kind(), kind);
            assert_eq!(topo.n_nodes(), 12, "{kind:?}");
            assert_eq!(topo.name(), kind.label());
            assert_eq!(topo.distance(NodeId(0), NodeId(0)), 0);
        }
        assert_eq!(TopologyKind::parse("torus"), Some(TopologyKind::Torus));
        assert_eq!(TopologyKind::parse("hypercube"), None);
    }

    #[test]
    fn healthy_degraded_view_matches_base() {
        let topo = Topo::Mesh(Mesh::new(4, 4));
        let d = Degraded::healthy(topo);
        for a in 0..16 {
            for b in 0..16 {
                let (a, b) = (NodeId(a), NodeId(b));
                assert!(d.path_is_clean(a, b));
                assert_eq!(d.distance(a, b), topo.distance(a, b));
                assert_eq!(d.next_hop(a, b), topo.next_hop(a, b));
            }
        }
    }

    #[test]
    fn dead_router_dirties_paths_through_it() {
        // Kill node 1 on a 4x1 mesh: 0 -> 2 routes through it.
        let topo = Topo::Mesh(Mesh::new(4, 1));
        let mut dead = vec![false; 4];
        dead[1] = true;
        let d = Degraded::new(topo, dead, vec![[false; 5]; 4]);
        assert!(!d.path_is_clean(NodeId(0), NodeId(2)));
        assert!(!d.path_is_clean(NodeId(1), NodeId(1)), "a dead endpoint is unreachable");
        assert!(d.path_is_clean(NodeId(2), NodeId(3)));
        assert!(
            d.distance(NodeId(0), NodeId(2)) > topo.diameter(),
            "dirty pairs must cost more than any clean path"
        );
    }

    #[test]
    fn severed_link_is_directional() {
        // Cut 1 -> 2 (East) only: 0 -> 3 dirty, 3 -> 0 still clean.
        let topo = Topo::Mesh(Mesh::new(4, 1));
        let mut link_dead = vec![[false; 5]; 4];
        link_dead[1][Dir::East.index()] = true;
        let d = Degraded::new(topo, vec![false; 4], link_dead);
        assert!(!d.path_is_clean(NodeId(0), NodeId(3)));
        assert!(d.path_is_clean(NodeId(3), NodeId(0)));
    }

    #[test]
    fn torus_wrap_survives_a_mid_row_kill() {
        // Kill node 1 on a 4-ring: 0 -> 2 is dirty eastward... but the
        // ring routes 0 -> 2 East (tie-break). 0 -> 3 routes West (1 hop)
        // and stays clean — the path diversity repair exploits.
        let topo = Topo::Ring(Ring::new(4));
        let mut dead = vec![false; 4];
        dead[1] = true;
        let d = Degraded::new(topo, dead, vec![[false; 5]; 4]);
        assert!(!d.path_is_clean(NodeId(0), NodeId(2)));
        assert!(d.path_is_clean(NodeId(0), NodeId(3)));
    }

    #[test]
    fn mesh_yx_fallback_survives_an_xy_kill() {
        // 4x4 mesh, kill router 1 = (1,0): the XY route 0 -> 5 crosses
        // it, but the YX route (via corner 4 = (0,1)) is intact.
        let topo = Topo::Mesh(Mesh::new(4, 4));
        let mut dead = vec![false; 16];
        dead[1] = true;
        let d = Degraded::new(topo, dead, vec![[false; 5]; 16]);
        assert!(!d.path_is_clean(NodeId(0), NodeId(5)));
        assert!(d.route_is_clean(NodeId(0), Some(NodeId(4)), NodeId(5)));
        assert_eq!(d.clean_route(NodeId(0), NodeId(5)), Some(Some(NodeId(4))));
        // A healthy pair reports the default route first.
        assert_eq!(d.clean_route(NodeId(0), NodeId(4)), Some(None));
        // Mesh candidates stop at the YX corner: kill both L-routes and
        // the pair is unreachable (no intermediate scan on a mesh).
        let mut dead2 = vec![false; 16];
        dead2[1] = true; // XY corner route
        dead2[4] = true; // YX corner route
        let d2 = Degraded::new(topo, dead2, vec![[false; 5]; 16]);
        assert_eq!(d2.clean_route(NodeId(0), NodeId(5)), None);
    }

    #[test]
    fn waypoint_routes_must_be_simple() {
        // Ring of 8: via=4 from 0 -> 1 ties East on the first segment,
        // crossing node 1 — the segments overlap, so the route is
        // rejected even though every router on it is alive.
        let topo = Topo::Ring(Ring::new(8));
        let d = Degraded::healthy(topo);
        assert!(!d.route_is_clean(NodeId(0), Some(NodeId(4)), NodeId(1)));
        // Endpoints are never valid waypoints.
        assert!(!d.route_is_clean(NodeId(0), Some(NodeId(0)), NodeId(1)));
        assert!(!d.route_is_clean(NodeId(0), Some(NodeId(1)), NodeId(1)));
    }

    #[test]
    fn ring_detours_the_long_way_around_a_kill() {
        // Ring of 8, kill node 1: the default 0 -> 2 route (East via 1)
        // is dirty; the complementary arc 0 -> 7 -> 6 -> 5 -> 4 -> 3 -> 2
        // is clean via the long-arc midpoint 5.
        let topo = Topo::Ring(Ring::new(8));
        let mut dead = vec![false; 8];
        dead[1] = true;
        let d = Degraded::new(topo, dead, vec![[false; 5]; 8]);
        assert!(!d.path_is_clean(NodeId(0), NodeId(2)));
        let via = d.clean_route(NodeId(0), NodeId(2)).expect("detour must exist");
        let v = via.expect("default route is dirty, so the route must use a waypoint");
        assert!(d.route_is_clean(NodeId(0), Some(v), NodeId(2)));
        // The first preferred candidate is the long-arc midpoint.
        assert_eq!(v, NodeId(5));
    }

    #[test]
    fn torus_wrap_candidates_route_around_a_dirty_row() {
        // 4x4 torus, 0=(0,0) -> 2=(2,0): default ties East through 1.
        // Kill node 1; the X long-way (West wrap via 3) must survive.
        let topo = Topo::Torus(Torus::new(4, 4));
        let mut dead = vec![false; 16];
        dead[1] = true;
        let d = Degraded::new(topo, dead, vec![[false; 5]; 16]);
        assert!(!d.path_is_clean(NodeId(0), NodeId(2)));
        let via = d.clean_route(NodeId(0), NodeId(2)).expect("torus detour must exist");
        assert!(via.is_some(), "default route is dirty");
        assert!(d.route_is_clean(NodeId(0), via, NodeId(2)));
    }

    #[test]
    fn wrap_mid_is_on_the_long_arc() {
        // len 8, 0 -> 2: default East (fwd 2), long arc West length 6,
        // midpoint 3 back from 0 = 5.
        assert_eq!(wrap_mid(8, 0, 2), Some(5));
        // Reverse: 2 -> 0 defaults West, long arc East length 6 -> 5.
        assert_eq!(wrap_mid(8, 2, 0), Some(5));
        assert_eq!(wrap_mid(8, 3, 3), None, "no arc to detour");
        assert_eq!(wrap_mid(3, 0, 1), None, "too small for an alternate arc");
    }

    #[test]
    fn mesh_trait_view_matches_inherent_api() {
        let m = Mesh::new(5, 4);
        let t: &dyn Topology = &m;
        for a in m.nodes() {
            for b in m.nodes() {
                assert_eq!(t.distance(a, b), m.manhattan(a, b));
                assert_eq!(t.next_hop(a, b), m.xy_next_hop(a, b));
                assert_eq!(t.path(a, b), m.xy_path(a, b));
            }
        }
    }
}

//! SoC assembly: NoC fabric + per-node memory, AXI slave, and all four
//! DMA engines, advanced in lock-step.
//!
//! Presets mirror the paper's three evaluation systems:
//! [`SocConfig::eval_4x5`] (20-cluster Occamy-derived SoC, §IV-A),
//! [`SocConfig::fpga_3x3`] (9-cluster VPK180 prototype, §IV-E) and
//! [`SocConfig::synth_2x2`] (4-cluster 16 nm synthesis SoC, §IV-F) —
//! all meshes, and each swappable to a torus or ring via
//! [`SocConfig::with_topology`] (the address map and engines are
//! fabric-agnostic; only routing and chain schedules change).
//!
//! [`Soc::run_until_idle`] steps the system in the configured
//! [`StepMode`]: the default event-driven mode fast-forwards the shared
//! clock over provably quiescent stretches (protocol waits, link
//! delay-line flight) using the per-component `next_event` hints, with
//! cycle counts bit-identical to full-tick stepping (property-tested in
//! `rust/tests/stepping.rs`).

pub mod config;

use crate::axi::AxiSlave;
use crate::dma::idma::Idma;
use crate::dma::mcast::{McastEngine, McastSink};
use crate::dma::torrent::dse::AffinePattern;
use crate::dma::torrent::{ChainDest, ChainTask, Torrent};
use crate::dma::{Engine, EngineCtx, EngineKind, TaskResult};
use crate::mem::{AddrMap, Scratchpad};
use crate::noc::packet::{PHASE_DISPATCH, PHASE_ENGINE, PHASE_EXTERNAL};
use crate::noc::shard::{fabric_phases, shard_ranges, split_ranges, QuietVote, ShardMail};
use crate::noc::{NetPort, NetStats, Network, NodeId, Topo, Topology};
use crate::sched::{schedule_pairs, Strategy};
use crate::sim::{FaultKind, StepMode, Watchdog};

pub use config::SocConfig;

/// Everything attached to one mesh node.
pub struct SocNode {
    pub torrent: Torrent,
    pub idma: Idma,
    pub xdma: crate::dma::xdma::Xdma,
    pub mcast: McastEngine,
    pub mcast_sink: McastSink,
    pub slave: AxiSlave,
    pub mem: Scratchpad,
}

impl SocNode {
    /// The node's four P2MP engines as [`Engine`] trait objects, in the
    /// deterministic dispatch order the event loop uses. XDMA precedes
    /// the Torrent frontend: chain legs it emits are offered to the
    /// engines ticked after it, so a leg starts the same cycle.
    pub fn engines(&self) -> [&dyn Engine; 4] {
        [&self.xdma, &self.torrent, &self.idma, &self.mcast]
    }

    /// Mutable form of [`SocNode::engines`], same order.
    pub fn engines_mut(&mut self) -> [&mut dyn Engine; 4] {
        [&mut self.xdma, &mut self.torrent, &mut self.idma, &mut self.mcast]
    }

    /// The engine serving `kind` — the single `EngineKind` → engine
    /// mapping in the codebase; everything else dispatches uniformly.
    pub fn engine(&self, kind: EngineKind) -> &dyn Engine {
        match kind {
            EngineKind::Torrent(_) => &self.torrent,
            EngineKind::Idma => &self.idma,
            EngineKind::Xdma => &self.xdma,
            EngineKind::Mcast => &self.mcast,
        }
    }

    /// Mutable form of [`SocNode::engine`].
    pub fn engine_mut(&mut self, kind: EngineKind) -> &mut dyn Engine {
        match kind {
            EngineKind::Torrent(_) => &mut self.torrent,
            EngineKind::Idma => &mut self.idma,
            EngineKind::Xdma => &mut self.xdma,
            EngineKind::Mcast => &mut self.mcast,
        }
    }
}

/// The simulated SoC.
pub struct Soc {
    pub cfg: SocConfig,
    pub net: Network,
    pub nodes: Vec<SocNode>,
    pub map: AddrMap,
    /// How [`Soc::run_until_idle`] advances the system.
    pub step_mode: StepMode,
    /// Ticks actually executed by the run loops (diagnostics / benches).
    pub ticks_executed: u64,
    /// Cycles fast-forwarded over by event-driven stepping.
    pub cycles_skipped: u64,
    /// Per-node engine drop-out cycle (`u64::MAX` = never), from the
    /// fault plan's [`FaultKind::FollowerDrop`] entries — from that cycle
    /// on, the node's engine complex (engines, AXI slave, multicast
    /// sink) is fail-silent while its router keeps routing. A direct
    /// table, not a scan over the plan: [`Soc::node_dropped`] sits on the
    /// per-packet dispatch path and must be O(1).
    drop_cycle: Vec<u64>,
    /// Sorted, deduplicated drop-activation cycles (per-node earliest),
    /// so [`Soc::next_drop_activation`] is one `partition_point`.
    drop_events: Vec<u64>,
    /// True when the config carries any fault at all (fabric or SoC
    /// layer) — the single gate in front of all degraded-path logic.
    faults_armed: bool,
}

impl Soc {
    pub fn new(cfg: SocConfig) -> Self {
        let topo = cfg.build_topo();
        let map = AddrMap::new(topo.n_nodes(), cfg.window);
        let nodes = (0..topo.n_nodes())
            .map(NodeId)
            .map(|id| SocNode {
                torrent: Torrent::new(id),
                idma: Idma::new(id),
                xdma: crate::dma::xdma::Xdma::new(id),
                mcast: McastEngine::new(id),
                mcast_sink: McastSink::default(),
                slave: AxiSlave::new(),
                mem: Scratchpad::new(map.base_of(id), cfg.spm_bytes),
            })
            .collect();
        let mut net = Network::new(topo);
        net.install_faults(&cfg.faults);
        let mut drop_cycle = vec![u64::MAX; topo.n_nodes()];
        for f in &cfg.faults.faults {
            if let FaultKind::FollowerDrop { node } = f.kind {
                drop_cycle[node] = drop_cycle[node].min(f.at_cycle);
            }
        }
        let mut drop_events: Vec<u64> =
            drop_cycle.iter().copied().filter(|&c| c != u64::MAX).collect();
        drop_events.sort_unstable();
        drop_events.dedup();
        let faults_armed = !cfg.faults.is_empty();
        let step_mode = if cfg.threads > 1 {
            StepMode::Parallel { threads: cfg.threads }
        } else {
            StepMode::default()
        };
        Soc {
            cfg,
            net,
            nodes,
            map,
            step_mode,
            ticks_executed: 0,
            cycles_skipped: 0,
            drop_cycle,
            drop_events,
            faults_armed,
        }
    }

    /// Builder-style step-mode override (differential tests, benches).
    pub fn with_step_mode(cfg: SocConfig, mode: StepMode) -> Self {
        let mut soc = Soc::new(cfg);
        soc.step_mode = mode;
        soc
    }

    /// The NoC fabric (mesh, torus or ring). `Copy`; coerces to
    /// `&dyn Topology` wherever the schedulers want the trait.
    pub fn topo(&self) -> Topo {
        self.net.topo
    }

    pub fn cycle(&self) -> u64 {
        self.net.cycle
    }

    /// True when the node's endpoint logic is fail-silent: its engines
    /// dropped out ([`FaultKind::FollowerDrop`]) or its router was killed
    /// (the cluster behind the local port dies with it).
    pub fn node_dropped(&self, node: NodeId) -> bool {
        (self.faults_armed && self.drop_cycle[node.0] <= self.net.cycle)
            || self.net.router_dead(node)
    }

    /// True once any scheduled fault — fabric or engine layer — has
    /// taken effect. From this point on the event-driven stepper stops
    /// skipping, so faulted runs are bit-identical across step modes.
    pub fn any_fault_active(&self) -> bool {
        self.net.fault_active()
            || (self.faults_armed
                && self.drop_events.first().is_some_and(|&at| at <= self.net.cycle))
    }

    /// Earliest not-yet-effective engine drop-out, if any.
    fn next_drop_activation(&self) -> Option<u64> {
        let i = self.drop_events.partition_point(|&at| at <= self.net.cycle);
        self.drop_events.get(i).copied()
    }

    /// Per-node fail-silent flags for this tick, `None` on a fault-free
    /// run (the healthy path allocates nothing). Safe to compute once per
    /// tick: drop activations and router kills cannot change during the
    /// endpoint phases — fault activation happens inside the fabric tick.
    fn dropped_now(&self) -> Option<Vec<bool>> {
        if !self.faults_armed {
            return None;
        }
        Some((0..self.nodes.len()).map(|i| self.node_dropped(NodeId(i))).collect())
    }

    /// Advance one cycle: deliver inboxes, tick engines, tick the fabric.
    pub fn tick(&mut self) {
        let now = self.net.cycle;
        let dropped = self.dropped_now();
        run_endpoint_phases(&mut self.nodes, &mut self.net, 0, now, dropped.as_deref());
        self.net.tick();
    }

    /// [`Soc::tick`] with the endpoint phases and the fabric sharded
    /// across `threads` workers (the [`StepMode::Parallel`] kernel).
    ///
    /// Each worker owns a contiguous node range — routers and their
    /// co-located engines/memory move together, so engine sends stay
    /// shard-local ([`crate::noc::shard`] has the merge-order argument
    /// for why the result is bit-identical to [`Soc::tick`]).
    ///
    /// Healthy and drop-only plans take a *fused* path: one thread scope
    /// runs endpoint phases, a quiet consensus vote, and the fabric
    /// phases back-to-back, with the vote's barrier separating endpoint
    /// sends from fabric delivery. Plans with fabric faults split into
    /// two scopes so fault activation runs on the main thread between
    /// them — a global barrier event, exactly where the sequential kernel
    /// activates faults (inside `Network::tick`, before delivery).
    pub fn tick_parallel(&mut self, threads: usize) {
        let ranges = shard_ranges(self.nodes.len(), threads);
        if ranges.len() <= 1 {
            // One shard is definitionally the sequential kernel; skip the
            // scope/barrier machinery entirely.
            self.tick();
            return;
        }
        let now = self.net.cycle;
        let topo = self.net.topo;
        let dropped = self.dropped_now();
        let drop_slices: Vec<Option<&[bool]>> = ranges
            .iter()
            .map(|r| dropped.as_deref().map(|d| &d[r.start..r.end]))
            .collect();
        if self.net.faults.is_some() {
            // Split path: endpoint scope, then the fabric's own parallel
            // tick (which activates due faults on the main thread first).
            let shards = self.net.endpoint_shards(&ranges);
            let node_slices = split_ranges(&mut self.nodes, &ranges);
            let deltas: Vec<NetStats> = std::thread::scope(|sc| {
                let handles: Vec<_> = shards
                    .into_iter()
                    .zip(node_slices)
                    .zip(&drop_slices)
                    .enumerate()
                    .map(|(si, ((mut shard, nodes), &drop))| {
                        let base = ranges[si].start;
                        sc.spawn(move || {
                            run_endpoint_phases(nodes, &mut shard, base, now, drop);
                            shard.finish().1
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("soc endpoint shard worker panicked"))
                    .collect()
            });
            for d in &deltas {
                self.net.stats.merge(d);
            }
            self.net.tick_parallel(threads);
            return;
        }
        // Fused path: endpoint phases at cycle `now`, then — behind the
        // consensus barrier — the fabric phases at `now + 1`, all in one
        // scope. The vote decides globally between a real fabric tick and
        // the quiet round-robin advance, mirroring `Network::tick`'s
        // all-lanes-quiet shortcut (fast-forward only when all shards
        // agree the fabric is quiet).
        let s = ranges.len();
        let mail = ShardMail::new(s);
        let vote = QuietVote::new();
        let shards = self.net.endpoint_shards(&ranges);
        let node_slices = split_ranges(&mut self.nodes, &ranges);
        let deltas: Vec<NetStats> = std::thread::scope(|sc| {
            let handles: Vec<_> = shards
                .into_iter()
                .zip(node_slices)
                .zip(&drop_slices)
                .enumerate()
                .map(|(si, ((mut shard, nodes), &drop))| {
                    let (ranges, mail, vote) = (&ranges, &mail, &vote);
                    sc.spawn(move || {
                        let base = ranges[si].start;
                        run_endpoint_phases(nodes, &mut shard, base, now, drop);
                        let (lanes, mut stats) = shard.finish();
                        vote.report(lanes);
                        mail.barrier.wait();
                        if vote.busy() {
                            fabric_phases(
                                lanes,
                                base,
                                si,
                                ranges,
                                topo,
                                now + 1,
                                None,
                                mail,
                                &mut stats,
                            );
                        } else {
                            for lane in lanes.iter_mut() {
                                lane.router.rr_advance(1);
                            }
                        }
                        stats
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("soc shard worker panicked"))
                .collect()
        });
        self.net.cycle += 1;
        for d in &deltas {
            self.net.stats.merge(d);
        }
    }

    /// All engines and the fabric quiescent. Dropped nodes are excluded:
    /// whatever state their dead engines hold can never move again, so it
    /// must not keep the system formally "busy" forever.
    pub fn is_idle(&self) -> bool {
        self.net.is_idle()
            && self.net.inboxes_empty()
            && self.nodes.iter().enumerate().all(|(i, n)| {
                (self.faults_armed && self.node_dropped(NodeId(i)))
                    || (n.engines().into_iter().all(|e| e.is_idle()) && n.slave.is_idle())
            })
    }

    /// Earliest cycle at which any component performs observable work
    /// (the `sim::Clocked::next_event` contract lifted to the system):
    /// `Some(now)` = busy, `Some(c > now)` = quiescent until `c`, `None`
    /// = no scheduled event anywhere (idle, or stalled on messages that
    /// can never arrive — a deadlock the watchdog reports).
    pub fn next_event(&self) -> Option<u64> {
        let now = self.net.cycle;
        if !self.net.inboxes_empty() {
            return Some(now);
        }
        let mut min = self.net.next_event();
        let mut fold = |e: Option<u64>| {
            if let Some(c) = e {
                let c = c.max(now);
                min = Some(min.map_or(c, |m: u64| m.min(c)));
            }
        };
        // A scheduled engine drop-out is an event: the tick at its cycle
        // must execute (not be skipped) so the drop takes effect at the
        // same cycle under both step modes.
        if self.faults_armed {
            fold(self.next_drop_activation().map(|a| a.saturating_sub(1)));
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if self.faults_armed && self.node_dropped(NodeId(i)) {
                continue; // dead engines schedule nothing
            }
            for e in n.engines() {
                fold(e.next_event(now));
            }
            fold(n.slave.next_event(now));
        }
        min
    }

    /// Event-driven fast-forward: jump the shared clock to the earliest
    /// pending event when every skipped tick is provably a no-op. The
    /// jump is capped at the watchdog deadline so a stalled system panics
    /// at exactly the same cycle as full-tick stepping.
    fn fast_forward(&mut self, start: u64, max_cycles: u64) {
        self.fast_forward_cap(start + max_cycles);
    }

    /// The skip kernel shared by [`Soc::run_until_idle`] (cap = watchdog
    /// deadline) and the bounded-horizon stepper (cap = horizon − 1, so
    /// the tick that follows lands exactly on the horizon in every step
    /// mode — see [`Soc::step_toward`]).
    fn fast_forward_cap(&mut self, deadline: u64) {
        // Inbox backlogs and packets mid-ejection drive endpoint logic
        // (dispatch, cut-through forward gates) on the very next tick;
        // the fabric itself must also be skippable.
        if !self.net.inboxes_empty() || self.net.ejections_pending() || !self.net.can_skip() {
            return;
        }
        // Degraded systems tick cycle-by-cycle (see Network::can_skip for
        // the fabric half; engine drop-outs are SoC state the fabric
        // cannot see, hence this second gate).
        if self.faults_armed && self.any_fault_active() {
            return;
        }
        let now = self.net.cycle;
        let target = match self.next_event() {
            Some(ev) if ev > now => ev.min(deadline),
            Some(_) => return, // busy this cycle
            None => deadline,  // stalled: every tick until the cap is a no-op
        };
        if target > now {
            self.net.skip_quiet_cycles(target - now);
            self.cycles_skipped += target - now;
        }
    }

    /// One scheduling quantum of [`Soc::run_until_idle`]: an event-driven
    /// fast-forward (when [`Soc::step_mode`] allows it) followed by
    /// exactly one tick. Exposed so the coordinator's scheduler loop can
    /// interleave task dispatch/collection with stepping while keeping
    /// cycle counts bit-identical to an uninterrupted `run_until_idle`.
    pub fn step_quantum(&mut self, start: u64, max_cycles: u64) {
        match self.step_mode {
            StepMode::FullTick => self.tick(),
            StepMode::EventDriven => {
                self.fast_forward(start, max_cycles);
                self.tick();
            }
            StepMode::Parallel { threads } => {
                // Fast-forward is a main-thread (all-shards) decision: the
                // quiet predicate is global, so the skip is taken exactly
                // when the event-driven stepper would take it.
                self.fast_forward(start, max_cycles);
                self.tick_parallel(threads);
            }
        }
        self.ticks_executed += 1;
    }

    /// One stepping quantum toward an absolute cycle `target`, landing
    /// on or before it — never past it. The event-driven/parallel modes
    /// cap their fast-forward at `target - 1` so the tick that follows
    /// advances the clock to at most `target`; full-tick trivially moves
    /// one cycle. All three modes therefore visit `target` itself with
    /// an executed tick, which is what makes a bounded-horizon run
    /// bit-identical across modes: injection at the horizon happens at
    /// the same cycle regardless of how the gap was crossed.
    ///
    /// Requires `self.cycle() < target` (debug-asserted): a quantum must
    /// move time forward.
    pub fn step_toward(&mut self, target: u64) {
        debug_assert!(self.net.cycle < target, "step_toward requires cycle < target");
        match self.step_mode {
            StepMode::FullTick => self.tick(),
            StepMode::EventDriven => {
                self.fast_forward_cap(target.saturating_sub(1));
                self.tick();
            }
            StepMode::Parallel { threads } => {
                self.fast_forward_cap(target.saturating_sub(1));
                self.tick_parallel(threads);
            }
        }
        self.ticks_executed += 1;
    }

    /// Step until the shared clock reaches the absolute cycle `target`
    /// exactly (no-op when already there). Unlike
    /// [`Soc::run_until_idle`], this does not require quiescence and
    /// never panics: an open-loop driver calls it between injections.
    pub fn step_bounded(&mut self, target: u64) {
        while self.net.cycle < target {
            self.step_toward(target);
        }
    }

    /// Advance the system exactly `cycles` cycles — the bounded-horizon
    /// run API (ISSUE 8): the clock lands precisely on `now + cycles` in
    /// every [`StepMode`], busy or idle, so callers can interleave task
    /// injection with stepping deterministically. Returns the new cycle.
    pub fn run_for(&mut self, cycles: u64) -> u64 {
        self.step_bounded(self.net.cycle + cycles);
        self.net.cycle
    }

    /// Run until quiescent; panics (watchdog) after `max_cycles`. Steps
    /// according to [`Soc::step_mode`]; both modes report bit-identical
    /// cycle counts — event-driven stepping only skips ticks that are
    /// provable no-ops.
    pub fn run_until_idle(&mut self, max_cycles: u64) -> u64 {
        let start = self.net.cycle;
        let dog = Watchdog::new(max_cycles, "soc.quiesce");
        while !self.is_idle() {
            self.step_quantum(start, max_cycles);
            dog.check(self.net.cycle - start);
        }
        self.net.cycle - start
    }

    /// Submit a Chainwrite: `dests` are (node, local write pattern) pairs;
    /// the chain order is decided by `strategy`. Returns the ordered set.
    pub fn chainwrite(
        &mut self,
        task: u32,
        src: NodeId,
        read: AffinePattern,
        dests: &[(NodeId, AffinePattern)],
        strategy: Strategy,
        with_data: bool,
    ) -> Vec<NodeId> {
        let topo = self.topo();
        let (order, ordered) = schedule_pairs(strategy, &topo, src, dests.to_vec());
        let ordered: Vec<ChainDest> = ordered
            .into_iter()
            .map(|(node, pattern)| ChainDest { node, pattern, vias: Default::default() })
            .collect();
        let now = self.net.cycle;
        self.nodes[src.0].torrent.submit(
            ChainTask { task, read, dests: ordered, with_data },
            now,
        );
        order
    }

    /// Latest completed Torrent task result at `node` with id `task`.
    pub fn torrent_result(&self, node: NodeId, task: u32) -> Option<&TaskResult> {
        self.nodes[node.0].torrent.results.iter().find(|r| r.task == task)
    }
}

/// The per-cycle endpoint phases — packet dispatch, then engine logic —
/// for the node range `[base, base + nodes.len())`, against any
/// [`NetPort`] (the whole fabric for sequential stepping, one
/// [`crate::noc::shard::EndpointShard`] per worker for parallel
/// stepping). This is THE single copy of the event loop's endpoint
/// semantics: both kernels execute this exact code, which is half of the
/// bit-exactness argument (the other half lives in `noc::shard`).
///
/// `now` is the cycle the phases run at (the fabric advances afterwards);
/// `dropped`, when present, is base-relative fail-silent flags frozen at
/// tick start. Packet-id phase stamps (`PHASE_DISPATCH` / `PHASE_ENGINE`)
/// keep composed ids in global send order without any shared counter.
fn run_endpoint_phases(
    nodes: &mut [SocNode],
    net: &mut dyn NetPort,
    base: usize,
    now: u64,
    dropped: Option<&[bool]>,
) {
    // 1. Dispatch delivered packets: every engine sees every packet
    //    (uniform dispatch through `dma::Engine`; owners consume,
    //    eavesdroppers return false), then the multicast sink and the
    //    AXI slave get their turn.
    net.set_phase(PHASE_DISPATCH);
    for li in 0..nodes.len() {
        let i = base + li;
        if dropped.is_some_and(|d| d[li]) {
            // Fail-silent endpoint: packets are ejected into the void
            // (the router still routes if only the engines dropped).
            while net.recv(NodeId(i)).is_some() {}
            continue;
        }
        while let Some(pkt) = net.recv(NodeId(i)) {
            let SocNode { torrent, idma, xdma, mcast, mcast_sink, slave, mem } = &mut nodes[li];
            let mut consumed = false;
            {
                let mut ctx = EngineCtx { net: &mut *net, mem: &mut *mem };
                let engines: [&mut dyn Engine; 4] =
                    [&mut *xdma, &mut *torrent, &mut *idma, &mut *mcast];
                for e in engines {
                    consumed |= e.handle(&pkt, &mut ctx, now);
                }
            }
            consumed = consumed
                || mcast_sink.handle(NodeId(i), &pkt, mem, &mut *net)
                || slave.handle(NodeId(i), &pkt, mem, now);
            assert!(consumed, "undeliverable packet at node {i}: {:?}", pkt.msg);
        }
    }
    // 2. Engine logic, uniformly through the trait. Frontend legs
    //    emitted by one engine (XDMA's P2P sub-transfers) are offered
    //    to the engines ticked after it; the Torrent frontend drains
    //    them before its own tick, so legs start the same cycle.
    net.set_phase(PHASE_ENGINE);
    for li in 0..nodes.len() {
        let i = base + li;
        if dropped.is_some_and(|d| d[li]) {
            continue; // dead engines hold no clock
        }
        let SocNode { torrent, idma, xdma, mcast, slave, mem, .. } = &mut nodes[li];
        let mut legs: Vec<(ChainTask, u64)> = Vec::new();
        {
            let mut ctx = EngineCtx { net: &mut *net, mem: &mut *mem };
            let engines: [&mut dyn Engine; 4] =
                [&mut *xdma, &mut *torrent, &mut *idma, &mut *mcast];
            for e in engines {
                e.accept_frontend_legs(&mut legs);
                e.tick(&mut ctx);
                legs.extend(e.take_frontend_legs());
            }
        }
        debug_assert!(legs.is_empty(), "frontend legs left unclaimed at node {i}");
        slave.tick(NodeId(i), &mut *net);
    }
    net.set_phase(PHASE_EXTERNAL);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dma::idma::IdmaTask;
    use crate::dma::mcast::McastTask;
    use crate::dma::xdma::XdmaTask;
    use crate::sched::Strategy;

    fn soc(cols: usize, rows: usize) -> Soc {
        Soc::new(SocConfig::custom(cols, rows, 64 * 1024))
    }

    fn fill_src(soc: &mut Soc, node: NodeId, offset: u64, len: usize) -> Vec<u8> {
        let base = soc.map.base_of(node) + offset;
        let data: Vec<u8> = (0..len).map(|i| (i * 31 + 7) as u8).collect();
        soc.nodes[node.0].mem.write(base, &data);
        data
    }

    #[test]
    fn p2p_chainwrite_moves_data() {
        let mut s = soc(3, 3);
        let data = fill_src(&mut s, NodeId(0), 0x100, 4096);
        let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)) + 0x100, 4096);
        let wr = AffinePattern::contiguous(s.map.base_of(NodeId(5)) + 0x800, 4096);
        s.chainwrite(1, NodeId(0), read, &[(NodeId(5), wr)], Strategy::Naive, true);
        s.run_until_idle(100_000);
        let got = s.nodes[5].mem.peek(s.map.base_of(NodeId(5)) + 0x800, 4096);
        assert_eq!(got, &data[..]);
        let r = s.torrent_result(NodeId(0), 1).expect("result recorded");
        assert!(r.latency() > 0);
    }

    #[test]
    fn chainwrite_delivers_to_all_destinations_in_order() {
        let mut s = soc(4, 4);
        let len = 8 * 1024;
        let data = fill_src(&mut s, NodeId(0), 0, len);
        let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
        let dests: Vec<(NodeId, AffinePattern)> = [5usize, 3, 10, 15]
            .iter()
            .map(|&n| {
                (
                    NodeId(n),
                    AffinePattern::contiguous(s.map.base_of(NodeId(n)) + 0x40, len),
                )
            })
            .collect();
        let order = s.chainwrite(7, NodeId(0), read, &dests, Strategy::Greedy, true);
        assert_eq!(order.len(), 4);
        s.run_until_idle(200_000);
        for (n, _) in &dests {
            let got = s.nodes[n.0].mem.peek(s.map.base_of(*n) + 0x40, len);
            assert_eq!(got, &data[..], "dest {n:?} data mismatch");
        }
        // Middle followers forwarded bytes; the tail did not.
        let tail = *order.last().unwrap();
        assert_eq!(s.nodes[tail.0].torrent.stats.bytes_forwarded, 0);
        for n in &order[..order.len() - 1] {
            assert!(s.nodes[n.0].torrent.stats.bytes_forwarded as usize >= len);
        }
    }

    #[test]
    fn chainwrite_with_layout_transform() {
        // Source contiguous; destination scatters into a strided layout.
        let mut s = soc(3, 3);
        let len = 2048;
        let data = fill_src(&mut s, NodeId(0), 0, len);
        let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
        let dst_base = s.map.base_of(NodeId(4));
        // 128 rows of 16 B, pitch 64 B.
        let wr = AffinePattern::strided(dst_base, 128, 16, 64);
        s.chainwrite(9, NodeId(0), read, &[(NodeId(4), wr)], Strategy::Naive, true);
        s.run_until_idle(200_000);
        for row in 0..128 {
            let got = s.nodes[4].mem.peek(dst_base + row as u64 * 64, 16);
            assert_eq!(got, &data[row * 16..row * 16 + 16], "row {row}");
        }
    }

    #[test]
    fn chainwrite_latency_scales_with_dest_count() {
        // More destinations => more overhead, but far less than linear in
        // total bytes (that's the whole point of Chainwrite).
        let lat = |n_dests: usize| -> u64 {
            let mut s = soc(4, 5);
            let len = 16 * 1024;
            fill_src(&mut s, NodeId(0), 0, len);
            let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
            let dests: Vec<(NodeId, AffinePattern)> = (1..=n_dests)
                .map(|n| {
                    (
                        NodeId(n),
                        AffinePattern::contiguous(s.map.base_of(NodeId(n)), len),
                    )
                })
                .collect();
            s.chainwrite(1, NodeId(0), read, &dests, Strategy::Greedy, false);
            s.run_until_idle(500_000);
            s.torrent_result(NodeId(0), 1).unwrap().latency()
        };
        let l1 = lat(1);
        let l4 = lat(4);
        let l8 = lat(8);
        assert!(l4 > l1 && l8 > l4);
        // Chainwrite: 8 dests must cost far less than 8 separate copies.
        assert!(l8 < l1 * 4, "chainwrite not amortizing: l1={l1} l8={l8}");
    }

    #[test]
    fn chainwrite_moves_bytes_on_torus_and_ring() {
        use crate::noc::TopologyKind;
        for topology in [TopologyKind::Torus, TopologyKind::Ring] {
            let mut s =
                Soc::new(SocConfig::custom(3, 3, 64 * 1024).with_topology(topology));
            let len = 2048;
            let data = fill_src(&mut s, NodeId(0), 0, len);
            let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
            let dests: Vec<(NodeId, AffinePattern)> = [8usize, 4, 1]
                .iter()
                .map(|&n| {
                    (
                        NodeId(n),
                        AffinePattern::contiguous(s.map.base_of(NodeId(n)) + 0x40, len),
                    )
                })
                .collect();
            let order = s.chainwrite(3, NodeId(0), read, &dests, Strategy::Greedy, true);
            assert_eq!(order.len(), 3, "{topology:?}");
            s.run_until_idle(200_000);
            for (n, _) in &dests {
                let got = s.nodes[n.0].mem.peek(s.map.base_of(*n) + 0x40, len);
                assert_eq!(got, &data[..], "{topology:?} dest {n:?} data mismatch");
            }
        }
    }

    #[test]
    fn idma_p2mp_is_sequential_sum() {
        let mut s = soc(3, 3);
        let len = 4096;
        let data = fill_src(&mut s, NodeId(0), 0, len);
        let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
        let dests: Vec<(NodeId, AffinePattern)> = [1usize, 2, 4]
            .iter()
            .map(|&n| {
                (NodeId(n), AffinePattern::contiguous(s.map.base_of(NodeId(n)), len))
            })
            .collect();
        let now = s.cycle();
        s.nodes[0].idma.submit(
            IdmaTask { task: 3, read, dests: dests.clone(), with_data: true },
            now,
        );
        s.run_until_idle(200_000);
        for (n, _) in &dests {
            assert_eq!(
                s.nodes[n.0].mem.peek(s.map.base_of(*n), len),
                &data[..],
                "dest {n:?}"
            );
        }
        assert_eq!(s.nodes[0].idma.results.len(), 1);
    }

    #[test]
    fn xdma_software_p2mp_completes_and_is_slower_than_chainwrite() {
        let run = |use_chain: bool| -> u64 {
            let mut s = soc(3, 3);
            let len = 32 * 1024;
            fill_src(&mut s, NodeId(0), 0, len);
            let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
            let dests: Vec<(NodeId, AffinePattern)> = (1..9)
                .map(|n| {
                    (NodeId(n), AffinePattern::contiguous(s.map.base_of(NodeId(n)), len))
                })
                .collect();
            let now = s.cycle();
            if use_chain {
                s.chainwrite(11, NodeId(0), read, &dests, Strategy::Tsp, false);
                s.run_until_idle(1_000_000);
                s.torrent_result(NodeId(0), 11).unwrap().latency()
            } else {
                s.nodes[0].xdma.submit(
                    XdmaTask { task: 11, read, dests, with_data: false },
                    now,
                );
                s.run_until_idle(1_000_000);
                s.nodes[0].xdma.results[0].latency()
            }
        };
        let chain = run(true);
        let xdma = run(false);
        assert!(
            xdma > 4 * chain,
            "expected chainwrite >> xdma-unicast at 8 dests: chain={chain} xdma={xdma}"
        );
    }

    #[test]
    fn mcast_delivers_and_completes() {
        let mut s = soc(4, 4);
        let len = 8 * 1024;
        let data = fill_src(&mut s, NodeId(0), 0, len);
        let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
        let dests: Vec<NodeId> = [3usize, 12, 15].iter().map(|&n| NodeId(n)).collect();
        let now = s.cycle();
        s.nodes[0].mcast.submit(
            McastTask { task: 5, read, dests: dests.clone(), drop_offset: 0x100, with_data: true },
            now,
        );
        s.run_until_idle(200_000);
        for n in &dests {
            let got = s.nodes[n.0].mem.peek(s.map.base_of(*n) + 0x100, len);
            assert_eq!(got, &data[..], "dest {n:?}");
        }
        assert_eq!(s.nodes[0].mcast.results.len(), 1);
    }

    #[test]
    fn concurrent_chainwrites_from_different_initiators() {
        let mut s = soc(4, 4);
        let len = 4096;
        let d0 = fill_src(&mut s, NodeId(0), 0, len);
        let d15 = fill_src(&mut s, NodeId(15), 0, len);
        let r0 = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
        let r15 = AffinePattern::contiguous(s.map.base_of(NodeId(15)), len);
        let w = |n: usize, off: u64| {
            AffinePattern::contiguous(s.map.base_of(NodeId(n)) + off, len)
        };
        let dests0 = vec![(NodeId(5), w(5, 0)), (NodeId(6), w(6, 0))];
        let dests15 = vec![(NodeId(9), w(9, 0x2000)), (NodeId(10), w(10, 0x2000))];
        s.chainwrite(21, NodeId(0), r0, &dests0, Strategy::Greedy, true);
        s.chainwrite(22, NodeId(15), r15, &dests15, Strategy::Greedy, true);
        s.run_until_idle(300_000);
        assert_eq!(s.nodes[5].mem.peek(s.map.base_of(NodeId(5)), len), &d0[..]);
        assert_eq!(s.nodes[6].mem.peek(s.map.base_of(NodeId(6)), len), &d0[..]);
        assert_eq!(
            s.nodes[9].mem.peek(s.map.base_of(NodeId(9)) + 0x2000, len),
            &d15[..]
        );
        assert_eq!(
            s.nodes[10].mem.peek(s.map.base_of(NodeId(10)) + 0x2000, len),
            &d15[..]
        );
    }

    #[test]
    fn event_driven_matches_full_tick_and_actually_skips() {
        use crate::sim::StepMode;
        let run = |mode: StepMode| -> (u64, u64, u64, u64, u64) {
            let mut s = Soc::with_step_mode(SocConfig::custom(3, 3, 64 * 1024), mode);
            let len = 8 * 1024;
            fill_src(&mut s, NodeId(0), 0, len);
            let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
            let dests: Vec<(NodeId, AffinePattern)> = [4usize, 8]
                .iter()
                .map(|&n| {
                    (NodeId(n), AffinePattern::contiguous(s.map.base_of(NodeId(n)), len))
                })
                .collect();
            s.chainwrite(1, NodeId(0), read, &dests, Strategy::Greedy, true);
            let cycles = s.run_until_idle(200_000);
            let lat = s.torrent_result(NodeId(0), 1).unwrap().latency();
            (cycles, lat, s.net.stats.flit_hops, s.ticks_executed, s.cycles_skipped)
        };
        let (c_full, l_full, h_full, t_full, sk_full) = run(StepMode::FullTick);
        let (c_ev, l_ev, h_ev, t_ev, sk_ev) = run(StepMode::EventDriven);
        assert_eq!(c_full, c_ev, "quiesce cycle diverged");
        assert_eq!(l_full, l_ev, "latency diverged");
        assert_eq!(h_full, h_ev, "flit-hops diverged");
        assert_eq!(sk_full, 0);
        assert_eq!(t_full, c_full, "full-tick executes one tick per cycle");
        assert!(sk_ev > 0, "event-driven mode never skipped a cycle");
        assert_eq!(t_ev + sk_ev, c_ev, "ticks + skips must cover the run");
    }

    #[test]
    fn parallel_stepping_matches_event_driven() {
        use crate::sim::StepMode;
        let run = |mode: StepMode| -> (u64, u64, u64, u64) {
            let mut s = Soc::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
            let len = 8 * 1024;
            fill_src(&mut s, NodeId(0), 0, len);
            let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), len);
            let dests: Vec<(NodeId, AffinePattern)> = [5usize, 10, 15]
                .iter()
                .map(|&n| {
                    (NodeId(n), AffinePattern::contiguous(s.map.base_of(NodeId(n)), len))
                })
                .collect();
            s.chainwrite(1, NodeId(0), read, &dests, Strategy::Greedy, true);
            let cycles = s.run_until_idle(300_000);
            let lat = s.torrent_result(NodeId(0), 1).unwrap().latency();
            (cycles, lat, s.net.stats.flit_hops, s.cycles_skipped)
        };
        let (c_ev, l_ev, h_ev, sk_ev) = run(StepMode::EventDriven);
        for threads in [1, 2, 3, 4, 16] {
            let (c, l, h, sk) = run(StepMode::Parallel { threads });
            assert_eq!(c, c_ev, "quiesce cycle diverged at {threads} threads");
            assert_eq!(l, l_ev, "latency diverged at {threads} threads");
            assert_eq!(h, h_ev, "flit-hops diverged at {threads} threads");
            // Parallel mode shares the event-driven fast-forward, so the
            // skip decisions are identical too.
            assert_eq!(sk, sk_ev, "skips diverged at {threads} threads");
        }
    }

    #[test]
    fn parallel_ticks_match_sequential_under_engine_drop() {
        use crate::sim::FaultPlan;
        let cfg = || {
            SocConfig::custom(3, 3, 64 * 1024)
                .with_faults(FaultPlan::parse("drop:4@600").unwrap())
        };
        let submit = |s: &mut Soc| {
            fill_src(s, NodeId(0), 0, 4096);
            let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), 4096);
            let dests: Vec<(NodeId, AffinePattern)> = [4usize, 8]
                .iter()
                .map(|&n| {
                    (NodeId(n), AffinePattern::contiguous(s.map.base_of(NodeId(n)), 4096))
                })
                .collect();
            s.chainwrite(1, NodeId(0), read, &dests, Strategy::Naive, true);
        };
        let mut seq = Soc::new(cfg());
        let mut par = Soc::new(cfg());
        submit(&mut seq);
        submit(&mut par);
        for _ in 0..3_000 {
            seq.tick();
            par.tick_parallel(3);
            assert_eq!(seq.net.cycle, par.net.cycle);
        }
        assert_eq!(seq.net.stats.flit_hops, par.net.stats.flit_hops);
        assert_eq!(seq.net.stats.packets_sent, par.net.stats.packets_sent);
        assert_eq!(seq.net.stats.packets_delivered, par.net.stats.packets_delivered);
        assert_eq!(
            seq.nodes[8].mem.peek(seq.map.base_of(NodeId(8)), 4096),
            par.nodes[8].mem.peek(par.map.base_of(NodeId(8)), 4096),
            "surviving follower memory diverged"
        );
        assert_eq!(
            seq.torrent_result(NodeId(0), 1).is_some(),
            par.torrent_result(NodeId(0), 1).is_some()
        );
    }

    #[test]
    fn drop_table_matches_plan_semantics() {
        use crate::sim::FaultPlan;
        // Activations fire in sorted order; each node flips exactly at
        // its own cycle, independent of plan order.
        let cfg = SocConfig::custom(2, 2, 64 * 1024)
            .with_faults(FaultPlan::parse("drop:1@50;drop:2@20").unwrap());
        let mut s = Soc::new(cfg);
        assert!(!s.node_dropped(NodeId(1)));
        assert!(!s.any_fault_active());
        assert_eq!(s.next_drop_activation(), Some(20));
        s.net.cycle = 20;
        assert!(s.node_dropped(NodeId(2)));
        assert!(!s.node_dropped(NodeId(1)));
        assert!(s.any_fault_active());
        assert_eq!(s.next_drop_activation(), Some(50));
        s.net.cycle = 50;
        assert!(s.node_dropped(NodeId(1)));
        assert_eq!(s.next_drop_activation(), None);
    }

    #[test]
    fn run_until_idle_allows_exactly_the_deadline() {
        let mut probe = soc(2, 2);
        fill_src(&mut probe, NodeId(0), 0, 1024);
        let read = AffinePattern::contiguous(probe.map.base_of(NodeId(0)), 1024);
        let wr = AffinePattern::contiguous(probe.map.base_of(NodeId(3)), 1024);
        probe.chainwrite(
            1,
            NodeId(0),
            read.clone(),
            &[(NodeId(3), wr.clone())],
            Strategy::Naive,
            false,
        );
        let need = probe.run_until_idle(100_000);
        assert!(need > 0);
        // A deadline of exactly `need` must pass (off-by-one regression).
        let mut s = soc(2, 2);
        fill_src(&mut s, NodeId(0), 0, 1024);
        s.chainwrite(1, NodeId(0), read, &[(NodeId(3), wr)], Strategy::Naive, false);
        assert_eq!(s.run_until_idle(need), need);
    }

    #[test]
    #[should_panic(expected = "watchdog 'soc.quiesce' expired")]
    fn run_until_idle_panics_one_past_the_deadline() {
        let mut s = soc(2, 2);
        fill_src(&mut s, NodeId(0), 0, 1024);
        let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), 1024);
        let wr = AffinePattern::contiguous(s.map.base_of(NodeId(3)), 1024);
        s.chainwrite(1, NodeId(0), read, &[(NodeId(3), wr)], Strategy::Naive, false);
        s.run_until_idle(10); // a 1 KB chainwrite needs far more than 10 cycles
    }

    #[test]
    fn run_for_lands_exactly_on_target() {
        use crate::sim::StepMode;
        for mode in [
            StepMode::FullTick,
            StepMode::EventDriven,
            StepMode::Parallel { threads: 2 },
        ] {
            let mut s = Soc::with_step_mode(SocConfig::custom(2, 2, 64 * 1024), mode);
            // Idle system: bounded stepping must still land exactly on the
            // horizon (the fast-forward cap is horizon - 1, tick closes it).
            assert_eq!(s.run_for(1), 1, "{mode:?}");
            assert_eq!(s.run_for(999), 1_000, "{mode:?}");
            // Busy system: mid-transfer horizons must not overshoot either.
            fill_src(&mut s, NodeId(0), 0, 2048);
            let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), 2048);
            let wr = AffinePattern::contiguous(s.map.base_of(NodeId(3)), 2048);
            s.chainwrite(1, NodeId(0), read, &[(NodeId(3), wr)], Strategy::Naive, true);
            for chunk in [1u64, 7, 64, 500] {
                let before = s.net.cycle;
                assert_eq!(s.run_for(chunk), before + chunk, "{mode:?}");
            }
            assert_eq!(s.run_for(0), s.net.cycle, "{mode:?}: zero-length run moves time");
        }
    }

    #[test]
    fn run_for_chunked_matches_run_until_idle_across_modes() {
        use crate::sim::StepMode;
        let submit = |s: &mut Soc| {
            fill_src(s, NodeId(0), 0, 4096);
            let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), 4096);
            let dests: Vec<(NodeId, AffinePattern)> = [5usize, 10, 15]
                .iter()
                .map(|&n| {
                    (NodeId(n), AffinePattern::contiguous(s.map.base_of(NodeId(n)), 4096))
                })
                .collect();
            s.chainwrite(1, NodeId(0), read, &dests, Strategy::Greedy, true);
        };
        // Reference: uninterrupted quiescence drain, event-driven.
        let mut reference =
            Soc::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), StepMode::EventDriven);
        submit(&mut reference);
        let need = reference.run_until_idle(300_000);
        let ref_lat = reference.torrent_result(NodeId(0), 1).unwrap().latency();
        let ref_hops = reference.net.stats.flit_hops;
        // Bounded-horizon stepping in awkward chunk sizes must reproduce
        // the same completion latency and traffic in every mode: run_for
        // only changes *when control returns*, never what the hardware did.
        for mode in [
            StepMode::FullTick,
            StepMode::EventDriven,
            StepMode::Parallel { threads: 2 },
            StepMode::Parallel { threads: 4 },
        ] {
            let mut s = Soc::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
            submit(&mut s);
            while s.net.cycle < need {
                let step = 113.min(need - s.net.cycle);
                s.run_for(step);
            }
            assert_eq!(s.net.cycle, need, "{mode:?}");
            assert!(s.is_idle(), "{mode:?}: not idle at the reference quiesce cycle");
            assert_eq!(
                s.torrent_result(NodeId(0), 1).unwrap().latency(),
                ref_lat,
                "{mode:?}: latency diverged under chunked stepping"
            );
            assert_eq!(s.net.stats.flit_hops, ref_hops, "{mode:?}: traffic diverged");
        }
    }

    #[test]
    fn dropped_follower_goes_fail_silent() {
        use crate::sim::FaultPlan;
        let cfg = SocConfig::custom(2, 2, 64 * 1024)
            .with_faults(FaultPlan::parse("drop:1@0").unwrap());
        let mut s = Soc::new(cfg);
        assert!(s.node_dropped(NodeId(1)));
        assert!(s.any_fault_active());
        fill_src(&mut s, NodeId(0), 0, 1024);
        let read = AffinePattern::contiguous(s.map.base_of(NodeId(0)), 1024);
        let wr = AffinePattern::contiguous(s.map.base_of(NodeId(1)), 1024);
        s.chainwrite(1, NodeId(0), read, &[(NodeId(1), wr)], Strategy::Naive, true);
        for _ in 0..5_000 {
            s.tick();
        }
        // The cfg packet was ejected into the void: no grant ever comes
        // back, the task never completes, and the initiator still holds
        // protocol state (the stall the coordinator's watchdog detects).
        assert!(s.torrent_result(NodeId(0), 1).is_none());
        assert!(!s.is_idle(), "initiator must still be waiting");
        assert!(s.net.is_idle(), "no traffic may linger in the fabric");
        assert_eq!(
            s.nodes[1].mem.peek(s.map.base_of(NodeId(1)), 1024),
            vec![0u8; 1024],
            "a dropped follower must not write memory"
        );
    }

    #[test]
    fn local_loopback_reshuffles_in_place() {
        let mut s = soc(2, 2);
        let base = s.map.base_of(NodeId(0));
        let data = fill_src(&mut s, NodeId(0), 0, 1024);
        let node = &mut s.nodes[0];
        let read = AffinePattern::contiguous(base, 1024);
        let write = AffinePattern::strided(base + 0x4000, 64, 16, 32);
        let done = node.torrent.local_loopback(&read, &write, &mut node.mem, 0);
        assert!(done >= 32, "loopback should cost stream cycles");
        for row in 0..64 {
            assert_eq!(
                node.mem.peek(base + 0x4000 + row as u64 * 32, 16),
                &data[row * 16..row * 16 + 16]
            );
        }
    }
}

//! Descriptive statistics + ordinary-least-squares regression.
//!
//! Used by the benches: Fig 7 fits the per-destination configuration
//! overhead slope (paper: 82 CC/destination), Fig 6 reports means over 128
//! random destination sets, and the §Perf harness reports p50/p99.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via nearest-rank on a sorted copy. `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank]
}

/// OLS fit `y = slope * x + intercept`; returns `(slope, intercept, r2)`.
pub fn linregress(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (slope, intercept, r2)
}

/// Simple timing summary for the in-repo bench harness.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub p50: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for &x in xs {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            p50: percentile(xs, 50.0),
            p99: percentile(xs, 99.0),
            min: mn,
            max: mx,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn linregress_exact_line() {
        let xs: Vec<f64> = (1..=8).map(|v| v as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 82.0 * x + 110.0).collect();
        let (s, i, r2) = linregress(&xs, &ys);
        assert!((s - 82.0).abs() < 1e-9);
        assert!((i - 110.0).abs() < 1e-6);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linregress_noisy_r2_below_one() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 2.5, 2.6, 4.2];
        let (_, _, r2) = linregress(&xs, &ys);
        assert!(r2 < 1.0 && r2 > 0.8);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }
}

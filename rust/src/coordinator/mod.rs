//! Task-level coordinator: the framework layer a launcher talks to.
//!
//! Owns the simulated SoC, assigns global task ids, routes P2MP requests
//! to the right engine (Torrent Chainwrite with a scheduling strategy,
//! iDMA repeated-unicast, XDMA software P2MP, or ESP-style network
//! multicast), runs the system to completion and aggregates the metrics
//! every bench reports (latency, η_P2MP, hops, activity counters).

use crate::analysis::eta_p2mp;
use crate::dma::idma::IdmaTask;
use crate::dma::mcast::McastTask;
use crate::dma::torrent::dse::AffinePattern;
use crate::dma::xdma::XdmaTask;
use crate::dma::TaskResult;
use crate::noc::NodeId;
use crate::sched::Strategy;
use crate::soc::{Soc, SocConfig};

/// Which engine serves a P2MP request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Torrent Chainwrite with the given chain-order strategy.
    Torrent(Strategy),
    /// iDMA: repeated unicast, sequential.
    Idma,
    /// XDMA: software P2MP over the distributed frontend.
    Xdma,
    /// ESP-style network-layer multicast.
    Mcast,
}

impl EngineKind {
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Torrent(Strategy::Naive) => "torrent/naive",
            EngineKind::Torrent(Strategy::Greedy) => "torrent/greedy",
            EngineKind::Torrent(Strategy::Tsp) => "torrent/tsp",
            EngineKind::Idma => "idma",
            EngineKind::Xdma => "xdma",
            EngineKind::Mcast => "mcast",
        }
    }
}

/// A point-to-multipoint request.
#[derive(Debug, Clone)]
pub struct P2mpRequest {
    pub src: NodeId,
    pub read: AffinePattern,
    pub dests: Vec<(NodeId, AffinePattern)>,
    pub engine: EngineKind,
    pub with_data: bool,
}

/// Submission record + (after completion) the result.
#[derive(Debug)]
pub struct Record {
    pub task: u32,
    pub engine: EngineKind,
    pub src: NodeId,
    pub n_dests: usize,
    pub bytes: usize,
    pub chain_order: Option<Vec<NodeId>>,
    pub result: Option<TaskResult>,
}

impl Record {
    /// η_P2MP of the completed task (Eq. 1).
    pub fn eta(&self) -> Option<f64> {
        self.result
            .as_ref()
            .map(|r| eta_p2mp(self.n_dests, self.bytes, r.latency()))
    }
}

/// The coordinator.
pub struct Coordinator {
    pub soc: Soc,
    next_task: u32,
    pub records: Vec<Record>,
}

impl Coordinator {
    pub fn new(cfg: SocConfig) -> Self {
        Coordinator { soc: Soc::new(cfg), next_task: 1, records: Vec::new() }
    }

    /// Coordinator over a SoC stepped in an explicit `sim::StepMode`
    /// (differential tests and the stepping benches; the default is the
    /// activity-tracked event-driven stepper).
    pub fn with_step_mode(cfg: SocConfig, mode: crate::sim::StepMode) -> Self {
        Coordinator { soc: Soc::with_step_mode(cfg, mode), next_task: 1, records: Vec::new() }
    }

    /// Submit a request; returns its task id.
    pub fn submit(&mut self, req: P2mpRequest) -> u32 {
        let task = self.next_task;
        self.next_task += 1;
        let now = self.soc.cycle();
        let bytes = req.read.total_bytes();
        let mut chain_order = None;
        match req.engine {
            EngineKind::Torrent(strategy) => {
                let order = self.soc.chainwrite(
                    task,
                    req.src,
                    req.read.clone(),
                    &req.dests,
                    strategy,
                    req.with_data,
                );
                chain_order = Some(order);
            }
            EngineKind::Idma => {
                self.soc.nodes[req.src.0].idma.submit(
                    IdmaTask {
                        task,
                        read: req.read.clone(),
                        dests: req.dests.clone(),
                        with_data: req.with_data,
                    },
                    now,
                );
            }
            EngineKind::Xdma => {
                self.soc.nodes[req.src.0].xdma.submit(
                    XdmaTask {
                        task,
                        read: req.read.clone(),
                        dests: req.dests.clone(),
                        with_data: req.with_data,
                    },
                    now,
                );
            }
            EngineKind::Mcast => {
                // Multicast drops the block at the same window-local offset
                // everywhere: derive it from the first destination pattern.
                let (n0, p0) = &req.dests[0];
                let offset = p0.base - self.soc.map.base_of(*n0);
                self.soc.nodes[req.src.0].mcast.submit(
                    McastTask {
                        task,
                        read: req.read.clone(),
                        dests: req.dests.iter().map(|(n, _)| *n).collect(),
                        drop_offset: offset,
                        with_data: req.with_data,
                    },
                    now,
                );
            }
        }
        self.records.push(Record {
            task,
            engine: req.engine,
            src: req.src,
            n_dests: req.dests.len(),
            bytes,
            chain_order,
            result: None,
        });
        task
    }

    /// Route a request to the initiator that owns the source data: the
    /// Torrent attached to the memory `read.base` resolves to (the
    /// "distributed" in distributed DMA — no central engine pulls the
    /// data across the fabric first).
    pub fn submit_auto(&mut self, mut req: P2mpRequest) -> u32 {
        let owner = self
            .soc
            .map
            .node_of(req.read.base)
            .expect("source address outside the SoC map");
        req.src = owner;
        self.submit(req)
    }

    /// Convenience: contiguous `bytes` from `src`'s window to the upper
    /// half of each destination window.
    pub fn submit_simple(
        &mut self,
        src: NodeId,
        dests: &[NodeId],
        bytes: usize,
        engine: EngineKind,
        with_data: bool,
    ) -> u32 {
        let half = self.soc.cfg.spm_bytes as u64 / 2;
        assert!(bytes as u64 <= half, "transfer must fit half a scratchpad");
        let read = AffinePattern::contiguous(self.soc.map.base_of(src), bytes);
        let dest_patterns: Vec<(NodeId, AffinePattern)> = dests
            .iter()
            .map(|&d| {
                (d, AffinePattern::contiguous(self.soc.map.base_of(d) + half, bytes))
            })
            .collect();
        self.submit(P2mpRequest { src, read, dests: dest_patterns, engine, with_data })
    }

    /// Run until every engine drains, then collect results into records.
    /// Stepping follows `self.soc.step_mode`; the underlying loop is
    /// watchdog-guarded (`sim::Watchdog`, label `soc.quiesce`).
    pub fn run_to_completion(&mut self, max_cycles: u64) {
        self.soc.run_until_idle(max_cycles);
        for rec in &mut self.records {
            if rec.result.is_some() {
                continue;
            }
            let node = &self.soc.nodes[rec.src.0];
            let found = match rec.engine {
                EngineKind::Torrent(_) => {
                    node.torrent.results.iter().find(|r| r.task == rec.task)
                }
                EngineKind::Idma => node.idma.results.iter().find(|r| r.task == rec.task),
                EngineKind::Xdma => node.xdma.results.iter().find(|r| r.task == rec.task),
                EngineKind::Mcast => node.mcast.results.iter().find(|r| r.task == rec.task),
            };
            rec.result = found.cloned();
        }
    }

    /// Latency of a completed task.
    pub fn latency_of(&self, task: u32) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.task == task)
            .and_then(|r| r.result.as_ref())
            .map(|res| res.latency())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord() -> Coordinator {
        Coordinator::new(SocConfig::custom(3, 3, 64 * 1024))
    }

    #[test]
    fn all_engines_complete_a_simple_p2mp() {
        for engine in [
            EngineKind::Torrent(Strategy::Greedy),
            EngineKind::Idma,
            EngineKind::Xdma,
            EngineKind::Mcast,
        ] {
            let mut c = coord();
            let dests = vec![NodeId(1), NodeId(4), NodeId(8)];
            let t = c.submit_simple(NodeId(0), &dests, 8 * 1024, engine, false);
            c.run_to_completion(2_000_000);
            let lat = c.latency_of(t).unwrap_or_else(|| panic!("{engine:?} incomplete"));
            assert!(lat > 0, "{engine:?}");
        }
    }

    #[test]
    fn eta_ordering_matches_paper_mechanisms() {
        // For a large transfer to many destinations: chainwrite and mcast
        // must beat unicast (η>1), idma stays ≤ ~1.
        let mut c = coord();
        let dests: Vec<NodeId> = (1..9).map(NodeId).collect();
        let bytes = 16 * 1024;
        let t_chain = c.submit_simple(
            NodeId(0),
            &dests,
            bytes,
            EngineKind::Torrent(Strategy::Tsp),
            false,
        );
        c.run_to_completion(4_000_000);
        let mut c2 = coord();
        let t_idma = c2.submit_simple(NodeId(0), &dests, bytes, EngineKind::Idma, false);
        c2.run_to_completion(4_000_000);
        let eta_chain = c.records.iter().find(|r| r.task == t_chain).unwrap().eta().unwrap();
        let eta_idma =
            c2.records.iter().find(|r| r.task == t_idma).unwrap().eta().unwrap();
        assert!(eta_chain > 2.0, "chainwrite eta {eta_chain}");
        assert!(eta_idma <= 1.05, "idma eta {eta_idma}");
    }

    #[test]
    fn torrent_records_chain_order() {
        let mut c = coord();
        let t = c.submit_simple(
            NodeId(0),
            &[NodeId(2), NodeId(6)],
            1024,
            EngineKind::Torrent(Strategy::Greedy),
            false,
        );
        let rec = c.records.iter().find(|r| r.task == t).unwrap();
        assert_eq!(rec.chain_order.as_ref().unwrap().len(), 2);
    }

    #[test]
    fn task_ids_are_unique_and_increasing() {
        let mut c = coord();
        let a = c.submit_simple(NodeId(0), &[NodeId(1)], 64, EngineKind::Idma, false);
        let b = c.submit_simple(NodeId(4), &[NodeId(5)], 64, EngineKind::Idma, false);
        assert!(b > a);
    }
}

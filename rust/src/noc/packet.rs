//! Packets, flits and the message vocabulary carried over the NoC.
//!
//! Links are 64 bytes/cycle (paper §IV-A), so one flit carries 64 B. A
//! packet is one head flit (routing + message metadata) followed by
//! `ceil(payload / 64)` body flits; the last flit is the tail. Payload
//! bytes ride the packet as an `Rc<Vec<u8>>` shared by all of its flits —
//! wormhole timing comes from flit accounting, data integrity from the
//! payload arriving with the tail.

use std::rc::Rc;

use super::topology::NodeId;

/// Link width: bytes moved per flit per cycle (64 B/CC, paper §IV-A).
pub const FLIT_BYTES: usize = 64;

/// Unique packet id (simulation-global).
pub type PacketId = u64;

/// Message vocabulary. The NoC treats these opaquely; the AXI layer and
/// the DMA engines give them meaning.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// AXI AW+W burst: write `bytes` at `addr` (payload carries the data).
    AxiWriteReq { addr: u64, bytes: usize, axi_id: u16 },
    /// AXI B response.
    AxiWriteResp { axi_id: u16, ok: bool },
    /// AXI AR request: read `bytes` from `addr`.
    AxiReadReq { addr: u64, bytes: usize, axi_id: u16 },
    /// AXI R response burst (payload carries the data).
    AxiReadResp { axi_id: u16, ok: bool },
    /// Torrent cross-DMA configuration frames (payload = encoded cfg).
    TorrentCfg { task: u32 },
    /// Chainwrite Grant, propagated tail -> head.
    TorrentGrant { task: u32 },
    /// Chainwrite Finish, propagated tail -> head.
    TorrentFinish { task: u32 },
    /// Chainwrite data stream segment (payload = data; `seq` orders segments).
    ChainData { task: u32, seq: u32, last: bool },
    /// Multicast data stream segment (ESP-style network-layer multicast).
    McastData { task: u32, seq: u32, last: bool, addr: u64 },
    /// Multicast delivery acknowledgement (dest -> source).
    McastAck { task: u32, seq: u32 },
    /// Test-only raw message.
    Raw(u64),
}

/// A NoC packet.
#[derive(Debug, Clone)]
pub struct Packet {
    pub id: PacketId,
    pub src: NodeId,
    pub dst: NodeId,
    pub msg: Message,
    /// Payload byte count (determines body-flit count). May exceed
    /// `payload.len()` only when a test models phantom data.
    pub payload_bytes: usize,
    /// Actual data moved, if any.
    pub payload: Option<Rc<Vec<u8>>>,
    /// ESP-style multicast destination set; `dst` is ignored when set.
    pub mcast_dsts: Option<Rc<Vec<NodeId>>>,
}

impl Packet {
    pub fn new(id: PacketId, src: NodeId, dst: NodeId, msg: Message) -> Self {
        Packet { id, src, dst, msg, payload_bytes: 0, payload: None, mcast_dsts: None }
    }

    pub fn with_payload(mut self, data: Vec<u8>) -> Self {
        self.payload_bytes = data.len();
        self.payload = Some(Rc::new(data));
        self
    }

    /// Account payload length without materializing bytes (pure-timing runs).
    pub fn with_phantom_payload(mut self, bytes: usize) -> Self {
        self.payload_bytes = bytes;
        self.payload = None;
        self
    }

    /// Attach an already-shared payload without copying (the Torrent data
    /// switch forwards the incoming stream's bytes to the next hop).
    pub fn with_shared_payload(mut self, data: Option<Rc<Vec<u8>>>, bytes: usize) -> Self {
        self.payload_bytes = bytes;
        self.payload = data;
        self
    }

    pub fn with_mcast(mut self, dsts: Vec<NodeId>) -> Self {
        self.mcast_dsts = Some(Rc::new(dsts));
        self
    }

    /// Total flits: 1 head + ceil(payload/FLIT_BYTES) body.
    pub fn len_flits(&self) -> usize {
        1 + self.payload_bytes.div_ceil(FLIT_BYTES)
    }
}

/// One flit of a packet in flight. All flits of a packet share the
/// `Rc<Packet>`; `seq` runs 0..len_flits.
#[derive(Debug, Clone)]
pub struct Flit {
    pub packet: Rc<Packet>,
    pub seq: u32,
}

impl Flit {
    pub fn is_head(&self) -> bool {
        self.seq == 0
    }

    pub fn is_tail(&self) -> bool {
        self.seq as usize == self.packet.len_flits() - 1
    }
}

/// Expand a packet into its flit sequence (used by injection queues).
pub fn flits_of(packet: Rc<Packet>) -> impl Iterator<Item = Flit> {
    let n = packet.len_flits() as u32;
    (0..n).map(move |seq| Flit { packet: packet.clone(), seq })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(bytes: usize) -> Packet {
        Packet::new(1, NodeId(0), NodeId(1), Message::Raw(0)).with_phantom_payload(bytes)
    }

    #[test]
    fn flit_count_header_plus_body() {
        assert_eq!(pkt(0).len_flits(), 1); // head only
        assert_eq!(pkt(1).len_flits(), 2);
        assert_eq!(pkt(64).len_flits(), 2);
        assert_eq!(pkt(65).len_flits(), 3);
        assert_eq!(pkt(4096).len_flits(), 65);
    }

    #[test]
    fn head_and_tail_flags() {
        let p = Rc::new(pkt(128));
        let fl: Vec<Flit> = flits_of(p).collect();
        assert_eq!(fl.len(), 3);
        assert!(fl[0].is_head() && !fl[0].is_tail());
        assert!(!fl[1].is_head() && !fl[1].is_tail());
        assert!(fl[2].is_tail());
    }

    #[test]
    fn single_flit_packet_is_head_and_tail() {
        let p = Rc::new(pkt(0));
        let fl: Vec<Flit> = flits_of(p).collect();
        assert!(fl[0].is_head() && fl[0].is_tail());
    }

    #[test]
    fn payload_roundtrip() {
        let data: Vec<u8> = (0..200).map(|i| i as u8).collect();
        let p = Packet::new(2, NodeId(0), NodeId(3), Message::Raw(1)).with_payload(data.clone());
        assert_eq!(p.payload_bytes, 200);
        assert_eq!(p.len_flits(), 1 + 4);
        assert_eq!(&**p.payload.as_ref().unwrap(), &data);
    }
}

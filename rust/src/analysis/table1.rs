//! Table I: qualitative comparison of Torrent with SoTA DMAs and NoCs.

use crate::util::table::Table;

/// One comparison row.
#[derive(Debug, Clone)]
pub struct SotaRow {
    pub name: &'static str,
    pub arch: &'static str,
    pub addr_gen: &'static str,
    pub axi_compatible: &'static str,
    pub p2mp_method: &'static str,
    pub area_scaling: &'static str,
    pub open_sourced: &'static str,
}

/// The paper's Table I, Torrent first.
pub fn rows() -> Vec<SotaRow> {
    vec![
        SotaRow { name: "Torrent", arch: "Dist. DMA", addr_gen: "ND", axi_compatible: "Yes", p2mp_method: "Chainwrite", area_scaling: "~O(1)", open_sourced: "Yes" },
        SotaRow { name: "Pulp XBar", arch: "XBar", addr_gen: "N/A", axi_compatible: "Yes", p2mp_method: "Multicast", area_scaling: "~O(1)", open_sourced: "Yes" },
        SotaRow { name: "ESP NoC", arch: "NoC", addr_gen: "N/A", axi_compatible: "No", p2mp_method: "Multicast", area_scaling: "O(N)", open_sourced: "Yes" },
        SotaRow { name: "FlexNoC", arch: "NoC", addr_gen: "N/A", axi_compatible: "Yes", p2mp_method: "Multicast", area_scaling: "N/A", open_sourced: "No" },
        SotaRow { name: "XDMA", arch: "Dist. DMA", addr_gen: "ND", axi_compatible: "Yes", p2mp_method: "SW", area_scaling: "N/A", open_sourced: "Yes" },
        SotaRow { name: "iDMA", arch: "Mono. DMA", addr_gen: "ND", axi_compatible: "Yes", p2mp_method: "SW", area_scaling: "N/A", open_sourced: "Yes" },
        SotaRow { name: "HyperDMA", arch: "Dist. DMA", addr_gen: "ND", axi_compatible: "No", p2mp_method: "SW", area_scaling: "N/A", open_sourced: "No" },
        SotaRow { name: "Xilinx DMA", arch: "Mono. DMA", addr_gen: "1D", axi_compatible: "Yes", p2mp_method: "SW", area_scaling: "N/A", open_sourced: "No" },
    ]
}

/// Render Table I as ASCII.
pub fn render() -> String {
    let mut t = Table::new("Table I: Torrent comparison with SoTA DMAs and NoCs")
        .header(["System", "Arch.", "Addr.Gen", "AXI-Comp.", "P2MP", "Area-Scaling", "Open-Source"]);
    for r in rows() {
        t.row([r.name, r.arch, r.addr_gen, r.axi_compatible, r.p2mp_method, r.area_scaling, r.open_sourced]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_systems_torrent_first() {
        let r = rows();
        assert_eq!(r.len(), 8);
        assert_eq!(r[0].name, "Torrent");
        assert_eq!(r[0].p2mp_method, "Chainwrite");
    }

    #[test]
    fn renders_all_rows() {
        let s = render();
        for r in rows() {
            assert!(s.contains(r.name), "missing {}", r.name);
        }
    }

    #[test]
    fn only_torrent_has_chainwrite() {
        assert_eq!(
            rows().iter().filter(|r| r.p2mp_method == "Chainwrite").count(),
            1
        );
    }
}

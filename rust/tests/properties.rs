//! Property-based tests over the coordinator-level invariants, using the
//! in-repo `util::prop` harness (proptest substitute — DESIGN.md §3).

use torrent::axi::split_bursts;
use torrent::coordinator::{Coordinator, EngineKind};
use torrent::dma::torrent::cfg::{CfgType, TorrentCfg};
use torrent::dma::torrent::dse::AffinePattern;
use torrent::noc::multicast::mcast_tree_hops;
use torrent::noc::{Mesh, NodeId};
use torrent::sched::{self, Strategy};
use torrent::soc::SocConfig;
use torrent::util::prop::{check, forall};
use torrent::util::rng::Rng;

/// Random destination set on an 8x8 mesh (source = 0).
fn gen_dests(rng: &mut Rng) -> Vec<NodeId> {
    let n = 1 + rng.index(16);
    rng.sample_distinct(63, n).into_iter().map(|v| NodeId(v + 1)).collect()
}

#[test]
fn prop_schedulers_produce_permutations() {
    let mesh = Mesh::new(8, 8);
    forall(0xA1, 200, gen_dests, |dests| {
        for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp] {
            let order = sched::schedule(s, &mesh, NodeId(0), dests);
            let mut a = order.clone();
            a.sort();
            let mut b = dests.clone();
            b.sort();
            check(a == b, format!("{s:?} not a permutation"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_tsp_never_worse_than_greedy_never_worse_than_random_avg() {
    let mesh = Mesh::new(8, 8);
    forall(0xA2, 120, gen_dests, |dests| {
        let naive = sched::chain_hops(&mesh, NodeId(0), &sched::naive_order(dests));
        let greedy =
            sched::chain_hops(&mesh, NodeId(0), &sched::greedy_order(&mesh, NodeId(0), dests));
        let tsp = sched::chain_hops(&mesh, NodeId(0), &sched::tsp_order(&mesh, NodeId(0), dests));
        check(tsp <= naive, format!("tsp {tsp} > naive {naive}"))?;
        check(tsp <= greedy, format!("tsp {tsp} > greedy {greedy}"))?;
        // Any chain visits every destination: at least 1 hop per dest
        // unless adjacent duplicates (impossible: distinct nodes).
        check(tsp >= dests.len(), "tsp shorter than destination count")?;
        Ok(())
    });
}

#[test]
fn prop_multicast_tree_bounds() {
    let mesh = Mesh::new(8, 8);
    forall(0xA3, 200, gen_dests, |dests| {
        let tree = mcast_tree_hops(&mesh, NodeId(0), dests);
        let uni = sched::unicast_hops(&mesh, NodeId(0), dests);
        let farthest = dests
            .iter()
            .map(|&d| mesh.manhattan(NodeId(0), d))
            .max()
            .unwrap_or(0);
        check(tree <= uni, format!("tree {tree} > unicast {uni}"))?;
        check(tree >= farthest, format!("tree {tree} < eccentricity {farthest}"))?;
        Ok(())
    });
}

#[test]
fn prop_axi_bursts_partition_any_transfer() {
    forall(
        0xA4,
        300,
        |rng| (rng.below(1 << 20), 1 + rng.index(128 * 1024)),
        |&(addr, len)| {
            let bursts = split_bursts(addr, len);
            let mut cur = addr;
            for b in &bursts {
                check(b.addr == cur, "gap or overlap in burst chain")?;
                check(b.bytes > 0, "empty burst")?;
                let last = b.addr + b.bytes as u64 - 1;
                check(b.addr >> 12 == last >> 12, format!("burst {b:?} crosses 4K"))?;
                cur += b.bytes as u64;
            }
            check(cur == addr + len as u64, "bursts do not cover transfer")?;
            Ok(())
        },
    );
}

#[test]
fn prop_cfg_encoding_roundtrips() {
    forall(
        0xA5,
        300,
        |rng| TorrentCfg {
            task: rng.next_u64() as u32,
            cfg_type: if rng.below(2) == 0 { CfgType::Read } else { CfgType::Write },
            prev: (rng.below(2) == 0).then(|| NodeId(rng.index(64))),
            next: (rng.below(2) == 0).then(|| NodeId(rng.index(64))),
            position: rng.below(64) as u16,
            chain_len: rng.below(64) as u16,
            axi_burst_bytes: rng.below(1 << 16) as u32,
            pattern: AffinePattern {
                base: rng.below(1 << 30),
                elem_bytes: 1 + rng.index(256),
                dims: (0..rng.index(4))
                    .map(|_| (1 + rng.index(64), rng.range(1, 1 << 12) as i64))
                    .collect(),
            },
        },
        |cfg| {
            let back = TorrentCfg::decode(&cfg.encode()).map_err(|e| e.to_string())?;
            check(&back == cfg, "cfg roundtrip mismatch")?;
            Ok(())
        },
    );
}

#[test]
fn prop_dse_gather_scatter_inverse() {
    use torrent::mem::Scratchpad;
    forall(
        0xA6,
        60,
        |rng| {
            let rows = 1 + rng.index(32);
            let run = 1 + rng.index(64);
            let pitch = run as i64 + rng.range(0, 128) as i64;
            (rows, run, pitch, rng.next_u64())
        },
        |&(rows, run, pitch, seed)| {
            let mut src = Scratchpad::new(0, 1 << 16);
            src.fill_pattern(seed as u8);
            let mut dst = Scratchpad::new(0, 1 << 16);
            let p = AffinePattern::strided(0x100, rows, run, pitch);
            if p.total_bytes() + 0x100 > (1 << 15) {
                return Ok(()); // skip out-of-window cases
            }
            let stream = p.gather(&mut src);
            check(stream.len() == p.total_bytes(), "gather length")?;
            p.scatter(&stream, &mut dst);
            for (addr, len) in p.runs() {
                check(
                    dst.peek(addr, len) == src.peek(addr, len),
                    format!("mismatch at run {addr:#x}+{len}"),
                )?;
            }
            Ok(())
        },
    );
}

/// Full-simulation property: random chain tasks always complete, η never
/// exceeds N_dst, and counters are consistent.
#[test]
fn prop_random_chainwrites_complete_with_sane_eta() {
    forall(
        0xA7,
        25,
        |rng| {
            let n_dst = 1 + rng.index(8);
            let kb = 1 << rng.index(6); // 1..32 KB
            let dests = rng
                .sample_distinct(8, n_dst)
                .into_iter()
                .map(|v| NodeId(v + 1))
                .collect::<Vec<_>>();
            (kb * 1024, dests, rng.next_u64())
        },
        |(bytes, dests, _seed)| {
            let mut c = Coordinator::new(SocConfig::custom(3, 3, 256 * 1024));
            let chain = EngineKind::Torrent(Strategy::Greedy);
            let task = c.submit_simple(NodeId(0), dests, *bytes, chain, false).unwrap();
            c.run_to_completion(50_000_000);
            let rec = c.record(task).unwrap();
            let res = rec.result.as_ref().ok_or("task incomplete")?;
            let eta = rec.eta().unwrap();
            check(eta <= dests.len() as f64 + 1e-9, format!("eta {eta} > N_dst"))?;
            check(res.latency() > 0, "zero latency")?;
            check(
                c.soc.net.stats.packets_delivered >= dests.len() as u64,
                "fewer packets than destinations",
            )?;
            Ok(())
        },
    );
}

/// Monotonicity: bigger transfers never get *faster*, for every engine.
#[test]
fn prop_latency_monotone_in_size() {
    for engine in [
        EngineKind::Torrent(Strategy::Greedy),
        EngineKind::Idma,
        EngineKind::Mcast,
    ] {
        let mut prev = 0u64;
        for kb in [1usize, 4, 16, 64] {
            let mut c = Coordinator::new(SocConfig::custom(3, 3, 256 * 1024));
            let dests = [NodeId(1), NodeId(4), NodeId(8)];
            let task = c.submit_simple(NodeId(0), &dests, kb * 1024, engine, false).unwrap();
            c.run_to_completion(50_000_000);
            let lat = c.latency_of(task).unwrap();
            assert!(lat >= prev, "{engine:?}: {kb}KB lat {lat} < previous {prev}");
            prev = lat;
        }
    }
}

//! Failure-injection and adversarial-condition tests: busy followers,
//! saturated fabrics, degenerate patterns, protocol edge cases.

use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest, TaskOutcome, TaskStatus};
use torrent::dma::torrent::dse::AffinePattern;
use torrent::dma::torrent::{ChainDest, ChainTask};
use torrent::noc::{Message, NodeId, Packet, TopologyKind};
use torrent::sched::Strategy;
use torrent::sim::{Fault, FaultKind, FaultPlan, StepMode};
use torrent::soc::{Soc, SocConfig};
use torrent::workloads;

fn coord() -> Coordinator {
    Coordinator::new(SocConfig::custom(3, 3, 256 * 1024))
}

/// A follower already serving one chain delays — but does not deadlock —
/// a second chain through the same node (grant withheld until ready).
#[test]
fn overlapping_chains_through_shared_follower() {
    let mut c = coord();
    let bytes = 32 * 1024;
    // Chain A: 0 -> {1, 4}; Chain B: 8 -> {4, 2}; node 4 is shared.
    let naive = EngineKind::Torrent(Strategy::Naive);
    let ta = c.submit_simple(NodeId(0), &[NodeId(1), NodeId(4)], bytes, naive, false).unwrap();
    let read_b = AffinePattern::contiguous(c.soc.map.base_of(NodeId(8)), bytes);
    let dests_b = vec![
        (NodeId(4), AffinePattern::contiguous(c.soc.map.base_of(NodeId(4)) + 0x20000, bytes)),
        (NodeId(2), AffinePattern::contiguous(c.soc.map.base_of(NodeId(2)) + 0x20000, bytes)),
    ];
    let tb = c
        .submit(
            P2mpRequest::to_patterns(dests_b)
                .src(NodeId(8))
                .read(read_b)
                .engine(EngineKind::Torrent(Strategy::Naive)),
        )
        .unwrap();
    c.run_to_completion(50_000_000);
    assert!(c.latency_of(ta).is_some(), "chain A deadlocked");
    assert!(c.latency_of(tb).is_some(), "chain B deadlocked");
}

/// Sixteen concurrent all-to-different-destination chains saturate the
/// fabric without deadlock or data loss.
#[test]
fn fabric_saturation_many_concurrent_chains() {
    let mut c = Coordinator::new(SocConfig::eval_4x5());
    let bytes = 8 * 1024;
    let mut tasks = vec![];
    for src in 0..16usize {
        let d1 = (src + 2) % 20;
        let d2 = (src + 7) % 20;
        if d1 == src || d2 == src || d1 == d2 {
            continue;
        }
        let read = AffinePattern::contiguous(c.soc.map.base_of(NodeId(src)), bytes);
        let base1 = c.soc.map.base_of(NodeId(d1)) + 0x40000;
        let base2 = c.soc.map.base_of(NodeId(d2)) + 0x60000 + src as u64 * 0x2000;
        let dests = vec![
            (NodeId(d1), AffinePattern::contiguous(base1, bytes)),
            (NodeId(d2), AffinePattern::contiguous(base2, bytes)),
        ];
        tasks.push(
            c.submit(
                P2mpRequest::to_patterns(dests)
                    .src(NodeId(src))
                    .read(read)
                    .engine(EngineKind::Torrent(Strategy::Greedy)),
            )
            .unwrap(),
        );
    }
    c.run_to_completion(100_000_000);
    for t in tasks {
        assert!(c.latency_of(t).is_some(), "task {t} starved");
    }
}

/// Zero-payload cfg-only edge: a 1-byte transfer exercises the full
/// four-phase protocol.
#[test]
fn one_byte_chainwrite() {
    let mut c = coord();
    c.soc.nodes[0].mem.write(c.soc.map.base_of(NodeId(0)), &[0xAB]);
    let chain = EngineKind::Torrent(Strategy::Greedy);
    let t = c.submit_simple(NodeId(0), &[NodeId(8)], 1, chain, true).unwrap();
    c.run_to_completion(1_000_000);
    assert!(c.latency_of(t).is_some());
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    assert_eq!(c.soc.nodes[8].mem.peek(c.soc.map.base_of(NodeId(8)) + half, 1), &[0xAB]);
}

/// Chain where consecutive destinations are maximally distant (worst-case
/// naive order): must still complete within the watchdog.
#[test]
fn pathological_zigzag_chain() {
    let mut c = Coordinator::new(SocConfig::eval_4x5());
    // Alternate corners: 1, 19, 4, 16, 3, 15 (naive keeps this order? No:
    // naive sorts by id — so submit as explicit ChainTask to force it).
    let bytes = 4 * 1024;
    let order = [1usize, 19, 4, 16, 3, 15];
    let dests: Vec<ChainDest> = order
        .iter()
        .map(|&n| ChainDest {
            node: NodeId(n),
            pattern: AffinePattern::contiguous(c.soc.map.base_of(NodeId(n)) + 0x80000, bytes),
            vias: Default::default(),
        })
        .collect();
    let now = c.soc.cycle();
    c.soc.nodes[0].torrent.submit(
        ChainTask {
            task: 777,
            read: AffinePattern::contiguous(c.soc.map.base_of(NodeId(0)), bytes),
            dests,
            with_data: false,
        },
        now,
    );
    c.soc.run_until_idle(50_000_000);
    assert!(c.soc.torrent_result(NodeId(0), 777).is_some());
}

/// Unroutable / malformed traffic is rejected loudly, not silently.
#[test]
#[should_panic(expected = "undeliverable packet")]
fn unknown_message_panics_at_dispatch() {
    let mut soc = Soc::new(SocConfig::custom(2, 2, 32 * 1024));
    soc.net.send(
        NodeId(0),
        Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0xDEAD)),
    );
    soc.run_until_idle(10_000);
}

/// AXI write beyond the destination scratchpad returns ok=false and the
/// initiating engine panics (data would be lost silently otherwise).
#[test]
#[should_panic(expected = "iDMA write burst failed")]
fn idma_write_out_of_range_fails_loudly() {
    let mut soc = Soc::new(SocConfig::custom(2, 2, 32 * 1024));
    let now = soc.cycle();
    // Destination pattern points past node 3's scratchpad.
    soc.nodes[0].idma.submit(
        torrent::dma::idma::IdmaTask {
            task: 1,
            read: AffinePattern::contiguous(soc.map.base_of(NodeId(0)), 64),
            dests: vec![(
                NodeId(3),
                AffinePattern::contiguous(soc.map.base_of(NodeId(3)) + (32 * 1024), 64),
            )],
            with_data: false,
        },
        now,
    );
    soc.run_until_idle(100_000);
}

/// Watchdog fires (panics) when the system genuinely cannot quiesce —
/// here by never delivering a grant (destination outside the mesh is
/// prevented by AddrMap, so emulate with an undeliverable follower cfg).
#[test]
fn watchdog_catches_stall() {
    let mut soc = Soc::new(SocConfig::custom(2, 2, 32 * 1024));
    // A chain whose only destination never grants because we steal its
    // cfg: submit, then drop the cfg packet by draining node 3's inbox
    // before dispatch. Simplest equivalent: assert the watchdog mechanism
    // itself.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        soc.net.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(3), Message::TorrentGrant { task: 42 }),
        );
        // Grant for an unknown task is consumed silently; the fabric
        // drains fine — so use an absurd deadline of 0 to prove the
        // watchdog path triggers.
        soc.run_until_idle(0);
    }));
    assert!(result.is_err(), "watchdog must fire on impossible deadline");
}

/// Strided destination patterns with sub-flit runs (worst DSE rate) still
/// deliver byte-exact data.
#[test]
fn worst_case_strided_write_pattern() {
    let mut c = coord();
    let rows = 512usize;
    let bytes = rows * 4;
    let base0 = c.soc.map.base_of(NodeId(0));
    let data: Vec<u8> = (0..bytes).map(|i| (i % 241) as u8).collect();
    c.soc.nodes[0].mem.write(base0, &data);
    let dst_base = c.soc.map.base_of(NodeId(4)) + 0x1000;
    let write = AffinePattern::strided(dst_base, rows, 4, 32);
    let t = c
        .submit(
            P2mpRequest::to_patterns(vec![(NodeId(4), write)])
                .src(NodeId(0))
                .read(AffinePattern::contiguous(base0, bytes))
                .engine(EngineKind::Torrent(Strategy::Greedy))
                .with_data(true),
        )
        .unwrap();
    c.run_to_completion(10_000_000);
    assert!(c.latency_of(t).is_some());
    for r in 0..rows {
        assert_eq!(
            c.soc.nodes[4].mem.peek(dst_base + r as u64 * 32, 4),
            &data[r * 4..r * 4 + 4],
            "row {r}"
        );
    }
}

// ===========================================================================
// Seeded chaos property suite (DESIGN.md §Fault-model).
//
// Each case draws a random destination set, payload size and fault
// schedule (router kills, follower drop-outs, stragglers) from a seeded
// RNG, then checks three properties:
//
//   1. the run terminates well inside the watchdog bound — no fault
//      combination may wedge the scheduler or the fabric;
//   2. the task reaches a terminal classification (Done, Repaired or
//      Failed), never a silent in-between;
//   3. every destination that survives on the degraded fabric — live
//      router, engines not dropped, clean routes to AND from the
//      initiator (cfg/data out, grant/finish back) — holds byte-exact
//      payload data, whether the original chain or a repair chain
//      served it.
//
// 20 seeds per topology (mesh, torus, ring) = 60 randomized cases, plus
// the cross-step-mode determinism cases below.
// ===========================================================================

const CHAOS_SEEDS: u64 = 20;
const CHAOS_DETECT_TIMEOUT: u64 = 2_000;

/// `TORRENT_TOPOLOGY={mesh,torus,ring}` filters the chaos suite to one
/// fabric (the CI fault-matrix job runs one process per fabric; unset
/// runs all three).
fn fabric_selected(topology: TopologyKind) -> bool {
    match std::env::var("TORRENT_TOPOLOGY").ok().as_deref() {
        Some(s) if !s.is_empty() => {
            TopologyKind::parse(s)
                .unwrap_or_else(|| panic!("TORRENT_TOPOLOGY={s:?} (mesh|torus|ring)"))
                == topology
        }
        _ => true,
    }
}

/// Deterministic payload derived from the case seed.
fn chaos_payload(seed: u64, bytes: usize) -> Vec<u8> {
    (0..bytes).map(|i| (i as u64).wrapping_mul(131).wrapping_add(seed) as u8).collect()
}

/// Draw one randomized (dest-set, payload, fault-schedule) case on a
/// 4x4 fabric of the given topology.
fn chaos_case(topology: TopologyKind, seed: u64) -> (SocConfig, Vec<NodeId>, usize) {
    let mut rng = torrent::util::rng(
        seed,
        torrent::util::stream::FAULTS + (topology as u64 + 1),
    );
    let cfg = SocConfig::custom(4, 4, 64 * 1024).with_topology(topology);
    let n_nodes = cfg.n_nodes();
    let n_dests = rng.range(2, 5) as usize;
    let dests = workloads::random_dest_sets(
        &cfg.build_topo(),
        NodeId(0),
        n_dests,
        1,
        rng.next_u64(),
    )
    .remove(0);
    let bytes = rng.range(1, 4) as usize * 1024;
    let mut faults = Vec::new();
    for _ in 0..rng.range(1, 2) {
        let node = rng.range(0, n_nodes as u64 - 1) as usize;
        let at_cycle = rng.range(20, 1_200);
        let kind = match rng.range(0, 2) {
            0 => FaultKind::RouterKill { node },
            1 => FaultKind::FollowerDrop { node },
            _ => FaultKind::Straggler { node, factor: rng.range(2, 4) as u32 },
        };
        faults.push(Fault::new(at_cycle, kind));
    }
    let plan = FaultPlan {
        faults,
        detect_timeout: CHAOS_DETECT_TIMEOUT,
        repair: true,
        resume: false,
        reroute: false,
    };
    (cfg.with_faults(plan), dests, bytes)
}

/// Run one chaos case and check the three properties.
fn check_chaos_case(topology: TopologyKind, seed: u64) {
    let (cfg, dests, bytes) = chaos_case(topology, seed);
    let mut c = Coordinator::new(cfg);
    let src = NodeId(0);
    let payload = chaos_payload(seed, bytes);
    let base = c.soc.map.base_of(src);
    c.soc.nodes[src.0].mem.write(base, &payload);
    let t = c
        .submit_simple(src, &dests, bytes, EngineKind::Torrent(Strategy::Greedy), true)
        .expect("chaos case is a valid request");
    // Property 1: terminates inside the bound (the watchdog panics
    // otherwise, and detection alone needs only a few multiples of the
    // 2000-cycle stall window).
    c.run_to_completion(1_000_000);
    // Property 2: terminal classification.
    let st = t.status(&c);
    assert!(
        matches!(st, TaskStatus::Done | TaskStatus::Repaired | TaskStatus::Failed),
        "{topology:?} seed {seed}: non-terminal status {st:?} after quiescence"
    );
    // Property 3: surviving destinations hold byte-exact data. A
    // destination survives when its router is alive, its engines were
    // not dropped, and both route directions to the initiator are clean
    // (a one-hop repair chain needs cfg/data out and grant/finish back).
    let deg = c.soc.net.degraded_topology();
    if !deg.node_alive(src) || c.soc.node_dropped(src) {
        return; // initiator lost: no delivery guarantees remain
    }
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    for &d in &dests {
        let survivor = deg.node_alive(d)
            && !c.soc.node_dropped(d)
            && deg.path_is_clean(src, d)
            && deg.path_is_clean(d, src);
        if !survivor {
            continue;
        }
        assert_eq!(
            c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(d) + half, bytes),
            &payload[..],
            "{topology:?} seed {seed}: surviving dest {d:?} lost data (status {st:?})"
        );
    }
}

#[test]
fn chaos_mesh_survivors_get_exact_bytes() {
    if !fabric_selected(TopologyKind::Mesh) {
        return;
    }
    for seed in 0..CHAOS_SEEDS {
        check_chaos_case(TopologyKind::Mesh, seed);
    }
}

#[test]
fn chaos_torus_survivors_get_exact_bytes() {
    if !fabric_selected(TopologyKind::Torus) {
        return;
    }
    for seed in 0..CHAOS_SEEDS {
        check_chaos_case(TopologyKind::Torus, seed);
    }
}

#[test]
fn chaos_ring_survivors_get_exact_bytes() {
    if !fabric_selected(TopologyKind::Ring) {
        return;
    }
    for seed in 0..CHAOS_SEEDS {
        check_chaos_case(TopologyKind::Ring, seed);
    }
}

/// One randomized fault-free workload run under a given step mode;
/// returns (report cycles, task latency, bytes at each destination).
fn fault_free_run(
    topology: TopologyKind,
    seed: u64,
    mode: StepMode,
) -> (u64, u64, Vec<Vec<u8>>) {
    let mut rng = torrent::util::rng(
        seed,
        torrent::util::stream::WORKLOAD + (topology as u64 + 1),
    );
    let cfg = SocConfig::custom(4, 4, 64 * 1024).with_topology(topology);
    let n_dests = rng.range(2, 5) as usize;
    let dests = workloads::random_dest_sets(
        &cfg.build_topo(),
        NodeId(0),
        n_dests,
        1,
        rng.next_u64(),
    )
    .remove(0);
    let bytes = rng.range(1, 4) as usize * 1024;
    let mut c = Coordinator::with_step_mode(cfg, mode);
    let src = NodeId(0);
    let payload = chaos_payload(seed, bytes);
    let base = c.soc.map.base_of(src);
    c.soc.nodes[src.0].mem.write(base, &payload);
    let t = c
        .submit_simple(src, &dests, bytes, EngineKind::Torrent(Strategy::Greedy), true)
        .unwrap();
    let report = c.run_to_completion(1_000_000);
    assert!(report.is_clean(), "{topology:?} seed {seed}: fault machinery fired without faults");
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    let mem: Vec<Vec<u8>> = dests
        .iter()
        .map(|&d| c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(d) + half, bytes).to_vec())
        .collect();
    (report.cycles, c.latency_of(t).expect("fault-free run completes"), mem)
}

/// With no faults scheduled the fault layer must be invisible:
/// event-driven, full-tick and sharded-parallel stepping stay
/// bit-identical in cycle count, latency and delivered bytes (12
/// fault-free seeds × three steppers).
#[test]
fn chaos_fault_free_runs_bit_identical_across_step_modes() {
    for topology in TopologyKind::ALL {
        if !fabric_selected(topology) {
            continue;
        }
        for seed in 0..4 {
            let ev = fault_free_run(topology, seed, StepMode::EventDriven);
            let ft = fault_free_run(topology, seed, StepMode::FullTick);
            assert_eq!(ev, ft, "{topology:?} seed {seed}: step modes diverged");
            let threads = 2 + (seed as usize % 3); // 2..=4 across the seeds
            let par = fault_free_run(topology, seed, StepMode::Parallel { threads });
            assert_eq!(
                ev, par,
                "{topology:?} seed {seed}: Parallel{{{threads}}} diverged fault-free"
            );
        }
    }
}

/// Detection and repair are deterministic across step modes: once a
/// fault activates, event-driven stepping stops skipping, so heartbeat
/// sampling, stall detection and repair dispatch land on identical
/// cycles — and the parallel stepper activates faults as a main-thread
/// barrier event, so its degraded runs land on the same cycles too.
/// Compares full outcome records on faulted runs (6 cases × four
/// steppers).
#[test]
fn chaos_faulted_runs_identical_across_step_modes() {
    for topology in TopologyKind::ALL {
        if !fabric_selected(topology) {
            continue;
        }
        for seed in [3, 11] {
            let run = |mode: StepMode| {
                let (cfg, dests, bytes) = chaos_case(topology, seed);
                let mut c = Coordinator::with_step_mode(cfg, mode);
                let src = NodeId(0);
                let payload = chaos_payload(seed, bytes);
                let base = c.soc.map.base_of(src);
                c.soc.nodes[src.0].mem.write(base, &payload);
                let t = c
                    .submit_simple(
                        src,
                        &dests,
                        bytes,
                        EngineKind::Torrent(Strategy::Greedy),
                        true,
                    )
                    .unwrap();
                let report = c.run_to_completion(1_000_000);
                let rec = c.record(t).unwrap();
                (report.cycles, rec.outcome.clone(), c.latency_of(t))
            };
            let ev = run(StepMode::EventDriven);
            let ft = run(StepMode::FullTick);
            assert_eq!(ev, ft, "{topology:?} seed {seed}: faulted step modes diverged");
            for threads in [2, 4] {
                let par = run(StepMode::Parallel { threads });
                assert_eq!(
                    ev, par,
                    "{topology:?} seed {seed}: Parallel{{{threads}}} diverged on a faulted run"
                );
            }
        }
    }
}

/// A transient router kill (`router:N@C+D`) heals after its duration.
/// The cfg lost while the router was down stays lost — healing restores
/// the fabric, not in-flight state — so the wedged chain is detected
/// and repaired on the now-healthy fabric: every destination served,
/// none written off. Both the activation and the heal are barrier
/// events, so event-driven, full-tick and sharded-parallel stepping
/// land on identical cycles, outcomes and latencies.
#[test]
fn transient_fault_heals_and_stays_identical_across_step_modes() {
    let bytes = 8 * 1024;
    let payload = chaos_payload(99, bytes);
    let run = |mode: StepMode| {
        let cfg = SocConfig::custom(4, 4, 64 * 1024)
            .with_faults(FaultPlan::parse("router:1@0+600;timeout:800").unwrap());
        let mut c = Coordinator::with_step_mode(cfg, mode);
        let src = NodeId(0);
        let base = c.soc.map.base_of(src);
        c.soc.nodes[src.0].mem.write(base, &payload);
        let t = c
            .submit_simple(
                src,
                &[NodeId(4), NodeId(5)],
                bytes,
                EngineKind::Torrent(Strategy::Greedy),
                true,
            )
            .unwrap();
        let report = c.run_to_completion(2_000_000);
        assert_eq!(t.status(&c), TaskStatus::Repaired);
        match c.record(t).unwrap().outcome.clone().unwrap() {
            TaskOutcome::Repaired { served, lost, .. } => {
                assert_eq!(served, 2, "the healed fabric serves every destination");
                assert!(lost.is_empty(), "nothing is written off after the heal");
            }
            o => panic!("expected Repaired, got {o:?}"),
        }
        let half = c.soc.cfg.spm_bytes as u64 / 2;
        for d in [NodeId(4), NodeId(5)] {
            assert_eq!(
                c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(d) + half, bytes),
                &payload[..],
                "dest {d:?} must hold exact bytes after the heal"
            );
        }
        (report.cycles, c.record(t).unwrap().outcome.clone(), c.latency_of(t))
    };
    let ev = run(StepMode::EventDriven);
    assert_eq!(ev, run(StepMode::FullTick), "transient heal diverged across step modes");
    for threads in [2, 4] {
        assert_eq!(
            ev,
            run(StepMode::Parallel { threads }),
            "Parallel{{{threads}}} diverged on a transient-fault run"
        );
    }
}

//! Cycle-stepped 2D-mesh wormhole NoC with XY routing, virtual channels,
//! credit flow control and an ESP-style network-layer multicast baseline.
//!
//! Layering follows the paper's Fig 2: this module is the *network* and
//! *link* layers; `crate::axi` is the transport layer; the DMA engines in
//! `crate::dma` are the application layer.

pub mod multicast;
pub mod network;
pub mod packet;
pub mod router;
pub mod topology;

pub use network::{Gate, NetStats, Network};
pub use packet::{Flit, Message, Packet, PacketId, FLIT_BYTES};
pub use router::{BUF_FLITS, LINK_CYCLES, NUM_VCS, ROUTER_PIPELINE};
pub use topology::{Coord, Dir, Mesh, NodeId};

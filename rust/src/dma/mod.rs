//! Application-layer DMA engines — the paper's contribution and its two
//! baselines.
//!
//! * [`torrent`] — the Torrent distributed DMA: DSE (ND-affine address
//!   generation), data switch (stream duplication / cut-through
//!   forwarding), backend (AXI/cfg packet construction) and the
//!   four-phase **Chainwrite** orchestration of Fig 4.
//! * [`idma`] — monolithic P2P DMA (iDMA baseline): P2MP = repeated
//!   unicast, sequential per destination.
//! * [`xdma`] — the distributed XDMA predecessor (the paper's FPGA
//!   baseline): remote-configured P2P transfers, software P2MP, per-run
//!   descriptor overhead on non-contiguous patterns.
//! * [`mcast`] — source engine for the ESP-style network-layer multicast
//!   baseline (replication in the routers, §II-B).

pub mod idma;
pub mod mcast;
pub mod torrent;
pub mod xdma;

pub use torrent::{ChainTask, ChainDest, Torrent};

/// Completion record every engine produces for a finished task.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: u32,
    /// Cycle the task was submitted to the engine.
    pub submitted_at: u64,
    /// Cycle the engine observed completion (initiator-side, matching the
    /// paper's "from task dispatch to the DSE until the initiator Torrent
    /// receives the finish signal").
    pub finished_at: u64,
    /// Payload bytes moved per destination.
    pub bytes: usize,
    pub n_dests: usize,
}

impl TaskResult {
    pub fn latency(&self) -> u64 {
        self.finished_at - self.submitted_at
    }
}

//! Analytic models: P2MP efficiency (Eq. 1), the 16 nm area model and the
//! activity-based power model of §IV-F, and the Table I feature matrix.

pub mod area;
pub mod experiments;
pub mod power;
pub mod table1;

pub use area::{mcast_router_area_um2, soc_area_breakdown, torrent_area_um2, AreaItem};
pub use power::{chain_energy_pj, cluster_power_mw, PowerRole};

/// Ideal P2P bandwidth (bytes/cycle) — the system AXI bandwidth, Eq. 1.
pub const BW_P2P_IDEAL: f64 = 64.0;

/// P2MP efficiency η (paper Eq. 1): theoretical repeated-P2P latency over
/// measured latency. η ≤ 1 for unicast engines; the ideal P2MP limit is
/// η = N_dst.
pub fn eta_p2mp(n_dst: usize, bytes: usize, latency_cycles: u64) -> f64 {
    assert!(latency_cycles > 0);
    let theo = n_dst as f64 * bytes as f64 / BW_P2P_IDEAL;
    theo / latency_cycles as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_of_ideal_p2p_is_one() {
        // One destination moved exactly at link rate.
        let lat = (64 * 1024) / 64;
        assert!((eta_p2mp(1, 64 * 1024, lat as u64) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eta_upper_bound_is_n_dst() {
        // All 8 destinations served in the time of one ideal P2P copy.
        let lat = (16 * 1024) / 64;
        let eta = eta_p2mp(8, 16 * 1024, lat as u64);
        assert!((eta - 8.0).abs() < 1e-12);
    }

    #[test]
    fn slower_transfers_lower_eta() {
        assert!(eta_p2mp(4, 4096, 1000) < eta_p2mp(4, 4096, 500));
    }
}

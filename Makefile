# Convenience targets; the source of truth is Cargo.toml (Rust) and
# python/compile/aot.py (artifacts).

.PHONY: all build test tier1 artifacts figures clean

all: tier1

build:
	cargo build --release

test:
	cargo test -q

# The repo's tier-1 verification gate (ROADMAP.md).
tier1:
	cargo build --release && cargo test -q

# AOT-lower the JAX/Pallas entry points to HLO text + manifest.txt.
# Requires JAX; the Rust side runs without it (reference backend).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

# Regenerate every paper figure/table via the CLI (EXPERIMENTS.md).
figures:
	cargo run --release -- table1
	cargo run --release -- fig5 --quick
	cargo run --release -- fig6
	cargo run --release -- fig7
	cargo run --release -- fig9
	cargo run --release -- fig11

clean:
	cargo clean
	rm -f artifacts/*.hlo.txt  # manifest.txt is committed; only HLO is generated

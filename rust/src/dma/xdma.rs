//! XDMA baseline: the distributed-DMA predecessor Torrent's frontend
//! builds on (Kong et al., 2025) — ND-affine DSEs at both endpoints,
//! cross-DMA configuration, but **software P2MP**: a multi-destination
//! job runs as N strictly sequential P2P transfers, each paying the full
//! cfg → grant → data → finish round trip and re-reading the source.
//!
//! This is the unicast baseline of the paper's FPGA evaluation (Fig 9):
//! Torrent's speedup over XDMA is Chainwrite amortizing the source read
//! and the per-transfer handshake across the whole destination set.
//!
//! Implementation: XDMA *is* a P2P-only Torrent frontend, so this engine
//! drives the node's [`Torrent`](super::Torrent) with single-destination
//! chain tasks, one at a time. The coupling is fully message-shaped: each leg is
//! relayed through the SoC via [`Engine::take_frontend_legs`] (the
//! frontend drains it the same cycle, so leg timing equals a direct
//! submission), and leg completion is observed by eavesdropping the
//! `TorrentFinish` the frontend receives — no direct borrow of the
//! sibling engine.

use std::collections::VecDeque;

use crate::noc::{Message, NodeId, Packet};

use super::torrent::dse::AffinePattern;
use super::torrent::{ChainDest, ChainTask};
use super::{Engine, EngineCtx, SubmitError, TaskPhase, TaskResult, TaskSpec};

/// High bit tagging XDMA-internal sub-transfers, so leg ids never
/// collide with coordinator-assigned task ids (the coordinator drops
/// drained results carrying this tag instead of treating them as
/// orphaned tasks).
pub const XDMA_SUBTASK_BIT: u32 = 0x8000_0000;

/// A software-P2MP job.
#[derive(Debug, Clone)]
pub struct XdmaTask {
    pub task: u32,
    pub read: AffinePattern,
    pub dests: Vec<(NodeId, AffinePattern)>,
    pub with_data: bool,
}

#[derive(Debug)]
struct Active {
    task: XdmaTask,
    submitted_at: u64,
    next_dest: usize,
    /// Sub-task id currently in flight on the Torrent frontend.
    inflight: Option<u32>,
}

/// Software P2MP driver.
#[derive(Debug)]
pub struct Xdma {
    pub node: NodeId,
    queue: VecDeque<(XdmaTask, u64)>,
    active: Option<Active>,
    pub results: Vec<TaskResult>,
    /// Sub-task id space, tagged with [`XDMA_SUBTASK_BIT`].
    next_subtask: u32,
    /// Legs awaiting relay to the node's Torrent frontend. The SoC
    /// drains this between this engine's tick and the frontend's, so a
    /// leg starts the same cycle it was emitted.
    outbox: Vec<(ChainTask, u64)>,
}

impl Xdma {
    pub fn new(node: NodeId) -> Self {
        Xdma {
            node,
            queue: VecDeque::new(),
            active: None,
            results: Vec::new(),
            next_subtask: 0,
            outbox: Vec::new(),
        }
    }

    pub fn submit(&mut self, task: XdmaTask, now: u64) {
        assert!(!task.dests.is_empty());
        self.queue.push_back((task, now));
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_none() && self.queue.is_empty() && self.outbox.is_empty()
    }

    /// Activity hint (the `sim::Clocked::next_event` contract). An
    /// in-flight P2P leg is tracked by the node's Torrent frontend, whose
    /// own hints/messages drive progress; XDMA itself only needs a tick
    /// to pop its queue or to launch the next leg (both "now" events —
    /// completion of a leg is observed on the same inbox tick that
    /// delivers the Torrent finish, so no wait is ever skipped past).
    pub fn next_event(&self, now: u64) -> Option<u64> {
        match &self.active {
            None => (!self.queue.is_empty()).then_some(now),
            Some(a) => a.inflight.is_none().then_some(now),
        }
    }

    /// Eavesdrop the frontend's finish signalling: a `TorrentFinish` for
    /// the in-flight leg id marks the leg complete. Returns `false`
    /// always — the Torrent frontend owns (and consumes) the message.
    pub fn handle(&mut self, pkt: &Packet, _now: u64) -> bool {
        if let Message::TorrentFinish { task } = pkt.msg {
            if let Some(a) = self.active.as_mut() {
                if a.inflight == Some(task) {
                    a.inflight = None;
                }
            }
        }
        false
    }

    /// Per-cycle logic: pop the queue, retire completed jobs, emit the
    /// next P2P leg into the outbox. Call once per cycle *before* the
    /// node's Torrent tick, then drain [`Xdma::take_frontend_legs`] into
    /// the frontend.
    pub fn tick(&mut self, now: u64) {
        if self.active.is_none() {
            if let Some((task, submitted_at)) = self.queue.pop_front() {
                self.active = Some(Active {
                    submitted_at: submitted_at.max(now),
                    next_dest: 0,
                    inflight: None,
                    task,
                });
            }
        }
        let Some(a) = self.active.as_mut() else { return };
        if a.inflight.is_some() {
            return;
        }
        if a.next_dest == a.task.dests.len() {
            // All legs done.
            self.results.push(TaskResult {
                task: a.task.task,
                submitted_at: a.submitted_at,
                finished_at: now,
                bytes: a.task.read.total_bytes(),
                n_dests: a.task.dests.len(),
            });
            self.active = None;
            return;
        }
        let (node, pattern) = a.task.dests[a.next_dest].clone();
        let sub = XDMA_SUBTASK_BIT | self.next_subtask;
        self.next_subtask += 1;
        self.outbox.push((
            ChainTask {
                task: sub,
                read: a.task.read.clone(),
                dests: vec![ChainDest { node, pattern, vias: Default::default() }],
                with_data: a.task.with_data,
            },
            now,
        ));
        a.inflight = Some(sub);
        a.next_dest += 1;
    }

    /// Drain legs emitted by [`Xdma::tick`] for the Torrent frontend.
    pub fn take_frontend_legs(&mut self) -> Vec<(ChainTask, u64)> {
        std::mem::take(&mut self.outbox)
    }
}

/// Uniform dispatch surface; delegates to the inherent methods above.
impl Engine for Xdma {
    fn label(&self) -> &'static str {
        "xdma"
    }

    fn submit(&mut self, spec: TaskSpec, now: u64) -> Result<(), SubmitError> {
        spec.validate()?;
        let TaskSpec { task, read, dests, with_data, .. } = spec;
        Xdma::submit(self, XdmaTask { task, read, dests, with_data }, now);
        Ok(())
    }

    fn handle(&mut self, pkt: &Packet, _ctx: &mut EngineCtx<'_>, now: u64) -> bool {
        Xdma::handle(self, pkt, now)
    }

    fn tick(&mut self, ctx: &mut EngineCtx<'_>) {
        Xdma::tick(self, ctx.net.cycle())
    }

    fn next_event(&self, now: u64) -> Option<u64> {
        Xdma::next_event(self, now)
    }

    fn is_idle(&self) -> bool {
        Xdma::is_idle(self)
    }

    fn drain_results(&mut self) -> Vec<TaskResult> {
        std::mem::take(&mut self.results)
    }

    fn peek_result(&self, task: u32) -> Option<&TaskResult> {
        self.results.iter().find(|r| r.task == task)
    }

    fn phase_of(&self, task: u32, _now: u64) -> Option<TaskPhase> {
        if self.queue.iter().any(|(t, _)| t.task == task) {
            return Some(TaskPhase::Configuring);
        }
        self.active
            .as_ref()
            .filter(|a| a.task.task == task)
            .map(|_| TaskPhase::Streaming)
    }

    fn take_frontend_legs(&mut self) -> Vec<(ChainTask, u64)> {
        Xdma::take_frontend_legs(self)
    }
}

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (and hypothesis sweeps)
compare each Pallas kernel's output against the function of the same name
here with ``assert_allclose``. Keep these boring and obviously correct —
no tiling, no Pallas, just jnp.
"""

import jax.numpy as jnp


def matmul(a, b, out_dtype=None):
    """Plain matrix multiply with explicit accumulation dtype.

    For int8 inputs the accelerator accumulates in int32 (1024 8-bit MACs);
    for floats we accumulate in f32.
    """
    if a.dtype == jnp.int8:
        out_dtype = out_dtype or jnp.int32
        return jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32)).astype(out_dtype)
    out_dtype = out_dtype or jnp.float32
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)


def softmax(x, axis=-1):
    """Numerically stable row softmax."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def to_blocked(x, tm, tn):
    """Logical (M, N) matrix -> physical blocked layout (M/tm, N/tn, tm, tn).

    This is the paper's "MNMxNy" layout family (Table II): the matrix is
    partitioned into tm x tn tiles, tiles stored row-major (M outer, N
    inner), elements row-major within a tile. MNM16N8 == to_blocked(x,16,8).
    """
    m, n = x.shape
    assert m % tm == 0 and n % tn == 0, (x.shape, tm, tn)
    return x.reshape(m // tm, tm, n // tn, tn).transpose(0, 2, 1, 3)


def from_blocked(xb):
    """Inverse of :func:`to_blocked`: (Mt, Nt, tm, tn) -> (Mt*tm, Nt*tn)."""
    mt, nt, tm, tn = xb.shape
    return xb.transpose(0, 2, 1, 3).reshape(mt * tm, nt * tn)


def relayout(xb, tm_out, tn_out):
    """Re-tile a blocked matrix into a different tile geometry.

    E.g. MNM16N8 -> MNM8N8 (prefill output feeding the next GeMM) or
    MNM16N8 -> MNM64N16 (decode input layout).
    """
    return to_blocked(from_blocked(xb), tm_out, tn_out)


def attention_prefill(q, k, v, scale=None):
    """Single-head self-attention, prefill: softmax(Q.K^T * scale) . V."""
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = matmul(q, k.T) * scale
    p = softmax(s, axis=-1)
    return matmul(p, v)


def attention_decode(q, k_cache, v_cache, scale=None):
    """Single-head decode step: q is (1, d), caches are (T, d)."""
    return attention_prefill(q, k_cache, v_cache, scale)


def kv_recovery(c_kv, w_uk, w_uv):
    """DeepSeek-V3 MLA KV recovery: up-project the compressed KV cache.

    c_kv: (T, d_c) compressed latent; w_uk/w_uv: (d_c, d) up-projections.
    Returns (K, V), each (T, d). This is workload P3/D3 of Table II.
    """
    return matmul(c_kv, w_uk), matmul(c_kv, w_uv)

//! SoC configuration, with the paper's three evaluation presets and a
//! minimal TOML-subset loader so launch scripts can describe custom
//! systems without recompiling.

use crate::mem::addr_map::DEFAULT_WINDOW;
use crate::noc::{Topo, TopologyKind};
use crate::sim::FaultPlan;

/// Static description of a simulated SoC.
#[derive(Debug, Clone, PartialEq)]
pub struct SocConfig {
    /// Grid columns (x extent).
    pub cols: usize,
    /// Grid rows (y extent).
    pub rows: usize,
    /// NoC fabric over the `cols` × `rows` node grid. Default mesh (the
    /// paper's FlooNoC systems); a ring threads all `cols * rows` nodes.
    pub topology: TopologyKind,
    /// Scratchpad bytes per node.
    pub spm_bytes: usize,
    /// Address window per node (≥ spm_bytes, power of two).
    pub window: u64,
    /// Human label for reports.
    pub name: String,
    /// Fault-injection scenario (empty by default — a healthy SoC).
    pub faults: FaultPlan,
    /// Simulation worker threads. `1` (default) selects the sequential
    /// stepper; `> 1` selects [`crate::sim::StepMode::Parallel`] — the
    /// sharded kernel with the deterministic barrier merge, bit-identical
    /// to the sequential modes at any thread count.
    pub threads: usize,
}

impl SocConfig {
    /// §IV-A evaluation SoC: 4×5 mesh, 1 MB per cluster (Occamy-derived,
    /// FlooNoC, 64 B/CC).
    pub fn eval_4x5() -> Self {
        SocConfig {
            cols: 4,
            rows: 5,
            topology: TopologyKind::Mesh,
            spm_bytes: 1 << 20,
            window: DEFAULT_WINDOW,
            name: "eval-4x5".into(),
            faults: FaultPlan::default(),
            threads: 1,
        }
    }

    /// §IV-C hop-study mesh: 8×8, memory irrelevant (analytic hops) but
    /// kept small so full-system runs stay cheap.
    pub fn mesh_8x8() -> Self {
        SocConfig {
            cols: 8,
            rows: 8,
            topology: TopologyKind::Mesh,
            spm_bytes: 256 << 10,
            window: DEFAULT_WINDOW,
            name: "mesh-8x8".into(),
            faults: FaultPlan::default(),
            threads: 1,
        }
    }

    /// §IV-E FPGA prototype: 3×3 clusters on the VPK180. Scratchpads are
    /// sized 4 MB so the largest Table II matrix (D3: 4096×512 int8 =
    /// 2 MB) fits untiled; the FPGA tiles it instead — same traffic.
    pub fn fpga_3x3() -> Self {
        SocConfig {
            cols: 3,
            rows: 3,
            topology: TopologyKind::Mesh,
            spm_bytes: 4 << 20,
            window: 4 << 20,
            name: "fpga-3x3".into(),
            faults: FaultPlan::default(),
            threads: 1,
        }
    }

    /// §IV-F synthesis SoC: 4 clusters, 256 KB each.
    pub fn synth_2x2() -> Self {
        SocConfig {
            cols: 2,
            rows: 2,
            topology: TopologyKind::Mesh,
            spm_bytes: 256 << 10,
            window: DEFAULT_WINDOW,
            name: "synth-2x2".into(),
            faults: FaultPlan::default(),
            threads: 1,
        }
    }

    /// Custom geometry with default windowing.
    pub fn custom(cols: usize, rows: usize, spm_bytes: usize) -> Self {
        assert!(spm_bytes as u64 <= DEFAULT_WINDOW);
        SocConfig {
            cols,
            rows,
            topology: TopologyKind::Mesh,
            spm_bytes,
            window: DEFAULT_WINDOW,
            name: format!("custom-{cols}x{rows}"),
            faults: FaultPlan::default(),
            threads: 1,
        }
    }

    /// Swap the NoC fabric while keeping the node grid and memory map
    /// (`SocConfig::eval_4x5().with_topology(TopologyKind::Torus)`).
    pub fn with_topology(mut self, topology: TopologyKind) -> Self {
        self.topology = topology;
        self
    }

    /// Set the worker-thread count for the sharded parallel stepper
    /// (`SocConfig::eval_4x5().with_threads(4)`). `1` keeps the
    /// sequential kernel.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Attach a fault-injection scenario
    /// (`SocConfig::eval_4x5().with_faults(FaultPlan::parse("router:5@300")?)`).
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// The concrete fabric this config describes.
    pub fn build_topo(&self) -> Topo {
        Topo::build(self.topology, self.cols, self.rows)
    }

    /// Parse a TOML-subset config:
    ///
    /// ```toml
    /// name = "my-soc"
    /// cols = 4
    /// rows = 5
    /// topology = "torus"   # mesh (default) | torus | ring
    /// spm_kib = 1024
    /// threads = 4          # parallel stepper workers (default 1)
    /// ```
    ///
    /// Supports `key = value` lines, `#` comments, quoted strings and
    /// integers — the subset the launcher needs (serde/toml are not
    /// vendored in this image; see DESIGN.md §3).
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let mut cfg = SocConfig::eval_4x5();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || line.starts_with('[') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
            let (k, v) = (k.trim(), v.trim());
            let int = |v: &str| -> Result<usize, String> {
                v.parse().map_err(|e| format!("line {}: bad integer {v:?}: {e}", ln + 1))
            };
            match k {
                "name" => cfg.name = v.trim_matches('"').to_string(),
                "cols" => cfg.cols = int(v)?,
                "rows" => cfg.rows = int(v)?,
                "topology" => {
                    let t = v.trim_matches('"');
                    cfg.topology = TopologyKind::parse(t).ok_or_else(|| {
                        format!("line {}: unknown topology {t:?} (mesh|torus|ring)", ln + 1)
                    })?;
                }
                "spm_kib" => cfg.spm_bytes = int(v)? << 10,
                "threads" => cfg.threads = int(v)?.max(1),
                "window_mib" => cfg.window = (int(v)? as u64) << 20,
                "faults" => {
                    cfg.faults = FaultPlan::parse(v.trim_matches('"'))
                        .map_err(|e| format!("line {}: {e}", ln + 1))?;
                }
                other => return Err(format!("line {}: unknown key {other:?}", ln + 1)),
            }
        }
        if cfg.spm_bytes as u64 > cfg.window {
            return Err("spm does not fit the address window".into());
        }
        // The loader knows the final geometry, so structurally invalid
        // fault specs (out-of-fabric nodes, self-links) fail here with
        // the typed message instead of surviving to `Soc::new`.
        cfg.faults.validate(cfg.n_nodes()).map_err(|e| e.to_string())?;
        Ok(cfg)
    }

    pub fn n_nodes(&self) -> usize {
        self.cols * self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        assert_eq!(SocConfig::eval_4x5().n_nodes(), 20);
        assert_eq!(SocConfig::fpga_3x3().n_nodes(), 9);
        assert_eq!(SocConfig::synth_2x2().n_nodes(), 4);
        assert_eq!(SocConfig::synth_2x2().spm_bytes, 256 << 10);
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = SocConfig::from_toml(
            r#"
            # my test soc
            name = "t"
            cols = 6
            rows = 2
            topology = "torus"
            spm_kib = 512
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "t");
        assert_eq!(cfg.cols, 6);
        assert_eq!(cfg.rows, 2);
        assert_eq!(cfg.topology, TopologyKind::Torus);
        assert_eq!(cfg.spm_bytes, 512 << 10);
    }

    #[test]
    fn toml_rejects_unknown_keys_and_bad_ints() {
        assert!(SocConfig::from_toml("bogus = 1").is_err());
        assert!(SocConfig::from_toml("cols = banana").is_err());
        assert!(SocConfig::from_toml("colsbanana").is_err());
        assert!(SocConfig::from_toml("topology = \"hypercube\"").is_err());
    }

    #[test]
    fn topology_defaults_to_mesh_and_builds_each_fabric() {
        use crate::noc::{NodeId, Topo, Topology};
        assert_eq!(SocConfig::eval_4x5().topology, TopologyKind::Mesh);
        let torus = SocConfig::custom(4, 4, 64 << 10).with_topology(TopologyKind::Torus);
        assert!(matches!(torus.build_topo(), Topo::Torus(_)));
        // A ring threads the full grid: same node count as the mesh.
        let ring = SocConfig::custom(4, 4, 64 << 10).with_topology(TopologyKind::Ring);
        let topo = ring.build_topo();
        assert_eq!(topo.n_nodes(), 16);
        assert_eq!(topo.distance(NodeId(0), NodeId(15)), 1);
    }

    #[test]
    fn threads_default_and_override() {
        use crate::sim::StepMode;
        assert_eq!(SocConfig::eval_4x5().threads, 1);
        let cfg = SocConfig::from_toml("threads = 4").unwrap();
        assert_eq!(cfg.threads, 4);
        // threads = 0 is clamped, not an error (matches with_threads).
        assert_eq!(SocConfig::from_toml("threads = 0").unwrap().threads, 1);
        assert_eq!(SocConfig::custom(2, 2, 1024).with_threads(0).threads, 1);
        // The builder maps threads > 1 to the parallel step mode, and a
        // single thread keeps the default sequential stepper.
        let par = crate::soc::Soc::new(SocConfig::custom(2, 2, 1024).with_threads(3));
        assert_eq!(par.step_mode, StepMode::Parallel { threads: 3 });
        let seq = crate::soc::Soc::new(SocConfig::custom(2, 2, 1024));
        assert_eq!(seq.step_mode, StepMode::default());
    }

    #[test]
    fn toml_rejects_oversized_spm() {
        assert!(SocConfig::from_toml("spm_kib = 4096\nwindow_mib = 1").is_err());
    }

    #[test]
    fn toml_parses_fault_spec() {
        let cfg = SocConfig::from_toml(
            "faults = \"router:5@300;timeout:2000;norepair\"",
        )
        .unwrap();
        assert_eq!(cfg.faults.faults.len(), 1);
        assert_eq!(cfg.faults.detect_timeout, 2000);
        assert!(!cfg.faults.repair);
        assert!(SocConfig::from_toml("faults = \"router:x@300\"").is_err());
        // Default presets ship a disarmed plan — healthy by construction.
        assert!(SocConfig::eval_4x5().faults.is_empty());
    }

    #[test]
    fn toml_validates_fault_spec_against_geometry() {
        // eval_4x5 default geometry is 20 nodes; node 25 is outside it.
        let err = SocConfig::from_toml("faults = \"router:25@300\"").unwrap_err();
        assert!(err.contains("outside the 20-node fabric"), "{err}");
        // Self-links are structural nonsense regardless of geometry.
        let err = SocConfig::from_toml("faults = \"link:3-3@10\"").unwrap_err();
        assert!(err.contains("self-link"), "{err}");
        // A spec that fits the declared grid passes.
        assert!(SocConfig::from_toml("cols = 6\nrows = 5\nfaults = \"router:25@300\"").is_ok());
    }
}

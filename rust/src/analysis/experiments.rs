//! Experiment drivers — one per paper figure/table. The benches and the
//! CLI both call these, so `cargo bench` output and `torrent fig5 ...`
//! print identical rows.

use crate::coordinator::{Coordinator, EngineKind};
use crate::dma::torrent::dse::AffinePattern;
use crate::noc::{Mesh, NodeId, Ring, Topo, Topology, TopologyKind, Torus};
use crate::sched::{self, Strategy};
use crate::soc::SocConfig;
use crate::util::stats::linregress;
use crate::util::table::{fnum, Table};
use crate::workloads::{self, TABLE2};

/// One measured η_P2MP point.
#[derive(Debug, Clone)]
pub struct EtaPoint {
    pub mechanism: &'static str,
    pub bytes: usize,
    pub n_dst: usize,
    pub latency: u64,
    pub eta: f64,
}

/// Fig 5: η_P2MP for iDMA / ESP-multicast / Torrent over the
/// 1–128 KB × 2–16-destination grid on the 4×5 evaluation SoC.
/// `quick` subsamples the grid (sizes {4,64} KB × dests {2,8,16}).
pub fn fig5(quick: bool) -> (Vec<EtaPoint>, Vec<Table>) {
    let grid = if quick {
        let mut g = vec![];
        for s in [4 * 1024, 64 * 1024] {
            for d in [2usize, 8, 16] {
                g.push((s, d));
            }
        }
        g
    } else {
        workloads::synthetic::fig5_grid()
    };
    let mechanisms: [(&'static str, EngineKind); 3] = [
        ("iDMA (unicast)", EngineKind::Idma),
        ("ESP (multicast)", EngineKind::Mcast),
        ("Torrent (chainwrite)", EngineKind::Torrent(Strategy::Greedy)),
    ];
    let mut points = Vec::new();
    let mut tables = Vec::new();
    for (label, engine) in mechanisms {
        let dest_counts: Vec<usize> = {
            let mut d: Vec<usize> = grid.iter().map(|&(_, d)| d).collect();
            d.sort_unstable();
            d.dedup();
            d
        };
        let mut t = Table::new(format!("Fig 5 η_P2MP — {label}")).header(
            std::iter::once("KB".to_string())
                .chain(dest_counts.iter().map(|d| format!("N={d}"))),
        );
        let sizes: Vec<usize> = {
            let mut s: Vec<usize> = grid.iter().map(|&(s, _)| s).collect();
            s.sort_unstable();
            s.dedup();
            s
        };
        for &bytes in &sizes {
            let mut row = vec![format!("{}", bytes / 1024)];
            for &n_dst in &dest_counts {
                if !grid.contains(&(bytes, n_dst)) {
                    row.push("-".into());
                    continue;
                }
                let mut c = Coordinator::new(SocConfig::eval_4x5());
                let dests: Vec<NodeId> = (1..=n_dst).map(NodeId).collect();
                let task =
                    c.submit_simple(NodeId(0), &dests, bytes, engine, false).expect("valid");
                c.run_to_completion(60_000_000);
                let rec = c.record(task).unwrap();
                let res = rec.result.as_ref().expect("task completed");
                let eta = rec.eta().unwrap();
                points.push(EtaPoint {
                    mechanism: label,
                    bytes,
                    n_dst,
                    latency: res.latency(),
                    eta,
                });
                row.push(fnum(eta, 2));
            }
            t.row(row);
        }
        tables.push(t);
    }
    (points, tables)
}

/// Fig 6: average hops per destination on an 8×8 mesh, 128 random sets
/// per destination-count group, five mechanisms.
pub fn fig6(seed: u64, trials: usize) -> Table {
    let mesh = Mesh::new(8, 8);
    let src = NodeId(0);
    let mut t = Table::new("Fig 6 — average hops per destination (8x8 mesh)").header([
        "N_dst",
        "unicast",
        "multicast",
        "chain/naive",
        "chain/greedy",
        "chain/TSP",
    ]);
    for n_dst in workloads::synthetic::fig6_groups() {
        let sets = workloads::random_dest_sets(&mesh, src, n_dst, trials, seed + n_dst as u64);
        let mut acc = [0.0f64; 5];
        for dests in &sets {
            let uni = sched::unicast_hops(&mesh, src, dests) as f64;
            let mc = crate::noc::multicast::mcast_tree_hops(&mesh, src, dests) as f64;
            let naive = sched::chain_hops(&mesh, src, &sched::naive_order(dests)) as f64;
            let greedy =
                sched::chain_hops(&mesh, src, &sched::greedy_order(&mesh, src, dests)) as f64;
            let tsp = sched::chain_hops(&mesh, src, &sched::tsp_order(&mesh, src, dests)) as f64;
            for (a, v) in acc.iter_mut().zip([uni, mc, naive, greedy, tsp]) {
                *a += v / n_dst as f64 / sets.len() as f64;
            }
        }
        t.row(
            std::iter::once(n_dst.to_string())
                .chain(acc.iter().map(|v| fnum(*v, 3)))
                .collect::<Vec<_>>(),
        );
    }
    t
}

/// Topology sweep: the Fig-6 hop metric re-run across the three fabrics
/// (8×8 mesh, 8×8 torus, 64-ring — equal node counts, so every fabric
/// sees the *same* seeded destination sets). Quantifies how much of the
/// greedy-vs-TSP gap §IV-C attributes to the chain order survives a
/// wraparound fabric, and pins torus ≤ mesh per strategy.
pub fn topology_sweep(seed: u64, trials: usize) -> Table {
    let fabrics: [Topo; 3] = [
        Topo::Mesh(Mesh::new(8, 8)),
        Topo::Torus(Torus::new(8, 8)),
        Topo::Ring(Ring::new(64)),
    ];
    let src = NodeId(0);
    let mut t = Table::new("Topology sweep — average hops per destination (64 nodes)")
        .header(["fabric", "N_dst", "unicast", "chain/naive", "chain/greedy", "chain/TSP"]);
    for topo in fabrics {
        for n_dst in [4usize, 8, 16, 32] {
            let sets = workloads::random_dest_sets(&topo, src, n_dst, trials, seed + n_dst as u64);
            let mut acc = [0.0f64; 4];
            for dests in &sets {
                let uni = sched::unicast_hops(&topo, src, dests) as f64;
                let naive = sched::chain_hops(&topo, src, &sched::naive_order(dests)) as f64;
                let greedy =
                    sched::chain_hops(&topo, src, &sched::greedy_order(&topo, src, dests)) as f64;
                let tsp =
                    sched::chain_hops(&topo, src, &sched::tsp_order(&topo, src, dests)) as f64;
                for (a, v) in acc.iter_mut().zip([uni, naive, greedy, tsp]) {
                    *a += v / n_dst as f64 / sets.len() as f64;
                }
            }
            t.row(
                std::iter::once(topo.name().to_string())
                    .chain(std::iter::once(n_dst.to_string()))
                    .chain(acc.iter().map(|v| fnum(*v, 3)))
                    .collect::<Vec<_>>(),
            );
        }
    }
    t
}

/// Fault sweep: availability and tail latency of chain repair vs. the
/// fail-stop baseline on degraded fabrics (ROADMAP "chain repair").
///
/// For each fabric (4×4 mesh, 4×4 torus) × fault rate (1–3 seeded
/// router-kill/follower-drop activations, never the initiator) the same
/// `trials` seeded workloads — 4 KB Chainwrite with real bytes to 4
/// random destinations — run twice: once with repair enabled, once
/// fail-stop (`norepair`). Availability counts destinations whose
/// scratchpads hold byte-exact payloads when the run ends (a fail-stop
/// run still credits destinations fully written before the fault hit);
/// p99 is over completed-task latencies, `-` when nothing completed.
pub fn fault_sweep(seed: u64, trials: usize) -> (Vec<FaultSweepRow>, Table) {
    use crate::sim::{Fault, FaultKind, FaultPlan};
    use crate::util::stream;

    let bytes = 4 * 1024;
    let n_dst = 4;
    let mut rows = Vec::new();
    let mut t = Table::new("Fault sweep — chain repair vs fail-stop (4 KB, 4 dests)").header([
        "fabric", "faults", "mode", "avail%", "p99[CC]", "done", "repaired", "failed",
    ]);
    for topology in [TopologyKind::Mesh, TopologyKind::Torus] {
        for rate in 1..=3usize {
            for repair in [true, false] {
                let mut served = 0usize;
                let mut wanted = 0usize;
                let mut lats: Vec<u64> = Vec::new();
                let (mut done, mut repaired, mut failed) = (0usize, 0usize, 0usize);
                for trial in 0..trials {
                    // One seed stream per (fabric, rate, trial): both
                    // repair modes replay the identical workload + fault
                    // schedule, so the comparison is paired.
                    let mut rng = crate::util::rng(
                        seed,
                        stream::FAULTS
                            + (rate as u64)
                            + ((topology as u64) << 8)
                            + ((trial as u64) << 16),
                    );
                    let cfg = SocConfig::custom(4, 4, 64 * 1024).with_topology(topology);
                    let dests: Vec<NodeId> = {
                        let topo = cfg.build_topo();
                        workloads::random_dest_sets(&topo, NodeId(0), n_dst, 1, rng.next_u64())
                            .remove(0)
                    };
                    let mut plan = FaultPlan {
                        faults: Vec::new(),
                        detect_timeout: 2_000,
                        repair,
                        resume: false,
                        reroute: false,
                    };
                    for _ in 0..rate {
                        // Never the initiator: a dead source has nothing
                        // to repair from and both modes trivially score 0.
                        let node = rng.range(1, 15) as usize;
                        let at_cycle = rng.range(50, 1_500);
                        let kind = if rng.next_u64() % 2 == 0 {
                            FaultKind::RouterKill { node }
                        } else {
                            FaultKind::FollowerDrop { node }
                        };
                        plan.faults.push(Fault::new(at_cycle, kind));
                    }
                    let mut c = Coordinator::new(cfg.with_faults(plan));
                    let pattern: Vec<u8> =
                        (0..bytes).map(|i| (i as u64 * 131 + seed) as u8).collect();
                    let base = c.soc.map.base_of(NodeId(0));
                    c.soc.nodes[0].mem.write(base, &pattern);
                    let task = c
                        .submit_simple(
                            NodeId(0),
                            &dests,
                            bytes,
                            EngineKind::Torrent(Strategy::Greedy),
                            true,
                        )
                        .expect("valid sweep request");
                    c.run_to_completion(2_000_000);
                    let half = c.soc.cfg.spm_bytes as u64 / 2;
                    wanted += dests.len();
                    for &d in &dests {
                        let addr = c.soc.map.base_of(d) + half;
                        if c.soc.nodes[d.0].mem.read(addr, bytes) == pattern {
                            served += 1;
                        }
                    }
                    match c.record(task).unwrap().outcome {
                        None => done += 1,
                        Some(crate::coordinator::TaskOutcome::Repaired { .. }) => repaired += 1,
                        Some(_) => failed += 1,
                    }
                    if let Some(lat) = c.latency_of(task) {
                        lats.push(lat);
                    }
                }
                lats.sort_unstable();
                let p99 = lats.last().map(|_| lats[(lats.len() * 99 + 99) / 100 - 1]);
                let row = FaultSweepRow {
                    fabric: topology.label(),
                    rate,
                    repair,
                    availability: 100.0 * served as f64 / wanted as f64,
                    p99,
                    done,
                    repaired,
                    failed,
                };
                t.row([
                    row.fabric.to_string(),
                    rate.to_string(),
                    if repair { "repair" } else { "fail-stop" }.to_string(),
                    fnum(row.availability, 1),
                    p99.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
                    done.to_string(),
                    repaired.to_string(),
                    failed.to_string(),
                ]);
                rows.push(row);
            }
        }
    }
    (rows, t)
}

/// One `fault_sweep` cell: a (fabric, fault-rate, policy) aggregate.
#[derive(Debug, Clone)]
pub struct FaultSweepRow {
    pub fabric: &'static str,
    pub rate: usize,
    /// true = repair enabled, false = fail-stop baseline.
    pub repair: bool,
    /// Percentage of requested destinations holding byte-exact payloads.
    pub availability: f64,
    /// p99 completion latency over completed tasks (`None`: none completed).
    pub p99: Option<u64>,
    pub done: usize,
    pub repaired: usize,
    pub failed: usize,
}

/// Fig 7: 64 KB Chainwrite configuration overhead, 1–8 destinations on
/// the 4×5 SoC. Returns `(table, slope, intercept, r²)` — the paper
/// reports a linear trend of ≈82 CC per destination.
pub fn fig7() -> (Table, f64, f64, f64) {
    let bytes = 64 * 1024;
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut t = Table::new("Fig 7 — Chainwrite latency, 64 KB, 1-8 destinations")
        .header(["N_dst", "latency[CC]", "Δ vs N-1"]);
    let mut prev = None;
    for n in 1..=8usize {
        let mut c = Coordinator::new(SocConfig::eval_4x5());
        let dests: Vec<NodeId> = (1..=n).map(NodeId).collect();
        let task = c
            .submit_simple(NodeId(0), &dests, bytes, EngineKind::Torrent(Strategy::Greedy), false)
            .expect("valid");
        c.run_to_completion(10_000_000);
        let lat = c.latency_of(task).expect("completed");
        xs.push(n as f64);
        ys.push(lat as f64);
        let delta = prev.map(|p: u64| format!("{}", lat as i64 - p as i64)).unwrap_or("-".into());
        t.row([n.to_string(), lat.to_string(), delta]);
        prev = Some(lat);
    }
    let (slope, intercept, r2) = linregress(&xs, &ys);
    (t, slope, intercept, r2)
}

/// One Fig 9 measurement.
#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub id: &'static str,
    pub n_dst: usize,
    pub xdma_cycles: u64,
    pub torrent_cycles: u64,
    pub speedup: f64,
}

/// Fig 9: Table II DeepSeek-V3 workloads on the 3×3 FPGA SoC, Torrent
/// Chainwrite vs XDMA software P2MP.
pub fn fig9() -> (Vec<Fig9Row>, Table) {
    let mut rows = Vec::new();
    let mut t = Table::new("Fig 9 — DeepSeek-V3 attention data movement (3x3 SoC)").header([
        "workload", "KB", "layout", "N_dst", "XDMA[CC]", "Torrent[CC]", "speedup",
    ]);
    for w in TABLE2 {
        // Multicast workloads fan out to all 8 other clusters; unicast
        // (D1/D2) move to a single neighbour accelerator.
        let n_dst = if w.multicast { 8 } else { 1 };
        let run = |engine: EngineKind| -> u64 {
            let mut c = Coordinator::new(SocConfig::fpga_3x3());
            let src = NodeId(0);
            let read = w.read_pattern(c.soc.map.base_of(src));
            let dests: Vec<(NodeId, AffinePattern)> = (1..=n_dst)
                .map(|n| {
                    let node = NodeId(n);
                    (node, w.write_pattern(c.soc.map.base_of(node)))
                })
                .collect();
            let task = c
                .submit(
                    crate::coordinator::P2mpRequest::to_patterns(dests)
                        .src(src)
                        .read(read)
                        .engine(engine),
                )
                .expect("valid fig9 request");
            c.run_to_completion(200_000_000);
            c.latency_of(task).expect("fig9 task completed")
        };
        let xdma = run(EngineKind::Xdma);
        let torrent = run(EngineKind::Torrent(Strategy::Greedy));
        let speedup = xdma as f64 / torrent as f64;
        t.row([
            w.id.to_string(),
            (w.bytes() / 1024).to_string(),
            format!("{}->{}", w.in_layout.name(), w.out_layout.name()),
            n_dst.to_string(),
            xdma.to_string(),
            torrent.to_string(),
            format!("{}x", fnum(speedup, 2)),
        ]);
        rows.push(Fig9Row { id: w.id, n_dst, xdma_cycles: xdma, torrent_cycles: torrent, speedup });
    }
    (rows, t)
}

/// ISSUE 8 serving sweep: open-loop offered-load sweep past saturation,
/// one leg per (topology × scheduler × thread-count). Every load point
/// runs under FullTick, EventDriven *and* Parallel{threads}, and the
/// per-request dispositions and occupancy time-series are asserted
/// bit-identical across the three — the cross-mode acceptance criterion
/// is re-checked on every sweep, not just in the test suite. The
/// EventDriven run supplies the reported row.
///
/// `quick` runs one leg (mesh/greedy/2 threads) over three rates;
/// the full sweep crosses {mesh, torus} × {greedy, tsp} × {1, 2}
/// threads over five rates up to well past the ~8-task service
/// capacity of the 4×4 fabric.
pub fn serve_sweep(seed: u64, quick: bool) -> (Vec<crate::serve::ServeSweepRow>, Table) {
    use crate::serve::{self, AdmissionPolicy, ArrivalKind, ServeConfig, ServeSweepRow};
    use crate::sim::StepMode;

    let legs: Vec<(TopologyKind, Strategy, usize)> = if quick {
        vec![(TopologyKind::Mesh, Strategy::Greedy, 2)]
    } else {
        let mut l = Vec::new();
        for topo in [TopologyKind::Mesh, TopologyKind::Torus] {
            for strat in [Strategy::Greedy, Strategy::Tsp, Strategy::LoadAware] {
                for threads in [1usize, 2] {
                    l.push((topo, strat, threads));
                }
            }
        }
        l
    };
    let rates: Vec<u64> = if quick { vec![1, 4, 12] } else { vec![1, 2, 4, 8, 16] };
    let horizon = if quick { 6_000 } else { 16_000 };

    let mut rows = Vec::new();
    let mut t = Table::new("Serve sweep — open-loop tail latency vs offered load").header([
        "fabric", "sched", "thr", "rate/kcc", "offered", "admitted", "rejected", "completed",
        "p50", "p99", "p999", "util", "pend_pk",
    ]);
    for (topo, strat, threads) in legs {
        let sched_label = sched_label(strat);
        for &rate in &rates {
            let cfg = ServeConfig {
                seed,
                horizon,
                drain: 60_000,
                arrival: ArrivalKind::Poisson { rate_per_kcycle: rate },
                policy: AdmissionPolicy::Queue,
                strategy: strat,
                ..ServeConfig::default()
            };
            let soc = SocConfig::custom(4, 4, 64 * 1024).with_topology(topo);
            let reference = serve::run(cfg.clone(), soc.clone(), StepMode::EventDriven);
            for mode in [StepMode::FullTick, StepMode::Parallel { threads }] {
                let other = serve::run(cfg.clone(), soc.clone(), mode);
                assert_eq!(
                    reference.dispositions,
                    other.dispositions,
                    "per-request dispositions diverged across step modes \
                     ({} {} t={} rate={} vs {:?})",
                    topo.label(),
                    sched_label,
                    threads,
                    rate,
                    mode
                );
                assert_eq!(
                    reference.samples,
                    other.samples,
                    "occupancy samples diverged across step modes \
                     ({} {} t={} rate={} vs {:?})",
                    topo.label(),
                    sched_label,
                    threads,
                    rate,
                    mode
                );
            }
            let r = reference;
            t.row([
                topo.label().to_string(),
                sched_label.to_string(),
                threads.to_string(),
                rate.to_string(),
                r.offered.to_string(),
                r.admitted.to_string(),
                r.rejected().to_string(),
                r.completed.to_string(),
                r.p50().to_string(),
                r.p99().to_string(),
                r.p999().to_string(),
                fnum(r.util, 3),
                r.pending_peak.to_string(),
            ]);
            rows.push(ServeSweepRow {
                fabric: topo.label(),
                sched: sched_label,
                threads,
                rate_per_kcycle: rate,
                offered: r.offered,
                admitted: r.admitted,
                rejected: r.rejected(),
                completed: r.completed,
                p50: r.p50(),
                p99: r.p99(),
                p999: r.p999(),
                util: r.util,
                pending_peak: r.pending_peak,
            });
        }
    }
    (rows, t)
}

/// CLI/report label for a chain-scheduling strategy.
pub fn sched_label(strategy: Strategy) -> &'static str {
    match strategy {
        Strategy::Naive => "naive",
        Strategy::Greedy => "greedy",
        Strategy::Tsp => "tsp",
        Strategy::LoadAware => "load_aware",
    }
}

/// One `contention_sweep` cell: a (strategy, background-level) aggregate.
#[derive(Debug, Clone)]
pub struct ContentionRow {
    pub strategy: &'static str,
    /// Number of background unicast flows hammering the hot corridor.
    pub background: usize,
    pub trials: usize,
    pub p50: u64,
    pub p99: u64,
    /// Trials whose dispatch took the k-way partition path.
    pub splits: usize,
}

/// ISSUE 10 contention sweep: chain scheduling under seeded background
/// traffic at rising load, naive/greedy/TSP/load-aware side by side on
/// a 4×4 mesh.
///
/// Per trial, long-lived unicast iDMA streams are injected along the
/// eastward links of row 0 — the corridor every XY route out of the
/// corner source crosses first — then, after two full EWMA windows of
/// warm-up, an 8 KB Chainwrite to `{3, 12, 15}` dispatches with the
/// strategy under test. Destination 3 sits behind the hot corridor;
/// 12 and 15 are reachable around it, so a load-aware order can serve
/// the whole set over cold links while the static strategies stream
/// their first data leg straight through the contention.
///
/// In-tree guarantees, re-checked on every sweep, not just in tests:
///   * every strategy delivers byte-exact payloads at every load point;
///   * each cell is bit-identical across FullTick, EventDriven and
///     Parallel{2} stepping (latency, chain order, partition width);
///   * at the most congested point, load-aware p99 ≤ greedy p99.
pub fn contention_sweep(seed: u64, quick: bool) -> (Vec<ContentionRow>, Table) {
    use crate::dma::idma::IdmaTask;
    use crate::noc::LOAD_WINDOW;
    use crate::sim::StepMode;
    use crate::util::stream;

    let levels: Vec<usize> = if quick { vec![0, 2] } else { vec![0, 1, 2] };
    let trials = if quick { 2 } else { 4 };
    let fg_bytes = 8 * 1024;

    // One seeded cell run → (latency, chain order, partition width).
    // The background schedule is keyed by (level, trial) only, so every
    // strategy replays the identical contention — cells are paired.
    let run_cell = |strategy: Strategy,
                    level: usize,
                    trial: usize,
                    mode: StepMode|
     -> (u64, Vec<NodeId>, usize) {
        let mut rng = crate::util::rng(
            seed,
            stream::CONTENTION + ((level as u64) << 16) + trial as u64,
        );
        let mut c = Coordinator::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
        let half = c.soc.cfg.spm_bytes as u64 / 2;
        // Arm the load telemetry before any traffic flows: the first
        // load_view() call opens the counter window the dispatch-time
        // snapshot folds.
        let _ = c.soc.net.load_view();
        let payload: Vec<u8> = (0..fg_bytes).map(|i| (i as u64 * 131 + seed) as u8).collect();
        let base = c.soc.map.base_of(NodeId(0));
        c.soc.nodes[0].mem.write(base, &payload);
        let flows: Vec<(usize, usize)> = match level {
            0 => vec![],
            1 => vec![if rng.range(0, 1) == 0 { (1, 3) } else { (2, 3) }],
            _ => vec![(1, 3), (2, 3)],
        };
        for (i, &(s, d)) in flows.iter().enumerate() {
            // Phantom (timing-only) streams long enough to outlive the
            // foreground transfer; sizes are seeded per trial.
            let bg = rng.range(24, 32) as usize * 1024;
            let read = AffinePattern::contiguous(c.soc.map.base_of(NodeId(s)), bg);
            let write = AffinePattern::contiguous(c.soc.map.base_of(NodeId(d)) + half, bg);
            c.soc.nodes[s].idma.submit(
                IdmaTask {
                    task: 0x4000_0000 + i as u32,
                    read,
                    dests: vec![(NodeId(d), write)],
                    with_data: false,
                },
                0,
            );
        }
        // Two full EWMA windows of background streaming before the
        // foreground dispatch snapshots the fabric.
        c.run_for(2 * LOAD_WINDOW);
        let dests = [NodeId(3), NodeId(12), NodeId(15)];
        let task = c
            .submit_simple(NodeId(0), &dests, fg_bytes, EngineKind::Torrent(strategy), true)
            .expect("valid contention request");
        let lat = c.run_until_complete(task, 20_000_000);
        for d in dests {
            assert_eq!(
                c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(d) + half, fg_bytes),
                &payload[..],
                "{strategy:?} level {level} trial {trial}: dest {d:?} not byte-exact"
            );
        }
        let rec = c.record(task).unwrap();
        (lat, rec.chain_order.clone().unwrap(), rec.partition_width())
    };

    let pctl = |lats: &[u64], q: usize| -> u64 { lats[(lats.len() * q + 99) / 100 - 1] };
    let mut rows: Vec<ContentionRow> = Vec::new();
    let mut t = Table::new("Contention sweep — chain scheduling under background traffic (4x4)")
        .header(["sched", "bg_flows", "trials", "p50[CC]", "p99[CC]", "splits"]);
    for strategy in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp, Strategy::LoadAware] {
        let label = sched_label(strategy);
        for &level in &levels {
            let mut lats = Vec::new();
            let mut splits = 0usize;
            for trial in 0..trials {
                let reference = run_cell(strategy, level, trial, StepMode::EventDriven);
                for mode in [StepMode::FullTick, StepMode::Parallel { threads: 2 }] {
                    let other = run_cell(strategy, level, trial, mode);
                    assert_eq!(
                        reference, other,
                        "{label} level {level} trial {trial}: cell diverged under {mode:?}"
                    );
                }
                lats.push(reference.0);
                if reference.2 > 0 {
                    splits += 1;
                }
            }
            lats.sort_unstable();
            let row = ContentionRow {
                strategy: label,
                background: level,
                trials,
                p50: pctl(&lats, 50),
                p99: pctl(&lats, 99),
                splits,
            };
            t.row([
                row.strategy.to_string(),
                row.background.to_string(),
                row.trials.to_string(),
                row.p50.to_string(),
                row.p99.to_string(),
                row.splits.to_string(),
            ]);
            rows.push(row);
        }
    }
    // The congested-point guarantee: where the fabric is hottest, the
    // load-aware order must not lose to the load-blind greedy.
    let top = *levels.last().unwrap();
    let p99_of = |s: &str| {
        rows.iter()
            .find(|r| r.strategy == s && r.background == top)
            .map(|r| r.p99)
            .expect("sweep covered every (strategy, level) cell")
    };
    let (la, greedy) = (p99_of("load_aware"), p99_of("greedy"));
    assert!(
        la <= greedy,
        "load-aware p99 {la} exceeds greedy p99 {greedy} at {top} background flows"
    );
    (rows, t)
}

/// ISSUE 9 resilience sweep: the serving loop under injected faults,
/// comparing four repair postures on availability, goodput, re-streamed
/// bytes and tail latency — plus a deterministic closed-loop probe that
/// pins the resume/reroute guarantees byte-for-byte.
///
/// Postures, per paired fault schedule (identical workload + faults):
///   * `fail-stop` — detection only: stalled tasks fail, clients retry;
///   * `restream` — repair re-chains survivors, re-streams in full;
///   * `resume` — repair re-streams only the undelivered tail;
///   * `resume+reroute` — resume plus waypoint routes around damage.
///
/// In-tree guarantees, re-checked on every sweep, not just in tests:
///   * the probe's resumed repair re-streams strictly fewer bytes than
///     the full re-stream, and the survivor payload is byte-exact in
///     both postures;
///   * per paired seed, availability(resume+reroute) >=
///     availability(fail-stop);
///   * every cell (and the probe) is bit-identical across FullTick,
///     EventDriven and Parallel{2} stepping.
pub fn resilience_sweep(seed: u64, quick: bool) -> (Vec<crate::serve::ResilienceRow>, Table) {
    use crate::serve::{
        self, AdmissionPolicy, ArrivalKind, ResilienceRow, RetryPolicy, ServeConfig,
    };
    use crate::sim::{Fault, FaultKind, FaultPlan, StepMode};
    use crate::util::stream;

    let modes =
        [StepMode::EventDriven, StepMode::FullTick, StepMode::Parallel { threads: 2 }];

    // --- Closed-loop probe: the resume guarantee, pinned exactly -------
    // 4x4 mesh, 64 KB chain 0 -> 4 -> 5; router 4 dies mid-stream. The
    // back route 5 -> 0 crosses the dead router, so both cells need
    // reroute; the `resume` cell re-streams only the tail stranded above
    // survivor 5's watermark.
    let probe = |spec: &str, mode: StepMode| -> (u64, u64) {
        let bytes = 64 * 1024;
        let cfg = SocConfig::custom(4, 4, 256 * 1024)
            .with_faults(FaultPlan::parse(spec).expect("valid probe spec"));
        let mut c = Coordinator::with_step_mode(cfg, mode);
        let src = NodeId(0);
        let payload: Vec<u8> = (0..bytes).map(|i| (i * 131 % 251) as u8).collect();
        let base = c.soc.map.base_of(src);
        c.soc.nodes[src.0].mem.write(base, &payload);
        let t = c
            .submit_simple(
                src,
                &[NodeId(4), NodeId(5)],
                bytes,
                EngineKind::Torrent(Strategy::Greedy),
                true,
            )
            .expect("valid probe request");
        let report = c.run_to_completion(4_000_000);
        let restreamed = match c.record(t).unwrap().outcome.clone() {
            Some(crate::coordinator::TaskOutcome::Repaired { restreamed_bytes, .. }) => {
                restreamed_bytes
            }
            o => panic!("probe must end Repaired ({spec}), got {o:?}"),
        };
        let half = c.soc.cfg.spm_bytes as u64 / 2;
        assert_eq!(
            c.soc.nodes[5].mem.peek(c.soc.map.base_of(NodeId(5)) + half, bytes),
            &payload[..],
            "probe survivor must be byte-exact ({spec})"
        );
        (restreamed, report.cycles)
    };
    let mut full: Option<(u64, u64)> = None;
    let mut resumed: Option<(u64, u64)> = None;
    for mode in modes {
        let f = probe("router:4@600;timeout:1000;reroute", mode);
        let r = probe("router:4@600;timeout:1000;reroute;resume", mode);
        assert_eq!(*full.get_or_insert(f), f, "full-restream probe diverged in {mode:?}");
        assert_eq!(*resumed.get_or_insert(r), r, "resume probe diverged in {mode:?}");
    }
    let (full, resumed) = (full.unwrap().0, resumed.unwrap().0);
    assert!(
        resumed < full,
        "resume must re-stream strictly fewer bytes ({resumed} vs {full})"
    );

    // --- Serving cells: fabric x policy x seed, paired schedules -------
    let fabrics: Vec<TopologyKind> = if quick {
        vec![TopologyKind::Mesh]
    } else {
        vec![TopologyKind::Mesh, TopologyKind::Torus]
    };
    let seeds: Vec<u64> = if quick { vec![seed] } else { vec![seed, seed + 1] };
    let policies: [(&'static str, bool, bool, bool); 4] = [
        ("fail-stop", false, false, false),
        ("restream", true, false, false),
        ("resume", true, true, false),
        ("resume+reroute", true, true, true),
    ];
    let mut rows = Vec::new();
    let mut t = Table::new("Resilience sweep — serving under injected faults").header([
        "fabric",
        "policy",
        "seed",
        "offered",
        "completed",
        "failed",
        "rejected",
        "avail%",
        "goodput[B]",
        "restream[B]",
        "repaired",
        "retried",
        "p99",
    ]);
    for &topo in &fabrics {
        for &s in &seeds {
            let mut failstop_avail: Option<f64> = None;
            for (label, repair, resume, reroute) in policies {
                // One fault stream per (fabric, seed): every posture
                // replays the identical schedule, so cells are paired.
                let mut rng =
                    crate::util::rng(s, stream::FAULTS + 0x9100 + topo as u64);
                let mut faults = Vec::new();
                for _ in 0..rng.range(1, 2) {
                    let node = rng.range(1, 15) as usize;
                    let at_cycle = rng.range(1_500, 3_500);
                    faults.push(Fault::new(at_cycle, FaultKind::RouterKill { node }));
                }
                let plan =
                    FaultPlan { faults, detect_timeout: 1_200, repair, resume, reroute };
                let soc = SocConfig::custom(4, 4, 64 * 1024)
                    .with_topology(topo)
                    .with_faults(plan);
                let cfg = ServeConfig {
                    seed: s,
                    horizon: 6_000,
                    drain: 80_000,
                    arrival: ArrivalKind::Poisson { rate_per_kcycle: 4 },
                    policy: AdmissionPolicy::Queue,
                    retry: RetryPolicy {
                        max_attempts: 3,
                        base_backoff: 256,
                        max_backoff: 2_048,
                    },
                    ..ServeConfig::default()
                };
                let r = serve::run(cfg.clone(), soc.clone(), StepMode::EventDriven);
                for mode in [StepMode::FullTick, StepMode::Parallel { threads: 2 }] {
                    let other = serve::run(cfg.clone(), soc.clone(), mode);
                    assert_eq!(
                        r.dispositions,
                        other.dispositions,
                        "{} {label} seed {s}: dispositions diverged under {mode:?}",
                        topo.label()
                    );
                    assert_eq!(
                        (r.restreamed_bytes, r.goodput_bytes, r.retry_attempts),
                        (other.restreamed_bytes, other.goodput_bytes, other.retry_attempts),
                        "{} {label} seed {s}: telemetry diverged under {mode:?}",
                        topo.label()
                    );
                }
                match label {
                    "fail-stop" => failstop_avail = Some(r.availability()),
                    "resume+reroute" => {
                        let fs = failstop_avail.expect("fail-stop cell runs first");
                        assert!(
                            r.availability() >= fs,
                            "{} seed {s}: resume+reroute availability {:.4} fell \
                             below fail-stop {fs:.4}",
                            topo.label(),
                            r.availability()
                        );
                    }
                    _ => {}
                }
                t.row([
                    topo.label().to_string(),
                    label.to_string(),
                    s.to_string(),
                    r.offered.to_string(),
                    r.completed.to_string(),
                    r.failed.to_string(),
                    r.rejected().to_string(),
                    fnum(100.0 * r.availability(), 1),
                    r.goodput_bytes.to_string(),
                    r.restreamed_bytes.to_string(),
                    r.repaired_tasks.to_string(),
                    r.retried.to_string(),
                    r.p99().to_string(),
                ]);
                rows.push(ResilienceRow {
                    fabric: topo.label(),
                    policy: label,
                    seed: s,
                    offered: r.offered,
                    completed: r.completed,
                    failed: r.failed,
                    rejected: r.rejected(),
                    availability: r.availability(),
                    goodput_bytes: r.goodput_bytes,
                    restreamed_bytes: r.restreamed_bytes,
                    repaired_tasks: r.repaired_tasks,
                    retried: r.retried,
                    p99: r.p99(),
                });
            }
        }
    }
    (rows, t)
}

/// Fig 11 + Fig 1(d): area/power breakdowns and scaling.
pub fn fig11() -> Vec<Table> {
    use crate::analysis::{area, power};
    let mut tables = Vec::new();

    let mut a = Table::new("Fig 11(a) — 4-cluster SoC area breakdown (16nm)")
        .header(["component", "um^2", "share"]);
    let items = area::soc_area_breakdown();
    for i in &items {
        a.row([
            i.name.to_string(),
            fnum(i.um2, 0),
            format!("{}%", fnum(100.0 * i.share_of(area::SOC_AREA_UM2), 1)),
        ]);
    }
    tables.push(a);

    let mut b = Table::new("Fig 11(b) — accelerator cluster breakdown")
        .header(["component", "um^2", "share"]);
    let total = area::cluster0_area_um2();
    for i in area::cluster_area_breakdown() {
        b.row([
            i.name.to_string(),
            fnum(i.um2, 0),
            format!("{}%", fnum(100.0 * i.share_of(total), 1)),
        ]);
    }
    tables.push(b);

    // Fig 11(g) + Fig 1(d): area scaling vs max destinations.
    let mut g = Table::new("Fig 11(g)/Fig 1(d) — area vs max destinations")
        .header(["N_dst_max", "Torrent[um^2]", "mcast router[um^2]"]);
    for n in [1usize, 2, 4, 8, 16, 32, 64] {
        g.row([
            n.to_string(),
            fnum(area::torrent_area_um2(n), 0),
            fnum(area::mcast_router_area_um2(n), 0),
        ]);
    }
    tables.push(g);

    // Fig 11(d–f): run the synthesis workload (64 KB, 3 dests) and derive
    // cluster powers from actual simulated activity.
    let mut c = Coordinator::new(SocConfig::synth_2x2());
    let dests: Vec<NodeId> = vec![NodeId(1), NodeId(2), NodeId(3)];
    let task = c
        .submit_simple(NodeId(0), &dests, 64 * 1024, EngineKind::Torrent(Strategy::Greedy), false)
        .expect("valid");
    c.run_to_completion(10_000_000);
    let lat = c.latency_of(task).expect("fig11 chainwrite");
    let order = c.record(task).unwrap().chain_order.clone().unwrap();
    let mut p = Table::new("Fig 11(d-f) — cluster power during 64KB 3-dest Chainwrite")
        .header(["cluster", "role", "power[mW]"]);
    let stats0 = &c.soc.nodes[0].torrent.stats;
    p.row([
        "C0".into(),
        "initiator".into(),
        fnum(
            power::cluster_power_mw(
                power::PowerRole::Initiator,
                stats0.bytes_streamed_out,
                0,
                0,
                lat,
            ),
            1,
        ),
    ]);
    for (i, n) in order.iter().enumerate() {
        let st = &c.soc.nodes[n.0].torrent.stats;
        let role = if i + 1 == order.len() {
            power::PowerRole::TailFollower
        } else {
            power::PowerRole::MiddleFollower
        };
        p.row([
            format!("C{}", n.0),
            match role {
                power::PowerRole::TailFollower => "tail follower".into(),
                _ => "middle follower".to_string(),
            },
            fnum(
                power::cluster_power_mw(role, 0, st.bytes_written_local, st.bytes_forwarded, lat),
                1,
            ),
        ]);
    }
    tables.push(p);
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_quick_shapes_hold() {
        let (points, tables) = fig5(true);
        assert_eq!(tables.len(), 3);
        // idma stays ≤ ~1; torrent and mcast exceed 1 at 64KB/8+ dests.
        for p in &points {
            if p.mechanism.starts_with("iDMA") {
                assert!(p.eta <= 1.1, "{p:?}");
            }
            if p.bytes >= 64 * 1024 && p.n_dst >= 8 && !p.mechanism.starts_with("iDMA") {
                assert!(p.eta > 2.0, "{p:?}");
            }
        }
    }

    #[test]
    fn fig6_mechanism_ordering_at_scale() {
        let t = fig6(99, 16);
        let rendered = t.render();
        // At N=63 every optimized mechanism approaches 1 hop/dest.
        let last = rendered.lines().last().unwrap();
        assert!(last.trim_start().starts_with("63"), "{last}");
    }

    #[test]
    fn topology_sweep_orders_fabrics_sanely() {
        // Differential invariants the sweep must respect: for identical
        // destination sets, the torus TSP chain never costs more than
        // the mesh TSP chain (wrap links only add shortcuts), and on
        // every fabric TSP <= naive.
        let seed = 31;
        let trials = 8;
        let src = NodeId(0);
        let fabrics = [Topo::Mesh(Mesh::new(8, 8)), Topo::Torus(Torus::new(8, 8))];
        for n_dst in [4usize, 8] {
            let sets =
                workloads::random_dest_sets(&fabrics[0], src, n_dst, trials, seed + n_dst as u64);
            for dests in &sets {
                let cost = |topo: &Topo| {
                    sched::chain_hops(topo, src, &sched::tsp_order(topo, src, dests))
                };
                let (mesh, torus) = (cost(&fabrics[0]), cost(&fabrics[1]));
                assert!(torus <= mesh, "torus {torus} > mesh {mesh} for {dests:?}");
                for topo in &fabrics {
                    let naive = sched::chain_hops(topo, src, &sched::naive_order(dests));
                    assert!(cost(topo) <= naive, "{}", topo.name());
                }
            }
        }
        // And the rendered table carries all three fabrics.
        let table = topology_sweep(seed, 4).render();
        for fabric in ["mesh", "torus", "ring"] {
            assert!(table.contains(fabric), "missing {fabric} rows:\n{table}");
        }
    }

    #[test]
    fn fault_sweep_pairs_repair_against_failstop() {
        let (rows, table) = fault_sweep(7, 3);
        // 2 fabrics x 3 rates x 2 modes.
        assert_eq!(rows.len(), 12);
        let rendered = table.render();
        for needle in ["mesh", "torus", "repair", "fail-stop"] {
            assert!(rendered.contains(needle), "missing {needle}:\n{rendered}");
        }
        for r in &rows {
            assert!((0.0..=100.0).contains(&r.availability), "{r:?}");
            assert_eq!(r.done + r.repaired + r.failed, 3, "{r:?}");
            if !r.repair {
                assert_eq!(r.repaired, 0, "fail-stop must never re-chain: {r:?}");
            }
        }
        // Paired runs (identical seeds per cell): repair can only add
        // served destinations on top of whatever landed pre-fault, so
        // availability with repair dominates fail-stop cell by cell.
        for pair in rows.chunks(2) {
            let (rep, stop) = (&pair[0], &pair[1]);
            assert!(rep.repair && !stop.repair);
            assert_eq!((rep.fabric, rep.rate), (stop.fabric, stop.rate));
            assert!(
                rep.availability >= stop.availability,
                "repair {:.1}% < fail-stop {:.1}% on {} rate {}",
                rep.availability,
                stop.availability,
                rep.fabric,
                rep.rate
            );
        }
    }

    #[test]
    fn serve_sweep_quick_holds_accounting_and_mode_parity() {
        // serve_sweep asserts cross-mode disposition/sample equality
        // internally; reaching the end means FullTick, EventDriven and
        // Parallel{2} agreed bit-exactly at every load point.
        let (rows, table) = serve_sweep(5, true);
        assert_eq!(rows.len(), 3, "one quick leg x three rates");
        for r in &rows {
            assert_eq!((r.fabric, r.sched, r.threads), ("mesh", "greedy", 2), "{r:?}");
            assert_eq!(r.offered, r.admitted + r.rejected, "{r:?}");
            assert!(r.completed <= r.admitted, "{r:?}");
            assert!(r.util > 0.0, "a served leg must move flits: {r:?}");
        }
        // Open loop: a 12x arrival rate must offer more work than 1x.
        assert!(rows[0].offered < rows[2].offered, "{rows:?}");
        let rendered = table.render();
        for needle in ["mesh", "greedy", "p999"] {
            assert!(rendered.contains(needle), "missing {needle}:\n{rendered}");
        }
    }

    #[test]
    fn contention_sweep_quick_holds_guarantees() {
        // contention_sweep asserts byte-exactness, cross-mode
        // bit-identity and the congested-point p99 ordering internally;
        // reaching the end means all of them held.
        let (rows, table) = contention_sweep(11, true);
        assert_eq!(rows.len(), 8, "four strategies x two load levels");
        for r in &rows {
            assert_eq!(r.trials, 2, "{r:?}");
            assert!(r.p50 > 0 && r.p50 <= r.p99, "{r:?}");
            if r.strategy != "load_aware" {
                assert_eq!(r.splits, 0, "static strategies never partition: {r:?}");
            }
        }
        // Background flows are real contention: a load-blind strategy
        // keeps its chain order across levels, so added traffic can only
        // delay it. (Load-aware re-orders under load and is covered by
        // the p99-vs-greedy guarantee instead.)
        for s in ["naive", "greedy", "tsp"] {
            let at = |bg: usize| {
                rows.iter().find(|r| r.strategy == s && r.background == bg).unwrap().p99
            };
            assert!(at(2) >= at(0), "{s}: congested p99 below idle p99");
        }
        let rendered = table.render();
        for needle in ["load_aware", "greedy", "bg_flows", "splits"] {
            assert!(rendered.contains(needle), "missing {needle}:\n{rendered}");
        }
    }

    #[test]
    fn resilience_sweep_quick_holds_guarantees() {
        // resilience_sweep asserts the resume inequality, byte-exactness
        // and cross-mode bit-identity internally; reaching the end means
        // all of them held.
        let (rows, table) = resilience_sweep(17, true);
        assert_eq!(rows.len(), 4, "one fabric x one seed x four postures");
        let labels: Vec<&str> = rows.iter().map(|r| r.policy).collect();
        assert_eq!(labels, ["fail-stop", "restream", "resume", "resume+reroute"]);
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.availability), "{r:?}");
            assert!(r.offered > 0, "no arrivals inside the horizon: {r:?}");
            assert!(
                r.completed + r.failed + r.rejected <= r.offered,
                "terminal outcomes exceed offered requests: {r:?}"
            );
            if r.policy == "fail-stop" {
                assert_eq!(r.repaired_tasks, 0, "fail-stop must never repair: {r:?}");
                assert_eq!(r.restreamed_bytes, 0, "{r:?}");
            }
        }
        let rendered = table.render();
        for needle in ["fail-stop", "resume+reroute", "restream[B]"] {
            assert!(rendered.contains(needle), "missing {needle}:\n{rendered}");
        }
    }

    #[test]
    fn fig7_slope_near_82() {
        let (_, slope, _, r2) = fig7();
        assert!(r2 > 0.97, "not linear: r2={r2}");
        assert!(
            (60.0..110.0).contains(&slope),
            "per-destination overhead {slope} CC too far from the published 82"
        );
    }

    #[test]
    fn fig9_torrent_wins_multicast_workloads() {
        let (rows, _) = fig9();
        for r in &rows {
            if r.n_dst == 8 {
                assert!(r.speedup > 4.0, "{r:?}");
                assert!(r.speedup < 9.0, "{r:?}");
            } else {
                // Single-destination: modest gain from avoided handshakes.
                assert!(r.speedup > 0.8 && r.speedup < 2.5, "{r:?}");
            }
        }
        let max = rows.iter().map(|r| r.speedup).fold(0.0, f64::max);
        assert!(max > 6.0, "headline speedup only {max}");
    }

    #[test]
    fn fig11_produces_four_tables() {
        let t = fig11();
        assert_eq!(t.len(), 4);
        let power_tbl = t[3].render();
        assert!(power_tbl.contains("initiator"));
        assert!(power_tbl.contains("tail follower"));
    }
}

//! XLA PJRT backend (`pjrt` feature): compile each artifact's HLO text
//! once on the PJRT CPU client and execute it on demand.
//!
//! Offline builds link against the `vendor/xla` stub, which keeps this
//! module compile-checked but errors at runtime; swap the path dependency
//! for the real `xla` crate (xla-rs) to run on XLA (DESIGN.md §5).

// Outside the simulation core: the artifact registry is looked up by
// name and `names()` sorts before exposing, so hash-iteration order is
// never observable (clippy.toml bans HashMap in core code).
#[allow(clippy::disallowed_types)]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::manifest::{Manifest, ManifestEntry};
use super::{validate_inputs, Tensor};

/// A compiled artifact ready to execute.
pub struct Executable {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT-backed runtime: all compiled artifacts + the client.
#[allow(clippy::disallowed_types)] // see the import note above
pub struct Engine {
    pub dir: PathBuf,
    client: xla::PjRtClient,
    exes: HashMap<String, Executable>,
}

#[allow(clippy::disallowed_types)] // see the import note above
impl Engine {
    /// Load and compile every artifact listed in `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        for entry in manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
            exes.insert(entry.name.clone(), Executable { entry, exe });
        }
        Ok(Engine { dir, client, exes })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.exes.get(name).map(|e| &e.entry)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute artifact `name` on f32 inputs; returns the output tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have {:?})", self.names()))?;
        let spec = &exe.entry;
        validate_inputs(spec, inputs)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let mut result = exe
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output to_vec: {e:?}"))?;
                Ok(Tensor::new(s.dims.clone(), data))
            })
            .collect()
    }
}

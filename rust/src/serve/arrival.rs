//! Seeded open-loop arrival processes (ISSUE 8 tentpole).
//!
//! Open-loop means arrivals do not wait for the system: the generator
//! produces a cycle schedule from `(seed, process)` alone, so offered
//! load keeps climbing past saturation — exactly the regime where the
//! closed-loop drivers (submit a batch, drain to quiescence) can never
//! take the fabric. All randomness comes from
//! [`crate::util::rng`] on [`crate::util::stream::ARRIVALS`]; the
//! schedule is a pure function of the seed and is identical under every
//! [`crate::sim::StepMode`] by construction (the simulator never feeds
//! back into it).

use crate::util::{self, stream};

/// The arrival process shape. Rates are integers per kilocycle so
/// configurations hash/compare exactly (no floats in config identity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Memoryless arrivals: exponential gaps with mean `1000 /
    /// rate_per_kcycle` cycles.
    Poisson { rate_per_kcycle: u64 },
    /// On-off (bursty) arrivals: Poisson at `rate_per_kcycle` inside
    /// `on_cycles`-long windows separated by `off_cycles`-long silences.
    /// Gaps that land in a silence carry over to the next window start,
    /// so bursts open with a pile-up — the tail-latency stressor.
    Bursty { rate_per_kcycle: u64, on_cycles: u64, off_cycles: u64 },
    /// Deterministic arrivals every `interval` cycles (calibration runs:
    /// the latency curve with zero arrival variance).
    Fixed { interval: u64 },
}

impl ArrivalKind {
    /// Parse the CLI form: `poisson:R`, `bursty:R:ON:OFF`, `fixed:I`.
    pub fn parse(s: &str) -> Result<ArrivalKind, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |p: &str| -> Result<u64, String> {
            p.parse::<u64>().map_err(|_| format!("bad number '{p}' in arrival spec '{s}'"))
        };
        match parts.as_slice() {
            ["poisson", r] => {
                let rate_per_kcycle = num(r)?;
                if rate_per_kcycle == 0 {
                    return Err("poisson rate must be > 0".to_string());
                }
                Ok(ArrivalKind::Poisson { rate_per_kcycle })
            }
            ["bursty", r, on, off] => {
                let (rate_per_kcycle, on_cycles, off_cycles) = (num(r)?, num(on)?, num(off)?);
                if rate_per_kcycle == 0 || on_cycles == 0 {
                    return Err("bursty rate and on-window must be > 0".to_string());
                }
                Ok(ArrivalKind::Bursty { rate_per_kcycle, on_cycles, off_cycles })
            }
            ["fixed", i] => {
                let interval = num(i)?;
                if interval == 0 {
                    return Err("fixed interval must be > 0".to_string());
                }
                Ok(ArrivalKind::Fixed { interval })
            }
            _ => Err(format!(
                "unknown arrival spec '{s}' (want poisson:R | bursty:R:ON:OFF | fixed:I)"
            )),
        }
    }

    /// Offered rate in arrivals per kilocycle, averaged over on+off
    /// periods for bursty processes (the sweep's x-axis).
    pub fn mean_rate_per_kcycle(&self) -> f64 {
        match *self {
            ArrivalKind::Poisson { rate_per_kcycle } => rate_per_kcycle as f64,
            ArrivalKind::Bursty { rate_per_kcycle, on_cycles, off_cycles } => {
                rate_per_kcycle as f64 * on_cycles as f64 / (on_cycles + off_cycles) as f64
            }
            ArrivalKind::Fixed { interval } => 1000.0 / interval as f64,
        }
    }
}

/// Iterator over the arrival schedule: strictly driver-side state, never
/// touched by the simulator.
#[derive(Debug, Clone)]
pub struct ArrivalGen {
    kind: ArrivalKind,
    rng: util::rng::Rng,
    /// Next arrival cycle (already mapped through on/off windows).
    next: u64,
}

impl ArrivalGen {
    pub fn new(kind: ArrivalKind, seed: u64) -> Self {
        let mut gen = ArrivalGen { kind, rng: util::rng(seed, stream::ARRIVALS), next: 0 };
        gen.next = gen.after(0);
        gen
    }

    /// The upcoming arrival cycle without consuming it.
    pub fn peek(&self) -> u64 {
        self.next
    }

    /// Consume and return the upcoming arrival cycle.
    pub fn pop(&mut self) -> u64 {
        let cur = self.next;
        self.next = self.after(cur);
        cur
    }

    /// Next arrival strictly after `t`.
    fn after(&mut self, t: u64) -> u64 {
        let raw = t + self.gap();
        match self.kind {
            ArrivalKind::Bursty { on_cycles, off_cycles, .. } => {
                let period = on_cycles + off_cycles;
                let phase = raw % period;
                if phase < on_cycles {
                    raw
                } else {
                    // Carried into the next burst: arrivals pile up at the
                    // window start (same cycle is fine, the driver injects
                    // every arrival due at the wake cycle).
                    raw + (period - phase)
                }
            }
            _ => raw,
        }
    }

    /// One inter-arrival gap (>= 1 cycle: two tasks cannot arrive with a
    /// negative-duration gap, and a zero gap would loop forever).
    fn gap(&mut self) -> u64 {
        match self.kind {
            ArrivalKind::Poisson { rate_per_kcycle }
            | ArrivalKind::Bursty { rate_per_kcycle, .. } => {
                // Inverse-CDF exponential. u in [0,1) so 1-u in (0,1]:
                // ln never sees zero.
                let u = self.rng.f64();
                let gap = (-(1.0 - u).ln() * 1000.0 / rate_per_kcycle as f64).ceil();
                (gap as u64).max(1)
            }
            ArrivalKind::Fixed { interval } => interval,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_replay_by_seed() {
        for kind in [
            ArrivalKind::Poisson { rate_per_kcycle: 8 },
            ArrivalKind::Bursty { rate_per_kcycle: 16, on_cycles: 200, off_cycles: 800 },
            ArrivalKind::Fixed { interval: 125 },
        ] {
            let mut a = ArrivalGen::new(kind, 7);
            let mut b = ArrivalGen::new(kind, 7);
            for _ in 0..200 {
                assert_eq!(a.pop(), b.pop(), "{kind:?}");
            }
            let mut c = ArrivalGen::new(kind, 8);
            let first_200: Vec<u64> = (0..200).map(|_| c.pop()).collect();
            let mut d = ArrivalGen::new(kind, 7);
            let other: Vec<u64> = (0..200).map(|_| d.pop()).collect();
            if !matches!(kind, ArrivalKind::Fixed { .. }) {
                assert_ne!(first_200, other, "{kind:?}: seeds 7 and 8 drew one schedule");
            }
        }
    }

    #[test]
    fn poisson_rate_is_roughly_right() {
        let mut gen = ArrivalGen::new(ArrivalKind::Poisson { rate_per_kcycle: 10 }, 42);
        let mut last = 0;
        let n = 2_000;
        for _ in 0..n {
            last = gen.pop();
        }
        // Mean gap should be ~100 cycles; allow a wide statistical band.
        let mean_gap = last as f64 / n as f64;
        assert!((60.0..160.0).contains(&mean_gap), "mean gap {mean_gap}");
    }

    #[test]
    fn arrivals_are_monotone_and_gapped() {
        for kind in [
            ArrivalKind::Poisson { rate_per_kcycle: 50 },
            ArrivalKind::Bursty { rate_per_kcycle: 50, on_cycles: 100, off_cycles: 400 },
        ] {
            let mut gen = ArrivalGen::new(kind, 3);
            let mut prev = 0;
            for _ in 0..500 {
                let t = gen.pop();
                assert!(t >= prev, "{kind:?}: time went backwards");
                assert!(t > 0);
                prev = t;
            }
        }
    }

    #[test]
    fn bursty_arrivals_land_only_in_on_windows() {
        let (on, off) = (150u64, 350u64);
        let mut gen = ArrivalGen::new(
            ArrivalKind::Bursty { rate_per_kcycle: 40, on_cycles: on, off_cycles: off },
            11,
        );
        for _ in 0..400 {
            let t = gen.pop();
            assert!(t % (on + off) < on, "arrival {t} inside the off window");
        }
    }

    #[test]
    fn fixed_is_exactly_periodic() {
        let mut gen = ArrivalGen::new(ArrivalKind::Fixed { interval: 250 }, 1);
        for i in 1..=20u64 {
            assert_eq!(gen.pop(), 250 * i);
        }
    }

    #[test]
    fn parse_round_trips_the_cli_forms() {
        assert_eq!(
            ArrivalKind::parse("poisson:12").unwrap(),
            ArrivalKind::Poisson { rate_per_kcycle: 12 }
        );
        assert_eq!(
            ArrivalKind::parse("bursty:8:200:800").unwrap(),
            ArrivalKind::Bursty { rate_per_kcycle: 8, on_cycles: 200, off_cycles: 800 }
        );
        assert_eq!(ArrivalKind::parse("fixed:125").unwrap(), ArrivalKind::Fixed { interval: 125 });
        assert!(ArrivalKind::parse("poisson:0").is_err());
        assert!(ArrivalKind::parse("uniform:3").is_err());
        assert!(ArrivalKind::parse("bursty:1:2").is_err());
    }

    #[test]
    fn mean_rate_accounts_for_duty_cycle() {
        let b = ArrivalKind::Bursty { rate_per_kcycle: 40, on_cycles: 250, off_cycles: 750 };
        assert!((b.mean_rate_per_kcycle() - 10.0).abs() < 1e-9);
        let f = ArrivalKind::Fixed { interval: 100 };
        assert!((f.mean_rate_per_kcycle() - 10.0).abs() < 1e-9);
    }
}

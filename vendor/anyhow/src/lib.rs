//! Offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! This image has no crates.io access (DESIGN.md §3), so the workspace
//! vendors the small API subset it actually uses, source-compatible with
//! anyhow 1.x:
//!
//! * [`Error`] — an opaque, `Display`/`Debug` error value;
//! * [`Result<T>`] — `Result<T, Error>` with the same default type
//!   parameter trick as the real crate;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`] / [`bail!`] — format-style error construction.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: that keeps the blanket
//! `From<E: std::error::Error>` conversion coherent, so `?` works on
//! `io::Error`, `ParseIntError`, etc. Swapping this path dependency for
//! the registry crate requires no source changes.

use std::fmt;

/// Opaque error: a rendered message plus an optional source chain
/// (flattened into the message at construction time — good enough for a
/// simulator whose errors are read by humans, not matched by code).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    // `fn main() -> anyhow::Result<()>` and `.unwrap()` print Debug;
    // render the message itself so failures stay readable.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: plain `Result` with [`Error`] as the default
/// error type (callers can still write `Result<T, OtherError>`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring anyhow's `Context` trait.
pub trait Context<T, E> {
    /// Wrap the error with `context: original`.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Lazily-evaluated variant of [`Context::context`].
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<u32, std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn anyhow_macro_formats_with_captures() {
        let x = 7;
        let e = anyhow!("value {x} bad");
        assert_eq!(e.to_string(), "value 7 bad");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");
    }

    #[test]
    fn context_on_result_and_option() {
        let e = io_err().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");
        let e = io_err().with_context(|| format!("pass {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "pass 2: gone");
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
    }
}

//! Memory substrate: banked scratchpads and the SoC address map.
//!
//! Each compute cluster has a 1 MB, 32-bank, 64-bit-per-bank scratchpad
//! (paper §IV-A); the synthesis SoC (§IV-F) uses 256 KB per cluster plus a
//! 512 KB global SRAM. Banking gives 32 × 8 B = 256 B/cycle of internal
//! bandwidth, comfortably above the 64 B/cycle NoC link rate, so the
//! model charges one cycle per 64 B port access and tracks bank conflicts
//! only for the sub-64 B strided patterns the DSE can emit.

pub mod addr_map;
pub mod scratchpad;

pub use addr_map::AddrMap;
pub use scratchpad::{Scratchpad, BANK_BYTES, NUM_BANKS};

//! Quickstart: build a small SoC, Chainwrite a buffer to three clusters,
//! inspect the four-phase protocol's counters.
//!
//! Run: `cargo run --release --example quickstart`

use torrent::analysis::eta_p2mp;
use torrent::coordinator::{Coordinator, EngineKind};
use torrent::noc::NodeId;
use torrent::sched::Strategy;
use torrent::soc::SocConfig;

fn main() {
    // A 4x4 mesh with 64 KB scratchpads.
    let mut coord = Coordinator::new(SocConfig::custom(4, 4, 64 * 1024));

    // Put recognizable data in cluster 0.
    let base = coord.soc.map.base_of(NodeId(0));
    let payload: Vec<u8> = (0..16 * 1024).map(|i| (i % 251) as u8).collect();
    coord.soc.nodes[0].mem.write(base, &payload);

    // One P2MP request: 16 KB to three clusters, greedy chain order.
    let dests = [NodeId(5), NodeId(10), NodeId(15)];
    let task = coord
        .submit_simple(
            NodeId(0),
            &dests,
            payload.len(),
            EngineKind::Torrent(Strategy::Greedy),
            true, // move real bytes
        )
        .expect("valid request");
    coord.run_to_completion(1_000_000);

    let rec = coord.record(task).unwrap();
    let res = rec.result.as_ref().expect("completed");
    println!("chain order: {:?}", rec.chain_order.as_ref().unwrap());
    println!(
        "latency: {} cycles for {} KB x {} destinations",
        res.latency(),
        payload.len() / 1024,
        dests.len()
    );
    println!(
        "eta_P2MP: {:.2} (ideal = {})",
        eta_p2mp(dests.len(), payload.len(), res.latency()),
        dests.len()
    );

    // Verify every destination received the exact bytes.
    let half = coord.soc.cfg.spm_bytes as u64 / 2;
    for d in dests {
        let got = coord.soc.nodes[d.0].mem.peek(coord.soc.map.base_of(d) + half, payload.len());
        assert_eq!(got, &payload[..], "dest {d:?}");
    }
    println!("data integrity: OK at all destinations");

    // Peek at the protocol counters.
    for d in dests {
        let st = &coord.soc.nodes[d.0].torrent.stats;
        println!(
            "  node {:2}: cfg_rx {} grants {} finishes {} fwd {} B written {} B",
            d.0, st.cfgs_received, st.grants_relayed, st.finishes_relayed,
            st.bytes_forwarded, st.bytes_written_local
        );
    }
    println!(
        "network: {} flit-hops, {} packets delivered",
        coord.soc.net.stats.flit_hops, coord.soc.net.stats.packets_delivered
    );
}

//! Chainwrite sequence scheduling (paper §III-D).
//!
//! Chainwrite exposes the destination traversal order explicitly; §IV-C
//! shows the order decides whether Chainwrite matches network-layer
//! multicast. The strategies consume the fabric through the
//! [`Topology`] trait (`distance`/`next_hop`/`links`), so the same
//! three orders apply to meshes, tori and rings. Three strategies:
//!
//! * [`naive_order`] — follow cluster IDs (the paper's baseline that
//!   "suffers from redundant paths");
//! * [`greedy_order`] — Alg. 1: pick the next destination whose routed
//!   path does not overlap already-used links, minimizing path length
//!   (just-in-time optimization);
//! * [`tsp_order`] — open-path TSP on the routing-distance matrix; exact
//!   Held–Karp for small sets, nearest-neighbour + 2-opt beyond (the
//!   paper used OR-Tools; see DESIGN.md §3);
//! * [`load_aware_order`] — greedy's walk scored `hops + w·max link
//!   load` against a windowed [`LoadView`] occupancy snapshot, with a
//!   k-way partition pass for congested long chains (DESIGN.md
//!   §Scheduler).

pub mod chain;
pub mod hops;
pub mod load;
pub mod tsp;

pub use chain::{greedy_order, naive_order, Strategy};
pub use hops::{chain_hops, unicast_hops};
pub use load::{load_aware_order, partition_chains};
pub use tsp::tsp_order;

use std::collections::{BTreeMap, VecDeque};

use crate::noc::{LoadView, NodeId, Topology};

/// Dispatch by strategy. `src` is the initiator; returns the destination
/// visit order (a permutation of `dests`). `Strategy::LoadAware` runs
/// against an idle load view here — use [`schedule_with_load`] to feed
/// it a real fabric snapshot.
pub fn schedule(
    strategy: Strategy,
    topo: &dyn Topology,
    src: NodeId,
    dests: &[NodeId],
) -> Vec<NodeId> {
    schedule_with_load(strategy, topo, src, dests, None)
}

/// [`schedule`] with an optional fabric-load snapshot. Only
/// `Strategy::LoadAware` consumes the view (the static strategies are
/// load-blind by definition); `None` means "assume idle", which keeps
/// the call deterministic for paths that never observe the fabric
/// (e.g. repair planning over a `Degraded` view).
pub fn schedule_with_load(
    strategy: Strategy,
    topo: &dyn Topology,
    src: NodeId,
    dests: &[NodeId],
    load: Option<&LoadView>,
) -> Vec<NodeId> {
    match strategy {
        Strategy::Naive => naive_order(dests),
        Strategy::Greedy => greedy_order(topo, src, dests),
        Strategy::Tsp => tsp_order(topo, src, dests),
        Strategy::LoadAware => {
            let idle;
            let view = match load {
                Some(v) => v,
                None => {
                    idle = LoadView::zero(topo.n_nodes());
                    &idle
                }
            };
            load_aware_order(topo, src, dests, view)
        }
    }
}

/// [`schedule`] lifted to keyed payloads (write patterns, descriptors):
/// returns the visit order plus the `(node, payload)` pairs permuted
/// into that order. The single chain-ordering path shared by
/// `Soc::chainwrite` and the coordinator's dispatcher.
///
/// Payload slots are indexed by `NodeId`, so the reorder is O(n) — the
/// old linear slot scan was O(n²) and showed up at the paper's largest
/// destination sets (63 on the 8×8 study). Duplicate nodes (not
/// produced by the validated coordinator path, but legal here) keep
/// their submission order: slots drain per-node FIFO.
pub fn schedule_pairs<T>(
    strategy: Strategy,
    topo: &dyn Topology,
    src: NodeId,
    dests: Vec<(NodeId, T)>,
) -> (Vec<NodeId>, Vec<(NodeId, T)>) {
    schedule_pairs_with_load(strategy, topo, src, dests, None)
}

/// [`schedule_pairs`] with an optional fabric-load snapshot (see
/// [`schedule_with_load`]). The coordinator's dispatch path feeds the
/// snapshot it takes at dispatch time through here.
pub fn schedule_pairs_with_load<T>(
    strategy: Strategy,
    topo: &dyn Topology,
    src: NodeId,
    dests: Vec<(NodeId, T)>,
    load: Option<&LoadView>,
) -> (Vec<NodeId>, Vec<(NodeId, T)>) {
    let nodes: Vec<NodeId> = dests.iter().map(|(n, _)| *n).collect();
    let order = schedule_with_load(strategy, topo, src, &nodes, load);
    let mut slots: BTreeMap<NodeId, VecDeque<(NodeId, T)>> = BTreeMap::new();
    for pair in dests {
        slots.entry(pair.0).or_default().push_back(pair);
    }
    let ordered = order
        .iter()
        .map(|n| {
            slots
                .get_mut(n)
                .and_then(|q| q.pop_front())
                .expect("scheduled order permutes the destination set")
        })
        .collect();
    (order, ordered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{Mesh, Ring, Torus};

    #[test]
    fn schedule_pairs_keeps_payloads_with_their_nodes() {
        let m = Mesh::new(4, 4);
        let dests: Vec<(NodeId, &str)> =
            vec![(NodeId(5), "five"), (NodeId(10), "ten"), (NodeId(3), "three")];
        for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp, Strategy::LoadAware] {
            let (order, ordered) = schedule_pairs(s, &m, NodeId(0), dests.clone());
            assert_eq!(order.len(), dests.len(), "{s:?}");
            for ((n, payload), o) in ordered.iter().zip(&order) {
                assert_eq!(n, o, "{s:?} pair order must match the visit order");
                let want = dests.iter().find(|(d, _)| d == n).unwrap().1;
                assert_eq!(*payload, want, "{s:?} payload moved to the wrong node");
            }
        }
    }

    #[test]
    fn schedule_pairs_64_distinct_dests_stay_keyed() {
        // The O(n) indexed reorder at the paper's largest set size: a
        // duplicate-free 64-dest set on a 65-node fabric.
        let m = Mesh::new(13, 5);
        let dests: Vec<(NodeId, usize)> = (1..65).map(|n| (NodeId(n), n * 7)).collect();
        for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp, Strategy::LoadAware] {
            let (order, ordered) = schedule_pairs(s, &m, NodeId(0), dests.clone());
            assert_eq!(order.len(), 64, "{s:?}");
            let mut sorted: Vec<NodeId> = order.clone();
            sorted.sort();
            assert_eq!(sorted, (1..65).map(NodeId).collect::<Vec<_>>(), "{s:?}");
            for ((n, payload), o) in ordered.iter().zip(&order) {
                assert_eq!(n, o, "{s:?}");
                assert_eq!(*payload, n.0 * 7, "{s:?} payload detached from its node");
            }
        }
    }

    #[test]
    fn schedule_pairs_duplicates_drain_fifo() {
        // Duplicate destination nodes keep submission order per node for
        // *every* strategy — greedy used to collapse duplicates via
        // `retain`, which tripped the permutation expect below.
        let m = Mesh::new(4, 1);
        for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp, Strategy::LoadAware] {
            let dests =
                vec![(NodeId(2), "first"), (NodeId(2), "second"), (NodeId(1), "only")];
            let (order, ordered) = schedule_pairs(s, &m, NodeId(0), dests);
            let mut sorted = order.clone();
            sorted.sort();
            assert_eq!(sorted, vec![NodeId(1), NodeId(2), NodeId(2)], "{s:?}");
            let at_two: Vec<&str> = ordered
                .iter()
                .filter(|(n, _)| *n == NodeId(2))
                .map(|(_, p)| *p)
                .collect();
            assert_eq!(at_two, vec!["first", "second"], "{s:?} FIFO per node");
        }
    }

    #[test]
    fn schedule_dispatches_all_strategies() {
        let m = Mesh::new(4, 4);
        let dests = vec![NodeId(5), NodeId(10), NodeId(3)];
        for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp, Strategy::LoadAware] {
            let order = schedule(s, &m, NodeId(0), &dests);
            let mut sorted = order.clone();
            sorted.sort();
            let mut want = dests.clone();
            want.sort();
            assert_eq!(sorted, want, "{s:?} must permute the destination set");
        }
    }

    #[test]
    fn schedule_permutes_on_every_topology() {
        let fabrics: [&dyn Topology; 3] = [&Mesh::new(4, 4), &Torus::new(4, 4), &Ring::new(16)];
        let dests = vec![NodeId(15), NodeId(3), NodeId(9), NodeId(12)];
        for topo in fabrics {
            for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp, Strategy::LoadAware] {
                let order = schedule(s, topo, NodeId(0), &dests);
                let mut sorted = order.clone();
                sorted.sort();
                let mut want = dests.clone();
                want.sort();
                assert_eq!(sorted, want, "{s:?} on {}", topo.name());
            }
        }
    }
}

//! Small in-repo utilities replacing crates that are unavailable in this
//! offline image (see DESIGN.md §3 toolchain substitutions): a seeded PRNG
//! (`rng`), descriptive statistics + linear regression (`stats`), a CLI
//! argument parser (`cli`), a property-test harness (`prop`), and an ASCII
//! table printer (`table`).

pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

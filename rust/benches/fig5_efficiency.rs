//! Regenerates paper Fig 5: η_P2MP heatmaps for iDMA (unicast), ESP
//! (network-layer multicast) and Torrent (Chainwrite) over data sizes
//! 1–128 KB and 2–16 destinations on the 4×5 evaluation SoC (192 points
//! per mechanism). Pass --quick for the subsampled grid.
mod common;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    common::banner("Fig 5: P2MP copy efficiency (eta_P2MP)");
    let t0 = std::time::Instant::now();
    let (points, tables) = torrent::analysis::experiments::fig5(quick);
    for t in tables {
        t.print();
        println!();
    }
    // Paper-shape assertions: who wins where.
    let eta = |mech: &str, kb: usize, n: usize| {
        points
            .iter()
            .find(|p| p.mechanism.starts_with(mech) && p.bytes == kb * 1024 && p.n_dst == n)
            .map(|p| p.eta)
    };
    let at_64k_8 = (eta("iDMA", 64, 8), eta("ESP", 64, 8), eta("Torrent", 64, 8));
    if let (Some(i), Some(m), Some(t)) = at_64k_8 {
        println!("check @64KB/8dst: idma {i:.2} <= 1.1: {}", i <= 1.1);
        println!("check @64KB/8dst: torrent {t:.2} and mcast {m:.2} > 4: {}", t > 4.0 && m > 4.0);
    }
    println!("fig5 total wall time: {:.1?}", t0.elapsed());
}

//! `torrent` — launcher CLI for the Torrent reproduction.
//!
//! ```text
//! torrent table1                          # print Table I
//! torrent fig5 [--quick]                  # η_P2MP sweep (Fig 5)
//! torrent fig6 [--seed N] [--trials N]    # hop study (Fig 6)
//! torrent fig7                            # config overhead (Fig 7)
//! torrent fig9                            # DeepSeek-V3 workloads (Fig 9)
//! torrent fig11                           # area/power (Fig 11, Fig 1d)
//! torrent topo-sweep [--seed N] [--trials N]  # hops across mesh/torus/ring
//! torrent fault-sweep [--seed N] [--trials N] # availability: repair vs fail-stop
//! torrent serve-sim [--seed N] [--quick] [--out PREFIX]  # open-loop serving sweep
//!             [--scheduler naive|greedy|tsp|load_aware]
//!             [--faults SPEC] [--retries N]   # single faulted serving run instead
//! torrent contention-sweep [--seed N] [--quick]  # schedulers under background load
//! torrent resilience-sweep [--seed N] [--quick] [--out PREFIX]  # fault-policy sweep
//! torrent run [--config soc.toml] [--topology mesh|torus|ring] [--size KB]
//!             [--dests N] [--engine E] [--strategy naive|greedy|tsp|load_aware] [--data]
//!             [--faults SPEC]             # e.g. "router:5@300+200;timeout:2000;resume"
//!             [--threads N]               # sharded parallel stepper (default 1)
//! torrent artifacts [--dir artifacts]     # load + smoke-run AOT artifacts
//! ```
//!
//! `artifacts` executes on the pure-Rust reference backend by default;
//! build with `--features pjrt` (and a real `xla` dependency) to run on
//! the XLA PJRT client instead (DESIGN.md §5).

use torrent::analysis::{experiments, table1};
use torrent::coordinator::{Coordinator, EngineKind};
use torrent::noc::{NodeId, TopologyKind};
use torrent::runtime::{Engine, Tensor};
use torrent::sched::Strategy;
use torrent::soc::SocConfig;
use torrent::util::cli::Args;

const USAGE: &str =
    "torrent <table1|fig5|fig6|fig7|fig9|fig11|topo-sweep|fault-sweep|serve-sim|contention-sweep|resilience-sweep|run|artifacts> [options]
  fig5   [--quick]
  fig6   [--seed N] [--trials N]
  topo-sweep [--seed N] [--trials N]
  fault-sweep [--seed N] [--trials N]
  serve-sim [--seed N] [--quick] [--out PREFIX]   # writes PREFIX.json + PREFIX.md
            [--scheduler naive|greedy|tsp|load_aware]
            [--faults SPEC] [--retries N]         # single faulted serving run instead
  contention-sweep [--seed N] [--quick]           # schedulers under background load
  resilience-sweep [--seed N] [--quick] [--out PREFIX]  # fail-stop vs restream vs
                                                  # resume vs resume+reroute
  run    [--config soc.toml] [--topology mesh|torus|ring] [--size KB] [--dests N]
         [--engine torrent|idma|xdma|mcast] [--strategy naive|greedy|tsp|load_aware]
         [--data]
         [--faults \"link:FROM-TO@C[+D];router:N@C[+D];straggle:NxF@C;drop:N@C;\\
timeout:C;norepair;resume;reroute\"]
         [--threads N]
  artifacts [--dir artifacts]";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "table1" => print!("{}", table1::render()),
        "fig5" => {
            let (_, tables) = experiments::fig5(args.flag("quick"));
            for t in tables {
                t.print();
                println!();
            }
        }
        "fig6" => {
            let seed = args.u64_or("seed", 2025);
            let trials = args.usize_or("trials", 128);
            experiments::fig6(seed, trials).print();
        }
        "fig7" => {
            let (t, slope, intercept, r2) = experiments::fig7();
            t.print();
            println!(
                "linear fit: {slope:.1} CC/destination + {intercept:.0} CC (r^2={r2:.4}); \
                 paper: 82 CC/destination"
            );
        }
        "fig9" => {
            let (rows, t) = experiments::fig9();
            t.print();
            let max = rows.iter().map(|r| r.speedup).fold(0.0, f64::max);
            println!("max speedup {max:.2}x (paper: up to 7.88x)");
        }
        "fig11" => {
            for t in experiments::fig11() {
                t.print();
                println!();
            }
        }
        "topo-sweep" => {
            let seed = args.u64_or("seed", 2025);
            let trials = args.usize_or("trials", 64);
            experiments::topology_sweep(seed, trials).print();
        }
        "fault-sweep" => {
            let seed = args.u64_or("seed", 2025);
            let trials = args.usize_or("trials", 24);
            let (_, t) = experiments::fault_sweep(seed, trials);
            t.print();
        }
        "serve-sim" => {
            let seed = args.u64_or("seed", 2025);
            // A single serving run instead of the sweep: a fault plan
            // and/or an explicit scheduler pins one configuration.
            if args.get("faults").is_some() || args.get("scheduler").is_some() {
                serve_single(&args, seed);
                return;
            }
            let quick = args.flag("quick");
            let (rows, t) = experiments::serve_sweep(seed, quick);
            t.print();
            println!(
                "{} load points, cross-mode parity held (FullTick == EventDriven == Parallel)",
                rows.len()
            );
            if let Some(prefix) = args.get("out") {
                let json = format!("{prefix}.json");
                let md = format!("{prefix}.md");
                std::fs::write(&json, torrent::serve::sweep_json(&rows))
                    .unwrap_or_else(|e| panic!("write {json}: {e}"));
                std::fs::write(&md, torrent::serve::sweep_markdown(&rows))
                    .unwrap_or_else(|e| panic!("write {md}: {e}"));
                println!("wrote {json} + {md}");
            }
        }
        "contention-sweep" => {
            let seed = args.u64_or("seed", 2025);
            let quick = args.flag("quick");
            let (rows, t) = experiments::contention_sweep(seed, quick);
            t.print();
            println!(
                "{} cells; in-tree guarantees held (byte-exact delivery, cross-mode \
                 parity, load-aware p99 <= greedy p99 at the congested point)",
                rows.len()
            );
        }
        "resilience-sweep" => {
            let seed = args.u64_or("seed", 2025);
            let quick = args.flag("quick");
            let (rows, t) = experiments::resilience_sweep(seed, quick);
            t.print();
            println!(
                "{} cells; in-tree guarantees held (resume < full re-stream, \
                 byte-exact survivors, availability ordering, cross-mode parity)",
                rows.len()
            );
            if let Some(prefix) = args.get("out") {
                let json = format!("{prefix}.json");
                let md = format!("{prefix}.md");
                std::fs::write(&json, torrent::serve::resilience_json(&rows))
                    .unwrap_or_else(|e| panic!("write {json}: {e}"));
                std::fs::write(&md, torrent::serve::resilience_markdown(&rows))
                    .unwrap_or_else(|e| panic!("write {md}: {e}"));
                println!("wrote {json} + {md}");
            }
        }
        "run" => run_custom(&args),
        "artifacts" => smoke_artifacts(&args),
        _ => println!("{USAGE}"),
    }
}

/// `--scheduler` flag shared by the serving entrypoints (default greedy).
fn parse_scheduler(args: &Args) -> Strategy {
    match args.get_or("scheduler", "greedy") {
        "naive" => Strategy::Naive,
        "tsp" => Strategy::Tsp,
        "load_aware" => Strategy::LoadAware,
        "greedy" => Strategy::Greedy,
        other => panic!("--scheduler: unknown strategy {other:?} (naive|greedy|tsp|load_aware)"),
    }
}

/// One open-loop serving run on a 4x4 fabric
/// (`serve-sim [--faults SPEC] [--scheduler S] [--retries N]`): prints
/// the client-facing availability / goodput / repair telemetry for the
/// pinned configuration.
fn serve_single(args: &Args, seed: u64) {
    use torrent::serve::{self, RetryPolicy, ServeConfig};
    let spec = args.get("faults").unwrap_or("");
    let plan = torrent::sim::FaultPlan::parse(spec)
        .unwrap_or_else(|e| panic!("--faults: {e}"));
    let topo = match args.get("topology") {
        Some(t) => TopologyKind::parse(t).unwrap_or_else(|| {
            panic!("--topology: unknown fabric {t:?} (mesh|torus|ring)")
        }),
        None => TopologyKind::Mesh,
    };
    let retries = args.u64_or("retries", 0) as u32;
    let cfg = ServeConfig {
        seed,
        strategy: parse_scheduler(args),
        retry: RetryPolicy { max_attempts: retries, ..RetryPolicy::default() },
        ..ServeConfig::default()
    };
    let soc = SocConfig::custom(4, 4, 64 * 1024).with_topology(topo).with_faults(plan);
    let sched = experiments::sched_label(cfg.strategy);
    let r = serve::run(cfg, soc, torrent::sim::StepMode::EventDriven);
    println!(
        "serve-sim ({sched}, faults: {}) on {}: offered {}, completed {}, failed {}, \
         rejected {}, unfinished {}",
        if spec.is_empty() { "none" } else { spec },
        topo.label(),
        r.offered,
        r.completed,
        r.failed,
        r.rejected(),
        r.unfinished
    );
    println!(
        "availability {:.4}, goodput {} B, repaired tasks {}, re-streamed {} B, \
         retried {} ({} re-offers), p50/p99/p999 = {}/{}/{} CC",
        r.availability(),
        r.goodput_bytes,
        r.repaired_tasks,
        r.restreamed_bytes,
        r.retried,
        r.retry_attempts,
        r.p50(),
        r.p99(),
        r.p999()
    );
}

/// One-off P2MP transfer on a custom SoC.
fn run_custom(args: &Args) {
    let cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).expect("read --config file");
            SocConfig::from_toml(&text).expect("parse --config")
        }
        None => SocConfig::eval_4x5(),
    };
    let cfg = match args.get("topology") {
        Some(t) => cfg.with_topology(TopologyKind::parse(t).unwrap_or_else(|| {
            panic!("--topology: unknown fabric {t:?} (mesh|torus|ring)")
        })),
        None => cfg,
    };
    let cfg = match args.get("faults") {
        Some(spec) => cfg.with_faults(
            torrent::sim::FaultPlan::parse(spec)
                .unwrap_or_else(|e| panic!("--faults: {e}")),
        ),
        None => cfg,
    };
    // --threads overrides the config file; absent both, stay sequential.
    let cfg = match args.get("threads") {
        Some(_) => {
            let threads = args.usize_or("threads", 1);
            cfg.with_threads(threads)
        }
        None => cfg,
    };
    let size_kb = args.usize_or("size", 64);
    let n_dests = args.usize_or("dests", 4);
    let strategy = match args.get_or("strategy", "greedy") {
        "naive" => Strategy::Naive,
        "tsp" => Strategy::Tsp,
        "load_aware" => Strategy::LoadAware,
        _ => Strategy::Greedy,
    };
    let engine = match args.get_or("engine", "torrent") {
        "idma" => EngineKind::Idma,
        "xdma" => EngineKind::Xdma,
        "mcast" => EngineKind::Mcast,
        _ => EngineKind::Torrent(strategy),
    };
    let with_data = args.flag("data");
    assert!(n_dests < cfg.n_nodes(), "--dests must leave room for the source");
    let topo_label = cfg.topology.label();

    let mut c = Coordinator::new(cfg);
    if with_data {
        let base = c.soc.map.base_of(NodeId(0));
        let bytes: Vec<u8> = (0..size_kb * 1024).map(|i| (i % 251) as u8).collect();
        c.soc.nodes[0].mem.write(base, &bytes);
    }
    let dests: Vec<NodeId> = (1..=n_dests).map(NodeId).collect();
    let task = match c.submit_simple(NodeId(0), &dests, size_kb * 1024, engine, with_data) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("invalid request: {e}");
            std::process::exit(2);
        }
    };
    let report = c.run_to_completion(1_000_000_000);
    let rec = c.record(task).unwrap();
    if let Some(o) = &rec.outcome {
        println!("fault outcome: {o:?}");
    }
    match rec.result.as_ref() {
        Some(res) => println!(
            "{} on {}: {}KB -> {} dests: {} cycles, eta_P2MP = {:.2}",
            engine.label(),
            topo_label,
            size_kb,
            n_dests,
            res.latency(),
            rec.eta().unwrap()
        ),
        None => println!(
            "{} on {}: {}KB -> {} dests: no result (task failed after {} cycles)",
            engine.label(),
            topo_label,
            size_kb,
            n_dests,
            report.cycles
        ),
    }
    if let Some(order) = &rec.chain_order {
        println!("chain order: {:?}", order.iter().map(|n| n.0).collect::<Vec<_>>());
    }
    println!(
        "network: {} flit-hops, {} packets",
        c.soc.net.stats.flit_hops, c.soc.net.stats.packets_delivered
    );
}

/// Load the AOT artifacts and run each once on random inputs. The
/// default (reference) backend needs only `manifest.txt`; the `pjrt`
/// backend also parses the `.hlo.txt` files (`make artifacts`).
fn smoke_artifacts(args: &Args) {
    let dir = args.get_or("dir", "artifacts");
    let engine = Engine::load(dir).expect("load artifacts (run `make artifacts`)");
    println!("PJRT platform: {}", engine.platform());
    for name in engine.names() {
        let entry = engine.entry(name).unwrap().clone();
        let inputs: Vec<Tensor> = entry
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s.dims.clone(), 0xC0FFEE + i as u64))
            .collect();
        let t0 = std::time::Instant::now();
        let outs = engine.run(name, &inputs).expect("execute");
        println!(
            "  {name}: {} inputs -> {} outputs {:?} in {:.2?}",
            inputs.len(),
            outs.len(),
            outs.iter().map(|o| o.shape.clone()).collect::<Vec<_>>(),
            t0.elapsed()
        );
    }
}

//! Mechanism sweep: compare all four P2MP engines (Torrent Chainwrite,
//! ESP-style multicast, XDMA software P2MP, iDMA unicast) across
//! destination counts on the evaluation SoC — the motivating scenario of
//! the paper's intro (distributing one GeMM operand to many accelerators).
//!
//! Run: `cargo run --release --example multicast_sweep [--size-kb 32]`

use torrent::coordinator::{Coordinator, EngineKind};
use torrent::noc::NodeId;
use torrent::sched::Strategy;
use torrent::soc::SocConfig;
use torrent::util::cli::Args;
use torrent::util::table::{fnum, Table};

fn main() {
    let args = Args::from_env();
    let size_kb = args.usize_or("size-kb", 32);
    let engines = [
        ("torrent/tsp", EngineKind::Torrent(Strategy::Tsp)),
        ("mcast", EngineKind::Mcast),
        ("xdma", EngineKind::Xdma),
        ("idma", EngineKind::Idma),
    ];
    let mut lat_tbl = Table::new(format!("latency [CC], {size_kb} KB, 4x5 SoC"))
        .header(["N_dst", "torrent/tsp", "mcast", "xdma", "idma"]);
    let mut eta_tbl = Table::new(format!("eta_P2MP, {size_kb} KB, 4x5 SoC"))
        .header(["N_dst", "torrent/tsp", "mcast", "xdma", "idma"]);

    for n_dst in [2usize, 4, 8, 12, 16] {
        let mut lat_row = vec![n_dst.to_string()];
        let mut eta_row = vec![n_dst.to_string()];
        for (_, engine) in engines {
            let mut c = Coordinator::new(SocConfig::eval_4x5());
            let dests: Vec<NodeId> = (1..=n_dst).map(NodeId).collect();
            let task = c
                .submit_simple(NodeId(0), &dests, size_kb * 1024, engine, false)
                .expect("valid request");
            c.run_to_completion(100_000_000);
            let rec = c.record(task).unwrap();
            let res = rec.result.as_ref().expect("completed");
            lat_row.push(res.latency().to_string());
            eta_row.push(fnum(rec.eta().unwrap(), 2));
        }
        lat_tbl.row(lat_row);
        eta_tbl.row(eta_row);
    }
    lat_tbl.print();
    println!();
    eta_tbl.print();
    println!("\nreading guide: idma eta <= 1 (no duplication); mcast wins at small N_dst");
    println!("(cheap link setup); chainwrite scales past it as N grows (linear 82CC/dest");
    println!("config vs the multicast router's super-linear set programming).");
}

"""L1 Pallas layout-transform kernel — the paper's MNMxNy re-tiling.

Table II's workloads move matrices between blocked layouts (MNM16N8 ->
MNM8N8 for prefill, MNM16N8 -> MNM64N16 for decode). In the paper this is
done on the fly by the Torrent DSE's ND-affine address generator; on TPU
we express the same gather as a Pallas kernel whose BlockSpecs read one
*logical* row-panel per grid step and emit it in the destination tile
geometry.

Blocked layouts are carried as 4D arrays (Mt, Nt, tm, tn) — see
ref.to_blocked. A transform (tm_in, tn_in) -> (tm_out, tn_out) works on
the least-common-multiple panel so each grid step touches whole tiles of
both geometries.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relayout_kernel(x_ref, o_ref, *, tm_in, tn_in, tm_out, tn_out):
    """Re-tile one LCM panel.

    x_ref: (pm/tm_in, pn/tn_in, tm_in, tn_in) — input tiles of the panel
    o_ref: (pm/tm_out, pn/tn_out, tm_out, tn_out) — output tiles
    """
    xt = x_ref[...]
    a, b, _, _ = xt.shape
    # blocked -> logical panel
    logical = xt.transpose(0, 2, 1, 3).reshape(a * tm_in, b * tn_in)
    pm, pn = logical.shape
    # logical -> output blocked
    o_ref[...] = logical.reshape(
        pm // tm_out, tm_out, pn // tn_out, tn_out
    ).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("tm_out", "tn_out"))
def relayout(xb, tm_out, tn_out):
    """(Mt, Nt, tm_in, tn_in) blocked matrix -> (tm_out, tn_out) tiling."""
    mt, nt, tm_in, tn_in = xb.shape
    m, n = mt * tm_in, nt * tn_in
    assert m % tm_out == 0 and n % tn_out == 0, (xb.shape, tm_out, tn_out)
    # LCM panel: whole tiles of both geometries.
    pm = math.lcm(tm_in, tm_out)
    pn = math.lcm(tn_in, tn_out)
    grid = (m // pm, n // pn)
    in_block = (pm // tm_in, pn // tn_in, tm_in, tn_in)
    out_block = (pm // tm_out, pn // tn_out, tm_out, tn_out)
    kern = functools.partial(
        _relayout_kernel, tm_in=tm_in, tn_in=tn_in, tm_out=tm_out, tn_out=tn_out
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(in_block, lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec(out_block, lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (m // tm_out, n // tn_out, tm_out, tn_out), xb.dtype
        ),
        interpret=True,
    )(xb)

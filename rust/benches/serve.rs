//! Serving-simulator benchmarks — wall-clock cost of the open-loop
//! driver (ISSUE 8).
//!
//! Three load points on the 4×4 custom fabric: an unloaded leg, a
//! saturated leg, and a saturated leg on the sharded parallel stepper.
//! Each run also prints its deterministic simulated tail latencies, so
//! the log doubles as a quick sanity readout (those numbers are
//! seed-exact and machine-independent; only the milliseconds vary).
//!
//! CI integration mirrors `simcore`: `TORRENT_BENCH_JSON` writes a
//! `torrent-bench-v1` baseline, `TORRENT_BENCH_BASELINE` compares p50s
//! against the committed `BENCH_serve.json` and fails on >2x
//! calibrated regressions.

mod common;

use torrent::serve::{run, AdmissionPolicy, ArrivalKind, ServeConfig};
use torrent::sim::StepMode;
use torrent::soc::SocConfig;

fn cfg(rate: u64) -> ServeConfig {
    ServeConfig {
        seed: 17,
        horizon: 4_000,
        drain: 40_000,
        arrival: ArrivalKind::Poisson { rate_per_kcycle: rate },
        policy: AdmissionPolicy::Queue,
        ..ServeConfig::default()
    }
}

fn fabric() -> SocConfig {
    SocConfig::custom(4, 4, 64 * 1024)
}

fn main() {
    common::banner("serve: open-loop serving-driver benchmarks");
    let mut results: Vec<(String, f64)> = Vec::new();
    let mut record = |name: &str, s: &torrent::util::stats::Summary| {
        results.push((name.to_string(), s.p50));
    };

    // 1. Light load: the driver overhead floor (fabric mostly idle).
    let mut last = None;
    let s = common::bench("serve_4x4_rate2_light", 1, common::iters(5), || {
        last = Some(run(cfg(2), fabric(), StepMode::EventDriven));
    });
    let r = last.take().expect("bench ran");
    println!(
        "  -> {} offered, {} completed, p50/p99 = {}/{} CC",
        r.offered,
        r.completed,
        r.p50(),
        r.p99()
    );
    record("serve_4x4_rate2_light", &s);

    // 2. Saturated load: admission queue and batcher exercised hard.
    let s = common::bench("serve_4x4_rate12_saturated", 1, common::iters(5), || {
        last = Some(run(cfg(12), fabric(), StepMode::EventDriven));
    });
    let r = last.take().expect("bench ran");
    println!(
        "  -> {} offered, {} completed, {} rejected, p99/p999 = {}/{} CC, pending peak {}",
        r.offered,
        r.completed,
        r.rejected(),
        r.p99(),
        r.p999(),
        r.pending_peak
    );
    record("serve_4x4_rate12_saturated", &s);

    // 3. Same saturated leg through the sharded parallel stepper — the
    // bit-exactness contract means only the wall clock may differ.
    let s = common::bench("serve_4x4_rate12_parallel2", 1, common::iters(5), || {
        last = Some(run(cfg(12), fabric(), StepMode::Parallel { threads: 2 }));
    });
    let r = last.take().expect("bench ran");
    println!("  -> parallel(2): {} completed, p999 = {} CC", r.completed, r.p999());
    record("serve_4x4_rate12_parallel2", &s);

    // Baseline plumbing (see Makefile `bench-baseline` / `serve-smoke`).
    if let Ok(path) = std::env::var("TORRENT_BENCH_JSON") {
        let calibrated = std::env::var("TORRENT_BENCH_CALIBRATED").is_ok();
        let note = if calibrated {
            "calibrated from a real run via `make bench-baseline`"
        } else {
            "placeholder written without calibration; run `make bench-baseline`"
        };
        common::write_bench_json(&path, "serve", calibrated, note, &results)
            .expect("write bench JSON");
        println!("wrote baseline {path} (calibrated={calibrated})");
    }
    if let Ok(path) = std::env::var("TORRENT_BENCH_BASELINE") {
        common::banner("serve: baseline comparison");
        match common::read_bench_json(&path) {
            Err(e) => {
                eprintln!("baseline unavailable: {e}");
                std::process::exit(1);
            }
            Ok(base) => {
                let regressions = common::count_regressions(&results, &base);
                if regressions > 0 {
                    eprintln!("{regressions} bench regression(s) vs {path}");
                    std::process::exit(1);
                }
            }
        }
    }
}

//! Serving telemetry (ISSUE 8): log-bucketed latency histograms with
//! exact tail percentiles, and admission/occupancy time-series.
//!
//! The histogram keeps both a 64-bucket log2 shape (for display: bucket
//! `i` covers `[2^i, 2^(i+1))` cycles, bucket 0 covers `{0, 1}`) and the
//! raw samples, so p50/p99/p999 are *exact* nearest-rank order
//! statistics, not bucket interpolations — at serving scale the p999 of
//! a log-bucketed estimate can be off by half a bucket (~40%), which is
//! bigger than the effects the sweep measures.

/// Latency histogram: log2 display buckets + exact percentile samples.
#[derive(Debug, Clone, Default)]
pub struct LatencyHisto {
    buckets: [u64; 64],
    samples: Vec<u64>,
}

impl LatencyHisto {
    pub fn new() -> Self {
        LatencyHisto { buckets: [0; 64], samples: Vec::new() }
    }

    pub fn record(&mut self, latency: u64) {
        let idx = (64 - latency.max(1).leading_zeros() as usize - 1).min(63);
        self.buckets[idx] += 1;
        self.samples.push(latency);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Exact nearest-rank percentile (`q` in [0, 100]); `None` when
    /// empty. p50/p99/p999 below are the report fields.
    pub fn percentile(&self, q: f64) -> Option<u64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
        Some(sorted[rank.clamp(1, sorted.len()) - 1])
    }

    pub fn p50(&self) -> Option<u64> {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> Option<u64> {
        self.percentile(99.0)
    }

    pub fn p999(&self) -> Option<u64> {
        self.percentile(99.9)
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        Some(self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64)
    }

    pub fn max(&self) -> Option<u64> {
        self.samples.iter().copied().max()
    }

    /// Non-empty log2 buckets as `(bucket_floor_cycles, count)`, for the
    /// Markdown histogram rendering.
    pub fn shape(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (1u64 << i, c))
            .collect()
    }
}

/// One occupancy sample on the driver's fixed cadence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sample {
    pub cycle: u64,
    /// Requests waiting in the admission queue.
    pub pending: usize,
    /// Admitted-but-incomplete requests.
    pub inflight: usize,
    /// Cumulative admitted arrivals.
    pub admitted: u64,
    /// Cumulative rejected arrivals.
    pub rejected: u64,
}

/// Total switch capacity of the fabric in flits per cycle: each router
/// can move at most one flit per output port per cycle, and its port
/// count is topology-dependent (Local eject plus one port per live
/// neighbour — 5 for an interior mesh router, 3 for a mesh corner, 3
/// everywhere on a ring).
pub fn fabric_port_capacity(topo: &dyn crate::noc::Topology) -> u64 {
    use crate::noc::Dir;
    // Cardinal ports only — `neighbour(n, Local)` is `Some(n)` by
    // convention, so Local is added explicitly as the eject port.
    let cardinal = [Dir::North, Dir::East, Dir::South, Dir::West];
    (0..topo.n_nodes())
        .map(|n| {
            let node = crate::noc::NodeId(n);
            let radix =
                cardinal.iter().filter(|&&d| topo.neighbour(node, d).is_some()).count() as u64;
            radix + 1 // + Local eject port
        })
        .sum()
}

/// Fabric utilization over a window: router lane-activity delta
/// normalized by the fabric's aggregate port capacity
/// (`fabric_port_capacity(topo) · cycles`). A router moves up to one
/// flit per output port per cycle — not one per router — so dividing by
/// the per-router port count is what makes this a true fraction:
/// 0 means a quiet fabric, 1.0 means every port on every router moved
/// a flit every cycle. Clamped defensively to `[0, 1]` so accounting
/// drift can never report an impossible > 100%.
pub fn utilization(activity_delta: u64, port_capacity: u64, cycles: u64) -> f64 {
    if cycles == 0 || port_capacity == 0 {
        return 0.0;
    }
    (activity_delta as f64 / (port_capacity as f64 * cycles as f64)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_exact_order_statistics() {
        let mut h = LatencyHisto::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.p50(), Some(500));
        assert_eq!(h.p99(), Some(990));
        assert_eq!(h.p999(), Some(999));
        assert_eq!(h.percentile(100.0), Some(1000));
        assert_eq!(h.max(), Some(1000));
        assert!((h.mean().unwrap() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut h = LatencyHisto::new();
        h.record(42);
        assert_eq!(h.p50(), Some(42));
        assert_eq!(h.p999(), Some(42));
    }

    #[test]
    fn empty_histogram_has_no_percentiles() {
        let h = LatencyHisto::new();
        assert_eq!(h.p50(), None);
        assert_eq!(h.mean(), None);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn log_buckets_cover_the_tail() {
        let mut h = LatencyHisto::new();
        h.record(0); // clamps into bucket 0
        h.record(1);
        h.record(2);
        h.record(3);
        h.record(1024);
        h.record(u64::MAX); // must not index out of bounds
        let shape = h.shape();
        assert_eq!(shape[0], (1, 2)); // {0, 1}
        assert_eq!(shape[1], (2, 2)); // {2, 3}
        assert!(shape.contains(&(1024, 1)));
        assert!(shape.contains(&(1u64 << 63, 1)));
    }

    #[test]
    fn utilization_normalizes_per_port_cycle() {
        // 64 ports moving every cycle for 100 cycles is exactly full.
        assert!((utilization(6400, 64, 100) - 1.0).abs() < 1e-9);
        assert_eq!(utilization(5, 64, 0), 0.0);
        assert_eq!(utilization(5, 0, 100), 0.0);
        assert!(utilization(800, 64, 100) < utilization(1600, 64, 100));
    }

    #[test]
    fn utilization_is_clamped_to_one() {
        // Even a nonsense delta (more flits than ports could move) must
        // report at most 100% — the old per-router normalization leaked
        // values like 4.2 on hot fabrics.
        assert_eq!(utilization(u64::MAX, 16, 100), 1.0);
        assert_eq!(utilization(1601, 16, 100), 1.0);
    }

    #[test]
    fn port_capacity_counts_topology_radix() {
        use crate::noc::{Mesh, Ring, Torus};
        // 4×4 mesh: 4 corners (radix 2), 8 edges (radix 3), 4 interior
        // (radix 4), plus a Local port each: 4*3 + 8*4 + 4*5 = 64.
        assert_eq!(fabric_port_capacity(&Mesh::new(4, 4)), 64);
        // Torus: every router has all four neighbours: 16 * 5 = 80.
        assert_eq!(fabric_port_capacity(&Torus::new(4, 4)), 80);
        // Ring of 8: two neighbours + Local each: 8 * 3 = 24.
        assert_eq!(fabric_port_capacity(&Ring::new(8)), 24);
    }
}

//! Regenerates paper Fig 11 (+ Fig 1(d)): 16 nm area breakdowns, the
//! area-vs-N_dst,max scaling of the initiator Torrent against a
//! multicast router, and the activity-derived cluster power of the 64 KB
//! 3-destination post-synthesis Chainwrite.
mod common;

fn main() {
    common::banner("Fig 11 / Fig 1(d): ASIC area & power");
    for t in torrent::analysis::experiments::fig11() {
        t.print();
        println!();
    }
    println!("paper anchors: 2.8mm^2 SoC; Torrent 5.3% of cluster; 207 um^2/dest;");
    println!("initiator cluster 175.7 mW; middle followers > tail follower; 4.68 pJ/B/hop");
}

//! Regenerates paper Table I: qualitative comparison with SoTA DMAs/NoCs.
mod common;

fn main() {
    common::banner("Table I");
    print!("{}", torrent::analysis::table1::render());
}

//! # torrent-dma
//!
//! Reproduction of *"Torrent: A Distributed DMA for Efficient and Flexible
//! Point-to-Multipoint Data Movement"* (Deng, Kong, Yi, Antonio, Verhelst —
//! CS.AR 2025).
//!
//! Torrent embeds point-to-multipoint (P2MP) capability in distributed DMA
//! endpoints instead of NoC routers: a P2MP transfer becomes a *Chainwrite*
//! through a doubly linked list of endpoints, keeping every on-wire
//! transfer point-to-point and AXI-compatible.
//!
//! This crate contains:
//!
//! * a cycle-stepped topology-generic wormhole NoC simulator (2D mesh
//!   with XY routing, wraparound torus, bidirectional ring) with an
//!   ESP-style network-layer multicast router baseline ([`noc`]);
//! * an AXI4 transaction layer ([`axi`]) and banked scratchpads ([`mem`]);
//! * the Torrent architecture — DSE, data switch, backend, Chainwrite
//!   four-phase FSM — plus the iDMA / XDMA baselines ([`dma`]);
//! * the chain-sequence schedulers (naive / greedy / TSP) and hop-count
//!   models ([`sched`]);
//! * compute clusters, the Occamy-derived SoC builder and the task-level
//!   coordinator ([`cluster`], [`soc`], [`coordinator`]);
//! * a runtime that loads the JAX/Pallas AOT artifacts and runs the
//!   DeepSeek-V3 attention numerics from Rust ([`runtime`]) — on a
//!   pure-Rust reference backend by default, or on XLA PJRT with the
//!   `pjrt` feature;
//! * analytic area/power/efficiency models calibrated with the paper's
//!   published constants ([`analysis`]);
//! * the workload generators for every figure/table ([`workloads`]);
//! * an open-loop serving simulator — seeded arrival processes,
//!   admission control, continuous batching, tail-latency telemetry —
//!   driving the coordinator past saturation ([`serve`], CLI
//!   `torrent serve-sim`).
//!
//! See `DESIGN.md` for the module map and experiment index and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Feature flags
//!
//! * `pjrt` *(off by default)* — execute the AOT artifacts on the XLA
//!   PJRT CPU client instead of the pure-Rust reference backend
//!   (DESIGN.md §5). The default build needs no XLA toolchain and no
//!   network access.
//!
//! ## Example: schedule a Chainwrite order
//!
//! The scheduler picks the destination traversal order; a chain through
//! clusters that extend away from the source traverses no more mesh
//! links than repeated unicast (paper §III-D):
//!
//! ```
//! use torrent::noc::{Mesh, NodeId};
//! use torrent::sched::{chain_hops, schedule, unicast_hops, Strategy};
//!
//! // 4x4 mesh; Chainwrite from corner cluster 0 along its row.
//! let mesh = Mesh::new(4, 4);
//! let src = NodeId(0);
//! let dests = [NodeId(1), NodeId(2), NodeId(3)];
//!
//! let order = schedule(Strategy::Greedy, &mesh, src, &dests);
//! assert_eq!(order.len(), dests.len());
//! assert!(chain_hops(&mesh, src, &order) <= unicast_hops(&mesh, src, &dests));
//! ```
//!
//! ## Example: run a P2MP transfer on the cycle simulator
//!
//! Requests are built fluently, submission is fallible and returns a
//! typed handle, and tasks can depend on each other (`.after`) — see
//! [`coordinator`] and `examples/batch_pipeline.rs` for dependency DAGs:
//!
//! ```
//! use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest};
//! use torrent::noc::NodeId;
//! use torrent::sched::Strategy;
//! use torrent::soc::SocConfig;
//!
//! let mut c = Coordinator::new(SocConfig::custom(3, 3, 64 * 1024));
//! let task = c
//!     .submit(
//!         P2mpRequest::to(&[NodeId(1), NodeId(4)]) // destinations
//!             .src(NodeId(0))                      // initiator
//!             .bytes(4096)
//!             .engine(EngineKind::Torrent(Strategy::Greedy)),
//!     )
//!     .expect("valid request");
//! let latency = c.run_until_complete(task, 1_000_000);
//! assert!(latency > 0);
//! ```

pub mod analysis;
pub mod axi;
pub mod cluster;
pub mod coordinator;
pub mod dma;
pub mod mem;
pub mod noc;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod soc;
pub mod util;
pub mod workloads;

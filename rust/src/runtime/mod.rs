//! Runtime: load the JAX/Pallas AOT artifacts and execute them from
//! Rust. Python never runs at simulation time.
//!
//! `make artifacts` lowers every L2 entry point to HLO **text**
//! (`artifacts/<name>.hlo.txt` + `manifest.txt`). Two interchangeable
//! backends implement [`Engine`]:
//!
//! * **default** — the pure-Rust [`reference`] backend: the manifest
//!   still drives entry points and shapes, and the known kernels (GeMM,
//!   attention, MLA KV recovery, MNMxNy relayout) are evaluated with
//!   f64 accumulation, so CI and the examples never need the XLA
//!   toolchain (DESIGN.md §5);
//! * **`pjrt` feature (off by default)** — compiles the HLO text once on
//!   the PJRT CPU client (`xla` crate) and exposes typed f32-tensor
//!   execution. HLO text — not serialized protos — is the interchange
//!   format because jax ≥ 0.5 emits 64-bit instruction ids the bundled
//!   xla_extension 0.5.1 rejects (see DESIGN.md §5 and
//!   /opt/xla-example/README.md).

pub mod manifest;
pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{Engine, Executable};

#[cfg(not(feature = "pjrt"))]
pub use reference::Engine;

pub use manifest::{Manifest, ManifestEntry, ShapeSpec};

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor (test/workload inputs).
    pub fn random(shape: Vec<usize>, seed: u64) -> Self {
        let mut rng = crate::util::rng(seed, crate::util::stream::PAYLOAD);
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect();
        Tensor { shape, data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Bytes when materialized as f32 (sizes the simulated transfers).
    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }
}

/// Shape-check `inputs` against a manifest entry — shared by both
/// backends so they reject malformed calls identically.
pub(crate) fn validate_inputs(spec: &ManifestEntry, inputs: &[Tensor]) -> anyhow::Result<()> {
    use anyhow::anyhow;
    let name = &spec.name;
    if inputs.len() != spec.inputs.len() {
        return Err(anyhow!(
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        ));
    }
    for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if t.shape != s.dims {
            return Err(anyhow!(
                "{name}: input {i} shape {:?} != manifest {:?}",
                t.shape,
                s.dims
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_invariants() {
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.bytes(), 24);
        let r1 = Tensor::random(vec![4], 1);
        let r2 = Tensor::random(vec![4], 1);
        assert_eq!(r1, r2);
        assert_ne!(r1, Tensor::random(vec![4], 2));
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn validate_inputs_checks_arity_and_shapes() {
        let spec = ManifestEntry {
            name: "gemm".into(),
            file: "gemm.hlo.txt".into(),
            inputs: vec![
                ShapeSpec { dtype: "f32".into(), dims: vec![2, 3] },
                ShapeSpec { dtype: "f32".into(), dims: vec![3, 4] },
            ],
            outputs: vec![ShapeSpec { dtype: "f32".into(), dims: vec![2, 4] }],
        };
        let a = Tensor::zeros(vec![2, 3]);
        let b = Tensor::zeros(vec![3, 4]);
        assert!(validate_inputs(&spec, &[a.clone(), b.clone()]).is_ok());
        assert!(validate_inputs(&spec, &[a.clone()]).is_err());
        assert!(validate_inputs(&spec, &[b, a]).is_err());
    }
}

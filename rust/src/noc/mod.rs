//! Cycle-stepped wormhole NoC with virtual channels, credit flow control
//! and an ESP-style network-layer multicast baseline, over a pluggable
//! fabric: 2D mesh (XY routing), 2D torus (wraparound XY) or ring
//! (bidirectional shortest-arc) — see [`topology`].
//!
//! Layering follows the paper's Fig 2: this module is the *network* and
//! *link* layers; `crate::axi` is the transport layer; the DMA engines in
//! `crate::dma` are the application layer.

pub mod multicast;
pub mod network;
pub mod packet;
pub mod router;
pub mod shard;
pub mod topology;

pub use network::{Gate, GateCell, LoadView, NetPort, NetStats, Network, LOAD_WINDOW};
pub use shard::shard_ranges;
pub use packet::{Flit, Message, Packet, PacketId, FLIT_BYTES};
pub use router::{BUF_FLITS, LINK_CYCLES, NUM_VCS, ROUTER_PIPELINE};
pub use topology::{Coord, Degraded, Dir, Mesh, NodeId, Ring, Topo, Topology, TopologyKind, Torus};

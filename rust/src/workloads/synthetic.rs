//! Synthetic workload generation for the §IV-B efficiency sweep and the
//! §IV-C hop study (randomized destination sets, seeded for exact
//! reproducibility of every figure).

use crate::noc::{NodeId, Topology};
use crate::util::stream;

/// Generate `count` random destination sets of size `n_dst`, drawn from
/// the fabric excluding `src` (paper: "every group selects destinations
/// randomly and repeats this 128 times"). Sets depend only on the node
/// count, so equally-sized fabrics draw identical sets from one seed —
/// the basis of the cross-topology differential comparisons.
pub fn random_dest_sets(
    topo: &dyn Topology,
    src: NodeId,
    n_dst: usize,
    count: usize,
    seed: u64,
) -> Vec<Vec<NodeId>> {
    let candidates: Vec<NodeId> = (0..topo.n_nodes()).map(NodeId).filter(|&n| n != src).collect();
    assert!(n_dst <= candidates.len(), "n_dst {n_dst} exceeds fabric minus source");
    let mut rng = crate::util::rng(seed, stream::DEST_SETS);
    (0..count)
        .map(|_| {
            rng.sample_distinct(candidates.len(), n_dst)
                .into_iter()
                .map(|i| candidates[i])
                .collect()
        })
        .collect()
}

/// The §IV-B sweep grid: data sizes 1–128 KB (powers of two) ×
/// destination counts 2–16 → the paper's 192 test points per mechanism.
pub fn fig5_grid() -> Vec<(usize, usize)> {
    let sizes: Vec<usize> = (0..8).map(|i| (1 << i) * 1024).collect(); // 1..128 KB
    let dests: Vec<usize> = (2..=16).collect();
    let mut grid = Vec::new();
    for &s in &sizes {
        for &d in &dests {
            grid.push((s, d));
        }
    }
    grid
}

/// The §IV-C destination-count groups on the 8×8 mesh.
pub fn fig6_groups() -> Vec<usize> {
    vec![4, 8, 16, 24, 32, 40, 48, 63]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{Mesh, Ring};

    #[test]
    fn dest_sets_are_distinct_and_exclude_source() {
        let m = Mesh::new(8, 8);
        let sets = random_dest_sets(&m, NodeId(0), 16, 128, 1);
        assert_eq!(sets.len(), 128);
        for s in &sets {
            assert_eq!(s.len(), 16);
            assert!(!s.contains(&NodeId(0)));
            let mut d = s.clone();
            d.sort();
            d.dedup();
            assert_eq!(d.len(), 16);
        }
    }

    #[test]
    fn dest_sets_reproducible_by_seed() {
        let m = Mesh::new(8, 8);
        assert_eq!(
            random_dest_sets(&m, NodeId(0), 8, 4, 7),
            random_dest_sets(&m, NodeId(0), 8, 4, 7)
        );
    }

    #[test]
    fn fig5_grid_has_192_points() {
        let g = fig5_grid();
        assert_eq!(g.len(), 8 * 15);
        assert!(g.contains(&(1024, 2)));
        assert!(g.contains(&(131072, 16)));
    }

    #[test]
    fn fig6_groups_match_paper() {
        let g = fig6_groups();
        assert_eq!(g.len(), 8);
        assert_eq!(*g.first().unwrap(), 4);
        assert_eq!(*g.last().unwrap(), 63);
    }

    #[test]
    fn full_mesh_63_dests_possible() {
        let m = Mesh::new(8, 8);
        let sets = random_dest_sets(&m, NodeId(0), 63, 2, 3);
        assert_eq!(sets[0].len(), 63);
    }

    #[test]
    fn equal_sized_fabrics_draw_identical_sets() {
        // 64-node mesh and 64-node ring: same seed, same destination sets
        // — the topology sweep compares fabrics on identical workloads.
        let m = Mesh::new(8, 8);
        let r = Ring::new(64);
        assert_eq!(
            random_dest_sets(&m, NodeId(0), 8, 4, 11),
            random_dest_sets(&r, NodeId(0), 8, 4, 11)
        );
    }
}

//! Open-path TSP chain ordering (paper §III-D strategy 2).
//!
//! The scheduling problem is an *open-path* TSP: start at the initiator,
//! visit every destination once, no return edge. The paper solves it with
//! Google OR-Tools ahead of time; this in-repo solver is exact (Held–Karp
//! dynamic program) up to [`EXACT_LIMIT`] destinations and falls back to
//! nearest-neighbour construction + 2-opt refinement beyond that —
//! near-optimal at the paper's largest set (63 destinations) while
//! staying dependency-free. Distances come from the fabric's
//! [`Topology::distance`], so the same solver orders chains on meshes,
//! tori and rings.

use crate::noc::{NodeId, Topology};

/// Held–Karp is O(2^n · n²); 15 destinations ≈ 7.4 M steps — instant.
pub const EXACT_LIMIT: usize = 15;

/// Open-path TSP order of `dests` starting from `src`.
pub fn tsp_order(topo: &dyn Topology, src: NodeId, dests: &[NodeId]) -> Vec<NodeId> {
    match dests.len() {
        0 => vec![],
        1 => vec![dests[0]],
        n if n <= EXACT_LIMIT => held_karp(topo, src, dests),
        _ => two_opt(topo, src, nearest_neighbour(topo, src, dests)),
    }
}

/// Routing distance (= Manhattan on a mesh, shortest-arc on tori/rings).
fn dist(topo: &dyn Topology, a: NodeId, b: NodeId) -> u32 {
    topo.distance(a, b) as u32
}

/// Exact open-path Held–Karp.
fn held_karp(topo: &dyn Topology, src: NodeId, dests: &[NodeId]) -> Vec<NodeId> {
    let n = dests.len();
    let full: usize = (1 << n) - 1;
    // dp[mask][i] = min cost of starting at src, visiting mask, ending at i.
    let mut dp = vec![vec![u32::MAX; n]; 1 << n];
    let mut parent = vec![vec![usize::MAX; n]; 1 << n];
    for i in 0..n {
        dp[1 << i][i] = dist(topo, src, dests[i]);
    }
    for mask in 1..=full {
        for last in 0..n {
            if mask & (1 << last) == 0 || dp[mask][last] == u32::MAX {
                continue;
            }
            let base = dp[mask][last];
            for next in 0..n {
                if mask & (1 << next) != 0 {
                    continue;
                }
                let nm = mask | (1 << next);
                let cost = base + dist(topo, dests[last], dests[next]);
                if cost < dp[nm][next] {
                    dp[nm][next] = cost;
                    parent[nm][next] = last;
                }
            }
        }
    }
    // Best endpoint, then walk parents back.
    let end = (0..n).min_by_key(|&i| dp[full][i]).unwrap();
    let mut order = vec![0usize; n];
    let (mut mask, mut cur) = (full, end);
    for slot in (0..n).rev() {
        order[slot] = cur;
        let p = parent[mask][cur];
        mask &= !(1 << cur);
        if p == usize::MAX {
            break;
        }
        cur = p;
    }
    order.into_iter().map(|i| dests[i]).collect()
}

/// Nearest-neighbour construction.
fn nearest_neighbour(topo: &dyn Topology, src: NodeId, dests: &[NodeId]) -> Vec<NodeId> {
    let mut remaining = dests.to_vec();
    let mut order = Vec::with_capacity(dests.len());
    let mut cur = src;
    while !remaining.is_empty() {
        let (idx, _) = remaining
            .iter()
            .enumerate()
            .min_by_key(|(_, &d)| (dist(topo, cur, d), d))
            .unwrap();
        cur = remaining.swap_remove(idx);
        order.push(cur);
    }
    order
}

/// 2-opt refinement for the open path src -> order[..]. Reversing the
/// segment (i..=j) changes cost by the two boundary edges only.
fn two_opt(topo: &dyn Topology, src: NodeId, mut order: Vec<NodeId>) -> Vec<NodeId> {
    let n = order.len();
    if n < 3 {
        return order;
    }
    let node_at = |order: &[NodeId], i: isize| -> NodeId {
        if i < 0 {
            src
        } else {
            order[i as usize]
        }
    };
    let mut improved = true;
    while improved {
        improved = false;
        for i in 0..n - 1 {
            for j in i + 1..n {
                // Edges (i-1 -> i) and (j -> j+1); j+1 may not exist (open path).
                let a = node_at(&order, i as isize - 1);
                let b = order[i];
                let c = order[j];
                let before = dist(topo, a, b)
                    + if j + 1 < n { dist(topo, c, order[j + 1]) } else { 0 };
                let after = dist(topo, a, c)
                    + if j + 1 < n { dist(topo, b, order[j + 1]) } else { 0 };
                if after < before {
                    order[i..=j].reverse();
                    improved = true;
                }
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{Mesh, Torus};
    use crate::sched::hops::chain_hops;

    #[test]
    fn exact_matches_brute_force_small() {
        let m = Mesh::new(5, 5);
        let dests: Vec<NodeId> = [7, 18, 3, 22, 11].map(NodeId).to_vec();
        let got = chain_hops(&m, NodeId(0), &tsp_order(&m, NodeId(0), &dests));
        // Brute force all 120 permutations.
        let best = permutations(&dests)
            .into_iter()
            .map(|p| chain_hops(&m, NodeId(0), &p))
            .min()
            .unwrap();
        assert_eq!(got, best);
    }

    #[test]
    fn tsp_never_worse_than_greedy_or_naive() {
        let m = Mesh::new(8, 8);
        let mut rng = crate::util::rng(7, crate::util::stream::WORKLOAD);
        for _ in 0..30 {
            let set: Vec<NodeId> = rng
                .sample_distinct(63, 10)
                .into_iter()
                .map(|v| NodeId(v + 1))
                .collect();
            let t = chain_hops(&m, NodeId(0), &tsp_order(&m, NodeId(0), &set));
            let g = chain_hops(
                &m,
                NodeId(0),
                &crate::sched::greedy_order(&m, NodeId(0), &set),
            );
            let nv = chain_hops(&m, NodeId(0), &crate::sched::naive_order(&set));
            assert!(t <= g, "tsp {t} > greedy {g}");
            assert!(t <= nv, "tsp {t} > naive {nv}");
        }
    }

    #[test]
    fn heuristic_path_reasonable_at_63_dests() {
        // Full 8x8 mesh minus the source: a Hamiltonian path of 63 hops
        // exists (boustrophedon). NN+2-opt must get within 15%.
        let m = Mesh::new(8, 8);
        let dests: Vec<NodeId> = (1..64).map(NodeId).collect();
        let order = tsp_order(&m, NodeId(0), &dests);
        assert_eq!(order.len(), 63);
        let hops = chain_hops(&m, NodeId(0), &order);
        assert!(hops >= 63);
        assert!(hops <= 72, "heuristic too weak: {hops} hops for 63 dests");
    }

    #[test]
    fn two_opt_fixes_a_crossing() {
        let m = Mesh::new(8, 1);
        // Deliberately bad order on a line: 0 -> 6 -> 1 -> 7 (cost 6+5+6=17).
        let fixed = two_opt(&m, NodeId(0), vec![NodeId(6), NodeId(1), NodeId(7)]);
        assert_eq!(chain_hops(&m, NodeId(0), &fixed), 7); // 1 -> 6 -> 7
    }

    #[test]
    fn tsp_keeps_duplicate_destinations() {
        // Both solver paths index destinations by position (duplicate
        // copies sit at distance 0), so multiplicity must survive —
        // matching naive/greedy multiset semantics.
        let m = Mesh::new(4, 4);
        let small: Vec<NodeId> = [5, 2, 5, 2].map(NodeId).to_vec();
        let mut o = tsp_order(&m, NodeId(0), &small);
        o.sort();
        assert_eq!(o, [2, 2, 5, 5].map(NodeId).to_vec());
        // Force the NN + 2-opt path (> EXACT_LIMIT) with duplicates.
        let mut big: Vec<NodeId> = (1..=12).map(NodeId).collect();
        big.extend((1..=12).map(NodeId));
        let mut o = tsp_order(&m, NodeId(0), &big);
        assert_eq!(o.len(), 24);
        o.sort();
        let mut want = big.clone();
        want.sort();
        assert_eq!(o, want);
    }

    #[test]
    fn handles_trivial_sizes() {
        let m = Mesh::new(4, 4);
        assert!(tsp_order(&m, NodeId(0), &[]).is_empty());
        assert_eq!(tsp_order(&m, NodeId(0), &[NodeId(9)]), vec![NodeId(9)]);
    }

    #[test]
    fn torus_exact_matches_brute_force_and_beats_mesh() {
        let t = Torus::new(5, 5);
        let m = Mesh::new(5, 5);
        let dests: Vec<NodeId> = [24, 4, 20, 13, 7].map(NodeId).to_vec();
        let got = chain_hops(&t, NodeId(0), &tsp_order(&t, NodeId(0), &dests));
        let best = permutations(&dests)
            .into_iter()
            .map(|p| chain_hops(&t, NodeId(0), &p))
            .min()
            .unwrap();
        assert_eq!(got, best);
        // Wrap links can only shorten the optimal chain (corner-heavy set).
        let mesh_best = chain_hops(&m, NodeId(0), &tsp_order(&m, NodeId(0), &dests));
        assert!(got <= mesh_best, "torus {got} > mesh {mesh_best}");
    }

    fn permutations(xs: &[NodeId]) -> Vec<Vec<NodeId>> {
        if xs.len() <= 1 {
            return vec![xs.to_vec()];
        }
        let mut out = vec![];
        for i in 0..xs.len() {
            let mut rest = xs.to_vec();
            let x = rest.remove(i);
            for mut p in permutations(&rest) {
                p.insert(0, x);
                out.push(p);
            }
        }
        out
    }
}

//! Naive and greedy (paper Alg. 1) chain ordering, over any
//! [`Topology`] (the link-overlap test walks the fabric's own routes).

use std::collections::BTreeSet;

use crate::noc::{NodeId, Topology};

/// Chain-sequence strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Visit in cluster-ID order.
    Naive,
    /// Paper Alg. 1: link-disjoint greedy.
    Greedy,
    /// Open-path TSP (OR-Tools in the paper; Held–Karp/2-opt here).
    Tsp,
}

/// Naive ordering: ascending cluster ID (the paper's "simple Chainwrite").
pub fn naive_order(dests: &[NodeId]) -> Vec<NodeId> {
    let mut order = dests.to_vec();
    order.sort();
    order
}

/// Paper Algorithm 1 — Chain Write Greedy Optimization.
///
/// Iteratively extend the chain with the destination whose routed path
/// from the chain tail (a) shares no link with any previously used path
/// and (b) is shortest; fall back to the plain nearest destination when
/// no link-disjoint candidate exists. Link-disjointness keeps the
/// chain's hop-to-hop transfers from serializing on shared fabric links
/// while the stream is pipelined through all destinations.
pub fn greedy_order(topo: &dyn Topology, src: NodeId, dests: &[NodeId]) -> Vec<NodeId> {
    if dests.is_empty() {
        return vec![];
    }
    let mut remaining: Vec<NodeId> = dests.to_vec();
    // Start from the destination closest to the initiator (ties: lowest id,
    // matching the paper's min() over the destination list).
    let start = *remaining
        .iter()
        .min_by_key(|&&d| (topo.distance(src, d), d))
        .unwrap();
    remaining.retain(|&d| d != start);
    let mut order = vec![start];
    let mut used: BTreeSet<(NodeId, NodeId)> = topo.links(src, start).into_iter().collect();

    while !remaining.is_empty() {
        let tail = *order.last().unwrap();
        // Alg.1 line 6 init: any real path is at most `diameter` hops, so
        // diameter + 1 accepts every candidate (on a mesh this matches the
        // original cols + rows bound exactly — both exceed every path).
        let max_hops = topo.diameter() + 1;
        let mut best: Option<(NodeId, usize)> = None;
        for &cand in &remaining {
            // Walk the routed path in place (§Perf: no Vec per candidate)
            // and bail out at the first used link.
            let bound = best.map(|(_, h)| h).unwrap_or(max_hops);
            let mut cur = tail;
            let mut hops = 0usize;
            let mut disjoint = true;
            while cur != cand && hops < bound {
                let d = topo.next_hop(cur, cand);
                let next = topo.neighbour(cur, d).expect("routing left the fabric");
                if used.contains(&(cur, next)) {
                    disjoint = false;
                    break;
                }
                cur = next;
                hops += 1;
            }
            if disjoint && cur == cand && hops < bound {
                best = Some((cand, hops));
            }
        }
        let chosen = match best {
            Some((c, _)) => c,
            // Fallback (Alg.1 line 13): shortest path regardless of overlap.
            None => *remaining
                .iter()
                .min_by_key(|&&c| (topo.distance(tail, c), c))
                .unwrap(),
        };
        for l in topo.links(tail, chosen) {
            used.insert(l);
        }
        order.push(chosen);
        remaining.retain(|&d| d != chosen);
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{Mesh, Ring};
    use crate::sched::hops::chain_hops;

    #[test]
    fn naive_sorts_by_id() {
        let o = naive_order(&[NodeId(9), NodeId(2), NodeId(5)]);
        assert_eq!(o, vec![NodeId(2), NodeId(5), NodeId(9)]);
    }

    #[test]
    fn greedy_is_permutation() {
        let m = Mesh::new(8, 8);
        let dests: Vec<NodeId> = [3, 7, 21, 63, 40, 11].map(NodeId).to_vec();
        let o = greedy_order(&m, NodeId(0), &dests);
        let mut a = o.clone();
        a.sort();
        let mut b = dests.clone();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn greedy_starts_nearest_to_source() {
        let m = Mesh::new(8, 8);
        // 9=(1,1) is 2 hops from 0; others much farther.
        let o = greedy_order(&m, NodeId(0), &[NodeId(63), NodeId(9), NodeId(56)]);
        assert_eq!(o[0], NodeId(9));
    }

    #[test]
    fn greedy_single_destination() {
        let m = Mesh::new(4, 4);
        assert_eq!(greedy_order(&m, NodeId(0), &[NodeId(7)]), vec![NodeId(7)]);
    }

    #[test]
    fn greedy_empty() {
        let m = Mesh::new(4, 4);
        assert!(greedy_order(&m, NodeId(0), &[]).is_empty());
    }

    #[test]
    fn greedy_beats_or_ties_naive_on_random_sets() {
        let m = Mesh::new(8, 8);
        let mut rng = crate::util::rng(42, crate::util::stream::WORKLOAD);
        let mut greedy_wins = 0;
        for _ in 0..50 {
            let mut set = rng.sample_distinct(63, 8);
            set.iter_mut().for_each(|v| *v += 1); // exclude src node 0
            let dests: Vec<NodeId> = set.into_iter().map(NodeId).collect();
            let h_naive = chain_hops(&m, NodeId(0), &naive_order(&dests));
            let h_greedy = chain_hops(&m, NodeId(0), &greedy_order(&m, NodeId(0), &dests));
            if h_greedy < h_naive {
                greedy_wins += 1;
            }
        }
        // Greedy should beat ID-order on the clear majority of random sets.
        assert!(greedy_wins >= 35, "greedy won only {greedy_wins}/50");
    }

    #[test]
    fn greedy_row_chain_is_optimal() {
        // All dests on one row: visiting in x order is optimal and greedy
        // must find it (disjoint eastward links).
        let m = Mesh::new(8, 1);
        let dests: Vec<NodeId> = [4, 1, 6, 2].map(NodeId).to_vec();
        let o = greedy_order(&m, NodeId(0), &dests);
        assert_eq!(o, [1, 2, 4, 6].map(NodeId).to_vec());
        assert_eq!(chain_hops(&m, NodeId(0), &o), 6);
    }

    #[test]
    fn greedy_on_a_ring_chains_around_one_arc() {
        // {1, 2, 3} East of the source on an 8-ring: greedy walks the
        // arc with disjoint links, 1 hop per destination.
        let r = Ring::new(8);
        let dests: Vec<NodeId> = [3, 1, 2].map(NodeId).to_vec();
        let o = greedy_order(&r, NodeId(0), &dests);
        assert_eq!(o, [1, 2, 3].map(NodeId).to_vec());
        assert_eq!(chain_hops(&r, NodeId(0), &o), 3);
    }
}

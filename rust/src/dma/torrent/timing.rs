//! Torrent micro-architectural timing constants.
//!
//! Single calibration point for the protocol-processing delays. Values
//! are chosen so the *measured* per-destination Chainwrite overhead on
//! the paper's evaluation SoC (4×5 mesh, Fig 7 setup) lands at the
//! published ≈82 cycles/destination — the structural model (cfg
//! serialization + grant/finish back-propagation + store-and-forward
//! insertion) provides the linear shape; these constants set the slope.

/// Initiator: descriptor build + issue per follower cfg (serializes the
/// parallel cfg dispatch out of one NI).
pub const CFG_ISSUE_CYCLES: u64 = 6;

/// Follower: cfg frame decode + DSE programming before it can take part
/// in grant propagation.
pub const CFG_DECODE_CYCLES: u64 = 16;

/// Follower: grant generation/forwarding pipeline.
pub const GRANT_PROC_CYCLES: u64 = 26;

/// Follower: finish generation/forwarding pipeline.
pub const FIN_PROC_CYCLES: u64 = 26;

/// Data-switch cut-through insertion delay: a forwarded flit leaves this
/// many cycles after it arrived (duplicator + backend repacketization).
pub const FWD_LATENCY_CYCLES: u64 = 6;

/// Chainwrite data segment size (one AXI-burst-sized packet).
pub const SEG_BYTES: usize = 4096;

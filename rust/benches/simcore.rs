//! Simulator-core micro-benchmarks — the §Perf L3 harness.
//!
//! Measures the hot paths the figure sweeps are built on: raw network
//! tick throughput under load, end-to-end Chainwrite simulation rate, and
//! the schedulers at Fig-6 scale. Run before/after optimizations; the
//! iteration log lives in EXPERIMENTS.md §Perf.
mod common;

use torrent::coordinator::{Coordinator, EngineKind};
use torrent::noc::{Mesh, Message, Network, NodeId, Packet};
use torrent::sched::{self, Strategy};
use torrent::soc::SocConfig;
use torrent::util::rng::Rng;
use torrent::workloads;

fn main() {
    common::banner("simcore: L3 hot-path micro-benchmarks");

    // 1. Saturated 8x8 network: all nodes stream to the opposite corner.
    let s = common::bench("net_8x8_saturated_10k_cycles", 1, 5, || {
        let mesh = Mesh::new(8, 8);
        let mut net = Network::new(mesh);
        for n in 0..64usize {
            let dst = NodeId(63 - n);
            if dst.0 != n {
                net.send(
                    NodeId(n),
                    Packet::new(0, NodeId(n), dst, Message::Raw(n as u64))
                        .with_phantom_payload(16 * 1024),
                );
            }
        }
        for _ in 0..10_000 {
            net.tick();
        }
    });
    let cycles_per_sec = 10_000.0 / (s.mean / 1e3);
    println!("  -> {:.2} M network-cycles/s on a 64-router mesh", cycles_per_sec / 1e6);

    // 2. End-to-end Chainwrite simulation rate (the Fig 5 unit of work).
    common::bench("chainwrite_64kb_8dst_eval4x5", 1, 5, || {
        let mut c = Coordinator::new(SocConfig::eval_4x5());
        let dests: Vec<NodeId> = (1..=8).map(NodeId).collect();
        c.submit_simple(NodeId(0), &dests, 64 * 1024, EngineKind::Torrent(Strategy::Greedy), false);
        c.run_to_completion(10_000_000);
    });

    // 3. Schedulers at the Fig-6 extremes.
    let mesh = Mesh::new(8, 8);
    let sets = workloads::random_dest_sets(&mesh, NodeId(0), 32, 64, 11);
    common::bench("greedy_order_32dst_x64", 1, 10, || {
        for s in &sets {
            let _ = sched::greedy_order(&mesh, NodeId(0), s);
        }
    });
    common::bench("tsp_2opt_32dst_x64", 1, 10, || {
        for s in &sets {
            let _ = sched::tsp_order(&mesh, NodeId(0), s);
        }
    });
    let mut rng = Rng::new(3);
    let mut set15: Vec<NodeId> = Vec::new();
    for v in rng.sample_distinct(63, 15) {
        set15.push(NodeId(v + 1));
    }
    common::bench("tsp_heldkarp_exact_15dst", 1, 5, || {
        let _ = sched::tsp_order(&mesh, NodeId(0), &set15);
    });
}

//! Chain-order visualizer: draws the mesh and the visit order each
//! scheduling strategy produces for a random destination set, with the
//! resulting hop counts (paper §III-D / Fig 6 intuition).
//!
//! Run: `cargo run --release --example chain_visualizer [--n 8] [--seed 7]`

use torrent::noc::{Mesh, NodeId};
use torrent::sched::{self, Strategy};
use torrent::util::cli::Args;
use torrent::workloads;

fn draw(mesh: &Mesh, src: NodeId, order: &[NodeId]) {
    // Mark each destination with its 1-based visit index, the source with S.
    let mut label = vec![String::from(" ."); mesh.n_nodes()];
    label[src.0] = " S".into();
    for (i, n) in order.iter().enumerate() {
        label[n.0] = format!("{:2}", i + 1);
    }
    for y in (0..mesh.rows).rev() {
        let row: Vec<&str> = (0..mesh.cols)
            .map(|x| label[y * mesh.cols + x].as_str())
            .collect();
        println!("    {}", row.join(" "));
    }
}

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 8);
    let seed = args.u64_or("seed", 7);
    let mesh = Mesh::new(8, 8);
    let src = NodeId(0);
    let dests = workloads::random_dest_sets(&mesh, src, n, 1, seed).remove(0);
    println!("mesh 8x8, source = node 0 (bottom-left), {n} random destinations\n");

    for strategy in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp] {
        let order = sched::schedule(strategy, &mesh, src, &dests);
        let hops = sched::chain_hops(&mesh, src, &order);
        println!(
            "{strategy:?}: total {hops} hops, {:.2} hops/dest",
            hops as f64 / n as f64
        );
        draw(&mesh, src, &order);
        println!();
    }
    let uni = sched::unicast_hops(&mesh, src, &dests);
    let mc = torrent::noc::multicast::mcast_tree_hops(&mesh, src, &dests);
    println!("reference: unicast {uni} hops, multicast tree {mc} hops");
}

//! Deterministic PRNG (SplitMix64 core, PCG-style helpers).
//!
//! The `rand` crate is not vendored in this image; experiments need only a
//! fast, seedable, reproducible generator — SplitMix64 passes BigCrush for
//! this use and is 4 lines long. All benches seed explicitly so every
//! figure regenerates identically.

/// SplitMix64 PRNG. Deterministic, seedable, `Copy`-cheap.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. Uses Lemire's multiply-shift reduction.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `0..n` (k <= n), in random order.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        // Partial Fisher–Yates over an index pool.
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            pool.swap(i, j);
        }
        pool.truncate(k);
        pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn sample_distinct_is_distinct_and_sized() {
        let mut r = Rng::new(13);
        for k in 0..=20 {
            let s = r.sample_distinct(20, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {s:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(19);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "skewed: {counts:?}");
        }
    }
}

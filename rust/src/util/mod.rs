//! Small in-repo utilities replacing crates that are unavailable in this
//! offline image (see DESIGN.md §3 toolchain substitutions): a seeded PRNG
//! (`rng`), descriptive statistics + linear regression (`stats`), a CLI
//! argument parser (`cli`), a property-test harness (`prop`), and an ASCII
//! table printer (`table`).

pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

/// Named RNG streams for [`rng`] — one constant per independent random
/// process in the repo. Seed derivation used to be hand-rolled at every
/// call site (`seed ^ (x << 8) ^ ...`), which invites silent stream
/// collisions: an arrival generator and a destination-set draw seeded
/// from the same user seed would replay correlated sequences. Every
/// constant keeps the low 56 bits free, so call sites compose per-trial
/// sub-indices additively (`stream::FAULTS + composed_index`) without
/// crossing into a neighbouring stream.
pub mod stream {
    /// `workloads::random_dest_sets` destination draws.
    pub const DEST_SETS: u64 = 0x01 << 56;
    /// Open-loop serving arrival processes (`serve::ArrivalGen`).
    pub const ARRIVALS: u64 = 0x02 << 56;
    /// Seeded fault schedules (fault sweep, chaos suites).
    pub const FAULTS: u64 = 0x03 << 56;
    /// Payload/tensor content generation.
    pub const PAYLOAD: u64 = 0x04 << 56;
    /// Property-test case derivation (`util::prop::forall`).
    pub const PROP: u64 = 0x05 << 56;
    /// Bench-local draws (destination samples, shuffles).
    pub const BENCH: u64 = 0x06 << 56;
    /// Randomized workload shapes in test suites.
    pub const WORKLOAD: u64 = 0x07 << 56;
    /// Serving workload-mix draws (`serve::WorkloadMix`).
    pub const MIX: u64 = 0x08 << 56;
    /// Scheduler-internal randomized restarts.
    pub const SCHED: u64 = 0x09 << 56;
    /// Retry-backoff jitter in the serving loop (`serve::RetryPolicy`).
    /// Call sites compose `(attempt << 32) + request_id` into the low
    /// bits so every (request, attempt) pair draws an independent value
    /// regardless of processing order.
    pub const RETRY: u64 = 0x0A << 56;
    /// Background-traffic injection in the contention sweep
    /// (`experiments::contention_sweep`). Call sites compose
    /// `(level << 16) + trial` into the low bits so every strategy
    /// replays the identical background schedule per cell.
    pub const CONTENTION: u64 = 0x0B << 56;
}

/// Construct a seeded [`rng::Rng`] on an independent named stream: the
/// single seed-derivation point for every randomized process in the
/// repo (ISSUE 8 satellite). Two calls differing in *either* argument
/// produce decorrelated sequences — `(seed, stream)` is finalized
/// through two rounds of the SplitMix64 mixer, so nearby seeds (`7` vs
/// `8`) or nearby streams land in unrelated regions of the state space,
/// unlike the raw `Rng::new(seed ^ small_constant)` pattern this
/// replaces.
pub fn rng(seed: u64, stream: u64) -> rng::Rng {
    fn mix(mut z: u64) -> u64 {
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
    rng::Rng::new(mix(seed.wrapping_add(mix(
        stream.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0x6A09_E667_F3BC_C909),
    ))))
}

#[cfg(test)]
mod stream_tests {
    use super::*;

    #[test]
    fn same_seed_and_stream_replays() {
        let mut a = rng(42, stream::ARRIVALS);
        let mut b = rng(42, stream::ARRIVALS);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent_for_one_seed() {
        // The correlation failure this helper exists to prevent: one
        // user seed feeding two processes must not replay one sequence.
        let mut a = rng(2025, stream::ARRIVALS);
        let mut b = rng(2025, stream::DEST_SETS);
        let clash = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(clash, 0, "streams collided");
    }

    #[test]
    fn nearby_seeds_decorrelate() {
        let mut a = rng(7, stream::FAULTS);
        let mut b = rng(8, stream::FAULTS);
        let clash = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(clash, 0);
    }

    #[test]
    fn composed_sub_indices_stay_inside_the_stream() {
        // Low 56 bits are sub-index space; composing must not alias the
        // neighbouring stream constant.
        let max_sub = (1u64 << 56) - 1;
        assert_ne!(stream::DEST_SETS + max_sub, stream::ARRIVALS + 0);
        let mut a = rng(1, stream::FAULTS + 3);
        let mut b = rng(1, stream::FAULTS + 4);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

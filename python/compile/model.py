"""L2 — the paper's real-workload compute graph in JAX.

The FPGA evaluation (paper §IV-E, Table II) runs the data movement of
DeepSeek-V3 self-attention layers: Q.K^T (P1/D1), S.V (P2/D2) and the MLA
KV-cache recovery (P3/D3), all feeding the cluster GeMM accelerator. This
module is the accelerator's compute expressed over the L1 Pallas kernels;
`aot.py` lowers each entry point once to HLO text and the Rust coordinator
executes the artifacts through PJRT while the simulator accounts for the
data movement cycles.

Python never runs on the simulation/request path.
"""

import jax.numpy as jnp

from .kernels import decode_matvec, flash_attention, matmul, relayout, softmax


def attention_prefill(q, k, v):
    """Single-head prefill attention: softmax(Q.K^T / sqrt(d)) . V.

    q, k, v: (T, d). Covers workloads P1 (Q.K^T) and P2 (S.V).
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = matmul(q, k.T) * scale
    p = softmax(s)
    return (matmul(p, v),)


def attention_decode(q, k_cache, v_cache):
    """Single-token decode: q (1, d) against caches (T, d). D1 + D2."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = matmul(q, k_cache.T) * scale
    p = softmax(s)
    return (matmul(p, v_cache),)


def kv_recovery(c_kv, w_uk, w_uv):
    """MLA KV recovery (P3/D3): up-project the compressed KV latent."""
    return (matmul(c_kv, w_uk), matmul(c_kv, w_uv))


def gemm_prefill(a, b):
    """Bare accelerator prefill GeMM, exported for the quickstart path."""
    return (matmul(a, b),)


def gemm_decode(x, w):
    """Bare accelerator decode GeMM: batched 1x64 @ 64x16."""
    return (decode_matvec(x, w),)


def relayout_16x8_to_8x8(xb):
    """Table II layout transform MNM16N8 -> MNM8N8 (prefill chain)."""
    return (relayout(xb, 8, 8),)


def relayout_16x8_to_64x16(xb):
    """Table II layout transform MNM16N8 -> MNM64N16 (decode chain)."""
    return (relayout(xb, 64, 16),)


def attention_prefill_flash(q, k, v):
    """Blocked online-softmax attention (never materializes T x T scores).

    Same math as :func:`attention_prefill`; the VMEM-resident variant a
    long-context deployment would ship (DESIGN.md §Hardware-Adaptation).
    """
    return (flash_attention(q, k, v),)

//! Chain-scheduler benchmarks — wall-clock cost of planning, not of the
//! transfers it plans (ISSUE 10).
//!
//! Greedy (the load-blind default) against load-aware ordering plus the
//! k-way partition pass, at the paper's destination-set scales (8, 32
//! and 63 of 64 nodes on an 8×8 mesh), under a saturated-row load view.
//! Each sample plans many independent seeded destination sets, so the
//! numbers amortize the per-call setup and expose the O(n²) leg-score
//! walks the load-aware path adds.
//!
//! CI integration mirrors `serve`: `TORRENT_BENCH_JSON` writes a
//! `torrent-bench-v1` baseline, `TORRENT_BENCH_BASELINE` compares p50s
//! against the committed `BENCH_sched.json` and fails on >2x calibrated
//! regressions.

mod common;

use torrent::noc::{Mesh, NodeId};
use torrent::sched::load::hot_row_view;
use torrent::sched::{greedy_order, load_aware_order, partition_chains};
use torrent::util::stream;

/// Seeded destination sets: `reps` draws of `n_dests` distinct non-source
/// nodes on the 64-node mesh.
fn dest_sets(n_dests: usize, reps: usize) -> Vec<Vec<NodeId>> {
    let mut rng = torrent::util::rng(907, stream::BENCH + n_dests as u64);
    (0..reps)
        .map(|_| {
            let mut pool: Vec<usize> = (1..64).collect();
            let mut set = Vec::with_capacity(n_dests);
            for _ in 0..n_dests {
                let i = rng.below(pool.len() as u64) as usize;
                set.push(NodeId(pool.swap_remove(i)));
            }
            set
        })
        .collect()
}

fn main() {
    common::banner("sched: chain-planning benchmarks (greedy vs load-aware, 8x8)");
    let mesh = Mesh::new(8, 8);
    let src = NodeId(0);
    let hot = hot_row_view(64, 8, 0, 1000);
    let reps = 64;
    let mut results: Vec<(String, f64)> = Vec::new();

    for n_dests in [8usize, 32, 63] {
        let sets = dest_sets(n_dests, reps);

        // Greedy: the load-blind baseline every strategy is measured
        // against ("is load-awareness affordable at dispatch time?").
        let name = format!("sched_greedy_{n_dests}");
        let mut sink = 0usize;
        let s = common::bench(&name, 1, common::iters(20), || {
            for set in &sets {
                sink += greedy_order(&mesh, src, set).len();
            }
        });
        results.push((name, s.p50));

        // Load-aware ordering plus the partition decision — the exact
        // work `Strategy::LoadAware` adds on the dispatch path.
        let name = format!("sched_load_aware_{n_dests}");
        let mut splits = 0usize;
        let s = common::bench(&name, 1, common::iters(20), || {
            splits = 0;
            for set in &sets {
                let order = load_aware_order(&mesh, src, set, &hot);
                let parts = partition_chains(&mesh, src, &order, &hot);
                sink += order.len();
                if parts.len() > 1 {
                    splits += 1;
                }
            }
        });
        println!("  -> {splits}/{reps} sets split under the saturated row");
        results.push((name, s.p50));
        assert!(sink > 0, "planner output must be consumed");
    }

    // Baseline plumbing (see Makefile `bench-baseline` / `contention-smoke`).
    if let Ok(path) = std::env::var("TORRENT_BENCH_JSON") {
        let calibrated = std::env::var("TORRENT_BENCH_CALIBRATED").is_ok();
        let note = if calibrated {
            "calibrated from a real run via `make bench-baseline`"
        } else {
            "placeholder written without calibration; run `make bench-baseline`"
        };
        common::write_bench_json(&path, "sched", calibrated, note, &results)
            .expect("write bench JSON");
        println!("wrote baseline {path} (calibrated={calibrated})");
    }
    if let Ok(path) = std::env::var("TORRENT_BENCH_BASELINE") {
        common::banner("sched: baseline comparison");
        match common::read_bench_json(&path) {
            Err(e) => {
                eprintln!("baseline unavailable: {e}");
                std::process::exit(1);
            }
            Ok(base) => {
                let regressions = common::count_regressions(&results, &base);
                if regressions > 0 {
                    eprintln!("{regressions} bench regression(s) vs {path}");
                    std::process::exit(1);
                }
            }
        }
    }
}

//! Admission control for the open-loop serving driver (ISSUE 8).
//!
//! The controller bounds *admitted-but-incomplete requests* (`inflight`)
//! — engine-level tasks may be fewer after batching coalesces requests —
//! and decides what happens to an arrival once the bound is hit, by
//! policy:
//!
//! * [`AdmissionPolicy::Shed`] — reject immediately (load shedding; the
//!   client retries elsewhere). Latency stays flat, goodput saturates.
//! * [`AdmissionPolicy::Queue`] — hold up to `queue_cap` requests in a
//!   bounded FIFO, reject the overflow. The classic serving shape:
//!   latency climbs with occupancy until the queue fills, then rejects.
//! * [`AdmissionPolicy::Backpressure`] — unbounded FIFO, never reject.
//!   Past saturation the queue grows without bound and tail latency
//!   diverges — the congestion-collapse curve the sweep must expose.
//!
//! Queued requests keep their original arrival cycle, so queue wait is
//! inside the reported latency (that is the point of the comparison).
//!
//! [`RetryPolicy`] (ISSUE 9) layers client-side retry on top: a request
//! rejected at the door or whose engine task failed is re-offered after
//! a bounded exponential backoff instead of terminating, until its
//! attempt budget runs out. The policy only computes the deterministic
//! part of the delay; the driver adds seeded jitter so colliding
//! retries decorrelate without breaking replay.

use std::collections::VecDeque;

/// What the controller decided about one arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Admitted now; the caller dispatches it.
    Admit,
    /// Held in the pending queue; released by [`Admission::release`].
    Enqueue,
    /// Dropped with the given typed reason.
    Reject(RejectKind),
}

/// Why an arrival was dropped — stable snake_case forms for reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectKind {
    /// Shed at the door: the inflight bound was hit under `Shed`.
    Shed,
    /// The bounded pending queue overflowed under `Queue`.
    QueueFull,
}

impl RejectKind {
    pub fn as_str(self) -> &'static str {
        match self {
            RejectKind::Shed => "shed",
            RejectKind::QueueFull => "queue_full",
        }
    }
}

/// The admission policy knob (CLI: `--policy shed|queue|backpressure`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    Shed,
    #[default]
    Queue,
    Backpressure,
}

impl AdmissionPolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            AdmissionPolicy::Shed => "shed",
            AdmissionPolicy::Queue => "queue",
            AdmissionPolicy::Backpressure => "backpressure",
        }
    }

    pub fn parse(s: &str) -> Result<AdmissionPolicy, String> {
        match s {
            "shed" => Ok(AdmissionPolicy::Shed),
            "queue" => Ok(AdmissionPolicy::Queue),
            "backpressure" => Ok(AdmissionPolicy::Backpressure),
            _ => Err(format!("unknown admission policy '{s}' (shed|queue|backpressure)")),
        }
    }
}

/// Bounded-retry policy for rejected or failed requests
/// (CLI: `--retries N`; `max_attempts = 0` disables retry entirely and
/// keeps the ISSUE-8 terminal semantics bit-for-bit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries per request beyond the first attempt; 0 = off.
    pub max_attempts: u32,
    /// Backoff before retry 1, in cycles; doubles per attempt.
    pub base_backoff: u64,
    /// Backoff ceiling in cycles (the exponential clamps here).
    pub max_backoff: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 0, base_backoff: 256, max_backoff: 4096 }
    }
}

impl RetryPolicy {
    pub fn enabled(&self) -> bool {
        self.max_attempts > 0
    }

    /// Deterministic backoff (pre-jitter) for the 1-based retry
    /// `attempt`: `base_backoff * 2^(attempt-1)`, clamped to
    /// `max_backoff`. Saturates instead of overflowing on absurd
    /// attempt counts.
    pub fn backoff_for(&self, attempt: u32) -> u64 {
        let exp = attempt.saturating_sub(1).min(63);
        let scaled = if exp >= 63 {
            u64::MAX
        } else {
            self.base_backoff.saturating_mul(1u64 << exp)
        };
        scaled.min(self.max_backoff)
    }
}

/// The admission controller. Tracks only request ids, so it can be
/// unit-tested without the full driver.
#[derive(Debug)]
pub struct Admission {
    policy: AdmissionPolicy,
    max_inflight: usize,
    queue_cap: usize,
    inflight: usize,
    pending: VecDeque<u32>,
}

impl Admission {
    pub fn new(policy: AdmissionPolicy, max_inflight: usize, queue_cap: usize) -> Self {
        assert!(max_inflight > 0, "max_inflight must be > 0");
        Admission { policy, max_inflight, queue_cap, inflight: 0, pending: VecDeque::new() }
    }

    /// Admitted-but-incomplete requests.
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    /// Requests waiting in the pending queue.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Decide one arrival. On [`Verdict::Admit`] the inflight slot is
    /// already taken; on [`Verdict::Enqueue`] the id is parked.
    pub fn offer(&mut self, req: u32) -> Verdict {
        if self.inflight < self.max_inflight && self.pending.is_empty() {
            self.inflight += 1;
            return Verdict::Admit;
        }
        match self.policy {
            AdmissionPolicy::Shed => Verdict::Reject(RejectKind::Shed),
            AdmissionPolicy::Queue => {
                if self.pending.len() < self.queue_cap {
                    self.pending.push_back(req);
                    Verdict::Enqueue
                } else {
                    Verdict::Reject(RejectKind::QueueFull)
                }
            }
            AdmissionPolicy::Backpressure => {
                self.pending.push_back(req);
                Verdict::Enqueue
            }
        }
    }

    /// Release queued requests into freed inflight slots (FIFO). Call
    /// after completions; returns the ids to dispatch now.
    pub fn pump(&mut self) -> Vec<u32> {
        let mut released = Vec::new();
        while self.inflight < self.max_inflight {
            match self.pending.pop_front() {
                Some(req) => {
                    self.inflight += 1;
                    released.push(req);
                }
                None => break,
            }
        }
        released
    }

    /// One admitted request finished (completed or failed): free its slot.
    pub fn release(&mut self) {
        debug_assert!(self.inflight > 0, "release without a matching admit");
        self.inflight = self.inflight.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shed_rejects_once_full() {
        let mut a = Admission::new(AdmissionPolicy::Shed, 2, 0);
        assert_eq!(a.offer(1), Verdict::Admit);
        assert_eq!(a.offer(2), Verdict::Admit);
        assert_eq!(a.offer(3), Verdict::Reject(RejectKind::Shed));
        a.release();
        assert_eq!(a.offer(4), Verdict::Admit);
        assert_eq!(a.inflight(), 2);
    }

    #[test]
    fn queue_holds_then_overflows() {
        let mut a = Admission::new(AdmissionPolicy::Queue, 1, 2);
        assert_eq!(a.offer(1), Verdict::Admit);
        assert_eq!(a.offer(2), Verdict::Enqueue);
        assert_eq!(a.offer(3), Verdict::Enqueue);
        assert_eq!(a.offer(4), Verdict::Reject(RejectKind::QueueFull));
        assert_eq!(a.pending(), 2);
        a.release();
        // FIFO: the oldest queued request gets the freed slot.
        assert_eq!(a.pump(), vec![2]);
        assert_eq!(a.pending(), 1);
    }

    #[test]
    fn backpressure_never_rejects() {
        let mut a = Admission::new(AdmissionPolicy::Backpressure, 1, 0);
        assert_eq!(a.offer(1), Verdict::Admit);
        for req in 2..100 {
            assert_eq!(a.offer(req), Verdict::Enqueue);
        }
        assert_eq!(a.pending(), 98);
        a.release();
        assert_eq!(a.pump(), vec![2]);
    }

    #[test]
    fn arrivals_behind_a_queue_do_not_jump_it() {
        // Even with a free slot, an arrival may not overtake queued
        // requests: FIFO order is part of the latency semantics.
        let mut a = Admission::new(AdmissionPolicy::Queue, 1, 4);
        assert_eq!(a.offer(1), Verdict::Admit);
        assert_eq!(a.offer(2), Verdict::Enqueue);
        a.release();
        // Slot free but 2 still queued: 3 must queue behind it.
        assert_eq!(a.offer(3), Verdict::Enqueue);
        assert_eq!(a.pump(), vec![2]);
        assert_eq!(a.pump(), Vec::<u32>::new());
    }

    #[test]
    fn backoff_doubles_then_clamps() {
        let p = RetryPolicy { max_attempts: 5, base_backoff: 100, max_backoff: 1000 };
        assert!(p.enabled());
        assert_eq!(p.backoff_for(1), 100);
        assert_eq!(p.backoff_for(2), 200);
        assert_eq!(p.backoff_for(3), 400);
        assert_eq!(p.backoff_for(4), 800);
        assert_eq!(p.backoff_for(5), 1000, "clamped to max_backoff");
        assert_eq!(p.backoff_for(100), 1000, "huge attempts saturate, not overflow");
    }

    #[test]
    fn default_retry_policy_is_disabled() {
        assert!(!RetryPolicy::default().enabled());
    }

    #[test]
    fn policy_strings_are_stable() {
        for p in
            [AdmissionPolicy::Shed, AdmissionPolicy::Queue, AdmissionPolicy::Backpressure]
        {
            assert_eq!(AdmissionPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(AdmissionPolicy::parse("fifo").is_err());
        assert_eq!(RejectKind::Shed.as_str(), "shed");
        assert_eq!(RejectKind::QueueFull.as_str(), "queue_full");
    }
}

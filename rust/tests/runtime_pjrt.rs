//! Runtime round-trip tests: the AOT artifacts must load and compute
//! correct numbers from Rust (kernel-vs-oracle at the Rust boundary —
//! the same check pytest does inside Python). They run against
//! whichever backend the build selected: the pure-Rust reference
//! engine by default, XLA PJRT with `--features pjrt` (DESIGN.md §5).
//!
//! `artifacts/manifest.txt` is committed, so the default build runs
//! these for real; tests skip (with a loud message) if the manifest is
//! missing so `cargo test` stays runnable after `make clean`.

use torrent::runtime::{Engine, Tensor};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP runtime_pjrt: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Engine::load("artifacts").expect("load artifacts"))
}

fn matmul_oracle(a: &Tensor, b: &Tensor) -> Vec<f32> {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    assert_eq!(k, b.shape[0]);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for e in 0..k {
                acc += a.data[i * k + e] as f64 * b.data[e * n + j] as f64;
            }
            out[i * n + j] = acc as f32;
        }
    }
    out
}

fn allclose(a: &[f32], b: &[f32], atol: f32) {
    assert_eq!(a.len(), b.len());
    let worst = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(worst <= atol, "max abs err {worst} > {atol}");
}

#[test]
fn manifest_lists_all_entry_points() {
    let Some(e) = engine() else { return };
    let names = e.names();
    for want in [
        "attn_prefill",
        "attn_decode",
        "kv_recovery",
        "gemm_prefill",
        "gemm_decode",
        "relayout_16x8_to_8x8",
    ] {
        assert!(names.contains(&want), "missing artifact {want}: {names:?}");
    }
}

#[test]
fn gemm_prefill_matches_rust_oracle() {
    let Some(e) = engine() else { return };
    let spec = e.entry("gemm_prefill").unwrap().clone();
    let a = Tensor::random(spec.inputs[0].dims.clone(), 11);
    let b = Tensor::random(spec.inputs[1].dims.clone(), 12);
    let out = e.run("gemm_prefill", &[a.clone(), b.clone()]).unwrap();
    allclose(&out[0].data, &matmul_oracle(&a, &b), 1e-3);
}

#[test]
fn gemm_decode_matches_rust_oracle() {
    let Some(e) = engine() else { return };
    let spec = e.entry("gemm_decode").unwrap().clone();
    let x = Tensor::random(spec.inputs[0].dims.clone(), 13);
    let w = Tensor::random(spec.inputs[1].dims.clone(), 14);
    let out = e.run("gemm_decode", &[x.clone(), w.clone()]).unwrap();
    allclose(&out[0].data, &matmul_oracle(&x, &w), 1e-3);
}

#[test]
fn kv_recovery_outputs_two_projections() {
    let Some(e) = engine() else { return };
    let spec = e.entry("kv_recovery").unwrap().clone();
    let c = Tensor::random(spec.inputs[0].dims.clone(), 15);
    let wk = Tensor::random(spec.inputs[1].dims.clone(), 16);
    let wv = Tensor::random(spec.inputs[2].dims.clone(), 17);
    let out = e.run("kv_recovery", &[c.clone(), wk.clone(), wv.clone()]).unwrap();
    assert_eq!(out.len(), 2);
    allclose(&out[0].data, &matmul_oracle(&c, &wk), 1e-3);
    allclose(&out[1].data, &matmul_oracle(&c, &wv), 1e-3);
}

#[test]
fn attention_rows_are_convex_combinations() {
    let Some(e) = engine() else { return };
    let spec = e.entry("attn_prefill").unwrap().clone();
    let q = Tensor::random(spec.inputs[0].dims.clone(), 18);
    let k = Tensor::random(spec.inputs[1].dims.clone(), 19);
    let v = Tensor::random(spec.inputs[2].dims.clone(), 20);
    let out = &e.run("attn_prefill", &[q, k, v.clone()]).unwrap()[0];
    // Every output element lies within the min/max of V's column.
    let (t, d) = (v.shape[0], v.shape[1]);
    for col in 0..d {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for row in 0..t {
            lo = lo.min(v.data[row * d + col]);
            hi = hi.max(v.data[row * d + col]);
        }
        for row in 0..out.shape[0] {
            let x = out.data[row * d + col];
            assert!(x >= lo - 1e-4 && x <= hi + 1e-4, "out[{row},{col}]={x} outside [{lo},{hi}]");
        }
    }
}

#[test]
fn attn_decode_is_deterministic() {
    let Some(e) = engine() else { return };
    let spec = e.entry("attn_decode").unwrap().clone();
    let ins: Vec<Tensor> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, s)| Tensor::random(s.dims.clone(), 21 + i as u64))
        .collect();
    let a = e.run("attn_decode", &ins).unwrap();
    let b = e.run("attn_decode", &ins).unwrap();
    assert_eq!(a[0].data, b[0].data);
}

#[test]
fn relayout_artifact_is_a_permutation() {
    let Some(e) = engine() else { return };
    let spec = e.entry("relayout_16x8_to_8x8").unwrap().clone();
    let x = Tensor::random(spec.inputs[0].dims.clone(), 23);
    let out = &e.run("relayout_16x8_to_8x8", &[x.clone()]).unwrap()[0];
    // Same multiset of values.
    let mut a = x.data.clone();
    let mut b = out.data.clone();
    a.sort_by(f32::total_cmp);
    b.sort_by(f32::total_cmp);
    assert_eq!(a, b, "relayout changed values, not just positions");
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(e) = engine() else { return };
    let bad = Tensor::zeros(vec![2, 2]);
    assert!(e.run("gemm_prefill", &[bad.clone(), bad]).is_err());
    assert!(e.run("nonexistent", &[]).is_err());
}

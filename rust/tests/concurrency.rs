//! Task-service concurrency: many in-flight P2MP tasks across mixed
//! engines, dependency DAGs, and step-mode equivalence.
//!
//! The contract under test (coordinator redesign): (a) every submitted
//! task completes under `run_until_all_done`, (b) per-task timings under
//! `StepMode::EventDriven` are bit-identical to `StepMode::FullTick`
//! even with concurrent tasks and dependency releases interleaving with
//! the stepper, and (c) a task never finishes before its dependencies.

use torrent::coordinator::{
    Coordinator, EngineKind, P2mpRequest, TaskHandle, TaskStatus,
};
use torrent::noc::NodeId;
use torrent::sched::Strategy;
use torrent::sim::StepMode;
use torrent::soc::SocConfig;
use torrent::util::prop::{check, forall};
use torrent::util::rng::Rng;

const N_NODES: usize = 16; // 4x4 mesh
const FREE_TASKS: usize = 8; // dependency-free prefix => ≥8 in flight

#[derive(Debug, Clone)]
struct TaskDesc {
    src: usize,
    dests: Vec<usize>,
    bytes: usize,
    engine_idx: u8,
    /// Indices of earlier tasks this one waits on.
    deps: Vec<usize>,
}

fn engine_of(idx: u8) -> EngineKind {
    match idx {
        0 => EngineKind::Torrent(Strategy::Naive),
        1 => EngineKind::Torrent(Strategy::Greedy),
        2 => EngineKind::Torrent(Strategy::Tsp),
        3 => EngineKind::Idma,
        4 => EngineKind::Xdma,
        _ => EngineKind::Mcast,
    }
}

/// 8 independent tasks plus up to 4 dependent ones, random sources,
/// engines, destination sets and transfer sizes.
fn gen_workload(rng: &mut Rng) -> Vec<TaskDesc> {
    let n_tasks = FREE_TASKS + rng.index(5);
    (0..n_tasks)
        .map(|i| {
            let src = rng.index(N_NODES);
            let n_dst = 1 + rng.index(3);
            // Distinct destinations excluding the source.
            let dests: Vec<usize> = rng
                .sample_distinct(N_NODES - 1, n_dst)
                .into_iter()
                .map(|v| if v >= src { v + 1 } else { v })
                .collect();
            let bytes = 256 + rng.index(4 * 1024);
            let engine_idx = rng.index(6) as u8;
            let mut deps = Vec::new();
            if i >= FREE_TASKS {
                for _ in 0..1 + rng.index(2) {
                    let k = rng.index(i);
                    if !deps.contains(&k) {
                        deps.push(k);
                    }
                }
            }
            TaskDesc { src, dests, bytes, engine_idx, deps }
        })
        .collect()
}

/// Submit the workload, drive it to completion, and return per-task
/// (submitted_at, finished_at) pairs.
fn run(descs: &[TaskDesc], mode: StepMode) -> Result<Vec<(u64, u64)>, String> {
    let mut c = Coordinator::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
    let mut handles: Vec<TaskHandle> = Vec::new();
    for (i, d) in descs.iter().enumerate() {
        let deps: Vec<TaskHandle> = d.deps.iter().map(|&k| handles[k]).collect();
        let dests: Vec<NodeId> = d.dests.iter().map(|&n| NodeId(n)).collect();
        let h = c
            .submit(
                P2mpRequest::to(&dests)
                    .src(NodeId(d.src))
                    .bytes(d.bytes)
                    .engine(engine_of(d.engine_idx))
                    .after(&deps),
            )
            .map_err(|e| format!("task {i} rejected: {e}"))?;
        handles.push(h);
    }
    // The dependency-free prefix must already be in flight.
    let in_flight =
        handles.iter().filter(|h| h.status(&c) != TaskStatus::Queued).count();
    check(
        in_flight >= FREE_TASKS,
        format!("only {in_flight} of {} tasks in flight after submission", descs.len()),
    )?;
    // Dependent tasks must be admission-queued, not dispatched.
    for (i, d) in descs.iter().enumerate() {
        if !d.deps.is_empty() {
            check(
                handles[i].status(&c) == TaskStatus::Queued,
                format!("dependent task {i} dispatched before its deps completed"),
            )?;
        }
    }
    c.run_until_all_done(50_000_000);
    let mut timings = Vec::new();
    for (i, h) in handles.iter().enumerate() {
        check(h.status(&c) == TaskStatus::Done, format!("task {i} incomplete"))?;
        let res = c.record(*h).unwrap().result.clone().unwrap();
        for &k in &descs[i].deps {
            let dep = c.record(handles[k]).unwrap().result.as_ref().unwrap().finished_at;
            check(
                dep < res.finished_at && dep < res.submitted_at,
                format!(
                    "task {i} ran [{}, {}] but dep {k} finished at {dep}",
                    res.submitted_at, res.finished_at
                ),
            )?;
        }
        timings.push((res.submitted_at, res.finished_at));
    }
    // The quiescence drain must still converge afterwards.
    c.run_to_completion(50_000_000);
    Ok(timings)
}

/// The tentpole property: seeded random ≥8-task mixed-engine workloads
/// with dependency edges complete under both steppers with identical
/// per-task submission and completion cycles.
#[test]
fn prop_concurrent_dag_workloads_complete_identically_across_steppers() {
    forall(0xC0C0, 12, gen_workload, |descs| {
        let full = run(descs, StepMode::FullTick)?;
        let fast = run(descs, StepMode::EventDriven)?;
        check(
            full == fast,
            format!(
                "per-task timings diverged between steppers:\n  full: {full:?}\n  fast: {fast:?}"
            ),
        )
    });
}

/// Deterministic smoke: one task per engine flavour, all submitted
/// up-front from distinct initiators, genuinely overlapping in time.
#[test]
fn eight_concurrent_tasks_across_all_engines_overlap() {
    let mut c = Coordinator::new(SocConfig::custom(4, 4, 64 * 1024));
    let mut handles = Vec::new();
    for (i, engine_idx) in (0..8u8).enumerate() {
        let src = 2 * i; // 0, 2, .., 14
        let dest = src + 1;
        let h = c
            .submit_simple(
                NodeId(src),
                &[NodeId(dest)],
                2 * 1024,
                engine_of(engine_idx % 6),
                false,
            )
            .unwrap();
        handles.push(h);
    }
    assert_eq!(c.open_tasks(), 8);
    c.run_until_all_done(5_000_000);
    let spans: Vec<(u64, u64)> = handles
        .iter()
        .map(|h| {
            let r = c.record(*h).unwrap().result.as_ref().unwrap();
            (r.submitted_at, r.finished_at)
        })
        .collect();
    // All submitted at cycle 0 and none instantaneous: every pair overlaps.
    for (i, &(s, f)) in spans.iter().enumerate() {
        assert_eq!(s, 0, "task {i} was not admitted immediately");
        assert!(f > 0, "task {i} has no duration");
    }
}

/// A three-stage chain through `run_until_complete`: each stage becomes
/// dispatchable only when the previous one finishes, and the
/// intermediate run modes expose the expected statuses.
#[test]
fn dependency_chain_runs_stage_by_stage() {
    let mut c = Coordinator::new(SocConfig::custom(3, 3, 64 * 1024));
    let chain = EngineKind::Torrent(Strategy::Greedy);
    let a = c.submit_simple(NodeId(0), &[NodeId(1)], 4 * 1024, chain, false).unwrap();
    let b = c
        .submit(
            P2mpRequest::to(&[NodeId(2)])
                .src(NodeId(1))
                .bytes(4 * 1024)
                .engine(EngineKind::Idma)
                .after(&[a]),
        )
        .unwrap();
    let d = c
        .submit(
            P2mpRequest::to(&[NodeId(5)])
                .src(NodeId(2))
                .bytes(4 * 1024)
                .engine(EngineKind::Xdma)
                .after(&[b]),
        )
        .unwrap();
    assert_eq!(b.status(&c), TaskStatus::Queued);
    assert_eq!(d.status(&c), TaskStatus::Queued);
    let lat_a = c.run_until_complete(a, 1_000_000);
    assert!(lat_a > 0);
    assert_eq!(a.status(&c), TaskStatus::Done);
    // b is released (dispatched) the moment a's completion is observed.
    assert_ne!(b.status(&c), TaskStatus::Queued);
    assert_eq!(d.status(&c), TaskStatus::Queued, "transitive dep released early");
    c.run_until_all_done(2_000_000);
    let fin = |h: TaskHandle| c.record(h).unwrap().result.as_ref().unwrap().finished_at;
    assert!(fin(a) < fin(b) && fin(b) < fin(d), "stage order violated");
}

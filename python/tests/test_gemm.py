"""Pallas GeMM kernels vs the pure-jnp oracle — the core L1 signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_matvec, matmul, matmul_int8, ref

# K-blocked accumulation reorders float adds vs the oracle's single dot.
RTOL, ATOL = 1e-3, 1e-4


def _rand(shape, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    if dtype == jnp.int8:
        return jax.random.randint(k, shape, -128, 127, jnp.int32).astype(jnp.int8)
    return jax.random.normal(k, shape, dtype)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (16, 8, 8),  # one accelerator prefill tile
        (64, 64, 64),  # one TPU block
        (128, 192, 128),  # prefill head slice (paper P1 geometry / 16)
        (256, 64, 128),
        (32, 8, 16),  # non-square, small K
        (17, 13, 5),  # prime sizes force degenerate 1-wide blocks
    ],
)
def test_matmul_shapes(m, k, n):
    a, b = _rand((m, k), seed=1), _rand((k, n), seed=2)
    np.testing.assert_allclose(matmul(a, b), ref.matmul(a, b), rtol=RTOL, atol=ATOL)


def test_matmul_block_sweep():
    a, b = _rand((128, 96), seed=3), _rand((96, 64), seed=4)
    want = ref.matmul(a, b)
    for bm, bk, bn in [(16, 8, 8), (32, 32, 32), (128, 96, 64), (64, 48, 16)]:
        got = matmul(a, b, bm=bm, bk=bk, bn=bn)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL, err_msg=f"{bm},{bk},{bn}")


def test_matmul_identity():
    a = _rand((64, 64), seed=5)
    np.testing.assert_allclose(matmul(a, jnp.eye(64)), a, rtol=RTOL)


def test_matmul_zeros():
    a = _rand((32, 16), seed=6)
    assert jnp.all(matmul(a, jnp.zeros((16, 8))) == 0.0)


@pytest.mark.parametrize("m,k,n", [(16, 8, 8), (64, 64, 64), (48, 24, 40)])
def test_matmul_int8_exact(m, k, n):
    a, b = _rand((m, k), jnp.int8, seed=7), _rand((k, n), jnp.int8, seed=8)
    got = matmul_int8(a, b)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.matmul(a, b)))


def test_matmul_int8_saturating_inputs():
    # Extremes: full-scale +/- int8 values must accumulate exactly in int32.
    a = jnp.full((16, 64), -128, jnp.int8)
    b = jnp.full((64, 16), 127, jnp.int8)
    got = matmul_int8(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.full((16, 16), -128 * 127 * 64))


@pytest.mark.parametrize("batch", [1, 16, 64, 200])
def test_decode_matvec(batch):
    x, w = _rand((batch, 64), seed=9), _rand((64, 16), seed=10)
    np.testing.assert_allclose(decode_matvec(x, w), ref.matmul(x, w), rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 12).map(lambda v: v * 8),
    k=st.integers(1, 12).map(lambda v: v * 8),
    n=st.integers(1, 12).map(lambda v: v * 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_f32(m, k, n, seed):
    """Hypothesis sweep: tile-aligned shapes, arbitrary seeds."""
    a, b = _rand((m, k), seed=seed), _rand((k, n), seed=seed + 1)
    np.testing.assert_allclose(matmul(a, b), ref.matmul(a, b), rtol=1e-3, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_ragged(m, k, n, seed):
    """Non-aligned shapes must still be exact (block fallback path)."""
    a, b = _rand((m, k), seed=seed), _rand((k, n), seed=seed + 1)
    np.testing.assert_allclose(matmul(a, b), ref.matmul(a, b), rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 8).map(lambda v: v * 16),
    k=st.integers(1, 8).map(lambda v: v * 8),
    n=st.integers(1, 8).map(lambda v: v * 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_int8_hypothesis(m, k, n, seed):
    a = _rand((m, k), jnp.int8, seed=seed)
    b = _rand((k, n), jnp.int8, seed=seed + 1)
    np.testing.assert_array_equal(
        np.asarray(matmul_int8(a, b)), np.asarray(ref.matmul(a, b))
    )

# Convenience targets; the source of truth is Cargo.toml (Rust) and
# python/compile/aot.py (artifacts).

.PHONY: all build test tier1 artifacts figures bench-smoke bench-baseline \
	bench-scaling examples-smoke doc clean topo-sweep topo-matrix \
	golden-bless fault-sweep fault-matrix serve-sim serve-smoke \
	resilience-sweep resilience-smoke contention-sweep contention-smoke

all: tier1

build:
	cargo build --release

test:
	cargo test -q

# The repo's tier-1 verification gate (ROADMAP.md).
tier1:
	cargo build --release && cargo test -q

# AOT-lower the JAX/Pallas entry points to HLO text + manifest.txt.
# Requires JAX; the Rust side runs without it (reference backend).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

# CI smoke: one iteration of the simcore bench. Fails on panic; on a
# >2x absolute-p50 regression vs the committed BENCH_simcore.json when
# run on the machine that calibrated it (wall-clock does not transfer
# across hardware); and — machine-independently, so CI runners enforce
# it too — when the event-driven/full-tick speedup ratio collapses
# below half its calibrated value.
bench-smoke:
	TORRENT_BENCH_ITERS=1 TORRENT_BENCH_BASELINE=BENCH_simcore.json \
		cargo bench --bench simcore

# Rewrite BENCH_simcore.json + BENCH_serve.json + BENCH_resilience.json
# from a full local run (commit the result). Includes the sharded-stepper
# scaling curve so the baseline keeps its parallel_net_* entries across
# recalibrations.
bench-baseline:
	TORRENT_BENCH_SCALING=1 TORRENT_BENCH_JSON=BENCH_simcore.json \
		TORRENT_BENCH_CALIBRATED=1 cargo bench --bench simcore
	TORRENT_BENCH_JSON=BENCH_serve.json \
		TORRENT_BENCH_CALIBRATED=1 cargo bench --bench serve
	TORRENT_BENCH_JSON=BENCH_resilience.json \
		TORRENT_BENCH_CALIBRATED=1 cargo bench --bench resilience
	TORRENT_BENCH_JSON=BENCH_sched.json \
		TORRENT_BENCH_CALIBRATED=1 cargo bench --bench sched

# The sharded-stepper scaling curve (cycles/s vs threads at 8x8 through
# 64x64; ISSUE 7 satellite). Prints M cycles/s and the speedup vs t=1
# per point; too slow for bench-smoke, so it is opt-in here and in
# bench-baseline only.
bench-scaling:
	TORRENT_BENCH_SCALING=1 cargo bench --bench simcore

# Build every example and run the fast ones (CI smoke). attention_e2e is
# build-only here: it exercises the full artifact suite and is covered by
# the figures/EXPERIMENTS flow.
examples-smoke: topo-sweep
	cargo build --release --examples
	cargo run --release --example quickstart
	cargo run --release --example chain_visualizer
	cargo run --release --example batch_pipeline
	cargo run --release --example multicast_sweep -- --size-kb 4

# The cross-fabric hop study (EXPERIMENTS.md §Topology sweep).
topo-sweep:
	cargo run --release -- topo-sweep --trials 32

# One tier of the differential suite per fabric (CI topology-matrix).
# Usage: make topo-matrix TOPOLOGY=torus   (defaults to all fabrics)
topo-matrix:
	TORRENT_TOPOLOGY=$(TOPOLOGY) cargo test --release --test topologies

# Availability + tail latency of chain repair vs fail-stop under seeded
# fault schedules (EXPERIMENTS.md §Fault sweep).
fault-sweep:
	cargo run --release -- fault-sweep --trials 24

# The chaos property suite + repair unit tests, one fabric per process
# (CI fault-matrix). Usage: make fault-matrix TOPOLOGY=torus
# (defaults to all fabrics).
fault-matrix:
	TORRENT_TOPOLOGY=$(TOPOLOGY) cargo test --release --test failure_injection --test repair

# The full serving sweep: offered load past saturation on every
# (fabric x scheduler x thread-count) leg, cross-mode parity asserted at
# each point; writes serve_sweep.json + serve_sweep.md
# (EXPERIMENTS.md §Serve sweep).
serve-sim:
	cargo run --release -- serve-sim --out serve_sweep

# CI smoke: the quick sweep (three fixed-seed load points, parity
# asserted internally), the serving determinism suite — including the
# faulted leg — and one iteration of the serve bench against the
# committed BENCH_serve.json.
serve-smoke:
	cargo run --release -- serve-sim --quick --out target/serve_smoke
	cargo test --release --test serving
	TORRENT_BENCH_ITERS=1 TORRENT_BENCH_BASELINE=BENCH_serve.json \
		cargo bench --bench serve

# The full resilience sweep: serving under paired seeded fault
# schedules, fail-stop vs restream vs resume vs resume+reroute; writes
# resilience.json + resilience.md (EXPERIMENTS.md §Resilience sweep).
# Every in-tree guarantee (strictly fewer re-streamed bytes under
# resume, byte-exact survivors, availability ordering, cross-mode
# parity) is asserted inside the sweep.
resilience-sweep:
	cargo run --release -- resilience-sweep --out resilience

# CI smoke: the quick seeded resilience sweep (guarantees asserted
# internally), one faulted serve-sim leg per fabric, and one iteration
# of the resilience bench against the committed BENCH_resilience.json.
resilience-smoke:
	cargo run --release -- resilience-sweep --quick --out target/resilience_smoke
	cargo run --release -- serve-sim --faults "router:5@1500;timeout:1200;resume;reroute" --retries 3
	cargo run --release -- serve-sim --topology torus --faults "router:5@1500;timeout:1200;resume;reroute" --retries 3
	cargo run --release -- serve-sim --topology ring --faults "router:5@1500+2000;timeout:1200;resume" --retries 3
	TORRENT_BENCH_ITERS=1 TORRENT_BENCH_BASELINE=BENCH_resilience.json \
		cargo bench --bench resilience

# The full contention sweep: naive/greedy/TSP/load-aware chain
# scheduling under seeded background traffic at rising load, every
# in-tree guarantee (byte-exact delivery, cross-step-mode parity,
# load-aware p99 <= greedy p99 at the congested point) asserted inside
# the sweep (EXPERIMENTS.md §Contention sweep).
contention-sweep:
	cargo run --release -- contention-sweep

# CI smoke: the quick two-level sweep (guarantees asserted internally),
# the contention differential suite, one load-aware serve-sim leg, and
# one iteration of the sched bench against the committed
# BENCH_sched.json.
contention-smoke:
	cargo run --release -- contention-sweep --quick
	cargo test --release --test contention
	cargo run --release -- serve-sim --scheduler load_aware
	TORRENT_BENCH_ITERS=1 TORRENT_BENCH_BASELINE=BENCH_sched.json \
		cargo bench --bench sched

# Measure and commit the golden mesh cycle pins (rust/tests/
# golden_cycles.tsv). Run once on the first machine with a toolchain;
# afterwards any drift in mesh cycle counts fails `cargo test`.
golden-bless:
	TORRENT_GOLDEN_BLESS=1 cargo test --test golden_cycles -- --nocapture

# API docs for the torrent crate; rustdoc warnings (broken intra-doc
# links, malformed code blocks) are errors so the redesigned public API
# stays documented.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p torrent

# Regenerate every paper figure/table via the CLI (EXPERIMENTS.md).
figures:
	cargo run --release -- table1
	cargo run --release -- fig5 --quick
	cargo run --release -- fig6
	cargo run --release -- fig7
	cargo run --release -- fig9
	cargo run --release -- fig11

clean:
	cargo clean
	rm -f artifacts/*.hlo.txt  # manifest.txt is committed; only HLO is generated

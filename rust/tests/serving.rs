//! Serving-layer determinism suite (ISSUE 8 satellite): the open-loop
//! driver must be replay-identical by seed and bit-identical across
//! every step mode — FullTick, EventDriven, Parallel{1,2,4} — on every
//! fabric, because all serving decisions are functions of the seed
//! streams and of engine-reported completion cycles, which the three
//! modes agree on cycle-for-cycle.

use torrent::noc::TopologyKind;
use torrent::serve::{run, AdmissionPolicy, ArrivalKind, ServeConfig, ServeReport};
use torrent::sim::{FaultPlan, StepMode};
use torrent::soc::SocConfig;

fn cfg(seed: u64, rate: u64, policy: AdmissionPolicy) -> ServeConfig {
    ServeConfig {
        seed,
        horizon: 3_000,
        drain: 40_000,
        arrival: ArrivalKind::Poisson { rate_per_kcycle: rate },
        policy,
        ..ServeConfig::default()
    }
}

fn fabric(topology: TopologyKind) -> SocConfig {
    SocConfig::custom(4, 4, 64 * 1024).with_topology(topology)
}

/// Everything observable must match: per-request dispositions, the
/// occupancy time-series, every counter, and the (integer-derived)
/// utilization down to the last bit.
fn assert_reports_identical(a: &ServeReport, b: &ServeReport, what: &str) {
    assert_eq!(a.dispositions, b.dispositions, "dispositions diverged: {what}");
    assert_eq!(a.samples, b.samples, "occupancy samples diverged: {what}");
    let counters = |r: &ServeReport| {
        (
            r.offered,
            r.admitted,
            r.rejected_shed,
            r.rejected_queue_full,
            r.completed,
            r.failed,
            r.unfinished,
            r.tasks_submitted,
            r.cycles,
            r.pending_peak,
            r.inflight_peak,
        )
    };
    assert_eq!(counters(a), counters(b), "counters diverged: {what}");
    assert_eq!(a.util.to_bits(), b.util.to_bits(), "utilization diverged: {what}");
}

#[test]
fn per_task_results_match_across_all_step_modes_on_every_fabric() {
    for topology in TopologyKind::ALL {
        let reference =
            run(cfg(21, 8, AdmissionPolicy::Queue), fabric(topology), StepMode::EventDriven);
        assert!(reference.offered > 0, "{topology:?}: no arrivals");
        assert!(reference.completed > 0, "{topology:?}: nothing completed");
        for mode in [
            StepMode::FullTick,
            StepMode::Parallel { threads: 1 },
            StepMode::Parallel { threads: 2 },
            StepMode::Parallel { threads: 4 },
        ] {
            let other = run(cfg(21, 8, AdmissionPolicy::Queue), fabric(topology), mode);
            assert_reports_identical(&reference, &other, &format!("{topology:?} {mode:?}"));
        }
    }
}

#[test]
fn bursty_and_fixed_arrivals_hold_cross_mode_parity_too() {
    let kinds = [
        ArrivalKind::Bursty { rate_per_kcycle: 20, on_cycles: 500, off_cycles: 500 },
        ArrivalKind::Fixed { interval: 150 },
    ];
    for arrival in kinds {
        let c = ServeConfig { arrival, ..cfg(33, 0, AdmissionPolicy::Queue) };
        let reference = run(c.clone(), fabric(TopologyKind::Mesh), StepMode::EventDriven);
        assert!(reference.offered > 0, "{arrival:?}: no arrivals");
        for mode in [StepMode::FullTick, StepMode::Parallel { threads: 4 }] {
            let other = run(c.clone(), fabric(TopologyKind::Mesh), mode);
            assert_reports_identical(&reference, &other, &format!("{arrival:?} {mode:?}"));
        }
    }
}

#[test]
fn replay_is_bit_identical_by_seed() {
    let go = |seed: u64| {
        run(
            cfg(seed, 12, AdmissionPolicy::Queue),
            fabric(TopologyKind::Torus),
            StepMode::Parallel { threads: 2 },
        )
    };
    let a = go(7);
    let b = go(7);
    assert_reports_identical(&a, &b, "same seed, same mode");
    // A different seed draws different arrival times, so the recorded
    // dispositions cannot coincide.
    let c = go(8);
    assert_ne!(a.dispositions, c.dispositions, "seed must steer the run");
}

#[test]
fn overload_policies_diverge_as_specified() {
    // Well past the ~8-inflight service capacity of the 4x4 fabric.
    let overload =
        |policy| run(cfg(5, 50, policy), fabric(TopologyKind::Mesh), StepMode::EventDriven);
    let shed = overload(AdmissionPolicy::Shed);
    assert!(shed.rejected_shed > 0, "shed policy must shed past saturation");
    assert_eq!(shed.pending_peak, 0, "shed policy never queues");

    let queue = overload(AdmissionPolicy::Queue);
    assert!(queue.pending_peak <= ServeConfig::default().queue_cap, "queue bound violated");

    let bp = overload(AdmissionPolicy::Backpressure);
    assert_eq!(bp.rejected(), 0, "backpressure never rejects");
    assert!(
        bp.pending_peak > queue.pending_peak,
        "unbounded queue must grow past the bounded one at 6x overload"
    );
}

#[test]
fn faulted_fabric_stays_deterministic_and_conserves_accounting() {
    let faulted = || {
        fabric(TopologyKind::Mesh)
            .with_faults(FaultPlan::parse("router:5@1500;timeout:3000").expect("valid fault spec"))
    };
    let reference = run(cfg(13, 8, AdmissionPolicy::Queue), faulted(), StepMode::EventDriven);
    assert_eq!(
        reference.admitted,
        reference.completed + reference.failed + reference.unfinished,
        "admitted requests must reach a terminal state on a degraded fabric"
    );
    assert_eq!(reference.offered, reference.admitted + reference.rejected());
    for mode in [StepMode::FullTick, StepMode::Parallel { threads: 2 }] {
        let other = run(cfg(13, 8, AdmissionPolicy::Queue), faulted(), mode);
        assert_reports_identical(&reference, &other, &format!("faulted {mode:?}"));
    }
}

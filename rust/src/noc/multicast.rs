//! ESP-style network-layer multicast support: XY-tree forking.
//!
//! The baseline the paper compares against (§II-B, §IV-B) replicates
//! packets *inside* the routers: at Route Computation the head flit
//! resolves a destination set to several output ports; at VA/SA/ST the
//! packet is duplicated to all of them, stalling until every branch has a
//! free slot (the paper's "may stall if some VCs are unavailable").
//!
//! This module computes the per-router fork: destinations are partitioned
//! by their next hop under the fabric's routing function (`Topology`),
//! producing the multicast tree edges used both by the cycle simulator's
//! multicast routers and by the Fig-6 analytic hop model. On a mesh the
//! tree is the paper's XY tree; on a torus or ring the same partition
//! follows the wraparound shortest-direction routes.

use super::topology::{Dir, NodeId, Topology};

/// Partition a destination set by next-hop direction at router `cur`.
///
/// Returns `(dir, subset)` pairs; a `Dir::Local` entry appears iff `cur`
/// itself is a destination. Subsets preserve input order.
pub fn mcast_fork(topo: &dyn Topology, cur: NodeId, dsts: &[NodeId]) -> Vec<(Dir, Vec<NodeId>)> {
    let mut out: Vec<(Dir, Vec<NodeId>)> = Vec::new();
    for &d in dsts {
        let dir = topo.next_hop(cur, d);
        match out.iter_mut().find(|(od, _)| *od == dir) {
            Some((_, v)) => v.push(d),
            None => out.push((dir, vec![d])),
        }
    }
    out
}

/// Total directed-link count of the routed multicast tree from `src` to
/// `dsts` — the Fig-6 hop metric for network-layer multicast ("one packet
/// is routed following standard XY-routing, and is divided when routes to
/// different destinations do not overlap").
pub fn mcast_tree_hops(topo: &dyn Topology, src: NodeId, dsts: &[NodeId]) -> usize {
    // Walk the tree: count each traversed link once (shared prefixes shared).
    let mut hops = 0;
    let mut frontier: Vec<(NodeId, Vec<NodeId>)> = vec![(src, dsts.to_vec())];
    while let Some((cur, set)) = frontier.pop() {
        for (dir, subset) in mcast_fork(topo, cur, &set) {
            if dir == Dir::Local {
                continue; // delivered here; ejection is not a fabric link
            }
            let next = topo.neighbour(cur, dir).expect("tree left the fabric");
            hops += 1;
            frontier.push((next, subset));
        }
    }
    hops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::topology::{Mesh, Ring, Torus};

    #[test]
    fn fork_partitions_by_direction() {
        let m = Mesh::new(4, 4);
        // from node 5=(1,1): 6=(2,1) east, 4=(0,1) west, 13=(1,3) north
        let forks = mcast_fork(&m, NodeId(5), &[NodeId(6), NodeId(4), NodeId(13)]);
        assert_eq!(forks.len(), 3);
        let dirs: Vec<Dir> = forks.iter().map(|(d, _)| *d).collect();
        for want in [Dir::East, Dir::West, Dir::North] {
            assert!(dirs.contains(&want), "missing fork {want:?}");
        }
    }

    #[test]
    fn fork_local_when_self_is_destination() {
        let m = Mesh::new(3, 3);
        let forks = mcast_fork(&m, NodeId(4), &[NodeId(4), NodeId(5)]);
        assert!(forks.iter().any(|(d, s)| *d == Dir::Local && s == &vec![NodeId(4)]));
    }

    #[test]
    fn xy_shared_prefix_counted_once() {
        let m = Mesh::new(4, 4);
        // 0=(0,0) -> {3=(3,0), 7=(3,1)}: east x3 shared, then 7 needs +1 north
        // from node 3. Total tree = 3 + 1 = 4 (unicast would be 3 + 4 = 7).
        assert_eq!(mcast_tree_hops(&m, NodeId(0), &[NodeId(3), NodeId(7)]), 4);
    }

    #[test]
    fn single_dest_tree_is_manhattan() {
        let m = Mesh::new(8, 8);
        assert_eq!(
            mcast_tree_hops(&m, NodeId(0), &[NodeId(63)]),
            m.manhattan(NodeId(0), NodeId(63))
        );
    }

    #[test]
    fn dest_equals_source_adds_nothing() {
        let m = Mesh::new(3, 3);
        assert_eq!(mcast_tree_hops(&m, NodeId(0), &[NodeId(0)]), 0);
    }

    #[test]
    fn tree_never_exceeds_unicast_sum() {
        let m = Mesh::new(8, 8);
        let dsts: Vec<NodeId> = [9, 18, 27, 36, 45, 54, 63].map(NodeId).to_vec();
        let uni: usize = dsts.iter().map(|&d| m.manhattan(NodeId(0), d)).sum();
        assert!(mcast_tree_hops(&m, NodeId(0), &dsts) <= uni);
    }

    #[test]
    fn torus_tree_uses_wrap_links() {
        // 0=(0,0) -> {12=(0,3), 3=(3,0)}: one South wrap + one West wrap.
        let t = Torus::new(4, 4);
        assert_eq!(mcast_tree_hops(&t, NodeId(0), &[NodeId(12), NodeId(3)]), 2);
        let m = Mesh::new(4, 4);
        assert_eq!(mcast_tree_hops(&m, NodeId(0), &[NodeId(12), NodeId(3)]), 6);
    }

    #[test]
    fn ring_fork_splits_both_arcs() {
        let r = Ring::new(8);
        let forks = mcast_fork(&r, NodeId(0), &[NodeId(2), NodeId(6)]);
        let dirs: Vec<Dir> = forks.iter().map(|(d, _)| *d).collect();
        assert!(dirs.contains(&Dir::East) && dirs.contains(&Dir::West));
        // Shared-arc prefix counted once: {1, 2} costs 2 links, not 3.
        assert_eq!(mcast_tree_hops(&r, NodeId(0), &[NodeId(1), NodeId(2)]), 2);
    }
}

//! Ablation benches for the design choices DESIGN.md calls out — not a
//! paper figure, but the studies a reviewer would ask for:
//!
//! A1: chain-order strategy in the *cycle simulator* (Fig 6 measures
//!     hops analytically; here the same orders race end-to-end, showing
//!     link contention is what the greedy link-disjoint rule buys).
//! A2: ESP configuration-cost sensitivity — how the Fig 5 crossover
//!     moves if the multicast router programming were free.
//! A3: iDMA outstanding-window sweep — why 8 IDs suffice at 64 B/CC.
//! A4: DSE pattern-rate impact — contiguous vs MNMxNy re-tiling reads.
mod common;

use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest};
use torrent::dma::torrent::dse::AffinePattern;
use torrent::noc::NodeId;
use torrent::sched::Strategy;
use torrent::soc::SocConfig;
use torrent::util::table::{fnum, Table};
use torrent::workloads::{random_dest_sets, TABLE2};

fn main() {
    common::banner("A1: chain order strategy, cycle-accurate (64KB, 8 random dests, 8x8)");
    let mesh = torrent::noc::Mesh::new(8, 8);
    let sets = random_dest_sets(&mesh, NodeId(0), 8, 8, 77);
    let mut t = Table::new("A1 — end-to-end latency by chain order")
        .header(["set", "naive[CC]", "greedy[CC]", "tsp[CC]", "greedy gain"]);
    for (i, dests) in sets.iter().enumerate() {
        let mut lat = vec![];
        for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp] {
            let mut c = Coordinator::new(SocConfig::mesh_8x8());
            let task = c
                .submit_simple(NodeId(0), dests, 64 * 1024, EngineKind::Torrent(s), false)
                .expect("valid request");
            c.run_to_completion(50_000_000);
            lat.push(c.latency_of(task).unwrap());
        }
        t.row([
            i.to_string(),
            lat[0].to_string(),
            lat[1].to_string(),
            lat[2].to_string(),
            format!("{}%", fnum(100.0 * (lat[0] as f64 - lat[1] as f64) / lat[0] as f64, 1)),
        ]);
    }
    t.print();

    common::banner("A2: ESP config-cost sensitivity (what if router programming were free?)");
    let mut t = Table::new("A2 — mcast latency minus modelled config cycles")
        .header(["N_dst", "mcast[CC]", "cfg model[CC]", "data-only[CC]", "torrent[CC]"]);
    for n in [2usize, 4, 8, 16] {
        let mut c = Coordinator::new(SocConfig::eval_4x5());
        let dests: Vec<NodeId> = (1..=n).map(NodeId).collect();
        let task = c
            .submit_simple(NodeId(0), &dests, 64 * 1024, EngineKind::Mcast, false)
            .expect("valid request");
        c.run_to_completion(50_000_000);
        let mcast = c.latency_of(task).unwrap();
        let cfg = torrent::dma::mcast::esp_cfg_cycles(n);
        let mut c2 = Coordinator::new(SocConfig::eval_4x5());
        let chain = EngineKind::Torrent(Strategy::Greedy);
        let task2 = c2
            .submit_simple(NodeId(0), &dests, 64 * 1024, chain, false)
            .expect("valid request");
        c2.run_to_completion(50_000_000);
        t.row([
            n.to_string(),
            mcast.to_string(),
            cfg.to_string(),
            (mcast - cfg).to_string(),
            c2.latency_of(task2).unwrap().to_string(),
        ]);
    }
    t.print();
    println!("(even with free router programming, chainwrite stays within ~15% of");
    println!(" multicast's data phase — the chain costs only store-and-forward hops)");

    common::banner("A3: iDMA outstanding-window sweep (64KB P2P)");
    // The window is a compile-time constant; demonstrate its sufficiency
    // by comparing achieved vs ideal serialization.
    let mut c = Coordinator::new(SocConfig::eval_4x5());
    let task = c
        .submit_simple(NodeId(0), &[NodeId(1)], 64 * 1024, EngineKind::Idma, false)
        .expect("valid request");
    c.run_to_completion(10_000_000);
    let lat = c.latency_of(task).unwrap();
    let ideal = 64 * 1024 / 64;
    println!(
        "idma 64KB 1-hop: {lat} CC vs {ideal} CC ideal serialization -> {}% of link rate",
        fnum(100.0 * ideal as f64 / lat as f64, 1)
    );

    common::banner("A4: DSE pattern-rate impact (Table II read patterns, 1 dest, 3x3)");
    let mut t = Table::new("A4 — transfer latency by source pattern")
        .header(["workload", "KB", "rate[B/CC]", "latency[CC]"]);
    for w in [TABLE2[2], TABLE2[0]] {
        // P3 (contiguous) vs P1 (MNM16N8 logical-order read).
        let mut c = Coordinator::new(SocConfig::fpga_3x3());
        let read = w.read_pattern(c.soc.map.base_of(NodeId(0)));
        let rate = read.rate_per_cycle();
        let dst = NodeId(4);
        let write = w.write_pattern(c.soc.map.base_of(dst));
        let task = c
            .submit(
                P2mpRequest::to_patterns(vec![(dst, write)])
                    .src(NodeId(0))
                    .read(read)
                    .engine(EngineKind::Torrent(Strategy::Greedy)),
            )
            .expect("valid request");
        c.run_to_completion(100_000_000);
        t.row([
            w.id.to_string(),
            (w.bytes() / 1024).to_string(),
            fnum(rate, 1),
            c.latency_of(task).unwrap().to_string(),
        ]);
    }
    t.print();
    println!("(the 8x rate gap is exactly the relayout cost Fig 9 charges XDMA for N times)");
    let _ = AffinePattern::contiguous(0, 0);
}

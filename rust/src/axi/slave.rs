//! Memory-side AXI slave: services write/read bursts arriving over the
//! NoC against the node's scratchpad and returns B/R responses.
//!
//! The SoC endpoint demultiplexes its inbox; packets with AXI request
//! messages are handed here. A fixed SRAM access latency is charged
//! before the response packet is injected.

use std::collections::VecDeque;

use crate::mem::Scratchpad;
use crate::noc::{Message, NetPort, NodeId, Packet};

/// SRAM pipeline latency from request tail to response injection.
pub const MEM_LATENCY: u64 = 2;

/// Pending response.
#[derive(Debug)]
struct Pending {
    ready_at: u64,
    dst: NodeId,
    msg: Message,
    payload: Option<Vec<u8>>,
}

/// Per-node AXI slave.
#[derive(Debug, Default)]
pub struct AxiSlave {
    queue: VecDeque<Pending>,
    /// Served write bytes (activity counter for the power model).
    pub bytes_written: u64,
    pub bytes_read: u64,
}

impl AxiSlave {
    pub fn new() -> Self {
        Self::default()
    }

    /// Try to consume `pkt`; returns `false` if it is not an AXI request.
    pub fn handle(
        &mut self,
        node: NodeId,
        pkt: &Packet,
        mem: &mut Scratchpad,
        now: u64,
    ) -> bool {
        match pkt.msg {
            Message::AxiWriteReq { addr, bytes, axi_id } => {
                let ok = mem.contains(addr, bytes);
                if ok {
                    if let Some(data) = &pkt.payload {
                        mem.write(addr, &data[..bytes.min(data.len())]);
                    }
                    self.bytes_written += bytes as u64;
                }
                self.queue.push_back(Pending {
                    ready_at: now + MEM_LATENCY,
                    dst: pkt.src,
                    msg: Message::AxiWriteResp { axi_id, ok },
                    payload: None,
                });
                true
            }
            Message::AxiReadReq { addr, bytes, axi_id } => {
                let ok = mem.contains(addr, bytes);
                let payload = ok.then(|| mem.read(addr, bytes));
                if ok {
                    self.bytes_read += bytes as u64;
                }
                self.queue.push_back(Pending {
                    ready_at: now + MEM_LATENCY,
                    dst: pkt.src,
                    msg: Message::AxiReadResp { axi_id, ok },
                    payload,
                });
                let _ = node;
                true
            }
            _ => false,
        }
    }

    /// Inject ready responses.
    pub fn tick(&mut self, node: NodeId, net: &mut dyn NetPort) {
        while let Some(p) = self.queue.front() {
            if p.ready_at > net.cycle() {
                break;
            }
            let p = self.queue.pop_front().unwrap();
            let mut pkt = Packet::new(0, node, p.dst, p.msg);
            if let Some(data) = p.payload {
                pkt = pkt.with_payload(data);
            }
            net.send(node, pkt);
        }
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
    }

    /// Activity hint (the `sim::Clocked::next_event` contract): the next
    /// response injection. The queue is FIFO in `ready_at` order (handle
    /// times are monotone), so the front is the earliest event.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        self.queue.front().map(|p| p.ready_at.max(now))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{Mesh, Network};

    fn setup() -> (Network, Scratchpad, AxiSlave) {
        (
            Network::new(Mesh::new(2, 1)),
            Scratchpad::new(1 << 20, 4096),
            AxiSlave::new(),
        )
    }

    #[test]
    fn write_req_applies_and_responds() {
        let (mut net, mut mem, mut slave) = setup();
        let req = Packet::new(
            0,
            NodeId(0),
            NodeId(1),
            Message::AxiWriteReq { addr: (1 << 20) + 64, bytes: 4, axi_id: 3 },
        )
        .with_payload(vec![9, 8, 7, 6]);
        assert!(slave.handle(NodeId(1), &req, &mut mem, 0));
        assert_eq!(mem.peek((1 << 20) + 64, 4), &[9, 8, 7, 6]);
        // Response appears after MEM_LATENCY.
        for _ in 0..(MEM_LATENCY + 1) {
            net.tick();
            slave.tick(NodeId(1), &mut net);
        }
        net.run_until_idle(1_000);
        match &net.recv(NodeId(0)).expect("B response").msg {
            Message::AxiWriteResp { axi_id: 3, ok: true } => {}
            m => panic!("unexpected {m:?}"),
        }
    }

    #[test]
    fn read_req_returns_data() {
        let (mut net, mut mem, mut slave) = setup();
        mem.write((1 << 20) + 8, &[1, 2, 3, 4, 5]);
        let req = Packet::new(
            0,
            NodeId(0),
            NodeId(1),
            Message::AxiReadReq { addr: (1 << 20) + 8, bytes: 5, axi_id: 1 },
        );
        assert!(slave.handle(NodeId(1), &req, &mut mem, 0));
        for _ in 0..50 {
            net.tick();
            slave.tick(NodeId(1), &mut net);
        }
        let resp = net.recv(NodeId(0)).expect("R response");
        assert!(matches!(resp.msg, Message::AxiReadResp { axi_id: 1, ok: true }));
        assert_eq!(&**resp.payload.as_ref().unwrap(), &[1, 2, 3, 4, 5]);
    }

    #[test]
    fn out_of_range_write_errs() {
        let (_, mut mem, mut slave) = setup();
        let req = Packet::new(
            0,
            NodeId(0),
            NodeId(1),
            Message::AxiWriteReq { addr: 0, bytes: 8, axi_id: 0 }, // below base
        )
        .with_payload(vec![0; 8]);
        assert!(slave.handle(NodeId(1), &req, &mut mem, 0));
        // Error response queued with ok=false.
        assert!(matches!(
            slave.queue.front().unwrap().msg,
            Message::AxiWriteResp { ok: false, .. }
        ));
    }

    #[test]
    fn next_event_points_at_response_injection() {
        let (_, mut mem, mut slave) = setup();
        assert_eq!(slave.next_event(0), None);
        let req = Packet::new(
            0,
            NodeId(0),
            NodeId(1),
            Message::AxiWriteReq { addr: 1 << 20, bytes: 1, axi_id: 0 },
        )
        .with_payload(vec![1]);
        slave.handle(NodeId(1), &req, &mut mem, 10);
        assert_eq!(slave.next_event(10), Some(10 + MEM_LATENCY));
        // Past-due events clamp to "now" (busy).
        assert_eq!(slave.next_event(10 + MEM_LATENCY + 5), Some(10 + MEM_LATENCY + 5));
    }

    #[test]
    fn non_axi_messages_rejected() {
        let (_, mut mem, mut slave) = setup();
        let pkt = Packet::new(0, NodeId(0), NodeId(1), Message::Raw(1));
        assert!(!slave.handle(NodeId(1), &pkt, &mut mem, 0));
        assert!(slave.is_idle());
    }
}

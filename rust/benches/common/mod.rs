//! Minimal bench harness (criterion is not vendored in this image; see
//! DESIGN.md §3): warmup + timed iterations + a stats summary, printed in
//! a stable format that `bench_output.txt` captures.
//!
//! CI hooks (`make bench-smoke` / `make bench-baseline`):
//! * `TORRENT_BENCH_ITERS=n` overrides every `iters(default)` call — the
//!   smoke run uses 1 iteration;
//! * `TORRENT_BENCH_JSON=path` makes the bench write its p50s as a JSON
//!   baseline (`TORRENT_BENCH_CALIBRATED=1` marks it authoritative);
//! * `TORRENT_BENCH_BASELINE=path` compares against a committed baseline
//!   and fails the process on a >2x p50 regression (only when the
//!   baseline is calibrated — placeholder baselines report and pass).
#![allow(dead_code)] // each bench binary uses a subset of the harness

use std::time::Instant;

use torrent::util::stats::Summary;

/// Time `f` for `iters` iterations after `warmup` runs; print a summary.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let s = Summary::of(&samples);
    println!(
        "bench {name}: mean {:.3} ms  p50 {:.3}  p99 {:.3}  min {:.3}  max {:.3}  (n={})",
        s.mean, s.p50, s.p99, s.min, s.max, s.n
    );
    s
}

/// Banner separating experiment output inside bench logs.
pub fn banner(title: &str) {
    println!("\n==================== {title} ====================");
}

/// Iteration count, overridable via `TORRENT_BENCH_ITERS` (CI smoke).
pub fn iters(default: usize) -> usize {
    match std::env::var("TORRENT_BENCH_ITERS") {
        Ok(v) => v.parse().unwrap_or(default).max(1),
        Err(_) => default,
    }
}

/// A parsed bench baseline: calibrated flag, origin machine, and
/// (name, p50 ms) entries.
pub struct Baseline {
    pub calibrated: bool,
    pub machine: String,
    pub entries: Vec<(String, f64)>,
}

/// Best-effort machine identifier: wall-clock baselines only transfer
/// within one machine, so the regression gate enforces only when the
/// baseline's machine matches (cross-machine runs report informationally
/// — a laptop-calibrated baseline must not fail a slower CI runner).
pub fn machine_id() -> String {
    std::fs::read_to_string("/proc/sys/kernel/hostname")
        .ok()
        .map(|s| s.trim().to_string())
        .or_else(|| {
            // macOS/BSD have no /proc; HOSTNAME is a shell variable that
            // is usually not exported — ask uname instead.
            std::process::Command::new("uname")
                .arg("-n")
                .output()
                .ok()
                .and_then(|o| String::from_utf8(o.stdout).ok())
                .map(|s| s.trim().to_string())
        })
        .or_else(|| std::env::var("COMPUTERNAME").ok())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Escape a string for embedding in a JSON double-quoted literal.
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Write bench p50s as a JSON baseline (schema `torrent-bench-v1`).
pub fn write_bench_json(
    path: &str,
    bench_name: &str,
    calibrated: bool,
    note: &str,
    entries: &[(String, f64)],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"torrent-bench-v1\",\n");
    out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench_name)));
    out.push_str(&format!("  \"calibrated\": {calibrated},\n"));
    out.push_str(&format!("  \"machine\": \"{}\",\n", json_escape(&machine_id())));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"entries\": [\n");
    for (i, (name, p50)) in entries.iter().enumerate() {
        let comma = if i + 1 < entries.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{ \"name\": \"{}\", \"p50_ms\": {p50:.6} }}{comma}\n",
            json_escape(name)
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// Parse a `torrent-bench-v1` baseline (hand-rolled: serde is not
/// vendored in this image — DESIGN.md §3.2). Line-oriented: one entry
/// object per line.
pub fn read_bench_json(path: &str) -> Result<Baseline, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    if !text.contains("torrent-bench-v1") {
        return Err(format!("{path}: not a torrent-bench-v1 baseline"));
    }
    let quoted_after = |line: &str, key: &str| -> Option<String> {
        let rest = &line[line.find(key)? + key.len()..];
        let open = rest.find('"')?;
        let rest = &rest[open + 1..];
        Some(rest[..rest.find('"')?].to_string())
    };
    let mut calibrated = false;
    let mut machine = String::from("unknown");
    let mut entries = Vec::new();
    for line in text.lines() {
        if line.contains("\"calibrated\"") {
            calibrated = line.contains("true");
        }
        if let Some(m) = quoted_after(line, "\"machine\":") {
            machine = m;
        }
        if let Some(name) = quoted_after(line, "\"name\":") {
            let p50 = line
                .find("\"p50_ms\":")
                .map(|i| line[i + "\"p50_ms\":".len()..].trim_start())
                .and_then(|rest| {
                    let end = rest
                        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-'))
                        .unwrap_or(rest.len());
                    rest[..end].parse::<f64>().ok()
                })
                .ok_or_else(|| format!("{path}: entry {name:?} has no p50_ms"))?;
            entries.push((name, p50));
        }
    }
    Ok(Baseline { calibrated, machine, entries })
}

/// Compare current p50s against a baseline; returns the number of >2x
/// regressions. Always 0 when the baseline is an uncalibrated
/// placeholder or was calibrated on a different machine (wall-clock
/// baselines do not transfer across hardware) — those runs only report.
pub fn count_regressions(current: &[(String, f64)], base: &Baseline) -> usize {
    if !base.calibrated {
        println!(
            "baseline is uncalibrated (placeholder); recording only — run `make bench-baseline` \
             on a real toolchain to calibrate"
        );
        return 0;
    }
    let here = machine_id();
    let enforce = base.machine == here && base.machine != "unknown";
    if !enforce {
        println!(
            "baseline calibrated on {:?}, running on {here:?}: reporting only (wall-clock \
             baselines are per-machine)",
            base.machine
        );
    }
    let mut regressions = 0;
    for (name, p50) in current {
        let Some((_, base_p50)) = base.entries.iter().find(|(n, _)| n == name) else {
            println!("  {name}: no baseline entry (new bench) — skipped");
            continue;
        };
        if *base_p50 > 0.0 && *p50 > 2.0 * base_p50 {
            println!("  REGRESSION {name}: p50 {p50:.3} ms > 2x baseline {base_p50:.3} ms");
            if enforce {
                regressions += 1;
            }
        } else {
            println!("  ok {name}: p50 {p50:.3} ms (baseline {base_p50:.3} ms)");
        }
    }
    regressions
}

/// Machine-independent regression guard: p50 *ratios* between two benches
/// of the same run transfer across hardware (unlike absolute wall-clock,
/// which only the calibrating machine can enforce). Returns true when the
/// current `slow/fast` speedup ratio collapsed below half the calibrated
/// baseline's ratio — this is what lets an ephemeral CI runner still fail
/// on e.g. the event-driven stepper losing its advantage over full-tick.
pub fn ratio_regressed(current: &[(String, f64)], base: &Baseline, fast: &str, slow: &str) -> bool {
    if !base.calibrated {
        return false;
    }
    let get = |set: &[(String, f64)], n: &str| {
        set.iter().find(|(name, _)| name == n).map(|&(_, p)| p).filter(|p| *p > 0.0)
    };
    let (Some(cf), Some(cs)) = (get(current, fast), get(current, slow)) else {
        return false;
    };
    let (Some(bf), Some(bs)) = (get(&base.entries, fast), get(&base.entries, slow)) else {
        return false;
    };
    let (cur_ratio, base_ratio) = (cs / cf, bs / bf);
    if cur_ratio < base_ratio / 2.0 {
        println!(
            "  RATIO REGRESSION {slow}/{fast}: {cur_ratio:.2}x, less than half the calibrated \
             {base_ratio:.2}x (machine-independent guard)"
        );
        return true;
    }
    println!("  ok ratio {slow}/{fast}: {cur_ratio:.2}x (calibrated {base_ratio:.2}x)");
    false
}

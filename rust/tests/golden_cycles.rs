//! Golden-cycle regression pins — the oracle proving the topology
//! refactor (and any future fabric work) changed no mesh number.
//!
//! A table of fixed mesh scenarios (the quickstart example, the
//! multicast_sweep example's headline points, the batch_pipeline DAG,
//! Fig 7's per-destination marginal cost, and the quickstart transfer
//! under a mid-stream router kill — fail-stop and repaired) runs under
//! every step mode; each metric must be bit-identical across `FullTick`,
//! `EventDriven` and `Parallel` (at every thread count; `TORRENT_THREADS`
//! pins one for CI matrix legs), and — once blessed — bit-identical to
//! the committed `rust/tests/golden_cycles.tsv`.
//!
//! Blessing: the pins are measured numbers, so the first machine with a
//! toolchain runs `make golden-bless` (sets `TORRENT_GOLDEN_BLESS=1`)
//! and commits the TSV; from then on any drift in mesh cycle counts —
//! however introduced — fails this suite. Until the file exists the
//! suite still enforces the step-mode equality and the marginal-cost
//! band, and prints the would-be pin values.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest};
use torrent::noc::NodeId;
use torrent::sched::Strategy;
use torrent::sim::{FaultPlan, StepMode};
use torrent::soc::SocConfig;

const GOLDEN_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden_cycles.tsv");

/// (scenario, metric) -> value.
type Metrics = BTreeMap<(String, String), u64>;

fn record(m: &mut Metrics, scenario: &str, metric: &str, value: u64) {
    m.insert((scenario.to_string(), metric.to_string()), value);
}

fn fill(c: &mut Coordinator, node: usize, bytes: usize) {
    let base = c.soc.map.base_of(NodeId(node));
    let payload: Vec<u8> = (0..bytes).map(|i| (i % 251) as u8).collect();
    c.soc.nodes[node].mem.write(base, &payload);
}

/// The quickstart example's exact transfer: 16 KB from cluster 0 to
/// {5, 10, 15} on a 4×4 mesh, greedy chain, real bytes.
fn quickstart(m: &mut Metrics, mode: StepMode) {
    let mut c = Coordinator::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
    fill(&mut c, 0, 16 * 1024);
    let dests = [NodeId(5), NodeId(10), NodeId(15)];
    let task = c
        .submit_simple(NodeId(0), &dests, 16 * 1024, EngineKind::Torrent(Strategy::Greedy), true)
        .expect("valid request");
    c.run_to_completion(1_000_000);
    record(m, "quickstart", "latency", c.latency_of(task).unwrap());
    record(m, "quickstart", "quiesce_cycle", c.soc.cycle());
    record(m, "quickstart", "flit_hops", c.soc.net.stats.flit_hops);
}

/// The multicast_sweep example's headline column: 32 KB to 8
/// destinations on the 4×5 evaluation SoC, per engine.
fn multicast_sweep(m: &mut Metrics, mode: StepMode) {
    for (label, engine) in [
        ("torrent_tsp", EngineKind::Torrent(Strategy::Tsp)),
        ("mcast", EngineKind::Mcast),
        ("idma", EngineKind::Idma),
    ] {
        let mut c = Coordinator::with_step_mode(SocConfig::eval_4x5(), mode);
        let dests: Vec<NodeId> = (1..=8).map(NodeId).collect();
        let task = c
            .submit_simple(NodeId(0), &dests, 32 * 1024, engine, false)
            .expect("valid request");
        c.run_to_completion(100_000_000);
        record(m, "multicast_sweep", label, c.latency_of(task).unwrap());
    }
}

/// The batch_pipeline example's shape in miniature: a scatter feeding
/// two dependent stages (a 3-stage DAG across mixed engines).
fn batch_pipeline(m: &mut Metrics, mode: StepMode) {
    let mut c = Coordinator::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
    fill(&mut c, 0, 4 * 1024);
    let a = c
        .submit(
            P2mpRequest::to(&[NodeId(1), NodeId(2)])
                .src(NodeId(0))
                .bytes(4 * 1024)
                .engine(EngineKind::Torrent(Strategy::Greedy))
                .with_data(true),
        )
        .expect("stage a");
    let b = c
        .submit(
            P2mpRequest::to(&[NodeId(5), NodeId(6)])
                .src(NodeId(1))
                .bytes(4 * 1024)
                .engine(EngineKind::Torrent(Strategy::Tsp))
                .after(&[a]),
        )
        .expect("stage b");
    let d = c
        .submit(
            P2mpRequest::to(&[NodeId(10)])
                .src(NodeId(2))
                .bytes(4 * 1024)
                .engine(EngineKind::Idma)
                .after(&[a]),
        )
        .expect("stage c");
    c.run_until_all_done(10_000_000);
    record(m, "batch_pipeline", "stage_a_latency", c.latency_of(a).unwrap());
    record(m, "batch_pipeline", "stage_b_latency", c.latency_of(b).unwrap());
    record(m, "batch_pipeline", "stage_c_latency", c.latency_of(d).unwrap());
    record(m, "batch_pipeline", "all_done_cycle", c.soc.cycle());
}

/// Fig 7's per-destination marginal cost (the paper's "82 CC per
/// destination" linear trend): latency(4 dests) - latency(3 dests) at
/// 64 KB on the evaluation SoC.
fn marginal_cost(m: &mut Metrics, mode: StepMode) {
    let lat = |n: usize| -> u64 {
        let mut c = Coordinator::with_step_mode(SocConfig::eval_4x5(), mode);
        let dests: Vec<NodeId> = (1..=n).map(NodeId).collect();
        let engine = EngineKind::Torrent(Strategy::Greedy);
        let task = c
            .submit_simple(NodeId(0), &dests, 64 * 1024, engine, false)
            .expect("valid request");
        c.run_to_completion(10_000_000);
        c.latency_of(task).unwrap()
    };
    let (l3, l4) = (lat(3), lat(4));
    assert!(l4 > l3, "an extra destination must cost cycles");
    record(m, "fig7", "marginal_cc_per_dest", l4 - l3);
}

/// The quickstart transfer with chain hop 10's router killed mid-stream
/// (DESIGN.md §Fault-model), measured fail-stop vs repaired. Detection
/// and re-chaining are deterministic once a fault activates — both step
/// modes tick cycle-by-cycle from then on — so the watchdog firing
/// cycle, the repair latency and the quiesce cycle pin the fault
/// machinery exactly like the healthy scenarios above pin the fabric.
fn fault_scenarios(m: &mut Metrics, mode: StepMode) {
    for (label, spec) in [
        ("fault_failstop", "router:10@400;timeout:1000;norepair"),
        ("fault_repair", "router:10@400;timeout:1000"),
    ] {
        let cfg = SocConfig::custom(4, 4, 64 * 1024)
            .with_faults(FaultPlan::parse(spec).expect("valid fault spec"));
        let mut c = Coordinator::with_step_mode(cfg, mode);
        fill(&mut c, 0, 16 * 1024);
        let dests = [NodeId(5), NodeId(10), NodeId(15)];
        let task = c
            .submit_simple(NodeId(0), &dests, 16 * 1024, EngineKind::Torrent(Strategy::Greedy), true)
            .expect("valid request");
        let report = c.run_to_completion(1_000_000);
        record(m, label, "quiesce_cycle", c.soc.cycle());
        if label == "fault_failstop" {
            assert!(c.latency_of(task).is_none(), "fail-stop must not report a latency");
            assert_eq!(report.failed(), vec![task.id()], "fail-stop run must close the task");
        } else {
            record(m, label, "repaired_latency", c.latency_of(task).unwrap());
            assert_eq!(report.repaired(), vec![task.id()], "repair run must complete the task");
        }
    }
}

fn measure(mode: StepMode) -> Metrics {
    let mut m = Metrics::new();
    quickstart(&mut m, mode);
    multicast_sweep(&mut m, mode);
    batch_pipeline(&mut m, mode);
    marginal_cost(&mut m, mode);
    fault_scenarios(&mut m, mode);
    m
}

fn render(m: &Metrics) -> String {
    let mut out = String::from("# scenario\tmetric\tcycles — `make golden-bless` regenerates\n");
    for ((scenario, metric), value) in m {
        writeln!(out, "{scenario}\t{metric}\t{value}").unwrap();
    }
    out
}

fn parse(text: &str) -> Metrics {
    let mut m = Metrics::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split('\t');
        let (s, k, v) = (
            parts.next().expect("scenario"),
            parts.next().expect("metric"),
            parts.next().expect("value"),
        );
        m.insert((s.to_string(), k.to_string()), v.parse().expect("golden value"));
    }
    m
}

#[test]
fn golden_mesh_cycle_counts_are_pinned_and_step_mode_invariant() {
    let full = measure(StepMode::FullTick);
    let ev = measure(StepMode::EventDriven);
    assert_eq!(full, ev, "EventDriven diverged from FullTick on a pinned mesh scenario");

    // The sharded stepper is a third equal member of the pin contract:
    // every scenario — including the faulted ones — must land on the
    // same numbers at every thread count. `TORRENT_THREADS` lets the CI
    // parallel matrix pin one count per job; default sweeps a few.
    let counts: Vec<usize> = match std::env::var("TORRENT_THREADS") {
        Ok(v) => vec![v.parse().expect("TORRENT_THREADS must be an integer")],
        Err(_) => vec![1, 2, 4],
    };
    for threads in counts {
        let par = measure(StepMode::Parallel { threads });
        assert_eq!(
            full, par,
            "Parallel{{{threads}}} diverged from FullTick on a pinned mesh scenario"
        );
    }

    // The paper's Fig-7 trend: ~82 CC of configuration per added
    // destination. A loose band (the simulator is calibrated, not
    // cycle-copied from the RTL) that still catches structural drift.
    let marginal = full[&("fig7".to_string(), "marginal_cc_per_dest".to_string())];
    assert!(
        (40..=200).contains(&marginal),
        "per-destination marginal cost {marginal} CC strayed from the ~82 CC trend"
    );

    // Bless mode rewrites the pins whether or not the file exists —
    // it is the documented recovery path for *intentional* drift.
    if std::env::var("TORRENT_GOLDEN_BLESS").is_ok() {
        std::fs::write(GOLDEN_PATH, render(&full)).expect("write golden file");
        eprintln!("blessed {} pins into {GOLDEN_PATH} — commit it", full.len());
        return;
    }
    match std::fs::read_to_string(GOLDEN_PATH) {
        Ok(text) => {
            let pinned = parse(&text);
            assert_eq!(
                pinned, full,
                "cycle counts drifted from the blessed {GOLDEN_PATH}; if the change is \
                 intentional, re-bless with `make golden-bless` and commit the diff"
            );
        }
        Err(_) => {
            eprintln!(
                "no golden file at {GOLDEN_PATH}; run `make golden-bless` and commit it.\n\
                 measured pins:\n{}",
                render(&full)
            );
        }
    }
}

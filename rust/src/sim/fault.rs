//! Deterministic fault injection: seeded schedules of link kills, router
//! kills, straggler (slow-clock) routers, and follower-engine drop-outs.
//!
//! A [`FaultPlan`] is pure data — a list of `(cycle, kind)` activations
//! plus detection/repair policy knobs — attached to `SocConfig` and
//! interpreted by the fabric (`noc::Network`), the SoC tick loop
//! (follower drops), and the coordinator (detection + repair). Keeping
//! the plan here, below `noc`, means every layer can speak the same
//! vocabulary without a dependency cycle; node references are therefore
//! raw `usize` indices, converted to `NodeId` at the point of use.
//!
//! Determinism: activations fire at fixed cycles, the plan is immutable
//! after construction, and nothing in this module consults a clock or an
//! RNG — the same plan against the same workload replays bit-identically
//! under both step modes.

use std::fmt;

/// What breaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The directed physical channel `from -> to` becomes a black hole:
    /// flits in flight and every future flit die at the receiving edge,
    /// with their credits returned upstream — data is lost but flow
    /// control survives, so surviving routes sharing the sender keep
    /// moving (DESIGN.md §Fault-model). Kill both directions with two
    /// entries.
    LinkKill { from: usize, to: usize },
    /// The router (and the cluster behind its local port) goes dark:
    /// buffered flits are purged (credits returned to the neighbours
    /// that issued them), in-flight deliveries sink at the boundary, and
    /// nothing is ever forwarded again.
    RouterKill { node: usize },
    /// The router only advances its pipeline every `factor`-th cycle —
    /// a slow clock domain, not a failure. `factor >= 2`.
    Straggler { node: usize, factor: u32 },
    /// The node's DMA engines stop ticking and every packet addressed to
    /// the cluster is discarded on delivery; the router keeps forwarding
    /// through-traffic. Models a hung core with a live NoC interface.
    FollowerDrop { node: usize },
}

/// One scheduled activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    /// First cycle at which the fault is in effect.
    pub at_cycle: u64,
    pub kind: FaultKind,
}

/// A complete fault scenario: the activation schedule plus the
/// coordinator's detection/repair policy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    /// A task whose aggregate progress counter is flat for this many
    /// cycles is declared stalled.
    pub detect_timeout: u64,
    /// When false the coordinator diagnoses and fails the task but does
    /// not re-chain (the fail-stop baseline).
    pub repair: bool,
}

pub const DEFAULT_DETECT_TIMEOUT: u64 = 10_000;

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { faults: Vec::new(), detect_timeout: DEFAULT_DETECT_TIMEOUT, repair: true }
    }
}

impl FaultPlan {
    /// No faults scheduled (policy knobs are irrelevant then).
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// True when the plan changes anything at all — the fault layer is
    /// only wired into the fabric when this holds.
    pub fn armed(&self) -> bool {
        !self.faults.is_empty()
    }

    /// Parse the CLI/TOML spec string. Grammar (`;`-separated clauses):
    ///
    /// ```text
    /// link:FROM-TO@CYCLE      kill directed link FROM->TO at CYCLE
    /// router:NODE@CYCLE       kill router NODE at CYCLE
    /// straggle:NODExFACTOR@CYCLE   slow router NODE by FACTOR from CYCLE
    /// drop:NODE@CYCLE         drop follower engines at NODE at CYCLE
    /// timeout:CYCLES          stall-detection window (default 10000)
    /// norepair                fail-stop baseline: diagnose, don't re-chain
    /// ```
    ///
    /// Example: `link:3-4@1000;router:7@5000;timeout:2000`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if clause == "norepair" {
                plan.repair = false;
                continue;
            }
            let (head, body) = clause
                .split_once(':')
                .ok_or_else(|| format!("fault clause {clause:?}: expected `kind:args`"))?;
            if head == "timeout" {
                plan.detect_timeout = parse_num(body, clause)?;
                continue;
            }
            let (args, at) = body
                .split_once('@')
                .ok_or_else(|| format!("fault clause {clause:?}: expected `...@cycle`"))?;
            let at_cycle = parse_num(at, clause)?;
            let kind = match head {
                "link" => {
                    let (from, to) = args
                        .split_once('-')
                        .ok_or_else(|| format!("fault clause {clause:?}: expected `from-to`"))?;
                    FaultKind::LinkKill {
                        from: parse_num::<usize>(from, clause)?,
                        to: parse_num::<usize>(to, clause)?,
                    }
                }
                "router" => FaultKind::RouterKill { node: parse_num(args, clause)? },
                "straggle" => {
                    let (node, factor) = args
                        .split_once('x')
                        .ok_or_else(|| format!("fault clause {clause:?}: expected `nodexfactor`"))?;
                    let factor: u32 = parse_num(factor, clause)?;
                    if factor < 2 {
                        return Err(format!("fault clause {clause:?}: factor must be >= 2"));
                    }
                    FaultKind::Straggler { node: parse_num(node, clause)?, factor }
                }
                "drop" => FaultKind::FollowerDrop { node: parse_num(args, clause)? },
                other => return Err(format!("unknown fault kind {other:?} in {clause:?}")),
            };
            plan.faults.push(Fault { at_cycle, kind });
        }
        Ok(plan)
    }

    /// Every node index referenced by the schedule must be `< n_nodes`;
    /// called by `Soc::new` so a bad spec fails at construction, not
    /// mid-simulation.
    pub fn validate(&self, n_nodes: usize) -> Result<(), String> {
        for f in &self.faults {
            let nodes: &[usize] = match f.kind {
                FaultKind::LinkKill { from, to } => &[from, to],
                FaultKind::RouterKill { node }
                | FaultKind::Straggler { node, .. }
                | FaultKind::FollowerDrop { node } => &[node],
            };
            for &n in nodes {
                if n >= n_nodes {
                    return Err(format!("fault {f:?} references node {n} >= {n_nodes}"));
                }
            }
        }
        Ok(())
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::LinkKill { from, to } => write!(f, "link:{from}-{to}"),
            FaultKind::RouterKill { node } => write!(f, "router:{node}"),
            FaultKind::Straggler { node, factor } => write!(f, "straggle:{node}x{factor}"),
            FaultKind::FollowerDrop { node } => write!(f, "drop:{node}"),
        }
    }
}

fn parse_num<T: std::str::FromStr>(s: &str, clause: &str) -> Result<T, String> {
    s.trim().parse().map_err(|_| format!("fault clause {clause:?}: bad number {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_disarmed() {
        let p = FaultPlan::default();
        assert!(p.is_empty() && !p.armed());
        assert_eq!(p.detect_timeout, DEFAULT_DETECT_TIMEOUT);
        assert!(p.repair);
    }

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse("link:3-4@1000; router:7@5000;straggle:2x4@0;drop:9@2000;timeout:5000;norepair")
            .unwrap();
        assert_eq!(p.detect_timeout, 5000);
        assert!(!p.repair);
        assert_eq!(
            p.faults,
            vec![
                Fault { at_cycle: 1000, kind: FaultKind::LinkKill { from: 3, to: 4 } },
                Fault { at_cycle: 5000, kind: FaultKind::RouterKill { node: 7 } },
                Fault { at_cycle: 0, kind: FaultKind::Straggler { node: 2, factor: 4 } },
                Fault { at_cycle: 2000, kind: FaultKind::FollowerDrop { node: 9 } },
            ]
        );
    }

    #[test]
    fn empty_spec_is_default() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert_eq!(FaultPlan::parse(" ; ;").unwrap(), FaultPlan::default());
    }

    #[test]
    fn rejects_malformed_clauses() {
        assert!(FaultPlan::parse("link:3-4").is_err(), "missing @cycle");
        assert!(FaultPlan::parse("link:34@5").is_err(), "missing dash");
        assert!(FaultPlan::parse("router:x@5").is_err(), "bad number");
        assert!(FaultPlan::parse("straggle:2x1@0").is_err(), "factor < 2");
        assert!(FaultPlan::parse("meteor:3@5").is_err(), "unknown kind");
        assert!(FaultPlan::parse("norepair:yes").is_err(), "norepair takes no args");
    }

    #[test]
    fn validate_bounds_node_indices() {
        let p = FaultPlan::parse("router:7@5").unwrap();
        assert!(p.validate(8).is_ok());
        assert!(p.validate(7).is_err());
        let l = FaultPlan::parse("link:0-9@5").unwrap();
        assert!(l.validate(9).is_err());
    }

    #[test]
    fn display_roundtrips_kinds() {
        for spec in ["link:3-4", "router:7", "straggle:2x4", "drop:9"] {
            let p = FaultPlan::parse(&format!("{spec}@11")).unwrap();
            assert_eq!(p.faults[0].kind.to_string(), spec);
        }
    }
}

"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

HLO text — NOT ``lowered.compile()`` or ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla crate's bundled xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The HLO *text* parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs ``<name>.hlo.txt`` per entry point plus ``manifest.txt`` which the
Rust runtime parses to know each artifact's parameter/result shapes.
Shapes here are the *end-to-end example* shapes (a scaled-down DeepSeek-V3
head — see DESIGN.md §3); the cycle-level Fig-9 benchmark uses the paper's
full Table II shapes, which involve no numerics.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# End-to-end example geometry: one scaled-down DeepSeek-V3 MLA head.
SEQ_PREFILL = 256  # prefill sequence length
SEQ_DECODE = 512  # decode-time KV cache length
D_HEAD = 64  # head dim
D_LATENT = 128  # compressed MLA latent dim
GEMM_M, GEMM_K, GEMM_N = 256, 64, 128  # bare accelerator GeMM
DECODE_BATCH = 64  # batched decode rows


def _spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# name -> (fn, example_args)
ENTRY_POINTS = {
    "attn_prefill": (
        model.attention_prefill,
        (
            _spec(SEQ_PREFILL, D_HEAD),
            _spec(SEQ_PREFILL, D_HEAD),
            _spec(SEQ_PREFILL, D_HEAD),
        ),
    ),
    "attn_decode": (
        model.attention_decode,
        (_spec(1, D_HEAD), _spec(SEQ_DECODE, D_HEAD), _spec(SEQ_DECODE, D_HEAD)),
    ),
    "attn_prefill_flash": (
        model.attention_prefill_flash,
        (
            _spec(SEQ_PREFILL, D_HEAD),
            _spec(SEQ_PREFILL, D_HEAD),
            _spec(SEQ_PREFILL, D_HEAD),
        ),
    ),
    "kv_recovery": (
        model.kv_recovery,
        (
            _spec(SEQ_PREFILL, D_LATENT),
            _spec(D_LATENT, D_HEAD),
            _spec(D_LATENT, D_HEAD),
        ),
    ),
    "gemm_prefill": (
        model.gemm_prefill,
        (_spec(GEMM_M, GEMM_K), _spec(GEMM_K, GEMM_N)),
    ),
    "gemm_decode": (
        model.gemm_decode,
        (_spec(DECODE_BATCH, 64), _spec(64, 16)),
    ),
    "relayout_16x8_to_8x8": (
        model.relayout_16x8_to_8x8,
        (_spec(SEQ_PREFILL // 16, D_HEAD // 8, 16, 8),),
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(s):
    return "f32[" + ",".join(str(d) for d in s.shape) + "]"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated subset of entry points"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = list(ENTRY_POINTS)
    if args.only:
        names = [n for n in names if n in set(args.only.split(","))]

    manifest_lines = []
    for name in names:
        fn, specs = ENTRY_POINTS[name]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        ins = ";".join(_shape_str(s) for s in specs)
        outs_s = ";".join(_shape_str(s) for s in outs)
        manifest_lines.append(f"{name}\t{name}.hlo.txt\t{ins}\t{outs_s}")
        print(f"wrote {path} ({len(text)} chars)  in={ins}  out={outs_s}")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(
            "# name\tfile\tinput_shapes\toutput_shapes — parsed by rust/src/runtime/manifest.rs\n"
        )
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()

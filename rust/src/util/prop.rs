//! Property-test harness (proptest is not vendored in this image).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it retries a crude shrink (the generator is asked
//! for "smaller" values by re-seeding) and reports the seed + case so the
//! failure replays deterministically:
//!
//! ```text
//! property failed at case 17 (seed 0xDEADBEEF): <Debug of input>
//! ```

use crate::util::rng::Rng;

/// Run a property over `cases` random inputs produced by `gen`.
///
/// Panics with the failing input's `Debug` representation and the exact
/// (seed, case) pair needed to replay it.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        // Derive a per-case rng so a failure replays without running
        // the preceding cases.
        let mut rng = crate::util::rng(seed, crate::util::stream::PROP + case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed:#x}): {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Convenience: assert-style helper for property bodies.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |r| r.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(
            2,
            100,
            |r| r.below(10),
            |&x| check(x < 5, format!("{x} >= 5")),
        );
    }

    #[test]
    fn per_case_rng_is_replayable() {
        let mut first: Vec<u64> = vec![];
        forall(3, 5, |r| r.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        forall(3, 5, |r| r.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}

//! Stepping-mode equivalence: event-driven and sharded-parallel stepping
//! against the full-tick reference, skip-ahead hints for every protocol
//! wait, and watchdog deadline regressions.
//!
//! The contract under test (`sim::Clocked::next_event`, `Soc::run_until_idle`,
//! `StepMode::Parallel`): event-driven stepping may skip only provably
//! no-op cycles, and the parallel stepper's barrier merge must commit
//! cross-shard traffic in the sequential order — so every reported cycle
//! count — quiesce time, task latency, η_P2MP, traffic statistics — must
//! be **bit-identical** across all three steppers at any thread count.

use std::panic::{catch_unwind, AssertUnwindSafe};

use torrent::coordinator::{Coordinator, EngineKind};
use torrent::dma::mcast::{esp_cfg_cycles, McastEngine, McastTask};
use torrent::dma::torrent::cfg::{CfgType, TorrentCfg};
use torrent::dma::torrent::dse::AffinePattern;
use torrent::dma::torrent::timing::{
    CFG_DECODE_CYCLES, CFG_ISSUE_CYCLES, FIN_PROC_CYCLES, GRANT_PROC_CYCLES, SEG_BYTES,
};
use torrent::dma::torrent::{ChainDest, ChainTask, Torrent};
use torrent::mem::Scratchpad;
use torrent::noc::{Mesh, Message, Network, NodeId, Packet};
use torrent::sched::Strategy;
use torrent::sim::StepMode;
use torrent::soc::{Soc, SocConfig};
use torrent::util::prop::{check, forall};

/// Worker-thread counts the parallel differential sweeps: the
/// degenerate single shard, small shard counts that exercise uneven
/// splits, and whatever this machine actually has.
fn thread_counts() -> [usize; 4] {
    let ncpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    [1, 2, 4, ncpus]
}

/// The tentpole property: ≥100 seeded random P2MP tasks (Fig-5-style
/// size/destination grid points, all engines) run under all three
/// steppers — full-tick, event-driven, sharded-parallel — with identical
/// latencies, η_P2MP and traffic counters. Each case draws its parallel
/// thread count from [`thread_counts`], so the sweep covers 1, 2, 4 and
/// NUM_CPUS workers across the 110 workloads.
#[test]
fn prop_three_steppers_bit_identical() {
    let mut total_skipped = 0u64;
    forall(
        0x57E9,
        110,
        |rng| {
            let (cols, rows) = [(3usize, 3usize), (4, 4), (4, 5)][rng.index(3)];
            let n_nodes = cols * rows;
            let n_dst = 1 + rng.index(5);
            let dests: Vec<NodeId> = rng
                .sample_distinct(n_nodes - 1, n_dst)
                .into_iter()
                .map(|v| NodeId(v + 1))
                .collect();
            let bytes = 256 + rng.index(8 * 1024);
            let engine_idx = rng.index(6) as u8;
            let with_data = rng.below(4) == 0;
            let threads = thread_counts()[rng.index(4)];
            (cols, rows, dests, bytes, engine_idx, with_data, threads)
        },
        |&(cols, rows, ref dests, bytes, engine_idx, with_data, threads)| {
            let engine = match engine_idx {
                0 => EngineKind::Torrent(Strategy::Naive),
                1 => EngineKind::Torrent(Strategy::Greedy),
                2 => EngineKind::Torrent(Strategy::Tsp),
                3 => EngineKind::Idma,
                4 => EngineKind::Xdma,
                _ => EngineKind::Mcast,
            };
            let run = |mode: StepMode| -> (u64, u64, u64, u64, u64, u64) {
                let mut c =
                    Coordinator::with_step_mode(SocConfig::custom(cols, rows, 64 * 1024), mode);
                let task = c.submit_simple(NodeId(0), dests, bytes, engine, with_data).unwrap();
                c.run_to_completion(50_000_000);
                let rec = c.record(task).unwrap();
                let res = rec.result.as_ref().expect("task completed");
                (
                    c.soc.net.cycle,
                    res.latency(),
                    rec.eta().unwrap().to_bits(),
                    c.soc.net.stats.flit_hops,
                    c.soc.net.stats.packets_delivered,
                    c.soc.cycles_skipped,
                )
            };
            let full = run(StepMode::FullTick);
            let fast = run(StepMode::EventDriven);
            let par = run(StepMode::Parallel { threads });
            check(full.0 == fast.0, format!("quiesce cycle {} != {}", full.0, fast.0))?;
            check(full.1 == fast.1, format!("latency {} != {}", full.1, fast.1))?;
            check(full.2 == fast.2, "eta_P2MP bits diverged")?;
            check(full.3 == fast.3, format!("flit_hops {} != {}", full.3, fast.3))?;
            check(full.4 == fast.4, "packets_delivered diverged")?;
            check(full.5 == 0, "full-tick stepping must never skip")?;
            check(
                par.0 == fast.0,
                format!("parallel({threads}) quiesce cycle {} != {}", par.0, fast.0),
            )?;
            check(
                par.1 == fast.1,
                format!("parallel({threads}) latency {} != {}", par.1, fast.1),
            )?;
            check(par.2 == fast.2, format!("parallel({threads}) eta_P2MP bits diverged"))?;
            check(
                par.3 == fast.3,
                format!("parallel({threads}) flit_hops {} != {}", par.3, fast.3),
            )?;
            check(par.4 == fast.4, format!("parallel({threads}) packets_delivered diverged"))?;
            check(
                par.5 == fast.5,
                format!("parallel({threads}) skipped {} != event-driven {}", par.5, fast.5),
            )?;
            total_skipped += fast.5;
            Ok(())
        },
    );
    assert!(total_skipped > 0, "event-driven stepping never engaged across 110 workloads");
}

/// `Parallel {{ threads: 1 }}` collapses to the sequential kernel — same
/// ticks, same skips, same counters as the event-driven stepper, with no
/// scope/barrier machinery in the way.
#[test]
fn parallel_one_thread_is_event_driven() {
    let run = |mode: StepMode| -> (u64, u64, u64, u64, u64) {
        let mut c = Coordinator::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
        let task = c
            .submit_simple(
                NodeId(0),
                &[NodeId(3), NodeId(9), NodeId(14)],
                6 * 1024,
                EngineKind::Torrent(Strategy::Greedy),
                true,
            )
            .unwrap();
        c.run_to_completion(1_000_000);
        (
            c.soc.net.cycle,
            c.latency_of(task).unwrap(),
            c.soc.net.stats.flit_hops,
            c.soc.ticks_executed,
            c.soc.cycles_skipped,
        )
    };
    let fast = run(StepMode::EventDriven);
    let par1 = run(StepMode::Parallel { threads: 1 });
    assert_eq!(par1, fast, "Parallel{{1}} must be the event-driven stepper exactly");
}

/// Degraded fabrics across every topology: a schedule mixing a router
/// kill, a link cut, a straggler and an engine drop must evolve
/// bit-identically under the sequential and sharded kernels (fault
/// activation is a barrier event on the parallel path). Faulted tasks
/// may stall forever, so the comparison drives the two kernels in
/// per-tick lockstep over a fixed window instead of running to
/// quiescence.
#[test]
fn faulted_runs_identical_across_all_steppers() {
    use torrent::noc::TopologyKind;
    use torrent::sim::FaultPlan;
    for topology in [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::Ring] {
        let run = |threads: Option<usize>| -> (u64, u64, u64, u64, Vec<u8>) {
            let plan = FaultPlan::parse("straggle:2x4@100;link:1-2@400;drop:7@600;router:4@800")
                .unwrap();
            let cfg = SocConfig::custom(3, 3, 64 * 1024)
                .with_topology(topology)
                .with_faults(plan);
            let mut s = Soc::new(cfg);
            let base = s.map.base_of(NodeId(0));
            let data: Vec<u8> = (0..4096).map(|i| (i * 7 + 3) as u8).collect();
            s.nodes[0].mem.write(base, &data);
            let read = AffinePattern::contiguous(base, 4096);
            let dests: Vec<(NodeId, AffinePattern)> = [5usize, 7, 3]
                .iter()
                .map(|&n| {
                    (NodeId(n), AffinePattern::contiguous(s.map.base_of(NodeId(n)), 4096))
                })
                .collect();
            s.chainwrite(1, NodeId(0), read, &dests, Strategy::Naive, true);
            for _ in 0..4_000 {
                match threads {
                    Some(t) => s.tick_parallel(t),
                    None => s.tick(),
                }
            }
            (
                s.net.cycle,
                s.net.stats.flit_hops,
                s.net.stats.packets_delivered,
                s.net.stats.flits_dropped,
                s.nodes[5].mem.peek(s.map.base_of(NodeId(5)), 4096).to_vec(),
            )
        };
        let seq = run(None);
        for threads in [2, 3, 4] {
            let par = run(Some(threads));
            assert_eq!(
                (par.0, par.1, par.2, par.3),
                (seq.0, seq.1, seq.2, seq.3),
                "{topology:?} parallel({threads}) counters diverged under faults"
            );
            assert_eq!(
                par.4, seq.4,
                "{topology:?} parallel({threads}) survivor memory diverged"
            );
        }
    }
}

/// Cut-through forwarding (the FWD_LATENCY-gated data switch) under both
/// steppers: a 3-destination chain with real bytes must forward through
/// the middle followers and report identical cycles.
#[test]
fn chainwrite_forwarding_identical_across_modes() {
    let run = |mode: StepMode| -> (u64, u64, u64) {
        let mut c = Coordinator::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
        let base = c.soc.map.base_of(NodeId(0));
        let data: Vec<u8> = (0..8 * 1024).map(|i| (i * 13 + 5) as u8).collect();
        c.soc.nodes[0].mem.write(base, &data);
        let task = c
            .submit_simple(
                NodeId(0),
                &[NodeId(1), NodeId(6), NodeId(11)],
                8 * 1024,
                EngineKind::Torrent(Strategy::Greedy),
                true,
            )
            .unwrap();
        c.run_to_completion(1_000_000);
        let lat = c.latency_of(task).unwrap();
        let order = c.record(task).unwrap().chain_order.clone().unwrap();
        let forwarded: u64 = order[..order.len() - 1]
            .iter()
            .map(|n| c.soc.nodes[n.0].torrent.stats.bytes_forwarded)
            .sum();
        (c.soc.net.cycle, lat, forwarded)
    };
    let full = run(StepMode::FullTick);
    let fast = run(StepMode::EventDriven);
    assert_eq!(full, fast, "forwarding run diverged between steppers");
    assert!(full.2 >= 2 * 8 * 1024, "middle followers did not forward the stream");
}

/// CFG_ISSUE skip-ahead: after issuing one cfg the initiator's next
/// event is exactly one descriptor-build interval away.
#[test]
fn initiator_hints_cfg_issue_wait() {
    let mut net = Network::new(Mesh::new(3, 1));
    let mut mem = Scratchpad::new(0, 64 * 1024);
    let mut t = Torrent::new(NodeId(0));
    let read = AffinePattern::contiguous(0, 256);
    let dests = vec![
        ChainDest { node: NodeId(1), pattern: AffinePattern::contiguous(0x100, 256), vias: Default::default() },
        ChainDest { node: NodeId(2), pattern: AffinePattern::contiguous(0x200, 256), vias: Default::default() },
    ];
    t.submit(ChainTask { task: 1, read, dests, with_data: false }, 0);
    assert_eq!(t.next_event(0), Some(0), "queued task is immediate work");
    t.tick(&mut net, &mut mem); // pops the task, issues cfg[0]
    assert_eq!(t.next_event(0), Some(CFG_ISSUE_CYCLES), "cfg[1] waits a descriptor build");
}

/// CFG_DECODE → GRANT_PROC → FIN_PROC skip-ahead chain on a follower:
/// each protocol wait is reported exactly, so the event-driven stepper
/// can jump straight to the cycle where the FSM acts.
#[test]
fn follower_hints_decode_grant_finish_waits() {
    let mut net = Network::new(Mesh::new(2, 1));
    let mut mem = Scratchpad::new(0, 4096);
    let mut t = Torrent::new(NodeId(1));
    let cfg = TorrentCfg {
        task: 7,
        cfg_type: CfgType::Write,
        prev: Some(NodeId(0)),
        next: None, // tail: generates grant and finish itself
        position: 0,
        chain_len: 1,
        axi_burst_bytes: SEG_BYTES as u32,
        pattern: AffinePattern::contiguous(0, 0), // zero-byte control-only chain
    };
    let pkt = Packet::new(0, NodeId(0), NodeId(1), Message::TorrentCfg { task: 7 })
        .with_payload(cfg.encode());
    assert!(t.handle(&pkt, &mut mem, 100));
    assert_eq!(t.next_event(100), Some(100 + CFG_DECODE_CYCLES), "cfg decode wait");

    net.cycle = 100 + CFG_DECODE_CYCLES;
    t.tick(&mut net, &mut mem); // arms the grant pipeline
    assert_eq!(t.next_event(net.cycle), Some(net.cycle + GRANT_PROC_CYCLES), "grant wait");

    net.cycle += GRANT_PROC_CYCLES;
    t.tick(&mut net, &mut mem); // sends grant, arms the finish pipeline
    assert_eq!(t.next_event(net.cycle), Some(net.cycle + FIN_PROC_CYCLES), "finish wait");

    net.cycle += FIN_PROC_CYCLES;
    t.tick(&mut net, &mut mem); // sends finish, retires the follower role
    assert!(t.is_idle());
    assert_eq!(t.next_event(net.cycle), None);
}

/// The ESP multicast baseline's router-programming stretch is a timed
/// event too — the stepper can skip the whole configuration wait.
#[test]
fn mcast_hints_esp_config_wait() {
    let mut net = Network::new(Mesh::new(2, 1));
    let mut mem = Scratchpad::new(0, 4096);
    let mut m = McastEngine::new(NodeId(0));
    m.submit(
        McastTask {
            task: 1,
            read: AffinePattern::contiguous(0, 1024),
            dests: vec![NodeId(1)],
            drop_offset: 0,
            with_data: false,
        },
        0,
    );
    assert_eq!(m.next_event(0), Some(0));
    m.tick(&mut net, &mut mem); // activates; router programming starts
    assert_eq!(m.next_event(0), Some(esp_cfg_cycles(1)));
}

/// A stalled system (follower whose grant can never arrive) must expire
/// the watchdog at the **same cycle** in both step modes — the
/// event-driven stepper caps its fast-forward at the deadline.
#[test]
fn stalled_system_watchdog_identical_across_modes() {
    let stalled = |mode: StepMode| -> String {
        let mut s = Soc::with_step_mode(SocConfig::custom(2, 2, 32 * 1024), mode);
        let cfg = TorrentCfg {
            task: 9,
            cfg_type: CfgType::Write,
            prev: Some(NodeId(0)),
            next: Some(NodeId(3)), // node 3 never gets a cfg: grant never comes
            position: 0,
            chain_len: 2,
            axi_burst_bytes: SEG_BYTES as u32,
            pattern: AffinePattern::contiguous(s.map.base_of(NodeId(1)), 64),
        };
        s.net.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(1), Message::TorrentCfg { task: 9 })
                .with_payload(cfg.encode()),
        );
        let err = catch_unwind(AssertUnwindSafe(|| s.run_until_idle(500))).unwrap_err();
        err.downcast_ref::<String>().cloned().expect("watchdog panics with a String")
    };
    let full = stalled(StepMode::FullTick);
    let fast = stalled(StepMode::EventDriven);
    assert!(full.contains("watchdog 'soc.quiesce' expired"), "unexpected panic: {full}");
    assert_eq!(full, fast, "watchdog fired at different cycles across step modes");
}

//! Regenerates paper Fig 9: data movement of DeepSeek-V3 self-attention
//! layers (Table II workloads P1-P3, D1-D3) on the 3×3 FPGA SoC —
//! Torrent Chainwrite vs the XDMA software-P2MP baseline. The paper
//! reports up to 7.88x speedup.
mod common;

fn main() {
    common::banner("Fig 9: DeepSeek-V3 self-attention data movement");
    let t0 = std::time::Instant::now();
    let (rows, t) = torrent::analysis::experiments::fig9();
    t.print();
    let max = rows.iter().map(|r| r.speedup).fold(0.0, f64::max);
    println!("max speedup: {max:.2}x (paper: up to 7.88x)");
    println!("fig9 wall time: {:.1?}", t0.elapsed());
}

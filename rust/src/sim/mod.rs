//! Simulation kernel: the synchronous cycle-stepping contract.
//!
//! The whole SoC advances in lock-step — every component implements
//! [`Clocked`] and is ticked once per cycle by its owner (the `soc::Soc`
//! event loop ticks DMA engines, then the network, then memories'
//! bookkeeping). A shared [`Clock`] provides the cycle count; quiescence
//! is detected structurally (`is_idle`) rather than by event-queue
//! emptiness, because wormhole state lives in buffers, not events.

/// A component advanced once per cycle.
pub trait Clocked {
    /// Advance one cycle.
    fn tick(&mut self, cycle: u64);
    /// True when the component holds no in-flight work.
    fn is_idle(&self) -> bool;
}

/// Simulation clock.
#[derive(Debug, Default, Clone, Copy)]
pub struct Clock {
    pub cycle: u64,
}

impl Clock {
    pub fn advance(&mut self) -> u64 {
        self.cycle += 1;
        self.cycle
    }
}

/// Watchdog used by `run_until` loops: panics (with context) when a
/// simulation fails to make progress — the way the test suite detects
/// protocol deadlocks.
#[derive(Debug)]
pub struct Watchdog {
    pub deadline: u64,
    pub label: &'static str,
}

impl Watchdog {
    pub fn new(deadline: u64, label: &'static str) -> Self {
        Watchdog { deadline, label }
    }

    pub fn check(&self, cycle: u64) {
        assert!(
            cycle <= self.deadline,
            "watchdog '{}' expired at cycle {cycle} (deadline {})",
            self.label,
            self.deadline
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances() {
        let mut c = Clock::default();
        assert_eq!(c.advance(), 1);
        assert_eq!(c.advance(), 2);
        assert_eq!(c.cycle, 2);
    }

    #[test]
    #[should_panic(expected = "watchdog 'demo' expired")]
    fn watchdog_panics_past_deadline() {
        Watchdog::new(10, "demo").check(11);
    }

    #[test]
    fn watchdog_quiet_before_deadline() {
        Watchdog::new(10, "demo").check(10);
    }
}

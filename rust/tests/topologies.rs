//! Cross-topology differential property suite (mesh / torus / ring).
//!
//! The topology-generic NoC refactor gives the test suite an
//! independent axis: the same seeded scenario runs on three fabrics and
//! three step modes (full-tick, event-driven, sharded-parallel), and
//! every invariant must hold on all of them.
//!
//! Per seeded scenario (topology, src, dest set, engine, strategy):
//! * **byte-exactness** — every destination's scratchpad ends with the
//!   source payload, whatever fabric routed it;
//! * **permutation** — `sched::schedule` returns a true permutation of
//!   the destination set on every (topology, strategy) pair;
//! * **step-mode equivalence** — `StepMode::EventDriven` reports
//!   bit-identical per-task latency, quiesce cycle and flit-hops to
//!   `StepMode::FullTick` on torus and ring, not just the mesh;
//! * **wraparound dominance** — for corner-heavy ("wraparound
//!   favoring") destination sets, the torus TSP chain never traverses
//!   more links than the mesh TSP chain (Held–Karp is exact at these
//!   sizes, so this is a theorem, not a heuristic hope).
//!
//! Routing invariants (exhaustive on fabrics ≤ 5×5): `next_hop`
//! strictly decreases `distance`, `path` endpoints/length match
//! `distance`, and `links` are exactly `path`'s consecutive pairs.
//!
//! `TORRENT_TOPOLOGY={mesh,torus,ring}` filters the scenario suite to
//! one fabric (the CI topology-matrix job runs one process per fabric).

use torrent::coordinator::{Coordinator, EngineKind};
use torrent::noc::{Mesh, NodeId, Ring, Topo, Topology, TopologyKind, Torus};
use torrent::sched::{self, Strategy};
use torrent::sim::StepMode;
use torrent::soc::SocConfig;
use torrent::util::prop::{check, forall};
use torrent::util::rng::Rng;

/// The fabrics under test: equal node counts so destination sets and
/// address maps transfer unchanged between them.
const GRID: (usize, usize) = (4, 4);
const N_NODES: usize = GRID.0 * GRID.1;

fn fabric_kinds() -> Vec<TopologyKind> {
    match std::env::var("TORRENT_TOPOLOGY").ok().as_deref() {
        Some(s) if !s.is_empty() => {
            let kind = TopologyKind::parse(s)
                .unwrap_or_else(|| panic!("TORRENT_TOPOLOGY={s:?} (mesh|torus|ring)"));
            vec![kind]
        }
        _ => TopologyKind::ALL.to_vec(),
    }
}

fn config(kind: TopologyKind) -> SocConfig {
    SocConfig::custom(GRID.0, GRID.1, 64 * 1024).with_topology(kind)
}

fn topo_of(kind: TopologyKind) -> Topo {
    Topo::build(kind, GRID.0, GRID.1)
}

#[derive(Debug, Clone)]
struct Scenario {
    src: usize,
    dests: Vec<usize>,
    bytes: usize,
    engine_idx: u8,
}

fn engine_of(idx: u8) -> EngineKind {
    match idx {
        0 => EngineKind::Torrent(Strategy::Naive),
        1 => EngineKind::Torrent(Strategy::Greedy),
        2 => EngineKind::Torrent(Strategy::Tsp),
        3 => EngineKind::Idma,
        4 => EngineKind::Xdma,
        _ => EngineKind::Mcast,
    }
}

fn gen_scenario(rng: &mut Rng) -> Scenario {
    let src = rng.index(N_NODES);
    let n_dst = 1 + rng.index(4);
    let dests: Vec<usize> = rng
        .sample_distinct(N_NODES - 1, n_dst)
        .into_iter()
        .map(|v| if v >= src { v + 1 } else { v })
        .collect();
    Scenario {
        src,
        dests,
        bytes: 512 + rng.index(2 * 1024),
        engine_idx: rng.index(6) as u8,
    }
}

/// Drive one scenario on one fabric in one step mode; return
/// (latency, quiesce cycle, flit hops) and assert byte-exactness.
fn run(kind: TopologyKind, s: &Scenario, mode: StepMode) -> Result<(u64, u64, u64), String> {
    let mut c = Coordinator::with_step_mode(config(kind), mode);
    let src = NodeId(s.src);
    let payload: Vec<u8> = (0..s.bytes).map(|i| (i * 131 + s.src * 7 + 3) as u8).collect();
    let base = c.soc.map.base_of(src);
    c.soc.nodes[s.src].mem.write(base, &payload);
    let dests: Vec<NodeId> = s.dests.iter().map(|&d| NodeId(d)).collect();
    let task = c
        .submit_simple(src, &dests, s.bytes, engine_of(s.engine_idx), true)
        .map_err(|e| format!("submit failed: {e}"))?;
    c.run_to_completion(20_000_000);
    let lat = c.latency_of(task).ok_or("task never completed")?;
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    for d in &dests {
        let got = c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(*d) + half, s.bytes);
        check(
            got == &payload[..],
            format!("byte mismatch at {d:?} on {:?} ({mode:?})", kind),
        )?;
    }
    Ok((lat, c.soc.cycle(), c.soc.net.stats.flit_hops))
}

#[test]
fn chainwrite_is_byte_exact_and_step_mode_invariant_on_every_fabric() {
    for kind in fabric_kinds() {
        forall(0x70D0 ^ kind as u64, 10, gen_scenario, |s| {
            let full = run(kind, s, StepMode::FullTick)?;
            let ev = run(kind, s, StepMode::EventDriven)?;
            check(
                full == ev,
                format!("EventDriven {ev:?} != FullTick {full:?} on {kind:?}"),
            )
        });
    }
}

/// The sharded stepper as the third equal member of the cross-topology
/// differential: same scenarios, every fabric, a sweep of shard counts
/// (including one that exceeds the node count).
#[test]
fn chainwrite_is_parallel_invariant_on_every_fabric() {
    for kind in fabric_kinds() {
        forall(0x70D1 ^ kind as u64, 6, gen_scenario, |s| {
            let full = run(kind, s, StepMode::FullTick)?;
            for threads in [2, 3, 4, 32] {
                let par = run(kind, s, StepMode::Parallel { threads })?;
                check(
                    full == par,
                    format!("Parallel{{{threads}}} {par:?} != FullTick {full:?} on {kind:?}"),
                )?;
            }
            Ok(())
        });
    }
}

#[test]
fn schedule_returns_a_true_permutation_on_every_fabric() {
    for kind in fabric_kinds() {
        let topo = topo_of(kind);
        forall(0x5EED ^ kind as u64, 100, gen_scenario, |s| {
            let dests: Vec<NodeId> = s.dests.iter().map(|&d| NodeId(d)).collect();
            for strat in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp] {
                let order = sched::schedule(strat, &topo, NodeId(s.src), &dests);
                let mut a = order.clone();
                a.sort();
                let mut b = dests.clone();
                b.sort();
                check(a == b, format!("{strat:?} not a permutation on {kind:?}"))?;
                check(
                    sched::chain_hops(&topo, NodeId(s.src), &order) >= dests.len(),
                    "chain shorter than destination count",
                )?;
            }
            Ok(())
        });
    }
}

/// Destination sets drawn from the far corner region — the sets where
/// wraparound links pay. TSP at these sizes is exact Held–Karp, so the
/// optimal torus chain is provably no longer than the optimal mesh
/// chain evaluated on the mesh.
#[test]
fn torus_chains_never_cost_more_than_mesh_on_wraparound_favoring_sets() {
    let mesh = Mesh::new(GRID.0, GRID.1);
    let torus = Torus::new(GRID.0, GRID.1);
    let far: Vec<usize> = (0..N_NODES)
        .filter(|&n| n % GRID.0 >= GRID.0 / 2 || n / GRID.0 >= GRID.1 / 2)
        .collect();
    forall(
        0xFA12,
        50,
        |rng| {
            let n_dst = 1 + rng.index(5);
            rng.sample_distinct(far.len(), n_dst)
                .into_iter()
                .map(|i| NodeId(far[i]))
                .collect::<Vec<NodeId>>()
        },
        |dests| {
            let src = NodeId(0);
            let m = sched::chain_hops(&mesh, src, &sched::tsp_order(&mesh, src, dests));
            let t = sched::chain_hops(&torus, src, &sched::tsp_order(&torus, src, dests));
            check(t <= m, format!("torus tsp {t} > mesh tsp {m}"))?;
            // Same-order comparison holds for any order (pointwise
            // distance dominance), naive included.
            let naive = sched::naive_order(dests);
            let mn = sched::chain_hops(&mesh, src, &naive);
            let tn = sched::chain_hops(&torus, src, &naive);
            check(tn <= mn, format!("torus naive {tn} > mesh naive {mn}"))
        },
    );
}

// ---------------------------------------------------------------------
// Routing invariants, exhaustive on small fabrics.
// ---------------------------------------------------------------------

fn invariant_fabrics() -> Vec<Topo> {
    let mut out: Vec<Topo> = Vec::new();
    for (c, r) in [(2, 2), (3, 3), (4, 3), (5, 5), (1, 4), (2, 5)] {
        out.push(Topo::Torus(Torus::new(c, r)));
        out.push(Topo::Mesh(Mesh::new(c, r)));
    }
    for n in 1..=10 {
        out.push(Topo::Ring(Ring::new(n)));
    }
    out
}

#[test]
fn next_hop_strictly_decreases_distance() {
    for topo in invariant_fabrics() {
        for a in 0..topo.n_nodes() {
            for b in 0..topo.n_nodes() {
                let (a, b) = (NodeId(a), NodeId(b));
                if a == b {
                    assert_eq!(topo.next_hop(a, b), torrent::noc::Dir::Local);
                    continue;
                }
                let d = topo.next_hop(a, b);
                let next = topo
                    .neighbour(a, d)
                    .unwrap_or_else(|| panic!("{}: next_hop into a missing link", topo.name()));
                assert_eq!(
                    topo.distance(next, b),
                    topo.distance(a, b) - 1,
                    "{}: no progress {a:?} -> {b:?}",
                    topo.name()
                );
            }
        }
    }
}

#[test]
fn path_endpoints_and_length_match_distance() {
    for topo in invariant_fabrics() {
        for a in 0..topo.n_nodes() {
            for b in 0..topo.n_nodes() {
                let (a, b) = (NodeId(a), NodeId(b));
                let p = topo.path(a, b);
                assert_eq!(p.first(), Some(&a), "{}", topo.name());
                assert_eq!(p.last(), Some(&b), "{}", topo.name());
                assert_eq!(p.len(), topo.distance(a, b) + 1, "{}", topo.name());
            }
        }
    }
}

#[test]
fn links_are_consistent_with_path_and_neighbours() {
    for topo in invariant_fabrics() {
        for a in 0..topo.n_nodes() {
            for b in 0..topo.n_nodes() {
                let (a, b) = (NodeId(a), NodeId(b));
                let p = topo.path(a, b);
                let links = topo.links(a, b);
                assert_eq!(links.len(), topo.distance(a, b), "{}", topo.name());
                for (i, &(from, to)) in links.iter().enumerate() {
                    assert_eq!((from, to), (p[i], p[i + 1]), "{}", topo.name());
                    // Every link is a real single hop of the fabric.
                    let d = topo.next_hop(from, b);
                    assert_eq!(topo.neighbour(from, d), Some(to), "{}", topo.name());
                }
            }
        }
    }
}

#[test]
fn neighbour_links_are_symmetric() {
    use torrent::noc::Dir;
    for topo in invariant_fabrics() {
        for n in 0..topo.n_nodes() {
            for d in [Dir::North, Dir::East, Dir::South, Dir::West] {
                if let Some(next) = topo.neighbour(NodeId(n), d) {
                    assert_eq!(
                        topo.neighbour(next, d.opposite()),
                        Some(NodeId(n)),
                        "{}: asymmetric link {n} --{d:?}--> {next:?}",
                        topo.name()
                    );
                }
            }
        }
    }
}

#[test]
fn distance_is_symmetric_and_diameter_tight() {
    for topo in invariant_fabrics() {
        let mut max = 0;
        for a in 0..topo.n_nodes() {
            for b in 0..topo.n_nodes() {
                let (a, b) = (NodeId(a), NodeId(b));
                assert_eq!(topo.distance(a, b), topo.distance(b, a), "{}", topo.name());
                max = max.max(topo.distance(a, b));
            }
        }
        assert_eq!(max, topo.diameter(), "{}: diameter not tight", topo.name());
    }
}

"""L2 model entry points vs oracles: shapes, numerics, layout chains."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def test_attention_prefill_matches_ref():
    q, k, v = _rand((128, 64), 1), _rand((128, 64), 2), _rand((128, 64), 3)
    (got,) = model.attention_prefill(q, k, v)
    want = ref.attention_prefill(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_attention_decode_matches_ref():
    q = _rand((1, 64), 4)
    kc, vc = _rand((512, 64), 5), _rand((512, 64), 6)
    (got,) = model.attention_decode(q, kc, vc)
    want = ref.attention_decode(q, kc, vc)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_attention_rows_are_convex_combinations():
    # Each output row is a convex combination of V rows: bounded by V's extrema.
    q, k, v = _rand((64, 32), 7), _rand((64, 32), 8), _rand((64, 32), 9)
    (o,) = model.attention_prefill(q, k, v)
    assert bool(jnp.all(o <= jnp.max(v, axis=0) + 1e-5))
    assert bool(jnp.all(o >= jnp.min(v, axis=0) - 1e-5))


def test_kv_recovery_matches_ref():
    c = _rand((256, 128), 10)
    wk, wv = _rand((128, 64), 11), _rand((128, 64), 12)
    gk, gv = model.kv_recovery(c, wk, wv)
    wk_ref, wv_ref = ref.kv_recovery(c, wk, wv)
    np.testing.assert_allclose(gk, wk_ref, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gv, wv_ref, rtol=1e-3, atol=1e-4)


def test_gemm_entry_points():
    a, b = _rand((256, 64), 13), _rand((64, 128), 14)
    (g,) = model.gemm_prefill(a, b)
    np.testing.assert_allclose(g, ref.matmul(a, b), rtol=1e-4, atol=1e-6)
    x, w = _rand((64, 64), 15), _rand((64, 16), 16)
    (g2,) = model.gemm_decode(x, w)
    np.testing.assert_allclose(g2, ref.matmul(x, w), rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize(
    "fn,tout",
    [
        (model.relayout_16x8_to_8x8, (8, 8)),
        (model.relayout_16x8_to_64x16, (64, 16)),
    ],
)
def test_relayout_entry_points(fn, tout):
    x = _rand((128, 64), 17)
    xb = ref.to_blocked(x, 16, 8)
    (got,) = fn(xb)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.relayout(xb, *tout))
    )


def test_prefill_to_decode_pipeline():
    """Chained workload P1->P2 with the layout hop in between (Table II)."""
    q, k, v = _rand((128, 64), 18), _rand((128, 64), 19), _rand((128, 64), 20)
    (o,) = model.attention_prefill(q, k, v)
    # the accelerator emits MNM16N8; the next consumer wants MNM8N8
    ob = ref.to_blocked(o, 16, 8)
    (ob2,) = model.relayout_16x8_to_8x8(ob)
    np.testing.assert_allclose(
        np.asarray(ref.from_blocked(ob2)), np.asarray(o), rtol=1e-6
    )

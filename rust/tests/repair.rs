//! Unit tests for the fault-repair machinery: dead-hop diagnosis,
//! re-chain planning over a degraded fabric, and repair idempotence
//! (DESIGN.md §Fault-model).
//!
//! Geometry used throughout: a 4x4 XY-routed mesh, node id = y*4 + x.
//! Killing router 1 = (1,0) severs the XY route 0 -> 5 (which turns at
//! (1,0)) while leaving 0 -> 4, 4 -> 5 and the reverse routes intact —
//! the asymmetric damage that distinguishes per-leg route checks from
//! whole-protocol route checks.

use torrent::coordinator::{plan_repair_chains, Coordinator, EngineKind, TaskOutcome, TaskStatus};
use torrent::dma::torrent::ChainVias;
use torrent::noc::{Degraded, NodeId, Topo, TopologyKind};
use torrent::sched::{schedule_pairs, Strategy};
use torrent::sim::FaultPlan;
use torrent::soc::SocConfig;

fn mesh4() -> Topo {
    Topo::build(TopologyKind::Mesh, 4, 4)
}

/// A degraded view of `mesh4` with the given routers dead.
fn degraded(dead_routers: &[usize]) -> Degraded {
    let topo = mesh4();
    let n = 16;
    let mut dead = vec![false; n];
    for &r in dead_routers {
        dead[r] = true;
    }
    Degraded::new(topo, dead, vec![[false; 5]; n])
}

fn dests(nodes: &[usize]) -> Vec<(NodeId, ())> {
    nodes.iter().map(|&n| (NodeId(n), ())).collect()
}

fn chain_nodes(chain: &[(NodeId, (), ChainVias)]) -> Vec<usize> {
    chain.iter().map(|(n, _, _)| n.0).collect()
}

// ---------------------------------------------------------------------------
// plan_repair_chains: re-chain ordering over the degraded fabric
// ---------------------------------------------------------------------------

/// On an undamaged view the planner reproduces the scheduler's single
/// chain verbatim — repair planning degenerates to normal dispatch.
#[test]
fn healthy_fabric_plans_one_chain_in_schedule_order() {
    let deg = Degraded::healthy(mesh4());
    let src = NodeId(0);
    let (order, _) = schedule_pairs(Strategy::Greedy, &deg, src, dests(&[10, 3, 5]));
    let (chains, lost) = plan_repair_chains(&deg, Strategy::Greedy, src, dests(&[10, 3, 5]), false);
    assert!(lost.is_empty());
    assert_eq!(chains.len(), 1, "no damage, no reason to split");
    assert_eq!(chain_nodes(&chains[0]), order.iter().map(|n| n.0).collect::<Vec<_>>());
}

/// A destination whose router is dead is reported lost, never chained.
#[test]
fn dead_destination_is_lost_not_chained() {
    let deg = degraded(&[5]);
    let (chains, lost) =
        plan_repair_chains(&deg, Strategy::Greedy, NodeId(0), dests(&[4, 5]), false);
    assert_eq!(lost, vec![NodeId(5)]);
    assert_eq!(chains.len(), 1);
    assert_eq!(chain_nodes(&chains[0]), vec![4]);
}

/// With the initiator's own router dead nothing is reachable: every
/// destination is lost and no chain is emitted.
#[test]
fn dead_source_loses_everything() {
    let deg = degraded(&[0]);
    let (chains, lost) =
        plan_repair_chains(&deg, Strategy::Greedy, NodeId(0), dests(&[1, 4, 5]), false);
    assert!(chains.is_empty());
    let mut lost: Vec<usize> = lost.iter().map(|n| n.0).collect();
    lost.sort_unstable();
    assert_eq!(lost, vec![1, 4, 5]);
}

/// The planner validates every route the protocol uses, not just the
/// forward data legs. Killing router 1 leaves the legs 0 -> 4 and
/// 4 -> 5 clean, but the cfg descriptor for hop 5 travels the direct
/// route 0 -> 5 through the dead router — so 5 must be lost, not
/// chained behind 4 (where its missing grant would wedge the chain).
#[test]
fn cfg_route_damage_loses_the_hop_despite_clean_data_legs() {
    let deg = degraded(&[1]);
    assert!(deg.path_is_clean(NodeId(0), NodeId(4)) && deg.path_is_clean(NodeId(4), NodeId(5)));
    assert!(!deg.path_is_clean(NodeId(0), NodeId(5)), "geometry premise");
    let (chains, lost) =
        plan_repair_chains(&deg, Strategy::Greedy, NodeId(0), dests(&[4, 5]), false);
    assert_eq!(lost, vec![NodeId(5)]);
    assert_eq!(chains.len(), 1);
    assert_eq!(chain_nodes(&chains[0]), vec![4]);
}

/// Every emitted chain satisfies the full protocol-route invariant:
/// cfg src->hop, data prev->hop and grant/finish hop->prev all clean;
/// and lost is exactly the set of destinations unreachable both ways.
#[test]
fn plans_partition_dests_into_clean_chains_and_unreachable() {
    let src = NodeId(0);
    let all = [3, 5, 6, 9, 10, 12, 15];
    for kill in 1..16usize {
        let deg = degraded(&[kill]);
        let ds: Vec<usize> = all.iter().copied().filter(|&d| d != kill).collect();
        let (chains, lost) = plan_repair_chains(&deg, Strategy::Greedy, src, dests(&ds), false);
        let mut covered: Vec<usize> = lost.iter().map(|n| n.0).collect();
        for chain in &chains {
            let mut prev = src;
            for &(node, _, _) in chain {
                assert!(
                    deg.path_is_clean(src, node)
                        && deg.path_is_clean(prev, node)
                        && deg.path_is_clean(node, prev),
                    "kill {kill}: chain hop {node:?} has a dirty protocol route"
                );
                covered.push(node.0);
                prev = node;
            }
        }
        covered.sort_unstable();
        let mut expect = ds.clone();
        expect.sort_unstable();
        assert_eq!(covered, expect, "kill {kill}: chains + lost must partition the dests");
        for &l in &lost {
            assert!(
                !deg.path_is_clean(src, l) || !deg.path_is_clean(l, src),
                "kill {kill}: {l:?} was declared lost but is reachable both ways"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Diagnosis: naming the hop that killed a chain
// ---------------------------------------------------------------------------

fn faulted_coordinator(spec: &str) -> Coordinator {
    let cfg = SocConfig::custom(4, 4, 64 * 1024)
        .with_faults(FaultPlan::parse(spec).expect("valid fault spec"));
    Coordinator::new(cfg)
}

/// A killed router that is itself a chain hop is named directly.
#[test]
fn diagnose_names_dead_chain_hop() {
    let mut c = faulted_coordinator("router:5@100;timeout:500;norepair");
    let t = c
        .submit_simple(
            NodeId(0),
            &[NodeId(4), NodeId(5)],
            2048,
            EngineKind::Torrent(Strategy::Greedy),
            false,
        )
        .unwrap();
    c.run_to_completion(100_000);
    assert_eq!(t.status(&c), TaskStatus::Failed);
    let outcome = c.record(t).unwrap().outcome.clone().unwrap();
    match outcome {
        TaskOutcome::Failed { suspect, .. } => assert_eq!(suspect, Some(NodeId(5))),
        o => panic!("expected Failed, got {o:?}"),
    }
}

/// A dropped follower (live router, dead engines) is told apart from
/// fabric damage and named as the suspect.
#[test]
fn diagnose_names_dropped_follower() {
    let mut c = faulted_coordinator("drop:4@100;timeout:500;norepair");
    let t = c
        .submit_simple(
            NodeId(0),
            &[NodeId(4), NodeId(5)],
            2048,
            EngineKind::Torrent(Strategy::Greedy),
            false,
        )
        .unwrap();
    c.run_to_completion(100_000);
    assert_eq!(t.status(&c), TaskStatus::Failed);
    match c.record(t).unwrap().outcome.clone().unwrap() {
        TaskOutcome::Failed { suspect, .. } => assert_eq!(suspect, Some(NodeId(4))),
        o => panic!("expected Failed, got {o:?}"),
    }
}

/// Damage on a hop's cfg route (not on any data leg) is attributed to
/// that hop: with router 1 dead from cycle 0, hop 5 never receives its
/// descriptor even though every chain leg is clean.
#[test]
fn diagnose_names_hop_behind_dead_cfg_route() {
    let mut c = faulted_coordinator("router:1@0;timeout:500;norepair");
    let t = c
        .submit_simple(
            NodeId(0),
            &[NodeId(4), NodeId(5)],
            2048,
            EngineKind::Torrent(Strategy::Greedy),
            false,
        )
        .unwrap();
    c.run_to_completion(100_000);
    assert_eq!(t.status(&c), TaskStatus::Failed);
    match c.record(t).unwrap().outcome.clone().unwrap() {
        TaskOutcome::Failed { suspect, .. } => assert_eq!(suspect, Some(NodeId(5))),
        o => panic!("expected Failed, got {o:?}"),
    }
}

/// The per-router activity counters that back the diagnosis baseline:
/// routers on the task's routes move, routers off them stay flat.
#[test]
fn activity_counters_isolate_routers_off_the_route() {
    let mut c = Coordinator::new(SocConfig::custom(2, 2, 64 * 1024));
    let t = c
        .submit_simple(NodeId(0), &[NodeId(1)], 2048, EngineKind::Torrent(Strategy::Greedy), false)
        .unwrap();
    c.run_to_completion(100_000);
    assert_eq!(t.status(&c), TaskStatus::Done);
    assert!(c.soc.net.router_activity(NodeId(0)) > 0);
    assert!(c.soc.net.router_activity(NodeId(1)) > 0);
    // 0 -> 1 is a single east hop; the top row never sees a flit.
    assert_eq!(c.soc.net.router_activity(NodeId(2)), 0);
    assert_eq!(c.soc.net.router_activity(NodeId(3)), 0);
}

// ---------------------------------------------------------------------------
// Repair: re-chaining and idempotence
// ---------------------------------------------------------------------------

/// cfg-route damage with repair enabled: the task completes as Repaired,
/// serving hop 4 on a fresh chain and writing off unreachable hop 5 —
/// instead of re-issuing the doomed [4, 5] chain until the budget runs
/// out.
#[test]
fn repair_replans_around_cfg_route_damage() {
    let mut c = faulted_coordinator("router:1@0;timeout:500");
    let src = NodeId(0);
    let bytes = 2048usize;
    let payload: Vec<u8> = (0..bytes).map(|i| (i % 239) as u8).collect();
    let base = c.soc.map.base_of(src);
    c.soc.nodes[src.0].mem.write(base, &payload);
    let t = c
        .submit_simple(
            src,
            &[NodeId(4), NodeId(5)],
            bytes,
            EngineKind::Torrent(Strategy::Greedy),
            true,
        )
        .unwrap();
    c.run_to_completion(200_000);
    assert_eq!(t.status(&c), TaskStatus::Repaired);
    let rec = c.record(t).unwrap();
    assert_eq!(rec.repairs, 1, "one repair round suffices");
    match rec.outcome.clone().unwrap() {
        TaskOutcome::Repaired { suspect, served, lost, .. } => {
            assert_eq!(suspect, NodeId(5));
            assert_eq!(served, 1);
            assert_eq!(lost, vec![NodeId(5)]);
        }
        o => panic!("expected Repaired, got {o:?}"),
    }
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    assert_eq!(
        c.soc.nodes[4].mem.peek(c.soc.map.base_of(NodeId(4)) + half, bytes),
        &payload[..],
        "survivor must hold the payload"
    );
    assert!(c.latency_of(t).is_some(), "repaired tasks report a latency");
}

/// Repair is idempotent: the stall window is re-armed when replacement
/// chains are issued, so the watchdog firing every cycle afterwards
/// neither double-issues chains during the run nor disturbs a finished
/// record when invoked again by hand.
#[test]
fn repair_is_not_double_issued() {
    let mut c = faulted_coordinator("router:5@100;timeout:400");
    let t = c
        .submit_simple(
            NodeId(0),
            &[NodeId(4), NodeId(5)],
            2048,
            EngineKind::Torrent(Strategy::Greedy),
            false,
        )
        .unwrap();
    c.run_to_completion(200_000);
    assert_eq!(t.status(&c), TaskStatus::Repaired);
    assert_eq!(
        c.record(t).unwrap().repairs,
        1,
        "the detector ran every cycle after activation yet issued one repair round"
    );
    let outcome = c.record(t).unwrap().outcome.clone();
    for _ in 0..5 {
        c.watch_faults();
    }
    assert_eq!(c.record(t).unwrap().repairs, 1, "manual re-checks must not re-issue");
    assert_eq!(c.record(t).unwrap().outcome, outcome);
}

// ---------------------------------------------------------------------------
// Reroute: waypoint candidates revive hops the default routes lose
// ---------------------------------------------------------------------------

/// With reroute armed, the cfg-damaged hop from
/// `cfg_route_damage_loses_the_hop_despite_clean_data_legs` is chained
/// after all: the cfg leg 0 -> 5 detours through the YX corner 4 while
/// the clean legs keep their default routes.
#[test]
fn reroute_revives_a_cfg_damaged_hop() {
    let deg = degraded(&[1]);
    let (chains, lost) =
        plan_repair_chains(&deg, Strategy::Greedy, NodeId(0), dests(&[4, 5]), true);
    assert!(lost.is_empty(), "a clean waypoint exists for every leg");
    assert_eq!(chains.len(), 1);
    assert_eq!(chain_nodes(&chains[0]), vec![4, 5]);
    assert_eq!(chains[0][0].2, ChainVias::default(), "hop 4 needs no detour");
    let vias = chains[0][1].2;
    assert_eq!(vias.cfg, Some(NodeId(4)), "cfg 0 -> 5 detours via the YX corner");
    assert_eq!(vias.data, None, "data 4 -> 5 is clean by default");
    assert_eq!(vias.back, None, "grant/finish 5 -> 4 is clean by default");
}

/// Every leg of every rerouted chain is clean under its chosen route,
/// and reroute never loses more destinations than the default planner.
#[test]
fn rerouted_chains_satisfy_every_protocol_leg() {
    let src = NodeId(0);
    let all = [3, 5, 6, 9, 10, 12, 15];
    for kill in 1..16usize {
        let deg = degraded(&[kill]);
        let ds: Vec<usize> = all.iter().copied().filter(|&d| d != kill).collect();
        let (chains, lost) = plan_repair_chains(&deg, Strategy::Greedy, src, dests(&ds), true);
        let (_, lost_default) =
            plan_repair_chains(&deg, Strategy::Greedy, src, dests(&ds), false);
        assert!(
            lost.len() <= lost_default.len(),
            "kill {kill}: reroute lost more destinations than the default planner"
        );
        for chain in &chains {
            let mut prev = src;
            for &(node, _, vias) in chain {
                assert!(
                    deg.route_is_clean(src, vias.cfg, node),
                    "kill {kill}: dirty cfg leg to {node:?}"
                );
                assert!(
                    deg.route_is_clean(prev, vias.data, node),
                    "kill {kill}: dirty data leg to {node:?}"
                );
                assert!(
                    deg.route_is_clean(node, vias.back, prev),
                    "kill {kill}: dirty grant/finish leg from {node:?}"
                );
                prev = node;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Resume: partial-transfer equivalence properties
// ---------------------------------------------------------------------------

/// 2x2 chain 0 -> 1 -> 3; router 3 dies mid-stream. The dead boundary
/// sinks node 1's forwards but keeps returning credits, so node 1 still
/// receives and scatters the whole payload — only the finish back-prop
/// is lost. With resume armed, the repair recognizes the survivor's
/// watermark already covers the transfer and serves it without
/// re-streaming a single byte.
#[test]
fn fully_delivered_survivor_is_served_without_restreaming() {
    let bytes = 32 * 1024;
    let cfg = SocConfig::custom(2, 2, 64 * 1024)
        .with_faults(FaultPlan::parse("router:3@300;timeout:800;resume").unwrap());
    let mut c = Coordinator::new(cfg);
    let src = NodeId(0);
    let payload: Vec<u8> = (0..bytes).map(|i| (i * 31 % 253) as u8).collect();
    let base = c.soc.map.base_of(src);
    c.soc.nodes[src.0].mem.write(base, &payload);
    let t = c
        .submit_simple(
            src,
            &[NodeId(1), NodeId(3)],
            bytes,
            EngineKind::Torrent(Strategy::Greedy),
            true,
        )
        .unwrap();
    c.run_to_completion(2_000_000);
    assert_eq!(t.status(&c), TaskStatus::Repaired);
    match c.record(t).unwrap().outcome.clone().unwrap() {
        TaskOutcome::Repaired { served, lost, restreamed_bytes, .. } => {
            assert_eq!(served, 1);
            assert_eq!(lost, vec![NodeId(3)]);
            assert_eq!(restreamed_bytes, 0, "survivor held the full payload already");
        }
        o => panic!("expected Repaired, got {o:?}"),
    }
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    assert_eq!(
        c.soc.nodes[1].mem.peek(c.soc.map.base_of(NodeId(1)) + half, bytes),
        &payload[..],
        "survivor payload must be byte-exact"
    );
}

/// 4x4 chain 0 -> 4 -> 5; router 4 (the head hop) dies mid-stream,
/// stranding a delivered prefix at survivor 5. The repair needs reroute
/// either way — the default XY back route 5 -> 0 turns at the dead
/// router — and with resume armed on top, only the undelivered tail is
/// re-streamed. The survivor's payload is byte-exact in both modes:
/// resume splices the fresh tail onto the salvaged prefix.
#[test]
fn resume_restreams_only_the_tail_and_stays_byte_exact() {
    let bytes = 64 * 1024;
    let payload: Vec<u8> = (0..bytes).map(|i| (i * 131 % 251) as u8).collect();
    let mut run = |spec: &str| -> u64 {
        let cfg = SocConfig::custom(4, 4, 256 * 1024)
            .with_faults(FaultPlan::parse(spec).unwrap());
        let mut c = Coordinator::new(cfg);
        let src = NodeId(0);
        let base = c.soc.map.base_of(src);
        c.soc.nodes[src.0].mem.write(base, &payload);
        let t = c
            .submit_simple(
                src,
                &[NodeId(4), NodeId(5)],
                bytes,
                EngineKind::Torrent(Strategy::Greedy),
                true,
            )
            .unwrap();
        c.run_to_completion(4_000_000);
        assert_eq!(t.status(&c), TaskStatus::Repaired, "{spec}");
        let restreamed = match c.record(t).unwrap().outcome.clone().unwrap() {
            TaskOutcome::Repaired { served, lost, restreamed_bytes, .. } => {
                assert_eq!(served, 1, "{spec}: survivor 5 must be served");
                assert_eq!(lost, vec![NodeId(4)], "{spec}");
                restreamed_bytes
            }
            o => panic!("{spec}: expected Repaired, got {o:?}"),
        };
        let half = c.soc.cfg.spm_bytes as u64 / 2;
        assert_eq!(
            c.soc.nodes[5].mem.peek(c.soc.map.base_of(NodeId(5)) + half, bytes),
            &payload[..],
            "{spec}: survivor payload must be byte-exact"
        );
        restreamed
    };
    let full = run("router:4@600;timeout:1000;reroute");
    let tail = run("router:4@600;timeout:1000;reroute;resume");
    assert_eq!(full, bytes as u64, "without resume the survivor re-streams in full");
    assert!(tail < full, "resume must re-stream strictly fewer bytes ({tail} vs {full})");
    assert!(tail > 0, "the kill lands mid-stream, so an undelivered tail remains");
}

//! Packets, flits and the message vocabulary carried over the NoC.
//!
//! Links are 64 bytes/cycle (paper §IV-A), so one flit carries 64 B. A
//! packet is one head flit (routing + message metadata) followed by
//! `ceil(payload / 64)` body flits; the last flit is the tail. Payload
//! bytes ride the packet as an `Arc<Vec<u8>>` shared by all of its flits —
//! wormhole timing comes from flit accounting, data integrity from the
//! payload arriving with the tail. (`Arc`, not `Rc`: flits cross shard
//! boundaries under the parallel stepper, so everything a flit can carry
//! must be `Send`.)

use std::sync::Arc;

use super::topology::NodeId;

/// Link width: bytes moved per flit per cycle (64 B/CC, paper §IV-A).
pub const FLIT_BYTES: usize = 64;

/// Unique packet id.
///
/// Ids are *composed*, not sequentially counted: `(cycle, phase, node,
/// seq)` packed most-significant-first (see [`compose_id`]). The
/// lexicographic order of composed ids equals the allocation order the
/// old global counter produced — external sends happen between ticks,
/// dispatch-phase sends before engine-phase sends, nodes in index order
/// within a phase, calls in order within a node — so every ordered
/// structure keyed by id (NI ejection maps, forward tables) iterates
/// exactly as before. The payoff: a shard can allocate ids for its own
/// nodes with no cross-thread coordination and still produce the ids a
/// sequential run would have produced.
pub type PacketId = u64;

/// Bits of per-(cycle, phase, node) send sequence in a composed id.
pub const ID_SEQ_BITS: u32 = 12;
/// Bits of node index in a composed id (8191-node fabrics, 64×64 + slack).
pub const ID_NODE_BITS: u32 = 13;
/// Bits of tick phase in a composed id.
pub const ID_PHASE_BITS: u32 = 2;

/// Send issued outside any tick (test harnesses, task submission).
pub const PHASE_EXTERNAL: u8 = 0;
/// Send issued during the SoC packet-dispatch phase.
pub const PHASE_DISPATCH: u8 = 1;
/// Send issued during the SoC engine-tick phase (incl. the AXI slave).
pub const PHASE_ENGINE: u8 = 2;

/// Pack `(cycle, phase, node, seq)` into a [`PacketId`] whose numeric
/// order is the sequential allocation order (see [`PacketId`]).
pub fn compose_id(cycle: u64, phase: u8, node: usize, seq: u32) -> PacketId {
    debug_assert!(cycle < 1 << (64 - ID_SEQ_BITS - ID_NODE_BITS - ID_PHASE_BITS), "cycle overflow");
    debug_assert!((phase as u32) < 1 << ID_PHASE_BITS, "phase overflow");
    debug_assert!((node as u64) < 1 << ID_NODE_BITS, "node overflow");
    debug_assert!(seq < 1 << ID_SEQ_BITS, "per-cycle send sequence overflow");
    (cycle << (ID_PHASE_BITS + ID_NODE_BITS + ID_SEQ_BITS))
        | ((phase as u64) << (ID_NODE_BITS + ID_SEQ_BITS))
        | ((node as u64) << ID_SEQ_BITS)
        | seq as u64
}

/// Message vocabulary. The NoC treats these opaquely; the AXI layer and
/// the DMA engines give them meaning.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// AXI AW+W burst: write `bytes` at `addr` (payload carries the data).
    AxiWriteReq { addr: u64, bytes: usize, axi_id: u16 },
    /// AXI B response.
    AxiWriteResp { axi_id: u16, ok: bool },
    /// AXI AR request: read `bytes` from `addr`.
    AxiReadReq { addr: u64, bytes: usize, axi_id: u16 },
    /// AXI R response burst (payload carries the data).
    AxiReadResp { axi_id: u16, ok: bool },
    /// Torrent cross-DMA configuration frames (payload = encoded cfg).
    TorrentCfg { task: u32 },
    /// Chainwrite Grant, propagated tail -> head.
    TorrentGrant { task: u32 },
    /// Chainwrite Finish, propagated tail -> head.
    TorrentFinish { task: u32 },
    /// Chainwrite data stream segment (payload = data; `seq` orders segments).
    ChainData { task: u32, seq: u32, last: bool },
    /// Multicast data stream segment (ESP-style network-layer multicast).
    McastData { task: u32, seq: u32, last: bool, addr: u64 },
    /// Multicast delivery acknowledgement (dest -> source).
    McastAck { task: u32, seq: u32 },
    /// Test-only raw message.
    Raw(u64),
}

/// A NoC packet.
#[derive(Debug, Clone)]
pub struct Packet {
    pub id: PacketId,
    pub src: NodeId,
    pub dst: NodeId,
    pub msg: Message,
    /// Payload byte count (determines body-flit count). May exceed
    /// `payload.len()` only when a test models phantom data.
    pub payload_bytes: usize,
    /// Actual data moved, if any.
    pub payload: Option<Arc<Vec<u8>>>,
    /// ESP-style multicast destination set; `dst` is ignored when set.
    pub mcast_dsts: Option<Arc<Vec<NodeId>>>,
    /// Waypoint routing override (repair reroute): routers steer toward
    /// `via` while the current node lies on `path(src, via)` before
    /// `via`, then toward `dst`. `None` (the default) is the zero-cost
    /// healthy path — routing is untouched and golden pins hold.
    pub via: Option<NodeId>,
}

impl Packet {
    pub fn new(id: PacketId, src: NodeId, dst: NodeId, msg: Message) -> Self {
        Packet { id, src, dst, msg, payload_bytes: 0, payload: None, mcast_dsts: None, via: None }
    }

    /// Route this packet through waypoint `via` (see the field docs).
    /// The planner guarantees the detour is simple (`noc::Degraded::
    /// route_is_clean`); a non-simple waypoint would loop forever.
    pub fn with_via(mut self, via: Option<NodeId>) -> Self {
        self.via = via;
        self
    }

    pub fn with_payload(mut self, data: Vec<u8>) -> Self {
        self.payload_bytes = data.len();
        self.payload = Some(Arc::new(data));
        self
    }

    /// Account payload length without materializing bytes (pure-timing runs).
    pub fn with_phantom_payload(mut self, bytes: usize) -> Self {
        self.payload_bytes = bytes;
        self.payload = None;
        self
    }

    /// Attach an already-shared payload without copying (the Torrent data
    /// switch forwards the incoming stream's bytes to the next hop).
    pub fn with_shared_payload(mut self, data: Option<Arc<Vec<u8>>>, bytes: usize) -> Self {
        self.payload_bytes = bytes;
        self.payload = data;
        self
    }

    pub fn with_mcast(mut self, dsts: Vec<NodeId>) -> Self {
        self.mcast_dsts = Some(Arc::new(dsts));
        self
    }

    /// Total flits: 1 head + ceil(payload/FLIT_BYTES) body.
    pub fn len_flits(&self) -> usize {
        1 + self.payload_bytes.div_ceil(FLIT_BYTES)
    }
}

/// One flit of a packet in flight. All flits of a packet share the
/// `Arc<Packet>`; `seq` runs 0..len_flits.
#[derive(Debug, Clone)]
pub struct Flit {
    pub packet: Arc<Packet>,
    pub seq: u32,
}

impl Flit {
    pub fn is_head(&self) -> bool {
        self.seq == 0
    }

    pub fn is_tail(&self) -> bool {
        self.seq as usize == self.packet.len_flits() - 1
    }
}

/// Expand a packet into its flit sequence (used by injection queues).
pub fn flits_of(packet: Arc<Packet>) -> impl Iterator<Item = Flit> {
    let n = packet.len_flits() as u32;
    (0..n).map(move |seq| Flit { packet: packet.clone(), seq })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(bytes: usize) -> Packet {
        Packet::new(1, NodeId(0), NodeId(1), Message::Raw(0)).with_phantom_payload(bytes)
    }

    #[test]
    fn flit_count_header_plus_body() {
        assert_eq!(pkt(0).len_flits(), 1); // head only
        assert_eq!(pkt(1).len_flits(), 2);
        assert_eq!(pkt(64).len_flits(), 2);
        assert_eq!(pkt(65).len_flits(), 3);
        assert_eq!(pkt(4096).len_flits(), 65);
    }

    #[test]
    fn head_and_tail_flags() {
        let p = Arc::new(pkt(128));
        let fl: Vec<Flit> = flits_of(p).collect();
        assert_eq!(fl.len(), 3);
        assert!(fl[0].is_head() && !fl[0].is_tail());
        assert!(!fl[1].is_head() && !fl[1].is_tail());
        assert!(fl[2].is_tail());
    }

    #[test]
    fn single_flit_packet_is_head_and_tail() {
        let p = Arc::new(pkt(0));
        let fl: Vec<Flit> = flits_of(p).collect();
        assert!(fl[0].is_head() && fl[0].is_tail());
    }

    #[test]
    fn payload_roundtrip() {
        let data: Vec<u8> = (0..200).map(|i| i as u8).collect();
        let p = Packet::new(2, NodeId(0), NodeId(3), Message::Raw(1)).with_payload(data.clone());
        assert_eq!(p.payload_bytes, 200);
        assert_eq!(p.len_flits(), 1 + 4);
        assert_eq!(&**p.payload.as_ref().unwrap(), &data);
    }

    #[test]
    fn composed_ids_sort_in_sequential_allocation_order() {
        // External < dispatch < engine at the same cycle; node order
        // within a phase; call order within a node; cycle dominates all.
        let ids = [
            compose_id(5, PHASE_EXTERNAL, 3, 0),
            compose_id(5, PHASE_DISPATCH, 0, 0),
            compose_id(5, PHASE_DISPATCH, 0, 1),
            compose_id(5, PHASE_DISPATCH, 2, 0),
            compose_id(5, PHASE_ENGINE, 1, 0),
            compose_id(6, PHASE_EXTERNAL, 0, 0),
        ];
        for w in ids.windows(2) {
            assert!(w[0] < w[1], "composed order violated: {:#x} !< {:#x}", w[0], w[1]);
        }
    }
}

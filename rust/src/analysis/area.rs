//! 16 nm area model (paper §IV-F, Fig 11(a–c,g) and Fig 1(d)).
//!
//! Calibrated with every absolute number the paper publishes:
//! 2.8 mm² SoC; CVA6 5.9 %, cluster-0 23.3 %, global SRAM 16.6 %;
//! Torrent = 5.3 % of a cluster ≈ 1/5 of the GeMM accelerator; the
//! global-memory Torrent 0.6 % of the SoC; +0.65 % SoC area per
//! additional maximum destination; 207 µm² per destination.

/// Total synthesized SoC area (4 clusters + global SRAM + CVA6), µm².
pub const SOC_AREA_UM2: f64 = 2.8e6;
/// Fig 11(a) shares.
pub const CVA6_SHARE: f64 = 0.059;
pub const CLUSTER0_SHARE: f64 = 0.233;
pub const GLOBAL_SRAM_SHARE: f64 = 0.166;
/// Torrent share of a cluster (Fig 11(b)).
pub const TORRENT_CLUSTER_SHARE: f64 = 0.053;
/// Chainwrite per-destination hardware increment (Fig 11(g)).
pub const TORRENT_PER_DEST_UM2: f64 = 207.0;
/// Reference N_dst,max the synthesized Torrent was configured with.
pub const TORRENT_REF_NDST: usize = 8;

/// One row of an area breakdown.
#[derive(Debug, Clone)]
pub struct AreaItem {
    pub name: &'static str,
    pub um2: f64,
}

impl AreaItem {
    pub fn share_of(&self, total: f64) -> f64 {
        self.um2 / total
    }
}

/// Cluster-0 (full cluster) area in µm².
pub fn cluster0_area_um2() -> f64 {
    SOC_AREA_UM2 * CLUSTER0_SHARE
}

/// Initiator-Torrent area as a function of the configured maximum
/// destination count (Fig 11(g)): a fixed frontend/backend base plus
/// 207 µm² of cfg/chain state per destination.
pub fn torrent_area_um2(ndst_max: usize) -> f64 {
    let ref_area = cluster0_area_um2() * TORRENT_CLUSTER_SHARE;
    let base = ref_area - TORRENT_REF_NDST as f64 * TORRENT_PER_DEST_UM2;
    base + ndst_max as f64 * TORRENT_PER_DEST_UM2
}

/// ESP-style multicast router area vs maximum destination count
/// (Fig 1(d)): the destination-set CAM, replication crossbar and wider
/// VC state grow with N — modelled as a base mesh router plus a
/// per-destination term an order of magnitude above Torrent's, matching
/// the paper's O(N) vs ~O(1) contrast.
pub fn mcast_router_area_um2(ndst_max: usize) -> f64 {
    const ROUTER_BASE_UM2: f64 = 18_000.0;
    const PER_DEST_UM2: f64 = 2_300.0;
    ROUTER_BASE_UM2 + ndst_max as f64 * PER_DEST_UM2
}

/// Fig 11(a) SoC-level breakdown for the 4-cluster synthesis SoC.
pub fn soc_area_breakdown() -> Vec<AreaItem> {
    let cluster0 = cluster0_area_um2();
    let cva6 = SOC_AREA_UM2 * CVA6_SHARE;
    let sram = SOC_AREA_UM2 * GLOBAL_SRAM_SHARE;
    let torrent_gm = SOC_AREA_UM2 * 0.006;
    // Three GeMM-less clusters share the remainder with the NoC.
    let others = SOC_AREA_UM2 - cluster0 - cva6 - sram - torrent_gm;
    let lite_cluster = others * 0.27; // three of these + NoC/misc
    vec![
        AreaItem { name: "cluster0 (full, GeMM)", um2: cluster0 },
        AreaItem { name: "cluster1 (GeMM-less)", um2: lite_cluster },
        AreaItem { name: "cluster2 (GeMM-less)", um2: lite_cluster },
        AreaItem { name: "cluster3 (GeMM-less)", um2: lite_cluster },
        AreaItem { name: "CVA6 host core", um2: cva6 },
        AreaItem { name: "global SRAM (512KB)", um2: sram },
        AreaItem { name: "global-mem Torrent", um2: torrent_gm },
        AreaItem { name: "NoC + misc", um2: others - 3.0 * lite_cluster },
    ]
}

/// Fig 11(b) cluster-scope breakdown.
pub fn cluster_area_breakdown() -> Vec<AreaItem> {
    let total = cluster0_area_um2();
    let torrent = total * TORRENT_CLUSTER_SHARE;
    let gemm = torrent * 5.0; // Torrent ≈ 1/5 of the GeMM accelerator
    let spm = total * 0.52; // 256 KB SRAM dominates
    let cores = total * 0.09;
    vec![
        AreaItem { name: "scratchpad SRAM", um2: spm },
        AreaItem { name: "GeMM accelerator", um2: gemm },
        AreaItem { name: "Torrent", um2: torrent },
        AreaItem { name: "RV32 cores", um2: cores },
        AreaItem { name: "cluster misc", um2: total - spm - gemm - torrent - cores },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums_to_soc_area() {
        let total: f64 = soc_area_breakdown().iter().map(|i| i.um2).sum();
        assert!((total - SOC_AREA_UM2).abs() < 1.0, "sum {total}");
    }

    #[test]
    fn cluster_breakdown_sums() {
        let total: f64 = cluster_area_breakdown().iter().map(|i| i.um2).sum();
        assert!((total - cluster0_area_um2()).abs() < 1.0);
    }

    #[test]
    fn torrent_slope_is_207_um2_per_dest() {
        let d = torrent_area_um2(9) - torrent_area_um2(8);
        assert!((d - 207.0).abs() < 1e-9);
    }

    #[test]
    fn torrent_area_matches_published_share_at_ref() {
        let share = torrent_area_um2(TORRENT_REF_NDST) / cluster0_area_um2();
        assert!((share - 0.053).abs() < 1e-6);
    }

    #[test]
    fn torrent_scaling_is_far_below_mcast_router() {
        // Fig 1(d): growing N_dst,max 2 -> 64 barely moves Torrent but
        // multiplies the multicast router's area.
        let t_growth = torrent_area_um2(64) / torrent_area_um2(2);
        let m_growth = mcast_router_area_um2(64) / mcast_router_area_um2(2);
        assert!(t_growth < 1.6, "torrent grew {t_growth}x");
        assert!(m_growth > 5.0, "mcast router grew only {m_growth}x");
    }

    #[test]
    fn per_dest_soc_share_near_published() {
        // +0.65% of SoC area per destination across 5 Torrents ~= 5*207/2.8e6.
        let share = 5.0 * TORRENT_PER_DEST_UM2 / SOC_AREA_UM2;
        assert!(share < 0.0065, "share {share}");
    }
}

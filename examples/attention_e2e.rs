//! End-to-end driver: DeepSeek-V3-style single-head attention on the
//! simulated 3×3 SoC, proving all three layers compose.
//!
//! Flow (mirrors the paper's §IV-E scenario at e2e scale):
//!   1. PJRT executes the AOT-compiled `kv_recovery` artifact (L2 JAX +
//!      L1 Pallas) to up-project a compressed MLA latent into K and V;
//!   2. the K and V matrices are written into cluster 0's scratchpad and
//!      **Chainwritten** (real bytes, four-phase protocol, TSP order) to
//!      the 8 accelerator clusters; byte-exactness is asserted at every
//!      destination;
//!   3. every cluster reads K/V back from its scratchpad, runs the
//!      `attn_prefill` artifact on its own head's Q, and the result is
//!      checked against a Rust-side f64 attention oracle;
//!   4. the same movement is replayed over the XDMA baseline and the
//!      speedup + GeMM-accelerator timing model are reported.
//!
//! Run: `cargo run --release --example attention_e2e`
//!
//! The default build evaluates the artifacts on the pure-Rust reference
//! backend (only `artifacts/manifest.txt` is needed — committed in this
//! repo); with `--features pjrt` and a real `xla` dependency the same
//! calls execute the `make artifacts` HLO on XLA (DESIGN.md §5).

use torrent::cluster::{GemmAccel, GemmMode};
use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest};
use torrent::dma::torrent::dse::AffinePattern;
use torrent::noc::NodeId;
use torrent::runtime::{Engine, Tensor};
use torrent::sched::Strategy;
use torrent::soc::SocConfig;

const SEQ: usize = 256;
const D_HEAD: usize = 64;
const D_LATENT: usize = 128;

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    xs.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn bytes_to_f32s(bs: &[u8]) -> Vec<f32> {
    bs.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

/// f64 attention oracle (independent of JAX/XLA).
fn attention_oracle(q: &Tensor, k: &Tensor, v: &Tensor) -> Vec<f32> {
    let (t, d) = (SEQ, D_HEAD);
    let scale = 1.0 / (d as f64).sqrt();
    let mut out = vec![0f32; t * d];
    for i in 0..t {
        let mut scores = vec![0f64; t];
        for j in 0..t {
            let mut s = 0f64;
            for e in 0..d {
                s += q.data[i * d + e] as f64 * k.data[j * d + e] as f64;
            }
            scores[j] = s * scale;
        }
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = scores.iter().map(|s| (s - m).exp()).collect();
        let z: f64 = exps.iter().sum();
        for e in 0..d {
            let mut acc = 0f64;
            for j in 0..t {
                acc += exps[j] / z * v.data[j * d + e] as f64;
            }
            out[i * d + e] = acc as f32;
        }
    }
    out
}

fn allclose(a: &[f32], b: &[f32], atol: f32) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= atol)
}

fn main() -> anyhow::Result<()> {
    println!("=== attention_e2e: PJRT compute + Chainwrite movement on a 3x3 SoC ===");
    let engine = Engine::load("artifacts")?;
    println!("PJRT platform: {}; artifacts: {:?}", engine.platform(), engine.names());

    // ---- 1. MLA KV recovery through the Pallas/XLA artifact -------------
    let c_kv = Tensor::random(vec![SEQ, D_LATENT], 1);
    let w_uk = Tensor::random(vec![D_LATENT, D_HEAD], 2);
    let w_uv = Tensor::random(vec![D_LATENT, D_HEAD], 3);
    let kv = engine.run("kv_recovery", &[c_kv.clone(), w_uk.clone(), w_uv.clone()])?;
    let (k, v) = (&kv[0], &kv[1]);
    println!("kv_recovery: K{:?} V{:?} recovered from latent {:?}", k.shape, v.shape, c_kv.shape);

    // ---- 2. Chainwrite K and V to all 8 accelerator clusters ------------
    let mut coord = Coordinator::new(SocConfig::fpga_3x3());
    let src = NodeId(0);
    let base0 = coord.soc.map.base_of(src);
    let k_bytes = f32s_to_bytes(&k.data);
    let v_bytes = f32s_to_bytes(&v.data);
    coord.soc.nodes[0].mem.write(base0, &k_bytes);
    coord.soc.nodes[0].mem.write(base0 + k_bytes.len() as u64, &v_bytes);

    let dest_nodes: Vec<NodeId> = (1..9).map(NodeId).collect();
    let mk_dests = |coord: &Coordinator, off: u64, len: usize| {
        dest_nodes
            .iter()
            .map(|&n| {
                (n, AffinePattern::contiguous(coord.soc.map.base_of(n) + off, len))
            })
            .collect::<Vec<_>>()
    };
    let t_k = coord
        .submit(
            P2mpRequest::to_patterns(mk_dests(&coord, 0, k_bytes.len()))
                .src(src)
                .read(AffinePattern::contiguous(base0, k_bytes.len()))
                .engine(EngineKind::Torrent(Strategy::Tsp))
                .with_data(true),
        )
        .expect("valid K request");
    let t_v = coord
        .submit(
            P2mpRequest::to_patterns(mk_dests(&coord, k_bytes.len() as u64, v_bytes.len()))
                .src(src)
                .read(AffinePattern::contiguous(base0 + k_bytes.len() as u64, v_bytes.len()))
                .engine(EngineKind::Torrent(Strategy::Tsp))
                .with_data(true),
        )
        .expect("valid V request");
    coord.run_to_completion(50_000_000);
    let lat_k = coord.latency_of(t_k).expect("K chainwrite done");
    let lat_v = coord.latency_of(t_v).expect("V chainwrite done");
    println!(
        "chainwrite: K ({} KB) {} CC, V ({} KB) {} CC to {} clusters",
        k_bytes.len() / 1024,
        lat_k,
        v_bytes.len() / 1024,
        lat_v,
        dest_nodes.len()
    );

    // Byte-exact delivery at every cluster.
    for &n in &dest_nodes {
        let b = coord.soc.map.base_of(n);
        assert_eq!(coord.soc.nodes[n.0].mem.peek(b, k_bytes.len()), &k_bytes[..]);
        assert_eq!(
            coord.soc.nodes[n.0].mem.peek(b + k_bytes.len() as u64, v_bytes.len()),
            &v_bytes[..]
        );
    }
    println!("data integrity: all {} destinations byte-exact", dest_nodes.len());

    // ---- 3. Per-cluster attention through the PJRT artifact -------------
    let mut accel = GemmAccel::new();
    let mut checked = 0;
    for (h, &n) in dest_nodes.iter().enumerate() {
        let b = coord.soc.map.base_of(n);
        let k_local = Tensor::new(
            vec![SEQ, D_HEAD],
            bytes_to_f32s(coord.soc.nodes[n.0].mem.peek(b, k_bytes.len())),
        );
        let v_local = Tensor::new(
            vec![SEQ, D_HEAD],
            bytes_to_f32s(
                coord.soc.nodes[n.0].mem.peek(b + k_bytes.len() as u64, v_bytes.len()),
            ),
        );
        let q_h = Tensor::random(vec![SEQ, D_HEAD], 100 + h as u64);
        let out = engine.run("attn_prefill", &[q_h.clone(), k_local.clone(), v_local.clone()])?;
        let want = attention_oracle(&q_h, &k_local, &v_local);
        assert!(
            allclose(&out[0].data, &want, 2e-3),
            "cluster {n:?} attention mismatch vs f64 oracle"
        );
        // Charge the accelerator timing model (two GeMMs per head).
        accel.launch(GemmMode::Prefill, SEQ, D_HEAD, SEQ, 0);
        accel.launch(GemmMode::Prefill, SEQ, SEQ, D_HEAD, 0);
        checked += 1;
    }
    println!("attention: {checked} heads computed via PJRT, all match the f64 oracle");
    println!(
        "accelerator model: {} tile-ops, {} busy cycles/cluster (2 GeMMs/head)",
        accel.counters.tile_ops,
        accel.counters.busy_cycles / checked as u64
    );

    // ---- 4. XDMA baseline for the same movement --------------------------
    let mut base = Coordinator::new(SocConfig::fpga_3x3());
    base.soc.nodes[0].mem.write(base0, &k_bytes);
    let t_x = base
        .submit(
            P2mpRequest::to_patterns(mk_dests(&base, 0, k_bytes.len()))
                .src(src)
                .read(AffinePattern::contiguous(base0, k_bytes.len()))
                .engine(EngineKind::Xdma)
                .with_data(true),
        )
        .expect("valid XDMA request");
    base.run_to_completion(200_000_000);
    let lat_x = base.latency_of(t_x).expect("xdma done");
    println!(
        "movement speedup (K matrix): XDMA {} CC / Chainwrite {} CC = {:.2}x",
        lat_x,
        lat_k,
        lat_x as f64 / lat_k as f64
    );
    println!("=== attention_e2e OK ===");
    Ok(())
}

//! Simulator-core micro-benchmarks — the §Perf L3 harness.
//!
//! Measures the hot paths the figure sweeps are built on: raw network
//! tick throughput under load, end-to-end Chainwrite simulation rate
//! (under both step modes — the activity-tracked kernel's headline), and
//! the schedulers at Fig-6 scale. Run before/after optimizations; the
//! iteration log lives in EXPERIMENTS.md §Perf.
//!
//! CI integration: `make bench-smoke` runs one iteration per bench and
//! compares against the committed `BENCH_simcore.json`, failing on
//! panic, on a >2x absolute-p50 regression when run on the machine that
//! calibrated the baseline, or — machine-independently, so ephemeral CI
//! runners enforce it too — on the event-driven/full-tick speedup ratio
//! collapsing below half its calibrated value. `make bench-baseline`
//! rewrites the baseline from a real run.
mod common;

use torrent::coordinator::{Coordinator, EngineKind};
use torrent::noc::{Mesh, Message, Network, NodeId, Packet};
use torrent::sched::{self, Strategy};
use torrent::sim::StepMode;
use torrent::soc::SocConfig;
use torrent::workloads;

fn main() {
    common::banner("simcore: L3 hot-path micro-benchmarks");
    let mut results: Vec<(String, f64)> = Vec::new();
    let mut record = |name: &str, s: &torrent::util::stats::Summary| {
        results.push((name.to_string(), s.p50));
    };

    // 1. Saturated 8x8 network: all nodes stream to the opposite corner.
    let s = common::bench("net_8x8_saturated_10k_cycles", 1, common::iters(5), || {
        let mesh = Mesh::new(8, 8);
        let mut net = Network::new(mesh);
        for n in 0..64usize {
            let dst = NodeId(63 - n);
            if dst.0 != n {
                net.send(
                    NodeId(n),
                    Packet::new(0, NodeId(n), dst, Message::Raw(n as u64))
                        .with_phantom_payload(16 * 1024),
                );
            }
        }
        for _ in 0..10_000 {
            net.tick();
        }
    });
    let cycles_per_sec = 10_000.0 / (s.mean / 1e3);
    println!("  -> {:.2} M network-cycles/s on a 64-router mesh", cycles_per_sec / 1e6);
    record("net_8x8_saturated_10k_cycles", &s);

    // 2. End-to-end Chainwrite simulation rate (the Fig 5 unit of work).
    // Default stepping = activity-tracked; the full-tick run below is the
    // naive reference the tentpole speedup is measured against.
    let chainwrite = |mode: StepMode| {
        let mut c = Coordinator::with_step_mode(SocConfig::eval_4x5(), mode);
        let dests: Vec<NodeId> = (1..=8).map(NodeId).collect();
        c.submit_simple(NodeId(0), &dests, 64 * 1024, EngineKind::Torrent(Strategy::Greedy), false)
            .expect("valid request");
        c.run_to_completion(10_000_000);
        c
    };
    let mut skip_stats = (0u64, 0u64, 0u64); // (cycles skipped, total cycles, ticks)
    let s = common::bench("chainwrite_64kb_8dst_eval4x5", 1, common::iters(5), || {
        let c = chainwrite(StepMode::EventDriven);
        skip_stats = (c.soc.cycles_skipped, c.soc.net.cycle, c.soc.ticks_executed);
    });
    record("chainwrite_64kb_8dst_eval4x5", &s);
    let fast_p50 = s.p50;
    let s = common::bench("chainwrite_64kb_8dst_full_tick", 1, common::iters(5), || {
        chainwrite(StepMode::FullTick);
    });
    record("chainwrite_64kb_8dst_full_tick", &s);
    println!(
        "  -> event-driven vs full-tick: {:.2}x p50 ({} of {} cycles skipped, {} ticks)",
        s.p50 / fast_p50.max(1e-9),
        skip_stats.0,
        skip_stats.1,
        skip_stats.2,
    );

    // 3. Schedulers at the Fig-6 extremes.
    let mesh = Mesh::new(8, 8);
    let sets = workloads::random_dest_sets(&mesh, NodeId(0), 32, 64, 11);
    let s = common::bench("greedy_order_32dst_x64", 1, common::iters(10), || {
        for s in &sets {
            let _ = sched::greedy_order(&mesh, NodeId(0), s);
        }
    });
    record("greedy_order_32dst_x64", &s);
    let s = common::bench("tsp_2opt_32dst_x64", 1, common::iters(10), || {
        for s in &sets {
            let _ = sched::tsp_order(&mesh, NodeId(0), s);
        }
    });
    record("tsp_2opt_32dst_x64", &s);
    let mut rng = torrent::util::rng(3, torrent::util::stream::BENCH);
    let mut set15: Vec<NodeId> = Vec::new();
    for v in rng.sample_distinct(63, 15) {
        set15.push(NodeId(v + 1));
    }
    let s = common::bench("tsp_heldkarp_exact_15dst", 1, common::iters(5), || {
        let _ = sched::tsp_order(&mesh, NodeId(0), &set15);
    });
    record("tsp_heldkarp_exact_15dst", &s);

    // 4. Sharded-stepper scaling curve (`make bench-scaling`): saturated
    // all-to-opposite-corner traffic, fabric ticked through the parallel
    // kernel at a fixed ladder of thread counts and grid sizes. t=1 is
    // the sequential kernel (`tick_parallel(1)` collapses to `tick()`),
    // so each row's speedup column reads directly off the JSON. Gated
    // behind an env var: the 64x64 points are too slow for `bench-smoke`.
    if std::env::var("TORRENT_BENCH_SCALING").is_ok() {
        common::banner("simcore: sharded-stepper scaling (cycles/s vs threads)");
        const SCALE_CYCLES: u64 = 2_000;
        for (cols, rows) in [(8usize, 8usize), (16, 16), (32, 32), (64, 64)] {
            let mut seq_p50 = 0.0f64;
            for threads in [1usize, 2, 4, 8] {
                let name = format!("parallel_net_{cols}x{rows}_t{threads}");
                let s = common::bench(&name, 0, common::iters(3), || {
                    let mut net = Network::new(Mesh::new(cols, rows));
                    let n = cols * rows;
                    for src in 0..n {
                        let dst = NodeId(n - 1 - src);
                        if dst.0 != src {
                            net.send(
                                NodeId(src),
                                Packet::new(0, NodeId(src), dst, Message::Raw(src as u64))
                                    .with_phantom_payload(16 * 1024),
                            );
                        }
                    }
                    for _ in 0..SCALE_CYCLES {
                        net.tick_parallel(threads);
                    }
                });
                if threads == 1 {
                    seq_p50 = s.p50;
                }
                println!(
                    "  -> {cols}x{rows} t{threads}: {:.3} M cycles/s (speedup {:.2}x vs t1)",
                    SCALE_CYCLES as f64 / (s.p50 / 1e3) / 1e6,
                    seq_p50 / s.p50.max(1e-9),
                );
                record(&name, &s);
            }
        }
    }

    // Baseline plumbing (see module docs / Makefile).
    if let Ok(path) = std::env::var("TORRENT_BENCH_JSON") {
        let calibrated = std::env::var("TORRENT_BENCH_CALIBRATED").is_ok();
        let note = if calibrated {
            "calibrated from a real run via `make bench-baseline`"
        } else {
            "placeholder written without calibration; run `make bench-baseline`"
        };
        common::write_bench_json(&path, "simcore", calibrated, note, &results)
            .expect("write bench JSON");
        println!("wrote baseline {path} (calibrated={calibrated})");
    }
    if let Ok(path) = std::env::var("TORRENT_BENCH_BASELINE") {
        common::banner("simcore: baseline comparison");
        match common::read_bench_json(&path) {
            Err(e) => {
                // A named-but-unreadable baseline must fail the smoke run:
                // exiting 0 here would silently disarm the CI guard.
                eprintln!("baseline unavailable: {e}");
                std::process::exit(1);
            }
            Ok(base) => {
                let mut regressions = common::count_regressions(&results, &base);
                if common::ratio_regressed(
                    &results,
                    &base,
                    "chainwrite_64kb_8dst_eval4x5",
                    "chainwrite_64kb_8dst_full_tick",
                ) {
                    regressions += 1;
                }
                if regressions > 0 {
                    eprintln!("{regressions} bench regression(s) vs {path}");
                    std::process::exit(1);
                }
            }
        }
    }
}

"""Blocked online-softmax attention kernel vs the naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention


def _rand(shape, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize("t,d", [(64, 32), (128, 64), (256, 64), (96, 16)])
def test_flash_matches_naive(t, d):
    q, k, v = _rand((t, d), 1), _rand((t, d), 2), _rand((t, d), 3)
    got = flash_attention(q, k, v)
    want = ref.attention_prefill(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_block_size_sweep():
    q, k, v = _rand((128, 32), 4), _rand((128, 32), 5), _rand((128, 32), 6)
    want = ref.attention_prefill(q, k, v)
    for bq, bk in [(16, 16), (32, 64), (128, 128), (64, 32)]:
        got = flash_attention(q, k, v, bq=bq, bk=bk)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5, err_msg=f"{bq},{bk}")


def test_flash_cross_attention_shapes():
    # Decode-like: few queries against a long KV cache.
    q = _rand((8, 64), 7)
    k, v = _rand((512, 64), 8), _rand((512, 64), 9)
    got = flash_attention(q, k, v, bq=8, bk=64)
    want = ref.attention_prefill(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_stable_at_large_logits():
    # Online softmax must survive logits that overflow a naive exp.
    q, k, v = _rand((64, 32), 10, 40.0), _rand((64, 32), 11, 40.0), _rand((64, 32), 12)
    got = flash_attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(got)))
    want = ref.attention_prefill(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(1, 8).map(lambda v: v * 16),
    tk=st.integers(1, 8).map(lambda v: v * 16),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_hypothesis(t, tk, d, seed):
    q = _rand((t, d), seed)
    k, v = _rand((tk, d), seed + 1), _rand((tk, d), seed + 2)
    got = flash_attention(q, k, v)
    want = ref.attention_prefill(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

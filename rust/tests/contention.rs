//! ISSUE 10 differential suite: chain scheduling under contention.
//!
//! Three layers of guarantees around the load-aware scheduler:
//!
//! * **delivery** — every strategy stays byte-exact when the fabric is
//!   congested; steering around heat must never corrupt or drop data;
//! * **determinism** — each (strategy, congestion, trial) cell is
//!   bit-identical across FullTick / EventDriven / Parallel stepping,
//!   and replays identically run-to-run (latency, chain order, and
//!   partition width all compared);
//! * **partition correctness** — when the k-way partition pass fires,
//!   the sibling chains tile the planned order, serve every
//!   destination byte-exactly, report one joined result, and hold
//!   dependent tasks queued until the *last* sibling lands.
//!
//! Congestion geometry (4×4 cells): background unicast iDMA streams
//! hammer the eastward links of row 0 — the corridor every XY route
//! out of the corner source crosses first — exactly as in
//! `experiments::contention_sweep`. The partition test instead pins
//! the fabric-load picture directly via `Network::preload_load_view`,
//! so the dispatch-time snapshot is exact and the expected split is
//! hand-checkable.

use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest, TaskStatus};
use torrent::dma::idma::IdmaTask;
use torrent::dma::torrent::dse::AffinePattern;
use torrent::noc::{NodeId, LOAD_WINDOW};
use torrent::sched::load::hot_row_view;
use torrent::sched::{partition_chains, Strategy};
use torrent::sim::StepMode;
use torrent::soc::SocConfig;
use torrent::util::stream;

const FG_BYTES: usize = 8 * 1024;

/// One congested cell on a 4×4 mesh, mirroring the contention sweep's
/// level-2 geometry: two background streams heat row 0, then an 8 KB
/// Chainwrite to `{3, 12, 15}` dispatches with `strategy`. Returns
/// `(latency, chain order, partition width)` and asserts byte-exact
/// delivery at every destination on the way out.
fn run_congested_cell(
    strategy: Strategy,
    trial: usize,
    mode: StepMode,
) -> (u64, Vec<NodeId>, usize) {
    let seed = 2025u64;
    // Background keyed by (level=2, trial) only — every strategy and
    // every step mode replays the identical contention schedule.
    let mut rng = torrent::util::rng(seed, stream::CONTENTION + (2u64 << 16) + trial as u64);
    let mut c = Coordinator::with_step_mode(SocConfig::custom(4, 4, 64 * 1024), mode);
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    // Arm the load telemetry before any traffic flows.
    let _ = c.soc.net.load_view();
    let payload: Vec<u8> = (0..FG_BYTES).map(|i| (i as u64 * 131 + seed) as u8).collect();
    let base = c.soc.map.base_of(NodeId(0));
    c.soc.nodes[0].mem.write(base, &payload);
    for (i, &(s, d)) in [(1usize, 3usize), (2, 3)].iter().enumerate() {
        let bg = rng.range(24, 32) as usize * 1024;
        let read = AffinePattern::contiguous(c.soc.map.base_of(NodeId(s)), bg);
        let write = AffinePattern::contiguous(c.soc.map.base_of(NodeId(d)) + half, bg);
        c.soc.nodes[s].idma.submit(
            IdmaTask {
                task: 0x4000_0000 + i as u32,
                read,
                dests: vec![(NodeId(d), write)],
                with_data: false,
            },
            0,
        );
    }
    c.run_for(2 * LOAD_WINDOW);
    let dests = [NodeId(3), NodeId(12), NodeId(15)];
    let task = c
        .submit_simple(NodeId(0), &dests, FG_BYTES, EngineKind::Torrent(strategy), true)
        .expect("valid contention request");
    let lat = c.run_until_complete(task, 20_000_000);
    for d in dests {
        assert_eq!(
            c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(d) + half, FG_BYTES),
            &payload[..],
            "{strategy:?} trial {trial} {mode:?}: dest {d:?} not byte-exact under congestion"
        );
    }
    let rec = c.record(task).unwrap();
    (lat, rec.chain_order.clone().unwrap(), rec.partition_width())
}

/// Delivery under congestion: all four strategies stay byte-exact (the
/// helper asserts it), the chain order is a permutation of the
/// destination set, and the load-blind strategies never take the
/// partition path.
#[test]
fn congested_cells_deliver_byte_exact_payloads() {
    for strategy in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp, Strategy::LoadAware] {
        let (lat, order, width) = run_congested_cell(strategy, 0, StepMode::EventDriven);
        assert!(lat > 0, "{strategy:?}: zero-latency transfer is impossible");
        let mut sorted: Vec<usize> = order.iter().map(|n| n.0).collect();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![3, 12, 15], "{strategy:?}: order must permute the dests");
        if strategy != Strategy::LoadAware {
            assert_eq!(width, 0, "{strategy:?} must never dispatch a partition");
        }
    }
}

/// Step-mode parity: the same congested cell is bit-identical across
/// FullTick, EventDriven and Parallel{2} stepping — latency, chain
/// order and partition width. The EWMA folds only at dispatch-time
/// `load_view()` calls, so the snapshot the scheduler sees cannot
/// depend on how cycles were batched.
#[test]
fn congested_cells_are_bit_identical_across_step_modes() {
    for strategy in [Strategy::Greedy, Strategy::LoadAware] {
        let reference = run_congested_cell(strategy, 1, StepMode::EventDriven);
        for mode in [StepMode::FullTick, StepMode::Parallel { threads: 2 }] {
            let other = run_congested_cell(strategy, 1, mode);
            assert_eq!(reference, other, "{strategy:?} diverged under {mode:?}");
        }
    }
}

/// Replay determinism: two fresh coordinators fed the identical seeded
/// congestion produce the identical load-aware cell — the measured
/// EWMA, the steered order and the partition decision are all pure
/// functions of the simulated history.
#[test]
fn load_aware_replay_is_deterministic() {
    let a = run_congested_cell(Strategy::LoadAware, 2, StepMode::EventDriven);
    let b = run_congested_cell(Strategy::LoadAware, 2, StepMode::EventDriven);
    assert_eq!(a, b, "same seed, same cell — load-aware dispatch must replay");
}

/// An armed-but-idle fabric must not perturb dispatch: with telemetry
/// on and zero load, the load-aware strategy neither splits the chain
/// nor loses byte-exactness.
#[test]
fn idle_fabric_never_partitions() {
    let bytes = 4 * 1024;
    let mut c = Coordinator::new(SocConfig::custom(8, 8, 64 * 1024));
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    let _ = c.soc.net.load_view();
    let payload: Vec<u8> = (0..bytes).map(|i| (i * 37 % 251) as u8).collect();
    c.soc.nodes[0].mem.write(c.soc.map.base_of(NodeId(0)), &payload);
    let dests: Vec<NodeId> = [1, 2, 3, 4, 5, 6, 8, 16, 24, 32, 40, 48].map(NodeId).to_vec();
    let t = c
        .submit_simple(NodeId(0), &dests, bytes, EngineKind::Torrent(Strategy::LoadAware), true)
        .unwrap();
    c.run_until_complete(t, 20_000_000);
    assert_eq!(t.status(&c), TaskStatus::Done);
    let rec = c.record(t).unwrap();
    assert_eq!(rec.partition_width(), 0, "idle fabric must dispatch one chain");
    for &d in &dests {
        assert_eq!(
            c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(d) + half, bytes),
            &payload[..],
            "idle load-aware dispatch corrupted dest {d:?}"
        );
    }
}

/// The k-way partition as a dependency-correct sibling-task set.
///
/// Geometry (8×8, src 0, row 0 eastward saturated via
/// `preload_load_view`): six hot row-0 destinations plus six cold
/// column-0 destinations. The load-aware order serves the cold column
/// first, and the partition DP strictly prefers a 2-way split (max
/// segment + one chain overhead beats the single chain), so dispatch
/// must go down the sibling-chain path. The test then checks the
/// full contract:
///
/// * `partition_width()` reports 2, and re-running the planner on the
///   recorded order reproduces the split — the segments tile the
///   chain order exactly;
/// * every one of the 12 destinations is served byte-exactly and the
///   joined result counts all of them;
/// * a dependent task submitted `.after(&[parent])` stays `Queued`
///   while *any* sibling chain is still in flight, and completes once
///   the join releases it.
#[test]
fn partition_dispatches_dependency_correct_sibling_chains() {
    let bytes = 4 * 1024;
    let mut c = Coordinator::new(SocConfig::custom(8, 8, 64 * 1024));
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    let payload: Vec<u8> = (0..bytes).map(|i| (i * 37 % 251) as u8).collect();
    c.soc.nodes[0].mem.write(c.soc.map.base_of(NodeId(0)), &payload);
    // Pin the dispatch-time load picture: row 0 eastward fully hot.
    let view = hot_row_view(64, 8, 0, 1000);
    c.soc.net.preload_load_view(&view);
    let dests: Vec<NodeId> = [1, 2, 3, 4, 5, 6, 8, 16, 24, 32, 40, 48].map(NodeId).to_vec();
    let parent = c
        .submit_simple(NodeId(0), &dests, bytes, EngineKind::Torrent(Strategy::LoadAware), true)
        .unwrap();
    let child = c
        .submit(
            P2mpRequest::to(&[NodeId(9)])
                .src(NodeId(0))
                .bytes(1024)
                .engine(EngineKind::Torrent(Strategy::Greedy))
                .after(&[parent]),
        )
        .unwrap();
    assert_eq!(child.status(&c), TaskStatus::Queued, "dependent must start blocked");

    // Drive in small quanta so the DAG release is observable: while the
    // parent's sibling chains are in flight, the child must stay queued
    // — it may release only at the partition join.
    let mut guard = 0u32;
    while parent.status(&c) != TaskStatus::Done {
        assert_eq!(
            child.status(&c),
            TaskStatus::Queued,
            "dependent released before the partition join completed"
        );
        c.run_for(128);
        guard += 1;
        assert!(guard < 200_000, "partitioned parent never completed");
    }

    let rec = c.record(parent).unwrap();
    assert_eq!(rec.partition_width(), 2, "saturated row must force a 2-way split");
    let order = rec.chain_order.clone().expect("partitioned dispatch records the full order");
    let mut sorted: Vec<usize> = order.iter().map(|n| n.0).collect();
    sorted.sort_unstable();
    assert_eq!(sorted, vec![1, 2, 3, 4, 5, 6, 8, 16, 24, 32, 40, 48]);
    // The planner is deterministic: re-running it on the recorded order
    // under the pinned view reproduces the dispatched split, and the
    // segments concatenate back to the order (no dest dropped or
    // double-chained).
    let topo = c.soc.topo();
    let parts = partition_chains(&topo, NodeId(0), &order, &view);
    assert_eq!(parts.len(), rec.partition_width());
    let flat: Vec<NodeId> = parts.iter().flatten().copied().collect();
    assert_eq!(flat, order, "sibling segments must tile the chain order");
    for part in &parts {
        assert!(!part.is_empty(), "no empty sibling chain");
    }
    // The joined result speaks for the whole destination set.
    let result = rec.result.as_ref().expect("joined parent holds one result");
    assert_eq!(result.n_dests, 12);
    for &d in &dests {
        assert_eq!(
            c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(d) + half, bytes),
            &payload[..],
            "partitioned dispatch corrupted dest {d:?}"
        );
    }
    // The release actually happened: the child runs and completes.
    c.run_to_completion(2_000_000);
    assert_eq!(child.status(&c), TaskStatus::Done);
    assert!(child.latency(&c).is_some(), "released dependent must report a latency");
}

"""Pallas softmax kernel vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, softmax


def _rand(shape, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize("m,n", [(1, 8), (64, 64), (100, 256), (256, 2048)])
def test_softmax_matches_ref(m, n):
    x = _rand((m, n), seed=m + n)
    np.testing.assert_allclose(softmax(x), ref.softmax(x), rtol=1e-5, atol=1e-7)


def test_softmax_rows_sum_to_one():
    x = _rand((32, 128), seed=1)
    np.testing.assert_allclose(jnp.sum(softmax(x), axis=-1), jnp.ones(32), rtol=1e-5)


def test_softmax_large_magnitudes_stable():
    # Without the max-subtraction this overflows to nan.
    x = _rand((16, 64), seed=2, scale=200.0)
    y = softmax(x)
    assert bool(jnp.all(jnp.isfinite(y)))
    np.testing.assert_allclose(y, ref.softmax(x), rtol=1e-5, atol=1e-7)


def test_softmax_constant_row_is_uniform():
    x = jnp.full((4, 10), 3.5)
    np.testing.assert_allclose(softmax(x), jnp.full((4, 10), 0.1), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 30.0]),
)
def test_softmax_hypothesis(m, n, seed, scale):
    x = _rand((m, n), seed=seed, scale=scale)
    np.testing.assert_allclose(softmax(x), ref.softmax(x), rtol=1e-4, atol=1e-6)

//! Dependency-DAG pipeline: chained P2MP transfers expressed as one
//! batch of tasks with `after` edges, scheduled by the coordinator —
//! the paper's Fig 9 multi-step data movements as a task graph instead
//! of separate drained simulations.
//!
//! The DAG (4×4 mesh, real bytes, 8 tasks):
//!
//! ```text
//!   stage A:  0 ──chainwrite──▶ {1..6}            (scatter the operand)
//!   stage B:  i ──chainwrite──▶ {i+6}   i = 1..6  (six parallel hops,
//!                                                  each after A)
//!   stage C:  7 ──chainwrite──▶ {13,14,15}        (gather-side fan-out,
//!                                                  after all of stage B)
//! ```
//!
//! Stage B forwards the bytes stage A delivered, and stage C forwards a
//! stage-B result — so the final byte-exactness check proves the
//! dependency edges were honored *materially*, not just by timestamps.
//!
//! Run: `cargo run --release --example batch_pipeline`

use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest, TaskHandle, TaskStatus};
use torrent::dma::torrent::dse::AffinePattern;
use torrent::noc::NodeId;
use torrent::sched::Strategy;
use torrent::soc::SocConfig;

const LEN: usize = 8 * 1024;

fn main() {
    let mut c = Coordinator::new(SocConfig::custom(4, 4, 64 * 1024));
    let half = c.soc.cfg.spm_bytes as u64 / 2;

    // Seed the source operand at cluster 0.
    let payload: Vec<u8> = (0..LEN).map(|i| (i * 131 + 17) as u8).collect();
    let base0 = c.soc.map.base_of(NodeId(0));
    c.soc.nodes[0].mem.write(base0, &payload);

    // Stage A: scatter to clusters 1..6 (lands at window base + half).
    let stage_b_srcs: Vec<NodeId> = (1..=6).map(NodeId).collect();
    let a = c
        .submit(
            P2mpRequest::to(&stage_b_srcs)
                .src(NodeId(0))
                .bytes(LEN)
                .engine(EngineKind::Torrent(Strategy::Tsp))
                .with_data(true),
        )
        .expect("stage A request");

    // Stage B: each recipient forwards its copy one hop onward. The read
    // pattern targets the bytes stage A will deliver, so these tasks are
    // only correct because the `after` edge holds them back.
    let mut stage_b = Vec::new();
    for &src in &stage_b_srcs {
        let dst = NodeId(src.0 + 6);
        let read = AffinePattern::contiguous(c.soc.map.base_of(src) + half, LEN);
        let write = AffinePattern::contiguous(c.soc.map.base_of(dst) + half, LEN);
        let h = c
            .submit(
                P2mpRequest::to_patterns(vec![(dst, write)])
                    .read(read) // src derived from the read base (submit_auto semantics)
                    .engine(EngineKind::Torrent(Strategy::Greedy))
                    .with_data(true)
                    .after(&[a]),
            )
            .expect("stage B request");
        stage_b.push(h);
    }

    // Stage C: once every stage-B hop has landed, cluster 7 fans its
    // copy out to the last row.
    let finals = [NodeId(13), NodeId(14), NodeId(15)];
    let read_c = AffinePattern::contiguous(c.soc.map.base_of(NodeId(7)) + half, LEN);
    let c_dests: Vec<_> = finals
        .iter()
        .map(|&n| (n, AffinePattern::contiguous(c.soc.map.base_of(n) + half, LEN)))
        .collect();
    let last = c
        .submit(
            P2mpRequest::to_patterns(c_dests)
                .read(read_c)
                .engine(EngineKind::Torrent(Strategy::Tsp))
                .with_data(true)
                .after(&stage_b),
        )
        .expect("stage C request");

    println!("submitted {} tasks; statuses at cycle 0:", c.records.len());
    report(&c, a, &stage_b, last);

    // Drive stage A alone to completion: B is released mid-run.
    let lat_a = c.run_until_complete(a, 10_000_000);
    println!("\nstage A complete in {lat_a} CC; statuses now:");
    report(&c, a, &stage_b, last);

    // Drain the whole DAG.
    c.run_until_all_done(50_000_000);
    c.run_to_completion(50_000_000);
    println!("\nall {} tasks done at cycle {}:", c.records.len(), c.soc.cycle());
    for rec in &c.records {
        let res = rec.result.as_ref().expect("done");
        println!(
            "  {} {:>14} {:?} -> {} dests  [{:>6}, {:>6}]  ({} CC)",
            rec.task,
            rec.engine.label(),
            rec.src,
            rec.n_dests,
            res.submitted_at,
            res.finished_at,
            res.latency()
        );
    }

    // Dependency edges must hold on the timeline...
    let fin = |h| c.record(h).unwrap().result.as_ref().unwrap().finished_at;
    for &b in &stage_b {
        assert!(fin(a) < fin(b), "stage B started before stage A finished");
        assert!(fin(b) < fin(last), "stage C started before stage B finished");
    }
    // ...and materially: the last row holds the original operand after
    // three dependent hops.
    for &n in &finals {
        let got = c.soc.nodes[n.0].mem.peek(c.soc.map.base_of(n) + half, LEN);
        assert_eq!(got, &payload[..], "corrupt pipeline output at {n:?}");
    }
    println!("\ndata integrity: payload survived A -> B -> C at {finals:?}");
    println!("=== batch_pipeline OK ===");
}

fn report(c: &Coordinator, a: TaskHandle, stage_b: &[TaskHandle], last: TaskHandle) {
    let fmt = |s: TaskStatus| match s {
        TaskStatus::Queued => "queued",
        TaskStatus::Configuring => "configuring",
        TaskStatus::Streaming => "streaming",
        TaskStatus::Done => "done",
    };
    println!("  A: {}", fmt(a.status(c)));
    let b: Vec<&str> = stage_b.iter().map(|h| fmt(h.status(c))).collect();
    println!("  B: {b:?}");
    println!("  C: {}", fmt(last.status(c)));
}

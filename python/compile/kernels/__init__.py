"""L1 Pallas kernels (build-time only) + their pure-jnp oracles (ref)."""

from . import ref  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .gemm import decode_matvec, matmul, matmul_int8  # noqa: F401
from .relayout import relayout  # noqa: F401
from .softmax import softmax  # noqa: F401

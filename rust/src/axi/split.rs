//! AXI4 burst decomposition.
//!
//! A transfer `[addr, addr+len)` is split into INCR bursts that (a) never
//! cross a 4 KB boundary (AXI A3.4.1) and (b) never exceed 256 beats of
//! the 64 B data width — though the 4 KB rule binds first at this width
//! (4096 / 64 = 64 beats).

/// AXI 4 KB boundary.
pub const AXI_4K: u64 = 4096;
/// 256-beat INCR limit × 64 B beats.
pub const MAX_BURST_BYTES: usize = 256 * 64;

/// One AXI burst.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Burst {
    pub addr: u64,
    pub bytes: usize,
}

impl Burst {
    /// Beats at the 64 B data width (AWLEN+1).
    pub fn beats(&self) -> usize {
        self.bytes.div_ceil(64)
    }
}

/// Split `[addr, addr+len)` into legal AXI bursts, in address order.
pub fn split_bursts(addr: u64, len: usize) -> Vec<Burst> {
    let mut out = Vec::new();
    let mut cur = addr;
    let end = addr + len as u64;
    while cur < end {
        let to_4k = AXI_4K - (cur % AXI_4K);
        let bytes = (end - cur).min(to_4k).min(MAX_BURST_BYTES as u64) as usize;
        out.push(Burst { addr: cur, bytes });
        cur += bytes as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_transfer_splits_at_4k() {
        let b = split_bursts(0, 10 * 1024);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0], Burst { addr: 0, bytes: 4096 });
        assert_eq!(b[1], Burst { addr: 4096, bytes: 4096 });
        assert_eq!(b[2], Burst { addr: 8192, bytes: 2048 });
    }

    #[test]
    fn unaligned_start_trims_first_burst() {
        let b = split_bursts(4000, 200);
        assert_eq!(b[0], Burst { addr: 4000, bytes: 96 });
        assert_eq!(b[1], Burst { addr: 4096, bytes: 104 });
    }

    #[test]
    fn no_burst_crosses_4k() {
        for (addr, len) in [(0u64, 64 * 1024usize), (123, 9999), (4090, 20), (8191, 2)] {
            for b in split_bursts(addr, len) {
                let last = b.addr + b.bytes as u64 - 1;
                assert_eq!(b.addr / AXI_4K, last / AXI_4K, "burst {b:?} crosses 4K");
            }
        }
    }

    #[test]
    fn bursts_cover_exactly() {
        let (addr, len) = (777u64, 12345usize);
        let bs = split_bursts(addr, len);
        assert_eq!(bs[0].addr, addr);
        let total: usize = bs.iter().map(|b| b.bytes).sum();
        assert_eq!(total, len);
        for w in bs.windows(2) {
            assert_eq!(w[0].addr + w[0].bytes as u64, w[1].addr);
        }
    }

    #[test]
    fn zero_length_yields_no_bursts() {
        assert!(split_bursts(100, 0).is_empty());
    }

    #[test]
    fn beats_at_64b_width() {
        assert_eq!(Burst { addr: 0, bytes: 4096 }.beats(), 64);
        assert_eq!(Burst { addr: 0, bytes: 65 }.beats(), 2);
        assert_eq!(Burst { addr: 0, bytes: 1 }.beats(), 1);
    }
}

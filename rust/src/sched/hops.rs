//! Hop-count models — the implementation-agnostic Fig-6 metric
//! ("number of edges the data traverses divided by N_dst"), over any
//! [`Topology`] (legs cost the fabric's routing distance).

use crate::noc::{NodeId, Topology};

/// Total links the Chainwrite stream traverses: src -> order[0] -> ... ->
/// order[n-1], each leg routed by the fabric (= routing distance).
pub fn chain_hops(topo: &dyn Topology, src: NodeId, order: &[NodeId]) -> usize {
    let mut hops = 0;
    let mut cur = src;
    for &d in order {
        hops += topo.distance(cur, d);
        cur = d;
    }
    hops
}

/// Total links for repeated unicast: every destination is a separate
/// routed transfer from the source.
pub fn unicast_hops(topo: &dyn Topology, src: NodeId, dests: &[NodeId]) -> usize {
    dests.iter().map(|&d| topo.distance(src, d)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::multicast::mcast_tree_hops;
    use crate::noc::{Mesh, Ring, Torus};

    #[test]
    fn chain_hops_sums_legs() {
        let m = Mesh::new(4, 1);
        // 0 -> 2 -> 1 -> 3: 2 + 1 + 2 = 5
        assert_eq!(chain_hops(&m, NodeId(0), &[2, 1, 3].map(NodeId)), 5);
    }

    #[test]
    fn unicast_hops_sums_manhattan() {
        let m = Mesh::new(4, 4);
        assert_eq!(unicast_hops(&m, NodeId(0), &[NodeId(3), NodeId(12)]), 6);
    }

    #[test]
    fn empty_orders_are_zero() {
        let m = Mesh::new(4, 4);
        assert_eq!(chain_hops(&m, NodeId(0), &[]), 0);
        assert_eq!(unicast_hops(&m, NodeId(0), &[]), 0);
    }

    #[test]
    fn optimal_chain_can_reach_one_hop_per_dest() {
        // Fig 6's theoretical limit: a Hamiltonian-like chain over adjacent
        // nodes costs exactly 1 hop per destination.
        let m = Mesh::new(3, 1);
        let hops = chain_hops(&m, NodeId(0), &[1, 2].map(NodeId));
        assert_eq!(hops, 2); // = N_dst
    }

    #[test]
    fn mcast_tree_never_worse_than_unicast() {
        let m = Mesh::new(8, 8);
        let dests: Vec<NodeId> = [5, 13, 27, 45, 60].map(NodeId).to_vec();
        assert!(
            mcast_tree_hops(&m, NodeId(0), &dests) <= unicast_hops(&m, NodeId(0), &dests)
        );
    }

    #[test]
    fn wraparound_fabrics_never_cost_more_than_the_mesh() {
        // Same order, same node ids: every torus/ring leg is at most the
        // mesh leg (the shortest-arc min includes the non-wrap route).
        let mesh = Mesh::new(4, 4);
        let torus = Torus::new(4, 4);
        let ring = Ring::new(16);
        let order: Vec<NodeId> = [15, 3, 12, 7].map(NodeId).to_vec();
        let m = chain_hops(&mesh, NodeId(0), &order);
        assert!(chain_hops(&torus, NodeId(0), &order) <= m);
        assert!(unicast_hops(&torus, NodeId(0), &order) <= unicast_hops(&mesh, NodeId(0), &order));
        // The 16-ring wraps the far half of the id space.
        assert_eq!(ring.distance(NodeId(0), NodeId(15)), 1);
    }
}

//! Cross-module integration tests: full transfers on small SoCs with
//! data-integrity checks, mechanism equivalence, and workload-level runs.

use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest, TaskHandle};
use torrent::dma::torrent::dse::AffinePattern;
use torrent::noc::NodeId;
use torrent::sched::Strategy;
use torrent::soc::SocConfig;
use torrent::workloads::TABLE2;

fn coord(cols: usize, rows: usize, spm: usize) -> Coordinator {
    Coordinator::new(SocConfig::custom(cols, rows, spm))
}

fn seed_source(c: &mut Coordinator, node: NodeId, len: usize) -> Vec<u8> {
    let base = c.soc.map.base_of(node);
    let data: Vec<u8> = (0..len).map(|i| (i * 17 + 3) as u8).collect();
    c.soc.nodes[node.0].mem.write(base, &data);
    data
}

/// Every mechanism must deliver identical bytes to every destination.
#[test]
fn all_mechanisms_deliver_identical_data() {
    let len = 8 * 1024;
    let dests = vec![NodeId(1), NodeId(4), NodeId(8)];
    let mut reference: Option<Vec<Vec<u8>>> = None;
    for engine in [
        EngineKind::Torrent(Strategy::Greedy),
        EngineKind::Torrent(Strategy::Tsp),
        EngineKind::Idma,
        EngineKind::Xdma,
        EngineKind::Mcast,
    ] {
        let mut c = coord(3, 3, 64 * 1024);
        let data = seed_source(&mut c, NodeId(0), len);
        let task = c.submit_simple(NodeId(0), &dests, len, engine, true).unwrap();
        c.run_to_completion(10_000_000);
        assert!(c.latency_of(task).is_some(), "{engine:?} never finished");
        let half = c.soc.cfg.spm_bytes as u64 / 2;
        let delivered: Vec<Vec<u8>> = dests
            .iter()
            .map(|d| c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(*d) + half, len).to_vec())
            .collect();
        for (d, got) in dests.iter().zip(&delivered) {
            assert_eq!(got, &data, "{engine:?} corrupted data at {d:?}");
        }
        match &reference {
            None => reference = Some(delivered),
            Some(r) => assert_eq!(r, &delivered, "{engine:?} differs from reference"),
        }
    }
}

/// Chain order must not affect *what* is delivered, only when.
#[test]
fn chain_strategies_equivalent_payloads() {
    let len = 4 * 1024;
    let dests = vec![NodeId(2), NodeId(7), NodeId(5), NodeId(3)];
    let mut latencies = vec![];
    for strategy in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp] {
        let mut c = coord(3, 3, 32 * 1024);
        let data = seed_source(&mut c, NodeId(0), len);
        let task = c
            .submit_simple(NodeId(0), &dests, len, EngineKind::Torrent(strategy), true)
            .unwrap();
        c.run_to_completion(10_000_000);
        latencies.push(c.latency_of(task).unwrap());
        let half = c.soc.cfg.spm_bytes as u64 / 2;
        for d in &dests {
            assert_eq!(
                c.soc.nodes[d.0].mem.peek(c.soc.map.base_of(*d) + half, len),
                &data[..],
                "{strategy:?} at {d:?}"
            );
        }
    }
    // All finish, and the optimized orders should not be slower than naive
    // by more than noise.
    assert!(latencies[1] <= latencies[0] + 200, "greedy {latencies:?}");
    assert!(latencies[2] <= latencies[0] + 200, "tsp {latencies:?}");
}

/// Table II workload end-to-end through the coordinator with real bytes
/// and a layout transform: logical matrix must survive re-tiling.
#[test]
fn table2_p1_relayout_preserves_matrix() {
    let w = TABLE2[0]; // P1: MNM16N8 -> MNM8N8, 2048x192 int8
    // Scale down rows to keep the test fast, same tile geometry.
    let (rows, cols) = (128usize, w.cols);
    let bytes = rows * cols;
    let mut c = coord(3, 3, 1 << 20);
    let src = NodeId(0);
    let base_src = c.soc.map.base_of(src);
    let data: Vec<u8> = (0..bytes).map(|i| (i % 249) as u8).collect();
    c.soc.nodes[0].mem.write(base_src, &data);

    let read = torrent::workloads::table2::blocked_logical_order(
        base_src, rows, cols, w.in_layout,
    );
    let dst = NodeId(4);
    let base_dst = c.soc.map.base_of(dst);
    let write = torrent::workloads::table2::blocked_logical_order(
        base_dst, rows, cols, w.out_layout,
    );
    let task = c
        .submit(
            P2mpRequest::to_patterns(vec![(dst, write)])
                .src(src)
                .read(read)
                .engine(EngineKind::Torrent(Strategy::Greedy))
                .with_data(true),
        )
        .unwrap();
    c.run_to_completion(50_000_000);
    assert!(c.latency_of(task).is_some());

    // Element (r, c) in MNM16N8 at src must equal element (r, c) in
    // MNM8N8 at dst.
    let (tm_i, tn_i) = (w.in_layout.tm, w.in_layout.tn);
    let (tm_o, tn_o) = (w.out_layout.tm, w.out_layout.tn);
    for r in (0..rows).step_by(13) {
        for col in (0..cols).step_by(7) {
            let off_in = ((r / tm_i) * (cols / tn_i) + col / tn_i) * tm_i * tn_i
                + (r % tm_i) * tn_i
                + col % tn_i;
            let off_out = ((r / tm_o) * (cols / tn_o) + col / tn_o) * tm_o * tn_o
                + (r % tm_o) * tn_o
                + col % tn_o;
            assert_eq!(
                c.soc.nodes[0].mem.peek(base_src + off_in as u64, 1)[0],
                c.soc.nodes[4].mem.peek(base_dst + off_out as u64, 1)[0],
                "element ({r},{col})"
            );
        }
    }
}

/// Back-to-back tasks on one initiator queue and execute in order.
#[test]
fn queued_tasks_complete_in_submission_order() {
    let mut c = coord(3, 3, 64 * 1024);
    seed_source(&mut c, NodeId(0), 4096);
    let chain = EngineKind::Torrent(Strategy::Greedy);
    let t1 = c.submit_simple(NodeId(0), &[NodeId(4)], 4096, chain, false).unwrap();
    let t2 = c.submit_simple(NodeId(0), &[NodeId(8)], 4096, chain, false).unwrap();
    c.run_to_completion(10_000_000);
    let finished_at = |c: &Coordinator, t: TaskHandle| {
        c.record(t).unwrap().result.as_ref().unwrap().finished_at
    };
    let r1 = finished_at(&c, t1);
    let r2 = finished_at(&c, t2);
    assert!(r2 > r1, "second task must finish after the first");
}

/// A destination can itself initiate a chain concurrently (distributed
/// orchestration: every Torrent is initiator and follower).
#[test]
fn node_is_initiator_and_follower_simultaneously() {
    let mut c = coord(3, 3, 64 * 1024);
    let d0 = seed_source(&mut c, NodeId(0), 4096);
    let d4 = {
        let base = c.soc.map.base_of(NodeId(4)) + 0x4000;
        let data: Vec<u8> = (0..4096).map(|i| (i * 7 + 1) as u8).collect();
        c.soc.nodes[4].mem.write(base, &data);
        data
    };
    // Task A: 0 -> {4, 8}; Task B: 4 -> {2, 6}. Node 4 plays both roles.
    let chain = EngineKind::Torrent(Strategy::Greedy);
    let ta = c.submit_simple(NodeId(0), &[NodeId(4), NodeId(8)], 4096, chain, true).unwrap();
    let read_b = AffinePattern::contiguous(c.soc.map.base_of(NodeId(4)) + 0x4000, 4096);
    let dests_b: Vec<(NodeId, AffinePattern)> = [2usize, 6]
        .iter()
        .map(|&n| {
            let pat = AffinePattern::contiguous(c.soc.map.base_of(NodeId(n)) + 0x6000, 4096);
            (NodeId(n), pat)
        })
        .collect();
    let tb = c
        .submit(
            P2mpRequest::to_patterns(dests_b)
                .src(NodeId(4))
                .read(read_b)
                .engine(EngineKind::Torrent(Strategy::Greedy))
                .with_data(true),
        )
        .unwrap();
    c.run_to_completion(10_000_000);
    assert!(c.latency_of(ta).is_some() && c.latency_of(tb).is_some());
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    assert_eq!(c.soc.nodes[8].mem.peek(c.soc.map.base_of(NodeId(8)) + half, 4096), &d0[..]);
    assert_eq!(c.soc.nodes[2].mem.peek(c.soc.map.base_of(NodeId(2)) + 0x6000, 4096), &d4[..]);
    assert_eq!(c.soc.nodes[6].mem.peek(c.soc.map.base_of(NodeId(6)) + 0x6000, 4096), &d4[..]);
}

/// Tiny transfers (single burst, few flits) complete through all phases.
#[test]
fn minimal_transfer_sizes() {
    for len in [1usize, 63, 64, 65, 4096] {
        let mut c = coord(2, 2, 32 * 1024);
        let data = seed_source(&mut c, NodeId(0), len);
        let chain = EngineKind::Torrent(Strategy::Greedy);
        let task = c.submit_simple(NodeId(0), &[NodeId(3)], len, chain, true).unwrap();
        c.run_to_completion(1_000_000);
        assert!(c.latency_of(task).is_some(), "len {len}");
        let half = c.soc.cfg.spm_bytes as u64 / 2;
        assert_eq!(
            c.soc.nodes[3].mem.peek(c.soc.map.base_of(NodeId(3)) + half, len),
            &data[..],
            "len {len}"
        );
    }
}

/// The 20-cluster evaluation SoC handles a full 16-destination chain.
#[test]
fn eval_soc_16_destinations() {
    let mut c = Coordinator::new(SocConfig::eval_4x5());
    // 64 KB: large enough to amortize the per-destination protocol
    // overhead (paper: control overhead dominates at 1-4 KB).
    let len = 64 * 1024;
    seed_source(&mut c, NodeId(0), len);
    let dests: Vec<NodeId> = (1..=16).map(NodeId).collect();
    let task = c
        .submit_simple(NodeId(0), &dests, len, EngineKind::Torrent(Strategy::Tsp), true)
        .unwrap();
    c.run_to_completion(50_000_000);
    let rec = c.record(task).unwrap();
    assert!(rec.result.is_some());
    let eta = rec.eta().unwrap();
    assert!(eta > 5.0, "eta {eta} too low for 16-dest chainwrite at 64KB");
}

/// Remote-read (pull tunnel): node 4 pulls a strided region out of node
/// 0's scratchpad into its own, through the Read cfg type.
#[test]
fn remote_read_pull_tunnel() {
    let mut c = coord(3, 3, 64 * 1024);
    let data = seed_source(&mut c, NodeId(0), 8 * 1024);
    let remote_read = AffinePattern::contiguous(c.soc.map.base_of(NodeId(0)), 8 * 1024);
    let local_base = c.soc.map.base_of(NodeId(4)) + 0x4000;
    let local_write = AffinePattern::contiguous(local_base, 8 * 1024);
    {
        let soc = &mut c.soc;
        let now = soc.net.cycle;
        let (torrent, net) = (&mut soc.nodes[4].torrent, &mut soc.net);
        torrent.submit_read(9001, NodeId(0), remote_read, local_write, net, now);
    }
    c.soc.run_until_idle(10_000_000);
    // Requester records its own completion...
    let local = c.soc.nodes[4].torrent.results.iter().find(|r| r.task == 9001);
    assert!(local.is_some(), "requester never completed the read");
    assert!(local.unwrap().latency() > 0);
    // ...and the bytes are exact.
    assert_eq!(c.soc.nodes[4].mem.peek(local_base, 8 * 1024), &data[..]);
}

/// Pull with a layout transform on the remote side: gather a strided
/// remote pattern, land it contiguously.
#[test]
fn remote_read_strided_gather() {
    let mut c = coord(3, 3, 64 * 1024);
    let data = seed_source(&mut c, NodeId(0), 16 * 1024);
    let base0 = c.soc.map.base_of(NodeId(0));
    // Every other 64B line of the first 16KB.
    let remote_read = AffinePattern::strided(base0, 128, 64, 128);
    let local_base = c.soc.map.base_of(NodeId(8)) + 0x8000;
    let local_write = AffinePattern::contiguous(local_base, 128 * 64);
    {
        let soc = &mut c.soc;
        let now = soc.net.cycle;
        let (torrent, net) = (&mut soc.nodes[8].torrent, &mut soc.net);
        torrent.submit_read(9002, NodeId(0), remote_read, local_write, net, now);
    }
    c.soc.run_until_idle(10_000_000);
    assert!(c.soc.nodes[8].torrent.results.iter().any(|r| r.task == 9002));
    for row in 0..128usize {
        assert_eq!(
            c.soc.nodes[8].mem.peek(local_base + row as u64 * 64, 64),
            &data[row * 128..row * 128 + 64],
            "row {row}"
        );
    }
}

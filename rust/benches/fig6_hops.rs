//! Regenerates paper Fig 6: average hops per destination on an 8×8 mesh
//! for unicast, multicast and Chainwrite (naive / greedy / TSP orders),
//! 128 random destination sets per N_dst group (1024 points).
mod common;

fn main() {
    common::banner("Fig 6: average hops per destination");
    let table = torrent::analysis::experiments::fig6(2025, 128);
    table.print();
    println!("(paper: naive chain worst; greedy ~ multicast; TSP surpasses multicast at scale;");
    println!(" all optimized mechanisms approach 1 hop/destination at N_dst=63)");
    common::bench("fig6_hop_study_128trials", 1, 3, || {
        let _ = torrent::analysis::experiments::fig6(7, 128);
    });
}

//! Simulation kernel: the cycle-stepping contract and the activity-tracked
//! (event-driven) stepping extension.
//!
//! The whole SoC advances in lock-step — every component implements
//! [`Clocked`] and is ticked once per cycle by its owner (the `soc::Soc`
//! event loop ticks DMA engines, then the network, then memories'
//! bookkeeping). A shared [`Clock`] provides the cycle count; quiescence
//! is detected structurally (`is_idle`) rather than by event-queue
//! emptiness, because wormhole state lives in buffers, not events.
//!
//! # Activity-tracked stepping
//!
//! Naive lock-step ticking visits every router, link and engine on every
//! cycle even when the component is provably inert — e.g. a follower
//! Torrent counting down its `CFG_DECODE_CYCLES` wait, or a flit sitting
//! on a link delay line. The [`Clocked::next_event`] hint lets an
//! orchestrator (see `soc::Soc::run_until_idle`) fast-forward the shared
//! clock over such stretches:
//!
//! * `Some(c)` — ticking this component at any cycle **before** `c` is a
//!   provable no-op; the component must be ticked again at `c` (a value
//!   equal to the current cycle means "busy — tick me every cycle").
//! * `None` — the component holds no *scheduled* work: it is either idle
//!   or purely reactive (it progresses only when a message arrives, which
//!   implies fabric activity the orchestrator tracks separately).
//!
//! The contract is conservative by construction: a component unsure of
//! its future must report `Some(now)`, which disables skipping and
//! degrades gracefully to the full-tick behavior. Cycle counts reported
//! by event-driven and full-tick stepping are bit-identical — enforced by
//! the equivalence property test in `rust/tests/stepping.rs`.
//!
//! The engines satisfy this contract *structurally* rather than by
//! implementing the trait nominally: their tick/hint methods carry
//! context arguments (`&mut Network`, `&mut Scratchpad`) that the
//! object-level trait signature cannot express, so each exposes an
//! inherent `next_event(&self, now) -> Option<u64>` with these exact
//! semantics and `soc::Soc` folds them directly. The trait (with its
//! conservative default) is the documented contract new components
//! should follow; the equivalence property test is what enforces it.

pub mod fault;

pub use fault::{Fault, FaultKind, FaultPlan};

/// A component advanced once per cycle.
pub trait Clocked {
    /// Advance one cycle.
    fn tick(&mut self, cycle: u64);
    /// True when the component holds no in-flight work.
    fn is_idle(&self) -> bool;
    /// Earliest cycle at which `tick` would change observable state (see
    /// the module docs). The default is maximally conservative: busy on
    /// every cycle while not idle.
    fn next_event(&self, now: u64) -> Option<u64> {
        if self.is_idle() {
            None
        } else {
            Some(now)
        }
    }
}

/// How a `run_until_idle` loop advances the simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StepMode {
    /// Tick every component on every cycle (the reference behavior).
    FullTick,
    /// Skip provably no-op cycles using the [`Clocked::next_event`]
    /// hints. Bit-identical cycle counts to [`StepMode::FullTick`].
    #[default]
    EventDriven,
    /// Event-driven stepping with the per-cycle work sharded across
    /// `threads` worker threads (contiguous node ranges; cross-shard
    /// flits merge through per-cycle barriers in a fixed (cycle,
    /// src-shard, FIFO) order — see DESIGN.md §Parallel core).
    /// Bit-identical to [`StepMode::EventDriven`] for every thread
    /// count; `threads <= 1` runs the sequential kernel unchanged.
    ///
    /// Fast-forwarding stays a *global* decision: the main thread checks
    /// quiescence over all shards before skipping, so a shard never
    /// runs ahead of a fabric another shard still considers busy. Fault
    /// activations are applied between the engine and fabric phases on
    /// the main thread — a global barrier event, exactly where the
    /// sequential kernel applies them.
    Parallel {
        /// Worker threads (and shards) per tick. Clamped to the node
        /// count; 0 and 1 both mean "sequential".
        threads: usize,
    },
}

/// Simulation clock.
#[derive(Debug, Default, Clone, Copy)]
pub struct Clock {
    pub cycle: u64,
}

impl Clock {
    pub fn advance(&mut self) -> u64 {
        self.cycle += 1;
        self.cycle
    }

    /// Event-driven fast-forward: jump directly to `cycle` (which must
    /// not be in the past). This is the `Clock`-level form of the skip
    /// operation; the SoC stepper applies the same jump to the network's
    /// embedded cycle counter through `Network::skip_quiet_cycles` (which
    /// also replays the per-router arbitration-pointer advance), so use
    /// that when stepping a full `soc::Soc`.
    pub fn fast_forward_to(&mut self, cycle: u64) {
        assert!(cycle >= self.cycle, "clock cannot run backwards: {} -> {cycle}", self.cycle);
        self.cycle = cycle;
    }
}

/// Watchdog used by `run_until` loops: panics (with context) when a
/// simulation fails to make progress — the way the test suite detects
/// protocol deadlocks.
///
/// Deadline semantics (pinned by regression tests in
/// `rust/tests/stepping.rs`): a run may take **exactly** `deadline`
/// cycles; the first check past it panics. Event-driven stepping caps its
/// fast-forward at the deadline so a stalled system reports at the same
/// cycle as full-tick stepping.
#[derive(Debug)]
pub struct Watchdog {
    pub deadline: u64,
    pub label: &'static str,
}

impl Watchdog {
    pub fn new(deadline: u64, label: &'static str) -> Self {
        Watchdog { deadline, label }
    }

    pub fn check(&self, cycle: u64) {
        assert!(
            cycle <= self.deadline,
            "watchdog '{}' expired at cycle {cycle} (deadline {})",
            self.label,
            self.deadline
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances() {
        let mut c = Clock::default();
        assert_eq!(c.advance(), 1);
        assert_eq!(c.advance(), 2);
        assert_eq!(c.cycle, 2);
    }

    #[test]
    fn clock_fast_forwards() {
        let mut c = Clock::default();
        c.advance();
        c.fast_forward_to(100);
        assert_eq!(c.cycle, 100);
        c.fast_forward_to(100); // jumping to "now" is a no-op
        assert_eq!(c.cycle, 100);
    }

    #[test]
    #[should_panic(expected = "clock cannot run backwards")]
    fn clock_rejects_backward_jump() {
        let mut c = Clock { cycle: 10 };
        c.fast_forward_to(9);
    }

    #[test]
    #[should_panic(expected = "watchdog 'demo' expired")]
    fn watchdog_panics_past_deadline() {
        Watchdog::new(10, "demo").check(11);
    }

    #[test]
    fn watchdog_quiet_before_deadline() {
        Watchdog::new(10, "demo").check(10);
    }

    #[test]
    fn default_next_event_is_conservative() {
        struct Dummy {
            idle: bool,
        }
        impl Clocked for Dummy {
            fn tick(&mut self, _cycle: u64) {}
            fn is_idle(&self) -> bool {
                self.idle
            }
        }
        assert_eq!(Dummy { idle: true }.next_event(5), None);
        // A busy component without a hint must be ticked every cycle.
        assert_eq!(Dummy { idle: false }.next_event(5), Some(5));
    }

    #[test]
    fn step_mode_defaults_to_event_driven() {
        assert_eq!(StepMode::default(), StepMode::EventDriven);
    }
}

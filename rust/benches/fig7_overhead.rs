//! Regenerates paper Fig 7: Chainwrite configuration overhead — 64 KB
//! copy to 1..8 destinations; the paper reports a linear trend with
//! ~82 CC per added destination.
mod common;

fn main() {
    common::banner("Fig 7: Chainwrite configuration overhead");
    let (t, slope, intercept, r2) = torrent::analysis::experiments::fig7();
    t.print();
    println!("linear fit: {slope:.1} CC/destination + {intercept:.0} CC (r^2 = {r2:.4})");
    println!("paper: 82 CC/destination; match: {}", (slope - 82.0).abs() < 10.0);
}

//! The full fabric: routers + link delay lines + endpoint (NI)
//! injection/ejection queues, advanced one cycle at a time. The fabric
//! geometry is a [`Topo`] (mesh, torus or ring — `noc::topology`); every
//! structural decision (credits, link targets, route computation) goes
//! through the [`Topology`] trait.
//!
//! Per-node state (router, outbound link delay lines, NI queues, packet-id
//! allocator) lives in one [`Lane`] so the parallel stepper
//! (`noc::shard`) can hand each worker thread a contiguous `&mut [Lane]`
//! slice; everything cross-node — topology, fault state, aggregate stats
//! — is either read-only during a tick or merged deterministically after
//! it. The per-cycle phase helpers ([`deliver_links_range`],
//! [`inject_range`], [`switch_range`]) are shared verbatim between the
//! sequential [`Network::tick`] and the sharded tick, which is how the
//! two stay bit-identical by construction.
//!
//! Endpoint API used by the DMA engines (the [`NetPort`] surface):
//!
//! * [`Network::send`] — enqueue a packet for injection (serialized at one
//!   flit/cycle, the 64 B/CC link rate);
//! * [`Network::send_gated`] — cut-through injection: flit *i* may only
//!   leave once the shared gate counter exceeds *i*. The Torrent data
//!   switch uses this to forward an incoming Chainwrite stream to the next
//!   hop as flits arrive ("store and forward every received data frame as
//!   soon as it receives it", §III-A), without waiting for the tail;
//! * [`Network::recv`] — pop a fully-delivered packet;
//! * [`Network::progress_of`] — flits so far of an in-flight delivery
//!   (feeds the forwarding gate).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use super::packet::{compose_id, flits_of, Flit, Packet, PacketId, PHASE_EXTERNAL};
use super::router::{vc_of, Router, LINK_CYCLES, NUM_VCS, ROUTER_PIPELINE};
use super::topology::{Degraded, Dir, NodeId, Topo, Topology};
use crate::sim::fault::{Fault, FaultKind, FaultPlan};
use crate::sim::Watchdog;

/// Interior of a cut-through gate: the number of flits allowed to leave
/// so far. Atomic (relaxed) so gates may be read by fabric shards on
/// worker threads; writers (engines) and readers (injection) run in
/// different tick phases, separated by a thread join, so plain
/// load/store ordering suffices — the atomics exist for `Send`/`Sync`,
/// not for synchronization.
#[derive(Debug, Default)]
pub struct GateCell(AtomicU32);

impl GateCell {
    pub fn new(v: u32) -> Self {
        GateCell(AtomicU32::new(v))
    }

    pub fn get(&self) -> u32 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn set(&self, v: u32) {
        self.0.store(v, Ordering::Relaxed)
    }
}

/// Shared cut-through gate handle.
pub type Gate = Arc<GateCell>;

/// The per-node endpoint surface the DMA engines and the AXI slave
/// program against. Implemented by [`Network`] (sequential stepping) and
/// by the parallel stepper's per-shard views (`noc::shard`), so engine
/// code is oblivious to whether it runs on the main thread or inside a
/// shard worker. Every method takes the engine's own node; shard views
/// assert that `from`/`node` stay inside the shard — an engine touching
/// another node's NI would break the shard-ownership invariant.
pub trait NetPort {
    /// Current fabric cycle.
    fn cycle(&self) -> u64;
    /// Enqueue `pkt` for injection at `from`. Returns the packet id.
    fn send(&mut self, from: NodeId, pkt: Packet) -> PacketId;
    /// Gated (cut-through) injection: flit `i` may leave only once
    /// `gate.get() > i`.
    fn send_gated(&mut self, from: NodeId, pkt: Packet, gate: Gate) -> PacketId;
    /// Packets currently being assembled at `node`'s NI: `(id, packet,
    /// flits arrived)`, in packet-id (allocation) order.
    fn eject_in_progress(&self, node: NodeId) -> Vec<(PacketId, Arc<Packet>, u32)>;
    /// Flits of in-flight packet `id` that have arrived at `node`'s NI.
    fn progress_of(&self, node: NodeId, id: PacketId) -> Option<u32>;
    /// Pop a fully-delivered packet at `node`. Used by the SoC event
    /// loop's dispatch phase, not by engines (packets are handed to them).
    fn recv(&mut self, node: NodeId) -> Option<Arc<Packet>>;
    /// Set the tick phase stamped into composed packet ids
    /// (`packet::PHASE_*`). Called by the SoC event loop around its
    /// dispatch and engine phases; not for engine use.
    fn set_phase(&mut self, phase: u8);
}

/// An injection-queue entry: a flit, optionally gated.
pub(crate) struct InjectEntry {
    pub(crate) flit: Flit,
    pub(crate) gate: Option<Gate>,
}

/// In-flight ejection assembly at a node.
pub(crate) struct EjectState {
    pub(crate) packet: Arc<Packet>,
    pub(crate) arrived: u32,
}

/// Per-(cycle, phase) send-sequence allocator — the node-local half of
/// the composed packet-id scheme (`packet::compose_id`). Resets its
/// sequence whenever the (cycle, phase) key moves, so ids are dense per
/// node per phase and need no cross-node coordination.
#[derive(Debug, Default)]
pub(crate) struct AllocState {
    key: (u64, u8),
    seq: u32,
}

impl AllocState {
    pub(crate) fn next(&mut self, cycle: u64, phase: u8) -> u32 {
        if self.key != (cycle, phase) {
            self.key = (cycle, phase);
            self.seq = 0;
        }
        let s = self.seq;
        self.seq += 1;
        s
    }
}

/// Everything one node owns: its router, the delay lines of its
/// *outbound* links, its NI queues and its packet-id allocator. The unit
/// of shard ownership — a worker thread gets `&mut [Lane]` over a
/// contiguous node range and touches nothing outside it except through
/// the barrier mailboxes.
pub(crate) struct Lane {
    pub(crate) router: Router,
    /// `links[dir]`: flits in flight toward `neighbour(node, dir)`, as
    /// `(deliver_at, vc, flit)` in FIFO order.
    pub(crate) links: [VecDeque<(u64, usize, Flit)>; 5],
    pub(crate) inject: VecDeque<InjectEntry>,
    pub(crate) inbox: VecDeque<Arc<Packet>>,
    /// In-flight ejection assembly, keyed by packet id. Ordered map so
    /// [`Network::eject_in_progress`] scans in allocation order — the
    /// Torrent data switch starts forwards in that order, which must be
    /// deterministic for run-to-run cycle reproducibility.
    pub(crate) eject: BTreeMap<PacketId, EjectState>,
    /// Flits moved by this router over the run — the activity counter
    /// the coordinator's dead-hop diagnosis reads.
    pub(crate) activity: u64,
    /// `link_flits[dir]`: flits this router has pushed onto its outbound
    /// delay line toward `dir` (Local = ejections) over the run. The
    /// per-directed-link half of the activity telemetry; the load-aware
    /// scheduler reads windowed deltas of these through
    /// [`Network::load_view`]. Lane-owned, so the sharded tick counts
    /// them without any cross-thread merge.
    pub(crate) link_flits: [u64; 5],
    pub(crate) alloc: AllocState,
}

impl Lane {
    fn new(topo: &Topo, node: NodeId) -> Self {
        Lane {
            router: Router::new(topo, node),
            links: Default::default(),
            inject: VecDeque::new(),
            inbox: VecDeque::new(),
            eject: BTreeMap::new(),
            activity: 0,
            link_flits: [0; 5],
            alloc: AllocState::default(),
        }
    }

    fn links_empty(&self) -> bool {
        self.links.iter().all(|q| q.is_empty())
    }

    /// True when this node contributes no work to a fabric tick: nothing
    /// queued for injection, nothing in flight on its outbound links,
    /// nothing buffered in its router. The per-lane term of the global
    /// quiescence shortcut (sequential and sharded tick alike).
    pub(crate) fn fabric_quiet(&self) -> bool {
        self.inject.is_empty() && self.links_empty() && self.router.is_idle()
    }
}

/// Aggregate traffic statistics.
#[derive(Debug, Default, Clone)]
pub struct NetStats {
    /// Router-to-router link traversals (the Fig-6 "hops" unit).
    pub flit_hops: u64,
    /// Flits ejected at their destination NI.
    pub flit_ejections: u64,
    pub packets_sent: u64,
    pub packets_delivered: u64,
    /// Flits destroyed by fault injection (purged buffers, severed
    /// links, dead-router deliveries). Always 0 on a healthy fabric.
    pub flits_dropped: u64,
}

impl NetStats {
    /// Fold a shard's per-tick delta into the aggregate (all counters
    /// are sums, so merge order cannot matter; shards are merged in
    /// index order anyway).
    pub(crate) fn merge(&mut self, o: &NetStats) {
        self.flit_hops += o.flit_hops;
        self.flit_ejections += o.flit_ejections;
        self.packets_sent += o.packets_sent;
        self.packets_delivered += o.packets_delivered;
        self.flits_dropped += o.flits_dropped;
    }
}

/// Runtime fault state. Boxed behind an `Option` so a healthy fabric
/// pays one pointer of storage and one `is_some` branch per tick — the
/// "provably zero-cost when off" requirement. Read-only during the
/// parallel fabric phases (activations are applied on the main thread
/// between the engine and fabric phases — a global barrier event).
pub(crate) struct FaultState {
    /// Scheduled activations not yet applied.
    pub(crate) pending: Vec<Fault>,
    /// Scheduled heals of transient faults (`@C+D` grammar) not yet
    /// applied: `(heal cycle, the kind to revive)`, in install order.
    pub(crate) heals: Vec<(u64, FaultKind)>,
    /// Killed routers (the cluster behind the local port dies with it).
    pub(crate) dead: Vec<bool>,
    /// `link_dead[node][dir]`: the directed channel leaving `node`
    /// toward `dir` is severed.
    pub(crate) link_dead: Vec<[bool; 5]>,
    /// Clock-division factor per router; 1 = full speed.
    pub(crate) slow: Vec<u32>,
    /// True once any activation has been applied — from then on the
    /// event-driven stepper stops skipping (degraded fabrics are ticked
    /// cycle-by-cycle, so EventDriven trivially equals FullTick). This
    /// stays sticky even after every transient fault heals: a fabric
    /// that was ever degraded keeps ticking cycle-by-cycle, which is
    /// what makes heal cycles land identically under every step mode.
    pub(crate) active_any: bool,
}

/// Cycles per occupancy window: [`Network::load_view`] folds the
/// per-link flit deltas of the last completed window into the EWMA. 256
/// cycles ≈ a few chain-hop round trips — short enough to track serving
/// bursts, long enough that a single packet does not read as congestion.
pub const LOAD_WINDOW: u64 = 256;

/// Windowed link-occupancy EWMA state. Boxed behind an `Option` exactly
/// like [`FaultState`]: a fabric whose load is never observed pays one
/// pointer of storage and nothing per tick — counters are folded lazily
/// at [`Network::load_view`] call sites, never during `tick`, so the
/// event-driven fast-forward stays untouched.
pub(crate) struct LoadEwma {
    /// Per-node, per-direction EWMA of link occupancy in milli-flits
    /// per cycle (0..=1000). Integer arithmetic keeps the telemetry
    /// bit-identical across step modes and platforms.
    ewma_milli: Vec<[u32; 5]>,
    /// `link_flits` snapshot at the last window rollover.
    last: Vec<[u64; 5]>,
    /// Cycle of the last window rollover.
    last_cycle: u64,
}

/// Immutable snapshot of windowed link occupancy, in milli-flits per
/// cycle per directed link (0 = idle, 1000 = a flit every cycle). Taken
/// by the coordinator at dispatch time and consumed by
/// `sched::load_aware_order`; values are derived from deterministic
/// counters at deterministic call sites, so snapshots are bit-identical
/// across FullTick/EventDriven/Parallel runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoadView {
    load_milli: Vec<[u32; 5]>,
}

impl LoadView {
    /// An all-idle view over `n` nodes (what a never-observed or
    /// freshly-armed fabric reports).
    pub fn zero(n: usize) -> Self {
        LoadView { load_milli: vec![[0; 5]; n] }
    }

    /// Construct from explicit per-link milli-occupancies (tests and
    /// benches; production views come from [`Network::load_view`]).
    pub fn with_loads(load_milli: Vec<[u32; 5]>) -> Self {
        LoadView { load_milli }
    }

    pub fn n_nodes(&self) -> usize {
        self.load_milli.len()
    }

    /// Occupancy of the directed link leaving `from` toward `d`, in
    /// milli-flits/cycle. Out-of-range nodes read as idle.
    pub fn link_load_milli(&self, from: NodeId, d: Dir) -> u32 {
        self.load_milli.get(from.0).map_or(0, |a| a[d.index()])
    }

    /// Force one directed link's occupancy (test helper for scheduler
    /// unit tests that need a synthetic hot link).
    pub fn set_link(&mut self, from: NodeId, d: Dir, milli: u32) {
        self.load_milli[from.0][d.index()] = milli;
    }

    /// Hottest link on the fabric's routed path `from -> to` (0 when
    /// `from == to`). Walks `next_hop` — the same walk the chain
    /// schedulers use, so the score sees exactly the links a leg would
    /// traverse.
    pub fn max_on_path(&self, topo: &dyn Topology, from: NodeId, to: NodeId) -> u32 {
        let mut max = 0;
        let mut cur = from;
        while cur != to {
            let d = topo.next_hop(cur, to);
            let next = topo.neighbour(cur, d).expect("routing left the fabric");
            max = max.max(self.link_load_milli(cur, d));
            cur = next;
        }
        max
    }

    /// True when every link reads idle (e.g. the arming snapshot).
    pub fn is_zero(&self) -> bool {
        self.load_milli.iter().all(|a| a.iter().all(|&v| v == 0))
    }
}

pub struct Network {
    pub topo: Topo,
    pub cycle: u64,
    /// Tick phase of sends in flight (`packet::PHASE_*`): the SoC event
    /// loop raises this around its dispatch and engine phases so
    /// composed packet ids reflect where in the tick a send happened.
    pub(crate) cur_phase: u8,
    pub(crate) lanes: Vec<Lane>,
    /// Reused per-router move buffer (§Perf).
    moved_scratch: Vec<(Dir, usize, Flit)>,
    /// Reused freed-credit buffer: credits are collected during the
    /// switch phase and applied after every router has ticked, so no
    /// router's allocation sees a credit freed in the same cycle —
    /// matching the parallel stepper, where same-cycle credit visibility
    /// across shards is impossible by construction.
    credit_scratch: Vec<(usize, Dir, usize)>,
    /// Fault-injection state; `None` on a healthy fabric.
    pub(crate) faults: Option<Box<FaultState>>,
    /// Link-occupancy EWMA state; `None` until the first
    /// [`Network::load_view`] call arms it (zero-cost when unused).
    pub(crate) load: Option<Box<LoadEwma>>,
    pub stats: NetStats,
}

impl Network {
    pub fn new(topo: impl Into<Topo>) -> Self {
        let topo = topo.into();
        let n = topo.n_nodes();
        Network {
            topo,
            cycle: 0,
            cur_phase: PHASE_EXTERNAL,
            lanes: (0..n).map(|i| Lane::new(&topo, NodeId(i))).collect(),
            moved_scratch: Vec::new(),
            credit_scratch: Vec::new(),
            faults: None,
            load: None,
            stats: NetStats::default(),
        }
    }

    /// Arm the fabric-relevant part of a [`FaultPlan`] (link/router kills
    /// and stragglers; follower drops live at the SoC layer). Panics on a
    /// schedule that names a non-existent node or a non-adjacent link —
    /// a bad scenario should fail at construction, not mid-run.
    pub fn install_faults(&mut self, plan: &FaultPlan) {
        let n = self.topo.n_nodes();
        plan.validate(n).expect("fault plan out of bounds");
        let pending: Vec<Fault> = plan
            .faults
            .iter()
            .filter(|f| !matches!(f.kind, FaultKind::FollowerDrop { .. }))
            .copied()
            .collect();
        for f in &pending {
            if let FaultKind::LinkKill { from, to } = f.kind {
                assert!(
                    self.link_dir(from, to).is_some(),
                    "fault plan kills link {from}->{to}, but the nodes are not adjacent in {}",
                    self.topo.name()
                );
            }
        }
        if pending.is_empty() {
            return;
        }
        // Transient faults (`@C+D`) schedule their own undo. A heal is
        // always strictly after its activation (the parser enforces
        // duration > 0), and the fabric never skips cycles once any
        // fault has activated, so heals are processed exactly on time.
        let heals: Vec<(u64, FaultKind)> =
            pending.iter().filter_map(|f| f.heals_at.map(|h| (h, f.kind))).collect();
        self.faults = Some(Box::new(FaultState {
            pending,
            heals,
            dead: vec![false; n],
            link_dead: vec![[false; 5]; n],
            slow: vec![1; n],
            active_any: false,
        }));
    }

    /// Direction of the physical channel `from -> to`, if adjacent.
    fn link_dir(&self, from: usize, to: usize) -> Option<Dir> {
        [Dir::North, Dir::East, Dir::South, Dir::West]
            .into_iter()
            .find(|&d| self.topo.neighbour(NodeId(from), d) == Some(NodeId(to)))
    }

    /// True once any scheduled fault has activated.
    pub fn fault_active(&self) -> bool {
        self.faults.as_ref().is_some_and(|f| f.active_any)
    }

    /// Earliest not-yet-applied activation cycle, if any.
    pub fn next_fault_activation(&self) -> Option<u64> {
        self.faults.as_ref().and_then(|f| f.pending.iter().map(|x| x.at_cycle).min())
    }

    /// True when router `node` has been killed.
    pub fn router_dead(&self, node: NodeId) -> bool {
        self.faults.as_ref().is_some_and(|f| f.dead[node.0])
    }

    /// Flits moved by router `node` so far — the activity counter the
    /// coordinator's dead-hop diagnosis compares across a chain.
    pub fn router_activity(&self, node: NodeId) -> u64 {
        self.lanes[node.0].activity
    }

    /// Cumulative flits pushed by `node` onto each outbound direction
    /// (`Dir::index` order; Local = ejections to the NI).
    pub fn link_flits(&self, node: NodeId) -> [u64; 5] {
        self.lanes[node.0].link_flits
    }

    /// Snapshot the windowed link-occupancy EWMA, arming the tracker on
    /// first use (the arming call returns an all-idle view — there is no
    /// completed window to read yet). Folding happens here, never in
    /// `tick`: an unobserved fabric does zero load accounting, and the
    /// event-driven fast-forward path is untouched. Once armed, the EWMA
    /// advances only when at least [`LOAD_WINDOW`] cycles have elapsed
    /// since the last fold, with integer milli-occupancy arithmetic
    /// (`ewma' = (ewma + rate)/2`), so every step mode computes the same
    /// view at the same dispatch cycle.
    pub fn load_view(&mut self) -> LoadView {
        let n = self.lanes.len();
        if self.load.is_none() {
            self.load = Some(Box::new(LoadEwma {
                ewma_milli: vec![[0; 5]; n],
                last: self.lanes.iter().map(|l| l.link_flits).collect(),
                last_cycle: self.cycle,
            }));
            return LoadView::zero(n);
        }
        let st = self.load.as_mut().unwrap();
        let elapsed = self.cycle - st.last_cycle;
        if elapsed >= LOAD_WINDOW {
            for (i, lane) in self.lanes.iter().enumerate() {
                for d in 0..5 {
                    let delta = lane.link_flits[d] - st.last[i][d];
                    let rate = ((delta * 1000) / elapsed).min(1000) as u32;
                    st.ewma_milli[i][d] = (st.ewma_milli[i][d] + rate) / 2;
                    st.last[i][d] = lane.link_flits[d];
                }
            }
            st.last_cycle = self.cycle;
        }
        LoadView { load_milli: st.ewma_milli.clone() }
    }

    /// Test hook: seed the EWMA state so the next [`Network::load_view`]
    /// call within one window returns exactly `view`. Lets integration
    /// tests drive the coordinator's load-aware dispatch (ordering and
    /// the partition pass) against a pinned fabric-load picture without
    /// reverse-engineering a traffic schedule that produces it.
    #[doc(hidden)]
    pub fn preload_load_view(&mut self, view: &LoadView) {
        let n = self.lanes.len();
        assert_eq!(view.n_nodes(), n, "view shape must match the fabric");
        self.load = Some(Box::new(LoadEwma {
            ewma_milli: view.load_milli.clone(),
            last: self.lanes.iter().map(|l| l.link_flits).collect(),
            last_cycle: self.cycle,
        }));
    }

    /// Snapshot of the surviving fabric: the base topology minus killed
    /// routers and severed links, for re-chaining around the damage.
    pub fn degraded_topology(&self) -> Degraded {
        match &self.faults {
            Some(st) => Degraded::new(self.topo, st.dead.clone(), st.link_dead.clone()),
            None => Degraded::healthy(self.topo),
        }
    }

    /// Apply every activation whose cycle has arrived. Called once per
    /// tick, after the cycle counter advances — in the parallel stepper
    /// this runs on the main thread between the engine and fabric
    /// phases, so a kill at cycle C affects cycle C's link deliveries in
    /// every shard (the "fault activation is a barrier event" rule).
    pub(crate) fn activate_due_faults(&mut self) {
        let cycle = self.cycle;
        let (heal_due, due): (Vec<FaultKind>, Vec<Fault>) = {
            let st = self.faults.as_mut().expect("activate without fault state");
            if st.pending.is_empty() && st.heals.is_empty() {
                return;
            }
            let mut heal_due = Vec::new();
            st.heals.retain(|&(at, kind)| {
                let fire = at <= cycle;
                if fire {
                    heal_due.push(kind);
                }
                !fire
            });
            let mut due = Vec::new();
            st.pending.retain(|f| {
                let fire = f.at_cycle <= cycle;
                if fire {
                    due.push(*f);
                }
                !fire
            });
            (heal_due, due)
        };
        // Heals apply before same-cycle activations, so a fault that
        // re-strikes the component it just released wins — the component
        // ends the cycle dead, never spuriously alive.
        for kind in heal_due {
            self.heal_fault(kind);
        }
        for f in due {
            match f.kind {
                FaultKind::RouterKill { node } => self.kill_router(node),
                FaultKind::LinkKill { from, to } => self.kill_link(from, to),
                FaultKind::Straggler { node, factor } => {
                    let st = self.faults.as_mut().unwrap();
                    st.slow[node] = factor;
                    st.active_any = true;
                }
                FaultKind::FollowerDrop { .. } => unreachable!("filtered at install"),
            }
        }
    }

    fn kill_router(&mut self, node: usize) {
        // Buffered flits vanish; their credits return upstream so the
        // dead router behaves as a sink, not a wedge (see Router::purge —
        // withheld credits would freeze every upstream path prefix and
        // strand any repair traffic sharing a link with the wreck).
        let purged = self.lanes[node].router.purge();
        for d in Dir::ALL {
            for vc in 0..NUM_VCS {
                let k = purged[d.index()][vc];
                if k == 0 {
                    continue;
                }
                self.stats.flits_dropped += k as u64;
                if d == Dir::Local {
                    continue; // injection checks space directly, no credit
                }
                let upstream = self
                    .topo
                    .neighbour(NodeId(node), d)
                    .expect("purged flits on an edge port");
                for _ in 0..k {
                    self.lanes[upstream.0].router.return_credit(d.opposite(), vc);
                }
            }
        }
        // In-flight flits on inbound wires stay on the delay lines and
        // die at delivery (phase 1), where their credits return too.
        // The NI dies with the router: queued injections and partial
        // ejections vanish (no credits involved at the NI boundary).
        let inj = self.lanes[node].inject.len();
        self.stats.flits_dropped += inj as u64;
        self.lanes[node].inject.clear();
        self.lanes[node].eject.clear();
        let st = self.faults.as_mut().unwrap();
        st.dead[node] = true;
        st.active_any = true;
    }

    fn kill_link(&mut self, from: usize, to: usize) {
        // Flits already on the wire keep their delay-line slots and die
        // at delivery (phase 1) with credit return — the severed channel
        // is a sink from the activation cycle on.
        let d = self.link_dir(from, to).expect("validated at install");
        let st = self.faults.as_mut().unwrap();
        st.link_dead[from][d.index()] = true;
        st.active_any = true;
    }

    /// Undo a transient fault. Revival is credit-safe by construction:
    /// while a component is dead its boundary *sinks* flits but keeps
    /// honouring flow control (purge and the delivery sink both return
    /// credits; downstream slot-frees still land on a dead router's
    /// counters), so by heal time every credit counter has converged
    /// back to its resting value and clearing the flag is the whole
    /// revival. `active_any` deliberately stays sticky — see the field.
    fn heal_fault(&mut self, kind: FaultKind) {
        match kind {
            FaultKind::RouterKill { node } => {
                self.faults.as_mut().unwrap().dead[node] = false;
            }
            FaultKind::LinkKill { from, to } => {
                let d = self.link_dir(from, to).expect("validated at install");
                self.faults.as_mut().unwrap().link_dead[from][d.index()] = false;
            }
            FaultKind::Straggler { node, .. } => {
                self.faults.as_mut().unwrap().slow[node] = 1;
            }
            FaultKind::FollowerDrop { .. } => unreachable!("rejected by FaultPlan::validate"),
        }
    }

    /// Enqueue `pkt` for injection at `from`. Returns the packet id.
    pub fn send(&mut self, from: NodeId, pkt: Packet) -> PacketId {
        lane_send(&mut self.lanes[from.0], self.cycle, self.cur_phase, from, pkt, None, &mut self.stats)
    }

    /// Gated (cut-through) injection: flit `i` may leave only once
    /// `gate.get() > i`.
    pub fn send_gated(&mut self, from: NodeId, pkt: Packet, gate: Gate) -> PacketId {
        lane_send(
            &mut self.lanes[from.0],
            self.cycle,
            self.cur_phase,
            from,
            pkt,
            Some(gate),
            &mut self.stats,
        )
    }

    /// Pop a fully-delivered packet at `node`.
    pub fn recv(&mut self, node: NodeId) -> Option<Arc<Packet>> {
        self.lanes[node.0].inbox.pop_front()
    }

    /// Peek without consuming.
    pub fn peek(&self, node: NodeId) -> Option<&Arc<Packet>> {
        self.lanes[node.0].inbox.front()
    }

    /// Flits of in-flight packet `id` that have arrived at `node`'s NI.
    /// `None` once delivered (or never seen).
    pub fn progress_of(&self, node: NodeId, id: PacketId) -> Option<u32> {
        self.lanes[node.0].eject.get(&id).map(|e| e.arrived)
    }

    /// Flits still queued for injection at `node`.
    pub fn inject_backlog(&self, node: NodeId) -> usize {
        self.lanes[node.0].inject.len()
    }

    /// Packets currently being assembled at `node`'s NI: `(id, packet,
    /// flits arrived)`. The Torrent data switch scans this to start
    /// cut-through forwarding before the tail lands.
    pub fn eject_in_progress(&self, node: NodeId) -> Vec<(PacketId, Arc<Packet>, u32)> {
        self.lanes[node.0]
            .eject
            .iter()
            .map(|(&id, st)| (id, st.packet.clone(), st.arrived))
            .collect()
    }

    /// True when every NI inbox has been drained by the endpoint logic.
    pub fn inboxes_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.inbox.is_empty())
    }

    /// True when no flit exists anywhere in the fabric (inboxes may hold
    /// delivered packets).
    pub fn is_idle(&self) -> bool {
        self.lanes.iter().all(|l| {
            l.router.is_idle() && l.links_empty() && l.inject.is_empty() && l.eject.is_empty()
        })
    }

    /// True when skipping whole cycles (see
    /// [`Network::skip_quiet_cycles`]) is provably exact for the fabric:
    /// no flit sits in a router input or an injection queue, so a tick
    /// could only move link-delay-line time forward. Packets mid-ejection
    /// are inert to `tick` and do not block fabric skipping — callers
    /// owning endpoint logic that reacts to ejection progress must check
    /// [`Network::ejections_pending`] separately.
    /// Once a fault has activated the fabric is never skippable: a
    /// degraded fabric is ticked cycle-by-cycle, which makes EventDriven
    /// trivially bit-identical to FullTick on faulted runs. Before the
    /// first activation, skipping is exact as usual — [`Network::next_event`]
    /// caps the jump just short of the earliest activation cycle.
    pub fn can_skip(&self) -> bool {
        !self.fault_active()
            && self.lanes.iter().all(|l| l.inject.is_empty() && l.router.is_idle())
    }

    /// Packets currently mid-assembly at any NI.
    pub fn ejections_pending(&self) -> bool {
        self.lanes.iter().any(|l| !l.eject.is_empty())
    }

    /// Activity hint (the `sim::Clocked::next_event` contract): `None`
    /// when the fabric is fully idle; `Some(c)` when ticking before cycle
    /// `c` is a provable no-op (`c == self.cycle` means busy now). The
    /// only skippable fabric state is "flits exist solely on link delay
    /// lines": the first productive step is then the tick that raises the
    /// clock to the earliest `deliver_at`, i.e. the step taken at cycle
    /// `min_ready - 1`.
    pub fn next_event(&self) -> Option<u64> {
        // A pending fault activation is a scheduled event: the fabric
        // must be ticked at its cycle so the kill applies at the same
        // cycle under both step modes.
        let cap = self.next_fault_activation().map(|a| a.saturating_sub(1).max(self.cycle));
        if !self.can_skip() || self.ejections_pending() {
            return Some(self.cycle); // busy fabric: tick every cycle
        }
        let min_ready = self
            .lanes
            .iter()
            .flat_map(|l| l.links.iter())
            .filter_map(|q| q.front().map(|&(ready, _, _)| ready))
            .min();
        let Some(min_ready) = min_ready else {
            return cap; // idle fabric — except for scheduled faults
        };
        let ev = min_ready.saturating_sub(1).max(self.cycle);
        Some(match cap {
            Some(c) => ev.min(c),
            None => ev,
        })
    }

    /// Fast-forward the clock over `delta` provably quiescent cycles.
    /// Exactness: with [`Network::can_skip`] true and no link flit ready
    /// before the target cycle, each skipped `tick` would only have
    /// advanced every router's arbitration pointer — replayed here via
    /// [`Router::rr_advance`] so arbitration stays bit-identical.
    pub fn skip_quiet_cycles(&mut self, delta: u64) {
        debug_assert!(self.can_skip(), "skip_quiet_cycles on an active fabric");
        self.cycle += delta;
        for l in &mut self.lanes {
            l.router.rr_advance(delta);
        }
    }

    /// Advance one cycle (sequential reference kernel; the sharded
    /// parallel form lives in `noc::shard` and runs the same phase
    /// helpers per worker).
    pub fn tick(&mut self) {
        self.cycle += 1;
        let cycle = self.cycle;

        // Scheduled fault activations fire first, so a kill at cycle C
        // affects cycle C's own link deliveries — identically under all
        // step modes (next_event never skips past an activation).
        if self.faults.is_some() {
            self.activate_due_faults();
        }

        // Fully quiescent fabric: the whole tick reduces to advancing the
        // arbitration pointers (§Perf — this is the common case while
        // engines wait out protocol delays).
        if self.lanes.iter().all(Lane::fabric_quiet) {
            for l in &mut self.lanes {
                l.router.rr_advance(1);
            }
            return;
        }

        let topo = self.topo;
        let mut scratch = std::mem::take(&mut self.moved_scratch);
        let mut credits = std::mem::take(&mut self.credit_scratch);
        {
            let Network { lanes, faults, stats, .. } = self;
            let faults = faults.as_deref();

            // 1. Link delivery: ready flits enter downstream input
            //    buffers. base = 0 covers every node, so the cross-shard
            //    sink is unreachable.
            deliver_links_range(lanes, 0, topo, cycle, faults, stats, |_, _, _, _| {
                unreachable!("sequential tick has no remote shard")
            });

            // 2. Injection: one flit per node per cycle, gate and space
            //    permitting.
            inject_range(lanes, 0, faults, stats);

            // 3. Switch allocation + traversal per router. Idle routers
            //    only advance their arbitration pointer (exactly what a
            //    full `tick_into` would have done for them). Freed
            //    credits are collected, not applied: see below.
            switch_range(lanes, 0, &topo, cycle, faults, stats, &mut scratch, &mut credits);

            // 3b. Return freed credits upstream, after every router has
            //     allocated — no router may consume a credit freed this
            //     same cycle (same-cycle visibility would otherwise
            //     depend on router iteration order, the exact artifact
            //     the sharded stepper cannot reproduce).
            for &(node, dir, vc) in credits.iter() {
                lanes[node].router.return_credit(dir, vc);
            }
            credits.clear();
        }
        self.moved_scratch = scratch;
        self.credit_scratch = credits;
    }

    /// Run until the fabric drains or `max_cycles` elapse. Returns cycles
    /// spent. Panics (watchdog) if the deadline is hit — likely deadlock.
    /// Event-driven: skips ahead over link-delay-line waits; cycle counts
    /// are identical to ticking every cycle.
    pub fn run_until_idle(&mut self, max_cycles: u64) -> u64 {
        let start = self.cycle;
        let dog = Watchdog::new(max_cycles, "network.drain");
        while !self.is_idle() {
            if self.can_skip() {
                if let Some(ev) = self.next_event() {
                    let target = ev.min(start + max_cycles);
                    if target > self.cycle {
                        self.skip_quiet_cycles(target - self.cycle);
                    }
                }
            }
            self.tick();
            dog.check(self.cycle - start);
        }
        self.cycle - start
    }
}

impl NetPort for Network {
    fn cycle(&self) -> u64 {
        self.cycle
    }

    fn send(&mut self, from: NodeId, pkt: Packet) -> PacketId {
        Network::send(self, from, pkt)
    }

    fn send_gated(&mut self, from: NodeId, pkt: Packet, gate: Gate) -> PacketId {
        Network::send_gated(self, from, pkt, gate)
    }

    fn eject_in_progress(&self, node: NodeId) -> Vec<(PacketId, Arc<Packet>, u32)> {
        Network::eject_in_progress(self, node)
    }

    fn progress_of(&self, node: NodeId, id: PacketId) -> Option<u32> {
        Network::progress_of(self, node, id)
    }

    fn recv(&mut self, node: NodeId) -> Option<Arc<Packet>> {
        Network::recv(self, node)
    }

    fn set_phase(&mut self, phase: u8) {
        self.cur_phase = phase;
    }
}

/// Allocate a composed packet id and enqueue `pkt`'s flits at `lane`
/// (the shared body of `send`/`send_gated` across the sequential network
/// and the shard endpoint views).
pub(crate) fn lane_send(
    lane: &mut Lane,
    cycle: u64,
    phase: u8,
    from: NodeId,
    mut pkt: Packet,
    gate: Option<Gate>,
    stats: &mut NetStats,
) -> PacketId {
    pkt.id = compose_id(cycle, phase, from.0, lane.alloc.next(cycle, phase));
    let id = pkt.id;
    pkt.src = from;
    let arc = Arc::new(pkt);
    for flit in flits_of(arc) {
        lane.inject.push_back(InjectEntry { flit, gate: gate.clone() });
    }
    stats.packets_sent += 1;
    id
}

/// Tick phase 1 for the node range starting at `base`: pop every
/// link-delay-line flit whose `deliver_at` has arrived and push it into
/// the downstream router's input buffer. In-range destinations are
/// accepted directly; out-of-range ones go through `remote` (the shard
/// boundary mailbox). Fault boundaries sink the flit and return its
/// credit to the sending router — which is always in-range, because a
/// lane owns its node's *outbound* links.
pub(crate) fn deliver_links_range(
    lanes: &mut [Lane],
    base: usize,
    topo: Topo,
    cycle: u64,
    faults: Option<&FaultState>,
    stats: &mut NetStats,
    mut remote: impl FnMut(usize, Dir, usize, Flit),
) {
    let len = lanes.len();
    for li in 0..len {
        let node = base + li;
        for d in [Dir::North, Dir::East, Dir::South, Dir::West] {
            loop {
                match lanes[li].links[d.index()].front() {
                    Some(&(ready, _, _)) if ready <= cycle => {}
                    _ => break,
                }
                let (_, vc, flit) = lanes[li].links[d.index()].pop_front().unwrap();
                let dst = topo.neighbour(NodeId(node), d).expect("link to nowhere");
                if let Some(st) = faults {
                    if st.link_dead[node][d.index()] || st.dead[dst.0] {
                        // Severed wire or dead router: the flit vanishes,
                        // but its credit returns so the fault boundary is
                        // a sink. Withholding the credit would wedge the
                        // sender's output (wormhole lock + zero credits)
                        // and creep backpressure across the whole
                        // upstream path — stranding repair traffic on
                        // links the degraded topology reports clean.
                        stats.flits_dropped += 1;
                        lanes[li].router.return_credit(d, vc);
                        continue;
                    }
                }
                if dst.0 >= base && dst.0 < base + len {
                    lanes[dst.0 - base].router.accept(d.opposite(), vc, flit);
                } else {
                    remote(dst.0, d.opposite(), vc, flit);
                }
            }
        }
    }
}

/// Tick phase 2 for the node range starting at `base`: inject at most
/// one flit per node, gate and input-buffer space permitting. Entirely
/// node-local.
pub(crate) fn inject_range(
    lanes: &mut [Lane],
    base: usize,
    faults: Option<&FaultState>,
    stats: &mut NetStats,
) {
    for (li, lane) in lanes.iter_mut().enumerate() {
        let node = base + li;
        if faults.is_some_and(|st| st.dead[node]) {
            // The NI died after these flits were queued.
            let n = lane.inject.len();
            if n > 0 {
                stats.flits_dropped += n as u64;
                lane.inject.clear();
            }
            continue;
        }
        let Some(front) = lane.inject.front() else { continue };
        if let Some(g) = &front.gate {
            if g.get() <= front.flit.seq {
                continue; // cut-through gate not yet open
            }
        }
        let vc = vc_of(&front.flit.packet.msg);
        if lane.router.input_space(Dir::Local, vc) == 0 {
            continue;
        }
        let entry = lane.inject.pop_front().unwrap();
        lane.router.accept(Dir::Local, vc, entry.flit);
    }
}

/// Tick phase 3 for the node range starting at `base`: switch allocation
/// + traversal per router. Ejections land on the node's own NI; link
/// departures land on the node's own delay lines; freed input slots are
/// pushed to `credits_out` as `(upstream node, upstream output port,
/// vc)` for the caller to apply *after* every router has allocated.
pub(crate) fn switch_range(
    lanes: &mut [Lane],
    base: usize,
    topo: &Topo,
    cycle: u64,
    faults: Option<&FaultState>,
    stats: &mut NetStats,
    scratch: &mut Vec<(Dir, usize, Flit)>,
    credits_out: &mut Vec<(usize, Dir, usize)>,
) {
    for li in 0..lanes.len() {
        let node = base + li;
        if let Some(st) = faults {
            let f = st.slow[node];
            if f > 1 && cycle % f as u64 != 0 {
                // Straggler off-cycle: the slow clock domain holds its
                // pipeline; only the arbitration pointer moves.
                lanes[li].router.rr_advance(1);
                continue;
            }
        }
        if lanes[li].router.is_idle() {
            lanes[li].router.rr_advance(1);
            continue;
        }
        scratch.clear();
        lanes[li].router.tick_into(topo, scratch);
        lanes[li].activity += scratch.len() as u64;
        for k in 0..lanes[li].router.freed.len() {
            let (port_idx, vc) = lanes[li].router.freed[k];
            let port = Dir::ALL[port_idx];
            if port == Dir::Local {
                continue; // injection checks space directly
            }
            let upstream =
                topo.neighbour(NodeId(node), port).expect("freed slot from edge port");
            credits_out.push((upstream.0, port.opposite(), vc));
        }
        for (dir, vc, flit) in scratch.drain(..) {
            lanes[li].link_flits[dir.index()] += 1;
            if dir == Dir::Local {
                stats.flit_ejections += 1;
                deliver_local_lane(&mut lanes[li], flit, stats);
            } else {
                stats.flit_hops += 1;
                lanes[li].links[dir.index()].push_back((
                    cycle + LINK_CYCLES + ROUTER_PIPELINE,
                    vc,
                    flit,
                ));
            }
        }
    }
}

/// Eject one flit at its destination NI: advance (or open) the packet's
/// assembly entry, and move the packet to the inbox when the tail lands.
pub(crate) fn deliver_local_lane(lane: &mut Lane, flit: Flit, stats: &mut NetStats) {
    let id = flit.packet.id;
    let entry = match lane.eject.entry(id) {
        std::collections::btree_map::Entry::Vacant(v) => {
            v.insert(EjectState { packet: flit.packet.clone(), arrived: 0 })
        }
        std::collections::btree_map::Entry::Occupied(o) => o.into_mut(),
    };
    entry.arrived += 1;
    if flit.is_tail() {
        let st = lane.eject.remove(&id).unwrap();
        debug_assert_eq!(st.arrived as usize, st.packet.len_flits());
        lane.inbox.push_back(st.packet);
        stats.packets_delivered += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::packet::Message;
    use crate::noc::router::{LINK_CYCLES, ROUTER_PIPELINE};
    use crate::noc::topology::{Mesh, Ring, Torus};

    const HOP: u64 = LINK_CYCLES + ROUTER_PIPELINE;

    fn net(cols: usize, rows: usize) -> Network {
        Network::new(Mesh::new(cols, rows))
    }

    #[test]
    fn single_flit_latency_is_hops_times_hop_cost() {
        let mut n = net(4, 1);
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(3), Message::Raw(7)));
        let mut t = 0;
        let got = loop {
            n.tick();
            t += 1;
            if let Some(p) = n.recv(NodeId(3)) {
                break p;
            }
            assert!(t < 1000);
        };
        assert_eq!(got.msg, Message::Raw(7));
        // 1 injection cycle + 3 hops x (pipeline + link). Pinned exactly so
        // timing regressions are caught.
        assert_eq!(t, 1 + 3 * HOP as usize, "unexpected head latency");
    }

    #[test]
    fn payload_survives_transit() {
        let mut n = net(3, 3);
        let data: Vec<u8> = (0..1000).map(|i| (i * 7 % 251) as u8).collect();
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(8), Message::Raw(1)).with_payload(data.clone()),
        );
        n.run_until_idle(10_000);
        let p = n.recv(NodeId(8)).expect("delivered");
        assert_eq!(&**p.payload.as_ref().unwrap(), &data);
    }

    #[test]
    fn throughput_one_flit_per_cycle() {
        // A long packet's delivery time ~= serialization + pipe latency.
        let mut n = net(2, 1);
        let flits = 256usize; // 255 * 64B payload
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(1), Message::Raw(0))
                .with_phantom_payload((flits - 1) * 64),
        );
        let spent = n.run_until_idle(10_000);
        // Lower bound: flits cycles of serialization. Upper: + small constant.
        assert!(spent as usize >= flits, "{spent} < {flits}");
        assert!(spent as usize <= flits + 4 * HOP as usize, "{spent} too slow");
    }

    #[test]
    fn multicast_delivers_to_every_destination_with_shared_links() {
        let mut n = net(4, 4);
        let dsts = vec![NodeId(3), NodeId(7), NodeId(15)];
        let data: Vec<u8> = (0..256).map(|i| i as u8).collect();
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(3), Message::Raw(2))
                .with_payload(data.clone())
                .with_mcast(dsts.clone()),
        );
        n.run_until_idle(10_000);
        for d in &dsts {
            let p = n.recv(*d).expect("each dest gets a copy");
            assert_eq!(&**p.payload.as_ref().unwrap(), &data);
        }
        // Shared-prefix replication: strictly fewer flit-hops than 3 unicasts.
        let flits = 1 + 256 / 64;
        let unicast_hops: usize =
            dsts.iter().map(|&d| n.topo.distance(NodeId(0), d)).sum::<usize>() * flits;
        assert!((n.stats.flit_hops as usize) < unicast_hops);
    }

    #[test]
    fn torus_delivers_over_wrap_links_with_fewer_hops() {
        // 0 -> 15 on a 4x4 torus: 2 wrap hops instead of the mesh's 6.
        let run = |topo: Topo| -> (u64, bool) {
            let mut n = Network::new(topo);
            n.send(
                NodeId(0),
                Packet::new(0, NodeId(0), NodeId(15), Message::Raw(5)).with_payload(vec![7; 128]),
            );
            n.run_until_idle(10_000);
            let got = n.recv(NodeId(15)).expect("delivered");
            (n.stats.flit_hops, got.payload.as_ref().unwrap()[..] == [7; 128][..])
        };
        let (mesh_hops, mesh_ok) = run(Topo::Mesh(Mesh::new(4, 4)));
        let (torus_hops, torus_ok) = run(Topo::Torus(Torus::new(4, 4)));
        assert!(mesh_ok && torus_ok);
        assert!(torus_hops < mesh_hops, "torus {torus_hops} >= mesh {mesh_hops}");
    }

    #[test]
    fn ring_routes_both_arcs_and_drains() {
        let mut n = Network::new(Ring::new(8));
        let data: Vec<u8> = (0..256).map(|i| i as u8).collect();
        // 2 East hops to node 2, 2 West (wrap) hops to node 6.
        for dst in [2usize, 6] {
            n.send(
                NodeId(0),
                Packet::new(0, NodeId(0), NodeId(dst), Message::Raw(dst as u64))
                    .with_payload(data.clone()),
            );
        }
        n.run_until_idle(10_000);
        for dst in [2usize, 6] {
            let p = n.recv(NodeId(dst)).expect("delivered");
            assert_eq!(&**p.payload.as_ref().unwrap(), &data);
        }
        assert!(n.is_idle());
    }

    #[test]
    fn gated_injection_blocks_until_gate_opens() {
        let mut n = net(2, 1);
        let gate: Gate = Arc::new(GateCell::new(0));
        n.send_gated(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(1), Message::Raw(3)).with_phantom_payload(64),
            gate.clone(),
        );
        for _ in 0..50 {
            n.tick();
        }
        assert!(n.recv(NodeId(1)).is_none(), "nothing may move while gated");
        gate.set(2); // open both flits
        n.run_until_idle(1_000);
        assert!(n.recv(NodeId(1)).is_some());
    }

    #[test]
    fn progress_of_reports_partial_arrival() {
        let mut n = net(2, 1);
        let id = n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(1), Message::Raw(4)).with_phantom_payload(64 * 9),
        );
        // Tick until at least one flit arrived but not all.
        let mut partial_seen = false;
        for _ in 0..200 {
            n.tick();
            if let Some(k) = n.progress_of(NodeId(1), id) {
                assert!(k >= 1);
                partial_seen = true;
                break;
            }
        }
        assert!(partial_seen);
        n.run_until_idle(1_000);
        assert_eq!(n.progress_of(NodeId(1), id), None);
        assert!(n.recv(NodeId(1)).is_some());
    }

    #[test]
    fn two_streams_share_fabric_fairly() {
        // Two senders to the same column: both must complete.
        let mut n = net(3, 3);
        let bytes = 64 * 32;
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(8), Message::Raw(0)).with_phantom_payload(bytes),
        );
        n.send(
            NodeId(1),
            Packet::new(0, NodeId(1), NodeId(8), Message::Raw(1)).with_phantom_payload(bytes),
        );
        n.run_until_idle(10_000);
        let mut got = vec![];
        while let Some(p) = n.recv(NodeId(8)) {
            got.push(p.msg.clone());
        }
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn is_idle_after_drain() {
        let mut n = net(2, 2);
        assert!(n.is_idle());
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0)));
        assert!(!n.is_idle());
        n.run_until_idle(1_000);
        assert!(n.is_idle());
    }

    #[test]
    fn next_event_reports_delay_line_skip_ahead() {
        // Drive a single flit until it sits on a link delay line only,
        // then check the hint points at the cycle before delivery.
        let mut n = net(2, 1);
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(1), Message::Raw(0)));
        assert_eq!(n.next_event(), Some(0), "queued injection is busy work");
        // Cycle 1: the flit injects and traverses the switch in the same
        // tick, landing on the link with deliver_at = 1 + HOP.
        n.tick();
        assert!(n.can_skip(), "only link flits remain");
        // Delivery happens inside the tick that raises the clock to
        // 1 + HOP, i.e. the step taken at cycle HOP.
        assert_eq!(n.next_event(), Some(HOP));
        n.tick(); // an extra no-op tick must not move the event
        assert_eq!(n.next_event(), Some(HOP));
    }

    #[test]
    fn skipped_delay_line_delivers_at_the_same_cycle_as_full_tick() {
        let run = |skip: bool| -> (u64, u64) {
            let mut n = net(4, 1);
            n.send(
                NodeId(0),
                Packet::new(0, NodeId(0), NodeId(3), Message::Raw(9)).with_phantom_payload(64),
            );
            let mut ticks = 0u64;
            loop {
                if skip && n.can_skip() {
                    if let Some(ev) = n.next_event() {
                        if ev > n.cycle {
                            n.skip_quiet_cycles(ev - n.cycle);
                        }
                    }
                }
                n.tick();
                ticks += 1;
                if n.is_idle() {
                    return (n.cycle, ticks);
                }
                assert!(n.cycle < 1_000);
            }
        };
        let (full_cycle, full_ticks) = run(false);
        let (skip_cycle, skip_ticks) = run(true);
        assert_eq!(full_cycle, skip_cycle, "skip-ahead changed the drain cycle");
        assert!(skip_ticks < full_ticks, "skip-ahead executed no fewer ticks");
    }

    #[test]
    fn run_until_idle_skips_but_reports_identical_cycles() {
        let send_all = |n: &mut Network| {
            for src in [0usize, 2] {
                n.send(
                    NodeId(src),
                    Packet::new(0, NodeId(src), NodeId(8), Message::Raw(src as u64))
                        .with_phantom_payload(640),
                );
            }
        };
        let mut fast = net(3, 3);
        send_all(&mut fast);
        let spent_fast = fast.run_until_idle(10_000);
        let mut slow = net(3, 3);
        send_all(&mut slow);
        let mut spent_slow = 0;
        while !slow.is_idle() {
            slow.tick();
            spent_slow += 1;
        }
        assert_eq!(spent_fast, spent_slow);
        assert_eq!(fast.stats.flit_hops, slow.stats.flit_hops);
    }

    #[test]
    fn healthy_fabric_has_no_fault_state() {
        let mut n = net(3, 3);
        n.install_faults(&FaultPlan::default());
        assert!(n.faults.is_none(), "an empty plan must not allocate fault state");
        assert!(!n.fault_active());
        assert_eq!(n.next_fault_activation(), None);
    }

    #[test]
    fn router_kill_blackholes_traffic() {
        // 0 -> 2 on a 4x1 mesh, router 1 killed before injection: the
        // flit dies at node 1's inbound link and never arrives, and the
        // surviving fabric drains back to idle (the sink returns credits).
        let mut n = net(4, 1);
        n.install_faults(&FaultPlan::parse("router:1@0").unwrap());
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(2), Message::Raw(7)));
        for _ in 0..100 {
            n.tick();
        }
        assert!(n.recv(NodeId(2)).is_none(), "flit crossed a dead router");
        assert!(n.router_dead(NodeId(1)));
        assert!(n.fault_active());
        assert_eq!(n.stats.flits_dropped, 1);
        assert_eq!(n.stats.packets_delivered, 0);
        assert!(n.is_idle(), "dropped traffic must not strand fabric state");
    }

    #[test]
    fn link_kill_is_directional() {
        let mut n = net(4, 1);
        n.install_faults(&FaultPlan::parse("link:1-2@0").unwrap());
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(2), Message::Raw(1)));
        n.send(NodeId(3), Packet::new(0, NodeId(3), NodeId(0), Message::Raw(2)));
        for _ in 0..200 {
            n.tick();
        }
        assert!(n.recv(NodeId(2)).is_none(), "eastward flit crossed the severed link");
        let west = n.recv(NodeId(0)).expect("westward direction is a separate channel");
        assert_eq!(west.msg, Message::Raw(2));
    }

    #[test]
    fn kill_mid_flight_sinks_the_stream_without_wedging_upstream() {
        let mut n = net(4, 1);
        // Long packet so flits are buffered/in flight when the kill lands.
        n.install_faults(&FaultPlan::parse("router:2@8").unwrap());
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0)).with_phantom_payload(64 * 12),
        );
        for _ in 0..300 {
            n.tick();
        }
        assert!(n.recv(NodeId(3)).is_none());
        // Every flit of the stream dies at the fault boundary...
        assert_eq!(n.stats.flits_dropped, 13, "head + 12 payload flits sunk");
        // ...and because the boundary returns credits, the stranded tail
        // drains instead of freezing routers 0 and 1: the wormhole locks
        // release and the healthy neighbourhood keeps working.
        assert!(n.is_idle(), "upstream path must drain, not wedge");
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(1), Message::Raw(9)));
        for _ in 0..200 {
            n.tick();
        }
        let got = n.recv(NodeId(1)).expect("healthy neighbourhood must keep working");
        assert_eq!(got.msg, Message::Raw(9));
    }

    #[test]
    fn straggler_slows_but_delivers() {
        let lat = |spec: Option<&str>| -> u64 {
            let mut n = net(4, 1);
            if let Some(s) = spec {
                n.install_faults(&FaultPlan::parse(s).unwrap());
            }
            n.send(
                NodeId(0),
                Packet::new(0, NodeId(0), NodeId(3), Message::Raw(3)).with_phantom_payload(640),
            );
            let mut t = 0u64;
            loop {
                n.tick();
                t += 1;
                if n.recv(NodeId(3)).is_some() {
                    return t;
                }
                assert!(t < 10_000, "straggler starved the stream");
            }
        };
        let healthy = lat(None);
        let slowed = lat(Some("straggle:1x4@0"));
        assert!(slowed > healthy, "straggler {slowed} not slower than {healthy}");
    }

    #[test]
    fn transient_link_kill_heals_and_traffic_resumes() {
        let mut n = net(4, 1);
        n.install_faults(&FaultPlan::parse("link:1-2@5+20").unwrap());
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(2), Message::Raw(1)));
        for _ in 0..25 {
            n.tick(); // reaches cycle 25 = heal cycle
        }
        assert!(n.recv(NodeId(2)).is_none(), "flit crossed the severed window");
        assert_eq!(n.stats.flits_dropped, 1);
        // Healed: the same route works again...
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(2), Message::Raw(2)));
        for _ in 0..100 {
            n.tick();
        }
        assert_eq!(n.recv(NodeId(2)).expect("link healed").msg, Message::Raw(2));
        // ...but the fabric stays in cycle-by-cycle mode forever.
        assert!(n.fault_active(), "active_any must stay sticky after heal");
        assert!(!n.can_skip(), "a once-degraded fabric never skips");
        let d = n.degraded_topology();
        assert!(d.path_is_clean(NodeId(0), NodeId(2)), "snapshot reflects the heal");
    }

    #[test]
    fn transient_router_kill_revives_credit_safe() {
        let mut n = net(4, 1);
        n.install_faults(&FaultPlan::parse("router:1@5+40").unwrap());
        // A long stream dies at the fault boundary while the router is
        // down — exercising purge + sink credit returns.
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0)).with_phantom_payload(64 * 12),
        );
        for _ in 0..45 {
            n.tick(); // cycle 45 = heal cycle
        }
        assert!(n.recv(NodeId(3)).is_none());
        assert!(!n.router_dead(NodeId(1)), "router must be alive after +40");
        // Repeated traffic through the revived router: if any credit
        // leaked during the outage, one of these streams would wedge.
        for round in 0..3u64 {
            n.send(
                NodeId(0),
                Packet::new(0, NodeId(0), NodeId(2), Message::Raw(round))
                    .with_phantom_payload(64 * 10),
            );
            for _ in 0..200 {
                n.tick();
            }
            assert_eq!(
                n.recv(NodeId(2)).expect("revived router forwards").msg,
                Message::Raw(round)
            );
        }
        assert!(n.is_idle(), "no stranded fabric state after revival");
    }

    #[test]
    fn heal_applies_before_a_same_cycle_activation() {
        // link 1->2 heals at cycle 25; a second kill of the same link
        // activates at 25. Heal-then-activate means the link ends the
        // cycle dead — a flit sent after 25 must sink.
        let mut n = net(4, 1);
        n.install_faults(&FaultPlan::parse("link:1-2@5+20;link:1-2@25").unwrap());
        for _ in 0..30 {
            n.tick();
        }
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(2), Message::Raw(9)));
        for _ in 0..100 {
            n.tick();
        }
        assert!(n.recv(NodeId(2)).is_none(), "re-kill at the heal cycle must win");
        assert_eq!(n.stats.flits_dropped, 1);
    }

    #[test]
    fn transient_straggler_recovers_full_speed() {
        // Latency of a stream injected after the straggler window closes
        // must match a healthy fabric's.
        let lat = |spec: Option<&str>| -> u64 {
            let mut n = net(4, 1);
            if let Some(s) = spec {
                n.install_faults(&FaultPlan::parse(s).unwrap());
            }
            for _ in 0..50 {
                n.tick(); // straggle window (5..45) passes idle
            }
            n.send(
                NodeId(0),
                Packet::new(0, NodeId(0), NodeId(3), Message::Raw(3)).with_phantom_payload(640),
            );
            let mut t = 0u64;
            loop {
                n.tick();
                t += 1;
                if n.recv(NodeId(3)).is_some() {
                    return t;
                }
                assert!(t < 10_000);
            }
        };
        assert_eq!(lat(Some("straggle:1x4@5+40")), lat(None));
    }

    #[test]
    fn pending_fault_caps_next_event_and_blocks_skipping_after_activation() {
        let mut n = net(2, 1);
        n.install_faults(&FaultPlan::parse("router:1@50").unwrap());
        // Idle fabric, but an activation is scheduled: the hint points
        // at the tick that raises the clock to 50.
        assert_eq!(n.next_event(), Some(49));
        assert!(n.can_skip(), "pre-activation fabric may skip");
        n.skip_quiet_cycles(49);
        n.tick();
        assert_eq!(n.cycle, 50);
        assert!(n.fault_active());
        assert!(!n.can_skip(), "degraded fabrics tick cycle-by-cycle");
        assert_eq!(n.next_event(), Some(n.cycle));
    }

    #[test]
    fn degraded_topology_snapshot_reflects_kills() {
        let mut n = net(4, 1);
        n.install_faults(&FaultPlan::parse("router:1@5;link:2-3@5").unwrap());
        assert!(n.degraded_topology().path_is_clean(NodeId(0), NodeId(3)));
        for _ in 0..6 {
            n.tick();
        }
        let d = n.degraded_topology();
        assert!(!d.node_alive(NodeId(1)));
        assert!(!d.path_is_clean(NodeId(0), NodeId(2)), "dead router on path");
        assert!(!d.path_is_clean(NodeId(2), NodeId(3)), "severed link on path");
        assert!(d.path_is_clean(NodeId(3), NodeId(2)), "reverse direction intact");
    }

    #[test]
    fn activity_counters_track_per_router_flit_movement() {
        let mut n = net(4, 1);
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0)).with_phantom_payload(256),
        );
        n.run_until_idle(10_000);
        assert!(n.router_activity(NodeId(0)) > 0);
        assert!(n.router_activity(NodeId(1)) > 0);
        assert!(n.router_activity(NodeId(2)) > 0);
        assert!(n.router_activity(NodeId(3)) > 0, "ejection counts as movement");
    }

    #[test]
    fn link_flit_counters_track_directed_traffic() {
        // 0 -> 3 on a 4x1 mesh: every flit leaves 0, 1 and 2 eastward
        // and ejects at 3. Westward counters stay zero.
        let mut n = net(4, 1);
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0)).with_phantom_payload(256),
        );
        n.run_until_idle(10_000);
        let flits = 1 + 256 / 64;
        for node in [0usize, 1, 2] {
            assert_eq!(n.link_flits(NodeId(node))[Dir::East.index()], flits);
            assert_eq!(n.link_flits(NodeId(node))[Dir::West.index()], 0);
        }
        assert_eq!(n.link_flits(NodeId(3))[Dir::Local.index()], flits);
        // The per-dir counters decompose the per-router activity total.
        for node in 0..4 {
            let lane_total: u64 = n.link_flits(NodeId(node)).iter().sum();
            assert_eq!(lane_total, n.router_activity(NodeId(node)));
        }
    }

    #[test]
    fn load_view_is_lazy_and_zero_cost_when_unused() {
        let mut n = net(2, 1);
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(1), Message::Raw(0)));
        n.run_until_idle(1_000);
        assert!(n.load.is_none(), "unobserved fabric must not allocate load state");
        let v = n.load_view();
        assert!(v.is_zero(), "arming snapshot has no completed window");
        assert!(n.load.is_some());
    }

    #[test]
    fn load_view_ewma_tracks_a_hot_link_and_decays() {
        let mut n = net(2, 1);
        n.load_view(); // arm at cycle 0
        // Saturate 0 -> 1 for a full window: inject a stream long enough
        // that the link moves ~a flit per cycle.
        n.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(1), Message::Raw(0))
                .with_phantom_payload(64 * 300),
        );
        while n.cycle < LOAD_WINDOW {
            n.tick();
        }
        let hot = n.load_view();
        let e = hot.link_load_milli(NodeId(0), Dir::East);
        assert!(e > 300, "hot link must read loaded, got {e}");
        assert!(e <= 1000, "occupancy is capped at 1 flit/cycle");
        // Drain and run two more quiet windows: the EWMA must decay.
        n.run_until_idle(100_000);
        let c = n.cycle;
        while n.cycle < c + LOAD_WINDOW {
            n.tick();
        }
        let cooler = n.load_view();
        assert!(
            cooler.link_load_milli(NodeId(0), Dir::East) < e,
            "EWMA must decay on a quiet window"
        );
        // Calls inside the same window return the same snapshot.
        let again = n.load_view();
        assert_eq!(cooler, again, "intra-window snapshots must be stable");
    }

    #[test]
    fn load_view_max_on_path_walks_the_routed_links() {
        let mut v = LoadView::zero(16);
        let m = Mesh::new(4, 4);
        // Path 0 -> 10 routes XY: East (0,1),(1,2), then North (2,6),(6,10).
        v.set_link(NodeId(1), Dir::East, 700);
        v.set_link(NodeId(6), Dir::North, 400);
        v.set_link(NodeId(9), Dir::East, 999); // off-path: must not count
        assert_eq!(v.max_on_path(&m, NodeId(0), NodeId(10)), 700);
        assert_eq!(v.max_on_path(&m, NodeId(2), NodeId(10)), 400);
        assert_eq!(v.max_on_path(&m, NodeId(5), NodeId(5)), 0);
    }

    #[test]
    fn composed_packet_ids_allocate_in_send_order() {
        let mut n = net(2, 1);
        let a = n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(1), Message::Raw(0)));
        let b = n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(1), Message::Raw(1)));
        assert!(a < b, "same-node same-cycle sends must stay ordered");
        n.tick();
        let c = n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(1), Message::Raw(2)));
        assert!(b < c, "a later cycle dominates the id order");
    }

    #[test]
    #[should_panic(expected = "not adjacent")]
    fn non_adjacent_link_kill_rejected_at_install() {
        let mut n = net(4, 4);
        n.install_faults(&FaultPlan::parse("link:0-5@0").unwrap());
    }

    #[test]
    #[should_panic(expected = "watchdog 'network.drain' expired")]
    fn drain_watchdog_fires_past_deadline() {
        let mut n = net(4, 1);
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0)));
        n.run_until_idle(2); // needs 1 + 3*HOP cycles
    }

    #[test]
    fn drain_watchdog_allows_exactly_the_deadline() {
        let need = {
            let mut n = net(4, 1);
            n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0)));
            n.run_until_idle(1_000)
        };
        let mut n = net(4, 1);
        n.send(NodeId(0), Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0)));
        assert_eq!(n.run_until_idle(need), need, "deadline == need must pass");
    }
}

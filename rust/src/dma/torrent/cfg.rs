//! Cross-Torrent configuration packets (paper Fig 4(c)).
//!
//! A cfg packet carries: a Type Identifier (read/write), a Frame
//! Identifier (total frame count / current frame id — the cfg is split
//! into frame bodies so it can ride interconnects of any width), and per
//! frame body the six fields A–F: A/B the previous/next chain node, C the
//! chain position, D the task id, E the AXI burst size for the Backend,
//! and F the DSE access pattern. The byte encoding below is what the
//! simulator puts on the wire, so cfg dispatch cost scales with pattern
//! complexity exactly as in the RTL.

use crate::noc::NodeId;

use super::dse::AffinePattern;

/// Chainwrite role this cfg assigns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CfgType {
    /// Remote reads from us (P2P read tunnel).
    Read = 0,
    /// We write into the chain / remote memory.
    Write = 1,
}

/// Decoded configuration for one participating Torrent.
#[derive(Debug, Clone, PartialEq)]
pub struct TorrentCfg {
    pub task: u32,
    pub cfg_type: CfgType,
    /// Previous node in the chain (None for the first follower: the
    /// initiator itself precedes it).
    pub prev: Option<NodeId>,
    /// Next node in the chain (None for the tail).
    pub next: Option<NodeId>,
    /// 0-based position among the followers.
    pub position: u16,
    /// Follower count of the chain.
    pub chain_len: u16,
    /// AXI burst size the Backend should use (field E).
    pub axi_burst_bytes: u32,
    /// Local DSE write pattern (field F).
    pub pattern: AffinePattern,
    /// Waypoint for packets this node sends *backward* toward `prev`
    /// (grant/finish back-prop) when the default route is fault-dirty.
    /// `None` on healthy chains — and then the wire encoding is
    /// byte-identical to the pre-extension format.
    pub via_prev: Option<NodeId>,
    /// Waypoint for packets this node sends *forward* toward `next`
    /// (the data stream forward).
    pub via_next: Option<NodeId>,
}

const MAGIC: u16 = 0x70C7; // "TOrrent Cfg"

/// High bit of the cfg-type word: a via extension (8 trailing bytes —
/// via_prev u32, via_next u32) follows the pattern dims. Healthy cfgs
/// never set it, so their encoding is bit-for-bit the legacy one and
/// every golden cycle pin over cfg dispatch cost still holds.
const VIA_FLAG: u16 = 0x8000;

fn put_u16(v: &mut Vec<u8>, x: u16) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}
fn put_i64(v: &mut Vec<u8>, x: i64) {
    v.extend_from_slice(&x.to_le_bytes());
}

struct Reader<'a>(&'a [u8], usize);

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.1 + n > self.0.len() {
            return Err(format!("cfg truncated at byte {}", self.1));
        }
        let s = &self.0[self.1..self.1 + n];
        self.1 += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64, String> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Sentinel for "no node" in the prev/next fields.
const NONE_NODE: u32 = u32::MAX;

impl TorrentCfg {
    /// Wire encoding (little-endian, variable length with the pattern).
    pub fn encode(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(64);
        let has_via = self.via_prev.is_some() || self.via_next.is_some();
        put_u16(&mut v, MAGIC);
        put_u16(&mut v, self.cfg_type as u16 | if has_via { VIA_FLAG } else { 0 });
        put_u32(&mut v, self.task);
        put_u32(&mut v, self.prev.map(|n| n.0 as u32).unwrap_or(NONE_NODE));
        put_u32(&mut v, self.next.map(|n| n.0 as u32).unwrap_or(NONE_NODE));
        put_u16(&mut v, self.position);
        put_u16(&mut v, self.chain_len);
        put_u32(&mut v, self.axi_burst_bytes);
        // Field F: the DSE pattern.
        put_u64(&mut v, self.pattern.base);
        put_u32(&mut v, self.pattern.elem_bytes as u32);
        put_u16(&mut v, self.pattern.dims.len() as u16);
        for &(count, stride) in &self.pattern.dims {
            put_u32(&mut v, count as u32);
            put_i64(&mut v, stride);
        }
        if has_via {
            put_u32(&mut v, self.via_prev.map(|n| n.0 as u32).unwrap_or(NONE_NODE));
            put_u32(&mut v, self.via_next.map(|n| n.0 as u32).unwrap_or(NONE_NODE));
        }
        v
    }

    /// Decode one cfg from the front of `bytes`; returns the cfg and the
    /// bytes consumed. A packet may carry several concatenated cfgs (the
    /// read-tunnel request carries the remote read cfg followed by the
    /// requester's write-back cfg).
    pub fn decode_prefix(bytes: &[u8]) -> Result<(Self, usize), String> {
        let mut r = Reader(bytes, 0);
        let cfg = Self::decode_reader(&mut r)?;
        Ok((cfg, r.1))
    }

    pub fn decode(bytes: &[u8]) -> Result<Self, String> {
        Ok(Self::decode_prefix(bytes)?.0)
    }

    fn decode_reader(r: &mut Reader) -> Result<Self, String> {
        if r.u16()? != MAGIC {
            return Err("bad cfg magic".into());
        }
        let type_word = r.u16()?;
        let has_via = type_word & VIA_FLAG != 0;
        let cfg_type = match type_word & !VIA_FLAG {
            0 => CfgType::Read,
            1 => CfgType::Write,
            t => return Err(format!("bad cfg type {t}")),
        };
        let task = r.u32()?;
        let prev = match r.u32()? {
            NONE_NODE => None,
            n => Some(NodeId(n as usize)),
        };
        let next = match r.u32()? {
            NONE_NODE => None,
            n => Some(NodeId(n as usize)),
        };
        let position = r.u16()?;
        let chain_len = r.u16()?;
        let axi_burst_bytes = r.u32()?;
        let base = r.u64()?;
        let elem_bytes = r.u32()? as usize;
        let ndims = r.u16()? as usize;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            let count = r.u32()? as usize;
            let stride = r.i64()?;
            dims.push((count, stride));
        }
        let (via_prev, via_next) = if has_via {
            let vp = match r.u32()? {
                NONE_NODE => None,
                n => Some(NodeId(n as usize)),
            };
            let vn = match r.u32()? {
                NONE_NODE => None,
                n => Some(NodeId(n as usize)),
            };
            (vp, vn)
        } else {
            (None, None)
        };
        Ok(TorrentCfg {
            task,
            cfg_type,
            prev,
            next,
            position,
            chain_len,
            axi_burst_bytes,
            pattern: AffinePattern { base, elem_bytes, dims },
            via_prev,
            via_next,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TorrentCfg {
        TorrentCfg {
            task: 42,
            cfg_type: CfgType::Write,
            prev: Some(NodeId(3)),
            next: None,
            position: 2,
            chain_len: 3,
            axi_burst_bytes: 4096,
            pattern: AffinePattern {
                base: 0x20_0040,
                elem_bytes: 8,
                dims: vec![(16, 128), (4, 2048)],
            },
            via_prev: None,
            via_next: None,
        }
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        assert_eq!(TorrentCfg::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn roundtrip_no_prev_no_dims() {
        let c = TorrentCfg {
            task: 0,
            cfg_type: CfgType::Read,
            prev: None,
            next: Some(NodeId(7)),
            position: 0,
            chain_len: 1,
            axi_burst_bytes: 64,
            pattern: AffinePattern::contiguous(0, 64),
            via_prev: None,
            via_next: None,
        };
        assert_eq!(TorrentCfg::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn via_extension_roundtrips_and_costs_eight_bytes() {
        let plain = sample();
        let mut rerouted = sample();
        rerouted.via_prev = Some(NodeId(9));
        rerouted.via_next = None;
        let got = TorrentCfg::decode(&rerouted.encode()).unwrap();
        assert_eq!(got, rerouted);
        assert_eq!(rerouted.encode().len(), plain.encode().len() + 8);
        // Both vias set, including node 0 (must not collide with the
        // NONE sentinel).
        rerouted.via_next = Some(NodeId(0));
        assert_eq!(TorrentCfg::decode(&rerouted.encode()).unwrap(), rerouted);
    }

    #[test]
    fn via_free_encoding_is_bit_identical_to_legacy() {
        // No via = no flag, no trailing bytes: the type word is the bare
        // CfgType and nothing follows the pattern dims, so healthy-path
        // cfg dispatch cost (and every golden cycle pin) is unchanged.
        let bytes = sample().encode();
        assert_eq!(u16::from_le_bytes([bytes[2], bytes[3]]), CfgType::Write as u16);
        let (decoded, consumed) = TorrentCfg::decode_prefix(&bytes).unwrap();
        assert_eq!(consumed, bytes.len());
        assert_eq!(decoded, sample());
    }

    #[test]
    fn encoded_size_grows_with_pattern_dims() {
        let mut c = sample();
        let s2 = c.encode().len();
        c.pattern.dims.push((2, 4096));
        assert_eq!(c.encode().len(), s2 + 12);
    }

    #[test]
    fn truncated_rejected() {
        let bytes = sample().encode();
        assert!(TorrentCfg::decode(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().encode();
        bytes[0] ^= 0xFF;
        assert!(TorrentCfg::decode(&bytes).is_err());
    }

    #[test]
    fn negative_stride_survives() {
        let mut c = sample();
        c.pattern.dims[0].1 = -512;
        assert_eq!(TorrentCfg::decode(&c.encode()).unwrap(), c);
    }
}

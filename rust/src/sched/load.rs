//! Load-aware chain ordering and the k-way partition pass (ISSUE 10
//! tentpole).
//!
//! [`load_aware_order`] runs the same tail-extension walk as greedy
//! Alg. 1 but replaces the hard link-disjointness test with an
//! ICN-style weighted bid: every candidate leg is scored
//! `hops + w · max_link_load_on_path`, where link load is the fabric's
//! windowed occupancy ([`LoadView`], milli-flits/cycle) and links the
//! chain has already reserved for itself (data leg *and* grant/finish
//! back-leg) are charged as fully occupied. With an idle view the score
//! degenerates to hop count plus the self-collision penalty, i.e. a
//! soft variant of greedy's disjointness preference.
//!
//! [`partition_chains`] is the dynamic-partition extension (à la
//! arxiv 2108.00566): when one long chain's predicted completion under
//! the observed load exceeds the best contiguous k-way split's — plus a
//! per-chain dispatch overhead — the destination set is cut into k
//! concurrent sibling chains. Everything is integer arithmetic with
//! (score, node-id) tie-breaks, so orders and cuts are bit-identical
//! across FullTick/EventDriven/Parallel runs given the same view.

use std::collections::BTreeSet;

use crate::noc::{Dir, LoadView, NodeId, Topology};

/// Weight of the congestion term: milli-hops charged per
/// milli-occupancy unit. 2000 means a fully-occupied link (1000 milli)
/// costs as much as 2 extra hops — hot links are worth detouring
/// around, but not at any geometric price.
pub const LOAD_WEIGHT_MILLI: u64 = 2000;

/// Occupancy charged for links the chain itself already uses (both
/// directions of every reserved leg): full.
const SELF_LOAD_MILLI: u32 = 1000;

/// Per-extra-chain overhead charged against a split, in milli-hops.
/// Each sibling chain pays its own DSE config round and competes for
/// the initiator's injection port, so a split must beat the single
/// chain by a real margin before it wins.
pub const CHAIN_OVERHEAD_MILLI: u64 = 8000;

/// Maximum concurrent sibling chains a partition may produce.
pub const MAX_CHAINS: usize = 4;

/// Score of the routed leg `from -> to` under `load`: `1000 · hops +
/// LOAD_WEIGHT_MILLI · hottest/1000`, where `hottest` is the max
/// occupancy over the leg's links, counting `used` links as fully
/// occupied. Walks `next_hop` exactly like greedy's overlap test.
fn leg_score_milli(
    topo: &dyn Topology,
    from: NodeId,
    to: NodeId,
    load: &LoadView,
    used: &BTreeSet<(NodeId, NodeId)>,
) -> u64 {
    let mut cur = from;
    let mut hops = 0u64;
    let mut hottest = 0u32;
    while cur != to {
        let d = topo.next_hop(cur, to);
        let next = topo.neighbour(cur, d).expect("routing left the fabric");
        let ext = load.link_load_milli(cur, d);
        let link_load =
            if used.contains(&(cur, next)) { SELF_LOAD_MILLI.max(ext) } else { ext };
        hottest = hottest.max(link_load);
        cur = next;
        hops += 1;
    }
    hops * 1000 + LOAD_WEIGHT_MILLI * hottest as u64 / 1000
}

/// Reserve both directions of one chain leg (data + grant/finish
/// routes), mirroring `chain::greedy_order`'s reservation semantics.
fn reserve_leg(
    topo: &dyn Topology,
    used: &mut BTreeSet<(NodeId, NodeId)>,
    from: NodeId,
    to: NodeId,
) {
    for l in topo.links(from, to) {
        used.insert(l);
    }
    for l in topo.links(to, from) {
        used.insert(l);
    }
}

/// Load-aware chain order: repeatedly extend the chain with the
/// destination of minimal `(leg score, node id)` from the current tail.
/// Duplicate destinations keep their multiplicity (one removal per
/// placement), matching the other strategies' multiset semantics. With
/// `LoadView::zero` this is fully deterministic geometry; with a real
/// view the hop term steers legs off hot links.
pub fn load_aware_order(
    topo: &dyn Topology,
    src: NodeId,
    dests: &[NodeId],
    load: &LoadView,
) -> Vec<NodeId> {
    if dests.is_empty() {
        return vec![];
    }
    let mut remaining: Vec<NodeId> = dests.to_vec();
    let mut order = Vec::with_capacity(dests.len());
    let mut used: BTreeSet<(NodeId, NodeId)> = BTreeSet::new();
    let mut tail = src;
    while !remaining.is_empty() {
        let chosen = *remaining
            .iter()
            .min_by_key(|&&c| (leg_score_milli(topo, tail, c, load, &used), c))
            .unwrap();
        reserve_leg(topo, &mut used, tail, chosen);
        let pos = remaining.iter().position(|&d| d == chosen).unwrap();
        remaining.remove(pos);
        order.push(chosen);
        tail = chosen;
    }
    order
}

/// Predicted completion of a chain `src -> order[0] -> ...` under
/// `load`, in milli-hops: the sum of leg scores. No self-reservation —
/// the estimate ranks alternatives, it does not re-plan them.
fn chain_cost_milli(
    topo: &dyn Topology,
    src: NodeId,
    order: &[NodeId],
    load: &LoadView,
) -> u64 {
    let empty = BTreeSet::new();
    let mut cost = 0u64;
    let mut prev = src;
    for &d in order {
        cost += leg_score_milli(topo, prev, d, load, &empty);
        prev = d;
    }
    cost
}

/// Best contiguous split of `order` into exactly `k` non-empty
/// segments, minimizing the maximum per-segment cost (each segment pays
/// its own `src -> head` leg). Returns `(max segment cost, cut
/// indices)`; cuts are segment start offsets (excluding 0). O(n²k) DP —
/// n is at most the paper's 63-destination sets.
fn best_split(
    topo: &dyn Topology,
    src: NodeId,
    order: &[NodeId],
    load: &LoadView,
    k: usize,
) -> (u64, Vec<usize>) {
    let n = order.len();
    let empty = BTreeSet::new();
    // seg_cost[i][j]: cost of the segment order[i..=j] as its own chain.
    let mut seg_cost = vec![vec![0u64; n]; n];
    for i in 0..n {
        let mut cost = leg_score_milli(topo, src, order[i], load, &empty);
        seg_cost[i][i] = cost;
        for j in i + 1..n {
            cost += leg_score_milli(topo, order[j - 1], order[j], load, &empty);
            seg_cost[i][j] = cost;
        }
    }
    // dp[m][j]: min over splits of order[..=j] into m segments of the
    // max segment cost; cut[m][j] remembers the last segment's start.
    let mut dp = vec![vec![u64::MAX; n]; k + 1];
    let mut cut = vec![vec![0usize; n]; k + 1];
    for j in 0..n {
        dp[1][j] = seg_cost[0][j];
    }
    for m in 2..=k {
        for j in m - 1..n {
            for s in m - 1..=j {
                let prev = dp[m - 1][s - 1];
                if prev == u64::MAX {
                    continue;
                }
                let cand = prev.max(seg_cost[s][j]);
                if cand < dp[m][j] {
                    dp[m][j] = cand;
                    cut[m][j] = s;
                }
            }
        }
    }
    let mut cuts = Vec::with_capacity(k - 1);
    let mut j = n - 1;
    for m in (2..=k).rev() {
        let s = cut[m][j];
        cuts.push(s);
        j = s - 1;
    }
    cuts.reverse();
    (dp[k][n - 1], cuts)
}

/// Partition pass: split `order` into up to [`MAX_CHAINS`] concurrent
/// chains when the best split's predicted completion (max segment cost
/// plus [`CHAIN_OVERHEAD_MILLI`] per extra chain) strictly beats the
/// single chain's. Returns the segments in order-position order
/// (`len() == 1` means "don't split"). Ties keep the smaller k — the
/// deterministic, conservative choice.
pub fn partition_chains(
    topo: &dyn Topology,
    src: NodeId,
    order: &[NodeId],
    load: &LoadView,
) -> Vec<Vec<NodeId>> {
    if order.len() < 2 {
        return vec![order.to_vec()];
    }
    let single = chain_cost_milli(topo, src, order, load);
    let mut best_cost = single;
    let mut best_cuts: Vec<usize> = vec![];
    let max_k = MAX_CHAINS.min(order.len());
    for k in 2..=max_k {
        let (max_seg, cuts) = best_split(topo, src, order, load, k);
        let predicted = max_seg + CHAIN_OVERHEAD_MILLI * (k as u64 - 1);
        if predicted < best_cost {
            best_cost = predicted;
            best_cuts = cuts;
        }
    }
    if best_cuts.is_empty() {
        return vec![order.to_vec()];
    }
    let mut segments = Vec::with_capacity(best_cuts.len() + 1);
    let mut start = 0usize;
    for &c in &best_cuts {
        segments.push(order[start..c].to_vec());
        start = c;
    }
    segments.push(order[start..].to_vec());
    segments
}

/// Synthetic view with one hot row of eastward links — shared by the
/// unit tests here and the scheduler bench.
#[doc(hidden)]
pub fn hot_row_view(n_nodes: usize, cols: usize, row: usize, milli: u32) -> LoadView {
    let mut v = LoadView::zero(n_nodes);
    for x in 0..cols.saturating_sub(1) {
        v.set_link(NodeId(row * cols + x), Dir::East, milli);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::Mesh;
    use crate::sched::chain::greedy_order;
    use crate::sched::hops::chain_hops;

    #[test]
    fn idle_view_orders_are_deterministic_and_complete() {
        let m = Mesh::new(8, 8);
        let dests: Vec<NodeId> = [3, 7, 21, 63, 40, 11].map(NodeId).to_vec();
        let zero = LoadView::zero(64);
        let a = load_aware_order(&m, NodeId(0), &dests, &zero);
        let b = load_aware_order(&m, NodeId(0), &dests, &zero);
        assert_eq!(a, b, "same inputs must replay identically");
        let mut s = a.clone();
        s.sort();
        let mut want = dests.clone();
        want.sort();
        assert_eq!(s, want, "order must permute the destination set");
    }

    #[test]
    fn keeps_duplicate_destinations() {
        let m = Mesh::new(4, 4);
        let dests: Vec<NodeId> = [5, 2, 5, 2].map(NodeId).to_vec();
        let o = load_aware_order(&m, NodeId(0), &dests, &LoadView::zero(16));
        assert_eq!(o.len(), 4);
        let mut s = o.clone();
        s.sort();
        assert_eq!(s, [2, 2, 5, 5].map(NodeId).to_vec());
    }

    #[test]
    fn hot_link_steers_the_chain_off_the_congested_row() {
        // Destinations 3 (3,0) and 12 (0,3) from src 0 on a 4×4 mesh:
        // both 3 hops, so the idle tie-break takes the lower id first.
        // Saturate row-0 eastward: the 0→3 leg rides the hot row
        // (score 3000 + 2000) while 0→12 is pure-North and cold, so
        // the load-aware order flips.
        let m = Mesh::new(4, 4);
        let dests: Vec<NodeId> = [3, 12].map(NodeId).to_vec();
        let idle = load_aware_order(&m, NodeId(0), &dests, &LoadView::zero(16));
        assert_eq!(idle[0], NodeId(3), "idle tie-break is (score, id)");
        let hot = hot_row_view(16, 4, 0, 1000);
        let steered = load_aware_order(&m, NodeId(0), &dests, &hot);
        assert_eq!(steered[0], NodeId(12), "hot row must repel the first leg");
    }

    #[test]
    fn idle_scores_match_geometry() {
        // With no load anywhere and no reserved links, the first leg's
        // score is exactly 1000·hops, so the chain starts nearest —
        // agreeing with greedy's seed rule.
        let m = Mesh::new(8, 8);
        let dests: Vec<NodeId> = [63, 9, 56].map(NodeId).to_vec();
        let o = load_aware_order(&m, NodeId(0), &dests, &LoadView::zero(64));
        assert_eq!(o[0], NodeId(9));
        // And the full chain's geometric cost stays in greedy's league
        // (same walk, soft instead of hard disjointness).
        let g = chain_hops(&m, NodeId(0), &greedy_order(&m, NodeId(0), &dests));
        let l = chain_hops(&m, NodeId(0), &o);
        assert!(l <= g + 4, "load-aware idle geometry degraded: {l} vs greedy {g}");
    }

    #[test]
    fn partition_declines_on_an_idle_fabric() {
        let m = Mesh::new(4, 4);
        let order: Vec<NodeId> = [1, 2, 3, 7, 11, 15].map(NodeId).to_vec();
        let parts = partition_chains(&m, NodeId(0), &order, &LoadView::zero(16));
        assert_eq!(parts.len(), 1, "an uncongested short chain must not split");
        assert_eq!(parts[0], order);
    }

    #[test]
    fn partition_splits_a_chain_crossing_a_saturated_row() {
        // Six row-0 destinations on a fully-hot row (3000 per leg)
        // followed by six cold column-0 destinations: single chain =
        // 18000 + 7000 (cluster switch) + 5000 = 30000 milli-hops; the
        // 2-way split at the cluster boundary costs max(18000, 6000) +
        // 8000 overhead = 26000, so the partition pass must cut there.
        let m = Mesh::new(8, 8);
        let order: Vec<NodeId> = [1, 2, 3, 4, 5, 6, 8, 16, 24, 32, 40, 48].map(NodeId).to_vec();
        let hot = hot_row_view(64, 8, 0, 1000);
        let parts = partition_chains(&m, NodeId(0), &order, &hot);
        assert_eq!(parts.len(), 2, "saturated row must trigger a 2-way split");
        assert_eq!(parts[0], [1, 2, 3, 4, 5, 6].map(NodeId).to_vec());
        assert_eq!(parts[1], [8, 16, 24, 32, 40, 48].map(NodeId).to_vec());
        // Segments must concatenate back to the original order.
        let flat: Vec<NodeId> = parts.iter().flatten().copied().collect();
        assert_eq!(flat, order);
    }

    #[test]
    fn partition_is_deterministic_under_replay() {
        let m = Mesh::new(8, 8);
        let order: Vec<NodeId> = (1..=10).map(NodeId).collect();
        let hot = hot_row_view(64, 8, 0, 900);
        let a = partition_chains(&m, NodeId(0), &order, &hot);
        let b = partition_chains(&m, NodeId(0), &order, &hot);
        assert_eq!(a, b);
    }
}

//! Artifact manifest parsing (`artifacts/manifest.txt`, written by
//! `python/compile/aot.py`): tab-separated
//! `name \t file \t in_shapes \t out_shapes` with shapes like
//! `f32[256,64];f32[64,128]`.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// One tensor shape, e.g. `f32[256,64]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl ShapeSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| anyhow!("bad shape spec {s:?}"))?;
        let dims_str = rest.strip_suffix(']').ok_or_else(|| anyhow!("bad shape spec {s:?}"))?;
        let dims = if dims_str.is_empty() {
            vec![]
        } else {
            dims_str
                .split(',')
                .map(|d| d.trim().parse().with_context(|| format!("bad dim in {s:?}")))
                .collect::<Result<_>>()?
        };
        Ok(ShapeSpec { dtype: dtype.to_string(), dims })
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<ShapeSpec>,
    pub outputs: Vec<ShapeSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                return Err(anyhow!("manifest line {}: expected 4 columns", ln + 1));
            }
            let shapes = |s: &str| -> Result<Vec<ShapeSpec>> {
                s.split(';').map(ShapeSpec::parse).collect()
            };
            entries.push(ManifestEntry {
                name: cols[0].to_string(),
                file: cols[1].to_string(),
                inputs: shapes(cols[2])?,
                outputs: shapes(cols[3])?,
            });
        }
        Ok(Manifest { entries })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read manifest {:?} (run `make artifacts`)", path.as_ref()))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_shape_specs() {
        let s = ShapeSpec::parse("f32[256,64]").unwrap();
        assert_eq!(s.dtype, "f32");
        assert_eq!(s.dims, vec![256, 64]);
        assert_eq!(s.numel(), 256 * 64);
        assert_eq!(ShapeSpec::parse("f32[]").unwrap().dims, Vec::<usize>::new());
        assert!(ShapeSpec::parse("f32 256,64").is_err());
    }

    #[test]
    fn parses_manifest_lines() {
        let m = Manifest::parse(
            "# comment\n\
             gemm\tgemm.hlo.txt\tf32[2,3];f32[3,4]\tf32[2,4]\n\
             kv\tkv.hlo.txt\tf32[8,4];f32[4,2];f32[4,2]\tf32[8,2];f32[8,2]\n",
        )
        .unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[0].inputs.len(), 2);
        assert_eq!(m.entries[1].outputs.len(), 2);
        assert_eq!(m.entries[1].inputs[0].dims, vec![8, 4]);
    }

    #[test]
    fn rejects_malformed_rows() {
        assert!(Manifest::parse("just-one-column").is_err());
        assert!(Manifest::parse("a\tb\tf32[2\tf32[2]").is_err());
    }
}

//! # torrent-dma
//!
//! Reproduction of *"Torrent: A Distributed DMA for Efficient and Flexible
//! Point-to-Multipoint Data Movement"* (Deng, Kong, Yi, Antonio, Verhelst —
//! CS.AR 2025).
//!
//! Torrent embeds point-to-multipoint (P2MP) capability in distributed DMA
//! endpoints instead of NoC routers: a P2MP transfer becomes a *Chainwrite*
//! through a doubly linked list of endpoints, keeping every on-wire
//! transfer point-to-point and AXI-compatible.
//!
//! This crate contains:
//!
//! * a cycle-stepped 2D-mesh wormhole NoC simulator with XY routing and an
//!   ESP-style network-layer multicast router baseline ([`noc`]);
//! * an AXI4 transaction layer ([`axi`]) and banked scratchpads ([`mem`]);
//! * the Torrent architecture — DSE, data switch, backend, Chainwrite
//!   four-phase FSM — plus the iDMA / XDMA baselines ([`dma`]);
//! * the chain-sequence schedulers (naive / greedy / TSP) and hop-count
//!   models ([`sched`]);
//! * compute clusters, the Occamy-derived SoC builder and the task-level
//!   coordinator ([`cluster`], [`soc`], [`coordinator`]);
//! * a PJRT runtime that loads the JAX/Pallas AOT artifacts and runs the
//!   DeepSeek-V3 attention numerics from Rust ([`runtime`]);
//! * analytic area/power/efficiency models calibrated with the paper's
//!   published constants ([`analysis`]);
//! * the workload generators for every figure/table ([`workloads`]).
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod analysis;
pub mod axi;
pub mod cluster;
pub mod coordinator;
pub mod dma;
pub mod mem;
pub mod noc;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod soc;
pub mod util;
pub mod workloads;

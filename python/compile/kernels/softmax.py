"""L1 Pallas row-softmax kernel (numerically stable).

Used by the L2 attention model between the two GeMMs. One grid step
processes a block of rows; the full row lives in VMEM (attention rows of
a few thousand f32 fit comfortably), so a simple two-pass max/sum inside
the block suffices — no online renormalization needed at these shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bm",))
def softmax(x, bm=64):
    """Row softmax over the last axis of a 2D array."""
    m, n = x.shape
    bm = min(bm, m)
    while m % bm:
        bm -= 1
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))

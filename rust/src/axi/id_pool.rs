//! AXI transaction-ID pool: bounds outstanding transactions per master.
//!
//! The DMA engines pipeline several bursts; IDs are recycled when the
//! matching B/R response returns. Pool exhaustion is the AXI-level
//! backpressure that bounds a master's in-flight window.

/// Fixed-capacity ID pool.
#[derive(Debug, Clone)]
pub struct IdPool {
    free: Vec<u16>,
    capacity: usize,
}

impl IdPool {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0 && capacity <= u16::MAX as usize);
        IdPool { free: (0..capacity as u16).rev().collect(), capacity }
    }

    pub fn acquire(&mut self) -> Option<u16> {
        self.free.pop()
    }

    pub fn release(&mut self, id: u16) {
        assert!(
            !self.free.contains(&id) && (id as usize) < self.capacity,
            "double release of AXI id {id}"
        );
        self.free.push(id);
    }

    pub fn outstanding(&self) -> usize {
        self.capacity - self.free.len()
    }

    pub fn is_exhausted(&self) -> bool {
        self.free.is_empty()
    }

    pub fn all_free(&self) -> bool {
        self.free.len() == self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut p = IdPool::new(2);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert_ne!(a, b);
        assert!(p.acquire().is_none());
        assert!(p.is_exhausted());
        p.release(a);
        assert_eq!(p.outstanding(), 1);
        assert!(p.acquire().is_some());
    }

    #[test]
    fn all_free_after_full_release() {
        let mut p = IdPool::new(4);
        let ids: Vec<u16> = (0..4).map(|_| p.acquire().unwrap()).collect();
        for id in ids {
            p.release(id);
        }
        assert!(p.all_free());
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_asserts() {
        let mut p = IdPool::new(2);
        let a = p.acquire().unwrap();
        p.release(a);
        p.release(a);
    }
}

"""L1 Pallas GeMM kernels — the compute hot-spot of the paper's cluster.

The evaluation SoC's GeMM accelerator (1024 8-bit MACs) has two modes:

* prefill — multiply 16x8 by 8x8 operand tiles;
* decode  — multiply a 1x64 vector by a 64x16 matrix.

On TPU the same insight maps onto the MXU: we tile the operands into
VMEM-resident blocks with ``BlockSpec`` (the RTL did this with the DSE's
affine loops), run the systolic matmul per block, and accumulate over the
K grid dimension directly in the output block, which Pallas keeps resident
across sequential K steps. All kernels run ``interpret=True`` on this image
(CPU PJRT cannot execute Mosaic custom-calls); real-TPU perf is estimated
structurally in DESIGN.md §Perf-estimates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The accelerator's native tile geometry (prefill mode). TPU blocks are
# multiples of these so one HW tile never straddles a block boundary.
ACCEL_TILE_M, ACCEL_TILE_K, ACCEL_TILE_N = 16, 8, 8
# Decode mode: 1x64 vector times 64x16 matrix.
DECODE_K, DECODE_N = 64, 16


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk, acc_dtype):
    """Grid = (M/bm, N/bn, K/bk), K innermost; accumulate into o_ref."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=acc_dtype
    ).astype(o_ref.dtype)


def _pick_block(dim, pref):
    """Largest divisor of `dim` that is <= pref (block shapes must tile)."""
    b = min(dim, pref)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a, b, bm=64, bk=64, bn=64):
    """Tiled f32/bf16 matmul: (M, K) @ (K, N) -> (M, N) f32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = _pick_block(m, bm), _pick_block(k, bk), _pick_block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2], acc_dtype=jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_int8(a, b, bm=64, bk=64, bn=64):
    """Accelerator-faithful int8 matmul with int32 accumulation."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and a.dtype == jnp.int8 and b.dtype == jnp.int8
    bm, bk, bn = _pick_block(m, bm), _pick_block(k, bk), _pick_block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2], acc_dtype=jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b)


def _decode_kernel(x_ref, w_ref, o_ref):
    """One grid step: a block of decode rows times one weight tile."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bb",))
def decode_matvec(x, w, bb=64):
    """Decode-mode GeMM: (B, 64) @ (64, 16) -> (B, 16).

    The HW multiplies one 1x64 vector per invocation; a single row leaves
    the MXU almost idle, so the TPU adaptation batches `bb` decode rows per
    grid step (DESIGN.md §Hardware-Adaptation) — same math, restored
    occupancy.
    """
    batch, k = x.shape
    k2, n = w.shape
    assert k == k2 == DECODE_K and n == DECODE_N, (x.shape, w.shape)
    bb = _pick_block(batch, bb)
    return pl.pallas_call(
        _decode_kernel,
        grid=(batch // bb,),
        in_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.float32),
        interpret=True,
    )(x, w)

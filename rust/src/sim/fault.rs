//! Deterministic fault injection: seeded schedules of link kills, router
//! kills, straggler (slow-clock) routers, and follower-engine drop-outs.
//!
//! A [`FaultPlan`] is pure data — a list of `(cycle, kind)` activations
//! plus detection/repair policy knobs — attached to `SocConfig` and
//! interpreted by the fabric (`noc::Network`), the SoC tick loop
//! (follower drops), and the coordinator (detection + repair). Keeping
//! the plan here, below `noc`, means every layer can speak the same
//! vocabulary without a dependency cycle; node references are therefore
//! raw `usize` indices, converted to `NodeId` at the point of use.
//!
//! Determinism: activations fire at fixed cycles, the plan is immutable
//! after construction, and nothing in this module consults a clock or an
//! RNG — the same plan against the same workload replays bit-identically
//! under both step modes.

use std::fmt;

/// What breaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The directed physical channel `from -> to` becomes a black hole:
    /// flits in flight and every future flit die at the receiving edge,
    /// with their credits returned upstream — data is lost but flow
    /// control survives, so surviving routes sharing the sender keep
    /// moving (DESIGN.md §Fault-model). Kill both directions with two
    /// entries.
    LinkKill { from: usize, to: usize },
    /// The router (and the cluster behind its local port) goes dark:
    /// buffered flits are purged (credits returned to the neighbours
    /// that issued them), in-flight deliveries sink at the boundary, and
    /// nothing is ever forwarded again.
    RouterKill { node: usize },
    /// The router only advances its pipeline every `factor`-th cycle —
    /// a slow clock domain, not a failure. `factor >= 2`.
    Straggler { node: usize, factor: u32 },
    /// The node's DMA engines stop ticking and every packet addressed to
    /// the cluster is discarded on delivery; the router keeps forwarding
    /// through-traffic. Models a hung core with a live NoC interface.
    FollowerDrop { node: usize },
}

/// One scheduled activation, optionally transient (self-healing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    /// First cycle at which the fault is in effect.
    pub at_cycle: u64,
    pub kind: FaultKind,
    /// Absolute cycle at which the fault heals itself (`at_cycle + D`
    /// for the `@C+D` grammar), `None` for permanent faults. Only
    /// [`FaultKind::LinkKill`], [`FaultKind::RouterKill`] and
    /// [`FaultKind::Straggler`] may be transient: a dropped follower has
    /// lost engine state that no healed fabric can restore. Heals are
    /// processed *before* same-cycle activations, so a flapping link
    /// expressed as kill@C+D, kill@(C+D) re-kills cleanly.
    pub heals_at: Option<u64>,
}

impl Fault {
    /// A permanent fault at `at_cycle`.
    pub fn new(at_cycle: u64, kind: FaultKind) -> Self {
        Fault { at_cycle, kind, heals_at: None }
    }

    /// A transient fault in effect for `duration` cycles from `at_cycle`.
    pub fn transient(at_cycle: u64, kind: FaultKind, duration: u64) -> Self {
        assert!(duration > 0, "transient fault needs a positive duration");
        Fault { at_cycle, kind, heals_at: Some(at_cycle + duration) }
    }
}

/// A complete fault scenario: the activation schedule plus the
/// coordinator's detection/repair policy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    /// A task whose aggregate progress counter is flat for this many
    /// cycles is declared stalled.
    pub detect_timeout: u64,
    /// When false the coordinator diagnoses and fails the task but does
    /// not re-chain (the fail-stop baseline).
    pub repair: bool,
    /// When true, repair chains re-stream only the undelivered tail to
    /// each survivor (partial-transfer resume) instead of the full
    /// payload. Off by default so pre-existing fault pins replay
    /// unchanged; the resilience sweep compares both settings.
    pub resume: bool,
    /// When true, the repair planner searches alternate waypoint routes
    /// (YX fallback on mesh, wrap/detour candidates on torus/ring) for
    /// hops whose default routed path is dirty, instead of dropping
    /// them. Off by default for the same reason as `resume`.
    pub reroute: bool,
}

pub const DEFAULT_DETECT_TIMEOUT: u64 = 10_000;

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            faults: Vec::new(),
            detect_timeout: DEFAULT_DETECT_TIMEOUT,
            repair: true,
            resume: false,
            reroute: false,
        }
    }
}

/// A structurally invalid fault spec, caught at `SocConfig`/`Soc` build
/// time rather than surviving until mid-simulation activation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultError {
    /// A clause references a node index outside the fabric.
    NodeOutOfRange { fault: String, node: usize, n_nodes: usize },
    /// A link kill names the same node on both ends.
    SelfLink { node: usize },
    /// A fault kind that cannot heal carries a `+duration`.
    NotHealable { fault: String },
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultError::NodeOutOfRange { fault, node, n_nodes } => {
                write!(f, "fault {fault} references node {node} outside the {n_nodes}-node fabric")
            }
            FaultError::SelfLink { node } => {
                write!(f, "fault link:{node}-{node} is a self-link (no such channel)")
            }
            FaultError::NotHealable { fault } => {
                write!(f, "fault {fault} cannot be transient (engine state does not heal)")
            }
        }
    }
}

impl std::error::Error for FaultError {}

impl FaultPlan {
    /// No faults scheduled (policy knobs are irrelevant then).
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// True when the plan changes anything at all — the fault layer is
    /// only wired into the fabric when this holds.
    pub fn armed(&self) -> bool {
        !self.faults.is_empty()
    }

    /// Parse the CLI/TOML spec string. Grammar (`;`-separated clauses):
    ///
    /// ```text
    /// link:FROM-TO@CYCLE      kill directed link FROM->TO at CYCLE
    /// link:FROM-TO@CYCLE+DUR  ... transient: the link heals at CYCLE+DUR
    /// router:NODE@CYCLE       kill router NODE at CYCLE
    /// router:NODE@CYCLE+DUR   ... transient: the router revives at CYCLE+DUR
    /// straggle:NODExFACTOR@CYCLE[+DUR]  slow router NODE by FACTOR from CYCLE
    /// drop:NODE@CYCLE         drop follower engines at NODE at CYCLE
    /// timeout:CYCLES          stall-detection window (default 10000)
    /// norepair                fail-stop baseline: diagnose, don't re-chain
    /// resume                  repair re-streams only the undelivered tail
    /// reroute                 repair searches alternate waypoint routes
    /// ```
    ///
    /// `drop` rejects `+DUR`: a follower that lost its engine state has
    /// nothing to heal back to. Example:
    /// `link:3-4@1000+500;router:7@5000;resume;reroute;timeout:2000`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if clause == "norepair" {
                plan.repair = false;
                continue;
            }
            if clause == "resume" {
                plan.resume = true;
                continue;
            }
            if clause == "reroute" {
                plan.reroute = true;
                continue;
            }
            let (head, body) = clause
                .split_once(':')
                .ok_or_else(|| format!("fault clause {clause:?}: expected `kind:args`"))?;
            if head == "timeout" {
                plan.detect_timeout = parse_num(body, clause)?;
                continue;
            }
            let (args, at) = body
                .split_once('@')
                .ok_or_else(|| format!("fault clause {clause:?}: expected `...@cycle`"))?;
            let (at_cycle, duration) = match at.split_once('+') {
                Some((c, d)) => {
                    let dur: u64 = parse_num(d, clause)?;
                    if dur == 0 {
                        return Err(format!(
                            "fault clause {clause:?}: transient duration must be > 0"
                        ));
                    }
                    (parse_num::<u64>(c, clause)?, Some(dur))
                }
                None => (parse_num(at, clause)?, None),
            };
            let kind = match head {
                "link" => {
                    let (from, to) = args
                        .split_once('-')
                        .ok_or_else(|| format!("fault clause {clause:?}: expected `from-to`"))?;
                    FaultKind::LinkKill {
                        from: parse_num::<usize>(from, clause)?,
                        to: parse_num::<usize>(to, clause)?,
                    }
                }
                "router" => FaultKind::RouterKill { node: parse_num(args, clause)? },
                "straggle" => {
                    let (node, factor) = args
                        .split_once('x')
                        .ok_or_else(|| format!("fault clause {clause:?}: expected `nodexfactor`"))?;
                    let factor: u32 = parse_num(factor, clause)?;
                    if factor < 2 {
                        return Err(format!("fault clause {clause:?}: factor must be >= 2"));
                    }
                    FaultKind::Straggler { node: parse_num(node, clause)?, factor }
                }
                "drop" => {
                    if duration.is_some() {
                        return Err(format!(
                            "fault clause {clause:?}: drop cannot be transient \
                             (engine state does not heal)"
                        ));
                    }
                    FaultKind::FollowerDrop { node: parse_num(args, clause)? }
                }
                other => return Err(format!("unknown fault kind {other:?} in {clause:?}")),
            };
            plan.faults.push(Fault { at_cycle, kind, heals_at: duration.map(|d| at_cycle + d) });
        }
        Ok(plan)
    }

    /// Structural validation against a concrete fabric size — node
    /// indices in range, no self-links, no transient follower drops.
    /// Called by `Soc::new` (and the TOML/CLI loaders) so a bad spec
    /// fails at construction with a typed [`FaultError`], not
    /// mid-simulation.
    pub fn validate(&self, n_nodes: usize) -> Result<(), FaultError> {
        for f in &self.faults {
            if let FaultKind::LinkKill { from, to } = f.kind {
                if from == to {
                    return Err(FaultError::SelfLink { node: from });
                }
            }
            if f.heals_at.is_some() && matches!(f.kind, FaultKind::FollowerDrop { .. }) {
                return Err(FaultError::NotHealable { fault: f.kind.to_string() });
            }
            let nodes: &[usize] = match f.kind {
                FaultKind::LinkKill { from, to } => &[from, to],
                FaultKind::RouterKill { node }
                | FaultKind::Straggler { node, .. }
                | FaultKind::FollowerDrop { node } => &[node],
            };
            for &n in nodes {
                if n >= n_nodes {
                    return Err(FaultError::NodeOutOfRange {
                        fault: f.kind.to_string(),
                        node: n,
                        n_nodes,
                    });
                }
            }
        }
        Ok(())
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::LinkKill { from, to } => write!(f, "link:{from}-{to}"),
            FaultKind::RouterKill { node } => write!(f, "router:{node}"),
            FaultKind::Straggler { node, factor } => write!(f, "straggle:{node}x{factor}"),
            FaultKind::FollowerDrop { node } => write!(f, "drop:{node}"),
        }
    }
}

fn parse_num<T: std::str::FromStr>(s: &str, clause: &str) -> Result<T, String> {
    s.trim().parse().map_err(|_| format!("fault clause {clause:?}: bad number {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_disarmed() {
        let p = FaultPlan::default();
        assert!(p.is_empty() && !p.armed());
        assert_eq!(p.detect_timeout, DEFAULT_DETECT_TIMEOUT);
        assert!(p.repair);
        assert!(!p.resume && !p.reroute, "resume/reroute are opt-in");
    }

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse("link:3-4@1000; router:7@5000;straggle:2x4@0;drop:9@2000;timeout:5000;norepair")
            .unwrap();
        assert_eq!(p.detect_timeout, 5000);
        assert!(!p.repair);
        assert_eq!(
            p.faults,
            vec![
                Fault::new(1000, FaultKind::LinkKill { from: 3, to: 4 }),
                Fault::new(5000, FaultKind::RouterKill { node: 7 }),
                Fault::new(0, FaultKind::Straggler { node: 2, factor: 4 }),
                Fault::new(2000, FaultKind::FollowerDrop { node: 9 }),
            ]
        );
    }

    #[test]
    fn parses_transient_faults_and_policy_flags() {
        let p = FaultPlan::parse("link:3-4@1000+500;router:7@50+9;straggle:2x4@10+20;resume;reroute")
            .unwrap();
        assert!(p.resume && p.reroute);
        assert!(p.repair, "resume/reroute do not imply norepair");
        assert_eq!(
            p.faults,
            vec![
                Fault::transient(1000, FaultKind::LinkKill { from: 3, to: 4 }, 500),
                Fault::transient(50, FaultKind::RouterKill { node: 7 }, 9),
                Fault::transient(10, FaultKind::Straggler { node: 2, factor: 4 }, 20),
            ]
        );
        assert_eq!(p.faults[0].heals_at, Some(1500));
        assert_eq!(p.faults[1].heals_at, Some(59));
    }

    #[test]
    fn rejects_malformed_transients() {
        assert!(FaultPlan::parse("drop:3@100+50").is_err(), "drop cannot heal");
        assert!(FaultPlan::parse("link:0-1@100+0").is_err(), "zero duration");
        assert!(FaultPlan::parse("link:0-1@100+x").is_err(), "bad duration");
        assert!(FaultPlan::parse("resume:yes").is_err(), "resume takes no args");
        assert!(FaultPlan::parse("reroute:1").is_err(), "reroute takes no args");
    }

    #[test]
    fn empty_spec_is_default() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert_eq!(FaultPlan::parse(" ; ;").unwrap(), FaultPlan::default());
    }

    #[test]
    fn rejects_malformed_clauses() {
        assert!(FaultPlan::parse("link:3-4").is_err(), "missing @cycle");
        assert!(FaultPlan::parse("link:34@5").is_err(), "missing dash");
        assert!(FaultPlan::parse("router:x@5").is_err(), "bad number");
        assert!(FaultPlan::parse("straggle:2x1@0").is_err(), "factor < 2");
        assert!(FaultPlan::parse("meteor:3@5").is_err(), "unknown kind");
        assert!(FaultPlan::parse("norepair:yes").is_err(), "norepair takes no args");
    }

    #[test]
    fn validate_bounds_node_indices() {
        let p = FaultPlan::parse("router:7@5").unwrap();
        assert!(p.validate(8).is_ok());
        assert_eq!(
            p.validate(7),
            Err(FaultError::NodeOutOfRange { fault: "router:7".into(), node: 7, n_nodes: 7 })
        );
        let l = FaultPlan::parse("link:0-9@5").unwrap();
        assert_eq!(
            l.validate(9),
            Err(FaultError::NodeOutOfRange { fault: "link:0-9".into(), node: 9, n_nodes: 9 })
        );
    }

    #[test]
    fn validate_rejects_self_links() {
        let p = FaultPlan::parse("link:3-3@5").unwrap();
        assert_eq!(p.validate(8), Err(FaultError::SelfLink { node: 3 }));
        // The typed error carries a readable message for CLI surfaces.
        assert!(p.validate(8).unwrap_err().to_string().contains("self-link"));
    }

    #[test]
    fn validate_rejects_unhealable_transients() {
        // The parser already rejects `drop:...+D`; a hand-built plan must
        // still fail validation (defense in depth for programmatic plans).
        let mut p = FaultPlan::default();
        p.faults.push(Fault {
            at_cycle: 10,
            kind: FaultKind::FollowerDrop { node: 1 },
            heals_at: Some(20),
        });
        assert_eq!(p.validate(4), Err(FaultError::NotHealable { fault: "drop:1".into() }));
    }

    #[test]
    fn display_roundtrips_kinds() {
        for spec in ["link:3-4", "router:7", "straggle:2x4", "drop:9"] {
            let p = FaultPlan::parse(&format!("{spec}@11")).unwrap();
            assert_eq!(p.faults[0].kind.to_string(), spec);
        }
    }
}

//! Compute cluster model (paper §IV-A): two RV32I control cores, a GeMM
//! accelerator with 1024 8-bit MACs, hardware performance counters.
//!
//! The accelerator has two operating modes:
//! * **prefill** — multiplies 16×8 by 8×8 operand tiles (one tile-op =
//!   16·8·8 = 1024 MACs = 1 cycle at full utilisation);
//! * **decode** — multiplies a 1×64 vector by a 64×16 matrix (also 1024
//!   MACs/op).
//!
//! The cycle model charges `ceil(M·K·N / 1024)` active cycles plus a
//! fixed launch overhead; the *numerics* of the same GeMM run through the
//! PJRT artifacts (`crate::runtime`) in the end-to-end example — the
//! simulator times the movement, XLA computes the math.

/// MACs retired per cycle.
pub const MACS_PER_CYCLE: u64 = 1024;
/// Accelerator launch overhead (descriptor + pipeline fill).
pub const LAUNCH_CYCLES: u64 = 16;

/// Accelerator operating mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmMode {
    /// 16×8 · 8×8 operand tiles.
    Prefill,
    /// 1×64 · 64×16 vector-matrix.
    Decode,
}

impl GemmMode {
    /// Native tile geometry (m, k, n).
    pub fn tile(&self) -> (usize, usize, usize) {
        match self {
            GemmMode::Prefill => (16, 8, 8),
            GemmMode::Decode => (1, 64, 16),
        }
    }
}

/// Hardware counters (the paper reads latency from these, §IV-B).
#[derive(Debug, Default, Clone)]
pub struct HwCounters {
    pub busy_cycles: u64,
    pub tile_ops: u64,
    pub macs: u64,
    pub launches: u64,
}

/// The GeMM accelerator's timing model.
#[derive(Debug, Default)]
pub struct GemmAccel {
    pub counters: HwCounters,
    busy_until: u64,
}

impl GemmAccel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cycles for an (M, K, N) matmul in `mode`, padding partial tiles to
    /// the native geometry (the RTL pads too).
    pub fn gemm_cycles(&self, mode: GemmMode, m: usize, k: usize, n: usize) -> u64 {
        let (tm, tk, tn) = mode.tile();
        let tiles = m.div_ceil(tm) * k.div_ceil(tk) * n.div_ceil(tn);
        LAUNCH_CYCLES + tiles as u64 * (tm * tk * tn) as u64 / MACS_PER_CYCLE
    }

    /// Issue a matmul at `now`; returns the completion cycle.
    pub fn launch(&mut self, mode: GemmMode, m: usize, k: usize, n: usize, now: u64) -> u64 {
        let cycles = self.gemm_cycles(mode, m, k, n);
        let start = self.busy_until.max(now);
        self.busy_until = start + cycles;
        let (tm, tk, tn) = mode.tile();
        let tiles = (m.div_ceil(tm) * k.div_ceil(tk) * n.div_ceil(tn)) as u64;
        self.counters.busy_cycles += cycles;
        self.counters.tile_ops += tiles;
        self.counters.macs += tiles * (tm * tk * tn) as u64;
        self.counters.launches += 1;
        self.busy_until
    }

    pub fn busy_at(&self, cycle: u64) -> bool {
        cycle < self.busy_until
    }

    /// MAC utilisation over `elapsed` cycles.
    pub fn utilisation(&self, elapsed: u64) -> f64 {
        if elapsed == 0 {
            return 0.0;
        }
        self.counters.macs as f64 / (elapsed as f64 * MACS_PER_CYCLE as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_op_is_one_cycle() {
        let a = GemmAccel::new();
        assert_eq!(a.gemm_cycles(GemmMode::Prefill, 16, 8, 8), LAUNCH_CYCLES + 1);
        assert_eq!(a.gemm_cycles(GemmMode::Decode, 1, 64, 16), LAUNCH_CYCLES + 1);
    }

    #[test]
    fn big_gemm_scales_with_macs() {
        let a = GemmAccel::new();
        // 2048x192x128 int8 on prefill tiles: 128*24*16 tiles, 1 CC each.
        let c = a.gemm_cycles(GemmMode::Prefill, 2048, 192, 128);
        assert_eq!(c, LAUNCH_CYCLES + (2048 / 16 * 192 / 8 * 128 / 8) as u64);
    }

    #[test]
    fn partial_tiles_are_padded() {
        let a = GemmAccel::new();
        assert_eq!(
            a.gemm_cycles(GemmMode::Prefill, 17, 9, 9),
            a.gemm_cycles(GemmMode::Prefill, 32, 16, 16)
        );
    }

    #[test]
    fn launch_serializes_back_to_back_ops() {
        let mut a = GemmAccel::new();
        let t1 = a.launch(GemmMode::Prefill, 16, 8, 8, 0);
        let t2 = a.launch(GemmMode::Prefill, 16, 8, 8, 0);
        assert_eq!(t2, 2 * t1);
        assert!(a.busy_at(t2 - 1));
        assert!(!a.busy_at(t2));
        assert_eq!(a.counters.launches, 2);
    }

    #[test]
    fn utilisation_counts_macs() {
        let mut a = GemmAccel::new();
        let done = a.launch(GemmMode::Prefill, 256, 64, 64, 0);
        let util = a.utilisation(done);
        assert!(util > 0.9, "util {util}");
        assert!(util <= 1.0);
    }
}

//! ESP-style network-layer multicast source engine + destination sink
//! (the paper's primary comparison baseline, §IV-A/B).
//!
//! The source programs the routers' multicast destination sets (a
//! configuration cost that grows faster than Torrent's per-destination
//! cfg — the paper observes ESP's "configuration complexity grows faster
//! with N_dst"), then streams burst-sized segments with a destination-set
//! header; the mesh routers replicate flits along the XY tree
//! ([`crate::noc::multicast`]). Every destination writes the payload at
//! its drop address and acknowledges the final segment; the source
//! timestamps completion at the last ack.

use std::collections::{BTreeSet, VecDeque};
use std::sync::Arc;

use crate::mem::Scratchpad;
use crate::noc::{Message, NetPort, NodeId, Packet, FLIT_BYTES};

use super::torrent::dse::AffinePattern;
use super::torrent::timing::SEG_BYTES;
use super::{Engine, EngineCtx, SubmitError, TaskPhase, TaskResult, TaskSpec};

/// Router-programming cost model: `BASE + PER_DEST·N + QUAD·N²` cycles.
/// The quadratic term reflects per-router destination-set table updates
/// along the (growing) tree — the super-linear setup the paper contrasts
/// with Chainwrite's linear 82 CC/destination.
pub const ESP_CFG_BASE: u64 = 40;
pub const ESP_CFG_PER_DEST: u64 = 10;
pub const ESP_CFG_QUAD: u64 = 8;

/// Multicast configuration cycles for `n` destinations.
pub fn esp_cfg_cycles(n: usize) -> u64 {
    ESP_CFG_BASE + ESP_CFG_PER_DEST * n as u64 + ESP_CFG_QUAD * (n * n) as u64
}

/// A network-layer multicast job: the same contiguous block is dropped at
/// window-local offset `drop_offset` of every destination's scratchpad
/// (ESP multicasts to accelerator queues; patterned local writes are a
/// distributed-DMA capability).
#[derive(Debug, Clone)]
pub struct McastTask {
    pub task: u32,
    pub read: AffinePattern,
    pub dests: Vec<NodeId>,
    /// Offset within each destination's local window.
    pub drop_offset: u64,
    pub with_data: bool,
}

#[derive(Debug)]
struct Active {
    task: McastTask,
    submitted_at: u64,
    cfg_done_at: u64,
    stream: Option<Arc<Vec<u8>>>,
    segs: Vec<(usize, usize)>,
    next_seg: usize,
    budget: f64,
    rate: f64,
    /// Destinations that acked the last segment.
    acked: BTreeSet<NodeId>,
    sent_all: bool,
}

/// Source-side engine.
#[derive(Debug)]
pub struct McastEngine {
    pub node: NodeId,
    queue: VecDeque<(McastTask, u64)>,
    active: Option<Active>,
    pub results: Vec<TaskResult>,
}

impl McastEngine {
    pub fn new(node: NodeId) -> Self {
        McastEngine { node, queue: VecDeque::new(), active: None, results: Vec::new() }
    }

    pub fn submit(&mut self, task: McastTask, now: u64) {
        assert!(!task.dests.is_empty());
        self.queue.push_back((task, now));
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_none() && self.queue.is_empty()
    }

    /// Activity hint (the `sim::Clocked::next_event` contract): the
    /// router-programming wait is a timed event (`cfg_done_at`) — the
    /// tick returns early until then, so the whole ESP configuration
    /// stretch can be skipped. Streaming is busy every cycle; waiting for
    /// acks is message-driven.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        match &self.active {
            None => (!self.queue.is_empty()).then_some(now),
            Some(a) => {
                if a.sent_all {
                    None
                } else if now < a.cfg_done_at {
                    Some(a.cfg_done_at)
                } else {
                    Some(now)
                }
            }
        }
    }

    /// Consume ack messages addressed to the source.
    pub fn handle(&mut self, pkt: &Packet, now: u64) -> bool {
        let Message::McastAck { task, .. } = pkt.msg else { return false };
        let Some(a) = self.active.as_mut() else { return true };
        if a.task.task != task {
            return true;
        }
        a.acked.insert(pkt.src);
        if a.sent_all && a.acked.len() == a.task.dests.len() {
            self.results.push(TaskResult {
                task,
                submitted_at: a.submitted_at,
                finished_at: now,
                bytes: a.task.read.total_bytes(),
                n_dests: a.task.dests.len(),
            });
            self.active = None;
        }
        true
    }

    pub fn tick(&mut self, net: &mut dyn NetPort, mem: &mut Scratchpad) {
        let now = net.cycle();
        if self.active.is_none() {
            if let Some((task, submitted_at)) = self.queue.pop_front() {
                let total = task.read.total_bytes();
                let stream = task.with_data.then(|| Arc::new(task.read.gather(mem)));
                let mut segs = Vec::new();
                let mut off = 0;
                while off < total {
                    let len = SEG_BYTES.min(total - off);
                    segs.push((off, len));
                    off += len;
                }
                let rate = task.read.rate_per_cycle();
                self.active = Some(Active {
                    submitted_at: submitted_at.max(now),
                    cfg_done_at: now + esp_cfg_cycles(task.dests.len()),
                    stream,
                    segs,
                    next_seg: 0,
                    budget: 0.0,
                    rate,
                    acked: BTreeSet::new(),
                    sent_all: false,
                    task,
                });
            }
        }
        let Some(a) = self.active.as_mut() else { return };
        if now < a.cfg_done_at || a.sent_all {
            return;
        }
        a.budget += a.rate;
        while a.next_seg < a.segs.len() {
            let (off, len) = a.segs[a.next_seg];
            if a.budget < len as f64 {
                break;
            }
            a.budget -= len as f64;
            let payload = a.stream.as_ref().map(|s| Arc::new(s[off..off + len].to_vec()));
            let last = a.next_seg == a.segs.len() - 1;
            let pkt = Packet::new(
                0,
                self.node,
                a.task.dests[0],
                Message::McastData {
                    task: a.task.task,
                    seq: a.next_seg as u32,
                    last,
                    addr: a.task.drop_offset + off as u64,
                },
            )
            .with_shared_payload(payload, len)
            .with_mcast(a.task.dests.clone());
            net.send(self.node, pkt);
            a.next_seg += 1;
        }
        if a.next_seg == a.segs.len() {
            a.sent_all = true;
        }
        let _ = FLIT_BYTES;
    }
}

/// Uniform dispatch surface; delegates to the inherent methods above.
/// The write side of a [`TaskSpec`] collapses to the destination node
/// set plus the shared `drop_offset` — router-replicated streams land at
/// one window-local offset everywhere (per-destination write *patterns*
/// are a distributed-DMA capability the ESP baseline lacks).
impl Engine for McastEngine {
    fn label(&self) -> &'static str {
        "mcast"
    }

    fn submit(&mut self, spec: TaskSpec, now: u64) -> Result<(), SubmitError> {
        spec.validate()?;
        let TaskSpec { task, read, dests, with_data, drop_offset } = spec;
        let dests = dests.into_iter().map(|(n, _)| n).collect();
        McastEngine::submit(self, McastTask { task, read, dests, drop_offset, with_data }, now);
        Ok(())
    }

    fn handle(&mut self, pkt: &Packet, _ctx: &mut EngineCtx<'_>, now: u64) -> bool {
        McastEngine::handle(self, pkt, now)
    }

    fn tick(&mut self, ctx: &mut EngineCtx<'_>) {
        McastEngine::tick(self, ctx.net, ctx.mem)
    }

    fn next_event(&self, now: u64) -> Option<u64> {
        McastEngine::next_event(self, now)
    }

    fn is_idle(&self) -> bool {
        McastEngine::is_idle(self)
    }

    fn drain_results(&mut self) -> Vec<TaskResult> {
        std::mem::take(&mut self.results)
    }

    fn peek_result(&self, task: u32) -> Option<&TaskResult> {
        self.results.iter().find(|r| r.task == task)
    }

    fn phase_of(&self, task: u32, now: u64) -> Option<TaskPhase> {
        if self.queue.iter().any(|(t, _)| t.task == task) {
            return Some(TaskPhase::Configuring);
        }
        let a = self.active.as_ref().filter(|a| a.task.task == task)?;
        Some(if now < a.cfg_done_at {
            // Router destination-set programming in progress.
            TaskPhase::Configuring
        } else {
            TaskPhase::Streaming
        })
    }
}

/// Destination-side sink: writes multicast payloads into the local
/// scratchpad and acks the final segment. Lives in every SoC node.
#[derive(Debug, Default)]
pub struct McastSink {
    pub bytes_received: u64,
}

impl McastSink {
    pub fn handle(
        &mut self,
        node: NodeId,
        pkt: &Packet,
        mem: &mut Scratchpad,
        net: &mut dyn NetPort,
    ) -> bool {
        let Message::McastData { task, seq, last, addr } = pkt.msg else { return false };
        // `addr` is a window-local offset: resolve against this node's base.
        let local = mem.base + addr;
        if let Some(data) = &pkt.payload {
            if mem.contains(local, data.len()) {
                mem.write(local, data);
            }
        }
        self.bytes_received += pkt.payload_bytes as u64;
        if last {
            net.send(
                node,
                Packet::new(0, node, pkt.src, Message::McastAck { task, seq }),
            );
        }
        true
    }
}

//! ASCII table printer for bench output — every figure/table bench prints
//! the same rows/series the paper reports, in a stable plain-text format
//! that `bench_output.txt` captures.

/// Column-aligned ASCII table.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Table { title: title.into(), ..Default::default() }
    }

    pub fn header<S: Into<String>>(mut self, cols: impl IntoIterator<Item = S>) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>>(&mut self, cols: impl IntoIterator<Item = S>) {
        let row: Vec<String> = cols.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cols: &[String]| {
            cols.iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a f64 with `d` decimals.
pub fn fnum(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(["a", "bbbb"]);
        t.row(["1", "2"]);
        t.row(["333", "4"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("  a  bbbb"));
        assert!(r.contains("333     4"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        let mut t = Table::new("bad").header(["a"]);
        t.row(["1", "2"]);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(3.14159, 2), "3.14");
    }
}

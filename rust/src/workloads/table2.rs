//! Table II: the six DeepSeek-V3 self-attention data-movement workloads
//! evaluated on the FPGA SoC (paper §IV-E, Fig 9/10).
//!
//! Matrices are int8 (the GeMM accelerator is an 8-bit MAC array) and
//! stored in *blocked* "MNMxNy" layouts: tm×tn tiles, tiles row-major,
//! elements row-major inside a tile. A transfer that changes layout makes
//! the DSE read the source in logical element order — tn-byte runs — so
//! layout transforms cost link-rate, exactly the effect Fig 9 shows.

use crate::dma::torrent::dse::AffinePattern;

/// A blocked matrix layout: tm×tn tiles (MNM{tm}N{tn}).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout {
    pub tm: usize,
    pub tn: usize,
}

impl Layout {
    pub const fn new(tm: usize, tn: usize) -> Self {
        Layout { tm, tn }
    }

    pub fn name(&self) -> String {
        format!("MNM{}N{}", self.tm, self.tn)
    }
}

/// Prefill or decode stage (Table II's P*/D* prefix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Prefill,
    Decode,
}

/// One Table II row.
#[derive(Debug, Clone, Copy)]
pub struct AttnWorkload {
    pub id: &'static str,
    pub stage: Stage,
    /// Matrix shape (rows × cols), int8 elements.
    pub rows: usize,
    pub cols: usize,
    pub in_layout: Layout,
    pub out_layout: Layout,
    /// Whether the workload is P2MP (multicast column of Table II).
    pub multicast: bool,
}

/// The six workloads of Table II.
pub const TABLE2: [AttnWorkload; 6] = [
    AttnWorkload {
        id: "P1:QKT_Single_Head",
        stage: Stage::Prefill,
        rows: 2048,
        cols: 192,
        in_layout: Layout::new(16, 8),
        out_layout: Layout::new(8, 8),
        multicast: true,
    },
    AttnWorkload {
        id: "P2:SV_Single_Head",
        stage: Stage::Prefill,
        rows: 2048,
        cols: 128,
        in_layout: Layout::new(16, 8),
        out_layout: Layout::new(8, 8),
        multicast: true,
    },
    AttnWorkload {
        id: "P3:KV_Matrix_MLA_Recovery",
        stage: Stage::Prefill,
        rows: 2048,
        cols: 512,
        in_layout: Layout::new(16, 8),
        out_layout: Layout::new(16, 8),
        multicast: true,
    },
    AttnWorkload {
        id: "D1:QKT_Single_Head",
        stage: Stage::Decode,
        rows: 4096,
        cols: 192,
        in_layout: Layout::new(16, 8),
        out_layout: Layout::new(64, 16),
        multicast: false,
    },
    AttnWorkload {
        id: "D2:SV_Single_Head",
        stage: Stage::Decode,
        rows: 4096,
        cols: 128,
        in_layout: Layout::new(16, 8),
        out_layout: Layout::new(64, 16),
        multicast: false,
    },
    AttnWorkload {
        id: "D3:KV_Matrix_MLA_Recovery",
        stage: Stage::Decode,
        rows: 4096,
        cols: 512,
        in_layout: Layout::new(16, 8),
        out_layout: Layout::new(16, 8),
        multicast: true,
    },
];

impl AttnWorkload {
    /// Payload bytes (int8 elements).
    pub fn bytes(&self) -> usize {
        self.rows * self.cols
    }

    /// True when source and destination layouts differ (the DSE must
    /// re-tile on the fly).
    pub fn needs_relayout(&self) -> bool {
        self.in_layout != self.out_layout
    }

    /// DSE pattern reading a blocked matrix at `base` in *logical
    /// element order*. When no relayout is needed the DMA moves the
    /// matrix in memory order instead — a single contiguous run.
    pub fn read_pattern(&self, base: u64) -> AffinePattern {
        if !self.needs_relayout() {
            return AffinePattern::contiguous(base, self.bytes());
        }
        blocked_logical_order(base, self.rows, self.cols, self.in_layout)
    }

    /// DSE pattern writing the destination layout at `base` from a
    /// logical-order stream (contiguous when no relayout).
    pub fn write_pattern(&self, base: u64) -> AffinePattern {
        if !self.needs_relayout() {
            return AffinePattern::contiguous(base, self.bytes());
        }
        blocked_logical_order(base, self.rows, self.cols, self.out_layout)
    }
}

/// Affine pattern visiting a blocked (tm×tn) R×C int8 matrix in logical
/// row-major element order.
///
/// Memory offset of element (r, c):
/// `tile(r/tm, c/tn) * tm*tn + (r%tm)*tn + (c%tn)` with tiles row-major.
/// Logical order therefore iterates, innermost first: tile column
/// (stride tm·tn), row-within-tile (stride tn), tile row
/// (stride (C/tn)·tm·tn); each innermost step is one tn-byte run.
pub fn blocked_logical_order(base: u64, rows: usize, cols: usize, l: Layout) -> AffinePattern {
    assert!(rows % l.tm == 0 && cols % l.tn == 0, "{rows}x{cols} vs {l:?}");
    let tile = (l.tm * l.tn) as i64;
    let tiles_per_row = (cols / l.tn) as i64;
    AffinePattern {
        base,
        elem_bytes: l.tn,
        dims: vec![
            (cols / l.tn, tile),                    // tile column
            (l.tm, l.tn as i64),                    // row within tile
            (rows / l.tm, tiles_per_row * tile),    // tile row
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Scratchpad;

    #[test]
    fn table2_shapes_match_paper() {
        assert_eq!(TABLE2[0].bytes(), 2048 * 192);
        assert_eq!(TABLE2[5].bytes(), 4096 * 512);
        assert_eq!(TABLE2[2].in_layout, TABLE2[2].out_layout);
        assert!(TABLE2[0].needs_relayout());
        assert!(!TABLE2[2].needs_relayout());
        assert_eq!(TABLE2[3].out_layout.name(), "MNM64N16");
    }

    #[test]
    fn no_relayout_is_contiguous_full_rate() {
        let p = TABLE2[2].read_pattern(0);
        assert_eq!(p.runs().len(), 1);
        assert!((p.rate_per_cycle() - 64.0).abs() < 1e-9);
    }

    #[test]
    fn relayout_read_runs_are_tile_rows() {
        let w = TABLE2[0]; // MNM16N8 -> MNM8N8
        let p = w.read_pattern(0);
        assert_eq!(p.total_bytes(), w.bytes());
        // tn-byte runs at 1 B/element; tile-row boundaries occasionally
        // coalesce two runs, nudging the rate just above 8 B/CC.
        let rate = p.rate_per_cycle();
        assert!((7.9..8.3).contains(&rate), "rate {rate} not ~8 B/CC");
    }

    #[test]
    fn logical_order_pattern_is_a_permutation_of_the_matrix() {
        // Gather a small blocked matrix in logical order and check against
        // a direct software re-layout.
        let (rows, cols) = (32, 16);
        let l = Layout::new(16, 8);
        let mut mem = Scratchpad::new(0, 4096);
        // Fill memory so byte at offset o == o % 251 (identifiable).
        let backing: Vec<u8> = (0..rows * cols).map(|o| (o % 251) as u8).collect();
        mem.write(0, &backing);
        let stream = blocked_logical_order(0, rows, cols, l).gather(&mut mem);
        assert_eq!(stream.len(), rows * cols);
        // Element (r, c) must be the byte at its blocked offset.
        for r in 0..rows {
            for c in 0..cols {
                let tile = (r / l.tm) * (cols / l.tn) + (c / l.tn);
                let off = tile * l.tm * l.tn + (r % l.tm) * l.tn + (c % l.tn);
                assert_eq!(
                    stream[r * cols + c],
                    (off % 251) as u8,
                    "element ({r},{c})"
                );
            }
        }
    }

    #[test]
    fn relayout_roundtrip_via_two_patterns() {
        // read(in-layout) then write(out-layout) must preserve the logical
        // matrix: verify on a 64x32 MNM16N8 -> MNM8N8 transform.
        let (rows, cols) = (64, 32);
        let win = Layout::new(16, 8);
        let wout = Layout::new(8, 8);
        let mut src = Scratchpad::new(0, 1 << 16);
        src.fill_pattern(0x3C);
        let mut dst = Scratchpad::new(0, 1 << 16);
        let stream = blocked_logical_order(0, rows, cols, win).gather(&mut src);
        blocked_logical_order(0x8000, rows, cols, wout).scatter(&stream, &mut dst);
        // Check logical element (r, c) equality.
        for r in (0..rows).step_by(7) {
            for c in (0..cols).step_by(5) {
                let off_in = ((r / 16) * (cols / 8) + c / 8) * 128 + (r % 16) * 8 + c % 8;
                let off_out = ((r / 8) * (cols / 8) + c / 8) * 64 + (r % 8) * 8 + c % 8;
                assert_eq!(
                    src.peek(off_in as u64, 1)[0],
                    dst.peek(0x8000 + off_out as u64, 1)[0],
                    "element ({r},{c})"
                );
            }
        }
    }
}

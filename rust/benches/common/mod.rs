//! Minimal bench harness (criterion is not vendored in this image; see
//! DESIGN.md §3): warmup + timed iterations + a stats summary, printed in
//! a stable format that `bench_output.txt` captures.
#![allow(dead_code)] // each bench binary uses a subset of the harness

use std::time::Instant;

use torrent::util::stats::Summary;

/// Time `f` for `iters` iterations after `warmup` runs; print a summary.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let s = Summary::of(&samples);
    println!(
        "bench {name}: mean {:.3} ms  p50 {:.3}  p99 {:.3}  min {:.3}  max {:.3}  (n={})",
        s.mean, s.p50, s.p99, s.min, s.max, s.n
    );
    s
}

/// Banner separating experiment output inside bench logs.
pub fn banner(title: &str) {
    println!("\n==================== {title} ====================");
}

"""Pallas relayout kernel vs jnp oracle — Table II's MNMxNy transforms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, relayout


def _blocked(m, n, tm, tn, seed=0, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n), dtype)
    return x, ref.to_blocked(x, tm, tn)


def test_blocked_roundtrip_ref():
    x, xb = _blocked(64, 32, 16, 8)
    np.testing.assert_array_equal(np.asarray(ref.from_blocked(xb)), np.asarray(x))


def test_blocked_layout_is_papers_order():
    # Element (i, j) lives at tile (i//tm, j//tn), offset (i%tm, j%tn).
    x = jnp.arange(32 * 16, dtype=jnp.float32).reshape(32, 16)
    xb = ref.to_blocked(x, 16, 8)
    assert xb[1, 1, 3, 5] == x[16 + 3, 8 + 5]


@pytest.mark.parametrize(
    "m,n,tin,tout",
    [
        (64, 32, (16, 8), (8, 8)),  # MNM16N8 -> MNM8N8  (P1/P2)
        (64, 32, (16, 8), (16, 8)),  # identity re-tile    (P3/D3)
        (128, 64, (16, 8), (64, 16)),  # MNM16N8 -> MNM64N16 (D1/D2)
        (128, 64, (64, 16), (16, 8)),  # inverse direction
        (256, 64, (16, 8), (8, 8)),
    ],
)
def test_relayout_matches_ref(m, n, tin, tout):
    x, xb = _blocked(m, n, *tin)
    got = relayout(xb, *tout)
    want = ref.relayout(xb, *tout)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the logical matrix is unchanged
    np.testing.assert_array_equal(np.asarray(ref.from_blocked(got)), np.asarray(x))


def test_relayout_roundtrip_through_other_geometry():
    x, xb = _blocked(128, 64, 16, 8, seed=3)
    back = relayout(relayout(xb, 64, 16), 16, 8)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(xb))


@settings(max_examples=20, deadline=None)
@given(
    mt=st.integers(1, 4),
    nt=st.integers(1, 4),
    tin=st.sampled_from([(16, 8), (8, 8), (64, 16), (16, 16)]),
    tout=st.sampled_from([(16, 8), (8, 8), (64, 16), (8, 16)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_relayout_hypothesis(mt, nt, tin, tout, seed):
    import math

    m = mt * math.lcm(tin[0], tout[0])
    n = nt * math.lcm(tin[1], tout[1])
    x, xb = _blocked(m, n, *tin, seed=seed)
    got = relayout(xb, *tout)
    np.testing.assert_array_equal(
        np.asarray(ref.from_blocked(got)), np.asarray(x)
    )

//! Naive and greedy (paper Alg. 1) chain ordering, over any
//! [`Topology`] (the link-overlap test walks the fabric's own routes).

use std::collections::BTreeSet;

use crate::noc::{NodeId, Topology};

/// Chain-sequence strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Visit in cluster-ID order.
    Naive,
    /// Paper Alg. 1: link-disjoint greedy.
    Greedy,
    /// Open-path TSP (OR-Tools in the paper; Held–Karp/2-opt here).
    Tsp,
    /// Contention-aware: greedy's walk scored by `hops + w·max link
    /// load` against a [`crate::noc::LoadView`] snapshot, plus the
    /// k-way partition pass (`sched::load`). Falls back to pure
    /// geometry (an idle view) when no load snapshot is supplied.
    LoadAware,
}

/// Naive ordering: ascending cluster ID (the paper's "simple Chainwrite").
pub fn naive_order(dests: &[NodeId]) -> Vec<NodeId> {
    let mut order = dests.to_vec();
    order.sort();
    order
}

/// Paper Algorithm 1 — Chain Write Greedy Optimization.
///
/// Iteratively extend the chain with the destination whose routed path
/// from the chain tail (a) shares no link with any previously used path
/// and (b) is shortest; fall back to the plain nearest destination when
/// no link-disjoint candidate exists. Link-disjointness keeps the
/// chain's hop-to-hop transfers from serializing on shared fabric links
/// while the stream is pipelined through all destinations.
///
/// Every Chainwrite hop drives *three* routes over the fabric: the
/// forward data leg (prev → hop) plus the grant/finish back-legs
/// (hop → prev) — the same three-leg protocol the repair planner
/// validates per candidate detour. Both directions of each leg are
/// therefore reserved in `used`; [`greedy_order_forward_only`] keeps
/// the historical data-leg-only behavior for the differential test.
///
/// Duplicate destinations keep their multiplicity (matching
/// `naive_order` and `schedule_pairs` FIFO semantics): a duplicate of
/// the chain tail is zero hops away and chains consecutively.
pub fn greedy_order(topo: &dyn Topology, src: NodeId, dests: &[NodeId]) -> Vec<NodeId> {
    greedy_order_impl(topo, src, dests, true)
}

/// Pre-fix greedy that reserves only the forward data leg of each hop.
/// Test-only: kept so the differential suite can demonstrate the
/// back-leg blindness this module used to have. Not part of the API.
#[doc(hidden)]
pub fn greedy_order_forward_only(
    topo: &dyn Topology,
    src: NodeId,
    dests: &[NodeId],
) -> Vec<NodeId> {
    greedy_order_impl(topo, src, dests, false)
}

/// Reserve the routed links of one chain leg — and, when `both_dirs`,
/// of the reverse route the grant/finish control flits take. Under XY
/// routing the reverse route is *not* the mirrored forward path (it
/// re-routes YX from the other end), so it must be walked separately.
fn reserve_leg(
    topo: &dyn Topology,
    used: &mut BTreeSet<(NodeId, NodeId)>,
    from: NodeId,
    to: NodeId,
    both_dirs: bool,
) {
    for l in topo.links(from, to) {
        used.insert(l);
    }
    if both_dirs {
        for l in topo.links(to, from) {
            used.insert(l);
        }
    }
}

fn greedy_order_impl(
    topo: &dyn Topology,
    src: NodeId,
    dests: &[NodeId],
    both_dirs: bool,
) -> Vec<NodeId> {
    if dests.is_empty() {
        return vec![];
    }
    let mut remaining: Vec<NodeId> = dests.to_vec();
    // Start from the destination closest to the initiator (ties: lowest id,
    // matching the paper's min() over the destination list).
    let start = *remaining
        .iter()
        .min_by_key(|&&d| (topo.distance(src, d), d))
        .unwrap();
    // Remove exactly one occurrence — `retain` would silently collapse
    // duplicate destinations that naive_order (and the pair scheduler's
    // FIFO payload slots) preserve.
    let pos = remaining.iter().position(|&d| d == start).unwrap();
    remaining.remove(pos);
    let mut order = vec![start];
    let mut used: BTreeSet<(NodeId, NodeId)> = BTreeSet::new();
    reserve_leg(topo, &mut used, src, start, both_dirs);

    while !remaining.is_empty() {
        let tail = *order.last().unwrap();
        // Alg.1 line 6 init: any real path is at most `diameter` hops, so
        // diameter + 1 accepts every candidate (on a mesh this matches the
        // original cols + rows bound exactly — both exceed every path).
        let max_hops = topo.diameter() + 1;
        let mut best: Option<(NodeId, usize)> = None;
        for &cand in &remaining {
            // Walk the routed path in place (§Perf: no Vec per candidate)
            // and bail out at the first used link.
            let bound = best.map(|(_, h)| h).unwrap_or(max_hops);
            let mut cur = tail;
            let mut hops = 0usize;
            let mut disjoint = true;
            while cur != cand && hops < bound {
                let d = topo.next_hop(cur, cand);
                let next = topo.neighbour(cur, d).expect("routing left the fabric");
                if used.contains(&(cur, next)) {
                    disjoint = false;
                    break;
                }
                cur = next;
                hops += 1;
            }
            if disjoint && cur == cand && hops < bound {
                best = Some((cand, hops));
            }
        }
        let chosen = match best {
            Some((c, _)) => c,
            // Fallback (Alg.1 line 13): shortest path regardless of overlap.
            None => *remaining
                .iter()
                .min_by_key(|&&c| (topo.distance(tail, c), c))
                .unwrap(),
        };
        reserve_leg(topo, &mut used, tail, chosen, both_dirs);
        order.push(chosen);
        let pos = remaining.iter().position(|&d| d == chosen).unwrap();
        remaining.remove(pos);
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{Mesh, Ring};
    use crate::sched::hops::chain_hops;

    #[test]
    fn naive_sorts_by_id() {
        let o = naive_order(&[NodeId(9), NodeId(2), NodeId(5)]);
        assert_eq!(o, vec![NodeId(2), NodeId(5), NodeId(9)]);
    }

    #[test]
    fn greedy_is_permutation() {
        let m = Mesh::new(8, 8);
        let dests: Vec<NodeId> = [3, 7, 21, 63, 40, 11].map(NodeId).to_vec();
        let o = greedy_order(&m, NodeId(0), &dests);
        let mut a = o.clone();
        a.sort();
        let mut b = dests.clone();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn greedy_starts_nearest_to_source() {
        let m = Mesh::new(8, 8);
        // 9=(1,1) is 2 hops from 0; others much farther.
        let o = greedy_order(&m, NodeId(0), &[NodeId(63), NodeId(9), NodeId(56)]);
        assert_eq!(o[0], NodeId(9));
    }

    #[test]
    fn greedy_single_destination() {
        let m = Mesh::new(4, 4);
        assert_eq!(greedy_order(&m, NodeId(0), &[NodeId(7)]), vec![NodeId(7)]);
    }

    #[test]
    fn greedy_empty() {
        let m = Mesh::new(4, 4);
        assert!(greedy_order(&m, NodeId(0), &[]).is_empty());
    }

    #[test]
    fn greedy_beats_or_ties_naive_on_random_sets() {
        let m = Mesh::new(8, 8);
        let mut rng = crate::util::rng(42, crate::util::stream::WORKLOAD);
        let mut greedy_wins = 0;
        for _ in 0..50 {
            let mut set = rng.sample_distinct(63, 8);
            set.iter_mut().for_each(|v| *v += 1); // exclude src node 0
            let dests: Vec<NodeId> = set.into_iter().map(NodeId).collect();
            let h_naive = chain_hops(&m, NodeId(0), &naive_order(&dests));
            let h_greedy = chain_hops(&m, NodeId(0), &greedy_order(&m, NodeId(0), &dests));
            if h_greedy < h_naive {
                greedy_wins += 1;
            }
        }
        // Greedy should beat ID-order on the clear majority of random sets.
        assert!(greedy_wins >= 35, "greedy won only {greedy_wins}/50");
    }

    #[test]
    fn greedy_row_chain_is_optimal() {
        // All dests on one row: visiting in x order is optimal and greedy
        // must find it (disjoint eastward links).
        let m = Mesh::new(8, 1);
        let dests: Vec<NodeId> = [4, 1, 6, 2].map(NodeId).to_vec();
        let o = greedy_order(&m, NodeId(0), &dests);
        assert_eq!(o, [1, 2, 4, 6].map(NodeId).to_vec());
        assert_eq!(chain_hops(&m, NodeId(0), &o), 6);
    }

    #[test]
    fn greedy_reserves_grant_finish_back_legs() {
        // Leg 0→5 on a 4×4 mesh routes XY through node 1; its
        // grant/finish back-leg 5→0 routes XY through node 4, reserving
        // (5,4),(4,0). Candidate 8's data leg from tail 5 is
        // (5,4),(4,8) — "clean" under the old forward-only reservation
        // but colliding with the back-leg traffic in reality — so the
        // fixed greedy chains the genuinely disjoint 7 first.
        let m = Mesh::new(4, 4);
        let dests: Vec<NodeId> = [5, 8, 7].map(NodeId).to_vec();
        let legacy = greedy_order_forward_only(&m, NodeId(0), &dests);
        let fixed = greedy_order(&m, NodeId(0), &dests);
        assert_eq!(legacy, [5, 8, 7].map(NodeId).to_vec());
        assert_eq!(fixed, [5, 7, 8].map(NodeId).to_vec());
    }

    #[test]
    fn greedy_keeps_duplicate_destinations() {
        // `retain` used to collapse duplicates, silently disagreeing
        // with naive_order (and panicking schedule_pairs' permutation
        // check). One removal per placement keeps the multiset.
        let m = Mesh::new(4, 4);
        let dests: Vec<NodeId> = [5, 2, 5, 2].map(NodeId).to_vec();
        let o = greedy_order(&m, NodeId(0), &dests);
        assert_eq!(o.len(), dests.len());
        let mut a = o.clone();
        a.sort();
        let mut b = dests.clone();
        b.sort();
        assert_eq!(a, b, "greedy must preserve destination multiplicity");
        assert_eq!(naive_order(&dests).len(), dests.len());
    }

    #[test]
    fn greedy_on_a_ring_chains_around_one_arc() {
        // {1, 2, 3} East of the source on an 8-ring: greedy walks the
        // arc with disjoint links, 1 hop per destination.
        let r = Ring::new(8);
        let dests: Vec<NodeId> = [3, 1, 2].map(NodeId).to_vec();
        let o = greedy_order(&r, NodeId(0), &dests);
        assert_eq!(o, [1, 2, 3].map(NodeId).to_vec());
        assert_eq!(chain_hops(&r, NodeId(0), &o), 3);
    }
}

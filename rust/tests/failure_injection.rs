//! Failure-injection and adversarial-condition tests: busy followers,
//! saturated fabrics, degenerate patterns, protocol edge cases.

use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest};
use torrent::dma::torrent::dse::AffinePattern;
use torrent::dma::torrent::{ChainDest, ChainTask};
use torrent::noc::{Message, NodeId, Packet};
use torrent::sched::Strategy;
use torrent::soc::{Soc, SocConfig};

fn coord() -> Coordinator {
    Coordinator::new(SocConfig::custom(3, 3, 256 * 1024))
}

/// A follower already serving one chain delays — but does not deadlock —
/// a second chain through the same node (grant withheld until ready).
#[test]
fn overlapping_chains_through_shared_follower() {
    let mut c = coord();
    let bytes = 32 * 1024;
    // Chain A: 0 -> {1, 4}; Chain B: 8 -> {4, 2}; node 4 is shared.
    let naive = EngineKind::Torrent(Strategy::Naive);
    let ta = c.submit_simple(NodeId(0), &[NodeId(1), NodeId(4)], bytes, naive, false).unwrap();
    let read_b = AffinePattern::contiguous(c.soc.map.base_of(NodeId(8)), bytes);
    let dests_b = vec![
        (NodeId(4), AffinePattern::contiguous(c.soc.map.base_of(NodeId(4)) + 0x20000, bytes)),
        (NodeId(2), AffinePattern::contiguous(c.soc.map.base_of(NodeId(2)) + 0x20000, bytes)),
    ];
    let tb = c
        .submit(
            P2mpRequest::to_patterns(dests_b)
                .src(NodeId(8))
                .read(read_b)
                .engine(EngineKind::Torrent(Strategy::Naive)),
        )
        .unwrap();
    c.run_to_completion(50_000_000);
    assert!(c.latency_of(ta).is_some(), "chain A deadlocked");
    assert!(c.latency_of(tb).is_some(), "chain B deadlocked");
}

/// Sixteen concurrent all-to-different-destination chains saturate the
/// fabric without deadlock or data loss.
#[test]
fn fabric_saturation_many_concurrent_chains() {
    let mut c = Coordinator::new(SocConfig::eval_4x5());
    let bytes = 8 * 1024;
    let mut tasks = vec![];
    for src in 0..16usize {
        let d1 = (src + 2) % 20;
        let d2 = (src + 7) % 20;
        if d1 == src || d2 == src || d1 == d2 {
            continue;
        }
        let read = AffinePattern::contiguous(c.soc.map.base_of(NodeId(src)), bytes);
        let base1 = c.soc.map.base_of(NodeId(d1)) + 0x40000;
        let base2 = c.soc.map.base_of(NodeId(d2)) + 0x60000 + src as u64 * 0x2000;
        let dests = vec![
            (NodeId(d1), AffinePattern::contiguous(base1, bytes)),
            (NodeId(d2), AffinePattern::contiguous(base2, bytes)),
        ];
        tasks.push(
            c.submit(
                P2mpRequest::to_patterns(dests)
                    .src(NodeId(src))
                    .read(read)
                    .engine(EngineKind::Torrent(Strategy::Greedy)),
            )
            .unwrap(),
        );
    }
    c.run_to_completion(100_000_000);
    for t in tasks {
        assert!(c.latency_of(t).is_some(), "task {t} starved");
    }
}

/// Zero-payload cfg-only edge: a 1-byte transfer exercises the full
/// four-phase protocol.
#[test]
fn one_byte_chainwrite() {
    let mut c = coord();
    c.soc.nodes[0].mem.write(c.soc.map.base_of(NodeId(0)), &[0xAB]);
    let chain = EngineKind::Torrent(Strategy::Greedy);
    let t = c.submit_simple(NodeId(0), &[NodeId(8)], 1, chain, true).unwrap();
    c.run_to_completion(1_000_000);
    assert!(c.latency_of(t).is_some());
    let half = c.soc.cfg.spm_bytes as u64 / 2;
    assert_eq!(c.soc.nodes[8].mem.peek(c.soc.map.base_of(NodeId(8)) + half, 1), &[0xAB]);
}

/// Chain where consecutive destinations are maximally distant (worst-case
/// naive order): must still complete within the watchdog.
#[test]
fn pathological_zigzag_chain() {
    let mut c = Coordinator::new(SocConfig::eval_4x5());
    // Alternate corners: 1, 19, 4, 16, 3, 15 (naive keeps this order? No:
    // naive sorts by id — so submit as explicit ChainTask to force it).
    let bytes = 4 * 1024;
    let order = [1usize, 19, 4, 16, 3, 15];
    let dests: Vec<ChainDest> = order
        .iter()
        .map(|&n| ChainDest {
            node: NodeId(n),
            pattern: AffinePattern::contiguous(c.soc.map.base_of(NodeId(n)) + 0x80000, bytes),
        })
        .collect();
    let now = c.soc.cycle();
    c.soc.nodes[0].torrent.submit(
        ChainTask {
            task: 777,
            read: AffinePattern::contiguous(c.soc.map.base_of(NodeId(0)), bytes),
            dests,
            with_data: false,
        },
        now,
    );
    c.soc.run_until_idle(50_000_000);
    assert!(c.soc.torrent_result(NodeId(0), 777).is_some());
}

/// Unroutable / malformed traffic is rejected loudly, not silently.
#[test]
#[should_panic(expected = "undeliverable packet")]
fn unknown_message_panics_at_dispatch() {
    let mut soc = Soc::new(SocConfig::custom(2, 2, 32 * 1024));
    soc.net.send(
        NodeId(0),
        Packet::new(0, NodeId(0), NodeId(3), Message::Raw(0xDEAD)),
    );
    soc.run_until_idle(10_000);
}

/// AXI write beyond the destination scratchpad returns ok=false and the
/// initiating engine panics (data would be lost silently otherwise).
#[test]
#[should_panic(expected = "iDMA write burst failed")]
fn idma_write_out_of_range_fails_loudly() {
    let mut soc = Soc::new(SocConfig::custom(2, 2, 32 * 1024));
    let now = soc.cycle();
    // Destination pattern points past node 3's scratchpad.
    soc.nodes[0].idma.submit(
        torrent::dma::idma::IdmaTask {
            task: 1,
            read: AffinePattern::contiguous(soc.map.base_of(NodeId(0)), 64),
            dests: vec![(
                NodeId(3),
                AffinePattern::contiguous(soc.map.base_of(NodeId(3)) + (32 * 1024), 64),
            )],
            with_data: false,
        },
        now,
    );
    soc.run_until_idle(100_000);
}

/// Watchdog fires (panics) when the system genuinely cannot quiesce —
/// here by never delivering a grant (destination outside the mesh is
/// prevented by AddrMap, so emulate with an undeliverable follower cfg).
#[test]
fn watchdog_catches_stall() {
    let mut soc = Soc::new(SocConfig::custom(2, 2, 32 * 1024));
    // A chain whose only destination never grants because we steal its
    // cfg: submit, then drop the cfg packet by draining node 3's inbox
    // before dispatch. Simplest equivalent: assert the watchdog mechanism
    // itself.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        soc.net.send(
            NodeId(0),
            Packet::new(0, NodeId(0), NodeId(3), Message::TorrentGrant { task: 42 }),
        );
        // Grant for an unknown task is consumed silently; the fabric
        // drains fine — so use an absurd deadline of 0 to prove the
        // watchdog path triggers.
        soc.run_until_idle(0);
    }));
    assert!(result.is_err(), "watchdog must fire on impossible deadline");
}

/// Strided destination patterns with sub-flit runs (worst DSE rate) still
/// deliver byte-exact data.
#[test]
fn worst_case_strided_write_pattern() {
    let mut c = coord();
    let rows = 512usize;
    let bytes = rows * 4;
    let base0 = c.soc.map.base_of(NodeId(0));
    let data: Vec<u8> = (0..bytes).map(|i| (i % 241) as u8).collect();
    c.soc.nodes[0].mem.write(base0, &data);
    let dst_base = c.soc.map.base_of(NodeId(4)) + 0x1000;
    let write = AffinePattern::strided(dst_base, rows, 4, 32);
    let t = c
        .submit(
            P2mpRequest::to_patterns(vec![(NodeId(4), write)])
                .src(NodeId(0))
                .read(AffinePattern::contiguous(base0, bytes))
                .engine(EngineKind::Torrent(Strategy::Greedy))
                .with_data(true),
        )
        .unwrap();
    c.run_to_completion(10_000_000);
    assert!(c.latency_of(t).is_some());
    for r in 0..rows {
        assert_eq!(
            c.soc.nodes[4].mem.peek(dst_base + r as u64 * 32, 4),
            &data[r * 4..r * 4 + 4],
            "row {r}"
        );
    }
}

//! Minimal CLI argument parser (clap is not vendored in this image).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and defaults. Used by `main.rs` and the
//! examples.

// Outside the simulation core: option lookup is by exact key, nothing
// iterates `opts`, so hash-iteration order cannot reach simulated state
// (clippy.toml bans HashMap in core code for determinism).
#[allow(clippy::disallowed_types)]
use std::collections::HashMap;

/// Parsed command line: positionals + `--key value` options + `--flags`.
#[allow(clippy::disallowed_types)] // see the import note above
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed getter with default; panics with a clear message on a bad value.
    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{name}: cannot parse {v:?}: {e}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get_parse_or(name, default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get_parse_or(name, default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get_parse_or(name, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|w| w.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("run --size 64 --dests=8 fig5 --verbose");
        assert_eq!(a.positional, vec!["run", "fig5"]);
        assert_eq!(a.get("size"), Some("64"));
        assert_eq!(a.get("dests"), Some("8"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--n 12 --ratio 0.5");
        assert_eq!(a.usize_or("n", 1), 12);
        assert_eq!(a.usize_or("m", 7), 7);
        assert!((a.f64_or("ratio", 0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--fast --check");
        assert!(a.flag("fast") && a.flag("check"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_typed_value_panics() {
        parse("--n banana").usize_or("n", 1);
    }
}

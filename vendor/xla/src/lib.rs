//! Compile-time stub of the `xla` crate (xla-rs) PJRT surface.
//!
//! The image this repo builds in has neither crates.io access nor the XLA
//! toolchain, so the `pjrt` feature of the `torrent` crate links against
//! this stub instead: the integration code in `rust/src/runtime/pjrt.rs`
//! stays compile-checked, and every entry point fails at *runtime* with a
//! clear message. To execute artifacts on real XLA, replace the
//! `vendor/xla` path dependency in the workspace `Cargo.toml` with the
//! real crate (github.com/LaurentMazare/xla-rs) — the API below mirrors
//! the subset the runtime uses, so no source changes are needed.

use std::fmt;

/// Stub error: always "backend unavailable".
pub struct Error(String);

impl Error {
    fn unavailable(op: &str) -> Self {
        Error(format!(
            "{op}: XLA PJRT backend not vendored in this offline image; \
             replace the vendor/xla path dependency with the real xla crate \
             (xla-rs) to execute artifacts (DESIGN.md §5)"
        ))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, loaded executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal (stub).
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::decompose_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(err.contains("not vendored"), "{err}");
    }
}

//! The Torrent distributed DMA engine (paper §III).
//!
//! One `Torrent` instance sits at every mesh node (Fig 1(c)). A P2MP task
//! submitted to the *initiator* Torrent runs the four-phase Chainwrite of
//! Fig 4:
//!
//! 1. **Configuration dispatch** — the initiator encodes one
//!    [`cfg::TorrentCfg`] per follower (prev/next chain neighbours, AXI
//!    burst size, DSE write pattern) and sends them out in parallel;
//! 2. **Grant back-propagation** — the tail follower generates Grant on
//!    cfg decode; every intermediate follower forwards it to its
//!    predecessor once it is itself ready;
//! 3. **Data transfer** — the initiator's DSE streams the source pattern
//!    into the chain as burst-sized segments; every follower's data
//!    switch duplicates the incoming stream — one copy scattered into
//!    local memory by its DSE, one copy *cut-through forwarded* to the
//!    next hop (flits leave [`timing::FWD_LATENCY_CYCLES`] after they
//!    arrive, no store-and-wait);
//! 4. **Finish back-propagation** — the tail signals Finish when its
//!    local write completes; intermediates forward it once their own
//!    writes are done; the initiator timestamps completion.
//!
//! P2P copy is the same flow with a single follower; local loopback
//! (src/dst in the same scratchpad) degenerates to a DSE-only reshuffle.

pub mod cfg;
pub mod dse;
pub mod timing;

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use crate::mem::Scratchpad;
use crate::noc::{Gate, GateCell, Message, NetPort, NodeId, Packet, PacketId, FLIT_BYTES};

use self::cfg::{CfgType, TorrentCfg};
use self::dse::AffinePattern;
use self::timing::*;
use super::{Engine, EngineCtx, SubmitError, TaskPhase, TaskResult, TaskSpec};

/// Waypoint overrides for the three physical routes that must be clean
/// for a chain hop to function (see `coordinator::plan_repair_chains`):
/// the cfg dispatch `initiator -> hop`, the data stream `prev -> hop`,
/// and the grant/finish back-propagation `hop -> prev`. `None`
/// everywhere (the default) keeps the fabric's own routes — healthy
/// chains never carry waypoints, so their timing is untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ChainVias {
    /// Waypoint for the cfg packet `initiator -> node`.
    pub cfg: Option<NodeId>,
    /// Waypoint for the data stream `prev -> node`.
    pub data: Option<NodeId>,
    /// Waypoint for grant/finish `node -> prev`.
    pub back: Option<NodeId>,
}

/// One Chainwrite destination: node + local DSE write pattern.
#[derive(Debug, Clone)]
pub struct ChainDest {
    pub node: NodeId,
    pub pattern: AffinePattern,
    /// Fault-repair route overrides for this hop's three legs.
    pub vias: ChainVias,
}

/// A P2MP (or P2P when `dests.len() == 1`) task for an initiator Torrent.
/// `dests` is already in chain order — the coordinator applies a
/// `sched::Strategy` before submission.
#[derive(Debug, Clone)]
pub struct ChainTask {
    pub task: u32,
    /// Source DSE read pattern (in the initiator's scratchpad).
    pub read: AffinePattern,
    pub dests: Vec<ChainDest>,
    /// Move real bytes (integrity-checked runs) or phantom timing-only.
    pub with_data: bool,
}

/// Initiator progress.
#[derive(Debug)]
enum InitPhase {
    /// Sending cfg i at/after the embedded cycle.
    Dispatch { next_cfg: usize, ready_at: u64 },
    WaitGrant,
    /// Streaming data segments.
    SendData { next_seg: usize, sent_all: bool },
    WaitFinish,
}

#[derive(Debug)]
struct InitiatorState {
    task: ChainTask,
    submitted_at: u64,
    phase: InitPhase,
    /// Gathered source stream (None for phantom runs).
    stream: Option<Arc<Vec<u8>>>,
    /// Segment boundaries (byte offsets).
    segs: Vec<(usize, usize)>,
    /// DSE rate limiter: fractional flits of injection budget.
    dse_budget: f64,
    dse_rate_flits: f64,
    /// Gate of the segment currently being streamed.
    cur_gate: Option<Gate>,
    cur_gate_total: u32,
}

/// Follower-side per-task state.
#[derive(Debug)]
struct FollowerState {
    cfg: TorrentCfg,
    initiator: NodeId,
    cfg_ready_at: u64,
    grant_from_next: bool,
    grant_sent: bool,
    grant_ready_at: Option<u64>,
    /// Bytes of the expected stream that have fully arrived (delivered).
    bytes_arrived: usize,
    expected_bytes: usize,
    /// Local DSE write completion frontier.
    write_done_at: u64,
    /// Arrived stream segments awaiting enough bytes to scatter.
    stream_buf: Vec<u8>,
    scattered: bool,
    finish_from_next: bool,
    finish_sent: bool,
    finish_ready_at: Option<u64>,
    /// Cut-through forwarding gates keyed by incoming packet id. Ordered
    /// (composed packet ids sort in allocation order) so gate updates
    /// iterate deterministically.
    forwards: BTreeMap<PacketId, Gate>,
    /// Incoming packet ids already forwarded (guards the delivered path).
    forwarded: BTreeSet<PacketId>,
}

/// Activity counters (power model inputs, Fig 11(d–f)).
#[derive(Debug, Default, Clone)]
pub struct TorrentStats {
    pub cfgs_sent: u64,
    pub cfgs_received: u64,
    pub bytes_streamed_out: u64,
    pub bytes_forwarded: u64,
    pub bytes_written_local: u64,
    pub grants_relayed: u64,
    pub finishes_relayed: u64,
    pub tasks_completed: u64,
}

/// A Torrent DMA endpoint.
#[derive(Debug)]
pub struct Torrent {
    pub node: NodeId,
    queue: VecDeque<(ChainTask, u64)>,
    active: Option<InitiatorState>,
    /// Ordered by task id: follower processing (and therefore the order
    /// grant/finish packets inject) must be deterministic run-to-run —
    /// a HashMap here made concurrent-chain cycle counts irreproducible.
    followers: BTreeMap<u32, FollowerState>,
    /// Outstanding read-tunnel requests we initiated: task -> submit time.
    /// The remote Torrent streams the data back as a 1-node chain; we
    /// record a local TaskResult when our follower role completes.
    pending_reads: BTreeMap<u32, u64>,
    /// Tasks the coordinator cancelled here (fault repair). Late traffic
    /// for these ids — cfgs still in flight, stale ChainData segments —
    /// is consumed silently instead of re-creating state or panicking.
    cancelled: BTreeSet<u32>,
    pub results: Vec<TaskResult>,
    pub stats: TorrentStats,
}

impl Torrent {
    pub fn new(node: NodeId) -> Self {
        Torrent {
            node,
            queue: VecDeque::new(),
            active: None,
            followers: BTreeMap::new(),
            pending_reads: BTreeMap::new(),
            cancelled: BTreeSet::new(),
            results: Vec::new(),
            stats: TorrentStats::default(),
        }
    }

    /// Fault repair: forget every local trace of `task` and remember the
    /// id so late traffic is swallowed. Any half-open stream gates are
    /// released fully first — their flits are already queued in the NI
    /// and would otherwise wedge the injection queue forever.
    pub fn cancel(&mut self, task: u32) -> bool {
        let mut hit = false;
        let before = self.queue.len();
        self.queue.retain(|(t, _)| t.task != task);
        hit |= self.queue.len() != before;
        if self.active.as_ref().is_some_and(|i| i.task.task == task) {
            if let Some(g) = self.active.as_ref().and_then(|i| i.cur_gate.as_ref()) {
                g.set(u32::MAX);
            }
            self.active = None;
            hit = true;
        }
        if let Some(f) = self.followers.remove(&task) {
            for gate in f.forwards.values() {
                gate.set(u32::MAX);
            }
            hit = true;
        }
        hit |= self.pending_reads.remove(&task).is_some();
        self.cancelled.insert(task);
        hit
    }

    /// Resume watermark of our follower role in `task`: the longest
    /// stream prefix that is durable here — delivered in order *and*
    /// cut at a boundary the write pattern can resume from
    /// ([`AffinePattern::split_floor`]). `None` when this node holds no
    /// follower state for the task.
    pub fn follower_watermark(&self, task: u32) -> Option<usize> {
        self.followers
            .get(&task)
            .map(|f| if f.scattered { f.expected_bytes } else { f.cfg.pattern.split_floor(f.bytes_arrived) })
    }

    /// Fault repair, called immediately before [`Torrent::cancel`]:
    /// scatter the delivered stream prefix into local memory so a resume
    /// chain only has to re-stream the tail. With-data followers buffer
    /// the stream and scatter at the last segment (`handle`), so a
    /// cancelled follower would otherwise discard bytes that already
    /// crossed the fabric — and byte-exactness after resume would fail.
    /// Returns the salvaged watermark (0 for phantom streams, which have
    /// no bytes to make durable; their watermark still guides resume
    /// accounting via [`Torrent::follower_watermark`]).
    pub fn salvage(&mut self, task: u32, mem: &mut Scratchpad) -> usize {
        let Some(f) = self.followers.get_mut(&task) else { return 0 };
        if f.scattered {
            return f.expected_bytes;
        }
        if f.stream_buf.is_empty() {
            return 0;
        }
        let k = f.cfg.pattern.split_floor(f.stream_buf.len());
        let mut off = 0;
        for (addr, len) in f.cfg.pattern.runs() {
            if off >= k {
                break;
            }
            let take = len.min(k - off);
            mem.write(addr, &f.stream_buf[off..off + take]);
            off += take;
        }
        k
    }

    /// Heartbeat ordinal for the coordinator's stall detector: any value
    /// that keeps *changing* while the local protocol state advances.
    /// The coordinator sums this across every node's engines; a sum
    /// frozen for a full detection window marks the task as stalled.
    pub fn progress_of(&self, task: u32) -> Option<u64> {
        let mut seen = false;
        let mut acc: u64 = 0;
        if self.queue.iter().any(|(t, _)| t.task == task) {
            seen = true;
            acc = acc.wrapping_add(1);
        }
        if let Some(init) = self.active.as_ref().filter(|i| i.task.task == task) {
            seen = true;
            let phase = match &init.phase {
                InitPhase::Dispatch { next_cfg, .. } => 0x100 + *next_cfg as u64,
                InitPhase::WaitGrant => 0x1_0000,
                InitPhase::SendData { next_seg, .. } => {
                    0x10_0000
                        + (*next_seg as u64) * 0x1000
                        + init.cur_gate.as_ref().map_or(0, |g| g.get() as u64)
                }
                InitPhase::WaitFinish => 0x100_0000,
            };
            acc = acc.wrapping_add(phase);
        }
        if let Some(f) = self.followers.get(&task) {
            seen = true;
            acc = acc
                .wrapping_add((f.bytes_arrived as u64) << 4)
                .wrapping_add(f.grant_sent as u64)
                .wrapping_add((f.grant_from_next as u64) << 1)
                .wrapping_add((f.finish_sent as u64) << 2)
                .wrapping_add((f.finish_from_next as u64) << 3)
                .wrapping_add(f.forwarded.len() as u64);
        }
        if self.pending_reads.contains_key(&task) {
            seen = true;
            acc = acc.wrapping_add(0x200_0000);
        }
        seen.then_some(acc)
    }

    /// Submit a Chainwrite / P2P task (initiator side).
    pub fn submit(&mut self, task: ChainTask, now: u64) {
        assert!(!task.dests.is_empty(), "task needs at least one destination");
        for d in &task.dests {
            assert_eq!(
                d.pattern.total_bytes(),
                task.read.total_bytes(),
                "destination pattern size mismatch"
            );
        }
        self.queue.push_back((task, now));
    }

    /// Local loopback (src and dst in the same scratchpad): the Torrent
    /// acts as a data reshuffling engine; returns the completion cycle.
    pub fn local_loopback(
        &mut self,
        read: &AffinePattern,
        write: &AffinePattern,
        mem: &mut Scratchpad,
        now: u64,
    ) -> u64 {
        assert_eq!(read.total_bytes(), write.total_bytes());
        let stream = read.gather(mem);
        write.scatter(&stream, mem);
        self.stats.bytes_written_local += stream.len() as u64;
        // Read and write DSEs run concurrently; the slower side dominates.
        now + read.stream_cycles().max(write.stream_cycles())
    }

    /// Remote read (pull tunnel, paper Fig 4(c) Type Identifier = read):
    /// ask the Torrent at `remote` to stream `remote_read` back to us; our
    /// DSE scatters it with `local_write`. The data returns as a regular
    /// 1-destination Chainwrite initiated by the remote, so it reuses the
    /// whole grant/finish machinery. Always moves real bytes.
    pub fn submit_read(
        &mut self,
        task: u32,
        remote: NodeId,
        remote_read: AffinePattern,
        local_write: AffinePattern,
        net: &mut dyn NetPort,
        now: u64,
    ) {
        assert_eq!(remote_read.total_bytes(), local_write.total_bytes());
        let cfg_remote = TorrentCfg {
            task,
            cfg_type: CfgType::Read,
            prev: None,
            next: Some(self.node),
            position: 0,
            chain_len: 1,
            axi_burst_bytes: SEG_BYTES as u32,
            pattern: remote_read,
            via_prev: None,
            via_next: None,
        };
        let cfg_back = TorrentCfg {
            task,
            cfg_type: CfgType::Write,
            prev: Some(remote),
            next: None,
            position: 0,
            chain_len: 1,
            axi_burst_bytes: SEG_BYTES as u32,
            pattern: local_write,
            via_prev: None,
            via_next: None,
        };
        let mut payload = cfg_remote.encode();
        payload.extend_from_slice(&cfg_back.encode());
        net.send(
            self.node,
            Packet::new(0, self.node, remote, Message::TorrentCfg { task })
                .with_payload(payload),
        );
        self.stats.cfgs_sent += 1;
        self.pending_reads.insert(task, now);
    }

    /// True when nothing is in flight on this engine.
    pub fn is_idle(&self) -> bool {
        self.active.is_none()
            && self.queue.is_empty()
            && self.followers.is_empty()
            && self.pending_reads.is_empty()
    }

    /// Number of in-flight follower roles (used by tests/failure injection).
    pub fn follower_count(&self) -> usize {
        self.followers.len()
    }

    /// Activity hint (the `sim::Clocked::next_event` contract): earliest
    /// cycle at which ticking this engine changes observable state.
    /// `Some(now)` = busy every cycle; `None` = waiting on messages (or
    /// idle) — any progress then implies fabric activity, which the SoC
    /// stepper refuses to skip over. Mirrors `tick_initiator` /
    /// `tick_followers` case by case; every wait this engine self-times
    /// (`CFG_ISSUE`, `CFG_DECODE`, `GRANT_PROC`, `FIN_PROC`, local DSE
    /// write drain) is reported exactly so those stretches can be skipped.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        let mut min: Option<u64> = None;
        let mut fold = |c: u64| {
            let c = c.max(now);
            min = Some(min.map_or(c, |m: u64| m.min(c)));
        };
        if self.active.is_none() && !self.queue.is_empty() {
            fold(now); // next tick pops and starts the task
        }
        if let Some(init) = &self.active {
            match &init.phase {
                InitPhase::Dispatch { next_cfg, ready_at } => {
                    if *next_cfg < init.task.dests.len() {
                        fold(*ready_at); // CFG_ISSUE_CYCLES between cfgs
                    } else {
                        fold(now); // defensive: transition pending
                    }
                }
                // Streaming mutates the DSE budget every cycle.
                InitPhase::SendData { .. } => fold(now),
                // Externally driven: flips on TorrentGrant / TorrentFinish.
                InitPhase::WaitGrant | InitPhase::WaitFinish => {}
            }
        }
        for f in self.followers.values() {
            // Forward gates trail fabric state; while any exist the
            // incoming packet is still mid-ejection (the stepper is
            // already refusing to skip), but stay conservative.
            if !f.forwards.is_empty() {
                fold(now);
            }
            if !f.grant_sent && (f.cfg.next.is_none() || f.grant_from_next) {
                match f.grant_ready_at {
                    // The GRANT_PROC countdown starts at cfg_ready_at.
                    None => fold(f.cfg_ready_at),
                    Some(at) => fold(at),
                }
            }
            if f.grant_sent
                && !f.finish_sent
                && f.bytes_arrived >= f.expected_bytes
                && (f.cfg.next.is_none() || f.finish_from_next)
            {
                match f.finish_ready_at {
                    // The FIN_PROC countdown starts once local writes drain.
                    None => fold(f.write_done_at),
                    Some(at) => fold(at),
                }
            }
        }
        // `pending_reads` progresses via our follower role / messages.
        min
    }

    // ------------------------------------------------------------------
    // Inbox handling
    // ------------------------------------------------------------------

    /// Consume a packet addressed to this Torrent. Returns `false` if the
    /// message is not Torrent traffic.
    pub fn handle(&mut self, pkt: &Packet, mem: &mut Scratchpad, now: u64) -> bool {
        match &pkt.msg {
            Message::TorrentCfg { task } => {
                let bytes = pkt.payload.as_ref().expect("cfg carries its encoding");
                let (cfg, consumed) =
                    TorrentCfg::decode_prefix(bytes).expect("malformed cfg frame");
                debug_assert_eq!(cfg.task, *task);
                self.stats.cfgs_received += 1;
                if self.cancelled.contains(task) {
                    // Cfg raced a repair cancellation: resurrecting the
                    // follower role would wait forever for a stream the
                    // initiator will never send.
                    return true;
                }
                if cfg.cfg_type == CfgType::Read {
                    // Read tunnel: the requester's write-back cfg follows in
                    // the same payload; serve it as a 1-node Chainwrite from
                    // our memory back to the requester.
                    let back = TorrentCfg::decode(&bytes[consumed..])
                        .expect("read request missing write-back cfg");
                    self.submit(
                        ChainTask {
                            task: cfg.task,
                            read: cfg.pattern,
                            dests: vec![ChainDest {
                                node: pkt.src,
                                pattern: back.pattern,
                                vias: ChainVias::default(),
                            }],
                            with_data: true,
                        },
                        now,
                    );
                    return true;
                }
                let expected = cfg.pattern.total_bytes();
                self.followers.insert(
                    cfg.task,
                    FollowerState {
                        initiator: pkt.src,
                        cfg_ready_at: now + CFG_DECODE_CYCLES,
                        cfg,
                        grant_from_next: false,
                        grant_sent: false,
                        grant_ready_at: None,
                        bytes_arrived: 0,
                        expected_bytes: expected,
                        write_done_at: 0,
                        stream_buf: Vec::new(),
                        scattered: false,
                        finish_from_next: false,
                        finish_sent: false,
                        finish_ready_at: None,
                        forwards: BTreeMap::new(),
                        forwarded: Default::default(),
                    },
                );
                true
            }
            Message::TorrentGrant { task } => {
                if let Some(init) = self.active.as_mut() {
                    if init.task.task == *task {
                        debug_assert!(matches!(init.phase, InitPhase::WaitGrant));
                        init.phase = InitPhase::SendData { next_seg: 0, sent_all: false };
                        return true;
                    }
                }
                if let Some(f) = self.followers.get_mut(task) {
                    f.grant_from_next = true;
                    return true;
                }
                true // stale grant for a finished task
            }
            Message::TorrentFinish { task } => {
                if let Some(init) = self.active.as_mut() {
                    if init.task.task == *task {
                        let r = TaskResult {
                            task: *task,
                            submitted_at: init.submitted_at,
                            finished_at: now,
                            bytes: init.task.read.total_bytes(),
                            n_dests: init.task.dests.len(),
                        };
                        self.results.push(r);
                        self.stats.tasks_completed += 1;
                        self.active = None;
                        return true;
                    }
                }
                if let Some(f) = self.followers.get_mut(task) {
                    f.finish_from_next = true;
                    return true;
                }
                true
            }
            Message::ChainData { task, last, .. } => {
                let node = self.node;
                let Some(f) = self.followers.get_mut(task) else {
                    if self.cancelled.contains(task) {
                        return true; // stale segment of a repaired chain
                    }
                    panic!("ChainData for unknown task {task} at {node:?}");
                };
                f.bytes_arrived += pkt.payload_bytes;
                if let Some(data) = &pkt.payload {
                    f.stream_buf.extend_from_slice(data);
                }
                self.stats.bytes_written_local += pkt.payload_bytes as u64;
                // Local DSE write: charge pattern-rate cycles per segment.
                let rate = f.cfg.pattern.rate_per_cycle().max(1.0);
                let seg_cycles = (pkt.payload_bytes as f64 / rate).ceil() as u64;
                f.write_done_at = f.write_done_at.max(now) + seg_cycles;
                if *last {
                    debug_assert!(
                        f.bytes_arrived >= f.expected_bytes,
                        "short stream: {} < {}",
                        f.bytes_arrived,
                        f.expected_bytes
                    );
                    if !f.stream_buf.is_empty() && !f.scattered {
                        // Materialized run: scatter the full stream now
                        // (timing already charged incrementally).
                        f.scattered = true;
                        let buf = std::mem::take(&mut f.stream_buf);
                        f.cfg.pattern.scatter(&buf, mem);
                    }
                }
                true
            }
            _ => false,
        }
    }

    // ------------------------------------------------------------------
    // Per-cycle engine logic
    // ------------------------------------------------------------------

    pub fn tick(&mut self, net: &mut dyn NetPort, mem: &mut Scratchpad) {
        let now = net.cycle();
        self.tick_initiator(net, mem, now);
        self.tick_followers(net, now);
    }

    fn tick_initiator(&mut self, net: &mut dyn NetPort, mem: &mut Scratchpad, now: u64) {
        if self.active.is_none() {
            if let Some((task, submitted_at)) = self.queue.pop_front() {
                let total = task.read.total_bytes();
                let stream = task.with_data.then(|| Arc::new(task.read.gather(mem)));
                let mut segs = Vec::new();
                let mut off = 0;
                while off < total {
                    let len = SEG_BYTES.min(total - off);
                    segs.push((off, len));
                    off += len;
                }
                let rate = task.read.rate_per_cycle();
                self.active = Some(InitiatorState {
                    submitted_at: submitted_at.max(now),
                    phase: InitPhase::Dispatch { next_cfg: 0, ready_at: now },
                    stream,
                    segs,
                    dse_budget: 0.0,
                    dse_rate_flits: rate / FLIT_BYTES as f64,
                    cur_gate: None,
                    cur_gate_total: 0,
                    task,
                });
            }
        }
        let Some(init) = self.active.as_mut() else { return };

        match &mut init.phase {
            InitPhase::Dispatch { next_cfg, ready_at } => {
                // Issue one cfg per CFG_ISSUE_CYCLES (descriptor build),
                // serialized out of the NI.
                while *next_cfg < init.task.dests.len() && *ready_at <= now {
                    let i = *next_cfg;
                    let d = &init.task.dests[i];
                    let cfg = TorrentCfg {
                        task: init.task.task,
                        cfg_type: CfgType::Write,
                        prev: Some(if i == 0 { self.node } else { init.task.dests[i - 1].node }),
                        next: (i + 1 < init.task.dests.len())
                            .then(|| init.task.dests[i + 1].node),
                        position: i as u16,
                        chain_len: init.task.dests.len() as u16,
                        axi_burst_bytes: SEG_BYTES as u32,
                        pattern: d.pattern.clone(),
                        // The hop's own backward leg, and the *next*
                        // hop's data leg (the forward this node sends).
                        via_prev: d.vias.back,
                        via_next: init
                            .task
                            .dests
                            .get(i + 1)
                            .and_then(|nd| nd.vias.data),
                    };
                    let pkt = Packet::new(
                        0,
                        self.node,
                        d.node,
                        Message::TorrentCfg { task: init.task.task },
                    )
                    .with_payload(cfg.encode())
                    .with_via(d.vias.cfg);
                    net.send(self.node, pkt);
                    self.stats.cfgs_sent += 1;
                    *next_cfg += 1;
                    *ready_at = now + CFG_ISSUE_CYCLES;
                }
                if *next_cfg == init.task.dests.len() {
                    init.phase = InitPhase::WaitGrant;
                }
            }
            InitPhase::WaitGrant => {} // flips on TorrentGrant
            InitPhase::SendData { next_seg, sent_all } => {
                // Refill the DSE budget and open the current segment's gate.
                init.dse_budget += init.dse_rate_flits;
                if let Some(g) = &init.cur_gate {
                    let open = g.get();
                    if open < init.cur_gate_total && init.dse_budget >= 1.0 {
                        let add = (init.dse_budget as u32).min(init.cur_gate_total - open);
                        g.set(open + add);
                        init.dse_budget -= add as f64;
                        self.stats.bytes_streamed_out += add as u64 * FLIT_BYTES as u64;
                    }
                    if g.get() < init.cur_gate_total {
                        return; // still streaming this segment
                    }
                }
                if *next_seg < init.segs.len() {
                    let (off, len) = init.segs[*next_seg];
                    let seg_payload = init
                        .stream
                        .as_ref()
                        .map(|s| Arc::new(s[off..off + len].to_vec()));
                    let last = *next_seg == init.segs.len() - 1;
                    let msg = Message::ChainData {
                        task: init.task.task,
                        seq: *next_seg as u32,
                        last,
                    };
                    let pkt = Packet::new(0, self.node, init.task.dests[0].node, msg)
                        .with_shared_payload(seg_payload, len)
                        .with_via(init.task.dests[0].vias.data);
                    let n_flits = pkt.len_flits() as u32;
                    let gate: Gate = Arc::new(GateCell::new(1)); // head free
                    net.send_gated(self.node, pkt, gate.clone());
                    init.cur_gate = Some(gate);
                    init.cur_gate_total = n_flits;
                    *next_seg += 1;
                } else if !*sent_all {
                    *sent_all = true;
                    init.phase = InitPhase::WaitFinish;
                }
            }
            InitPhase::WaitFinish => {} // flips on TorrentFinish
        }
    }

    fn tick_followers(&mut self, net: &mut dyn NetPort, now: u64) {
        if self.followers.is_empty() {
            return; // §Perf: skip the per-cycle NI scan on idle endpoints
        }
        let node = self.node;
        let mut done: Vec<u32> = Vec::new();
        // 1. Cut-through forwarding: scan in-progress ejections.
        let in_progress = net.eject_in_progress(node);
        for (id, pkt, arrived) in in_progress {
            let Message::ChainData { task, seq, last } = pkt.msg else { continue };
            let Some(f) = self.followers.get_mut(&task) else { continue };
            let Some(next) = f.cfg.next else { continue };
            // The duplicator releases flit i of the forwarded copy
            // FWD_LATENCY_CYCLES after flit i of the incoming stream
            // arrived: the gate trails the arrival count by that many
            // flit-times (1 flit/cycle at link rate).
            let allowed = arrived.saturating_sub(FWD_LATENCY_CYCLES as u32).max(1);
            if let Some(gate) = f.forwards.get(&id) {
                gate.set(gate.get().max(allowed));
                continue;
            }
            if f.forwarded.contains(&id) {
                continue;
            }
            // New incoming segment: start the forwarded copy, gated.
            let fwd = Packet::new(0, node, next, Message::ChainData { task, seq, last })
                .with_shared_payload(pkt.payload.clone(), pkt.payload_bytes)
                .with_via(f.cfg.via_next);
            let gate: Gate = Arc::new(GateCell::new(allowed));
            net.send_gated(node, fwd, gate.clone());
            f.forwards.insert(id, gate);
            f.forwarded.insert(id);
            self.stats.bytes_forwarded += pkt.payload_bytes as u64;
        }
        // 2. Open gates fully for packets whose tail has been delivered.
        for f in self.followers.values_mut() {
            f.forwards.retain(|id, gate| {
                if net.progress_of(node, *id).is_none() {
                    gate.set(u32::MAX); // delivered: release remaining flits
                    false
                } else {
                    true
                }
            });
        }
        // 3. Grant + finish propagation.
        for (task, f) in self.followers.iter_mut() {
            let is_tail = f.cfg.next.is_none();
            let ready = now >= f.cfg_ready_at;
            // Grant: tail generates; intermediates need next's grant.
            if ready && !f.grant_sent && (is_tail || f.grant_from_next) {
                let at = *f.grant_ready_at.get_or_insert(now + GRANT_PROC_CYCLES);
                if now >= at {
                    let prev = f.cfg.prev.unwrap_or(f.initiator);
                    net.send(
                        node,
                        Packet::new(0, node, prev, Message::TorrentGrant { task: *task })
                            .with_via(f.cfg.via_prev),
                    );
                    f.grant_sent = true;
                    self.stats.grants_relayed += 1;
                }
            }
            // Finish: local write done + (tail || next finished).
            let data_done = f.bytes_arrived >= f.expected_bytes && now >= f.write_done_at;
            if f.grant_sent && !f.finish_sent && data_done && (is_tail || f.finish_from_next) {
                let at = *f.finish_ready_at.get_or_insert(now + FIN_PROC_CYCLES);
                if now >= at {
                    let prev = f.cfg.prev.unwrap_or(f.initiator);
                    net.send(
                        node,
                        Packet::new(0, node, prev, Message::TorrentFinish { task: *task })
                            .with_via(f.cfg.via_prev),
                    );
                    f.finish_sent = true;
                    self.stats.finishes_relayed += 1;
                    done.push(*task);
                }
            }
        }
        for t in done {
            let f = self.followers.remove(&t);
            // If this completed follower role was serving one of our own
            // read-tunnel requests, record the local result.
            if let Some(submitted_at) = self.pending_reads.remove(&t) {
                let bytes = f.map(|f| f.expected_bytes).unwrap_or(0);
                self.results.push(TaskResult {
                    task: t,
                    submitted_at,
                    finished_at: now,
                    bytes,
                    n_dests: 1,
                });
                self.stats.tasks_completed += 1;
            }
        }
    }
}

/// Uniform dispatch surface. The inherent methods above keep their
/// context-typed signatures (unit tests drive them directly); the trait
/// impl delegates, converting [`TaskSpec`] destinations — already in
/// chain order, the coordinator applies the `sched::Strategy` — into
/// [`ChainDest`]s.
impl Engine for Torrent {
    fn label(&self) -> &'static str {
        "torrent"
    }

    fn submit(&mut self, spec: TaskSpec, now: u64) -> Result<(), SubmitError> {
        spec.validate()?;
        let TaskSpec { task, read, dests, with_data, .. } = spec;
        let dests = dests
            .into_iter()
            .map(|(node, pattern)| ChainDest { node, pattern, vias: ChainVias::default() })
            .collect();
        Torrent::submit(self, ChainTask { task, read, dests, with_data }, now);
        Ok(())
    }

    fn handle(&mut self, pkt: &Packet, ctx: &mut EngineCtx<'_>, now: u64) -> bool {
        Torrent::handle(self, pkt, ctx.mem, now)
    }

    fn tick(&mut self, ctx: &mut EngineCtx<'_>) {
        Torrent::tick(self, ctx.net, ctx.mem)
    }

    fn next_event(&self, now: u64) -> Option<u64> {
        Torrent::next_event(self, now)
    }

    fn is_idle(&self) -> bool {
        Torrent::is_idle(self)
    }

    fn drain_results(&mut self) -> Vec<TaskResult> {
        std::mem::take(&mut self.results)
    }

    fn peek_result(&self, task: u32) -> Option<&TaskResult> {
        self.results.iter().find(|r| r.task == task)
    }

    fn progress_of(&self, task: u32) -> Option<u64> {
        Torrent::progress_of(self, task)
    }

    fn cancel(&mut self, task: u32) -> bool {
        Torrent::cancel(self, task)
    }

    fn phase_of(&self, task: u32, _now: u64) -> Option<TaskPhase> {
        if self.queue.iter().any(|(t, _)| t.task == task) {
            return Some(TaskPhase::Configuring);
        }
        let init = self.active.as_ref().filter(|i| i.task.task == task)?;
        Some(match init.phase {
            InitPhase::Dispatch { .. } | InitPhase::WaitGrant => TaskPhase::Configuring,
            InitPhase::SendData { .. } | InitPhase::WaitFinish => TaskPhase::Streaming,
        })
    }

    fn accept_frontend_legs(&mut self, legs: &mut Vec<(ChainTask, u64)>) {
        for (task, at) in legs.drain(..) {
            Torrent::submit(self, task, at);
        }
    }
}

//! PJRT runtime: load the JAX/Pallas AOT artifacts and execute them from
//! Rust. Python never runs at simulation time.
//!
//! `make artifacts` lowers every L2 entry point to HLO **text**
//! (`artifacts/<name>.hlo.txt` + `manifest.txt`); this module compiles
//! them once on the PJRT CPU client (`xla` crate) and exposes typed
//! f32-tensor execution. HLO text — not serialized protos — is the
//! interchange format because jax ≥ 0.5 emits 64-bit instruction ids the
//! bundled xla_extension 0.5.1 rejects (see DESIGN.md and
//! /opt/xla-example/README.md).

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

pub use manifest::{Manifest, ManifestEntry, ShapeSpec};

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor (test/workload inputs).
    pub fn random(shape: Vec<usize>, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect();
        Tensor { shape, data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Bytes when materialized as f32 (sizes the simulated transfers).
    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT-backed runtime: all compiled artifacts + the client.
pub struct Engine {
    pub dir: PathBuf,
    client: xla::PjRtClient,
    exes: HashMap<String, Executable>,
}

impl Engine {
    /// Load and compile every artifact listed in `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        for entry in manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
            exes.insert(entry.name.clone(), Executable { entry, exe });
        }
        Ok(Engine { dir, client, exes })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.exes.get(name).map(|e| &e.entry)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute artifact `name` on f32 inputs; returns the output tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have {:?})", self.names()))?;
        let spec = &exe.entry;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.dims {
                return Err(anyhow!(
                    "{name}: input {i} shape {:?} != manifest {:?}",
                    t.shape,
                    s.dims
                ));
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let mut result = exe
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output to_vec: {e:?}"))?;
                Ok(Tensor::new(s.dims.clone(), data))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_invariants() {
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.bytes(), 24);
        let r1 = Tensor::random(vec![4], 1);
        let r2 = Tensor::random(vec![4], 1);
        assert_eq!(r1, r2);
        assert_ne!(r1, Tensor::random(vec![4], 2));
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }
}

//! Wormhole virtual-channel router with a 4-stage pipeline and optional
//! network-layer multicast forking.
//!
//! Models the paper's §II-B router: Route Computation on the head flit,
//! VC/Switch allocation, Switch Traversal. Timing abstraction: the
//! per-hop pipeline depth (`ROUTER_PIPELINE`) plus link traversal
//! (`LINK_CYCLES`) is charged on the link delay line; the switch moves at
//! most one flit per output port per cycle, so head latency is
//! `(ROUTER_PIPELINE + LINK_CYCLES) * hops` and saturated throughput is
//! one flit/cycle — matching a FlooNoC-style 64 B/CC mesh.
//!
//! Multicast (ESP baseline): at RC a head flit with a destination set is
//! partitioned by next hop (`mcast_fork`); replication happens at SA/ST
//! and is *synchronized* — a flit advances only when every branch
//! output has credit, reproducing the VA stalls the paper describes.
//!
//! The router is topology-generic: route computation and the credit
//! wiring go through `&dyn Topology` (mesh XY, torus wraparound XY or
//! ring shortest-arc — `noc::topology`); nothing here assumes a mesh.

use std::collections::VecDeque;
use std::sync::Arc;

use super::multicast::mcast_fork;
use super::packet::{Flit, Message, Packet};
use super::topology::{Dir, NodeId, Topology};

/// Virtual channels: VC0 = control (cfg/grant/finish/acks), VC1 = data.
/// Separating the classes keeps the Chainwrite control plane live under
/// full data load (protocol-deadlock avoidance at the application layer).
pub const NUM_VCS: usize = 2;
/// Input buffer depth per VC, in flits.
pub const BUF_FLITS: usize = 8;
/// RC + VA + SA + ST stages (paper §II-B cites the common 4-stage pipe).
pub const ROUTER_PIPELINE: u64 = 4;
/// Physical link traversal.
pub const LINK_CYCLES: u64 = 1;

/// VC a message class travels on.
pub fn vc_of(msg: &Message) -> usize {
    match msg {
        Message::AxiWriteReq { .. }
        | Message::AxiReadResp { .. }
        | Message::ChainData { .. }
        | Message::McastData { .. } => 1,
        _ => 0,
    }
}

/// Route state locked by a head flit until its tail passes.
#[derive(Debug, Clone)]
struct RouteLock {
    /// Per-branch output: direction + the packet clone to emit there.
    branches: Vec<(Dir, Arc<Packet>)>,
}

/// One input VC: flit FIFO + the locked route of the packet being routed.
#[derive(Debug, Default)]
struct VcState {
    buf: VecDeque<Flit>,
    route: Option<RouteLock>,
}

/// Per-output wormhole lock: (input port, vc) holding the output.
type OutLock = Option<(usize, usize)>;

/// A single fabric router.
pub struct Router {
    pub node: NodeId,
    /// `input[port][vc]`
    inputs: [[VcState; NUM_VCS]; 5],
    /// Wormhole ownership per output port.
    out_locks: [OutLock; 5],
    /// Credits per output port per VC = free slots downstream.
    credits: [[usize; NUM_VCS]; 5],
    /// Round-robin arbitration pointer per output port.
    rr: [usize; 5],
    /// Flits across all input VCs — O(1) activity check for the
    /// event-driven stepper (§Perf: idle routers skip allocation).
    occupancy: usize,
    /// Input slots freed this tick `(port index, vc)` — drained by the
    /// network layer to return credits upstream.
    pub freed: Vec<(usize, usize)>,
}

impl Router {
    pub fn new(topo: &dyn Topology, node: NodeId) -> Self {
        let mut credits = [[0usize; NUM_VCS]; 5];
        for d in Dir::ALL {
            let have = match d {
                Dir::Local => usize::MAX / 2, // ejection always sinks
                _ => {
                    if topo.neighbour(node, d).is_some() {
                        BUF_FLITS
                    } else {
                        0
                    }
                }
            };
            for vc in 0..NUM_VCS {
                credits[d.index()][vc] = have;
            }
        }
        Router {
            node,
            inputs: Default::default(),
            out_locks: [None; 5],
            credits,
            rr: [0; 5],
            occupancy: 0,
            freed: Vec::new(),
        }
    }

    /// Free slots in input buffer `(port, vc)` — the upstream credit view.
    pub fn input_space(&self, port: Dir, vc: usize) -> usize {
        BUF_FLITS - self.inputs[port.index()][vc].buf.len()
    }

    pub fn accept(&mut self, port: Dir, vc: usize, flit: Flit) {
        let q = &mut self.inputs[port.index()][vc];
        assert!(q.buf.len() < BUF_FLITS, "credit protocol violated at {:?}", self.node);
        q.buf.push_back(flit);
        self.occupancy += 1;
    }

    pub fn return_credit(&mut self, out: Dir, vc: usize) {
        self.credits[out.index()][vc] += 1;
    }

    /// True if this router holds no flits (quiescence check). O(1): the
    /// occupancy counter tracks accepts and departures exactly.
    pub fn is_idle(&self) -> bool {
        debug_assert_eq!(
            self.occupancy == 0,
            self.inputs.iter().all(|p| p.iter().all(|v| v.buf.is_empty())),
            "router occupancy counter out of sync at {:?}",
            self.node
        );
        self.occupancy == 0
    }

    /// Fault injection: the router dies. Every buffered flit vanishes and
    /// wormhole locks are forgotten. Returns the number of flits purged
    /// per `(input port, vc)` so the fabric can return their credits
    /// upstream — a dead router *sinks* traffic rather than wedging it:
    /// if the purged credits never returned, a full input buffer would
    /// starve the neighbour's output forever and the backpressure would
    /// creep across the whole upstream path, making the surviving fabric
    /// unusable for repair. Data dies; flow control survives.
    pub fn purge(&mut self) -> [[usize; NUM_VCS]; 5] {
        let mut purged = [[0usize; NUM_VCS]; 5];
        for (pi, port) in self.inputs.iter_mut().enumerate() {
            for (vi, vc) in port.iter_mut().enumerate() {
                purged[pi][vi] = vc.buf.len();
                vc.buf.clear();
                vc.route = None;
            }
        }
        self.out_locks = [None; 5];
        self.freed.clear();
        self.occupancy = 0;
        purged
    }

    /// Advance the arbitration pointer by `delta` ticks without doing any
    /// allocation work. For an **empty** router this is exactly what
    /// `delta` calls to [`Router::tick_into`] would have done — the basis
    /// of the event-driven stepper's skip-ahead (the pointer must advance
    /// identically in both modes or arbitration outcomes would diverge).
    pub fn rr_advance(&mut self, delta: u64) {
        self.rr[0] = self.rr[0].wrapping_add(delta as usize);
    }

    /// Compute the route for the packet at the head of `(port, vc)`.
    fn compute_route(&self, topo: &dyn Topology, pkt: &Arc<Packet>) -> RouteLock {
        if let Some(dsts) = &pkt.mcast_dsts {
            let branches = mcast_fork(topo, self.node, dsts)
                .into_iter()
                .map(|(dir, subset)| {
                    // Per-branch packet clone carrying only that branch's
                    // destination subset (collapses to unicast at 1 dest).
                    let mut p: Packet = (**pkt).clone();
                    if subset.len() == 1 {
                        p.dst = subset[0];
                        p.mcast_dsts = None;
                    } else {
                        p.dst = subset[0];
                        p.mcast_dsts = Some(Arc::new(subset));
                    }
                    (dir, Arc::new(p))
                })
                .collect();
            RouteLock { branches }
        } else {
            // Waypoint override (repair reroute): steer toward `via`
            // while this node still lies on path(src, via) before the
            // waypoint itself, then toward the real destination. The
            // test is stateless — flits carry no "passed the waypoint"
            // bit — which is sound only for *simple* detours (the two
            // segments share no node besides `via`; the planner
            // guarantees this via `Degraded::route_is_clean`).
            let target = match pkt.via {
                Some(via) if via != self.node && via != pkt.dst && self.toward_via(topo, pkt, via) => via,
                _ => pkt.dst,
            };
            let dir = topo.next_hop(self.node, target);
            RouteLock { branches: vec![(dir, pkt.clone())] }
        }
    }

    /// True when this node is on `path(src, via)` strictly before `via`.
    /// Cold path: only packets carrying a waypoint (repair traffic) pay
    /// the path walk, and only once per packet at route computation.
    fn toward_via(&self, topo: &dyn Topology, pkt: &Packet, via: NodeId) -> bool {
        let mut cur = pkt.src;
        while cur != via {
            if cur == self.node {
                return true;
            }
            let d = topo.next_hop(cur, via);
            cur = topo.neighbour(cur, d).expect("routing left the fabric");
        }
        false
    }

    /// Switch allocation + traversal for one cycle. Emits the flits that
    /// leave this router as `(out_dir, vc, flit)`; the network layer puts
    /// them on the link delay lines. At most one flit per output port.
    /// Convenience wrapper over [`Router::tick_into`] (unit tests).
    pub fn tick(&mut self, topo: &dyn Topology) -> Vec<(Dir, usize, Flit)> {
        let mut moved = Vec::new();
        self.tick_into(topo, &mut moved);
        moved
    }

    /// Allocation-free variant: appends this cycle's moves to `moved`
    /// (§Perf: the network reuses one buffer across all routers).
    pub fn tick_into(&mut self, topo: &dyn Topology, moved: &mut Vec<(Dir, usize, Flit)>) {
        let mut out_taken = [false; 5];
        self.freed.clear();

        // Iterate inputs in round-robin order per output; simpler global
        // scheme: walk (port, vc) pairs starting at a rotating offset and
        // greedily claim outputs.
        let n_slots = 5 * NUM_VCS;
        let start = self.rr[0] % n_slots;
        for k in 0..n_slots {
            let slot = (start + k) % n_slots;
            let (port, vc) = (slot / NUM_VCS, slot % NUM_VCS);

            // Pre-compute route on a fresh head (RC stage).
            let front_is_head = {
                let vcs = &self.inputs[port][vc];
                match vcs.buf.front() {
                    Some(f) => f.is_head() && vcs.route.is_none(),
                    None => false,
                }
            };
            if front_is_head {
                let pkt = self.inputs[port][vc].buf.front().unwrap().packet.clone();
                let route = self.compute_route(topo, &pkt);
                self.inputs[port][vc].route = Some(route);
            }

            // All branch outputs must be free-or-ours and credited
            // (synchronized multicast replication; trivially one branch
            // for unicast). Checked through a shared borrow so the
            // blocked case allocates nothing (SPerf: this runs for every
            // occupied VC every cycle).
            let ok = {
                let vcs = &self.inputs[port][vc];
                match (&vcs.route, vcs.buf.is_empty()) {
                    (Some(route), false) => route.branches.iter().all(|(dir, _)| {
                        let di = dir.index();
                        !out_taken[di]
                            && self.credits[di][vc] > 0
                            && match self.out_locks[di] {
                                None => true,
                                Some(owner) => owner == (port, vc),
                            }
                    }),
                    _ => false,
                }
            };
            if !ok {
                continue;
            }

            // Move the flit: take the route instead of cloning it, and put
            // it back unless the tail just released the wormhole.
            let route = self.inputs[port][vc].route.take().unwrap();
            let flit = self.inputs[port][vc].buf.pop_front().unwrap();
            self.occupancy -= 1;
            self.freed.push((port, vc));
            let is_head = flit.is_head();
            let is_tail = flit.is_tail();
            for (dir, branch_pkt) in &route.branches {
                let di = dir.index();
                out_taken[di] = true;
                self.credits[di][vc] -= 1;
                if is_head {
                    self.out_locks[di] = Some((port, vc));
                }
                if is_tail {
                    self.out_locks[di] = None;
                }
                moved.push((*dir, vc, Flit { packet: branch_pkt.clone(), seq: flit.seq }));
            }
            if !is_tail {
                self.inputs[port][vc].route = Some(route);
            }
        }
        self.rr[0] = self.rr[0].wrapping_add(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::topology::Mesh;

    fn mk(mesh: &Mesh, node: usize) -> Router {
        Router::new(mesh, NodeId(node))
    }

    #[test]
    fn edge_ports_have_no_credit() {
        let m = Mesh::new(3, 3);
        let r = mk(&m, 0); // corner: no south/west neighbours
        assert_eq!(r.credits[Dir::South.index()][0], 0);
        assert_eq!(r.credits[Dir::West.index()][0], 0);
        assert_eq!(r.credits[Dir::East.index()][0], BUF_FLITS);
    }

    #[test]
    fn unicast_flit_moves_toward_dst() {
        let m = Mesh::new(3, 1);
        let mut r = mk(&m, 0);
        let pkt = Arc::new(Packet::new(1, NodeId(0), NodeId(2), Message::Raw(0)));
        r.accept(Dir::Local, 0, Flit { packet: pkt, seq: 0 });
        let moved = r.tick(&m);
        assert_eq!(moved.len(), 1);
        assert_eq!(moved[0].0, Dir::East);
    }

    #[test]
    fn waypoint_steers_until_the_via_then_toward_dst() {
        // 4x4 mesh, src 0 -> dst 5 via 4 = (0,1): the YX detour. At the
        // source the default XY route is East (toward 1); the waypoint
        // forces North (toward 4). At the waypoint itself the override
        // expires and routing resumes toward dst (East to 5).
        let m = Mesh::new(4, 4);
        let pkt = Arc::new(
            Packet::new(1, NodeId(0), NodeId(5), Message::Raw(0))
                .with_via(Some(NodeId(4))),
        );
        let mut at_src = mk(&m, 0);
        at_src.accept(Dir::Local, 0, Flit { packet: pkt.clone(), seq: 0 });
        assert_eq!(at_src.tick(&m)[0].0, Dir::North);
        let mut at_via = mk(&m, 4);
        at_via.accept(Dir::South, 0, Flit { packet: pkt.clone(), seq: 0 });
        assert_eq!(at_via.tick(&m)[0].0, Dir::East);
        // A via-less packet on the same pair keeps the default XY route.
        let plain = Arc::new(Packet::new(2, NodeId(0), NodeId(5), Message::Raw(0)));
        let mut healthy = mk(&m, 0);
        healthy.accept(Dir::Local, 0, Flit { packet: plain, seq: 0 });
        assert_eq!(healthy.tick(&m)[0].0, Dir::East);
    }

    #[test]
    fn multicast_head_forks_to_all_branches() {
        let m = Mesh::new(3, 3);
        let mut r = mk(&m, 4); // center
        let pkt = Arc::new(
            Packet::new(1, NodeId(4), NodeId(3), Message::Raw(0))
                .with_mcast(vec![NodeId(3), NodeId(5), NodeId(4)]),
        );
        r.accept(Dir::Local, 0, Flit { packet: pkt, seq: 0 });
        let moved = r.tick(&m);
        let dirs: Vec<Dir> = moved.iter().map(|(d, _, _)| *d).collect();
        assert_eq!(moved.len(), 3);
        for want in [Dir::West, Dir::East, Dir::Local] {
            assert!(dirs.contains(&want), "missing branch {want:?}");
        }
    }

    #[test]
    fn multicast_stalls_until_all_branches_credited() {
        let m = Mesh::new(3, 1);
        let mut r = mk(&m, 1); // middle of a 1-row mesh
        // Exhaust east credit.
        for _ in 0..BUF_FLITS {
            r.credits[Dir::East.index()][0] -= 1;
        }
        let pkt = Arc::new(
            Packet::new(1, NodeId(1), NodeId(0), Message::Raw(0))
                .with_mcast(vec![NodeId(0), NodeId(2)]),
        );
        r.accept(Dir::Local, 0, Flit { packet: pkt, seq: 0 });
        // West has credit, east does not: synchronized fork must stall.
        assert!(r.tick(&m).is_empty());
        r.return_credit(Dir::East, 0);
        assert_eq!(r.tick(&m).len(), 2);
    }

    #[test]
    fn wormhole_locks_output_until_tail() {
        let m = Mesh::new(2, 1);
        let mut r = mk(&m, 0);
        let a = Arc::new(
            Packet::new(1, NodeId(0), NodeId(1), Message::Raw(0)).with_phantom_payload(64),
        ); // 2 flits
        let b = Arc::new(Packet::new(2, NodeId(0), NodeId(1), Message::Raw(1)));
        // Packet a on VC0 via Local, packet b head on VC1 via Local: same
        // output. b must wait until a's tail frees the port.
        r.accept(Dir::Local, 0, Flit { packet: a.clone(), seq: 0 });
        r.accept(Dir::Local, 0, Flit { packet: a.clone(), seq: 1 });
        r.accept(Dir::Local, 1, Flit { packet: b.clone(), seq: 0 });
        let m1 = r.tick(&m);
        assert_eq!(m1.len(), 1, "one flit per output per cycle");
        assert_eq!(m1[0].2.packet.id, 1);
        let m2 = r.tick(&m);
        // a's tail goes out (wormhole lock); b still waits.
        assert_eq!(m2.len(), 1);
        assert_eq!(m2[0].2.packet.id, 1);
        assert!(m2[0].2.is_tail());
        let m3 = r.tick(&m);
        assert_eq!(m3[0].2.packet.id, 2);
    }

    #[test]
    fn no_move_without_credit() {
        let m = Mesh::new(2, 1);
        let mut r = mk(&m, 0);
        for _ in 0..BUF_FLITS {
            r.credits[Dir::East.index()][0] -= 1;
        }
        let pkt = Arc::new(Packet::new(1, NodeId(0), NodeId(1), Message::Raw(0)));
        r.accept(Dir::Local, 0, Flit { packet: pkt, seq: 0 });
        assert!(r.tick(&m).is_empty());
    }

    #[test]
    fn occupancy_tracks_accept_and_departure() {
        let m = Mesh::new(2, 1);
        let mut r = mk(&m, 0);
        assert!(r.is_idle());
        let pkt = Arc::new(Packet::new(1, NodeId(0), NodeId(1), Message::Raw(0)));
        r.accept(Dir::Local, 0, Flit { packet: pkt, seq: 0 });
        assert!(!r.is_idle());
        r.tick(&m);
        assert!(r.is_idle());
    }

    #[test]
    fn rr_advance_matches_empty_ticks() {
        let m = Mesh::new(2, 1);
        let mut a = mk(&m, 0);
        let mut b = mk(&m, 0);
        for _ in 0..5 {
            a.tick(&m); // empty ticks only move the arbitration pointer
        }
        b.rr_advance(5);
        assert_eq!(a.rr, b.rr);
    }

    #[test]
    fn vc_of_separates_control_and_data() {
        assert_eq!(vc_of(&Message::TorrentGrant { task: 0 }), 0);
        assert_eq!(vc_of(&Message::ChainData { task: 0, seq: 0, last: false }), 1);
        assert_eq!(
            vc_of(&Message::AxiWriteReq { addr: 0, bytes: 0, axi_id: 0 }),
            1
        );
        assert_eq!(vc_of(&Message::AxiWriteResp { axi_id: 0, ok: true }), 0);
    }
}

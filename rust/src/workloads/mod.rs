//! Workload generators: the DeepSeek-V3 self-attention data-movement
//! workloads of Table II, and the synthetic sweeps of §IV-B/C.

pub mod synthetic;
pub mod table2;

pub use synthetic::random_dest_sets;
pub use table2::{AttnWorkload, Layout, Stage, TABLE2};

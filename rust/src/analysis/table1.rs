//! Table I: qualitative comparison of Torrent with SoTA DMAs and NoCs.

use crate::util::table::Table;

/// One comparison row.
#[derive(Debug, Clone)]
pub struct SotaRow {
    pub name: &'static str,
    pub arch: &'static str,
    pub addr_gen: &'static str,
    pub axi_compatible: &'static str,
    pub p2mp_method: &'static str,
    pub area_scaling: &'static str,
    pub open_sourced: &'static str,
}

/// The paper's Table I, Torrent first.
pub fn rows() -> Vec<SotaRow> {
    let row = |name, arch, addr_gen, axi_compatible, p2mp_method, area_scaling, open_sourced| {
        SotaRow { name, arch, addr_gen, axi_compatible, p2mp_method, area_scaling, open_sourced }
    };
    vec![
        row("Torrent", "Dist. DMA", "ND", "Yes", "Chainwrite", "~O(1)", "Yes"),
        row("Pulp XBar", "XBar", "N/A", "Yes", "Multicast", "~O(1)", "Yes"),
        row("ESP NoC", "NoC", "N/A", "No", "Multicast", "O(N)", "Yes"),
        row("FlexNoC", "NoC", "N/A", "Yes", "Multicast", "N/A", "No"),
        row("XDMA", "Dist. DMA", "ND", "Yes", "SW", "N/A", "Yes"),
        row("iDMA", "Mono. DMA", "ND", "Yes", "SW", "N/A", "Yes"),
        row("HyperDMA", "Dist. DMA", "ND", "No", "SW", "N/A", "No"),
        row("Xilinx DMA", "Mono. DMA", "1D", "Yes", "SW", "N/A", "No"),
    ]
}

/// Render Table I as ASCII.
pub fn render() -> String {
    let mut t = Table::new("Table I: Torrent comparison with SoTA DMAs and NoCs").header([
        "System",
        "Arch.",
        "Addr.Gen",
        "AXI-Comp.",
        "P2MP",
        "Area-Scaling",
        "Open-Source",
    ]);
    for r in rows() {
        t.row([
            r.name,
            r.arch,
            r.addr_gen,
            r.axi_compatible,
            r.p2mp_method,
            r.area_scaling,
            r.open_sourced,
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_systems_torrent_first() {
        let r = rows();
        assert_eq!(r.len(), 8);
        assert_eq!(r[0].name, "Torrent");
        assert_eq!(r[0].p2mp_method, "Chainwrite");
    }

    #[test]
    fn renders_all_rows() {
        let s = render();
        for r in rows() {
            assert!(s.contains(r.name), "missing {}", r.name);
        }
    }

    #[test]
    fn only_torrent_has_chainwrite() {
        assert_eq!(
            rows().iter().filter(|r| r.p2mp_method == "Chainwrite").count(),
            1
        );
    }
}

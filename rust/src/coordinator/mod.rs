//! Task-service coordinator: the framework layer a launcher talks to.
//!
//! The coordinator owns the simulated SoC and runs it as a *service*:
//! many P2MP tasks are in flight concurrently (per-initiator admission
//! queues feed the engines' own queues), tasks can depend on each other
//! (`P2mpRequest::after` edges form a DAG, released as dependencies
//! complete), and every engine is driven uniformly through the
//! [`dma::Engine`](crate::dma::Engine) trait — there is no per-engine
//! control flow here.
//!
//! The NoC fabric is selected at SoC construction
//! ([`SocConfig::with_topology`]: mesh, torus or ring) — requests are
//! fabric-agnostic, and chain-based engines re-derive their traversal
//! order from the fabric's own routes at dispatch.
//!
//! Submission is fallible ([`SubmitError`]) and returns a typed
//! [`TaskHandle`]; progress is observable via [`TaskStatus`]. Three run
//! modes cover the workloads the benches and examples need:
//!
//! * [`Coordinator::run_until_complete`] — drive one task to completion
//!   (others keep streaming);
//! * [`Coordinator::run_until_all_done`] — drive every submitted task to
//!   completion;
//! * [`Coordinator::run_to_completion`] — the quiescence drain: run
//!   until the whole SoC is idle (identical stepping to
//!   `Soc::run_until_idle`, so single-task figure drivers report
//!   byte- and cycle-identical numbers).
//!
//! ```
//! use torrent::coordinator::{Coordinator, EngineKind, P2mpRequest, TaskStatus};
//! use torrent::noc::NodeId;
//! use torrent::sched::Strategy;
//! use torrent::soc::SocConfig;
//!
//! let mut c = Coordinator::new(SocConfig::custom(3, 3, 64 * 1024));
//! // Stage 1: scatter 4 KB from cluster 0 to two clusters.
//! let a = c
//!     .submit(
//!         P2mpRequest::to(&[NodeId(1), NodeId(4)])
//!             .src(NodeId(0))
//!             .bytes(4096)
//!             .engine(EngineKind::Torrent(Strategy::Greedy)),
//!     )
//!     .expect("valid request");
//! // Stage 2: cluster 1 forwards onward once stage 1 is done.
//! let b = c
//!     .submit(
//!         P2mpRequest::to(&[NodeId(8)])
//!             .src(NodeId(1))
//!             .bytes(4096)
//!             .after(&[a]),
//!     )
//!     .expect("valid request");
//! assert_eq!(b.status(&c), TaskStatus::Queued); // dependency-blocked
//! c.run_until_all_done(1_000_000);
//! assert!(c.latency_of(a).is_some() && c.latency_of(b).is_some());
//! ```

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;

use crate::analysis::eta_p2mp;
use crate::dma::torrent::dse::AffinePattern;
use crate::dma::torrent::{ChainDest, ChainTask, ChainVias};
use crate::dma::xdma::XDMA_SUBTASK_BIT;
use crate::dma::{Engine as _, TaskPhase, TaskResult, TaskSpec};
use crate::noc::{Degraded, NodeId};
use crate::sched;
use crate::sim::Watchdog;
use crate::soc::{Soc, SocConfig};
use anyhow::anyhow;

pub use crate::dma::{EngineKind, SubmitError, SubmitErrorKind};

/// Coordinator-issued task identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskId(pub u32);

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "task#{}", self.0)
    }
}

/// Handle returned by submission: a copyable reference to one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskHandle {
    id: TaskId,
}

impl TaskHandle {
    pub fn id(&self) -> TaskId {
        self.id
    }

    /// Current lifecycle status on `c` (the coordinator that minted this
    /// handle).
    pub fn status(&self, c: &Coordinator) -> TaskStatus {
        c.status(*self).expect("handle minted by this coordinator")
    }

    /// Completion latency, if the task has finished.
    pub fn latency(&self, c: &Coordinator) -> Option<u64> {
        c.latency_of(*self)
    }
}

impl fmt::Display for TaskHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.id.fmt(f)
    }
}

impl From<TaskHandle> for TaskId {
    fn from(h: TaskHandle) -> TaskId {
        h.id
    }
}

/// Task lifecycle as observed from the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskStatus {
    /// Held in an admission queue behind unfinished dependencies.
    Queued,
    /// On an engine: queued there, decoding descriptors or programming
    /// the transfer (Chainwrite cfg/grant round trip, ESP router set).
    Configuring,
    /// Data or finish signalling in flight.
    Streaming,
    /// Completed; the [`Record`] holds the [`TaskResult`].
    Done,
    /// Stalled by a fault; replacement chains are streaming around the
    /// suspect hop (see [`TaskOutcome::Repairing`]).
    Degraded,
    /// Completed via repair, possibly serving only the destinations
    /// still reachable on the degraded fabric.
    Repaired,
    /// Closed without a result: unrepairable, repair disabled, or a
    /// dependency failed.
    Failed,
}

/// What the fault machinery decided about a task. `None` on every record
/// of a healthy run — the field (and the watchdog producing it) only
/// engage when the config carries a [`crate::sim::FaultPlan`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskOutcome {
    /// The original chain stalled; replacement chains scheduled over the
    /// degraded fabric are in flight.
    Repairing { suspect: NodeId },
    /// Replacement chains completed. `served` destinations got their
    /// data; `lost` were unreachable on the degraded fabric (dead, or no
    /// clean route from the source). The byte fields account the repair:
    /// `served_bytes` is the payload confirmed delivered (full size per
    /// served destination), `lost_bytes` the payload written off with
    /// the unreachable ones, and `restreamed_bytes` what the repair
    /// chains actually re-sent — strictly the undelivered tails when the
    /// fault plan arms `resume`, full payloads otherwise.
    Repaired {
        suspect: NodeId,
        served: usize,
        lost: Vec<NodeId>,
        served_bytes: u64,
        lost_bytes: u64,
        restreamed_bytes: u64,
    },
    /// The task is closed without completing. `suspect` names the hop
    /// the diagnosis blamed, when there was a chain to diagnose.
    Failed { suspect: Option<NodeId>, reason: String },
}

impl TaskStatus {
    /// Stable snake_case wire form, used verbatim in serve-sim JSON
    /// reports and CLI output (ISSUE 8 satellite) — additions are fine,
    /// renames are a report-schema break.
    pub fn as_str(self) -> &'static str {
        match self {
            TaskStatus::Queued => "queued",
            TaskStatus::Configuring => "configuring",
            TaskStatus::Streaming => "streaming",
            TaskStatus::Done => "done",
            TaskStatus::Degraded => "degraded",
            TaskStatus::Repaired => "repaired",
            TaskStatus::Failed => "failed",
        }
    }

    /// Every variant, for round-trip tests and report legends.
    pub const ALL: [TaskStatus; 7] = [
        TaskStatus::Queued,
        TaskStatus::Configuring,
        TaskStatus::Streaming,
        TaskStatus::Done,
        TaskStatus::Degraded,
        TaskStatus::Repaired,
        TaskStatus::Failed,
    ];
}

impl fmt::Display for TaskStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for TaskStatus {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::ALL
            .into_iter()
            .find(|t| t.as_str() == s)
            .ok_or_else(|| format!("unknown TaskStatus '{s}'"))
    }
}

impl TaskOutcome {
    /// Stable snake_case kind tag for reports ("repairing" /
    /// "repaired" / "failed"); the variant payload is detail, not
    /// identity, so the tag alone round-trips through report schemas.
    pub fn kind(&self) -> &'static str {
        match self {
            TaskOutcome::Repairing { .. } => "repairing",
            TaskOutcome::Repaired { .. } => "repaired",
            TaskOutcome::Failed { .. } => "failed",
        }
    }
}

impl fmt::Display for TaskOutcome {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TaskOutcome::Repairing { suspect } => {
                write!(f, "repairing (suspect {suspect:?})")
            }
            TaskOutcome::Repaired { suspect, served, lost, restreamed_bytes, .. } => write!(
                f,
                "repaired (suspect {suspect:?}, served {served}, lost {}, restreamed {restreamed_bytes} B)",
                lost.len()
            ),
            TaskOutcome::Failed { suspect, reason } => match suspect {
                Some(n) => write!(f, "failed (suspect {n:?}: {reason})"),
                None => write!(f, "failed ({reason})"),
            },
        }
    }
}

#[cfg(test)]
mod status_string_tests {
    use super::*;

    #[test]
    fn task_status_strings_round_trip() {
        for status in TaskStatus::ALL {
            let s = status.as_str();
            assert_eq!(s, s.to_lowercase(), "{status:?} form is not snake_case");
            assert!(!s.contains(' '), "{status:?} form contains spaces");
            assert_eq!(s.parse::<TaskStatus>().unwrap(), status);
            assert_eq!(status.to_string(), s);
        }
        assert!("not_a_status".parse::<TaskStatus>().is_err());
    }

    #[test]
    fn task_outcome_kind_and_display_are_stable() {
        let repairing = TaskOutcome::Repairing { suspect: NodeId(3) };
        let repaired = TaskOutcome::Repaired {
            suspect: NodeId(3),
            served: 2,
            lost: vec![NodeId(5)],
            served_bytes: 8192,
            lost_bytes: 4096,
            restreamed_bytes: 4096,
        };
        let failed =
            TaskOutcome::Failed { suspect: None, reason: "unreachable".to_string() };
        assert_eq!(repairing.kind(), "repairing");
        assert_eq!(repaired.kind(), "repaired");
        assert_eq!(failed.kind(), "failed");
        // Display leads with the kind tag so log lines grep by it.
        for o in [&repairing, &repaired, &failed] {
            assert!(o.to_string().starts_with(o.kind()), "{o}");
        }
        assert!(repaired.to_string().contains("served 2"));
        assert!(repaired.to_string().contains("restreamed 4096 B"));
        assert!(failed.to_string().contains("unreachable"));
    }
}

/// Typed result of [`Coordinator::run_to_completion`]: what happened to
/// every task the fault machinery touched. Empty (`is_clean`) on healthy
/// runs, so existing callers that ignore the return value see no change.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    /// Cycles spent inside this run call.
    pub cycles: u64,
    /// Tasks holding a clean (non-repaired) result when the run ended.
    pub completed: usize,
    /// Every fault-touched task with its terminal (or in-flight repair)
    /// outcome, in task-id order.
    pub outcomes: Vec<(TaskId, TaskOutcome)>,
}

impl RunReport {
    /// No task was touched by a fault.
    pub fn is_clean(&self) -> bool {
        self.outcomes.is_empty()
    }

    /// Tasks that completed through repair.
    pub fn repaired(&self) -> Vec<TaskId> {
        self.outcomes
            .iter()
            .filter(|(_, o)| matches!(o, TaskOutcome::Repaired { .. }))
            .map(|&(t, _)| t)
            .collect()
    }

    /// Tasks closed without a result.
    pub fn failed(&self) -> Vec<TaskId> {
        self.outcomes
            .iter()
            .filter(|(_, o)| matches!(o, TaskOutcome::Failed { .. }))
            .map(|&(t, _)| t)
            .collect()
    }
}

/// Re-chain `dests` around the damage in `deg`: repeatedly schedule the
/// remaining destinations (the same `sched::Strategy` machinery used at
/// dispatch, now fed the degraded topology) and cut each proposed chain
/// at its first dirty leg — physical XY/arc routes cannot detour, so a
/// leg whose route crosses a dead router or severed link would feed the
/// replacement stream straight back into the fault. Returns the clean
/// chains plus the destinations no clean chain can reach (dead nodes,
/// or no clean route from `src` at all).
///
/// "Clean" covers *every* route the Chainwrite protocol exercises for a
/// hop, not just the forward data leg: the cfg descriptor travels
/// directly `src -> hop`, data cuts through `prev -> hop`, and grant /
/// finish back-propagate `hop -> prev`. Under dimension-ordered routing
/// those are three different physical paths, so a chain is only viable
/// when all three are undamaged — a plan validated on data legs alone
/// can re-stall on a cfg or grant route the planner never looked at.
///
/// With `reroute` set a dirty leg may still be viable through a
/// waypoint candidate ([`Degraded::clean_route`]): each of the three
/// legs is resolved independently to its first clean route (the default
/// physical route first), and the chosen waypoints come back per hop as
/// [`ChainVias`] for the repair cfgs to carry. A hop is dropped only
/// when some leg has no clean candidate at all.
pub fn plan_repair_chains<T>(
    deg: &Degraded,
    strategy: sched::Strategy,
    src: NodeId,
    mut remaining: Vec<(NodeId, T)>,
    reroute: bool,
) -> (Vec<Vec<(NodeId, T, ChainVias)>>, Vec<NodeId>) {
    let mut chains = Vec::new();
    let mut lost = Vec::new();
    // First clean route for one leg: `Some(None)` = the default physical
    // route is clean, `Some(Some(via))` = detour through a waypoint,
    // `None` = no clean candidate exists.
    let leg = |from: NodeId, to: NodeId| -> Option<Option<NodeId>> {
        if reroute {
            deg.clean_route(from, to)
        } else {
            deg.path_is_clean(from, to).then_some(None)
        }
    };
    remaining.retain(|(n, _)| {
        let alive = deg.node_alive(*n);
        if !alive {
            lost.push(*n);
        }
        alive
    });
    while !remaining.is_empty() {
        let (_, ordered) = sched::schedule_pairs(strategy, deg, src, remaining);
        let mut chain: Vec<(NodeId, T, ChainVias)> = Vec::new();
        let mut rest: Vec<(NodeId, T)> = Vec::new();
        let mut prev = src;
        let mut broken = false;
        for (node, t) in ordered {
            // cfg src->node, data prev->node, grant/finish node->prev.
            let vias = if broken {
                None
            } else {
                (|| {
                    Some(ChainVias {
                        cfg: leg(src, node)?,
                        data: leg(prev, node)?,
                        back: leg(node, prev)?,
                    })
                })()
            };
            match vias {
                Some(v) => {
                    prev = node;
                    chain.push((node, t, v));
                }
                None if broken => rest.push((node, t)),
                None => {
                    broken = true;
                    if leg(src, node).is_none() || leg(node, src).is_none() {
                        // Even a one-hop chain needs cfg/data out
                        // (src->node) and grant/finish back (node->src);
                        // with no clean candidate in either direction the
                        // destination is unreachable.
                        lost.push(node);
                    } else {
                        rest.push((node, t));
                    }
                }
            }
        }
        if !chain.is_empty() {
            chains.push(chain);
        }
        // Each round either emits a chain or loses the head destination,
        // so `remaining` strictly shrinks and the loop terminates.
        remaining = rest;
    }
    (chains, lost)
}

/// A point-to-multipoint request, built fluently:
///
/// ```
/// use torrent::coordinator::{EngineKind, P2mpRequest};
/// use torrent::noc::NodeId;
/// let req = P2mpRequest::to(&[NodeId(1), NodeId(2)])
///     .src(NodeId(0))
///     .bytes(8 * 1024)
///     .engine(EngineKind::Idma);
/// ```
///
/// Two construction modes:
/// * **simple** — [`P2mpRequest::to`] names bare destination nodes; the
///   coordinator reads `bytes` from the source window base and writes to
///   the upper half of each destination window (requires `.bytes()`).
/// * **explicit** — [`P2mpRequest::to_patterns`] carries one write
///   pattern per destination and requires `.read()`.
///
/// In both modes `.src()` may be omitted when a read pattern is given:
/// the source is derived from the pattern's base address (the
/// "distributed" in distributed DMA — the engine that owns the data
/// serves the task, no central engine pulls it across the fabric
/// first).
///
/// `.after(&[handle])` adds dependency edges: the task is dispatched to
/// its engine only once every named task has completed.
#[derive(Debug)]
pub struct P2mpRequest {
    src: Option<NodeId>,
    read: Option<AffinePattern>,
    dest_nodes: Vec<NodeId>,
    dest_patterns: Vec<(NodeId, AffinePattern)>,
    bytes: Option<usize>,
    engine: EngineKind,
    with_data: bool,
    after: Vec<TaskId>,
}

impl P2mpRequest {
    fn empty(engine: EngineKind) -> Self {
        P2mpRequest {
            src: None,
            read: None,
            dest_nodes: Vec::new(),
            dest_patterns: Vec::new(),
            bytes: None,
            engine,
            with_data: false,
            after: Vec::new(),
        }
    }

    /// Simple mode: bare destination nodes (patterns resolved against
    /// the SoC map at submission). Default engine: Torrent/greedy.
    pub fn to(dests: &[NodeId]) -> Self {
        let mut req = Self::empty(EngineKind::Torrent(sched::Strategy::Greedy));
        req.dest_nodes = dests.to_vec();
        req
    }

    /// Explicit mode: destination (node, local write pattern) pairs.
    pub fn to_patterns<I>(dests: I) -> Self
    where
        I: IntoIterator<Item = (NodeId, AffinePattern)>,
    {
        let mut req = Self::empty(EngineKind::Torrent(sched::Strategy::Greedy));
        req.dest_patterns = dests.into_iter().collect();
        req
    }

    /// Initiator node. Optional whenever a read pattern is given (the
    /// owner of the pattern's base address serves the task).
    pub fn src(mut self, src: NodeId) -> Self {
        self.src = Some(src);
        self
    }

    /// Source DSE read pattern. Required in explicit mode.
    pub fn read(mut self, read: AffinePattern) -> Self {
        self.read = Some(read);
        self
    }

    /// Transfer size (simple mode).
    pub fn bytes(mut self, bytes: usize) -> Self {
        self.bytes = Some(bytes);
        self
    }

    /// Serving engine (default: Torrent with the greedy chain order).
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Move real bytes instead of phantom timing-only payloads.
    pub fn with_data(mut self, with_data: bool) -> Self {
        self.with_data = with_data;
        self
    }

    /// Dependency edges: dispatch only after all of `deps` complete.
    pub fn after<D: Into<TaskId> + Copy>(mut self, deps: &[D]) -> Self {
        self.after.extend(deps.iter().map(|&d| d.into()));
        self
    }
}

/// Submission record + (after completion) the result.
#[derive(Debug)]
pub struct Record {
    pub task: TaskId,
    pub engine: EngineKind,
    pub src: NodeId,
    pub n_dests: usize,
    pub bytes: usize,
    /// Dependency edges this task waited on.
    pub deps: Vec<TaskId>,
    /// Chain traversal order (Torrent engines, set at dispatch).
    pub chain_order: Option<Vec<NodeId>>,
    pub result: Option<TaskResult>,
    /// Fault verdict; `None` on every record of a healthy run.
    pub outcome: Option<TaskOutcome>,
    /// Repair rounds spent on this task.
    pub repairs: u32,
    /// Resolved-but-undispatched job (present while dependency-blocked).
    pending: Option<Pending>,
    /// Cycle the task reached its engine (repair latency bookkeeping).
    dispatched_at: u64,
    /// (read, ordered dests, with_data) kept for re-issue; only cloned
    /// when a fault plan is armed, so healthy runs pay nothing.
    repair_spec: Option<(AffinePattern, Vec<(NodeId, AffinePattern)>, bool)>,
    /// Router-activity counters per chain hop, snapshotted at dispatch —
    /// a hop still at its baseline when the watchdog fires never moved a
    /// flit for anyone, which corners fail-silent hops the structural
    /// checks cannot see.
    act_baseline: Option<Vec<u64>>,
    /// Heartbeat: (progress sum, cycle it last changed).
    hb: Option<(u64, u64)>,
    /// Engine ids of the repair chains currently in flight.
    repair_live: Vec<u32>,
    /// Latest finish cycle among completed repair chains.
    repair_finish: u64,
    /// Destinations written off by repair planning so far.
    lost_dests: Vec<NodeId>,
    /// Bytes the repair rounds re-streamed so far (payload submitted on
    /// replacement chains; tails only when `resume` is armed).
    restreamed: u64,
    /// Per-destination resume watermark: bytes confirmed delivered
    /// before the current repair round — the split base its live tail
    /// chain (if any) streams from.
    resume_mark: BTreeMap<NodeId, usize>,
    /// Destinations each live repair chain serves, so a completed chain
    /// can advance its members' watermarks to "fully delivered".
    repair_members: BTreeMap<u32, Vec<NodeId>>,
    /// Engine ids of the load-aware partition's sibling chains still in
    /// flight. Empty for single-chain tasks.
    part_live: Vec<u32>,
    /// Latest finish cycle among completed sibling chains — the parent
    /// task's finish once the last sibling lands.
    part_finish: u64,
    /// Width of the load-aware partition this task dispatched as
    /// (0 = single chain). Survives completion, unlike `part_live`.
    part_chains: usize,
}

/// A validated request waiting in an admission queue.
#[derive(Debug)]
struct Pending {
    read: AffinePattern,
    dests: Vec<(NodeId, AffinePattern)>,
    with_data: bool,
    drop_offset: u64,
}

impl Record {
    /// Number of sibling chains the load-aware partition pass split this
    /// task into at dispatch — `0` for a task dispatched as one chain.
    pub fn partition_width(&self) -> usize {
        self.part_chains
    }

    /// η_P2MP of the completed task (Eq. 1).
    pub fn eta(&self) -> Option<f64> {
        self.result
            .as_ref()
            .map(|r| eta_p2mp(self.n_dests, self.bytes, r.latency()))
    }
}

fn err(kind: SubmitErrorKind, e: anyhow::Error) -> SubmitError {
    SubmitError::new(kind, e)
}

/// The coordinator.
pub struct Coordinator {
    pub soc: Soc,
    next_task: u32,
    /// Submission records in task-id order; [`Coordinator::record`] is
    /// the O(1) accessor.
    pub records: Vec<Record>,
    /// `TaskId` → `records` index.
    index: BTreeMap<u32, usize>,
    /// Per-initiator admission queues: dependency-blocked tasks wait
    /// here until their last dependency completes.
    admission: BTreeMap<NodeId, VecDeque<u32>>,
    /// Submitted tasks without a collected result yet.
    open_tasks: usize,
    /// Engine completions matching no coordinator task (e.g. read-tunnel
    /// transfers submitted directly to a Torrent). XDMA-internal leg
    /// results are dropped, not kept here.
    pub orphan_results: Vec<TaskResult>,
    /// Repair-chain engine id → index of the record it is healing.
    repair_parent: BTreeMap<u32, usize>,
    /// Partition sibling-chain engine id → index of the parent record
    /// (load-aware k-way splits; see [`Coordinator::dispatch`]).
    part_parent: BTreeMap<u32, usize>,
    /// Fault plan armed: run the heartbeat watchdog between quanta.
    fault_watch: bool,
}

/// Repair rounds allowed per task before the coordinator gives up — the
/// idempotence backstop: a fault storm cannot make it re-issue forever.
const MAX_REPAIRS: u32 = 3;

impl Coordinator {
    pub fn new(cfg: SocConfig) -> Self {
        Self::from_soc(Soc::new(cfg))
    }

    /// Coordinator over a SoC stepped in an explicit `sim::StepMode`
    /// (differential tests and the stepping benches; the default is the
    /// activity-tracked event-driven stepper).
    pub fn with_step_mode(cfg: SocConfig, mode: crate::sim::StepMode) -> Self {
        Self::from_soc(Soc::with_step_mode(cfg, mode))
    }

    fn from_soc(soc: Soc) -> Self {
        let fault_watch = !soc.cfg.faults.is_empty();
        Coordinator {
            soc,
            next_task: 1,
            records: Vec::new(),
            index: BTreeMap::new(),
            admission: BTreeMap::new(),
            open_tasks: 0,
            orphan_results: Vec::new(),
            repair_parent: BTreeMap::new(),
            part_parent: BTreeMap::new(),
            fault_watch,
        }
    }

    // ------------------------------------------------------------------
    // Submission
    // ------------------------------------------------------------------

    /// Submit a request. Validation happens here — engines never see a
    /// malformed job — and eligible tasks (no unfinished dependencies)
    /// are dispatched to their engine in the same cycle, so single-task
    /// timing is identical to submitting to the engine directly.
    pub fn submit(&mut self, req: P2mpRequest) -> Result<TaskHandle, SubmitError> {
        let P2mpRequest { src, read, dest_nodes, dest_patterns, bytes, engine, with_data, after } =
            req;
        let n_nodes = self.soc.cfg.n_nodes();
        // Bounds-check a node *before* it reaches `AddrMap::base_of`
        // (which asserts) — malformed requests must error, not panic.
        let in_mesh =
            |n: NodeId, kind: SubmitErrorKind, what: &str| -> Result<NodeId, SubmitError> {
                if n.0 < n_nodes {
                    Ok(n)
                } else {
                    Err(err(kind, anyhow!("{what} {n:?} outside the {n_nodes}-node fabric")))
                }
            };
        // A source can also be derived from the read pattern's base — the
        // engine attached to the memory that owns the data serves the
        // task (`submit_auto` semantics; no src needed in either mode).
        let resolve_src = |src: Option<NodeId>,
                           read: Option<&AffinePattern>|
         -> Result<NodeId, SubmitError> {
            match (src, read) {
                (Some(s), _) => in_mesh(s, SubmitErrorKind::UnmappedAddress, "source"),
                (None, Some(r)) => self.soc.map.node_of(r.base).ok_or_else(|| {
                    err(
                        SubmitErrorKind::UnmappedAddress,
                        anyhow!("source address {:#x} outside the SoC map", r.base),
                    )
                }),
                (None, None) => Err(err(
                    SubmitErrorKind::Underspecified,
                    anyhow!("request needs .src() (or .read() to derive the owner from)"),
                )),
            }
        };

        // --- resolve source, read pattern and destination patterns ---
        let explicit = !dest_patterns.is_empty();
        let (src, read, dests) = if explicit {
            let read = read.ok_or_else(|| {
                err(
                    SubmitErrorKind::Underspecified,
                    anyhow!("explicit destination patterns need a read pattern"),
                )
            })?;
            if let Some(b) = bytes.filter(|&b| b != read.total_bytes()) {
                return Err(err(
                    SubmitErrorKind::SizeMismatch,
                    anyhow!(".bytes({b}) conflicts with a {} B read pattern", read.total_bytes()),
                ));
            }
            let src = resolve_src(src, Some(&read))?;
            for (node, _) in &dest_patterns {
                in_mesh(*node, SubmitErrorKind::InvalidDestinations, "destination")?;
            }
            (src, read, dest_patterns)
        } else {
            if dest_nodes.is_empty() {
                return Err(err(
                    SubmitErrorKind::EmptyDestinations,
                    anyhow!("request names no destinations"),
                ));
            }
            let bytes = bytes.ok_or_else(|| {
                err(SubmitErrorKind::Underspecified, anyhow!("simple requests need .bytes()"))
            })?;
            let half = self.soc.cfg.spm_bytes as u64 / 2;
            if bytes as u64 > half {
                return Err(err(
                    SubmitErrorKind::TooLarge,
                    anyhow!(
                        "{bytes} B does not fit half a {} B scratchpad",
                        self.soc.cfg.spm_bytes
                    ),
                ));
            }
            let src = resolve_src(src, read.as_ref())?;
            for &d in &dest_nodes {
                in_mesh(d, SubmitErrorKind::InvalidDestinations, "destination")?;
            }
            let read = match read {
                Some(r) => {
                    if r.total_bytes() != bytes {
                        return Err(err(
                            SubmitErrorKind::SizeMismatch,
                            anyhow!(
                                "read pattern covers {} B, .bytes() says {bytes}",
                                r.total_bytes()
                            ),
                        ));
                    }
                    r
                }
                None => AffinePattern::contiguous(self.soc.map.base_of(src), bytes),
            };
            let dests = dest_nodes
                .iter()
                .map(|&d| {
                    (d, AffinePattern::contiguous(self.soc.map.base_of(d) + half, bytes))
                })
                .collect();
            (src, read, dests)
        };

        // --- shared validation (both branches produce non-empty,
        // in-mesh destination sets) ---
        if read.total_bytes() == 0 {
            return Err(err(
                SubmitErrorKind::EmptyTransfer,
                anyhow!("request moves zero bytes"),
            ));
        }
        let mut seen = BTreeSet::new();
        for (node, pattern) in &dests {
            if *node == src || !seen.insert(*node) {
                return Err(err(
                    SubmitErrorKind::InvalidDestinations,
                    anyhow!("destination {node:?} repeats or names the source"),
                ));
            }
            if pattern.total_bytes() != read.total_bytes() {
                return Err(err(
                    SubmitErrorKind::SizeMismatch,
                    anyhow!(
                        "destination {node:?} pattern covers {} B, read covers {} B",
                        pattern.total_bytes(),
                        read.total_bytes()
                    ),
                ));
            }
        }
        // Multicast drops one contiguous block at the same window-local
        // offset everywhere (per-destination write *patterns* are a
        // distributed-DMA capability the router-replication baseline
        // lacks) — every destination pattern must agree, or the engine
        // would silently write where the caller never asked.
        let drop_offset = if engine == EngineKind::Mcast {
            let (n0, p0) = &dests[0];
            let off = p0.base.checked_sub(self.soc.map.base_of(*n0)).ok_or_else(|| {
                err(
                    SubmitErrorKind::UnmappedAddress,
                    anyhow!("destination pattern base {:#x} below {n0:?}'s window", p0.base),
                )
            })?;
            for (n, p) in &dests {
                let same_offset = p.base.checked_sub(self.soc.map.base_of(*n)) == Some(off);
                if !same_offset || p.runs().len() != 1 {
                    return Err(err(
                        SubmitErrorKind::InvalidDestinations,
                        anyhow!(
                            "multicast writes one contiguous block at a shared window-local \
                             offset ({off:#x}); {n:?}'s pattern differs"
                        ),
                    ));
                }
            }
            off
        } else {
            0
        };
        for d in &after {
            if !self.index.contains_key(&d.0) {
                return Err(err(
                    SubmitErrorKind::UnknownDependency,
                    anyhow!("dependency {d} was never submitted here"),
                ));
            }
        }

        // --- admit ---
        let id = TaskId(self.next_task);
        self.next_task += 1;
        debug_assert!(id.0 & XDMA_SUBTASK_BIT == 0, "task id space exhausted");
        self.index.insert(id.0, self.records.len());
        self.records.push(Record {
            task: id,
            engine,
            src,
            n_dests: dests.len(),
            bytes: read.total_bytes(),
            deps: after,
            chain_order: None,
            result: None,
            outcome: None,
            repairs: 0,
            pending: Some(Pending { read, dests, with_data, drop_offset }),
            dispatched_at: 0,
            repair_spec: None,
            act_baseline: None,
            hb: None,
            repair_live: Vec::new(),
            repair_finish: 0,
            lost_dests: Vec::new(),
            restreamed: 0,
            resume_mark: BTreeMap::new(),
            repair_members: BTreeMap::new(),
            part_live: Vec::new(),
            part_finish: 0,
            part_chains: 0,
        });
        self.open_tasks += 1;
        // Fast path: a task with no unfinished dependencies goes straight
        // to its engine (same cycle as the submission). Only blocked
        // tasks enter the admission queue.
        let idx = self.records.len() - 1;
        if self.deps_ready(idx) {
            self.dispatch(idx);
        } else {
            self.admission.entry(src).or_default().push_back(id.0);
        }
        Ok(TaskHandle { id })
    }

    /// Route a request to the initiator that owns the source data,
    /// whatever `.src()` said: the Torrent attached to the memory the
    /// read pattern resolves to serves the task.
    pub fn submit_auto(&mut self, mut req: P2mpRequest) -> Result<TaskHandle, SubmitError> {
        req.src = None;
        self.submit(req)
    }

    /// Convenience: contiguous `bytes` from `src`'s window to the upper
    /// half of each destination window.
    pub fn submit_simple(
        &mut self,
        src: NodeId,
        dests: &[NodeId],
        bytes: usize,
        engine: EngineKind,
        with_data: bool,
    ) -> Result<TaskHandle, SubmitError> {
        self.submit(
            P2mpRequest::to(dests).src(src).bytes(bytes).engine(engine).with_data(with_data),
        )
    }

    // ------------------------------------------------------------------
    // Scheduler
    // ------------------------------------------------------------------

    /// All of a record's dependencies have completed.
    fn deps_ready(&self, idx: usize) -> bool {
        self.records[idx]
            .deps
            .iter()
            .all(|d| self.records[self.index[&d.0]].result.is_some())
    }

    /// A dependency of this record can never complete (failed terminal
    /// outcome without a result).
    fn dep_failed(&self, idx: usize) -> bool {
        self.records[idx].deps.iter().any(|d| {
            let dep = &self.records[self.index[&d.0]];
            dep.result.is_none() && matches!(dep.outcome, Some(TaskOutcome::Failed { .. }))
        })
    }

    /// Release dependency edges: dispatch every admitted task whose
    /// dependencies have all completed, in deterministic (initiator,
    /// FIFO) order. Independent tasks bypass dependency-blocked ones, so
    /// one stalled DAG branch never serializes the rest of an
    /// initiator's queue. Called only when a completion was observed —
    /// eligibility cannot change otherwise. Tasks behind a *failed*
    /// dependency are closed as failed themselves (repeating until a
    /// fixpoint covers transitive chains), so a fault never wedges the
    /// admission queue.
    fn dispatch_ready(&mut self) {
        loop {
            let mut changed = false;
            let nodes: Vec<NodeId> = self.admission.keys().copied().collect();
            for n in nodes {
                let ids: Vec<u32> = self.admission[&n].iter().copied().collect();
                let mut blocked = VecDeque::new();
                for id in ids {
                    let idx = self.index[&id];
                    if self.dep_failed(idx) {
                        let rec = &mut self.records[idx];
                        rec.pending = None;
                        rec.outcome = Some(TaskOutcome::Failed {
                            suspect: None,
                            reason: "dependency failed".into(),
                        });
                        self.open_tasks -= 1;
                        changed = true;
                    } else if self.deps_ready(idx) {
                        self.dispatch(idx);
                        changed = true;
                    } else {
                        blocked.push_back(id);
                    }
                }
                if blocked.is_empty() {
                    self.admission.remove(&n);
                } else {
                    *self.admission.get_mut(&n).unwrap() = blocked;
                }
            }
            if !changed {
                return;
            }
        }
    }

    /// Hand one admitted task to its engine. Chain-based engines get
    /// their destinations pre-ordered by the `sched::Strategy` here; the
    /// resolved request moves into the engine by value (no re-clone of
    /// read/write patterns).
    fn dispatch(&mut self, idx: usize) {
        let Pending { read, dests, with_data, drop_offset } =
            self.records[idx].pending.take().expect("task dispatched twice");
        let (task, engine, src) =
            (self.records[idx].task.0, self.records[idx].engine, self.records[idx].src);
        let dests = if let EngineKind::Torrent(strategy) = engine {
            let topo = self.soc.topo();
            // Load-aware scheduling observes the fabric at dispatch time:
            // the snapshot folds the directed-link counters into windowed
            // EWMA occupancies. Static strategies never take the snapshot,
            // so their dispatch stays byte-identical to before.
            let load =
                (strategy == sched::Strategy::LoadAware).then(|| self.soc.net.load_view());
            let (order, ordered) =
                sched::schedule_pairs_with_load(strategy, &topo, src, dests, load.as_ref());
            // Partition pass: when the snapshot predicts k concurrent
            // sub-chains beat the best single chain, dispatch the split
            // as sibling ChainTasks that jointly complete this record.
            // `drop_offset` arms a single-chain payload fault the split
            // could not carry — keep the single chain in that case.
            if let Some(view) = load.as_ref() {
                if drop_offset == 0 {
                    let parts = sched::partition_chains(&topo, src, &order, view);
                    if parts.len() > 1 {
                        return self
                            .dispatch_partitioned(idx, read, order, ordered, parts, with_data);
                    }
                }
            }
            self.records[idx].chain_order = Some(order);
            ordered
        } else {
            dests
        };
        let now = self.soc.cycle();
        self.records[idx].dispatched_at = now;
        if self.fault_watch {
            if let EngineKind::Torrent(_) = engine {
                // Keep what repair needs: the resolved job for re-issue,
                // and each chain hop's activity counter as the diagnosis
                // baseline.
                self.records[idx].act_baseline = self.records[idx]
                    .chain_order
                    .as_ref()
                    .map(|ch| ch.iter().map(|&h| self.soc.net.router_activity(h)).collect());
                self.records[idx].repair_spec = Some((read.clone(), dests.clone(), with_data));
            }
        }
        self.soc.nodes[src.0]
            .engine_mut(engine)
            .submit(TaskSpec { task, read, dests, with_data, drop_offset }, now)
            .expect("request validated at submission");
    }

    /// Dispatch a load-aware split as `k` sibling chains with fresh
    /// engine ids (like repair chains, submitted as `ChainTask`s
    /// directly). The parent record completes when the last sibling
    /// lands ([`Coordinator::collect_and_dispatch`]) with a synthesized
    /// result spanning dispatch to the latest sibling finish — dependency
    /// edges therefore release only after *every* destination was
    /// served, exactly as for a single chain.
    fn dispatch_partitioned(
        &mut self,
        idx: usize,
        read: AffinePattern,
        order: Vec<NodeId>,
        ordered: Vec<(NodeId, AffinePattern)>,
        parts: Vec<Vec<NodeId>>,
        with_data: bool,
    ) {
        let src = self.records[idx].src;
        let now = self.soc.cycle();
        self.records[idx].dispatched_at = now;
        if self.fault_watch {
            self.records[idx].act_baseline =
                Some(order.iter().map(|&h| self.soc.net.router_activity(h)).collect());
            self.records[idx].repair_spec = Some((read.clone(), ordered.clone(), with_data));
        }
        self.records[idx].chain_order = Some(order);
        self.records[idx].part_chains = parts.len();
        let mut rest = ordered;
        for part in parts {
            // Segments are contiguous slices of the visit order, so the
            // keyed pairs split at the same boundaries.
            let tail = rest.split_off(part.len());
            let seg = std::mem::replace(&mut rest, tail);
            debug_assert!(
                seg.iter().map(|(n, _)| *n).eq(part.iter().copied()),
                "partition segments must tile the visit order"
            );
            let pid = self.next_task;
            self.next_task += 1;
            debug_assert!(pid & XDMA_SUBTASK_BIT == 0, "task id space exhausted");
            self.records[idx].part_live.push(pid);
            self.part_parent.insert(pid, idx);
            let cdests: Vec<ChainDest> = seg
                .into_iter()
                .map(|(node, pattern)| ChainDest { node, pattern, vias: ChainVias::default() })
                .collect();
            self.soc.nodes[src.0]
                .torrent
                .submit(ChainTask { task: pid, read: read.clone(), dests: cdests, with_data }, now);
        }
        debug_assert!(rest.is_empty(), "every ordered destination joined a segment");
    }

    /// Synchronize records with engine state: drain completions and
    /// release dependency edges. The run modes call this between
    /// stepping quanta; call it manually after driving `self.soc`
    /// directly (e.g. `soc.run_until_idle`) so `record`/`latency_of`
    /// see the results.
    pub fn collect(&mut self) {
        self.collect_and_dispatch();
    }

    /// Drain engine completions into the records; release dependency
    /// edges and dispatch newly eligible tasks.
    fn collect_and_dispatch(&mut self) {
        let mut completed = false;
        for node in &mut self.soc.nodes {
            for engine in node.engines_mut() {
                for res in engine.drain_results() {
                    if let Some(&pidx) = self.repair_parent.get(&res.task) {
                        // A repair chain finished. When the last live one
                        // lands, the parent task completes as Repaired
                        // with a synthesized result spanning original
                        // dispatch to the final repair finish.
                        self.repair_parent.remove(&res.task);
                        let rec = &mut self.records[pidx];
                        rec.repair_live.retain(|&t| t != res.task);
                        rec.repair_finish = rec.repair_finish.max(res.finished_at);
                        if let Some(members) = rec.repair_members.remove(&res.task) {
                            // A finished chain's destinations hold their
                            // full payload: a later repair round must not
                            // re-stream them.
                            for n in members {
                                rec.resume_mark.insert(n, rec.bytes);
                            }
                        }
                        if rec.repair_live.is_empty() && rec.result.is_none() {
                            let mut lost = std::mem::take(&mut rec.lost_dests);
                            lost.sort_unstable_by_key(|n| n.0);
                            lost.dedup();
                            let suspect = match rec.outcome {
                                Some(TaskOutcome::Repairing { suspect }) => suspect,
                                _ => rec.src,
                            };
                            let served = rec.n_dests - lost.len();
                            rec.result = Some(TaskResult {
                                task: rec.task.0,
                                submitted_at: rec.dispatched_at,
                                finished_at: rec.repair_finish,
                                bytes: rec.bytes,
                                n_dests: served,
                            });
                            let lost_bytes = lost.len() as u64 * rec.bytes as u64;
                            rec.outcome = Some(TaskOutcome::Repaired {
                                suspect,
                                served,
                                lost,
                                served_bytes: served as u64 * rec.bytes as u64,
                                lost_bytes,
                                restreamed_bytes: rec.restreamed,
                            });
                            self.open_tasks -= 1;
                            completed = true;
                        }
                        continue;
                    }
                    if let Some(&pidx) = self.part_parent.get(&res.task) {
                        // A partition sibling finished. When the last
                        // live one lands, the parent task completes with
                        // a result spanning its dispatch to the latest
                        // sibling finish — the same join the repair path
                        // uses, minus any outcome (a healthy split is
                        // not a fault).
                        self.part_parent.remove(&res.task);
                        let rec = &mut self.records[pidx];
                        rec.part_live.retain(|&t| t != res.task);
                        rec.part_finish = rec.part_finish.max(res.finished_at);
                        if rec.part_live.is_empty() && rec.result.is_none() {
                            rec.result = Some(TaskResult {
                                task: rec.task.0,
                                submitted_at: rec.dispatched_at,
                                finished_at: rec.part_finish,
                                bytes: rec.bytes,
                                n_dests: rec.n_dests,
                            });
                            self.open_tasks -= 1;
                            completed = true;
                        }
                        continue;
                    }
                    match self.index.get(&res.task) {
                        Some(&i) if self.records[i].result.is_none() => {
                            self.records[i].result = Some(res);
                            self.open_tasks -= 1;
                            completed = true;
                        }
                        _ => {
                            // Engine-internal legs are bookkeeping only;
                            // anything else (direct read tunnels) is kept
                            // for the caller.
                            if res.task & XDMA_SUBTASK_BIT == 0 {
                                self.orphan_results.push(res);
                            }
                        }
                    }
                }
            }
        }
        if completed {
            self.dispatch_ready();
        }
    }

    /// The scheduler loop shared by every run mode: step the SoC one
    /// quantum at a time (identical stepping to `Soc::run_until_idle`),
    /// collecting completions and releasing dependencies between quanta.
    fn run_scheduler(
        &mut self,
        max_cycles: u64,
        label: &'static str,
        mut done: impl FnMut(&Coordinator) -> bool,
    ) {
        let start = self.soc.cycle();
        let dog = Watchdog::new(max_cycles, label);
        self.collect_and_dispatch();
        while !done(self) {
            self.soc.step_quantum(start, max_cycles);
            self.collect_and_dispatch();
            if self.fault_watch {
                self.watch_faults();
            }
            dog.check(self.soc.cycle() - start);
        }
    }

    /// Run until every engine and the fabric drain (the quiescence
    /// drain). Panics via `sim::Watchdog` after `max_cycles` — including
    /// when a dependency can never be released.
    ///
    /// Returns a [`RunReport`]: on a healthy run it is empty
    /// ([`RunReport::is_clean`]); under an armed
    /// [`crate::sim::FaultPlan`], stalled tasks are detected by the
    /// heartbeat watchdog, diagnosed to a suspect hop, and either
    /// re-chained around the damage or closed as
    /// [`TaskStatus::Failed`] — the report names each such task and its
    /// [`TaskOutcome`] instead of hanging until the cycle watchdog.
    pub fn run_to_completion(&mut self, max_cycles: u64) -> RunReport {
        let start = self.soc.cycle();
        self.run_scheduler(max_cycles, "soc.quiesce", |c| {
            c.admission.is_empty() && c.soc.is_idle()
        });
        let mut report = RunReport {
            cycles: self.soc.cycle() - start,
            ..RunReport::default()
        };
        for rec in &self.records {
            match &rec.outcome {
                Some(o) => report.outcomes.push((rec.task, o.clone())),
                None if rec.result.is_some() => report.completed += 1,
                None => {}
            }
        }
        report
    }

    /// Run until every submitted task has completed (trailing fabric
    /// activity may remain; follow with [`Coordinator::run_to_completion`]
    /// to drain it).
    pub fn run_until_all_done(&mut self, max_cycles: u64) {
        self.run_scheduler(max_cycles, "coordinator.all_done", |c| c.open_tasks == 0);
    }

    /// Advance the system exactly `cycles` cycles — the coordinator half
    /// of the bounded-horizon run API (ISSUE 8). Unlike the quiescence
    /// drains above, this neither requires nor expects idleness: an
    /// open-loop driver (see [`crate::serve`]) calls it between arrival
    /// injections. Completions are collected and dependency edges
    /// released after every executed tick, and the fault heartbeat runs
    /// when armed, so task lifecycle timing is identical to an
    /// uninterrupted [`Coordinator::run_to_completion`] over the same
    /// cycles. Bit-identical across [`crate::sim::StepMode`]s: the
    /// underlying [`Soc::step_toward`] lands every mode on the same
    /// horizon, and a tick that produces a completion is never
    /// fast-forwarded over (an active engine reports `next_event = now`),
    /// so collection fires at the same cycles in all modes. Returns the
    /// new cycle.
    pub fn run_for(&mut self, cycles: u64) -> u64 {
        let target = self.soc.cycle() + cycles;
        self.collect_and_dispatch();
        while self.soc.cycle() < target {
            self.soc.step_toward(target);
            self.collect_and_dispatch();
            if self.fault_watch {
                self.watch_faults();
            }
        }
        self.soc.cycle()
    }

    /// Run until `task` completes; other in-flight tasks keep streaming.
    /// Returns the task's latency. Panics if a fault closes the task as
    /// [`TaskStatus::Failed`] — a failed task has no latency.
    pub fn run_until_complete(&mut self, task: impl Into<TaskId>, max_cycles: u64) -> u64 {
        let id = task.into();
        assert!(self.index.contains_key(&id.0), "{id} was never submitted here");
        self.run_scheduler(max_cycles, "coordinator.task", |c| {
            c.record(id).is_some_and(|r| {
                r.result.is_some() || matches!(r.outcome, Some(TaskOutcome::Failed { .. }))
            })
        });
        self.latency_of(id)
            .unwrap_or_else(|| panic!("{id} failed under fault injection: no latency"))
    }

    // ------------------------------------------------------------------
    // Fault detection and repair
    // ------------------------------------------------------------------

    /// One heartbeat pass (called between stepping quanta when a fault
    /// plan is armed, and exposed for the repair test suite): each
    /// dispatched, non-terminal task's progress ordinal — summed across
    /// every engine on every live node — must change within
    /// `detect_timeout` cycles, or the task is declared stalled and
    /// handed to [`Coordinator::diagnose`]/repair.
    ///
    /// Inert until the first fault activates: both step modes reach the
    /// activation cycle in lockstep, so heartbeat trajectories — and
    /// therefore repair timing — stay bit-identical between
    /// `EventDriven` and `FullTick` runs.
    pub fn watch_faults(&mut self) {
        if !self.soc.any_fault_active() {
            return;
        }
        let now = self.soc.cycle();
        let timeout = self.soc.cfg.faults.detect_timeout;
        for idx in 0..self.records.len() {
            let rec = &self.records[idx];
            if rec.result.is_some()
                || rec.pending.is_some()
                || matches!(
                    rec.outcome,
                    Some(TaskOutcome::Failed { .. }) | Some(TaskOutcome::Repaired { .. })
                )
            {
                continue;
            }
            let sum = self.progress_sum(idx);
            let hb = self.records[idx].hb;
            match hb {
                Some((v, since)) if v == sum => {
                    if now.saturating_sub(since) >= timeout {
                        self.handle_stall(idx, now);
                    }
                }
                _ => self.records[idx].hb = Some((sum, now)),
            }
        }
    }

    /// Progress ordinal for a task: engine-reported progress folded over
    /// every live node. Changes every few tens of cycles while the
    /// protocol advances (cfg decode, grant/finish hops, per-flit gate
    /// counters); freezing for a full detection window means the chain is
    /// dead, not slow. Repairing tasks are tracked through their live
    /// repair-chain ids (the original id was cancelled).
    fn progress_sum(&self, idx: usize) -> u64 {
        let rec = &self.records[idx];
        let mut sum = 0u64;
        let ids: &[u32] = if !rec.repair_live.is_empty() {
            &rec.repair_live
        } else if !rec.part_live.is_empty() {
            // A partitioned task's engine state lives under its sibling
            // ids; the parent id never reached an engine.
            &rec.part_live
        } else {
            std::slice::from_ref(&rec.task.0)
        };
        for (i, node) in self.soc.nodes.iter().enumerate() {
            if self.soc.node_dropped(NodeId(i)) {
                continue;
            }
            for engine in node.engines() {
                for &tid in ids {
                    if let Some(p) = engine.progress_of(tid) {
                        // Mix in a presence mark so "state vanished" and
                        // "state at zero" differ.
                        sum = sum.wrapping_add(p).wrapping_add(0x9e37_79b9_97f4_a7c1);
                    }
                }
            }
        }
        sum
    }

    /// Name the hop that killed a stalled chain. Checks, in order of
    /// confidence: a structurally dead or dropped hop (including the
    /// source), the first chain leg whose physical route crosses the
    /// damage, a hop whose engine lost the task entirely (fail-silent
    /// drop before the cfg landed), and finally a hop whose router
    /// activity counter never moved off its dispatch baseline — it never
    /// forwarded a flit for anyone. `None` for tasks with no chain (non-
    /// Torrent engines).
    pub fn diagnose(&self, task: impl Into<TaskId>) -> Option<NodeId> {
        let rec = self.record(task)?;
        let chain = rec.chain_order.as_ref()?;
        let deg = self.soc.net.degraded_topology();
        let src = rec.src;
        if !deg.node_alive(src) || self.soc.node_dropped(src) {
            return Some(src);
        }
        for &h in chain {
            if !deg.node_alive(h) || self.soc.node_dropped(h) {
                return Some(h);
            }
        }
        let mut prev = src;
        for &h in chain {
            // A hop's protocol routes: cfg src->h, data prev->h,
            // grant/finish h->prev (three distinct physical paths under
            // dimension-ordered routing).
            if !deg.path_is_clean(src, h)
                || !deg.path_is_clean(prev, h)
                || !deg.path_is_clean(h, prev)
            {
                return Some(h);
            }
            prev = h;
        }
        if rec.outcome.is_none() && rec.part_live.is_empty() {
            // Engine-level evidence only applies before a repair (cancel
            // wipes task state everywhere, which would finger hop 0) and
            // to single chains — a partitioned task's engine state lives
            // under sibling ids, not `rec.task`.
            for &h in chain {
                if self.soc.nodes[h.0].torrent.progress_of(rec.task.0).is_none() {
                    return Some(h);
                }
            }
            if let Some(base) = &rec.act_baseline {
                for (i, &h) in chain.iter().enumerate() {
                    if self.soc.net.router_activity(h) == base[i] {
                        return Some(h);
                    }
                }
            }
        }
        chain.last().copied()
    }

    /// A task's heartbeat flatlined: cancel the wreck everywhere, then
    /// either re-chain the still-reachable destinations over the degraded
    /// fabric (fresh engine ids — the cancelled id's stale traffic is
    /// swallowed by the engines) or close the task as failed.
    ///
    /// With `resume` armed the delivered prefix of every survivor is
    /// kept — buffered bytes are salvaged into its scratchpad before the
    /// cancel wipes them — and only the undelivered tail is re-streamed.
    /// With `reroute` armed a hop whose default route is fault-dirty may
    /// still be chained through a clean waypoint candidate (see
    /// [`plan_repair_chains`]).
    fn handle_stall(&mut self, idx: usize, now: u64) {
        let task = self.records[idx].task;
        let suspect = self.diagnose(task);
        let resume = self.soc.cfg.faults.resume;
        let reroute = self.soc.cfg.faults.reroute;
        let mut ids = vec![task.0];
        ids.extend(self.records[idx].repair_live.drain(..));
        ids.extend(self.records[idx].part_live.drain(..));
        // Resume: read back each survivor's delivery watermark — and
        // salvage buffered-but-unscattered prefixes into its scratchpad —
        // BEFORE the cancel below wipes the follower state. Marks from a
        // repair chain are relative to that chain's tail and rebased
        // onto the recorded watermark when grouping.
        let mut fresh_marks: BTreeMap<NodeId, usize> = BTreeMap::new();
        if resume {
            if let Some((_, dests, with_data)) = &self.records[idx].repair_spec {
                let with_data = *with_data;
                for (dn, _) in dests {
                    let n = &mut self.soc.nodes[dn.0];
                    let mut got = 0usize;
                    for &tid in &ids {
                        let m = if with_data {
                            n.torrent.salvage(tid, &mut n.mem)
                        } else {
                            n.torrent.follower_watermark(tid).unwrap_or(0)
                        };
                        got = got.max(m);
                    }
                    if got > 0 {
                        fresh_marks.insert(*dn, got);
                    }
                }
            }
        }
        // Tear down engine state for the stalled ids on every node, so
        // the fabric can drain and a replacement cannot double-report.
        for id in &ids {
            self.repair_parent.remove(id);
            self.part_parent.remove(id);
            self.records[idx].repair_members.remove(id);
        }
        for node in &mut self.soc.nodes {
            for engine in node.engines_mut() {
                for &tid in &ids {
                    engine.cancel(tid);
                }
            }
        }
        let (engine, src, repairs) =
            (self.records[idx].engine, self.records[idx].src, self.records[idx].repairs);
        let strategy = match engine {
            EngineKind::Torrent(s) => s,
            _ => {
                return self.fail(idx, suspect, "engine cannot re-chain");
            }
        };
        if !self.soc.cfg.faults.repair {
            return self.fail(idx, suspect, "repair disabled (norepair)");
        }
        if repairs >= MAX_REPAIRS {
            return self.fail(idx, suspect, "repair budget exhausted");
        }
        if self.soc.node_dropped(src) || self.soc.net.router_dead(src) {
            return self.fail(idx, suspect, "initiator lost");
        }
        let Some((read, dests, with_data)) = self.records[idx].repair_spec.clone() else {
            return self.fail(idx, suspect, "no repair spec recorded");
        };
        // Survivors: drop destinations whose engine complex is gone
        // (their data can never land), then chain the rest around the
        // fabric damage.
        let mut lost_now = Vec::new();
        let dests: Vec<(NodeId, AffinePattern)> = dests
            .into_iter()
            .filter(|(n, _)| {
                let dead = self.soc.node_dropped(*n);
                if dead {
                    lost_now.push(*n);
                }
                !dead
            })
            .collect();
        // Partition survivors by resumable watermark: the bytes already
        // confirmed delivered, floored (to a fixpoint) to a boundary both
        // the read and that destination's write pattern can split at — a
        // partial block re-streams; the overlapping re-write is
        // idempotent. Destinations already holding their full payload
        // (the stall was in the finish back-prop) are served without
        // re-streaming anything.
        let total = read.total_bytes();
        let mut groups: BTreeMap<usize, Vec<(NodeId, AffinePattern)>> = BTreeMap::new();
        let mut fully_served = 0usize;
        for (n, pat) in dests {
            let mut k = 0usize;
            if resume {
                let base = self.records[idx].resume_mark.get(&n).copied().unwrap_or(0);
                k = (base + fresh_marks.get(&n).copied().unwrap_or(0)).min(total);
                loop {
                    let k2 = read.split_floor(pat.split_floor(k));
                    if k2 == k {
                        break;
                    }
                    k = k2;
                }
            }
            if k >= total {
                self.records[idx].resume_mark.insert(n, total);
                fully_served += 1;
                continue;
            }
            groups.entry(k).or_default().push((n, pat));
        }
        // One planning round per watermark group: every chain streams a
        // single read tail, so destinations resuming from different
        // boundaries cannot share a chain.
        let deg = self.soc.net.degraded_topology();
        let mut planned: Vec<(AffinePattern, Vec<ChainDest>)> = Vec::new();
        for (k, group) in groups {
            let (chains, lost_plan) = plan_repair_chains(&deg, strategy, src, group, reroute);
            lost_now.extend(lost_plan);
            let read_k = if k == 0 { read.clone() } else { read.tail_at(k) };
            for chain in chains {
                self.records[idx].restreamed += ((total - k) * chain.len()) as u64;
                let cdests: Vec<ChainDest> = chain
                    .into_iter()
                    .map(|(node, pattern, vias)| {
                        self.records[idx].resume_mark.insert(node, k);
                        ChainDest {
                            node,
                            pattern: if k == 0 { pattern } else { pattern.tail_at(k) },
                            vias,
                        }
                    })
                    .collect();
                planned.push((read_k.clone(), cdests));
            }
        }
        self.records[idx].lost_dests.extend(lost_now);
        if planned.is_empty() {
            if fully_served == 0 {
                return self.fail(idx, suspect, "no reachable destinations");
            }
            // Nothing left to stream: every reachable survivor already
            // holds its payload, so the task completes as Repaired here.
            let suspect = suspect.unwrap_or(src);
            let rec = &mut self.records[idx];
            let mut lost = std::mem::take(&mut rec.lost_dests);
            lost.sort_unstable_by_key(|n| n.0);
            lost.dedup();
            let served = rec.n_dests - lost.len();
            rec.result = Some(TaskResult {
                task: rec.task.0,
                submitted_at: rec.dispatched_at,
                finished_at: now,
                bytes: rec.bytes,
                n_dests: served,
            });
            let lost_bytes = lost.len() as u64 * rec.bytes as u64;
            rec.outcome = Some(TaskOutcome::Repaired {
                suspect,
                served,
                lost,
                served_bytes: served as u64 * rec.bytes as u64,
                lost_bytes,
                restreamed_bytes: rec.restreamed,
            });
            rec.repairs += 1;
            self.open_tasks -= 1;
            self.dispatch_ready();
            return;
        }
        let suspect = suspect.unwrap_or(src);
        for (read_k, cdests) in planned {
            let rid = self.next_task;
            self.next_task += 1;
            debug_assert!(rid & XDMA_SUBTASK_BIT == 0, "task id space exhausted");
            self.records[idx].repair_live.push(rid);
            self.records[idx]
                .repair_members
                .insert(rid, cdests.iter().map(|d| d.node).collect());
            self.repair_parent.insert(rid, idx);
            // Submitted as a ChainTask directly: TaskSpec cannot carry
            // the per-hop reroute waypoints the planner chose.
            self.soc.nodes[src.0].torrent.submit(
                ChainTask { task: rid, read: read_k, dests: cdests, with_data },
                now,
            );
        }
        let rec = &mut self.records[idx];
        rec.repairs += 1;
        rec.outcome = Some(TaskOutcome::Repairing { suspect });
        // Fresh detection window for the replacement chains.
        rec.hb = None;
    }

    /// Close a task without a result and propagate the failure to any
    /// dependents still waiting in admission.
    fn fail(&mut self, idx: usize, suspect: Option<NodeId>, reason: &str) {
        let rec = &mut self.records[idx];
        if matches!(rec.outcome, Some(TaskOutcome::Failed { .. })) {
            return;
        }
        let mut lost = std::mem::take(&mut rec.lost_dests);
        lost.sort_unstable_by_key(|n| n.0);
        lost.dedup();
        rec.lost_dests = lost;
        rec.outcome = Some(TaskOutcome::Failed { suspect, reason: reason.into() });
        if rec.result.is_none() {
            self.open_tasks -= 1;
        }
        self.dispatch_ready();
    }

    // ------------------------------------------------------------------
    // Queries
    // ------------------------------------------------------------------

    /// O(1) record lookup.
    pub fn record(&self, task: impl Into<TaskId>) -> Option<&Record> {
        self.index.get(&task.into().0).map(|&i| &self.records[i])
    }

    /// Latency of a completed task. Results still held by an engine
    /// (not yet drained by a run mode or [`Coordinator::collect`]) are
    /// visible here too, consistent with [`Coordinator::status`].
    pub fn latency_of(&self, task: impl Into<TaskId>) -> Option<u64> {
        let rec = self.record(task)?;
        if let Some(res) = rec.result.as_ref() {
            return Some(res.latency());
        }
        if rec.pending.is_some() {
            return None;
        }
        self.soc.nodes[rec.src.0]
            .engine(rec.engine)
            .peek_result(rec.task.0)
            .map(|res| res.latency())
    }

    /// Lifecycle status of a task (`None` for ids this coordinator never
    /// issued).
    pub fn status(&self, task: impl Into<TaskId>) -> Option<TaskStatus> {
        let rec = self.record(task)?;
        if let Some(outcome) = &rec.outcome {
            return Some(match outcome {
                TaskOutcome::Repairing { .. } => TaskStatus::Degraded,
                TaskOutcome::Repaired { .. } => TaskStatus::Repaired,
                TaskOutcome::Failed { .. } => TaskStatus::Failed,
            });
        }
        if rec.result.is_some() {
            return Some(TaskStatus::Done);
        }
        if rec.pending.is_some() {
            return Some(TaskStatus::Queued);
        }
        let engine = self.soc.nodes[rec.src.0].engine(rec.engine);
        if engine.peek_result(rec.task.0).is_some() {
            return Some(TaskStatus::Done);
        }
        Some(match engine.phase_of(rec.task.0, self.soc.cycle()) {
            Some(TaskPhase::Configuring) => TaskStatus::Configuring,
            // `None` is unreachable for a dispatched, uncompleted task;
            // report the engine as mid-transfer rather than panicking.
            Some(TaskPhase::Streaming) | None => TaskStatus::Streaming,
        })
    }

    /// Number of submitted tasks not yet completed.
    pub fn open_tasks(&self) -> usize {
        self.open_tasks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Strategy;

    fn coord() -> Coordinator {
        Coordinator::new(SocConfig::custom(3, 3, 64 * 1024))
    }

    #[test]
    fn all_engines_complete_a_simple_p2mp() {
        for engine in [
            EngineKind::Torrent(Strategy::Greedy),
            EngineKind::Idma,
            EngineKind::Xdma,
            EngineKind::Mcast,
        ] {
            let mut c = coord();
            let dests = vec![NodeId(1), NodeId(4), NodeId(8)];
            let t = c.submit_simple(NodeId(0), &dests, 8 * 1024, engine, false).unwrap();
            c.run_to_completion(2_000_000);
            let lat = c.latency_of(t).unwrap_or_else(|| panic!("{engine:?} incomplete"));
            assert!(lat > 0, "{engine:?}");
            assert_eq!(t.status(&c), TaskStatus::Done);
        }
    }

    #[test]
    fn eta_ordering_matches_paper_mechanisms() {
        // For a large transfer to many destinations: chainwrite and mcast
        // must beat unicast (η>1), idma stays ≤ ~1.
        let mut c = coord();
        let dests: Vec<NodeId> = (1..9).map(NodeId).collect();
        let bytes = 16 * 1024;
        let t_chain = c
            .submit_simple(NodeId(0), &dests, bytes, EngineKind::Torrent(Strategy::Tsp), false)
            .unwrap();
        c.run_to_completion(4_000_000);
        let mut c2 = coord();
        let t_idma =
            c2.submit_simple(NodeId(0), &dests, bytes, EngineKind::Idma, false).unwrap();
        c2.run_to_completion(4_000_000);
        let eta_chain = c.record(t_chain).unwrap().eta().unwrap();
        let eta_idma = c2.record(t_idma).unwrap().eta().unwrap();
        assert!(eta_chain > 2.0, "chainwrite eta {eta_chain}");
        assert!(eta_idma <= 1.05, "idma eta {eta_idma}");
    }

    #[test]
    fn all_engines_complete_on_torus_and_ring() {
        use crate::noc::TopologyKind;
        for topology in [TopologyKind::Torus, TopologyKind::Ring] {
            for engine in [
                EngineKind::Torrent(Strategy::Greedy),
                EngineKind::Idma,
                EngineKind::Xdma,
                EngineKind::Mcast,
            ] {
                let mut c = Coordinator::new(
                    SocConfig::custom(3, 3, 64 * 1024).with_topology(topology),
                );
                let dests = vec![NodeId(1), NodeId(4), NodeId(8)];
                let t = c.submit_simple(NodeId(0), &dests, 2 * 1024, engine, false).unwrap();
                c.run_to_completion(2_000_000);
                let lat = c
                    .latency_of(t)
                    .unwrap_or_else(|| panic!("{engine:?} incomplete on {topology:?}"));
                assert!(lat > 0, "{engine:?} on {topology:?}");
            }
        }
    }

    #[test]
    fn torus_wrap_links_shorten_a_far_corner_chainwrite() {
        use crate::noc::TopologyKind;
        let run = |topology: TopologyKind| -> u64 {
            let mut c =
                Coordinator::new(SocConfig::custom(4, 4, 64 * 1024).with_topology(topology));
            let t = c
                .submit_simple(
                    NodeId(0),
                    &[NodeId(15)],
                    4 * 1024,
                    EngineKind::Torrent(Strategy::Greedy),
                    false,
                )
                .unwrap();
            c.run_to_completion(2_000_000);
            c.latency_of(t).unwrap()
        };
        let mesh = run(TopologyKind::Mesh);
        let torus = run(TopologyKind::Torus);
        // 0 -> 15 is 6 hops on the mesh, 2 via the wrap links: the whole
        // cfg/grant/data/finish round trip shortens.
        assert!(torus < mesh, "torus {torus} >= mesh {mesh}");
    }

    #[test]
    fn torrent_records_chain_order() {
        let mut c = coord();
        let t = c
            .submit_simple(
                NodeId(0),
                &[NodeId(2), NodeId(6)],
                1024,
                EngineKind::Torrent(Strategy::Greedy),
                false,
            )
            .unwrap();
        let rec = c.record(t).unwrap();
        assert_eq!(rec.chain_order.as_ref().unwrap().len(), 2);
    }

    #[test]
    fn task_ids_are_unique_and_increasing() {
        let mut c = coord();
        let a = c.submit_simple(NodeId(0), &[NodeId(1)], 64, EngineKind::Idma, false).unwrap();
        let b = c.submit_simple(NodeId(4), &[NodeId(5)], 64, EngineKind::Idma, false).unwrap();
        assert!(b.id() > a.id());
    }

    #[test]
    fn empty_destination_set_is_rejected_not_a_panic() {
        // The Mcast arm used to index req.dests[0] unconditionally.
        for engine in [EngineKind::Mcast, EngineKind::Idma, EngineKind::Torrent(Strategy::Naive)]
        {
            let mut c = coord();
            let e = c.submit(P2mpRequest::to(&[]).src(NodeId(0)).bytes(64).engine(engine));
            assert_eq!(e.unwrap_err().kind, SubmitErrorKind::EmptyDestinations, "{engine:?}");
        }
    }

    #[test]
    fn unmapped_source_address_is_rejected_not_a_panic() {
        // submit_auto used to `expect` on the address lookup.
        let mut c = coord();
        let read = AffinePattern::contiguous(u64::MAX - 4096, 1024);
        let dests =
            vec![(NodeId(1), AffinePattern::contiguous(c.soc.map.base_of(NodeId(1)), 1024))];
        let e = c.submit_auto(P2mpRequest::to_patterns(dests).read(read));
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::UnmappedAddress);
    }

    #[test]
    fn mcast_pattern_below_window_is_rejected() {
        // The Mcast drop offset is pattern base minus window base; a
        // pattern below the destination's window used to underflow.
        let mut c = coord();
        let read = AffinePattern::contiguous(c.soc.map.base_of(NodeId(0)), 1024);
        let dests = vec![(NodeId(3), AffinePattern::contiguous(0, 1024))];
        let e = c.submit(
            P2mpRequest::to_patterns(dests).src(NodeId(0)).read(read).engine(EngineKind::Mcast),
        );
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::UnmappedAddress);
    }

    #[test]
    fn oversized_and_underspecified_requests_are_rejected() {
        let mut c = coord();
        let e = c.submit_simple(NodeId(0), &[NodeId(1)], 1 << 30, EngineKind::Idma, false);
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::TooLarge);
        let e = c.submit(P2mpRequest::to(&[NodeId(1)]).bytes(64));
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::Underspecified);
        let e = c.submit(P2mpRequest::to(&[NodeId(1)]).src(NodeId(0)));
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::Underspecified);
        let e = c.submit(P2mpRequest::to(&[NodeId(1), NodeId(1)]).src(NodeId(0)).bytes(64));
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::InvalidDestinations);
        let e = c.submit(P2mpRequest::to(&[NodeId(0)]).src(NodeId(0)).bytes(64));
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::InvalidDestinations);
    }

    #[test]
    fn out_of_mesh_nodes_are_rejected_not_a_panic() {
        // `AddrMap::base_of` asserts; malformed requests must error first.
        let mut c = coord();
        let e = c.submit_simple(NodeId(0), &[NodeId(99)], 64, EngineKind::Idma, false);
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::InvalidDestinations);
        let e = c.submit_simple(NodeId(99), &[NodeId(1)], 64, EngineKind::Idma, false);
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::UnmappedAddress);
        let read = AffinePattern::contiguous(c.soc.map.base_of(NodeId(0)), 64);
        let dests = vec![(NodeId(42), AffinePattern::contiguous(0x0, 64))];
        let e = c.submit(P2mpRequest::to_patterns(dests).src(NodeId(0)).read(read));
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::InvalidDestinations);
    }

    #[test]
    fn simple_mode_derives_source_from_read_pattern() {
        // submit_auto semantics work without .src() in simple mode too.
        let mut c = coord();
        let read = AffinePattern::contiguous(c.soc.map.base_of(NodeId(4)), 1024);
        let t = c
            .submit(P2mpRequest::to(&[NodeId(1)]).read(read).bytes(1024))
            .unwrap();
        assert_eq!(c.record(t).unwrap().src, NodeId(4));
    }

    #[test]
    fn results_are_visible_after_driving_the_soc_directly() {
        // `status`/`latency_of` must agree when the engine still holds
        // the result; `collect()` then syncs the record.
        let mut c = coord();
        let t = c.submit_simple(NodeId(0), &[NodeId(1)], 1024, EngineKind::Idma, false).unwrap();
        c.soc.run_until_idle(1_000_000);
        assert_eq!(t.status(&c), TaskStatus::Done);
        let lat = c.latency_of(t).expect("latency visible before collect");
        assert!(c.record(t).unwrap().result.is_none());
        c.collect();
        assert_eq!(c.record(t).unwrap().result.as_ref().unwrap().latency(), lat);
        assert_eq!(c.open_tasks(), 0);
    }

    #[test]
    fn zero_byte_transfers_are_rejected_not_hung() {
        // iDMA (and friends) detect completion off in-flight traffic; a
        // zero-byte job would stall until the watchdog.
        let mut c = coord();
        let e = c.submit_simple(NodeId(0), &[NodeId(1)], 0, EngineKind::Idma, false);
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::EmptyTransfer);
    }

    #[test]
    fn mcast_rejects_inconsistent_destination_offsets() {
        // Router replication lands every copy at one shared offset; a
        // per-destination pattern the engine cannot honor must error,
        // not silently write elsewhere.
        let mut c = coord();
        let base = |n: usize| c.soc.map.base_of(NodeId(n));
        let read = AffinePattern::contiguous(base(0), 1024);
        let dests = vec![
            (NodeId(1), AffinePattern::contiguous(base(1) + 0x100, 1024)),
            (NodeId(2), AffinePattern::contiguous(base(2) + 0x200, 1024)),
        ];
        let e = c.submit(
            P2mpRequest::to_patterns(dests).src(NodeId(0)).read(read).engine(EngineKind::Mcast),
        );
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::InvalidDestinations);
    }

    #[test]
    fn unknown_dependency_is_rejected() {
        let mut c = coord();
        let e = c.submit(
            P2mpRequest::to(&[NodeId(1)]).src(NodeId(0)).bytes(64).after(&[TaskId(99)]),
        );
        assert_eq!(e.unwrap_err().kind, SubmitErrorKind::UnknownDependency);
    }

    #[test]
    fn dependency_edges_gate_dispatch_and_release_on_completion() {
        let mut c = coord();
        let chain = EngineKind::Torrent(Strategy::Greedy);
        let a = c.submit_simple(NodeId(0), &[NodeId(4)], 4096, chain, false).unwrap();
        let b = c
            .submit(
                P2mpRequest::to(&[NodeId(8)])
                    .src(NodeId(4))
                    .bytes(4096)
                    .engine(EngineKind::Idma)
                    .after(&[a]),
            )
            .unwrap();
        assert_ne!(a.status(&c), TaskStatus::Queued, "independent task must dispatch");
        assert_eq!(b.status(&c), TaskStatus::Queued, "dependent task must wait");
        let lat_a = c.run_until_complete(a, 1_000_000);
        assert!(lat_a > 0);
        c.run_until_all_done(1_000_000);
        let fin = |t: TaskHandle| c.record(t).unwrap().result.as_ref().unwrap().finished_at;
        assert!(fin(b) > fin(a), "dependency order violated");
        assert_eq!(c.open_tasks(), 0);
    }

    #[test]
    fn healthy_run_report_is_clean() {
        let mut c = coord();
        let t = c
            .submit_simple(NodeId(0), &[NodeId(1)], 1024, EngineKind::Torrent(Strategy::Greedy), false)
            .unwrap();
        let report = c.run_to_completion(1_000_000);
        assert!(report.is_clean());
        assert_eq!(report.completed, 1);
        assert!(report.cycles > 0);
        assert_eq!(t.status(&c), TaskStatus::Done);
    }

    #[test]
    fn norepair_stall_is_failed_with_suspect_not_hung() {
        use crate::sim::FaultPlan;
        // Destination 3's engine complex drops out before the cfg lands:
        // the chain can never finish. With repair disabled the watchdog
        // must close the task as Failed (naming the dead hop) instead of
        // hanging until the cycle watchdog panics.
        let cfg = SocConfig::custom(2, 2, 64 * 1024)
            .with_faults(FaultPlan::parse("drop:3@0;timeout:500;norepair").unwrap());
        let mut c = Coordinator::new(cfg);
        let t = c
            .submit_simple(NodeId(0), &[NodeId(3)], 1024, EngineKind::Torrent(Strategy::Greedy), false)
            .unwrap();
        let report = c.run_to_completion(200_000);
        assert_eq!(t.status(&c), TaskStatus::Failed);
        assert_eq!(report.failed(), vec![t.id()]);
        let rec = c.record(t).unwrap();
        match &rec.outcome {
            Some(TaskOutcome::Failed { suspect, .. }) => {
                assert_eq!(*suspect, Some(NodeId(3)), "diagnosis must name the dropped hop");
            }
            o => panic!("expected Failed outcome, got {o:?}"),
        }
        assert!(c.latency_of(t).is_none());
        assert_eq!(c.open_tasks(), 0);
    }

    #[test]
    fn router_kill_repairs_surviving_destination() {
        use crate::sim::FaultPlan;
        // Chain 0 -> 1 -> 3 on a 2x2 mesh; router 3 dies mid-task. The
        // coordinator must detect the flatline, blame node 3, and
        // re-chain the surviving destination 1 under a fresh task id.
        let cfg = SocConfig::custom(2, 2, 64 * 1024)
            .with_faults(FaultPlan::parse("router:3@200;timeout:800").unwrap());
        let mut c = Coordinator::new(cfg);
        let t = c
            .submit_simple(
                NodeId(0),
                &[NodeId(1), NodeId(3)],
                2048,
                EngineKind::Torrent(Strategy::Greedy),
                false,
            )
            .unwrap();
        let report = c.run_to_completion(2_000_000);
        assert_eq!(t.status(&c), TaskStatus::Repaired);
        assert_eq!(report.repaired(), vec![t.id()]);
        let rec = c.record(t).unwrap();
        assert_eq!(rec.repairs, 1);
        match &rec.outcome {
            Some(TaskOutcome::Repaired {
                suspect,
                served,
                lost,
                served_bytes,
                lost_bytes,
                restreamed_bytes,
            }) => {
                assert_eq!(*suspect, NodeId(3));
                assert_eq!(*served, 1);
                assert_eq!(lost.as_slice(), &[NodeId(3)]);
                // resume is off: the one survivor re-streams in full.
                assert_eq!(*served_bytes, 2048);
                assert_eq!(*lost_bytes, 2048);
                assert_eq!(*restreamed_bytes, 2048);
            }
            o => panic!("expected Repaired outcome, got {o:?}"),
        }
        // The synthesized result spans dispatch to the repair finish.
        assert!(c.latency_of(t).unwrap() > 800, "repair latency includes the detection window");
        assert_eq!(c.open_tasks(), 0);
    }

    #[test]
    fn failed_dependency_fails_dependents_transitively() {
        use crate::sim::FaultPlan;
        let cfg = SocConfig::custom(2, 2, 64 * 1024)
            .with_faults(FaultPlan::parse("drop:3@0;timeout:500;norepair").unwrap());
        let mut c = Coordinator::new(cfg);
        let a = c
            .submit_simple(NodeId(0), &[NodeId(3)], 1024, EngineKind::Torrent(Strategy::Greedy), false)
            .unwrap();
        let b = c
            .submit(P2mpRequest::to(&[NodeId(2)]).src(NodeId(0)).bytes(1024).after(&[a]))
            .unwrap();
        let d = c
            .submit(P2mpRequest::to(&[NodeId(1)]).src(NodeId(0)).bytes(1024).after(&[b]))
            .unwrap();
        let report = c.run_to_completion(200_000);
        assert_eq!(a.status(&c), TaskStatus::Failed);
        assert_eq!(b.status(&c), TaskStatus::Failed, "dependent of a failed task");
        assert_eq!(d.status(&c), TaskStatus::Failed, "transitive dependent");
        assert_eq!(report.failed().len(), 3);
        assert_eq!(c.open_tasks(), 0);
    }

    #[test]
    fn concurrent_tasks_overlap_across_initiators() {
        // Two independent chains from different initiators must overlap
        // in time, not serialize.
        let mut c = Coordinator::new(SocConfig::custom(4, 4, 64 * 1024));
        let ta = c
            .submit_simple(NodeId(0), &[NodeId(5), NodeId(6)], 8 * 1024,
                EngineKind::Torrent(Strategy::Greedy), false)
            .unwrap();
        let tb = c
            .submit_simple(NodeId(15), &[NodeId(9), NodeId(10)], 8 * 1024,
                EngineKind::Torrent(Strategy::Greedy), false)
            .unwrap();
        c.run_until_all_done(1_000_000);
        let res = |t: TaskHandle| c.record(t).unwrap().result.clone().unwrap();
        let (ra, rb) = (res(ta), res(tb));
        assert!(
            ra.submitted_at < rb.finished_at && rb.submitted_at < ra.finished_at,
            "tasks did not overlap: {ra:?} {rb:?}"
        );
    }
}

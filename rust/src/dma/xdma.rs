//! XDMA baseline: the distributed-DMA predecessor Torrent's frontend
//! builds on (Kong et al., 2025) — ND-affine DSEs at both endpoints,
//! cross-DMA configuration, but **software P2MP**: a multi-destination
//! job runs as N strictly sequential P2P transfers, each paying the full
//! cfg → grant → data → finish round trip and re-reading the source.
//!
//! This is the unicast baseline of the paper's FPGA evaluation (Fig 9):
//! Torrent's speedup over XDMA is Chainwrite amortizing the source read
//! and the per-transfer handshake across the whole destination set.
//!
//! Implementation: XDMA *is* a P2P-only Torrent frontend, so this engine
//! drives the node's [`Torrent`] with single-destination chain tasks, one
//! at a time.

use std::collections::VecDeque;

use crate::noc::NodeId;

use super::torrent::dse::AffinePattern;
use super::torrent::{ChainDest, ChainTask, Torrent};
use super::TaskResult;

/// A software-P2MP job.
#[derive(Debug, Clone)]
pub struct XdmaTask {
    pub task: u32,
    pub read: AffinePattern,
    pub dests: Vec<(NodeId, AffinePattern)>,
    pub with_data: bool,
}

#[derive(Debug)]
struct Active {
    task: XdmaTask,
    submitted_at: u64,
    next_dest: usize,
    /// Sub-task id currently in flight on the Torrent frontend.
    inflight: Option<u32>,
}

/// Software P2MP driver.
#[derive(Debug)]
pub struct Xdma {
    pub node: NodeId,
    queue: VecDeque<(XdmaTask, u64)>,
    active: Option<Active>,
    pub results: Vec<TaskResult>,
    /// Sub-task id space: high bit tags XDMA-internal transfers so they
    /// never collide with coordinator-assigned Chainwrite ids.
    next_subtask: u32,
}

impl Xdma {
    pub fn new(node: NodeId) -> Self {
        Xdma { node, queue: VecDeque::new(), active: None, results: Vec::new(), next_subtask: 0 }
    }

    pub fn submit(&mut self, task: XdmaTask, now: u64) {
        assert!(!task.dests.is_empty());
        self.queue.push_back((task, now));
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_none() && self.queue.is_empty()
    }

    /// Activity hint (the `sim::Clocked::next_event` contract). An
    /// in-flight P2P leg is tracked by the node's Torrent frontend, whose
    /// own hints/messages drive progress; XDMA itself only needs a tick
    /// to pop its queue or to launch the next leg (both "now" events —
    /// completion of a leg is observed on the same inbox tick that
    /// delivers the Torrent finish, so no wait is ever skipped past).
    pub fn next_event(&self, now: u64) -> Option<u64> {
        match &self.active {
            None => (!self.queue.is_empty()).then_some(now),
            Some(a) => a.inflight.is_none().then_some(now),
        }
    }

    /// Drive the node's Torrent frontend. Call once per cycle *before*
    /// the Torrent's own tick.
    pub fn tick(&mut self, torrent: &mut Torrent, now: u64) {
        if self.active.is_none() {
            if let Some((task, submitted_at)) = self.queue.pop_front() {
                self.active = Some(Active {
                    submitted_at: submitted_at.max(now),
                    next_dest: 0,
                    inflight: None,
                    task,
                });
            }
        }
        let Some(a) = self.active.as_mut() else { return };

        // Completion of the in-flight P2P leg?
        if let Some(sub) = a.inflight {
            if torrent.results.iter().any(|r| r.task == sub) {
                a.inflight = None;
            }
        }
        if a.inflight.is_none() {
            if a.next_dest == a.task.dests.len() {
                // All legs done.
                self.results.push(TaskResult {
                    task: a.task.task,
                    submitted_at: a.submitted_at,
                    finished_at: now,
                    bytes: a.task.read.total_bytes(),
                    n_dests: a.task.dests.len(),
                });
                self.active = None;
                return;
            }
            let (node, pattern) = a.task.dests[a.next_dest].clone();
            let sub = 0x8000_0000 | self.next_subtask;
            self.next_subtask += 1;
            torrent.submit(
                ChainTask {
                    task: sub,
                    read: a.task.read.clone(),
                    dests: vec![ChainDest { node, pattern }],
                    with_data: a.task.with_data,
                },
                now,
            );
            a.inflight = Some(sub);
            a.next_dest += 1;
        }
    }
}

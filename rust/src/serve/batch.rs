//! Continuous-batching dispatcher (ISSUE 8): coalesce compatible KV
//! multicast requests inside a bounded batching window.
//!
//! Serving stacks batch at the same point: many decode streams want the
//! same attention KV block pushed to their engine regions, and one
//! Chainwrite whose destination set is the union moves it in a single
//! chain pass instead of N. Two requests are compatible when they share
//! `(src, bytes)` — same source scratchpad window and transfer size, so
//! the union set is one valid [`crate::dma::TaskSpec`]. The window is
//! anchored at the *first* member (`flush_at = opened_at + window`), so
//! no request waits more than `window` cycles in the batcher; `window =
//! 0` degenerates to one task per request. Background unicast traffic
//! never enters the batcher — the driver submits it directly.

use crate::noc::NodeId;

/// One open batch: the union destination set and the member request ids
/// that will share the resulting task's completion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    pub src: NodeId,
    pub bytes: usize,
    /// Union of member destination sets, sorted and deduplicated (chain
    /// order is the scheduler's job at submission).
    pub dests: Vec<NodeId>,
    /// Request ids sharing this batch's completion.
    pub members: Vec<u32>,
    /// Cycle the first member was staged.
    pub opened_at: u64,
    /// Cycle the batch closes and must be submitted.
    pub flush_at: u64,
}

/// The batcher: open batches keyed by compatibility, flushed by the
/// driver when their window expires.
#[derive(Debug)]
pub struct Batcher {
    window: u64,
    open: Vec<Batch>,
}

impl Batcher {
    pub fn new(window: u64) -> Self {
        Batcher { window, open: Vec::new() }
    }

    /// Stage one admitted KV request. Joins an open compatible batch
    /// (keeping its original `flush_at`) or opens a new one closing at
    /// `now + window`.
    pub fn stage(&mut self, req: u32, src: NodeId, dests: &[NodeId], bytes: usize, now: u64) {
        if let Some(b) = self.open.iter_mut().find(|b| b.src == src && b.bytes == bytes) {
            b.members.push(req);
            for &d in dests {
                if !b.dests.contains(&d) {
                    b.dests.push(d);
                }
            }
            b.dests.sort_unstable_by_key(|n| n.0);
            return;
        }
        let mut sorted: Vec<NodeId> = dests.to_vec();
        sorted.sort_unstable_by_key(|n| n.0);
        sorted.dedup();
        self.open.push(Batch {
            src,
            bytes,
            dests: sorted,
            members: vec![req],
            opened_at: now,
            flush_at: now + self.window,
        });
    }

    /// Earliest close cycle among open batches (a driver wake source).
    pub fn next_flush(&self) -> Option<u64> {
        self.open.iter().map(|b| b.flush_at).min()
    }

    /// Close and return every batch with `flush_at <= now`, oldest
    /// first (stable: `open` is append-ordered, so the drain order is
    /// deterministic).
    pub fn flush_due(&mut self, now: u64) -> Vec<Batch> {
        let mut due = Vec::new();
        let mut keep = Vec::new();
        for b in self.open.drain(..) {
            if b.flush_at <= now {
                due.push(b);
            } else {
                keep.push(b);
            }
        }
        self.open = keep;
        due
    }

    /// Close every open batch regardless of window (end-of-run drain).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        std::mem::take(&mut self.open)
    }

    /// Requests currently staged across all open batches.
    pub fn staged(&self) -> usize {
        self.open.iter().map(|b| b.members.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compatible_requests_coalesce_with_union_dests() {
        let mut b = Batcher::new(32);
        b.stage(1, NodeId(0), &[NodeId(3), NodeId(5)], 4096, 100);
        b.stage(2, NodeId(0), &[NodeId(5), NodeId(7)], 4096, 110);
        let due = b.flush_due(132);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].members, vec![1, 2]);
        assert_eq!(due[0].dests, vec![NodeId(3), NodeId(5), NodeId(7)]);
        assert_eq!(due[0].flush_at, 132, "window anchors at the first member");
    }

    #[test]
    fn incompatible_requests_stay_separate() {
        let mut b = Batcher::new(32);
        b.stage(1, NodeId(0), &[NodeId(3)], 4096, 100);
        b.stage(2, NodeId(1), &[NodeId(3)], 4096, 100); // other source
        b.stage(3, NodeId(0), &[NodeId(3)], 8192, 100); // other size
        assert_eq!(b.flush_all().len(), 3);
    }

    #[test]
    fn window_bounds_the_wait() {
        let mut b = Batcher::new(50);
        b.stage(1, NodeId(0), &[NodeId(3)], 1024, 100);
        assert_eq!(b.next_flush(), Some(150));
        assert!(b.flush_due(149).is_empty());
        // A late joiner does not extend the window.
        b.stage(2, NodeId(0), &[NodeId(4)], 1024, 149);
        let due = b.flush_due(150);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].members, vec![1, 2]);
        assert_eq!(b.next_flush(), None);
    }

    #[test]
    fn zero_window_is_one_task_per_flush_cycle() {
        let mut b = Batcher::new(0);
        b.stage(1, NodeId(0), &[NodeId(3)], 1024, 7);
        assert_eq!(b.next_flush(), Some(7));
        assert_eq!(b.flush_due(7).len(), 1);
    }

    #[test]
    fn staged_counts_members() {
        let mut b = Batcher::new(10);
        assert_eq!(b.staged(), 0);
        b.stage(1, NodeId(0), &[NodeId(1)], 512, 0);
        b.stage(2, NodeId(0), &[NodeId(2)], 512, 1);
        b.stage(3, NodeId(2), &[NodeId(1)], 512, 2);
        assert_eq!(b.staged(), 3);
    }
}

//! Chainwrite sequence scheduling (paper §III-D).
//!
//! Chainwrite exposes the destination traversal order explicitly; §IV-C
//! shows the order decides whether Chainwrite matches network-layer
//! multicast. Three strategies:
//!
//! * [`naive_order`] — follow cluster IDs (the paper's baseline that
//!   "suffers from redundant paths");
//! * [`greedy_order`] — Alg. 1: pick the next destination whose XY path
//!   does not overlap already-used links, minimizing path length
//!   (just-in-time optimization);
//! * [`tsp_order`] — open-path TSP on the XY distance matrix; exact
//!   Held–Karp for small sets, nearest-neighbour + 2-opt beyond (the
//!   paper used OR-Tools; see DESIGN.md §3).

pub mod chain;
pub mod hops;
pub mod tsp;

pub use chain::{greedy_order, naive_order, Strategy};
pub use hops::{chain_hops, unicast_hops};
pub use tsp::tsp_order;

use crate::noc::{Mesh, NodeId};

/// Dispatch by strategy. `src` is the initiator; returns the destination
/// visit order (a permutation of `dests`).
pub fn schedule(strategy: Strategy, mesh: &Mesh, src: NodeId, dests: &[NodeId]) -> Vec<NodeId> {
    match strategy {
        Strategy::Naive => naive_order(dests),
        Strategy::Greedy => greedy_order(mesh, src, dests),
        Strategy::Tsp => tsp_order(mesh, src, dests),
    }
}

/// [`schedule`] lifted to keyed payloads (write patterns, descriptors):
/// returns the visit order plus the `(node, payload)` pairs permuted
/// into that order. The single chain-ordering path shared by
/// `Soc::chainwrite` and the coordinator's dispatcher.
pub fn schedule_pairs<T>(
    strategy: Strategy,
    mesh: &Mesh,
    src: NodeId,
    dests: Vec<(NodeId, T)>,
) -> (Vec<NodeId>, Vec<(NodeId, T)>) {
    let nodes: Vec<NodeId> = dests.iter().map(|(n, _)| *n).collect();
    let order = schedule(strategy, mesh, src, &nodes);
    let mut slots: Vec<Option<(NodeId, T)>> = dests.into_iter().map(Some).collect();
    let ordered = order
        .iter()
        .map(|n| {
            slots
                .iter_mut()
                .find_map(|s| match s {
                    Some((d, _)) if d == n => s.take(),
                    _ => None,
                })
                .expect("scheduled order permutes the destination set")
        })
        .collect();
    (order, ordered)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_pairs_keeps_payloads_with_their_nodes() {
        let m = Mesh::new(4, 4);
        let dests: Vec<(NodeId, &str)> =
            vec![(NodeId(5), "five"), (NodeId(10), "ten"), (NodeId(3), "three")];
        for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp] {
            let (order, ordered) = schedule_pairs(s, &m, NodeId(0), dests.clone());
            assert_eq!(order.len(), dests.len(), "{s:?}");
            for ((n, payload), o) in ordered.iter().zip(&order) {
                assert_eq!(n, o, "{s:?} pair order must match the visit order");
                let want = dests.iter().find(|(d, _)| d == n).unwrap().1;
                assert_eq!(*payload, want, "{s:?} payload moved to the wrong node");
            }
        }
    }

    #[test]
    fn schedule_dispatches_all_strategies() {
        let m = Mesh::new(4, 4);
        let dests = vec![NodeId(5), NodeId(10), NodeId(3)];
        for s in [Strategy::Naive, Strategy::Greedy, Strategy::Tsp] {
            let order = schedule(s, &m, NodeId(0), &dests);
            let mut sorted = order.clone();
            sorted.sort();
            let mut want = dests.clone();
            want.sort();
            assert_eq!(sorted, want, "{s:?} must permute the destination set");
        }
    }
}

//! Application-layer DMA engines — the paper's contribution and its two
//! baselines — behind one object-safe [`Engine`] trait.
//!
//! * [`torrent`] — the Torrent distributed DMA: DSE (ND-affine address
//!   generation), data switch (stream duplication / cut-through
//!   forwarding), backend (AXI/cfg packet construction) and the
//!   four-phase **Chainwrite** orchestration of Fig 4.
//! * [`idma`] — monolithic P2P DMA (iDMA baseline): P2MP = repeated
//!   unicast, sequential per destination.
//! * [`xdma`] — the distributed XDMA predecessor (the paper's FPGA
//!   baseline): remote-configured P2P transfers, software P2MP, per-run
//!   descriptor overhead on non-contiguous patterns.
//! * [`mcast`] — source engine for the ESP-style network-layer multicast
//!   baseline (replication in the routers, §II-B).
//!
//! The [`Engine`] trait is the extension point the XDMA paper
//! (arXiv 2508.08396) argues for: the coordinator and the SoC event loop
//! dispatch uniformly through it (`submit` / `handle` / `tick` /
//! `next_event` / `drain_results`), so adding a fifth P2MP mechanism
//! means implementing the trait and adding one [`EngineKind`] arm — no
//! caller changes.

pub mod idma;
pub mod mcast;
pub mod torrent;
pub mod xdma;

pub use torrent::{ChainDest, ChainTask, Torrent};

use crate::mem::Scratchpad;
use crate::noc::{NetPort, NodeId, Packet};
use crate::sched::Strategy;
use anyhow::anyhow;
use std::fmt;

use self::torrent::dse::AffinePattern;

/// Which engine serves a P2MP request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Torrent Chainwrite with the given chain-order strategy.
    Torrent(Strategy),
    /// iDMA: repeated unicast, sequential.
    Idma,
    /// XDMA: software P2MP over the distributed frontend.
    Xdma,
    /// ESP-style network-layer multicast.
    Mcast,
}

impl EngineKind {
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Torrent(Strategy::Naive) => "torrent/naive",
            EngineKind::Torrent(Strategy::Greedy) => "torrent/greedy",
            EngineKind::Torrent(Strategy::Tsp) => "torrent/tsp",
            EngineKind::Torrent(Strategy::LoadAware) => "torrent/load_aware",
            EngineKind::Idma => "idma",
            EngineKind::Xdma => "xdma",
            EngineKind::Mcast => "mcast",
        }
    }
}

/// Completion record every engine produces for a finished task.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: u32,
    /// Cycle the task was submitted to the engine.
    pub submitted_at: u64,
    /// Cycle the engine observed completion (initiator-side, matching the
    /// paper's "from task dispatch to the DSE until the initiator Torrent
    /// receives the finish signal").
    pub finished_at: u64,
    /// Payload bytes moved per destination.
    pub bytes: usize,
    pub n_dests: usize,
}

impl TaskResult {
    pub fn latency(&self) -> u64 {
        self.finished_at - self.submitted_at
    }
}

/// Engine-agnostic description of one P2MP job, accepted by every
/// [`Engine`]. For chain-based engines `dests` is already in chain order
/// (the coordinator applies a `sched::Strategy` before dispatch).
#[derive(Debug)]
pub struct TaskSpec {
    pub task: u32,
    /// Source DSE read pattern (in the initiator's scratchpad).
    pub read: AffinePattern,
    /// Destinations with their local write patterns.
    pub dests: Vec<(NodeId, AffinePattern)>,
    /// Move real bytes (integrity-checked runs) or phantom timing-only.
    pub with_data: bool,
    /// Window-local drop offset (network-multicast engines; zero
    /// otherwise — router-replicated streams land at one shared offset,
    /// patterned per-destination writes are a distributed-DMA capability).
    pub drop_offset: u64,
}

impl TaskSpec {
    /// Shared submission validation: a non-empty destination set whose
    /// write patterns each cover exactly the read stream.
    pub fn validate(&self) -> Result<(), SubmitError> {
        if self.dests.is_empty() {
            return Err(SubmitError::new(
                SubmitErrorKind::EmptyDestinations,
                anyhow!("task {} has an empty destination set", self.task),
            ));
        }
        let total = self.read.total_bytes();
        if total == 0 {
            // Engines signal completion off in-flight traffic; a job
            // that never injects anything would hang until the watchdog.
            return Err(SubmitError::new(
                SubmitErrorKind::EmptyTransfer,
                anyhow!("task {} moves zero bytes", self.task),
            ));
        }
        for (node, pattern) in &self.dests {
            if pattern.total_bytes() != total {
                return Err(SubmitError::new(
                    SubmitErrorKind::SizeMismatch,
                    anyhow!(
                        "task {}: destination {:?} pattern covers {} B, read covers {} B",
                        self.task,
                        node,
                        pattern.total_bytes(),
                        total
                    ),
                ));
            }
        }
        Ok(())
    }
}

/// Why a submission was rejected. The coordinator and the engines return
/// this instead of panicking on malformed requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitErrorKind {
    /// The destination set is empty.
    EmptyDestinations,
    /// The request moves zero bytes (engines detect completion off
    /// in-flight traffic, so an empty job would never finish).
    EmptyTransfer,
    /// The request is missing a required field (source, read pattern or
    /// transfer size, depending on the construction mode).
    Underspecified,
    /// An address does not resolve inside the SoC address map.
    UnmappedAddress,
    /// Destinations repeat a node, include the source, or name a node
    /// outside the mesh.
    InvalidDestinations,
    /// A destination write pattern does not cover the read stream.
    SizeMismatch,
    /// A simple-mode transfer does not fit half a scratchpad window.
    TooLarge,
    /// A dependency references a task id this coordinator never issued.
    UnknownDependency,
}

impl SubmitErrorKind {
    /// Stable snake_case wire form, used verbatim in serve-sim JSON
    /// reports and CLI output (ISSUE 8 satellite) — additions are fine,
    /// renames are a report-schema break.
    pub fn as_str(self) -> &'static str {
        match self {
            SubmitErrorKind::EmptyDestinations => "empty_destinations",
            SubmitErrorKind::EmptyTransfer => "empty_transfer",
            SubmitErrorKind::Underspecified => "underspecified",
            SubmitErrorKind::UnmappedAddress => "unmapped_address",
            SubmitErrorKind::InvalidDestinations => "invalid_destinations",
            SubmitErrorKind::SizeMismatch => "size_mismatch",
            SubmitErrorKind::TooLarge => "too_large",
            SubmitErrorKind::UnknownDependency => "unknown_dependency",
        }
    }

    /// Every variant, for round-trip tests and report legends.
    pub const ALL: [SubmitErrorKind; 8] = [
        SubmitErrorKind::EmptyDestinations,
        SubmitErrorKind::EmptyTransfer,
        SubmitErrorKind::Underspecified,
        SubmitErrorKind::UnmappedAddress,
        SubmitErrorKind::InvalidDestinations,
        SubmitErrorKind::SizeMismatch,
        SubmitErrorKind::TooLarge,
        SubmitErrorKind::UnknownDependency,
    ];
}

impl fmt::Display for SubmitErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for SubmitErrorKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::ALL
            .into_iter()
            .find(|k| k.as_str() == s)
            .ok_or_else(|| format!("unknown SubmitErrorKind '{s}'"))
    }
}

/// Submission failure: a stable [`SubmitErrorKind`] for callers to match
/// on plus a human-readable message (built with the vendored `anyhow`).
#[derive(Debug)]
pub struct SubmitError {
    pub kind: SubmitErrorKind,
    msg: String,
}

impl SubmitError {
    pub fn new(kind: SubmitErrorKind, err: anyhow::Error) -> Self {
        SubmitError { kind, msg: err.to_string() }
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.kind, self.msg)
    }
}

impl std::error::Error for SubmitError {}

/// Coarse protocol phase of an in-flight task (drives
/// `coordinator::TaskStatus`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskPhase {
    /// Queued at the engine, decoding descriptors, or programming the
    /// fabric (ESP router set, Chainwrite cfg/grant round trip).
    Configuring,
    /// Data (or finish signalling) is moving.
    Streaming,
}

/// Per-call context handed to an engine: the fabric (through the
/// [`NetPort`] endpoint surface, so the same engine code runs against the
/// whole `Network` or a parallel-stepper shard view) and the node's local
/// scratchpad. The borrows live only for the duration of one `handle` /
/// `tick` call, so the SoC can rebuild the context per node per cycle.
pub struct EngineCtx<'a> {
    pub net: &'a mut dyn NetPort,
    pub mem: &'a mut Scratchpad,
}

/// The unified application-layer DMA engine interface.
///
/// Implemented by [`Torrent`], [`idma::Idma`], [`xdma::Xdma`] and
/// [`mcast::McastEngine`]; `soc::Soc` ticks and dispatches packets
/// through it and `coordinator::Coordinator` submits and collects
/// through it, so neither contains per-engine control flow.
///
/// Engines with private sub-transfers (XDMA's software-P2MP legs) hand
/// them to the node's Torrent frontend through the *frontend-leg* hooks:
/// after each engine's `tick` the SoC collects `take_frontend_legs` and
/// offers the batch to subsequent engines via `accept_frontend_legs` —
/// the Torrent (ticked right after the XDMA) drains it the same cycle,
/// so leg timing is identical to a direct submission.
pub trait Engine {
    /// Short diagnostic name ("torrent", "idma", ...).
    fn label(&self) -> &'static str;

    /// Accept a validated P2MP job. Returns an error instead of
    /// panicking on malformed specs (empty destination sets, pattern
    /// size mismatches).
    fn submit(&mut self, spec: TaskSpec, now: u64) -> Result<(), SubmitError>;

    /// Consume a packet addressed to this engine. Every engine of the
    /// node sees every delivered packet; return `true` only for traffic
    /// this engine owns (an eavesdropping engine returns `false`).
    fn handle(&mut self, pkt: &Packet, ctx: &mut EngineCtx<'_>, now: u64) -> bool;

    /// Advance one cycle.
    fn tick(&mut self, ctx: &mut EngineCtx<'_>);

    /// Activity hint — the `sim::Clocked::next_event` contract: earliest
    /// cycle at which ticking this engine changes observable state;
    /// `None` = idle or purely message-driven.
    fn next_event(&self, now: u64) -> Option<u64>;

    /// True when nothing is queued or in flight on this engine.
    fn is_idle(&self) -> bool;

    /// Remove and return all completion records accumulated so far.
    fn drain_results(&mut self) -> Vec<TaskResult>;

    /// Non-destructive lookup of a completion record still held by the
    /// engine (a task can be `Done` before the coordinator drains it).
    fn peek_result(&self, task: u32) -> Option<&TaskResult>;

    /// Coarse phase of an in-flight task, `None` if unknown here.
    fn phase_of(&self, task: u32, now: u64) -> Option<TaskPhase>;

    /// Monotone-while-healthy progress ordinal for an in-flight task —
    /// the coordinator's heartbeat. Any value that *changes* while the
    /// protocol advances works (segment indices, bytes landed, phase
    /// ordinals); a value frozen for a full detection window marks the
    /// task as stalled. `None` when this engine holds no state for the
    /// task. Default: no heartbeat (only fault-aware engines report).
    fn progress_of(&self, _task: u32) -> Option<u64> {
        None
    }

    /// Fault repair: abandon every local trace of `task` — queued work,
    /// in-flight state, forwarding gates — so a replacement chain can be
    /// issued without the wreck double-reporting or wedging the node.
    /// Returns true if any state was discarded. Default: nothing to do.
    fn cancel(&mut self, _task: u32) -> bool {
        false
    }

    /// Chain legs this engine wants the node's Torrent frontend to run.
    /// Default: none.
    fn take_frontend_legs(&mut self) -> Vec<(ChainTask, u64)> {
        Vec::new()
    }

    /// Offer relayed frontend legs to this engine; the chain frontend
    /// drains the vector into its queue. Default: ignore.
    fn accept_frontend_legs(&mut self, _legs: &mut Vec<(ChainTask, u64)>) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_spec_rejects_empty_destinations() {
        let spec = TaskSpec {
            task: 1,
            read: AffinePattern::contiguous(0, 64),
            dests: vec![],
            with_data: false,
            drop_offset: 0,
        };
        let err = spec.validate().unwrap_err();
        assert_eq!(err.kind, SubmitErrorKind::EmptyDestinations);
    }

    #[test]
    fn task_spec_rejects_size_mismatch() {
        let spec = TaskSpec {
            task: 2,
            read: AffinePattern::contiguous(0, 64),
            dests: vec![(NodeId(1), AffinePattern::contiguous(0x1000, 128))],
            with_data: false,
            drop_offset: 0,
        };
        let err = spec.validate().unwrap_err();
        assert_eq!(err.kind, SubmitErrorKind::SizeMismatch);
        assert!(err.to_string().contains("size_mismatch"), "{err}");
    }

    #[test]
    fn submit_error_kind_strings_round_trip() {
        for kind in SubmitErrorKind::ALL {
            let s = kind.as_str();
            assert_eq!(s, s.to_lowercase(), "{kind:?} form is not snake_case");
            assert!(!s.contains(' '), "{kind:?} form contains spaces");
            assert_eq!(s.parse::<SubmitErrorKind>().unwrap(), kind);
            assert_eq!(kind.to_string(), s);
        }
        assert!("not_a_kind".parse::<SubmitErrorKind>().is_err());
    }

    #[test]
    fn engine_kind_labels_are_stable() {
        assert_eq!(EngineKind::Torrent(Strategy::Tsp).label(), "torrent/tsp");
        assert_eq!(EngineKind::Idma.label(), "idma");
        assert_eq!(EngineKind::Xdma.label(), "xdma");
        assert_eq!(EngineKind::Mcast.label(), "mcast");
    }
}

//! Global SoC address map: one fixed-size window per mesh node.
//!
//! Cluster *i*'s scratchpad occupies `[i * window, i * window + size)`,
//! mirroring the Occamy-style flat map the paper's SoC uses. The map
//! resolves an address to the owning node — the routing decision every
//! AXI request and Torrent cfg makes.

use crate::noc::NodeId;

/// Address window size per node (1 MB default keeps cluster offsets
/// human-readable: node = addr >> 20).
pub const DEFAULT_WINDOW: u64 = 1 << 20;

#[derive(Debug, Clone, Copy)]
pub struct AddrMap {
    pub window: u64,
    pub n_nodes: usize,
}

impl AddrMap {
    pub fn new(n_nodes: usize, window: u64) -> Self {
        assert!(window.is_power_of_two());
        AddrMap { window, n_nodes }
    }

    pub fn with_default_window(n_nodes: usize) -> Self {
        Self::new(n_nodes, DEFAULT_WINDOW)
    }

    /// Base address of `node`'s window.
    pub fn base_of(&self, node: NodeId) -> u64 {
        assert!(node.0 < self.n_nodes);
        node.0 as u64 * self.window
    }

    /// Owning node of `addr`; `None` if outside the map.
    pub fn node_of(&self, addr: u64) -> Option<NodeId> {
        let n = (addr / self.window) as usize;
        (n < self.n_nodes).then_some(NodeId(n))
    }

    /// True if `[addr, addr+len)` stays inside a single node's window.
    pub fn single_node(&self, addr: u64, len: usize) -> bool {
        len == 0 || self.node_of(addr) == self.node_of(addr + len as u64 - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_and_node_roundtrip() {
        let m = AddrMap::with_default_window(20);
        for i in 0..20 {
            let b = m.base_of(NodeId(i));
            assert_eq!(m.node_of(b), Some(NodeId(i)));
            assert_eq!(m.node_of(b + DEFAULT_WINDOW - 1), Some(NodeId(i)));
        }
    }

    #[test]
    fn out_of_map_is_none() {
        let m = AddrMap::with_default_window(4);
        assert_eq!(m.node_of(4 * DEFAULT_WINDOW), None);
    }

    #[test]
    fn single_node_detects_window_straddle() {
        let m = AddrMap::with_default_window(4);
        assert!(m.single_node(0, DEFAULT_WINDOW as usize));
        assert!(!m.single_node(DEFAULT_WINDOW - 4, 8));
        assert!(m.single_node(123, 0));
    }
}

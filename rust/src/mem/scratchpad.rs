//! Banked scratchpad SRAM model.
//!
//! Functional storage (real bytes — the integration tests verify every
//! Chainwrite destination receives exactly the source data) plus a bank
//! model used for access statistics and conflict accounting.

/// Banks per scratchpad (paper §IV-A: 32-bank TCDM).
pub const NUM_BANKS: usize = 32;
/// Bytes per bank word (64-bit banks).
pub const BANK_BYTES: usize = 8;

/// A single cluster's scratchpad memory.
#[derive(Debug, Clone)]
pub struct Scratchpad {
    /// Base address in the global map.
    pub base: u64,
    data: Vec<u8>,
    /// Word accesses per bank (for the power model's activity counts).
    pub bank_accesses: [u64; NUM_BANKS],
    /// Accesses that conflicted (>1 word to the same bank in one group).
    pub conflicts: u64,
}

impl Scratchpad {
    pub fn new(base: u64, size: usize) -> Self {
        assert!(size % (NUM_BANKS * BANK_BYTES) == 0, "size must be bank-aligned");
        Scratchpad { base, data: vec![0; size], bank_accesses: [0; NUM_BANKS], conflicts: 0 }
    }

    pub fn size(&self) -> usize {
        self.data.len()
    }

    pub fn contains(&self, addr: u64, len: usize) -> bool {
        addr >= self.base && (addr + len as u64) <= self.base + self.data.len() as u64
    }

    fn offset(&self, addr: u64, len: usize) -> usize {
        assert!(
            self.contains(addr, len),
            "access [{addr:#x}..+{len}) outside scratchpad [{:#x}..+{})",
            self.base,
            self.data.len()
        );
        (addr - self.base) as usize
    }

    /// Bank index of a byte address.
    pub fn bank_of(&self, addr: u64) -> usize {
        ((addr - self.base) as usize / BANK_BYTES) % NUM_BANKS
    }

    /// Read `len` bytes at global address `addr`.
    pub fn read(&mut self, addr: u64, len: usize) -> Vec<u8> {
        let off = self.offset(addr, len);
        self.account(addr, len);
        self.data[off..off + len].to_vec()
    }

    /// Write bytes at global address `addr`.
    pub fn write(&mut self, addr: u64, bytes: &[u8]) {
        let off = self.offset(addr, bytes.len());
        self.account(addr, bytes.len());
        self.data[off..off + bytes.len()].copy_from_slice(bytes);
    }

    /// Borrow without statistics (test assertions, accelerator reads).
    pub fn peek(&self, addr: u64, len: usize) -> &[u8] {
        let off = (addr - self.base) as usize;
        assert!(off + len <= self.data.len());
        &self.data[off..off + len]
    }

    /// Account bank activity for an access group. Word addresses touching
    /// the same bank within one 256 B group (one cycle of full-width
    /// access) count as conflicts.
    fn account(&mut self, addr: u64, len: usize) {
        let first = (addr - self.base) as usize / BANK_BYTES;
        let last = ((addr - self.base) as usize + len.max(1) - 1) / BANK_BYTES;
        let words = last - first + 1;
        for w in first..=last {
            self.bank_accesses[w % NUM_BANKS] += 1;
        }
        // A contiguous run conflicts only when it wraps the bank set.
        if words > NUM_BANKS {
            self.conflicts += (words - NUM_BANKS) as u64;
        }
    }

    /// Cycles to stream `len` bytes through one 64 B/cycle port.
    pub fn stream_cycles(len: usize) -> u64 {
        (len as u64).div_ceil(crate::noc::FLIT_BYTES as u64)
    }

    /// Fill with a deterministic pattern (tests, workload setup).
    pub fn fill_pattern(&mut self, seed: u8) {
        for (i, b) in self.data.iter_mut().enumerate() {
            *b = seed ^ (i as u8) ^ ((i >> 8) as u8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_roundtrip() {
        let mut s = Scratchpad::new(0x1000, 4096);
        s.write(0x1100, &[1, 2, 3, 4]);
        assert_eq!(s.read(0x1100, 4), vec![1, 2, 3, 4]);
    }

    #[test]
    fn contains_bounds() {
        let s = Scratchpad::new(0x1000, 4096);
        assert!(s.contains(0x1000, 4096));
        assert!(!s.contains(0xfff, 1));
        assert!(!s.contains(0x1000, 4097));
    }

    #[test]
    #[should_panic(expected = "outside scratchpad")]
    fn out_of_bounds_panics() {
        let mut s = Scratchpad::new(0, 256);
        s.read(256, 1);
    }

    #[test]
    fn bank_of_cycles_through_banks() {
        let s = Scratchpad::new(0, 4096);
        assert_eq!(s.bank_of(0), 0);
        assert_eq!(s.bank_of(8), 1);
        assert_eq!(s.bank_of(8 * 32), 0);
    }

    #[test]
    fn bank_accesses_accumulate() {
        let mut s = Scratchpad::new(0, 4096);
        s.read(0, 64); // words 0..8 -> banks 0..8
        for b in 0..8 {
            assert_eq!(s.bank_accesses[b], 1);
        }
        assert_eq!(s.bank_accesses[8], 0);
        assert_eq!(s.conflicts, 0);
    }

    #[test]
    fn long_run_wraps_banks_and_conflicts() {
        let mut s = Scratchpad::new(0, 4096);
        s.read(0, 8 * NUM_BANKS + 16); // two extra words
        assert_eq!(s.conflicts, 2);
    }

    #[test]
    fn stream_cycles_at_link_rate() {
        assert_eq!(Scratchpad::stream_cycles(0), 0);
        assert_eq!(Scratchpad::stream_cycles(1), 1);
        assert_eq!(Scratchpad::stream_cycles(64), 1);
        assert_eq!(Scratchpad::stream_cycles(65536), 1024);
    }

    #[test]
    fn fill_pattern_deterministic() {
        let mut a = Scratchpad::new(0, 512);
        let mut b = Scratchpad::new(0, 512);
        a.fill_pattern(7);
        b.fill_pattern(7);
        assert_eq!(a.peek(0, 512), b.peek(0, 512));
    }
}

//! Pure-Rust reference backend (default; no XLA toolchain required).
//!
//! The manifest still defines the artifact set and the parameter/result
//! shapes; the computation itself is evaluated in Rust with f64
//! accumulation for the entry points `python/compile/aot.py` exports:
//!
//! * `gemm_prefill`, `gemm_decode` — `A · B`;
//! * `kv_recovery` — MLA up-projection `(C·Wk, C·Wv)`;
//! * `attn_prefill`, `attn_decode`, `attn_prefill_flash` —
//!   `softmax(Q·Kᵀ/√d) · V` (the flash variant is the same math by
//!   construction — online softmax only changes the schedule);
//! * `relayout_*` — blocked MNMxNy re-tiling, geometry taken from the
//!   manifest's 4-D `(Mt, Nt, tm, tn)` shapes.
//!
//! This keeps `cargo test` / the examples self-contained (DESIGN.md §5):
//! the same calls run on XLA when the crate is built with `--features
//! pjrt` and a real `xla` dependency.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use super::manifest::{Manifest, ManifestEntry};
use super::{validate_inputs, Tensor};

/// Manifest-driven engine evaluating the known kernels in pure Rust.
pub struct Engine {
    pub dir: PathBuf,
    entries: Vec<ManifestEntry>,
}

impl Engine {
    /// Load `<dir>/manifest.txt`. The `.hlo.txt` artifact files are not
    /// needed by this backend — only the manifest's names and shapes.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        Ok(Self::from_manifest(dir, manifest))
    }

    /// Build directly from a parsed manifest (embedding, tests).
    pub fn from_manifest(dir: PathBuf, manifest: Manifest) -> Self {
        Engine { dir, entries: manifest.entries }
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.iter().map(|e| e.name.as_str()).collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn platform(&self) -> String {
        "cpu-reference (pure Rust; build with --features pjrt for XLA)".to_string()
    }

    /// Execute artifact `name` on f32 inputs; returns the output tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .entry(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have {:?})", self.names()))?;
        validate_inputs(spec, inputs)?;
        let outs = eval(spec, inputs)?;
        if outs.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: got {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            ));
        }
        for (i, (t, s)) in outs.iter().zip(&spec.outputs).enumerate() {
            if t.shape != s.dims {
                return Err(anyhow!(
                    "{name}: output {i} shape {:?} != manifest {:?}",
                    t.shape,
                    s.dims
                ));
            }
        }
        Ok(outs)
    }
}

/// Dispatch on the entry-point name (the set `aot.py` exports).
fn eval(spec: &ManifestEntry, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let name = spec.name.as_str();
    match name {
        "gemm_prefill" | "gemm_decode" => {
            if inputs.len() != 2 {
                return Err(anyhow!("{name}: needs (a, b)"));
            }
            Ok(vec![matmul(&inputs[0], &inputs[1])?])
        }
        "kv_recovery" => {
            if inputs.len() != 3 {
                return Err(anyhow!("{name}: needs (latent, w_uk, w_uv)"));
            }
            Ok(vec![matmul(&inputs[0], &inputs[1])?, matmul(&inputs[0], &inputs[2])?])
        }
        "attn_prefill" | "attn_decode" | "attn_prefill_flash" => {
            if inputs.len() != 3 {
                return Err(anyhow!("{name}: needs (q, k, v)"));
            }
            Ok(vec![attention(&inputs[0], &inputs[1], &inputs[2])?])
        }
        _ if name.starts_with("relayout_") => {
            if inputs.len() != 1 || spec.outputs.is_empty() {
                return Err(anyhow!("{name}: needs one blocked input and output"));
            }
            let out_dims = &spec.outputs[0].dims;
            Ok(vec![relayout(&inputs[0], out_dims)?])
        }
        _ => Err(anyhow!(
            "artifact {name:?} has no pure-Rust reference implementation; \
             build with --features pjrt (and a real xla dependency) to run it"
        )),
    }
}

/// `A(m,k) · B(k,n)` with f64 accumulation.
fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let ([m, k], [kb, n]) = (dims2(a)?, dims2(b)?);
    if k != kb {
        return Err(anyhow!("matmul: inner dims {k} != {kb}"));
    }
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for e in 0..k {
                acc += a.data[i * k + e] as f64 * b.data[e * n + j] as f64;
            }
            out[i * n + j] = acc as f32;
        }
    }
    Ok(Tensor::new(vec![m, n], out))
}

/// `softmax(Q·Kᵀ/√d) · V` — Q `(tq,d)`, K `(tk,d)`, V `(tk,dv)`.
fn attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Tensor> {
    let ([tq, d], [tk, dk], [tv, dv]) = (dims2(q)?, dims2(k)?, dims2(v)?);
    if d != dk || tk != tv {
        return Err(anyhow!(
            "attention: incompatible shapes q{:?} k{:?} v{:?}",
            q.shape,
            k.shape,
            v.shape
        ));
    }
    let scale = 1.0 / (d as f64).sqrt();
    let mut out = vec![0f32; tq * dv];
    let mut scores = vec![0f64; tk];
    for i in 0..tq {
        for (j, s) in scores.iter_mut().enumerate() {
            let mut acc = 0f64;
            for e in 0..d {
                acc += q.data[i * d + e] as f64 * k.data[j * d + e] as f64;
            }
            *s = acc * scale;
        }
        let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut z = 0f64;
        for s in scores.iter_mut() {
            *s = (*s - max).exp();
            z += *s;
        }
        for e in 0..dv {
            let mut acc = 0f64;
            for (j, s) in scores.iter().enumerate() {
                acc += s / z * v.data[j * dv + e] as f64;
            }
            out[i * dv + e] = acc as f32;
        }
    }
    Ok(Tensor::new(vec![tq, dv], out))
}

/// Blocked MNMxNy re-tiling: `(Mt, Nt, tm_in, tn_in)` →
/// `(Mt', Nt', tm_out, tn_out)` over the same logical matrix.
fn relayout(x: &Tensor, out_dims: &[usize]) -> Result<Tensor> {
    let [mt_i, nt_i, tm_i, tn_i] = dims4(&x.shape)?;
    let [mt_o, nt_o, tm_o, tn_o] = dims4(out_dims)?;
    let (m, n) = (mt_i * tm_i, nt_i * tn_i);
    if (mt_o * tm_o, nt_o * tn_o) != (m, n) {
        return Err(anyhow!(
            "relayout: logical matrix {m}x{n} does not match output tiling {out_dims:?}"
        ));
    }
    let mut out = vec![0f32; x.data.len()];
    for r in 0..m {
        for c in 0..n {
            let src = ((r / tm_i) * nt_i + c / tn_i) * (tm_i * tn_i) + (r % tm_i) * tn_i + c % tn_i;
            let dst = ((r / tm_o) * nt_o + c / tn_o) * (tm_o * tn_o) + (r % tm_o) * tn_o + c % tn_o;
            out[dst] = x.data[src];
        }
    }
    Ok(Tensor::new(out_dims.to_vec(), out))
}

fn dims2(t: &Tensor) -> Result<[usize; 2]> {
    match t.shape[..] {
        [a, b] => Ok([a, b]),
        _ => Err(anyhow!("expected a 2-D tensor, got shape {:?}", t.shape)),
    }
}

fn dims4(dims: &[usize]) -> Result<[usize; 4]> {
    match dims[..] {
        [a, b, c, d] => Ok([a, b, c, d]),
        _ => Err(anyhow!("expected a 4-D blocked shape, got {dims:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::parse(
            "gemm_prefill\tgemm_prefill.hlo.txt\tf32[4,3];f32[3,5]\tf32[4,5]\n\
             kv_recovery\tkv.hlo.txt\tf32[6,4];f32[4,2];f32[4,2]\tf32[6,2];f32[6,2]\n\
             attn_prefill\tattn.hlo.txt\tf32[8,4];f32[8,4];f32[8,4]\tf32[8,4]\n\
             relayout_16x8_to_8x8\trelayout.hlo.txt\tf32[2,2,16,8]\tf32[4,2,8,8]\n",
        )
        .unwrap()
    }

    fn engine() -> Engine {
        Engine::from_manifest(PathBuf::new(), manifest())
    }

    #[test]
    fn gemm_matches_naive_oracle() {
        let e = engine();
        let a = Tensor::random(vec![4, 3], 1);
        let b = Tensor::random(vec![3, 5], 2);
        let out = &e.run("gemm_prefill", &[a.clone(), b.clone()]).unwrap()[0];
        assert_eq!(out.shape, vec![4, 5]);
        for i in 0..4 {
            for j in 0..5 {
                let want: f32 =
                    (0..3).map(|k| a.data[i * 3 + k] * b.data[k * 5 + j]).sum();
                assert!((out.data[i * 5 + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn kv_recovery_is_two_projections() {
        let e = engine();
        let c = Tensor::random(vec![6, 4], 3);
        let wk = Tensor::random(vec![4, 2], 4);
        let wv = Tensor::random(vec![4, 2], 5);
        let out = e.run("kv_recovery", &[c.clone(), wk.clone(), wv]).unwrap();
        assert_eq!(out.len(), 2);
        let k_direct = matmul(&c, &wk).unwrap();
        assert_eq!(out[0], k_direct);
    }

    #[test]
    fn attention_rows_are_convex_combinations_of_v() {
        let e = engine();
        let q = Tensor::random(vec![8, 4], 6);
        let k = Tensor::random(vec![8, 4], 7);
        let v = Tensor::random(vec![8, 4], 8);
        let out = &e.run("attn_prefill", &[q, k, v.clone()]).unwrap()[0];
        for col in 0..4 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for row in 0..8 {
                lo = lo.min(v.data[row * 4 + col]);
                hi = hi.max(v.data[row * 4 + col]);
            }
            for row in 0..8 {
                let x = out.data[row * 4 + col];
                assert!(x >= lo - 1e-5 && x <= hi + 1e-5, "[{row},{col}]={x}");
            }
        }
    }

    #[test]
    fn relayout_is_a_permutation_matching_the_blocked_index_math() {
        let e = engine();
        // 32x16 logical matrix, MNM16N8 -> MNM8N8; fill with the flat index.
        let x = Tensor::new(vec![2, 2, 16, 8], (0..512).map(|i| i as f32).collect());
        let out = &e.run("relayout_16x8_to_8x8", &[x.clone()]).unwrap()[0];
        assert_eq!(out.shape, vec![4, 2, 8, 8]);
        let mut sorted = out.data.clone();
        sorted.sort_by(f32::total_cmp);
        assert_eq!(sorted, x.data, "not a permutation");
        // Spot-check logical element (17, 9): tile (1,1) local (1,1) in,
        // tile (2,1) local (1,1) out.
        let src = ((17 / 16) * 2 + 9 / 8) * 128 + (17 % 16) * 8 + 9 % 8;
        let dst = ((17 / 8) * 2 + 9 / 8) * 64 + (17 % 8) * 8 + 9 % 8;
        assert_eq!(out.data[dst], x.data[src]);
    }

    #[test]
    fn unknown_artifacts_and_bad_shapes_are_rejected() {
        let e = engine();
        assert!(e.run("nonexistent", &[]).is_err());
        let bad = Tensor::zeros(vec![2, 2]);
        assert!(e.run("gemm_prefill", &[bad.clone(), bad]).is_err());
    }

    #[test]
    fn platform_names_the_backend() {
        assert!(engine().platform().contains("cpu-reference"));
    }
}

//! Hop-count models — the implementation-agnostic Fig-6 metric
//! ("number of edges the data traverses divided by N_dst").

use crate::noc::{Mesh, NodeId};

/// Total links the Chainwrite stream traverses: src -> order[0] -> ... ->
/// order[n-1], each leg XY-routed (= Manhattan length).
pub fn chain_hops(mesh: &Mesh, src: NodeId, order: &[NodeId]) -> usize {
    let mut hops = 0;
    let mut cur = src;
    for &d in order {
        hops += mesh.manhattan(cur, d);
        cur = d;
    }
    hops
}

/// Total links for repeated unicast: every destination is a separate
/// XY-routed transfer from the source.
pub fn unicast_hops(mesh: &Mesh, src: NodeId, dests: &[NodeId]) -> usize {
    dests.iter().map(|&d| mesh.manhattan(src, d)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::multicast::mcast_tree_hops;

    #[test]
    fn chain_hops_sums_legs() {
        let m = Mesh::new(4, 1);
        // 0 -> 2 -> 1 -> 3: 2 + 1 + 2 = 5
        assert_eq!(chain_hops(&m, NodeId(0), &[2, 1, 3].map(NodeId)), 5);
    }

    #[test]
    fn unicast_hops_sums_manhattan() {
        let m = Mesh::new(4, 4);
        assert_eq!(unicast_hops(&m, NodeId(0), &[NodeId(3), NodeId(12)]), 6);
    }

    #[test]
    fn empty_orders_are_zero() {
        let m = Mesh::new(4, 4);
        assert_eq!(chain_hops(&m, NodeId(0), &[]), 0);
        assert_eq!(unicast_hops(&m, NodeId(0), &[]), 0);
    }

    #[test]
    fn optimal_chain_can_reach_one_hop_per_dest() {
        // Fig 6's theoretical limit: a Hamiltonian-like chain over adjacent
        // nodes costs exactly 1 hop per destination.
        let m = Mesh::new(3, 1);
        let hops = chain_hops(&m, NodeId(0), &[1, 2].map(NodeId));
        assert_eq!(hops, 2); // = N_dst
    }

    #[test]
    fn mcast_tree_never_worse_than_unicast() {
        let m = Mesh::new(8, 8);
        let dests: Vec<NodeId> = [5, 13, 27, 45, 60].map(NodeId).to_vec();
        assert!(
            mcast_tree_hops(&m, NodeId(0), &dests) <= unicast_hops(&m, NodeId(0), &dests)
        );
    }
}

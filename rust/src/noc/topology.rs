//! 2D-mesh topology and dimension-ordered (XY) routing.
//!
//! The paper's evaluation SoCs are FlooNoC 2D meshes: 4×5 (20 clusters,
//! §IV-A), 8×8 (Fig 6 hop study) and 3×3 (FPGA, §IV-E), all XY-routed.
//! `NodeId`s are row-major: node = y * cols + x, so cluster C0 is the
//! origin corner — matching the paper's "start from dest closest to C0".

/// Node index in row-major order over the mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// (x, y) mesh coordinate; x is the column, y the row.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Coord {
    pub x: usize,
    pub y: usize,
}

/// Router port direction. `Local` is the endpoint (NI) port.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    Local,
    North,
    East,
    South,
    West,
}

impl Dir {
    pub const ALL: [Dir; 5] = [Dir::Local, Dir::North, Dir::East, Dir::South, Dir::West];

    pub fn index(self) -> usize {
        match self {
            Dir::Local => 0,
            Dir::North => 1,
            Dir::East => 2,
            Dir::South => 3,
            Dir::West => 4,
        }
    }

    /// The port on the neighbouring router that faces back at us.
    pub fn opposite(self) -> Dir {
        match self {
            Dir::Local => Dir::Local,
            Dir::North => Dir::South,
            Dir::East => Dir::West,
            Dir::South => Dir::North,
            Dir::West => Dir::East,
        }
    }
}

/// A `cols` × `rows` 2D mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh {
    pub cols: usize,
    pub rows: usize,
}

impl Mesh {
    pub fn new(cols: usize, rows: usize) -> Self {
        assert!(cols >= 1 && rows >= 1);
        Mesh { cols, rows }
    }

    pub fn n_nodes(&self) -> usize {
        self.cols * self.rows
    }

    pub fn coord(&self, n: NodeId) -> Coord {
        assert!(n.0 < self.n_nodes(), "node {n:?} out of mesh {self:?}");
        Coord { x: n.0 % self.cols, y: n.0 / self.cols }
    }

    pub fn node(&self, c: Coord) -> NodeId {
        assert!(c.x < self.cols && c.y < self.rows, "{c:?} out of mesh {self:?}");
        NodeId(c.y * self.cols + c.x)
    }

    /// Manhattan distance in hops.
    pub fn manhattan(&self, a: NodeId, b: NodeId) -> usize {
        let (ca, cb) = (self.coord(a), self.coord(b));
        ca.x.abs_diff(cb.x) + ca.y.abs_diff(cb.y)
    }

    /// Neighbour in direction `d`, if inside the mesh.
    pub fn neighbour(&self, n: NodeId, d: Dir) -> Option<NodeId> {
        let c = self.coord(n);
        let nc = match d {
            Dir::Local => return Some(n),
            Dir::North => {
                if c.y + 1 >= self.rows {
                    return None;
                }
                Coord { x: c.x, y: c.y + 1 }
            }
            Dir::South => {
                if c.y == 0 {
                    return None;
                }
                Coord { x: c.x, y: c.y - 1 }
            }
            Dir::East => {
                if c.x + 1 >= self.cols {
                    return None;
                }
                Coord { x: c.x + 1, y: c.y }
            }
            Dir::West => {
                if c.x == 0 {
                    return None;
                }
                Coord { x: c.x - 1, y: c.y }
            }
        };
        Some(self.node(nc))
    }

    /// Next output port under XY routing (X fully first, then Y).
    pub fn xy_next_hop(&self, cur: NodeId, dst: NodeId) -> Dir {
        let (c, d) = (self.coord(cur), self.coord(dst));
        if c.x < d.x {
            Dir::East
        } else if c.x > d.x {
            Dir::West
        } else if c.y < d.y {
            Dir::North
        } else if c.y > d.y {
            Dir::South
        } else {
            Dir::Local
        }
    }

    /// Full XY path from `from` to `to`, inclusive of both endpoints.
    pub fn xy_path(&self, from: NodeId, to: NodeId) -> Vec<NodeId> {
        let mut path = vec![from];
        let mut cur = from;
        while cur != to {
            let d = self.xy_next_hop(cur, to);
            cur = self.neighbour(cur, d).expect("XY routing left the mesh");
            path.push(cur);
        }
        path
    }

    /// The directed links (node pairs) of the XY path — the "edges" used
    /// by Alg. 1's overlap test.
    pub fn xy_links(&self, from: NodeId, to: NodeId) -> Vec<(NodeId, NodeId)> {
        let p = self.xy_path(from, to);
        p.windows(2).map(|w| (w[0], w[1])).collect()
    }

    pub fn nodes(&self) -> impl Iterator<Item = NodeId> {
        (0..self.n_nodes()).map(NodeId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_node_ids() {
        let m = Mesh::new(4, 5);
        assert_eq!(m.n_nodes(), 20);
        assert_eq!(m.coord(NodeId(0)), Coord { x: 0, y: 0 });
        assert_eq!(m.coord(NodeId(5)), Coord { x: 1, y: 1 });
        assert_eq!(m.node(Coord { x: 3, y: 4 }), NodeId(19));
    }

    #[test]
    fn manhattan_distance() {
        let m = Mesh::new(8, 8);
        assert_eq!(m.manhattan(NodeId(0), NodeId(63)), 14);
        assert_eq!(m.manhattan(NodeId(9), NodeId(9)), 0);
    }

    #[test]
    fn neighbours_at_edges() {
        let m = Mesh::new(3, 3);
        assert_eq!(m.neighbour(NodeId(0), Dir::West), None);
        assert_eq!(m.neighbour(NodeId(0), Dir::South), None);
        assert_eq!(m.neighbour(NodeId(0), Dir::East), Some(NodeId(1)));
        assert_eq!(m.neighbour(NodeId(0), Dir::North), Some(NodeId(3)));
        assert_eq!(m.neighbour(NodeId(8), Dir::East), None);
    }

    #[test]
    fn xy_routes_x_first() {
        let m = Mesh::new(4, 4);
        // 0=(0,0) -> 15=(3,3): east 3 times then north 3 times
        let p = m.xy_path(NodeId(0), NodeId(15));
        assert_eq!(
            p,
            vec![0, 1, 2, 3, 7, 11, 15].into_iter().map(NodeId).collect::<Vec<_>>()
        );
    }

    #[test]
    fn xy_path_length_is_manhattan() {
        let m = Mesh::new(5, 7);
        for a in m.nodes() {
            for b in m.nodes() {
                assert_eq!(m.xy_path(a, b).len(), m.manhattan(a, b) + 1);
            }
        }
    }

    #[test]
    fn xy_path_to_self() {
        let m = Mesh::new(2, 2);
        assert_eq!(m.xy_path(NodeId(3), NodeId(3)), vec![NodeId(3)]);
    }

    #[test]
    fn opposite_ports() {
        for d in Dir::ALL {
            assert_eq!(d.opposite().opposite(), d);
        }
    }

    #[test]
    fn next_hop_local_at_destination() {
        let m = Mesh::new(3, 3);
        assert_eq!(m.xy_next_hop(NodeId(4), NodeId(4)), Dir::Local);
    }
}

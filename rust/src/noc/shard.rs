//! Sharded parallel stepping for the fabric (`StepMode::Parallel`).
//!
//! The fabric's per-node state lives in [`Lane`]s (`noc::network`); a
//! shard is a contiguous node range that one worker thread owns for the
//! duration of a tick. Workers run the *same* phase helpers as the
//! sequential [`Network::tick`] — link delivery, injection, switch — over
//! their own slice; the only cross-shard traffic is
//!
//! * **boundary flits** (a link whose downstream router lives in another
//!   shard) and
//! * **freed credits** (an input slot freed by a switch whose upstream
//!   router lives in another shard),
//!
//! both of which travel through per-(src-shard, dst-shard) mailboxes and
//! are committed after a [`Barrier`], in ascending src-shard order with
//! FIFO order preserved within a shard. That (cycle, src-shard, FIFO)
//! key makes the merge independent of thread interleaving — the same
//! discipline that replaced hash-map iteration with `BTreeMap`s in the
//! endpoint engines.
//!
//! # Why this is bit-exact, not just deterministic
//!
//! Determinism alone would let `Parallel` disagree with `EventDriven` by
//! a fixed-but-different schedule. The stronger claim — bit-identical
//! cycles for every thread count, enforced by the three-way differential
//! in `rust/tests/stepping.rs` — rests on three facts:
//!
//! 1. **Each input FIFO has exactly one producer.** A router's input
//!    `(port, vc)` FIFO is fed only by the upstream node's link delay
//!    line for that direction, and a lane owns its node's *outbound*
//!    links. So every FIFO's content is determined by one source queue's
//!    pop order, which the mailbox preserves; cross-FIFO commit order is
//!    immaterial because the switch reads FIFOs, not a global queue.
//! 2. **No same-cycle credit visibility, in either kernel.** The
//!    sequential switch phase collects freed credits and applies them
//!    after every router has allocated (see `Network::tick`); workers do
//!    the same — in-shard credits after their own switch loop,
//!    cross-shard credits after the post-switch barrier. Credits are
//!    commutative counter increments, so apply order within the window
//!    cannot matter.
//! 3. **Packet ids are composed, not counted.** `packet::compose_id`
//!    packs (cycle, phase, node, seq), so a shard allocates the exact id
//!    a sequential run would have allocated, with no shared counter.
//!
//! Fault activation is a *barrier event*: activations mutate arbitrary
//! lanes (a router kill returns purged credits to its neighbours), so
//! they are applied on the main thread between the endpoint and fabric
//! phases — exactly where the sequential kernel applies them — and the
//! fabric phases only ever *read* fault state.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, Mutex};

use super::network::{
    deliver_links_range, inject_range, lane_send, switch_range, FaultState, Gate, Lane, NetPort,
    NetStats, Network,
};
use super::packet::{Flit, Packet, PacketId, PHASE_EXTERNAL};
use super::topology::{Dir, NodeId, Topo};
use std::sync::Arc;

/// A boundary flit headed for another shard: `(dst node, input port, vc,
/// flit)` in the source link queue's FIFO order.
type BoundaryFlit = (usize, Dir, usize, Flit);
/// A freed credit headed for another shard: `(upstream node, upstream
/// output port, vc)`.
type BoundaryCredit = (usize, Dir, usize);

/// Partition `n` nodes into at most `threads` contiguous shards, sizes
/// differing by at most one (the first `n % s` shards take the extra
/// node). Contiguity keeps a shard's lanes a single `&mut [Lane]` slice
/// and makes "src-shard order" well defined.
pub fn shard_ranges(n: usize, threads: usize) -> Vec<Range<usize>> {
    let s = threads.max(1).min(n.max(1));
    let (q, r) = (n / s, n % s);
    let mut out = Vec::with_capacity(s);
    let mut start = 0;
    for i in 0..s {
        let len = q + usize::from(i < r);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Shard index owning `node` (ranges are sorted and contiguous).
pub(crate) fn shard_of(ranges: &[Range<usize>], node: usize) -> usize {
    let s = ranges.partition_point(|r| r.end <= node);
    debug_assert!(ranges[s].contains(&node), "node {node} outside every shard");
    s
}

/// Split `items` into the per-shard `&mut` slices described by `ranges`
/// (which must tile `items` from 0). The borrow-splitting primitive both
/// the fabric and the SoC endpoint phases use.
pub(crate) fn split_ranges<'a, T>(items: &'a mut [T], ranges: &[Range<usize>]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = items;
    let mut off = 0;
    for r in ranges {
        debug_assert_eq!(r.start, off, "ranges must tile the slice");
        let (head, tail) = rest.split_at_mut(r.end - off);
        out.push(head);
        rest = tail;
        off = r.end;
    }
    debug_assert!(rest.is_empty(), "ranges must cover the slice");
    out
}

/// Per-tick cross-shard rendezvous: the barrier every worker meets
/// between phases, plus the (src-shard × dst-shard) mailboxes for
/// boundary flits and credits. A cell is written by exactly one shard
/// (pre-barrier) and drained by exactly one shard (post-barrier), so the
/// mutexes are never contended — they exist to make the cells `Sync`.
pub(crate) struct ShardMail {
    pub(crate) barrier: Barrier,
    shards: usize,
    flits: Vec<Mutex<Vec<BoundaryFlit>>>,
    credits: Vec<Mutex<Vec<BoundaryCredit>>>,
}

impl ShardMail {
    pub(crate) fn new(shards: usize) -> Self {
        ShardMail {
            barrier: Barrier::new(shards),
            shards,
            flits: (0..shards * shards).map(|_| Mutex::new(Vec::new())).collect(),
            credits: (0..shards * shards).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    fn cell(&self, src: usize, dst: usize) -> usize {
        src * self.shards + dst
    }

    fn post_flits(&self, src: usize, dst: usize, v: Vec<BoundaryFlit>) {
        let mut g = self.flits[self.cell(src, dst)].lock().unwrap();
        debug_assert!(g.is_empty(), "flit mailbox double-posted");
        *g = v;
    }

    fn take_flits(&self, src: usize, dst: usize) -> Vec<BoundaryFlit> {
        std::mem::take(&mut *self.flits[self.cell(src, dst)].lock().unwrap())
    }

    fn post_credits(&self, src: usize, dst: usize, v: Vec<BoundaryCredit>) {
        let mut g = self.credits[self.cell(src, dst)].lock().unwrap();
        debug_assert!(g.is_empty(), "credit mailbox double-posted");
        *g = v;
    }

    fn take_credits(&self, src: usize, dst: usize) -> Vec<BoundaryCredit> {
        std::mem::take(&mut *self.credits[self.cell(src, dst)].lock().unwrap())
    }
}

/// One worker's share of a fabric tick: the same link-delivery /
/// injection / switch sequence as `Network::tick`, with boundary flits
/// and credits exchanged through `mail` at the two barriers. Every
/// worker of the tick must call this (the barriers count all shards).
pub(crate) fn fabric_phases(
    lanes: &mut [Lane],
    base: usize,
    si: usize,
    ranges: &[Range<usize>],
    topo: Topo,
    cycle: u64,
    faults: Option<&FaultState>,
    mail: &ShardMail,
    stats: &mut NetStats,
) {
    let s = ranges.len();

    // 1. Link delivery. In-shard flits enter their input FIFO directly;
    //    boundary flits are bucketed per destination shard in source-
    //    queue pop order. Fault-sunk flits return their credit to the
    //    sending router, which is in-shard by lane ownership.
    {
        let mut out: Vec<Vec<BoundaryFlit>> = vec![Vec::new(); s];
        deliver_links_range(lanes, base, topo, cycle, faults, stats, |dst, port, vc, flit| {
            out[shard_of(ranges, dst)].push((dst, port, vc, flit));
        });
        for (ds, v) in out.into_iter().enumerate() {
            if !v.is_empty() {
                mail.post_flits(si, ds, v);
            }
        }
    }
    mail.barrier.wait();
    // Commit inbound boundary flits in ascending src-shard order, FIFO
    // within each. (Each (dst, port, vc) FIFO has exactly one producer
    // queue, so this order is for auditability — any commit order yields
    // the same FIFO contents.)
    for src in 0..s {
        for (dst, port, vc, flit) in mail.take_flits(src, si) {
            lanes[dst - base].router.accept(port, vc, flit);
        }
    }

    // 2. Injection — entirely node-local.
    inject_range(lanes, base, faults, stats);

    // 3. Switch allocation + traversal, credits deferred. In-shard
    //    credits apply after this shard's full switch pass (no router of
    //    ours has allocation left to run); cross-shard credits wait for
    //    the barrier so the owning shard has finished allocating too.
    //    Either way no router sees a credit freed this same cycle —
    //    matching the sequential kernel's deferred-credit rule.
    let mut scratch = Vec::new();
    let mut credits = Vec::new();
    switch_range(lanes, base, &topo, cycle, faults, stats, &mut scratch, &mut credits);
    {
        let mut out: Vec<Vec<BoundaryCredit>> = vec![Vec::new(); s];
        for (node, dir, vc) in credits {
            let ds = shard_of(ranges, node);
            if ds == si {
                lanes[node - base].router.return_credit(dir, vc);
            } else {
                out[ds].push((node, dir, vc));
            }
        }
        for (ds, v) in out.into_iter().enumerate() {
            if !v.is_empty() {
                mail.post_credits(si, ds, v);
            }
        }
    }
    mail.barrier.wait();
    // Credits are commutative increments; src-shard order is cosmetic.
    for src in 0..s {
        for (node, dir, vc) in mail.take_credits(src, si) {
            lanes[node - base].router.return_credit(dir, vc);
        }
    }
}

impl Network {
    /// Advance one cycle with the per-node work sharded across (at most)
    /// `threads` worker threads. Bit-identical to [`Network::tick`] for
    /// every thread count; `threads <= 1` (or a single-node fabric) runs
    /// the sequential kernel directly.
    pub fn tick_parallel(&mut self, threads: usize) {
        let ranges = shard_ranges(self.lanes.len(), threads);
        if ranges.len() <= 1 {
            self.tick();
            return;
        }
        self.cycle += 1;
        let cycle = self.cycle;
        // Fault activations mutate arbitrary lanes (kill_router returns
        // purged credits to the victim's neighbours), so they happen
        // here, on the main thread, before any worker exists — the
        // global barrier event. Workers then only read fault state.
        if self.faults.is_some() {
            self.activate_due_faults();
        }
        if self.lanes.iter().all(Lane::fabric_quiet) {
            for l in &mut self.lanes {
                l.router.rr_advance(1);
            }
            return;
        }
        let topo = self.topo;
        let Network { lanes, faults, stats, .. } = self;
        let faults = faults.as_deref();
        let mail = ShardMail::new(ranges.len());
        let deltas: Vec<NetStats> = std::thread::scope(|sc| {
            let handles: Vec<_> = split_ranges(lanes, &ranges)
                .into_iter()
                .enumerate()
                .map(|(si, slice)| {
                    let (ranges, mail) = (&ranges, &mail);
                    sc.spawn(move || {
                        let mut stats = NetStats::default();
                        fabric_phases(
                            slice,
                            ranges[si].start,
                            si,
                            ranges,
                            topo,
                            cycle,
                            faults,
                            mail,
                            &mut stats,
                        );
                        stats
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("fabric shard worker panicked"))
                .collect()
        });
        // Merge per-shard stat deltas in shard order (sums — order is
        // cosmetic, but fixed anyway).
        for d in &deltas {
            stats.merge(d);
        }
    }

    /// Carve the fabric's lanes into per-shard endpoint views for the
    /// SoC's parallel dispatch/engine phases. The views borrow the lanes;
    /// fabric-wide queries are unavailable until they are dropped.
    pub(crate) fn endpoint_shards(&mut self, ranges: &[Range<usize>]) -> Vec<EndpointShard<'_>> {
        let cycle = self.cycle;
        split_ranges(&mut self.lanes, ranges)
            .into_iter()
            .zip(ranges)
            .map(|(slice, r)| EndpointShard::new(r.start, cycle, slice))
            .collect()
    }
}

/// A shard-local [`NetPort`]: the endpoint surface over one shard's
/// lanes, used by the SoC's dispatch and engine phases on a worker
/// thread. Sends allocate composed packet ids from the lane's own
/// allocator, so the ids (and everything ordered by them) are identical
/// to a sequential run. Any access outside the shard panics — engines
/// only ever touch their own node's NI, and this is where that
/// invariant is enforced.
pub(crate) struct EndpointShard<'a> {
    base: usize,
    cycle: u64,
    phase: u8,
    lanes: &'a mut [Lane],
    stats: NetStats,
}

impl<'a> EndpointShard<'a> {
    pub(crate) fn new(base: usize, cycle: u64, lanes: &'a mut [Lane]) -> Self {
        EndpointShard { base, cycle, phase: PHASE_EXTERNAL, lanes, stats: NetStats::default() }
    }

    fn idx(&self, node: NodeId) -> usize {
        assert!(
            node.0 >= self.base && node.0 - self.base < self.lanes.len(),
            "endpoint access outside shard: node {} not in [{}, {})",
            node.0,
            self.base,
            self.base + self.lanes.len()
        );
        node.0 - self.base
    }

    /// Release the lane borrow, handing back the slice (for the fused
    /// endpoint+fabric worker) and the stats delta accumulated by sends.
    pub(crate) fn finish(self) -> (&'a mut [Lane], NetStats) {
        (self.lanes, self.stats)
    }
}

impl NetPort for EndpointShard<'_> {
    fn cycle(&self) -> u64 {
        self.cycle
    }

    fn send(&mut self, from: NodeId, pkt: Packet) -> PacketId {
        let i = self.idx(from);
        lane_send(&mut self.lanes[i], self.cycle, self.phase, from, pkt, None, &mut self.stats)
    }

    fn send_gated(&mut self, from: NodeId, pkt: Packet, gate: Gate) -> PacketId {
        let i = self.idx(from);
        lane_send(
            &mut self.lanes[i],
            self.cycle,
            self.phase,
            from,
            pkt,
            Some(gate),
            &mut self.stats,
        )
    }

    fn eject_in_progress(&self, node: NodeId) -> Vec<(PacketId, Arc<Packet>, u32)> {
        self.lanes[self.idx(node)]
            .eject
            .iter()
            .map(|(&id, st)| (id, st.packet.clone(), st.arrived))
            .collect()
    }

    fn progress_of(&self, node: NodeId, id: PacketId) -> Option<u32> {
        self.lanes[self.idx(node)].eject.get(&id).map(|e| e.arrived)
    }

    fn recv(&mut self, node: NodeId) -> Option<Arc<Packet>> {
        let i = self.idx(node);
        self.lanes[i].inbox.pop_front()
    }

    fn set_phase(&mut self, phase: u8) {
        self.phase = phase;
    }
}

/// Shared quiet-consensus vote for the fused endpoint+fabric worker (see
/// `soc::Soc::tick_parallel`): each worker ORs in its shard's busyness
/// before the barrier; all read the verdict after it. Relaxed ordering
/// suffices — the barrier provides the happens-before edge.
pub(crate) struct QuietVote(AtomicBool);

impl QuietVote {
    pub(crate) fn new() -> Self {
        QuietVote(AtomicBool::new(false))
    }

    /// Record this shard's vote: lanes with any fabric work mark the
    /// whole tick busy.
    pub(crate) fn report(&self, lanes: &[Lane]) {
        if !lanes.iter().all(Lane::fabric_quiet) {
            self.0.store(true, Ordering::Relaxed);
        }
    }

    /// The global verdict. Only valid after a barrier following every
    /// shard's [`QuietVote::report`].
    pub(crate) fn busy(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::packet::Message;
    use crate::noc::topology::{Mesh, Ring, Torus, Topology};
    use crate::sim::FaultPlan;

    #[test]
    fn shard_ranges_tile_and_balance() {
        assert_eq!(shard_ranges(10, 3), vec![0..4, 4..7, 7..10]);
        assert_eq!(shard_ranges(4, 4), vec![0..1, 1..2, 2..3, 3..4]);
        assert_eq!(shard_ranges(4, 9), vec![0..1, 1..2, 2..3, 3..4], "shards clamp to nodes");
        assert_eq!(shard_ranges(5, 1), vec![0..5]);
        assert_eq!(shard_ranges(5, 0), vec![0..5], "0 threads means sequential");
        for (n, t) in [(64, 4), (20, 3), (9, 2), (4096, 16)] {
            let r = shard_ranges(n, t);
            assert_eq!(r[0].start, 0);
            assert_eq!(r.last().unwrap().end, n);
            assert!(r.windows(2).all(|w| w[0].end == w[1].start), "gap in ranges");
            let (lo, hi) = (
                r.iter().map(|x| x.len()).min().unwrap(),
                r.iter().map(|x| x.len()).max().unwrap(),
            );
            assert!(hi - lo <= 1, "unbalanced shards for n={n} t={t}");
            for node in 0..n {
                assert!(r[shard_of(&r, node)].contains(&node));
            }
        }
    }

    /// Drive the same traffic through sequential and sharded fabric
    /// ticks and require identical delivery cycles, stats and payloads.
    fn assert_fabric_equivalent(mk: impl Fn() -> Network, threads: usize, max_cycles: u64) {
        let mut seq = mk();
        let mut par = mk();
        let mut delivered_seq: Vec<(u64, usize, PacketId)> = Vec::new();
        let mut delivered_par: Vec<(u64, usize, PacketId)> = Vec::new();
        for _ in 0..max_cycles {
            seq.tick();
            par.tick_parallel(threads);
            for node in 0..seq.topo.n_nodes() {
                while let Some(p) = seq.recv(NodeId(node)) {
                    delivered_seq.push((seq.cycle, node, p.id));
                }
                while let Some(p) = par.recv(NodeId(node)) {
                    delivered_par.push((par.cycle, node, p.id));
                }
            }
            if seq.is_idle() && par.is_idle() {
                break;
            }
        }
        assert!(seq.is_idle() && par.is_idle(), "traffic did not drain");
        assert_eq!(delivered_seq, delivered_par, "delivery schedule diverged");
        assert_eq!(seq.stats.flit_hops, par.stats.flit_hops);
        assert_eq!(seq.stats.flit_ejections, par.stats.flit_ejections);
        assert_eq!(seq.stats.packets_delivered, par.stats.packets_delivered);
        assert_eq!(seq.stats.flits_dropped, par.stats.flits_dropped);
    }

    fn all_to_one(topo: impl Into<Topo> + Copy) -> impl Fn() -> Network {
        move || {
            let mut n = Network::new(topo);
            let nodes = n.topo.n_nodes();
            for src in 0..nodes {
                if src == nodes - 1 {
                    continue;
                }
                n.send(
                    NodeId(src),
                    Packet::new(0, NodeId(src), NodeId(nodes - 1), Message::Raw(src as u64))
                        .with_phantom_payload(64 * (1 + src % 7)),
                );
            }
            n
        }
    }

    #[test]
    fn parallel_fabric_matches_sequential_on_mesh() {
        for threads in [2, 3, 4, 16] {
            assert_fabric_equivalent(all_to_one(Mesh::new(4, 4)), threads, 10_000);
        }
    }

    #[test]
    fn parallel_fabric_matches_sequential_on_torus_and_ring() {
        assert_fabric_equivalent(all_to_one(Torus::new(4, 4)), 4, 10_000);
        assert_fabric_equivalent(all_to_one(Ring::new(9)), 4, 10_000);
    }

    #[test]
    fn parallel_fabric_matches_sequential_with_multicast() {
        let mk = || {
            let mut n = Network::new(Mesh::new(4, 4));
            n.send(
                NodeId(0),
                Packet::new(0, NodeId(0), NodeId(3), Message::Raw(1))
                    .with_phantom_payload(512)
                    .with_mcast(vec![NodeId(3), NodeId(12), NodeId(15), NodeId(5)]),
            );
            n.send(
                NodeId(15),
                Packet::new(0, NodeId(15), NodeId(0), Message::Raw(2)).with_phantom_payload(256),
            );
            n
        };
        for threads in [2, 4] {
            assert_fabric_equivalent(mk, threads, 10_000);
        }
    }

    #[test]
    fn parallel_fabric_matches_sequential_under_faults() {
        // Kills and a straggler land mid-stream; activation is a main-
        // thread barrier event in the parallel tick and must produce the
        // same drop set and drain cycle as the sequential kernel.
        let mk = || {
            let mut n = Network::new(Mesh::new(4, 4));
            n.install_faults(&FaultPlan::parse("router:5@30;link:9-10@20;straggle:6x3@0").unwrap());
            for src in [0usize, 3, 12, 8] {
                n.send(
                    NodeId(src),
                    Packet::new(0, NodeId(src), NodeId(10), Message::Raw(src as u64))
                        .with_phantom_payload(64 * 20),
                );
            }
            n
        };
        for threads in [2, 4] {
            assert_fabric_equivalent(mk, threads, 20_000);
        }
    }

    #[test]
    fn tick_parallel_with_one_thread_is_the_sequential_kernel() {
        // Not just equivalent — the same code path (ranges collapse to
        // one shard), so Parallel{1} ≡ EventDriven holds by construction.
        let mk = all_to_one(Mesh::new(3, 3));
        assert_fabric_equivalent(mk, 1, 10_000);
    }

    #[test]
    fn endpoint_shard_sends_compose_the_sequential_ids() {
        let mut seq = Network::new(Mesh::new(4, 1));
        let a = seq.send(NodeId(1), Packet::new(0, NodeId(1), NodeId(0), Message::Raw(0)));
        let b = seq.send(NodeId(2), Packet::new(0, NodeId(2), NodeId(0), Message::Raw(1)));

        let mut par = Network::new(Mesh::new(4, 1));
        let ranges = shard_ranges(4, 2);
        let mut shards = par.endpoint_shards(&ranges);
        // Reverse order on purpose: id values must not depend on which
        // shard sends first.
        let b2 = shards[1].send(NodeId(2), Packet::new(0, NodeId(2), NodeId(0), Message::Raw(1)));
        let a2 = shards[0].send(NodeId(1), Packet::new(0, NodeId(1), NodeId(0), Message::Raw(0)));
        let deltas: Vec<NetStats> = shards.into_iter().map(|s| s.finish().1).collect();
        for d in &deltas {
            par.stats.merge(d);
        }
        assert_eq!((a, b), (a2, b2));
        assert_eq!(par.stats.packets_sent, 2);
    }

    #[test]
    #[should_panic(expected = "endpoint access outside shard")]
    fn endpoint_shard_rejects_foreign_nodes() {
        let mut n = Network::new(Mesh::new(4, 1));
        let ranges = shard_ranges(4, 2);
        let mut shards = n.endpoint_shards(&ranges);
        shards[0].send(NodeId(3), Packet::new(0, NodeId(3), NodeId(0), Message::Raw(0)));
    }
}
